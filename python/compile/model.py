"""L2 JAX model: the benchmark workload model and the report statistics model.

The Big Atomics paper's experimental methodology (§5) is parameterized
operation streams: each of p threads repeatedly draws a target index from a
Zipfian(z) distribution over n items and an operation kind from an update
fraction u (updates split evenly between insert and delete; the rest are
finds — §5.1/§5.2).  This module is that methodology as a JAX computation:

    workload_model:  (bits, op_bits, cdf, u_frac) -> (idx, op, key)
    stats_model:     (latencies_ns)               -> (mean, p50, p90, p99, max)

Both call the L1 Pallas kernels, are lowered ONCE by aot.py to HLO text,
and are executed from the Rust coordinator via PJRT (rust/src/runtime/).
Python never runs on the benchmark path.

Operation encoding (shared contract with rust/src/bench/workload.rs):
    0 = find/load, 1 = insert/cas-install, 2 = delete/cas-clear
An op is an update iff op_bits * 2^-32 < u_frac; updates alternate
insert/delete by the low bit of the op word, exactly like the Rust
generator — the two are cross-validated bit-for-bit in
rust/tests/runtime_artifacts.rs.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from .kernels import hashmix, zipfian

# Static shapes baked into the AOT artifacts (see aot.py and
# artifacts/manifest.txt).  One artifact execution produces BATCH ops.
BATCH = 65536
N_CDF = zipfian.N_CDF

_INV_2_32 = 2.3283064365386963e-10


def workload_model(
    bits: jax.Array,      # uint32[BATCH] — index randomness
    op_bits: jax.Array,   # uint32[BATCH] — op-kind randomness
    cdf: jax.Array,       # float32[N_CDF] — Zipfian CDF (see zipfian.make_zipf_cdf)
    u_frac: jax.Array,    # float32[] — update fraction in [0, 1]
):
    """One batch of benchmark operations: (idx int32, op int32, key uint64)."""
    idx = zipfian.zipfian_indices(bits, cdf, batch=BATCH)
    r = op_bits.astype(jnp.float32) * jnp.float32(_INV_2_32)
    is_update = r < u_frac
    # Updates split evenly between insert (1) and delete (2) on the op
    # word's low bit; finds are 0.
    upd_kind = 1 + (op_bits & jnp.uint32(1)).astype(jnp.int32)
    op = jnp.where(is_update, upd_kind, 0)
    key = hashmix.hashmix(idx.astype(jnp.uint64), batch=BATCH)
    return idx, op, key


def stats_model(latencies_ns: jax.Array):
    """Latency summary for the coordinator's reports.

    Args:
      latencies_ns: float32[BATCH] per-request latencies (ns).

    Returns:
      float32[5]: (mean, p50, p90, p99, max).
    """
    s = jnp.sort(latencies_ns)
    n = latencies_ns.shape[0]

    def q(p):
        return s[jnp.int32(min(n - 1, int(round(p * (n - 1)))))]

    return jnp.stack([jnp.mean(s), q(0.50), q(0.90), q(0.99), s[n - 1]])


@functools.partial(jax.jit, static_argnames=())
def workload_jit(bits, op_bits, cdf, u_frac):
    return workload_model(bits, op_bits, cdf, u_frac)


@jax.jit
def stats_jit(latencies_ns):
    return stats_model(latencies_ns)


def example_args_workload():
    """ShapeDtypeStructs matching the AOT signature of workload_model."""
    return (
        jax.ShapeDtypeStruct((BATCH,), jnp.uint32),
        jax.ShapeDtypeStruct((BATCH,), jnp.uint32),
        jax.ShapeDtypeStruct((N_CDF,), jnp.float32),
        jax.ShapeDtypeStruct((), jnp.float32),
    )


def example_args_stats():
    return (jax.ShapeDtypeStruct((BATCH,), jnp.float32),)
