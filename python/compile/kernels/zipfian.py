"""L1 Pallas kernel: inverse-CDF Zipfian sampling.

The Big Atomics paper's evaluation (§5) draws every operation's target
index from a Zipfian distribution with parameter z (z=0 uniform,
z→1 extremely contended).  This kernel is the hot loop of the workload
generator: it maps a batch of uniform 32-bit random words to Zipfian
indices by an unrolled, branch-free binary search over a precomputed,
monotone CDF table.

TPU adaptation notes (DESIGN.md §Hardware-Adaptation):
  * the CDF table (N_CDF f32 entries, 16 KB at 4K) is a single VMEM
    block via BlockSpec — it is reused by every lane, the classic
    "broadcast small table, stream big batch" shape;
  * the binary search is unrolled to exactly log2(N_CDF) steps with no
    data-dependent control flow, so it lowers to pure vector selects
    (VPU-friendly, nothing for the MXU to do);
  * interpret=True is mandatory here: real-TPU lowering produces a
    Mosaic custom-call the CPU PJRT plugin cannot execute.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Fixed table resolution for the AOT artifact.  4096 gives a max CDF
# quantization error of ~2.4e-4 in probability, far below anything the
# throughput benchmarks can resolve; Rust builds an exact n-entry CDF when
# n <= N_CDF and a stratified one otherwise (see rust/src/bench/workload.rs).
N_CDF = 4096
LOG2_N_CDF = 12

# 1/2^32 as f32; converts a uniform u32 to a uniform f32 in [0, 1).
_INV_2_32 = 2.3283064365386963e-10


def _zipfian_kernel(bits_ref, cdf_ref, out_ref):
    """Map uniform u32 `bits` to indices via binary search on `cdf`.

    out[i] = smallest j such that u[i] < cdf[j], where u = bits * 2^-32.
    cdf must be non-decreasing with cdf[N_CDF - 1] >= 1.0.
    """
    bits = bits_ref[...]
    cdf = cdf_ref[...]
    u = bits.astype(jnp.float32) * jnp.float32(_INV_2_32)

    # Branch-free unrolled binary search: after the loop, `lo` is the count
    # of CDF entries <= u, i.e. the first index with cdf[idx] > u.
    lo = jnp.zeros(bits.shape, dtype=jnp.int32)
    step = N_CDF // 2
    for _ in range(LOG2_N_CDF):
        probe = lo + (step - 1)
        val = jnp.take(cdf, probe, axis=0)
        lo = jnp.where(val <= u, lo + step, lo)
        step //= 2
    # bits >= 2^32 - 128 round to u == 1.0 (f32), which is <= every padded
    # CDF entry and would index one past the table: clamp (same clamp in
    # ref.py and rust/src/bench/workload.rs — the contract is bit-exact).
    out_ref[...] = jnp.minimum(lo, N_CDF - 1)


@functools.partial(jax.jit, static_argnames=("batch",))
def zipfian_indices(bits: jax.Array, cdf: jax.Array, *, batch: int) -> jax.Array:
    """Batch-map uniform u32 words to Zipfian indices (Pallas, interpret).

    Args:
      bits: uint32[batch] uniform random words.
      cdf:  float32[N_CDF] non-decreasing CDF table, cdf[-1] >= 1.0.
      batch: static batch size (== bits.shape[0]).

    Returns:
      int32[batch] indices in [0, N_CDF).
    """
    return pl.pallas_call(
        _zipfian_kernel,
        out_shape=jax.ShapeDtypeStruct((batch,), jnp.int32),
        interpret=True,
    )(bits, cdf)


def make_zipf_cdf(n: int, theta: float) -> jax.Array:
    """Zipfian CDF over n items with exponent theta, padded to N_CDF.

    Matches the YCSB [13] Zipfian used by the paper: P(i) ∝ 1/(i+1)^theta.
    For n < N_CDF the tail is padded with 1.0 (those indices are never
    produced).  Computed in float64-ish via cumulative sums of f32 — fine
    for the table sizes used here.
    """
    if n > N_CDF:
        raise ValueError(f"n={n} exceeds CDF table resolution {N_CDF}")
    ranks = jnp.arange(1, n + 1, dtype=jnp.float32)
    weights = ranks ** jnp.float32(-theta)
    cdf = jnp.cumsum(weights) / jnp.sum(weights)
    cdf = cdf.at[n - 1].set(1.0)
    pad = jnp.ones((N_CDF - n,), dtype=jnp.float32)
    return jnp.concatenate([cdf, pad]).astype(jnp.float32)
