"""Pure-jnp correctness oracles for the L1 Pallas kernels.

Every kernel in this package has an oracle here with the same signature;
python/tests asserts bit-exact (integer) or allclose (float) agreement.
The oracles deliberately use a *different* formulation (searchsorted vs
unrolled binary search; scalar-python vs lane-wise mix) so a shared bug
cannot hide.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

_INV_2_32 = 2.3283064365386963e-10

_C1 = 0xFF51AFD7ED558CCD
_C2 = 0xC4CEB9FE1A85EC53
_MASK64 = (1 << 64) - 1


def zipfian_indices_ref(bits: jax.Array, cdf: jax.Array) -> jax.Array:
    """Oracle for zipfian.zipfian_indices: jnp.searchsorted formulation.

    Returns the first index j with cdf[j] > u, identical to the kernel's
    "count of entries <= u".
    """
    u = bits.astype(jnp.float32) * jnp.float32(_INV_2_32)
    idx = jnp.searchsorted(cdf, u, side="right").astype(jnp.int32)
    return jnp.minimum(idx, cdf.shape[0] - 1)  # clamp the u == 1.0 edge


def hashmix_ref(keys: jax.Array) -> jax.Array:
    """Oracle for hashmix.hashmix (vector jnp, same algebra)."""
    x = keys.astype(jnp.uint64)
    x = x ^ (x >> jnp.uint64(33))
    x = x * jnp.uint64(_C1)
    x = x ^ (x >> jnp.uint64(33))
    x = x * jnp.uint64(_C2)
    x = x ^ (x >> jnp.uint64(33))
    return x


def mix64_py(x: int) -> int:
    """Scalar python reference of the same mix (used to validate both)."""
    x &= _MASK64
    x ^= x >> 33
    x = (x * _C1) & _MASK64
    x ^= x >> 33
    x = (x * _C2) & _MASK64
    x ^= x >> 33
    return x
