"""L1 Pallas kernel: 64-bit key mixing (murmur3 fmix64 variant).

CacheHash (paper §4) hashes 8-byte keys to bucket indices.  The benchmark
workload derives the key stream from the Zipfian index stream by a strong
64-bit mix so that (a) contended indices map to stable keys, preserving the
Zipfian contention structure, and (b) bucket residency is uniform, matching
the paper's "load factor one" setup.

This is the exact finalizer used by rust/src/hash/mod.rs::mix64 — the
integration test `runtime_artifacts.rs` cross-checks the two bit-for-bit.

Runs under jax_enable_x64 (uint64 lanes); interpret=True as always.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# murmur3 fmix64 constants.
_C1 = 0xFF51AFD7ED558CCD
_C2 = 0xC4CEB9FE1A85EC53


def _mix64(x: jax.Array) -> jax.Array:
    x = x ^ (x >> jnp.uint64(33))
    x = x * jnp.uint64(_C1)
    x = x ^ (x >> jnp.uint64(33))
    x = x * jnp.uint64(_C2)
    x = x ^ (x >> jnp.uint64(33))
    return x


def _hashmix_kernel(keys_ref, out_ref):
    out_ref[...] = _mix64(keys_ref[...])


@functools.partial(jax.jit, static_argnames=("batch",))
def hashmix(keys: jax.Array, *, batch: int) -> jax.Array:
    """Mix uint64[batch] keys with murmur3's fmix64 (Pallas, interpret)."""
    return pl.pallas_call(
        _hashmix_kernel,
        out_shape=jax.ShapeDtypeStruct((batch,), jnp.uint64),
        interpret=True,
    )(keys)
