"""AOT lowering: JAX model -> HLO *text* artifacts for the Rust runtime.

HLO text (NOT ``lowered.compile()`` / serialized HloModuleProto) is the
interchange format: jax >= 0.5 emits protos with 64-bit instruction ids
which the xla crate's xla_extension 0.5.1 rejects (``proto.id() <=
INT_MAX``); the text parser reassigns ids and round-trips cleanly.
See /opt/xla-example/README.md.

Artifacts (written to --outdir, default ../artifacts):
    workload.hlo.txt  — model.workload_model  (bits, op_bits, cdf, u) -> (idx, op, key)
    stats.hlo.txt     — model.stats_model     (latencies) -> summary[5]
    manifest.txt      — key=value contract (batch size, cdf resolution, ...)

Run via ``make artifacts`` (no-op when inputs are unchanged).
"""

from __future__ import annotations

import argparse
import os

import jax

jax.config.update("jax_enable_x64", True)  # uint64 keys in hashmix

from jax._src.lib import xla_client as xc  # noqa: E402

from . import model  # noqa: E402


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (id-safe interchange)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def lower_all(outdir: str) -> dict[str, str]:
    os.makedirs(outdir, exist_ok=True)
    written = {}

    wl = jax.jit(model.workload_model).lower(*model.example_args_workload())
    written["workload.hlo.txt"] = to_hlo_text(wl)

    st = jax.jit(model.stats_model).lower(*model.example_args_stats())
    written["stats.hlo.txt"] = to_hlo_text(st)

    manifest = (
        f"batch={model.BATCH}\n"
        f"n_cdf={model.N_CDF}\n"
        "workload_inputs=bits:u32[batch],op_bits:u32[batch],cdf:f32[n_cdf],u_frac:f32[]\n"
        "workload_outputs=idx:s32[batch],op:s32[batch],key:u64[batch]\n"
        "stats_inputs=latencies_ns:f32[batch]\n"
        "stats_outputs=summary:f32[5]  # mean,p50,p90,p99,max\n"
        "op_encoding=0:find 1:insert 2:delete\n"
    )
    written["manifest.txt"] = manifest

    for name, text in written.items():
        path = os.path.join(outdir, name)
        with open(path, "w") as f:
            f.write(text)
        print(f"wrote {path} ({len(text)} chars)")
    return written


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--outdir", default="../artifacts")
    args = ap.parse_args()
    lower_all(args.outdir)


if __name__ == "__main__":
    main()
