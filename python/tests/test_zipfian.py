"""L1 kernel vs oracle: Zipfian inverse-CDF sampler.

Bit-exact agreement between the unrolled-binary-search Pallas kernel and
the jnp.searchsorted oracle, plus distributional sanity (empirical
frequencies track the analytic Zipfian pmf).  Hypothesis sweeps seeds,
table sizes, and exponents.
"""

import jax

jax.config.update("jax_enable_x64", True)

import jax.numpy as jnp
import numpy as np
import pytest

# hypothesis is not in the offline container: the vendored mini-strategy
# shim (ministrategy.py — seeded, shrink-free sampling of the same API
# slice) keeps the property sweep running instead of skipping.
try:
    from hypothesis import given, settings
    from hypothesis import strategies as st
except ImportError:  # offline container
    from ministrategy import given, settings
    from ministrategy import strategies as st

from compile.kernels import ref, zipfian

SMALL_BATCH = 1024


def _bits(seed: int, batch: int) -> jax.Array:
    key = jax.random.PRNGKey(seed)
    return jax.random.bits(key, (batch,), dtype=jnp.uint32)


@pytest.mark.parametrize("theta", [0.0, 0.5, 0.75, 0.99])
@pytest.mark.parametrize("n", [2, 16, 1000, zipfian.N_CDF])
def test_kernel_matches_oracle(theta, n):
    cdf = zipfian.make_zipf_cdf(n, theta)
    bits = _bits(n * 31 + int(theta * 100), SMALL_BATCH)
    got = zipfian.zipfian_indices(bits, cdf, batch=SMALL_BATCH)
    want = ref.zipfian_indices_ref(bits, cdf)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


@settings(max_examples=25, deadline=None)
@given(
    seed=st.integers(0, 2**31 - 1),
    n=st.integers(1, zipfian.N_CDF),
    theta=st.floats(0.0, 0.999),
)
def test_kernel_matches_oracle_hypothesis(seed, n, theta):
    cdf = zipfian.make_zipf_cdf(n, theta)
    bits = _bits(seed, SMALL_BATCH)
    got = zipfian.zipfian_indices(bits, cdf, batch=SMALL_BATCH)
    want = ref.zipfian_indices_ref(bits, cdf)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_indices_in_range():
    n = 100
    cdf = zipfian.make_zipf_cdf(n, 0.9)
    bits = _bits(7, SMALL_BATCH)
    idx = np.asarray(zipfian.zipfian_indices(bits, cdf, batch=SMALL_BATCH))
    assert idx.min() >= 0
    assert idx.max() < n  # padded tail must be unreachable


def test_uniform_is_uniform():
    """theta=0 must be the uniform distribution (paper's z=0)."""
    n = 64
    cdf = zipfian.make_zipf_cdf(n, 0.0)
    bits = _bits(11, 1 << 16)
    idx = np.asarray(zipfian.zipfian_indices(bits, cdf, batch=1 << 16))
    counts = np.bincount(idx, minlength=n)
    expected = (1 << 16) / n
    # chi^2-ish loose bound: every bucket within 5 sigma of expectation.
    assert np.all(np.abs(counts - expected) < 5 * np.sqrt(expected) + 10)


def test_zipf_frequencies_match_pmf():
    """Empirical frequencies track the analytic Zipf pmf at theta=0.9."""
    n, theta = 32, 0.9
    cdf = zipfian.make_zipf_cdf(n, theta)
    bits = _bits(13, 1 << 17)
    idx = np.asarray(zipfian.zipfian_indices(bits, cdf, batch=1 << 17))
    counts = np.bincount(idx, minlength=n).astype(np.float64)
    freqs = counts / counts.sum()
    ranks = np.arange(1, n + 1, dtype=np.float64)
    pmf = ranks**-theta / np.sum(ranks**-theta)
    np.testing.assert_allclose(freqs, pmf, atol=0.01)


def test_hot_key_dominates_at_high_theta():
    """As z -> 1 the head index dominates (the paper's contention knob)."""
    n = 1000
    cdf = zipfian.make_zipf_cdf(n, 0.99)
    bits = _bits(17, 1 << 16)
    idx = np.asarray(zipfian.zipfian_indices(bits, cdf, batch=1 << 16))
    head_share = np.mean(idx == 0)
    assert head_share > 0.10  # analytic ~0.13 at n=1000, z=.99


def test_cdf_monotone_and_complete():
    for n in (1, 7, 4096):
        cdf = np.asarray(zipfian.make_zipf_cdf(n, 0.7))
        assert np.all(np.diff(cdf) >= -1e-7)
        assert cdf[-1] >= 1.0
        assert cdf.shape == (zipfian.N_CDF,)


def test_cdf_rejects_oversize():
    with pytest.raises(ValueError):
        zipfian.make_zipf_cdf(zipfian.N_CDF + 1, 0.5)
