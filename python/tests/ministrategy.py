"""Vendored mini-strategy shim: a deterministic, shrink-free stand-in
for the slice of the `hypothesis` API these tests use (ROADMAP open
item: the offline container has no hypothesis, and the property sweeps
used to skip there).

Scope — exactly what test_hashmix.py / test_zipfian.py need:

* ``@given(**kwargs)`` with keyword strategies,
* ``@settings(max_examples=..., deadline=...)`` in either decorator
  order,
* ``strategies.integers / floats / booleans / sampled_from / tuples``.

Examples are drawn with a ``random.Random`` seeded from the test's name
(Python's version-2 string seeding hashes via SHA-512, so the stream is
stable across processes, platforms, and PYTHONHASHSEED) — failures
reproduce by rerunning the same test.  There is **no shrinking**: the
failing example's kwargs appear in the assertion traceback instead.
"""

import random

_DEFAULT_MAX_EXAMPLES = 100


class _Strategy:
    """A sampling rule: ``sample(rng)`` draws one value."""

    def __init__(self, sample):
        self._sample = sample

    def sample(self, rng):
        return self._sample(rng)


class strategies:
    """Namespace mirroring ``hypothesis.strategies`` (import as ``st``)."""

    @staticmethod
    def integers(min_value=0, max_value=2**64 - 1):
        return _Strategy(lambda rng: rng.randint(min_value, max_value))

    @staticmethod
    def floats(min_value=0.0, max_value=1.0, **_ignored):
        return _Strategy(lambda rng: rng.uniform(min_value, max_value))

    @staticmethod
    def booleans():
        return _Strategy(lambda rng: rng.random() < 0.5)

    @staticmethod
    def sampled_from(seq):
        items = list(seq)
        return _Strategy(lambda rng: items[rng.randrange(len(items))])

    @staticmethod
    def tuples(*parts):
        return _Strategy(lambda rng: tuple(p.sample(rng) for p in parts))


def settings(max_examples=_DEFAULT_MAX_EXAMPLES, deadline=None, **_ignored):
    """Record ``max_examples``; works above or below ``@given``."""

    def deco(fn):
        if getattr(fn, "_ms_sweep", False):
            # @given already wrapped fn: configure the sweep directly.
            fn._ms_max_examples = max_examples
        else:
            # @given not applied yet: stash for it to pick up.
            fn._ms_pending_max = max_examples
        return fn

    return deco


def given(**strats):
    """Run the test once per drawn example (deterministic sweep)."""

    def deco(fn):
        pending = getattr(fn, "_ms_pending_max", None)

        def sweep(*args, **kwargs):
            rng = random.Random("ministrategy::" + fn.__name__)
            for _ in range(sweep._ms_max_examples):
                drawn = {name: s.sample(rng) for name, s in strats.items()}
                drawn.update(kwargs)  # explicit kwargs win (fixtures)
                fn(*args, **drawn)

        # Copy identity by hand: functools.wraps would also set
        # __wrapped__, which pytest follows to the original signature and
        # then demands a fixture per strategy parameter.
        sweep.__name__ = fn.__name__
        sweep.__qualname__ = getattr(fn, "__qualname__", fn.__name__)
        sweep.__doc__ = fn.__doc__
        sweep.__module__ = fn.__module__
        sweep._ms_sweep = True
        sweep._ms_max_examples = (
            pending if pending is not None else _DEFAULT_MAX_EXAMPLES
        )
        return sweep

    return deco
