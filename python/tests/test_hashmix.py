"""L1 kernel vs oracle: 64-bit key mixer."""

import jax

jax.config.update("jax_enable_x64", True)

import jax.numpy as jnp
import numpy as np

# hypothesis is not in the offline container: the vendored mini-strategy
# shim (ministrategy.py — seeded, shrink-free sampling of the same API
# slice) keeps the property sweeps running instead of skipping.
try:
    from hypothesis import given, settings
    from hypothesis import strategies as st
except ImportError:  # offline container
    from ministrategy import given, settings
    from ministrategy import strategies as st

from compile.kernels import hashmix, ref

BATCH = 1024


def _keys(seed: int) -> jax.Array:
    key = jax.random.PRNGKey(seed)
    return jax.random.bits(key, (BATCH,), dtype=jnp.uint64)


def test_kernel_matches_oracle():
    keys = _keys(3)
    got = hashmix.hashmix(keys, batch=BATCH)
    want = ref.hashmix_ref(keys)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


@settings(max_examples=25, deadline=None)
@given(seed=st.integers(0, 2**31 - 1))
def test_kernel_matches_oracle_hypothesis(seed):
    keys = _keys(seed)
    got = hashmix.hashmix(keys, batch=BATCH)
    want = ref.hashmix_ref(keys)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


@given(x=st.integers(0, 2**64 - 1))
@settings(max_examples=50, deadline=None)
def test_vector_matches_scalar_python(x):
    """The jnp lane algebra equals the pure-python big-int reference."""
    got = int(np.asarray(ref.hashmix_ref(jnp.array([x], dtype=jnp.uint64)))[0])
    assert got == ref.mix64_py(x)


def test_known_vectors():
    """Fixed vectors shared with rust/src/hash/mod.rs::mix64 unit tests.

    If these change, the Rust test_mix64_known_vectors must change too —
    the runtime cross-validation test depends on bit-equality.
    """
    vecs = {
        0: ref.mix64_py(0),
        1: ref.mix64_py(1),
        0xDEADBEEF: ref.mix64_py(0xDEADBEEF),
    }
    # mix64 of 0 is 0 for fmix64 (all-zero input is its fixed point).
    assert vecs[0] == 0
    assert vecs[1] == 0xB456BCFC34C2CB2C
    assert vecs[0xDEADBEEF] == 0xD24BD59F862A1DAC


def test_mix_is_injective_on_sample():
    """No collisions on 2^17 distinct inputs (birthday-safe for 64-bit)."""
    xs = np.arange(1 << 17, dtype=np.uint64)
    out = np.asarray(ref.hashmix_ref(jnp.asarray(xs)))
    assert len(np.unique(out)) == len(xs)


def test_avalanche():
    """Flipping one input bit flips ~32 output bits on average."""
    rng = np.random.default_rng(0)
    xs = rng.integers(0, 2**63, size=256, dtype=np.uint64)
    for bit in (0, 17, 63):
        flipped = xs ^ np.uint64(1 << bit)
        a = np.asarray(ref.hashmix_ref(jnp.asarray(xs)))
        b = np.asarray(ref.hashmix_ref(jnp.asarray(flipped)))
        popcounts = np.array([bin(int(v)).count("1") for v in a ^ b])
        assert 24 < popcounts.mean() < 40
