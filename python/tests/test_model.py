"""L2 model tests: workload stream semantics and stats model."""

import jax

jax.config.update("jax_enable_x64", True)

import jax.numpy as jnp
import numpy as np
import pytest

from compile import model
from compile.kernels import ref, zipfian


def _streams(seed: int):
    k1, k2 = jax.random.split(jax.random.PRNGKey(seed))
    bits = jax.random.bits(k1, (model.BATCH,), dtype=jnp.uint32)
    op_bits = jax.random.bits(k2, (model.BATCH,), dtype=jnp.uint32)
    return bits, op_bits


def test_workload_shapes_and_dtypes():
    bits, op_bits = _streams(0)
    cdf = zipfian.make_zipf_cdf(1000, 0.5)
    idx, op, key = model.workload_jit(bits, op_bits, cdf, jnp.float32(0.5))
    assert idx.shape == (model.BATCH,) and idx.dtype == jnp.int32
    assert op.shape == (model.BATCH,) and op.dtype == jnp.int32
    assert key.shape == (model.BATCH,) and key.dtype == jnp.uint64


@pytest.mark.parametrize("u", [0.0, 0.05, 0.5, 1.0])
def test_update_fraction(u):
    bits, op_bits = _streams(1)
    cdf = zipfian.make_zipf_cdf(1000, 0.0)
    _, op, _ = model.workload_jit(bits, op_bits, cdf, jnp.float32(u))
    op = np.asarray(op)
    frac = np.mean(op != 0)
    assert abs(frac - u) < 0.01
    if u > 0:
        ins, dele = np.mean(op == 1), np.mean(op == 2)
        assert abs(ins - dele) < 0.02  # even insert/delete split


def test_ops_only_in_encoding():
    bits, op_bits = _streams(2)
    cdf = zipfian.make_zipf_cdf(16, 0.99)
    _, op, _ = model.workload_jit(bits, op_bits, cdf, jnp.float32(0.3))
    assert set(np.unique(np.asarray(op))) <= {0, 1, 2}


def test_keys_are_mixed_indices():
    bits, op_bits = _streams(3)
    cdf = zipfian.make_zipf_cdf(100, 0.5)
    idx, _, key = model.workload_jit(bits, op_bits, cdf, jnp.float32(0.0))
    want = ref.hashmix_ref(np.asarray(idx).astype(np.uint64))
    np.testing.assert_array_equal(np.asarray(key), np.asarray(want))


def test_stats_model():
    lat = jnp.arange(model.BATCH, dtype=jnp.float32)
    out = np.asarray(model.stats_jit(lat))
    n = model.BATCH
    assert out.shape == (5,)
    np.testing.assert_allclose(out[0], (n - 1) / 2.0, rtol=1e-5)  # mean
    np.testing.assert_allclose(out[1], round(0.50 * (n - 1)), rtol=1e-6)
    np.testing.assert_allclose(out[2], round(0.90 * (n - 1)), rtol=1e-6)
    np.testing.assert_allclose(out[3], round(0.99 * (n - 1)), rtol=1e-6)
    np.testing.assert_allclose(out[4], n - 1, rtol=0)


def test_stats_model_unsorted_input():
    rng = np.random.default_rng(0)
    lat = rng.permutation(np.arange(model.BATCH)).astype(np.float32)
    out = np.asarray(model.stats_jit(jnp.asarray(lat)))
    assert out[4] == model.BATCH - 1
    assert out[1] <= out[2] <= out[3] <= out[4]
