import os
import sys

# Make `compile` importable when pytest runs from python/ or repo root,
# and `ministrategy` (the vendored hypothesis shim) importable even when
# pytest does not add the tests dir itself to sys.path.
_TESTS = os.path.dirname(os.path.abspath(__file__))
sys.path.insert(0, os.path.dirname(_TESTS))
sys.path.insert(0, _TESTS)
