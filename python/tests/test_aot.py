"""AOT emission tests: HLO text artifacts exist, parse, and carry the contract."""

import os
import tempfile

import jax

jax.config.update("jax_enable_x64", True)

from compile import aot, model


def test_lower_all_writes_artifacts():
    with tempfile.TemporaryDirectory() as d:
        written = aot.lower_all(d)
        for name in ("workload.hlo.txt", "stats.hlo.txt", "manifest.txt"):
            assert name in written
            path = os.path.join(d, name)
            assert os.path.getsize(path) > 0


def test_workload_hlo_text_shape_contract():
    with tempfile.TemporaryDirectory() as d:
        written = aot.lower_all(d)
        hlo = written["workload.hlo.txt"]
        # ENTRY computation must mention the batch-shaped params and the
        # tuple result types Rust expects.
        assert f"u32[{model.BATCH}]" in hlo
        assert f"f32[{model.N_CDF}]" in hlo
        assert f"s32[{model.BATCH}]" in hlo
        assert f"u64[{model.BATCH}]" in hlo
        assert "ENTRY" in hlo


def test_stats_hlo_text_shape_contract():
    with tempfile.TemporaryDirectory() as d:
        written = aot.lower_all(d)
        hlo = written["stats.hlo.txt"]
        assert f"f32[{model.BATCH}]" in hlo
        assert "f32[5]" in hlo


def test_manifest_contract():
    with tempfile.TemporaryDirectory() as d:
        written = aot.lower_all(d)
        man = written["manifest.txt"]
        assert f"batch={model.BATCH}" in man
        assert f"n_cdf={model.N_CDF}" in man
        assert "op_encoding=0:find 1:insert 2:delete" in man


def test_hlo_reparses_via_xla_client():
    """The emitted text must round-trip through an HLO parser (the same
    property the Rust HloModuleProto::from_text_file loader relies on)."""
    from jax._src.lib import xla_client as xc

    with tempfile.TemporaryDirectory() as d:
        written = aot.lower_all(d)
        # Re-lower and compile on the local CPU client as a proxy for the
        # Rust-side compile (same XLA pipeline).
        lowered = jax.jit(model.workload_model).lower(*model.example_args_workload())
        compiled = lowered.compile()
        assert compiled is not None
        assert len(written["workload.hlo.txt"]) > 1000
