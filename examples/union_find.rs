//! Concurrent union-find with (parent, rank) in one big atomic —
//! the paper's §2 citation of Jayanti–Tarjan [30], whose construction
//! "requires updating three fields atomically".
//!
//! ```bash
//! cargo run --release --example union_find
//! ```
//!
//! Each node holds (parent, rank, collapsed-flag) in a 3-word atomic:
//! union-by-rank and path-halving each become a *single* CAS on one
//! node, with no bit-packing tricks and no restriction on the id width.
//! A randomized multi-threaded stress run is checked against a
//! sequential union-find oracle.

use std::sync::Arc;

use big_atomics::atomics::{BigAtomic, CachedMemEff};
use big_atomics::impl_atomic_value;
use big_atomics::util::rng::Xoshiro256;

#[repr(C, align(8))]
#[derive(Copy, Clone, PartialEq, Eq, Debug, Default)]
struct Node {
    parent: u64,
    rank: u64,
    /// Set once the node is known non-root (lets finds skip a load).
    collapsed: u64,
}

impl_atomic_value!(Node);

struct UnionFind {
    nodes: Vec<CachedMemEff<Node>>,
}

impl UnionFind {
    fn new(n: usize) -> Self {
        Self {
            nodes: (0..n as u64)
                .map(|i| {
                    CachedMemEff::new(Node {
                        parent: i,
                        rank: 0,
                        collapsed: 0,
                    })
                })
                .collect(),
        }
    }

    /// Find with path halving: each halving step is one CAS that
    /// atomically rewrites (parent, collapsed) together.
    fn find(&self, mut x: u64) -> u64 {
        loop {
            let nx = self.nodes[x as usize].load();
            if nx.parent == x {
                return x;
            }
            let np = self.nodes[nx.parent as usize].load();
            if np.parent != nx.parent {
                // Halve: point x at its grandparent (single 3-word CAS;
                // best-effort, so the witness is discarded).
                let _ = self.nodes[x as usize].compare_exchange(
                    nx,
                    Node {
                        parent: np.parent,
                        rank: nx.rank,
                        collapsed: 1,
                    },
                );
            }
            x = nx.parent;
        }
    }

    /// Union by rank. Returns false if already in the same set.
    fn union(&self, a: u64, b: u64) -> bool {
        loop {
            let ra = self.find(a);
            let rb = self.find(b);
            if ra == rb {
                return false;
            }
            let na = self.nodes[ra as usize].load();
            let nb = self.nodes[rb as usize].load();
            // Re-validate rootness (find() result can be stale).
            if na.parent != ra || nb.parent != rb {
                continue;
            }
            let (child, child_val, parent, parent_val) = if na.rank < nb.rank {
                (ra, na, rb, nb)
            } else {
                (rb, nb, ra, na)
            };
            // Attach child root under parent root: one witnessing CAS.
            let attached = self.nodes[child as usize].compare_exchange(
                child_val,
                Node {
                    parent,
                    rank: child_val.rank,
                    collapsed: 1,
                },
            );
            if attached.is_ok() {
                // Possibly bump the parent's rank (best effort: a lost
                // race means someone else restructured — fine).
                if child_val.rank == parent_val.rank {
                    let _ = self.nodes[parent as usize].compare_exchange(
                        parent_val,
                        Node {
                            rank: parent_val.rank + 1,
                            ..parent_val
                        },
                    );
                }
                return true;
            }
        }
    }

    fn same_set(&self, a: u64, b: u64) -> bool {
        loop {
            let ra = self.find(a);
            let rb = self.find(b);
            if ra == rb {
                return true;
            }
            // Stable roots => definitely different sets.
            if self.nodes[ra as usize].load().parent == ra
                && self.nodes[rb as usize].load().parent == rb
            {
                return false;
            }
        }
    }
}

/// Sequential oracle.
struct SeqUf {
    parent: Vec<usize>,
}

impl SeqUf {
    fn new(n: usize) -> Self {
        Self {
            parent: (0..n).collect(),
        }
    }
    fn find(&mut self, x: usize) -> usize {
        if self.parent[x] == x {
            x
        } else {
            let r = self.find(self.parent[x]);
            self.parent[x] = r;
            r
        }
    }
    fn union(&mut self, a: usize, b: usize) {
        let (ra, rb) = (self.find(a), self.find(b));
        if ra != rb {
            self.parent[ra] = rb;
        }
    }
}

fn main() {
    let n = 10_000usize;

    // Phase 1: concurrent unions over a fixed edge list.
    let mut rng = Xoshiro256::seeded(2025);
    let edges: Vec<(u64, u64)> = (0..n * 2)
        .map(|_| {
            (
                rng.next_below(n) as u64,
                rng.next_below(n) as u64,
            )
        })
        .collect();

    let uf = Arc::new(UnionFind::new(n));
    let threads = 4;
    let chunks: Vec<Vec<(u64, u64)>> = edges
        .chunks(edges.len().div_ceil(threads))
        .map(|c| c.to_vec())
        .collect();
    let handles: Vec<_> = chunks
        .into_iter()
        .map(|chunk| {
            let uf = Arc::clone(&uf);
            std::thread::spawn(move || {
                for (a, b) in chunk {
                    uf.union(a, b);
                }
            })
        })
        .collect();
    for h in handles {
        h.join().unwrap();
    }

    // Phase 2: compare connectivity with the sequential oracle.
    let mut oracle = SeqUf::new(n);
    for &(a, b) in &edges {
        oracle.union(a as usize, b as usize);
    }
    let mut rng = Xoshiro256::seeded(7);
    let mut checked = 0;
    for _ in 0..50_000 {
        let a = rng.next_below(n);
        let b = rng.next_below(n);
        let want = oracle.find(a) == oracle.find(b);
        let got = uf.same_set(a as u64, b as u64);
        assert_eq!(got, want, "connectivity mismatch for ({a},{b})");
        checked += 1;
    }
    println!("union_find: {n} nodes, {} unions, {checked} connectivity queries match the sequential oracle", edges.len());
    println!("union_find OK");
}
