//! CacheHash tour: the paper's §4 table under different big-atomic
//! strategies, plus a head-to-head mini benchmark.
//!
//! ```bash
//! cargo run --release --example hashtable_tour
//! ```

use std::time::Duration;

use big_atomics::atomics::{CachedMemEff, SeqLock, Words};
use big_atomics::bench::driver::{run_map, run_map_wide, AtomicImpl, MapImpl, OpSource};
use big_atomics::bench::workload::WorkloadSpec;
use big_atomics::hash::{CacheHash, ConcurrentMap, Link, LinkVal};

fn api_tour<M: ConcurrentMap>(table: M) {
    // Insert-if-absent semantics, 8-byte keys and values.
    assert!(table.insert(1, 100));
    assert!(table.insert(2, 200));
    assert!(!table.insert(1, 999), "duplicate insert rejected");
    assert_eq!(table.find(1), Some(100));
    assert_eq!(table.find(3), None);
    assert!(table.remove(1));
    assert!(!table.remove(1));
    println!("  {:<24} api OK", table.map_name());
}

fn main() {
    println!("CacheHash API (generic over the big-atomic strategy):");
    api_tour(CacheHash::<SeqLock<LinkVal>>::new(1024));
    api_tour(CacheHash::<CachedMemEff<LinkVal>>::new(1024));

    // The same table with arbitrary-length keys AND values (§5.3):
    // 4-word keys map to 4-word values through a 9-word inlined link.
    println!("\ngeneric-value table (Words<4> -> Words<4>):");
    type WK = Words<4>;
    let wide: CacheHash<CachedMemEff<Link<WK, WK>>, WK, WK> = CacheHash::new(1024);
    assert!(wide.insert(Words([1, 2, 3, 4]), Words([40; 4])));
    assert!(!wide.insert(Words([1, 2, 3, 4]), Words([41; 4])));
    assert_eq!(wide.find(Words([1, 2, 3, 4])), Some(Words([40; 4])));
    assert!(wide.remove(Words([1, 2, 3, 4])));
    println!("  {:<24} wide api OK", wide.map_name());

    // Collision behaviour: tiny table, long chains, still correct.
    println!("\nchain stress (capacity 4, 1000 keys):");
    let t = CacheHash::<CachedMemEff<LinkVal>>::new(4);
    for k in 0..1000u64 {
        assert!(t.insert(k, k * 3));
    }
    for k in 0..1000u64 {
        assert_eq!(t.find(k), Some(k * 3));
    }
    for k in (0..1000u64).filter(|k| k % 7 == 0) {
        assert!(t.remove(k));
    }
    assert_eq!(t.find(700), None);
    assert_eq!(t.find(701), Some(2103));
    println!("  1000 keys through 4 buckets OK");

    // Head-to-head: inlined vs not, 2 threads, 50% updates.
    println!("\nmini benchmark (n=16K, u=50%, z=0, p=2, 200ms/point):");
    let spec = WorkloadSpec {
        n: 1 << 14,
        theta: 0.0,
        update_pct: 50,
        seed: 42,
    };
    for imp in [
        MapImpl::CacheHashMemEff,
        MapImpl::CacheHashSeqLock,
        MapImpl::Chaining,
        MapImpl::ShardedLock,
        MapImpl::GlobalLock,
    ] {
        let r = run_map(imp, &spec, 2, Duration::from_millis(200), &OpSource::Rust);
        println!("  {:<28} {:>8.3} Mop/s", imp.name(), r.mops());
    }

    // And the wide-value workload on the two leading strategies.
    println!("\nwide (4-word key/value) workload:");
    for imp in [AtomicImpl::CachedMemEff, AtomicImpl::SeqLock] {
        let r = run_map_wide(imp, &spec, 2, Duration::from_millis(200), &OpSource::Rust);
        println!("  {:<28} {:>8.3} Mop/s", r.label, r.mops());
    }
    println!("\nhashtable tour OK");
}
