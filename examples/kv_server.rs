//! End-to-end driver: the whole three-layer stack on a real workload.
//!
//! ```bash
//! make artifacts                       # once: AOT-compile the L1/L2 models
//! cargo run --release --example kv_server
//! ```
//!
//! Flow: the leader loads `artifacts/workload.hlo.txt` (the JAX/Pallas
//! workload model) on the PJRT CPU client, generates batched requests
//! through it, and pushes them through a bounded queue to worker threads
//! serving a shared `CacheHash<CachedMemEff>` table.  Batch latencies are
//! summarized by `artifacts/stats.hlo.txt` (the L2 stats model).  Python
//! is not involved at any point of this run.
//!
//! The run is recorded in EXPERIMENTS.md §End-to-end.

use std::time::Duration;

use big_atomics::coordinator::kv_service::{run, KvConfig};
use big_atomics::runtime::{default_artifact_dir, Runtime};

fn main() -> big_atomics::util::error::Result<()> {
    // Artifacts are required for this example — it's the end-to-end
    // proof that L1 (Pallas kernels) → L2 (JAX model) → HLO → PJRT →
    // L3 (Rust service) compose.
    let rt = Runtime::new(default_artifact_dir()).map_err(|e| {
        big_atomics::anyhow!(
            "{e}\n\nthis example needs the AOT artifacts: run `make artifacts` first \
             (and build with `--features pjrt`)"
        )
    })?;
    println!("PJRT platform: {}", rt.platform());

    for (workers, label) in [(2usize, "2 workers"), (4, "4 workers (oversubscribed)")] {
        let cfg = KvConfig {
            n: 1 << 16,
            workers,
            batch: 512,
            duration: Duration::from_secs(3),
            update_pct: 30,
            theta: 0.9,
            seed: 0x4B56,
            initial_capacity: 0,
            ..KvConfig::default()
        };
        println!(
            "\nkv_server: n={} {} batch={} u={}% z={} ingress={} for {:?}",
            cfg.n,
            label,
            cfg.batch,
            cfg.update_pct,
            cfg.theta,
            cfg.ingress.name(),
            cfg.duration
        );
        let rep = run(&cfg, Some(&rt))?;
        println!(
            "  served {} requests in {:.2}s = {:.3} Mop/s",
            rep.total_requests,
            rep.elapsed.as_secs_f64(),
            rep.mops()
        );
        println!(
            "  ingress: {} offered = {} served + {} shed (claim_runs={} steal_runs={})",
            rep.enqueued_batches, rep.sample_count, rep.shed_batches, rep.claim_runs, rep.steal_runs
        );
        println!(
            "  mix: {} finds / {} inserts / {} deletes",
            rep.finds, rep.inserts, rep.deletes
        );
        if let Some(lat) = rep.latency {
            println!("  request latency ({} batches): {}", rep.sample_count, lat);
        }
        if let Some(mean) = rep.latency_stats.mean() {
            println!(
                "  fetch_update stats cell: count={} mean={:.0}ns min={} max={}",
                rep.latency_stats.count, mean, rep.latency_stats.min, rep.latency_stats.max
            );
        }
    }
    println!("\nkv_server end-to-end OK");
    Ok(())
}
