//! Quickstart: the big-atomic API in five minutes.
//!
//! ```bash
//! cargo run --release --example quickstart
//! ```
//!
//! Shows single-threaded usage of every implementation, then a
//! multi-threaded CAS-counter demonstrating lock-freedom under
//! contention.

use std::sync::Arc;

use big_atomics::atomics::{
    BigAtomic, CachedMemEff, CachedWaitFree, CachedWritable, HtmSim, Indirect, LockPool, SeqLock,
    SimpLock, Words,
};

fn demo_one<A: BigAtomic<Words<4>>>(tag: &str) {
    // A 4-word (32-byte) atomic value — bigger than any hardware CAS.
    let a = A::new(Words([1, 2, 3, 4]));
    let v = a.load();
    assert_eq!(v, Words([1, 2, 3, 4]));

    // CAS: succeeds iff the whole 32-byte value matches.
    assert!(a.cas(v, Words([10, 20, 30, 40])));
    assert!(!a.cas(v, Words([0, 0, 0, 0]))); // stale expected

    // Store (on Cached-WaitFree this is a CAS loop — see Table 1).
    a.store(Words([7, 7, 7, 7]));
    assert_eq!(a.load(), Words([7, 7, 7, 7]));
    println!("  {tag:<24} load/store/cas OK");
}

fn main() {
    println!("big_atomics quickstart — all eight implementations:");
    demo_one::<SeqLock<Words<4>>>("SeqLock");
    demo_one::<SimpLock<Words<4>>>("SimpLock");
    demo_one::<LockPool<Words<4>>>("LockPool (libatomic)");
    demo_one::<Indirect<Words<4>>>("Indirect");
    demo_one::<CachedWaitFree<Words<4>>>("Cached-WaitFree (Alg 1)");
    demo_one::<CachedMemEff<Words<4>>>("Cached-MemEff (Alg 2)");
    demo_one::<CachedWritable<Words<4>>>("Cached-Writable (Alg 3)");
    demo_one::<HtmSim<Words<4>>>("HTM (simulated)");

    // Multi-threaded: a 4-word CAS counter. Word 0 counts successful
    // CASes; the other words carry per-thread tags that must never tear.
    println!("\nconcurrent CAS counter on Cached-MemEff (4 threads):");
    let a: Arc<CachedMemEff<Words<4>>> = Arc::new(CachedMemEff::new(Words([0; 4])));
    let threads = 4;
    let per = 10_000u64;
    let handles: Vec<_> = (0..threads)
        .map(|t| {
            let a = Arc::clone(&a);
            std::thread::spawn(move || {
                let mut wins = 0u64;
                while wins < per {
                    let cur = a.load();
                    let next = Words([cur.0[0] + 1, t, wins, cur.0[3].wrapping_add(t + 1)]);
                    if a.cas(cur, next) {
                        wins += 1;
                    }
                }
            })
        })
        .collect();
    for h in handles {
        h.join().unwrap();
    }
    let v = a.load();
    assert_eq!(v.0[0], threads * per);
    println!("  {} successful CASes, final value {:?}", v.0[0], v.0);
    println!("\nquickstart OK");
}
