//! Quickstart: the big-atomic API in five minutes.
//!
//! ```bash
//! cargo run --release --example quickstart
//! ```
//!
//! Shows single-threaded usage of every implementation in the
//! witnessing style — `compare_exchange` / `swap` / `fetch_update` —
//! then a multi-threaded counter demonstrating lock-freedom under
//! contention with zero retry re-loads.

use std::sync::Arc;

use big_atomics::atomics::{
    BigAtomic, CachedMemEff, CachedWaitFree, CachedWritable, HtmSim, Indirect, LockPool, SeqLock,
    SimpLock, Words,
};

fn demo_one<A: BigAtomic<Words<4>>>(tag: &str) {
    // A 4-word (32-byte) atomic value — bigger than any hardware CAS.
    let a = A::new(Words([1, 2, 3, 4]));
    let v = a.load();
    assert_eq!(v, Words([1, 2, 3, 4]));

    // compare_exchange: Ok(previous) iff the whole 32-byte value
    // matched; Err carries the *witnessed* current value, so a failed
    // attempt never needs a separate re-load.
    assert_eq!(a.compare_exchange(v, Words([10, 20, 30, 40])), Ok(v));
    let witness = a
        .compare_exchange(v, Words([0, 0, 0, 0]))
        .expect_err("stale expected must fail");
    assert_eq!(witness, Words([10, 20, 30, 40]));

    // swap: atomic exchange returning the previous value.
    assert_eq!(a.swap(Words([7, 7, 7, 7])), Words([10, 20, 30, 40]));

    // fetch_update: the whole load/modify/CAS retry loop in one call.
    let prev = a
        .fetch_update(|mut cur| {
            cur.0[0] += 1;
            Some(cur)
        })
        .expect("unconditional update");
    assert_eq!(prev, Words([7, 7, 7, 7]));

    // Store (on Cached-WaitFree this is a CAS loop — see Table 1).
    a.store(Words([9, 9, 9, 9]));
    assert_eq!(a.load(), Words([9, 9, 9, 9]));
    println!("  {tag:<24} load/store/compare_exchange/swap/fetch_update OK");
}

fn main() {
    println!("big_atomics quickstart — all eight implementations:");
    demo_one::<SeqLock<Words<4>>>("SeqLock");
    demo_one::<SimpLock<Words<4>>>("SimpLock");
    demo_one::<LockPool<Words<4>>>("LockPool (libatomic)");
    demo_one::<Indirect<Words<4>>>("Indirect");
    demo_one::<CachedWaitFree<Words<4>>>("Cached-WaitFree (Alg 1)");
    demo_one::<CachedMemEff<Words<4>>>("Cached-MemEff (Alg 2)");
    demo_one::<CachedWritable<Words<4>>>("Cached-Writable (Alg 3)");
    demo_one::<HtmSim<Words<4>>>("HTM (simulated)");

    // Multi-threaded: a 4-word fetch_update counter. Word 0 counts
    // updates; the other words carry per-thread tags that must never
    // tear. Every update lands exactly once — the witness-fed retry
    // loop is doing the work a load+cas loop used to.
    println!("\nconcurrent fetch_update counter on Cached-MemEff (4 threads):");
    let a: Arc<CachedMemEff<Words<4>>> = Arc::new(CachedMemEff::new(Words([0; 4])));
    let threads = 4;
    let per = 10_000u64;
    let handles: Vec<_> = (0..threads)
        .map(|t| {
            let a = Arc::clone(&a);
            std::thread::spawn(move || {
                for i in 0..per {
                    let _ = a
                        .fetch_update(|cur| {
                            Some(Words([cur.0[0] + 1, t, i, cur.0[3].wrapping_add(t + 1)]))
                        })
                        .expect("unconditional update");
                }
            })
        })
        .collect();
    for h in handles {
        h.join().unwrap();
    }
    let v = a.load();
    assert_eq!(v.0[0], threads * per);
    println!("  {} successful updates, final value {:?}", v.0[0], v.0);
    println!("\nquickstart OK");
}
