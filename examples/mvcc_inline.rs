//! MVCC with inlined versions — the paper's §2 motivating application.
//!
//! ```bash
//! cargo run --release --example mvcc_inline
//! ```
//!
//! Multiversion concurrency control stores, per object, a (value,
//! timestamp, next-version pointer) triple.  With a 3-word big atomic the
//! *current* version lives inline and is updated atomically — readers of
//! the latest version (the overwhelmingly common case) pay zero
//! indirection, exactly the paper's pitch.  Older versions hang off the
//! next pointer for snapshot reads.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;

use big_atomics::atomics::{BigAtomic, CachedMemEff};
use big_atomics::impl_atomic_value;
use big_atomics::smr::epoch;

/// The inlined current version: value, write timestamp, older-version ptr.
#[repr(C, align(8))]
#[derive(Copy, Clone, PartialEq, Eq, Debug, Default)]
struct Version {
    value: u64,
    ts: u64,
    older: u64, // *mut OldVersion
}

impl_atomic_value!(Version);

struct OldVersion {
    value: u64,
    ts: u64,
    older: *mut OldVersion,
}

/// A multi-versioned register built on one big atomic.
struct MvRegister {
    head: CachedMemEff<Version>,
}

impl MvRegister {
    fn new(value: u64) -> Self {
        Self {
            head: CachedMemEff::new(Version {
                value,
                ts: 0,
                older: 0,
            }),
        }
    }

    /// Latest value + timestamp: one atomic load, no indirection.
    fn read_latest(&self) -> (u64, u64) {
        let v = self.head.load();
        (v.value, v.ts)
    }

    /// Snapshot read: the value visible at timestamp `at`.
    fn read_at(&self, at: u64) -> Option<u64> {
        let _g = epoch::pin();
        let head = self.head.load();
        if head.ts <= at {
            return Some(head.value);
        }
        let mut p = head.older as *mut OldVersion;
        while !p.is_null() {
            // SAFETY: epoch-pinned; versions retired only after unlink.
            let v = unsafe { &*p };
            if v.ts <= at {
                return Some(v.value);
            }
            p = v.older;
        }
        None // object did not exist at `at`
    }

    /// Install a new version at timestamp `ts` (must exceed current).
    /// Returns false on a concurrent newer write.
    fn write(&self, value: u64, ts: u64) -> bool {
        loop {
            let _g = epoch::pin();
            let cur = self.head.load();
            if cur.ts >= ts {
                return false; // newer version already installed
            }
            // Current version moves to the history chain.
            let old = Box::into_raw(Box::new(OldVersion {
                value: cur.value,
                ts: cur.ts,
                older: cur.older as *mut OldVersion,
            }));
            let next = Version {
                value,
                ts,
                older: old as u64,
            };
            if self.head.compare_exchange(cur, next).is_ok() {
                return true;
            }
            // SAFETY: never published.
            drop(unsafe { Box::from_raw(old) });
        }
    }
}

impl Drop for MvRegister {
    fn drop(&mut self) {
        let mut p = self.head.load().older as *mut OldVersion;
        while !p.is_null() {
            // SAFETY: exclusive in Drop.
            let v = unsafe { Box::from_raw(p) };
            p = v.older;
        }
    }
}

fn main() {
    // Single-writer history.
    let reg = MvRegister::new(10);
    reg.write(20, 5);
    reg.write(30, 9);
    assert_eq!(reg.read_latest(), (30, 9));
    assert_eq!(reg.read_at(9), Some(30));
    assert_eq!(reg.read_at(7), Some(20));
    assert_eq!(reg.read_at(4), Some(10));
    println!("single-writer version history OK");

    // Concurrent writers with a global timestamp oracle; readers take
    // snapshots and verify monotonicity.
    let reg = Arc::new(MvRegister::new(0));
    let clock = Arc::new(AtomicU64::new(1));
    let writers: Vec<_> = (0..3)
        .map(|w| {
            let reg = Arc::clone(&reg);
            let clock = Arc::clone(&clock);
            std::thread::spawn(move || {
                let mut installed = 0u64;
                for i in 0..3_000u64 {
                    let ts = clock.fetch_add(1, Ordering::SeqCst);
                    if reg.write(w * 1_000_000 + i, ts) {
                        installed += 1;
                    }
                }
                installed
            })
        })
        .collect();
    let readers: Vec<_> = (0..2)
        .map(|_| {
            let reg = Arc::clone(&reg);
            std::thread::spawn(move || {
                let mut last_ts = 0;
                for _ in 0..20_000 {
                    let (_, ts) = reg.read_latest();
                    assert!(ts >= last_ts, "latest timestamp went backwards");
                    last_ts = ts;
                    // Snapshot at a past timestamp must always resolve.
                    if ts > 2 {
                        assert!(reg.read_at(ts - 1).is_some());
                    }
                }
            })
        })
        .collect();
    let total: u64 = writers.into_iter().map(|h| h.join().unwrap()).sum();
    for r in readers {
        r.join().unwrap();
    }
    println!("concurrent MVCC: {total} versions installed, snapshots consistent");
    println!("mvcc_inline OK");
}
