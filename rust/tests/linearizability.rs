//! Linearizability checking for every big-atomic implementation.
//!
//! Method: a register whose values are *globally unique by construction*
//! (each CAS installs a fresh tagged value).  Then:
//!
//! 1. every successful `cas(expected → desired)` consumes a unique prior
//!    value, so the set of successful CASes must form a single linear
//!    **chain** from the initial value (no forks, no orphans);
//! 2. every `load` must return a value on that chain;
//! 3. **per-thread order**: consecutive operations of one thread must
//!    observe non-decreasing chain positions;
//! 4. **real time**: if operation A completed before operation B started
//!    (disjoint stopwatch windows), B must not observe an earlier chain
//!    position than A observed.
//!
//! For a register with unique values these four properties are exactly
//! linearizability of load/cas histories; store is exercised through the
//! same chain by encoding stores as blind CAS loops.

use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;
use std::time::{Duration, Instant};

use big_atomics::atomics::{
    BigAtomic, CachedMemEff, CachedWaitFree, CachedWritable, HtmSim, Indirect, LockPool, SeqLock,
    SimpLock, Words,
};
use big_atomics::hash::{BackgroundMigrator, CacheHash, Chaining, ConcurrentMap, Link, Maintain};

const K: usize = 4;
type V = Words<K>;

/// Recorded operation: thread, stopwatch window, observed value
/// (for loads: returned; for cas: the value it acted on / installed).
struct Rec {
    thread: usize,
    start_ns: u64,
    end_ns: u64,
    observed: V, // chain value witnessed (pre-value for failed cas, installed for success)
    installed: Option<(V, V)>, // successful cas: (expected, desired)
}

fn unique_val(thread: u64, seq: u64) -> V {
    // Globally unique, never equal to another thread's value.
    Words([1 + thread, seq, thread ^ seq, 0xC0FFEE ^ (thread << 32) ^ seq])
}

fn run_history<A: BigAtomic<V> + 'static>(threads: usize, ops_per_thread: usize) -> Vec<Rec> {
    let atomic = Arc::new(A::new(Words([0; K])));
    let epoch = Instant::now();
    let recs: Arc<std::sync::Mutex<Vec<Rec>>> = Arc::new(std::sync::Mutex::new(Vec::new()));
    let barrier = Arc::new(std::sync::Barrier::new(threads));
    let seq_gen = Arc::new(AtomicU64::new(1));

    let handles: Vec<_> = (0..threads)
        .map(|t| {
            let atomic = Arc::clone(&atomic);
            let recs = Arc::clone(&recs);
            let barrier = Arc::clone(&barrier);
            let seq_gen = Arc::clone(&seq_gen);
            std::thread::spawn(move || {
                let mut local: Vec<Rec> = Vec::with_capacity(ops_per_thread);
                barrier.wait();
                for i in 0..ops_per_thread {
                    let start_ns = epoch.elapsed().as_nanos() as u64;
                    if i % 3 == 0 {
                        // load
                        let v = atomic.load();
                        let end_ns = epoch.elapsed().as_nanos() as u64;
                        local.push(Rec {
                            thread: t,
                            start_ns,
                            end_ns,
                            observed: v,
                            installed: None,
                        });
                    } else {
                        // cas from a freshly loaded snapshot; a failure's
                        // witness is itself a linearizable read and is
                        // recorded as this op's observation.
                        let cur = atomic.load();
                        let desired = unique_val(t as u64, seq_gen.fetch_add(1, Ordering::Relaxed));
                        let res = atomic.compare_exchange(cur, desired);
                        let end_ns = epoch.elapsed().as_nanos() as u64;
                        local.push(Rec {
                            thread: t,
                            start_ns,
                            end_ns,
                            observed: match res {
                                Ok(_) => desired,
                                Err(w) => w,
                            },
                            installed: res.ok().map(|prev| (prev, desired)),
                        });
                    }
                }
                recs.lock().unwrap().append(&mut local);
            })
        })
        .collect();
    for h in handles {
        h.join().unwrap();
    }
    Arc::try_unwrap(recs).ok().unwrap().into_inner().unwrap()
}

fn check_linearizable(recs: &[Rec], label: &str) {
    // 1. successful CASes form one chain from the initial value.
    let init: V = Words([0; K]);
    let mut next: HashMap<[u64; K], [u64; K]> = HashMap::new();
    for r in recs {
        if let Some((exp, des)) = &r.installed {
            let prev = next.insert(exp.0, des.0);
            assert!(
                prev.is_none(),
                "{label}: two successful CASes consumed the same value {exp:?}"
            );
        }
    }
    // Walk the chain, assigning positions.
    let mut pos: HashMap<[u64; K], usize> = HashMap::new();
    let mut cur = init.0;
    let mut p = 0usize;
    pos.insert(cur, p);
    while let Some(&nxt) = next.get(&cur) {
        p += 1;
        pos.insert(nxt, p);
        cur = nxt;
    }
    let installs = recs.iter().filter(|r| r.installed.is_some()).count();
    assert_eq!(
        p, installs,
        "{label}: chain length {p} != successful CAS count {installs} (forked history)"
    );

    // 2. every observed value lies on the chain.
    for r in recs {
        assert!(
            pos.contains_key(&r.observed.0),
            "{label}: observed off-chain value {:?}",
            r.observed.0
        );
    }

    // 3. per-thread monotonicity.
    let mut by_thread: HashMap<usize, Vec<&Rec>> = HashMap::new();
    for r in recs {
        by_thread.entry(r.thread).or_default().push(r);
    }
    for (t, mut ops) in by_thread {
        ops.sort_by_key(|r| r.start_ns);
        let mut last = 0usize;
        for r in ops {
            let p = pos[&r.observed.0];
            assert!(
                p >= last,
                "{label}: thread {t} observed chain position {p} after {last}"
            );
            last = p;
        }
    }

    // 4. real-time order across threads (sweep by end time).
    let mut sorted: Vec<&Rec> = recs.iter().collect();
    sorted.sort_by_key(|r| r.end_ns);
    let mut max_completed_pos = 0usize;
    let mut completed: Vec<(u64, usize)> = Vec::new(); // (end_ns, pos)
    let mut ci = 0usize;
    let mut by_start: Vec<&Rec> = recs.iter().collect();
    by_start.sort_by_key(|r| r.start_ns);
    for r in by_start {
        // advance completion frontier to ops that ended before r started
        while ci < sorted.len() && sorted[ci].end_ns < r.start_ns {
            max_completed_pos = max_completed_pos.max(pos[&sorted[ci].observed.0]);
            completed.push((sorted[ci].end_ns, max_completed_pos));
            ci += 1;
        }
        let p = pos[&r.observed.0];
        assert!(
            p >= max_completed_pos,
            "{label}: real-time violation: op observed position {p} after {max_completed_pos} completed"
        );
    }
}

fn check_impl<A: BigAtomic<V> + 'static>(label: &str) {
    let recs = run_history::<A>(4, 3_000);
    assert!(recs.len() == 12_000);
    check_linearizable(&recs, label);
}

#[test]
fn test_linearizable_seqlock() {
    check_impl::<SeqLock<V>>("SeqLock");
}

#[test]
fn test_linearizable_simplock() {
    check_impl::<SimpLock<V>>("SimpLock");
}

#[test]
fn test_linearizable_lockpool() {
    check_impl::<LockPool<V>>("LockPool");
}

#[test]
fn test_linearizable_indirect() {
    check_impl::<Indirect<V>>("Indirect");
}

#[test]
fn test_linearizable_cached_waitfree() {
    check_impl::<CachedWaitFree<V>>("Cached-WaitFree");
}

#[test]
fn test_linearizable_cached_memeff() {
    check_impl::<CachedMemEff<V>>("Cached-MemEff");
}

#[test]
fn test_linearizable_cached_writable() {
    check_impl::<CachedWritable<V>>("Cached-Writable");
}

#[test]
fn test_linearizable_htm_sim() {
    check_impl::<HtmSim<V>>("HTM(sim)");
}

// ---------------------------------------------------------------------
// Wide-table sweeps (ROADMAP): linearizability-style checks at the
// CacheHash<_, Words<4>, Words<4>> instantiation.
// ---------------------------------------------------------------------

type WK = Words<4>;

fn wkey(i: u64) -> WK {
    Words([i, i ^ 0x5151, i.rotate_left(11), !i])
}

/// The register driving a wide bucket is a 9-word `Link` value; run the
/// unique-value chain check directly on it: every successful CAS must
/// consume a distinct prior value (no forks), and the final value must
/// account for exactly the total number of wins.
#[test]
fn test_wide_link_register_unique_cas_chain() {
    type L = Link<WK, WK>;
    let a: Arc<CachedMemEff<L>> = Arc::new(CachedMemEff::new(L::default()));
    let threads = 4u64;
    let per = 1_500u64;
    let consumed: Arc<std::sync::Mutex<HashMap<([u64; 4], [u64; 4], u64), ()>>> =
        Arc::new(std::sync::Mutex::new(HashMap::new()));
    let handles: Vec<_> = (0..threads)
        .map(|t| {
            let a = Arc::clone(&a);
            let consumed = Arc::clone(&consumed);
            std::thread::spawn(move || {
                let mut cur = a.load();
                let mut wins = 0u64;
                let mut seq = 0u64;
                while wins < per {
                    seq += 1;
                    // Globally unique desired value: thread in key word 0,
                    // seq in value word 0, occupied-flagged next field.
                    let desired = L {
                        key: wkey((t + 1) << 32 | seq),
                        value: wkey(seq),
                        next: 1,
                    };
                    match a.compare_exchange(cur, desired) {
                        Ok(prev) => {
                            // Each consumed value must be consumed once.
                            let k = (prev.key.0, prev.value.0, prev.next);
                            let dup = consumed.lock().unwrap().insert(k, ()).is_some();
                            assert!(!dup, "two CASes consumed {k:?}");
                            wins += 1;
                            cur = desired;
                        }
                        Err(w) => cur = w,
                    }
                }
            })
        })
        .collect();
    for h in handles {
        h.join().unwrap();
    }
    assert_eq!(consumed.lock().unwrap().len() as u64, threads * per);
}

/// Same-key contention on the wide table: the net of successful inserts
/// and removes must equal final presence, and every observed value must
/// be the one its inserter wrote (values derive from keys).
#[test]
fn test_wide_map_same_key_accounting() {
    let t: Arc<CacheHash<CachedMemEff<Link<WK, WK>>, WK, WK>> = Arc::new(CacheHash::new(8));
    let key = wkey(42);
    let val = wkey(4242);
    let inserts = Arc::new(AtomicU64::new(0));
    let removes = Arc::new(AtomicU64::new(0));
    let handles: Vec<_> = (0..4u64)
        .map(|tix| {
            let t = Arc::clone(&t);
            let inserts = Arc::clone(&inserts);
            let removes = Arc::clone(&removes);
            std::thread::spawn(move || {
                for i in 0..2_500u64 {
                    if (i + tix) % 2 == 0 {
                        if t.insert(key, val) {
                            inserts.fetch_add(1, Ordering::Relaxed);
                        }
                    } else if t.remove(key) {
                        removes.fetch_add(1, Ordering::Relaxed);
                    }
                    if let Some(v) = t.find(key) {
                        assert_eq!(v, val, "foreign value under the wide key");
                    }
                }
            })
        })
        .collect();
    for h in handles {
        h.join().unwrap();
    }
    let ins = inserts.load(Ordering::SeqCst);
    let rem = removes.load(Ordering::SeqCst);
    let present = t.find(key).is_some() as u64;
    assert_eq!(ins, rem + present, "ins={ins} rem={rem} present={present}");
}

// ---------------------------------------------------------------------
// Online-resize linearizability (the resize PR's tentpole): concurrent
// insert/find/remove racing the stripe migration must lose nothing,
// duplicate nothing, and never surface a foreign value — across many
// doublings from a deliberately tiny table.
// ---------------------------------------------------------------------

/// The acceptance bar: a capacity-64 `CacheHash` absorbs 100k concurrent
/// inserts (plus find/remove churn racing the migration) and still
/// answers every `find` correctly during and after the growth, with no
/// lost or duplicated keys after ~10 doublings.
#[test]
fn test_cachehash_resize_100k_inserts_from_capacity_64() {
    let t: Arc<CacheHash<CachedMemEff<Link<u64, u64>>>> = Arc::new(CacheHash::new(64));
    assert_eq!(t.capacity(), 64);
    let threads = 4u64;
    let per = 25_000u64;
    let handles: Vec<_> = (0..threads)
        .map(|tix| {
            let t = Arc::clone(&t);
            std::thread::spawn(move || {
                let base = tix * per;
                for i in 0..per {
                    let k = base + i;
                    assert!(t.insert(k, k.wrapping_mul(7) ^ 0xA5), "lost insert {k}");
                    // Reads racing migration: earlier keys of this
                    // thread must stay visible with their exact values.
                    if i % 17 == 0 {
                        let probe = base + i / 2;
                        assert_eq!(
                            t.find(probe),
                            Some(probe.wrapping_mul(7) ^ 0xA5),
                            "stale/foreign read of {probe} mid-growth"
                        );
                    }
                    // Remove/re-insert churn exercises seal-vs-update
                    // races on both generations.
                    if i % 13 == 3 {
                        assert!(t.remove(k), "remove lost {k}");
                        assert!(t.insert(k, k.wrapping_mul(7) ^ 0xA5), "re-insert lost {k}");
                    }
                }
            })
        })
        .collect();
    for h in handles {
        h.join().unwrap();
    }
    t.finish_resizes();
    assert!(!t.resize_in_flight());
    assert!(
        t.capacity() >= 8192,
        "100k keys left capacity at {}",
        t.capacity()
    );
    assert!(t.generation() >= 7, "only {} doublings", t.generation());
    // Nothing lost, nothing duplicated: every key present exactly once.
    for k in 0..threads * per {
        assert_eq!(t.find(k), Some(k.wrapping_mul(7) ^ 0xA5), "key {k}");
    }
    for k in (0..threads * per).step_by(97) {
        assert!(t.remove(k), "key {k} vanished");
        assert!(!t.remove(k), "key {k} was duplicated across generations");
        assert_eq!(t.find(k), None);
    }
}

/// Checksummed `Words<4>` values across a forced grow: a reader thread
/// validates every observed value against its key-derived checksum while
/// writers push the wide table through repeated doublings (a torn or
/// cross-generation-mixed value fails the checksum).
#[test]
fn test_wide_resize_checksummed_values_under_growth() {
    fn wval(i: u64) -> WK {
        Words([i, i.wrapping_mul(0x9E3779B97F4A7C15), !i, i ^ 0xC0FFEE])
    }
    let t: Arc<CacheHash<CachedMemEff<Link<WK, WK>>, WK, WK>> = Arc::new(CacheHash::new(4));
    let per = 4_000u64;
    let stop = Arc::new(AtomicU64::new(0));
    let mut handles = Vec::new();
    for tix in 0..2u64 {
        let t = Arc::clone(&t);
        handles.push(std::thread::spawn(move || {
            let base = (tix + 1) << 32;
            for i in 0..per {
                assert!(t.insert(wkey(base + i), wval(base + i)));
            }
        }));
    }
    {
        let t = Arc::clone(&t);
        let stop = Arc::clone(&stop);
        handles.push(std::thread::spawn(move || {
            let mut probes = 0u64;
            while stop.load(Ordering::Acquire) == 0 {
                for tix in 0..2u64 {
                    let base = (tix + 1) << 32;
                    let i = probes % per;
                    if let Some(v) = t.find(wkey(base + i)) {
                        assert_eq!(v, wval(base + i), "checksum broke mid-growth");
                    }
                    probes += 1;
                }
            }
        }));
    }
    for h in handles.drain(..2) {
        h.join().unwrap();
    }
    stop.store(1, Ordering::Release);
    for h in handles {
        h.join().unwrap();
    }
    t.finish_resizes();
    assert!(t.capacity() > 4, "wide table never grew");
    for tix in 0..2u64 {
        let base = (tix + 1) << 32;
        for i in 0..per {
            assert_eq!(t.find(wkey(base + i)), Some(wval(base + i)));
        }
    }
}

/// The no-inline baseline grows through the same protocol: concurrent
/// mixed ops from a capacity-16 `Chaining` table.
#[test]
fn test_chaining_resize_concurrent_mixed() {
    let t: Arc<Chaining> = Arc::new(Chaining::new(16));
    let threads = 4u64;
    let per = 5_000u64;
    let handles: Vec<_> = (0..threads)
        .map(|tix| {
            let t = Arc::clone(&t);
            std::thread::spawn(move || {
                let base = tix * 1_000_000;
                for i in 0..per {
                    assert!(t.insert(base + i, i ^ 0x33));
                    if i % 3 == 0 {
                        assert!(t.remove(base + i));
                    }
                }
                for i in 0..per {
                    let want = if i % 3 == 0 { None } else { Some(i ^ 0x33) };
                    assert_eq!(t.find(base + i), want, "key {}", base + i);
                }
            })
        })
        .collect();
    for h in handles {
        h.join().unwrap();
    }
    t.finish_resizes();
    assert!(t.capacity() > 16, "chaining table never grew");
    assert!(t.generation() >= 1);
}

/// Drive `maintain` until the table is idle (no migration in flight) and
/// its capacity has stopped moving; returns the converged capacity.
fn converge<M, K, V>(t: &M) -> usize
where
    M: ConcurrentMap<K, V> + Maintain,
    K: big_atomics::atomics::AtomicValue,
    V: big_atomics::atomics::AtomicValue,
{
    let mut cap = t.capacity();
    loop {
        let idle = t.maintain();
        let now = t.capacity();
        if idle && now == cap {
            return now;
        }
        cap = now;
    }
}

/// Grow → mass-remove → shrink with wide checksummed `Words<4>` values:
/// after a concurrent grow and a concurrent 15/16 drain, maintenance must
/// shrink the table below its peak without losing, duplicating, or
/// resurrecting any key, and without disturbing the grow generation.
#[test]
fn test_wide_grow_mass_remove_shrink_linearizable() {
    fn wval(i: u64) -> WK {
        let a = i;
        let b = i.wrapping_mul(0x9E3779B97F4A7C15);
        let c = !i;
        Words([a, b, c, a ^ b ^ c])
    }
    let t: Arc<CacheHash<CachedMemEff<Link<WK, WK>>, WK, WK>> = Arc::new(CacheHash::new(2));
    let threads = 4u64;
    let per = 2_048u64;
    let handles: Vec<_> = (0..threads)
        .map(|tix| {
            let t = Arc::clone(&t);
            std::thread::spawn(move || {
                let base = (tix + 1) << 32;
                for i in 0..per {
                    assert!(t.insert(wkey(base + i), wval(base + i)));
                }
            })
        })
        .collect();
    for h in handles {
        h.join().unwrap();
    }
    t.finish_resizes();
    let peak = t.capacity();
    let grow_gens = t.generation();
    assert!(peak > 2, "wide table never grew");
    // Concurrent 15/16 drain: removals race each other and the shrink
    // migrations they kick off.
    let handles: Vec<_> = (0..threads)
        .map(|tix| {
            let t = Arc::clone(&t);
            std::thread::spawn(move || {
                let base = (tix + 1) << 32;
                for i in 0..per {
                    if i % 16 != 0 {
                        assert!(t.remove(wkey(base + i)), "lost key {}", base + i);
                    }
                }
            })
        })
        .collect();
    for h in handles {
        h.join().unwrap();
    }
    let cap = converge(&*t);
    assert!(t.shrink_generation() >= 1, "drained table never shrank");
    assert!(cap < peak, "capacity {cap} did not drop below peak {peak}");
    assert_eq!(t.generation(), grow_gens, "shrink bumped the grow generation");
    // Exactness: survivors keep their checksummed value, drained keys
    // stay gone, and each survivor is present exactly once.
    for tix in 0..threads {
        let base = (tix + 1) << 32;
        for i in 0..per {
            let want = if i % 16 == 0 { Some(wval(base + i)) } else { None };
            assert_eq!(t.find(wkey(base + i)), want, "key {}", base + i);
        }
    }
    for tix in 0..threads {
        let base = (tix + 1) << 32;
        for i in (0..per).step_by(16) {
            assert!(t.remove(wkey(base + i)), "survivor {} vanished", base + i);
            assert!(!t.remove(wkey(base + i)), "survivor {} duplicated", base + i);
        }
    }
}

/// Oscillation guard: the 4x hysteresis band between the grow trigger
/// (load factor 2) and the shrink trigger (load factor 1/4) means an
/// occupancy oscillating well inside the band must not thrash resizes —
/// after settling, alternating insert/remove bursts leave both generation
/// counters and the capacity untouched (at most one residual shrink).
#[test]
fn test_shrink_grow_oscillation_guard() {
    let t: Chaining = Chaining::new(2);
    let n = 4_096u64;
    for i in 0..n {
        assert!(t.insert(i, i));
    }
    t.finish_resizes();
    // Drop to 700 keys and converge: 700 * 4 >= any capacity the engine
    // settles on, so the steady state sits inside the hysteresis band.
    for i in 700..n {
        assert!(t.remove(i));
    }
    let cap0 = converge(&t);
    let grows0 = t.generation();
    let shrinks0 = t.shrink_generation();
    // 20 bursts oscillating occupancy between 700 and 1000 — a 1.43x
    // swing against a 4x band.
    for _ in 0..20 {
        for i in 0..300u64 {
            assert!(t.insert(n + i, i));
        }
        for i in 0..300u64 {
            assert!(t.remove(n + i));
        }
        converge(&t);
    }
    assert_eq!(t.generation(), grows0, "in-band bursts triggered grows");
    assert!(
        t.shrink_generation() - shrinks0 <= 1,
        "in-band bursts thrashed shrinks: {} -> {}",
        shrinks0,
        t.shrink_generation()
    );
    let cap = t.capacity();
    assert!(
        cap == cap0 || cap * 2 == cap0,
        "capacity oscillated: settled {cap0}, now {cap}"
    );
    for i in 0..700u64 {
        assert_eq!(t.find(i), Some(i), "resident key {i} lost in the bursts");
    }
}

/// A quiescent half-migrated table must converge through the background
/// migrator alone: after the drain returns (possibly mid-shrink), zero
/// foreground operations touch the table — the migrator has to finish the
/// in-flight migration and walk the capacity down by itself.
#[test]
fn test_background_migrator_quiescent_convergence() {
    let t: Arc<Chaining> = Arc::new(Chaining::new(2));
    let n = 4_096u64;
    for i in 0..n {
        assert!(t.insert(i, i ^ 0x77));
    }
    t.finish_resizes();
    let peak = t.capacity();
    for i in 256..n {
        assert!(t.remove(i));
    }
    // From here on the table is quiescent: only the migrator may act.
    let migrator = BackgroundMigrator::spawn(
        vec![Arc::clone(&t) as Arc<dyn Maintain>],
        Duration::from_micros(200),
    );
    let deadline = Instant::now() + Duration::from_secs(10);
    let mut stable = 0u32;
    let mut cap = t.capacity();
    while stable < 5 {
        assert!(
            Instant::now() < deadline,
            "migrator never converged: in_flight={} capacity={}",
            t.resize_in_flight(),
            t.capacity()
        );
        std::thread::sleep(Duration::from_millis(2));
        let now = t.capacity();
        if !t.resize_in_flight() && now == cap {
            stable += 1;
        } else {
            stable = 0;
        }
        cap = now;
    }
    assert_eq!(migrator.panics(), 0, "migrator pass panicked");
    migrator.stop();
    assert!(!t.resize_in_flight(), "migration still in flight after stop");
    assert!(t.capacity() < peak, "quiescent table never shrank below peak");
    assert!(t.shrink_generation() >= 1);
    for i in 0..256u64 {
        assert_eq!(t.find(i), Some(i ^ 0x77), "resident key {i} corrupted");
    }
    for i in 256..n {
        assert_eq!(t.find(i), None, "drained key {i} resurrected");
    }
}

/// Stores interleaved with CASes: the writable implementations must keep
/// the unique-value chain intact when stores (blind writes) participate.
#[test]
fn test_store_cas_mix_writable_impls() {
    fn run<A: BigAtomic<V> + 'static>(label: &str) {
        let atomic = Arc::new(A::new(Words([0; K])));
        let stop = Arc::new(std::sync::atomic::AtomicBool::new(false));
        let seq = Arc::new(AtomicU64::new(1));
        let mut handles = Vec::new();
        for t in 0..2u64 {
            let atomic = Arc::clone(&atomic);
            let stop = Arc::clone(&stop);
            let seq = Arc::clone(&seq);
            handles.push(std::thread::spawn(move || {
                while !stop.load(Ordering::Relaxed) {
                    let v = unique_val(t + 10, seq.fetch_add(1, Ordering::Relaxed));
                    atomic.store(v);
                }
            }));
        }
        // Reader: every load must be a value some writer produced (or init).
        for _ in 0..50_000 {
            let v = atomic.load();
            // Internal consistency of unique_val: word2 = thread ^ seq.
            assert!(
                v == Words([0; K]) || v.0[2] == ((v.0[0] - 1) ^ v.0[1]),
                "{label}: torn or fabricated store observed {:?}",
                v.0
            );
        }
        stop.store(true, Ordering::SeqCst);
        for h in handles {
            h.join().unwrap();
        }
    }
    run::<SeqLock<V>>("SeqLock");
    run::<CachedMemEff<V>>("Cached-MemEff");
    run::<CachedWritable<V>>("Cached-Writable");
    run::<HtmSim<V>>("HTM(sim)");
}

// ---------------------------------------------------------------------------
// Claim-queue (ingress) linearizability: the batch front door of the KV
// service. Items are tagged (producer, seq); producers enqueue batches
// concurrently with drainers claiming runs. The claim word serializes
// drains (exactly one odd-claim holder at a time), so appending each
// drained run to a shared log while holding the `Run` yields a single
// global service order to check against:
//
//   1. no batch lost, none served twice (multiset equality with pushes);
//   2. per-producer order: each producer's seqs appear strictly
//      increasing in the global service order (enqueue linearizes at
//      one witnessing CAS, claim detaches a whole chain, runs are
//      served one-at-a-time — FIFO per producer end to end);
//   3. under Shed admission, accepted + shed == attempted and only
//      accepted items are ever served.
// ---------------------------------------------------------------------------

use big_atomics::ingress::{admit, Admitted, AdmissionPolicy, ClaimQueue};

/// A tagged batch: (producer id, per-producer sequence number).
type Tagged = (usize, u64);

#[test]
fn test_claim_queue_no_loss_no_dup_per_producer_fifo() {
    const PRODUCERS: usize = 4;
    const PER_PRODUCER: u64 = 2_000;
    const DRAINERS: usize = 3;

    let q: Arc<ClaimQueue<Tagged>> = Arc::new(ClaimQueue::new(0)); // unbounded
    let served: Arc<std::sync::Mutex<Vec<Tagged>>> = Arc::new(std::sync::Mutex::new(Vec::new()));
    let live_producers = Arc::new(AtomicU64::new(PRODUCERS as u64));
    let barrier = Arc::new(std::sync::Barrier::new(PRODUCERS + DRAINERS));

    let mut handles = Vec::new();
    for p in 0..PRODUCERS {
        let q = Arc::clone(&q);
        let live = Arc::clone(&live_producers);
        let barrier = Arc::clone(&barrier);
        handles.push(std::thread::spawn(move || {
            barrier.wait();
            for seq in 0..PER_PRODUCER {
                q.try_push((p, seq)).expect("unbounded push failed");
            }
            live.fetch_sub(1, Ordering::AcqRel);
        }));
    }
    for _ in 0..DRAINERS {
        let q = Arc::clone(&q);
        let served = Arc::clone(&served);
        let live = Arc::clone(&live_producers);
        let barrier = Arc::clone(&barrier);
        handles.push(std::thread::spawn(move || {
            barrier.wait();
            loop {
                match q.try_claim() {
                    Some(mut run) => {
                        // Append while holding the Run: the claim word
                        // makes this the unique active drainer, so the
                        // log order is the service order.
                        let mut log = served.lock().unwrap();
                        log.extend(run.drain());
                    }
                    None => {
                        if live.load(Ordering::Acquire) == 0 && q.is_idle() {
                            break;
                        }
                        std::hint::spin_loop();
                    }
                }
            }
        }));
    }
    for h in handles {
        h.join().unwrap();
    }

    let log = served.lock().unwrap();
    // 1. Conservation: every pushed item served exactly once.
    assert_eq!(log.len(), PRODUCERS * PER_PRODUCER as usize, "lost/duplicated items");
    let mut seen = std::collections::HashSet::new();
    for &(p, seq) in log.iter() {
        assert!(seen.insert((p, seq)), "duplicate service of ({p},{seq})");
    }
    // 2. Per-producer FIFO in the global service order.
    let mut next_expected = [0u64; PRODUCERS];
    for &(p, seq) in log.iter() {
        assert_eq!(
            seq, next_expected[p],
            "producer {p} reordered: served {seq}, expected {}",
            next_expected[p]
        );
        next_expected[p] = seq + 1;
    }
    assert!(q.is_idle());
}

#[test]
fn test_claim_queue_exactly_one_drainer() {
    const THREADS: usize = 8;
    // Non-empty queue, THREADS concurrent claim attempts: exactly one
    // may win while the claim word is odd.
    let q: Arc<ClaimQueue<u64>> = Arc::new(ClaimQueue::new(0));
    for i in 0..64 {
        q.try_push(i).unwrap();
    }
    let winners = Arc::new(AtomicU64::new(0));
    let barrier = Arc::new(std::sync::Barrier::new(THREADS));
    let handles: Vec<_> = (0..THREADS)
        .map(|_| {
            let q = Arc::clone(&q);
            let winners = Arc::clone(&winners);
            let barrier = Arc::clone(&barrier);
            std::thread::spawn(move || {
                barrier.wait();
                if let Some(run) = q.try_claim() {
                    winners.fetch_add(1, Ordering::AcqRel);
                    // Hold the run so no second claim can start.
                    std::thread::sleep(std::time::Duration::from_millis(20));
                    assert_eq!(run.len(), 64);
                    assert!(q.try_claim().is_none(), "second drainer admitted mid-run");
                }
            })
        })
        .collect();
    for h in handles {
        h.join().unwrap();
    }
    assert_eq!(winners.load(Ordering::SeqCst), 1, "claim admitted multiple drainers");
}

#[test]
fn test_claim_queue_shed_conservation_under_concurrency() {
    const PRODUCERS: usize = 4;
    const ATTEMPTS: u64 = 5_000;
    const BOUND: u64 = 8;

    let q: Arc<ClaimQueue<Tagged>> = Arc::new(ClaimQueue::new(BOUND));
    let served: Arc<std::sync::Mutex<Vec<Tagged>>> = Arc::new(std::sync::Mutex::new(Vec::new()));
    let accepted = Arc::new(AtomicU64::new(0));
    let shed = Arc::new(AtomicU64::new(0));
    let live = Arc::new(AtomicU64::new(PRODUCERS as u64));

    let mut handles = Vec::new();
    for p in 0..PRODUCERS {
        let q = Arc::clone(&q);
        let accepted = Arc::clone(&accepted);
        let shed = Arc::clone(&shed);
        let live = Arc::clone(&live);
        handles.push(std::thread::spawn(move || {
            for seq in 0..ATTEMPTS {
                match admit(&q, AdmissionPolicy::Shed, (p, seq)) {
                    Admitted::Enqueued { depth, .. } => {
                        assert!(depth <= BOUND, "admitted past the bound: depth {depth}");
                        accepted.fetch_add(1, Ordering::AcqRel);
                    }
                    Admitted::Shed(item) => {
                        assert_eq!(item, (p, seq), "shed returned someone else's batch");
                        shed.fetch_add(1, Ordering::AcqRel);
                    }
                }
            }
            live.fetch_sub(1, Ordering::AcqRel);
        }));
    }
    // One drainer keeps the queue moving so some pushes are admitted.
    {
        let q = Arc::clone(&q);
        let served = Arc::clone(&served);
        let live = Arc::clone(&live);
        handles.push(std::thread::spawn(move || loop {
            match q.try_claim() {
                Some(mut run) => served.lock().unwrap().extend(run.drain()),
                None => {
                    if live.load(Ordering::Acquire) == 0 && q.is_idle() {
                        break;
                    }
                    std::hint::spin_loop();
                }
            }
        }));
    }
    for h in handles {
        h.join().unwrap();
    }

    let log = served.lock().unwrap();
    let acc = accepted.load(Ordering::SeqCst);
    let sh = shed.load(Ordering::SeqCst);
    // Conservation: attempted == accepted + shed, and exactly the
    // accepted items were served (once each).
    assert_eq!(acc + sh, PRODUCERS as u64 * ATTEMPTS, "an attempt vanished");
    assert_eq!(log.len() as u64, acc, "served != accepted");
    assert!(acc > 0, "bound shed everything — drainer never ran?");
    let mut seen = std::collections::HashSet::new();
    for &item in log.iter() {
        assert!(seen.insert(item), "duplicate service of {item:?}");
    }
}
