//! Property-based tests (via the in-crate mini harness,
//! `util::props::forall`) over the crate's core invariants.

use std::sync::Arc;

use big_atomics::atomics::{
    BigAtomic, CachedMemEff, CachedWaitFree, CachedWritable, HtmSim, Indirect, LockPool,
    MemEffDomain, SeqLock, SimpLock, Words,
};
use big_atomics::bench::workload::{classify, Op, ZipfCdf, N_CDF};
use big_atomics::util::props::forall;
use big_atomics::util::rng::{mix64, Xoshiro256};

/// Sequential ops on any big atomic must behave exactly like a plain
/// register with CAS semantics.
fn register_model_check<A: BigAtomic<Words<2>>>(ops: &[u64]) -> bool {
    let a = A::new(Words([0, 0]));
    let mut model = Words([0, 0]);
    for (i, &op) in ops.iter().enumerate() {
        match op % 3 {
            0 => {
                if a.load() != model {
                    return false;
                }
            }
            1 => {
                let v = Words([op, i as u64]);
                a.store(v);
                model = v;
            }
            _ => {
                // Mix of expected-correct and expected-stale CASes.
                let expected = if op % 2 == 0 { model } else { Words([op, op]) };
                let desired = Words([op ^ 0xABCD, i as u64 + 1]);
                let r = a.compare_exchange(expected, desired);
                let model_ok = expected == model;
                if r.is_ok() != model_ok && expected != desired {
                    return false;
                }
                // Single-threaded, so the witness must be exact: the
                // current (model) value on failure, `expected` on success.
                match r {
                    Ok(prev) => {
                        if prev != expected {
                            return false;
                        }
                        if expected != desired {
                            model = desired;
                        }
                    }
                    Err(w) => {
                        if w != model {
                            return false;
                        }
                    }
                }
            }
        }
    }
    a.load() == model
}

#[test]
fn prop_register_semantics_all_impls() {
    forall::<[u64; 24], _>(101, 200, |ops| register_model_check::<SeqLock<Words<2>>>(ops));
    forall::<[u64; 24], _>(102, 200, |ops| register_model_check::<SimpLock<Words<2>>>(ops));
    forall::<[u64; 24], _>(103, 200, |ops| register_model_check::<LockPool<Words<2>>>(ops));
    forall::<[u64; 24], _>(104, 200, |ops| register_model_check::<Indirect<Words<2>>>(ops));
    forall::<[u64; 24], _>(105, 200, |ops| {
        register_model_check::<CachedWaitFree<Words<2>>>(ops)
    });
    forall::<[u64; 24], _>(106, 200, |ops| {
        register_model_check::<CachedMemEff<Words<2>>>(ops)
    });
    forall::<[u64; 24], _>(107, 200, |ops| {
        register_model_check::<CachedWritable<Words<2>>>(ops)
    });
    forall::<[u64; 24], _>(108, 200, |ops| register_model_check::<HtmSim<Words<2>>>(ops));
}

#[test]
fn prop_zipf_search_equals_linear_scan() {
    // The branch-free binary search must agree with the obvious linear
    // definition: first index with cdf[i] > u.
    forall::<(u64, u64), _>(201, 300, |(n_raw, bits_raw)| {
        let n = (*n_raw as usize % N_CDF) + 1;
        let bits = *bits_raw as u32;
        let z = ZipfCdf::new(n, 0.77);
        let got = z.search(bits);
        let u = bits as f32 * 2.328_306_4e-10;
        let linear = z
            .cdf()
            .iter()
            .position(|&c| c > u)
            .unwrap_or(N_CDF - 1)
            .min(N_CDF - 1) as u32;
        got == linear
    });
}

#[test]
fn prop_zipf_spread_in_range() {
    forall::<(u64, u64), _>(202, 300, |(n_raw, extra)| {
        let n = (*n_raw as usize % 10_000_000) + 1;
        let z = ZipfCdf::new(n, 0.9);
        (0..N_CDF as u32)
            .step_by(37)
            .all(|slot| z.spread(slot, *extra) < n)
    });
}

#[test]
fn prop_classify_consistent_with_threshold() {
    forall::<u64, _>(203, 500, |&bits_raw| {
        let bits = bits_raw as u32;
        let r = bits as f32 * 2.328_306_4e-10;
        for u in [0.0f32, 0.3, 1.0] {
            let op = classify(bits, u);
            let is_update = r < u;
            match op {
                Op::Find => {
                    if is_update {
                        return false;
                    }
                }
                Op::Insert => {
                    if !is_update || bits & 1 != 0 {
                        return false;
                    }
                }
                Op::Delete => {
                    if !is_update || bits & 1 != 1 {
                        return false;
                    }
                }
            }
        }
        true
    });
}

#[test]
fn prop_mix64_bijective_on_sample() {
    // mix64 must be injective (it is a bijection; spot-check inverses
    // don't collide on arbitrary inputs).
    forall::<(u64, u64), _>(204, 2000, |(a, b)| a == b || mix64(*a) != mix64(*b));
}

#[test]
fn prop_memeff_node_bound_under_concurrency() {
    // §3.2's headline bound: nodes allocated stay O(p), independent of
    // the op count and the number of atomics, even under contention.
    let domain: Arc<MemEffDomain<Words<2>>> = Arc::new(MemEffDomain::new());
    let atomics: Arc<Vec<CachedMemEff<Words<2>>>> = Arc::new(
        (0..256)
            .map(|i| CachedMemEff::with_domain(Words([i, 0]), Arc::clone(&domain)))
            .collect(),
    );
    let threads = 4;
    let handles: Vec<_> = (0..threads)
        .map(|t| {
            let atomics = Arc::clone(&atomics);
            std::thread::spawn(move || {
                let mut rng = Xoshiro256::seeded(900 + t as u64);
                for i in 0..30_000u64 {
                    let a = &atomics[rng.next_below(atomics.len())];
                    let cur = a.load();
                    let _ = a.compare_exchange(cur, Words([cur.0[0].wrapping_add(1), i]));
                }
            })
        })
        .collect();
    for h in handles {
        h.join().unwrap();
    }
    let nodes = domain.allocated_nodes();
    // Bound: 3p per thread is the slab cap; in practice a handful per
    // thread. Assert well under the theoretical cap and far under
    // anything op- or n-proportional.
    assert!(
        nodes <= (3 * big_atomics::MAX_THREADS) as u64,
        "node pool exploded: {nodes}"
    );
    assert!(nodes <= 1024, "nodes {nodes} not O(p)-ish for p=4");
}

#[test]
fn prop_words_any_bits_roundtrip() {
    forall::<[u64; 8], _>(205, 300, |bits| {
        let a: SeqLock<Words<8>> = SeqLock::new(Words(*bits));
        a.load() == Words(*bits)
    });
}

#[test]
fn prop_cas_same_value_always_true_when_current() {
    // AA rule: compare_exchange(v, v) with v current returns Ok and
    // changes nothing (and must not disturb concurrent state) on every
    // implementation.
    forall::<[u64; 3], _>(206, 200, |bits| {
        fn check<A: BigAtomic<Words<3>>>(v: Words<3>) -> bool {
            let a = A::new(v);
            a.compare_exchange(v, v) == Ok(v) && a.load() == v
        }
        let v = Words(*bits);
        check::<SeqLock<Words<3>>>(v)
            && check::<Indirect<Words<3>>>(v)
            && check::<CachedWaitFree<Words<3>>>(v)
            && check::<CachedMemEff<Words<3>>>(v)
            && check::<CachedWritable<Words<3>>>(v)
    });
}
