//! Tests over the AOT artifacts: HLO load, bit-exact workload
//! cross-validation (Pallas kernel == Rust sampler), stats-model
//! agreement, and the artifact-driven benchmark path.
//!
//! These tests need `make artifacts` to have run; they skip (with a
//! loud message) when the artifacts are absent so plain `cargo test`
//! stays green in a fresh checkout.

use big_atomics::bench::driver::{run_atomics, AtomicImpl, OpSource};
use big_atomics::bench::workload::{generate_rust, WorkloadSpec};
use big_atomics::coordinator::Coordinator;
use big_atomics::runtime::workload_gen::WorkloadEngine;
use big_atomics::runtime::{default_artifact_dir, Runtime};

fn runtime_or_skip() -> Option<Runtime> {
    match Runtime::new(default_artifact_dir()) {
        Ok(rt) => Some(rt),
        Err(e) => {
            eprintln!("SKIPPING artifact test (run `make artifacts`): {e}");
            None
        }
    }
}

#[test]
fn test_artifacts_load_and_compile() {
    let Some(rt) = runtime_or_skip() else { return };
    assert_eq!(rt.platform(), "cpu");
    assert_eq!(rt.manifest.n_cdf, big_atomics::bench::workload::N_CDF);
    let engine = WorkloadEngine::new(&rt).unwrap();
    assert_eq!(engine.batch(), rt.manifest.batch);
    rt.stats_engine().unwrap();
}

#[test]
fn test_workload_bit_exact_cross_validation() {
    let Some(_rt) = runtime_or_skip() else { return };
    let coord = Coordinator::new(true).unwrap();
    // Covers n < N_CDF, n == N_CDF with extreme contention, and the
    // stratified-tail path (n = 1M), two thread streams each.
    let compared = coord.validate_workload(2048).unwrap();
    assert_eq!(compared, 3 * 2 * 2048);
}

#[test]
fn test_stats_engine_matches_rust_percentiles() {
    let Some(rt) = runtime_or_skip() else { return };
    let stats = rt.stats_engine().unwrap();
    let n = rt.manifest.batch;
    // A known distribution: latencies = 0..n shuffled.
    let mut lat: Vec<f32> = (0..n).map(|i| i as f32).collect();
    // Deterministic shuffle.
    let mut rng = big_atomics::util::rng::Xoshiro256::seeded(5);
    for i in (1..lat.len()).rev() {
        lat.swap(i, rng.next_below(i + 1));
    }
    let s = stats.summarize(&lat).unwrap();
    let nf = (n - 1) as f32;
    assert!((s.mean - nf / 2.0).abs() < 1.0, "mean {}", s.mean);
    assert!((s.p50 - 0.50 * nf).abs() <= 2.0, "p50 {}", s.p50);
    assert!((s.p90 - 0.90 * nf).abs() <= 2.0, "p90 {}", s.p90);
    assert!((s.p99 - 0.99 * nf).abs() <= 2.0, "p99 {}", s.p99);
    assert_eq!(s.max, nf);
}

#[test]
fn test_artifact_driven_benchmark_runs() {
    let Some(rt) = runtime_or_skip() else { return };
    let engine = WorkloadEngine::new(&rt).unwrap();
    let spec = WorkloadSpec {
        n: 1000,
        theta: 0.9,
        update_pct: 50,
        seed: 11,
    };
    let r = run_atomics(
        AtomicImpl::CachedMemEff,
        3,
        &spec,
        2,
        std::time::Duration::from_millis(50),
        &OpSource::Artifact(&engine),
    )
    .unwrap();
    assert!(r.total_ops > 1000, "{} ops", r.total_ops);
}

#[test]
fn test_engine_generate_matches_rust_generate_multi_batch() {
    // > one artifact batch, to exercise the batching loop.
    let Some(rt) = runtime_or_skip() else { return };
    let engine = WorkloadEngine::new(&rt).unwrap();
    let spec = WorkloadSpec {
        n: 4096,
        theta: 0.99,
        update_pct: 20,
        seed: 33,
    };
    let count = rt.manifest.batch + 1000;
    let ours = generate_rust(&spec, count, 9);
    let theirs = engine.generate(&spec, count, 9).unwrap();
    assert_eq!(ours.len(), theirs.len());
    for (a, b) in ours.iter().zip(&theirs) {
        assert_eq!(a.op, b.op);
        assert_eq!(a.rank, b.rank);
        assert_eq!(a.key, b.key);
    }
}

#[test]
fn test_kv_service_with_artifacts() {
    let Some(rt) = runtime_or_skip() else { return };
    let cfg = big_atomics::coordinator::kv_service::KvConfig {
        n: 4096,
        workers: 2,
        batch: 256,
        duration: std::time::Duration::from_millis(200),
        update_pct: 30,
        theta: 0.5,
        seed: 44,
        ..big_atomics::coordinator::kv_service::KvConfig::default()
    };
    let rep = big_atomics::coordinator::kv_service::run(&cfg, Some(&rt)).unwrap();
    assert!(rep.total_requests > 200);
    let lat = rep.latency.expect("stats artifact should produce a summary");
    assert!(lat.p50 > 0.0 && lat.p99 >= lat.p50 && lat.max >= lat.p99);
}
