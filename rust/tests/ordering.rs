//! Cross-backend fence-stress suite for the memory-ordering diet.
//!
//! The diet (Acquire/Release/Relaxed + two SeqCst fences — see
//! `util::ordering`) must be observationally equivalent to the seed's
//! blanket SeqCst. These tests hammer the properties a wrong demotion
//! breaks first, across **all eight** backends:
//!
//! * **torn values** — a missing seqlock fence (reader load-load or
//!   writer store-store) lets a reader assemble words from two different
//!   stores and still pass the version re-check;
//! * **witness monotonicity** — with a monotonically increasing counter,
//!   every linearizable read (loads *and* failed-CAS witnesses) observed
//!   by one thread must be non-decreasing; a mis-ordered
//!   publication/validation lets a stale value surface after a newer one;
//! * **hazard announce visibility** — the relaxed-store-plus-fence
//!   announce path must still be visible to `protected_snapshot` across
//!   threads;
//! * **epoch announce visibility** — the relaxed-store-plus-fence *pin*
//!   path (the epoch mirror of the hazard announce) must block a
//!   cross-thread advance, under the fenced and the blanket-`SeqCst`
//!   policies alike;
//! * **retire/recycle integrity** — link chains whose nodes are
//!   retired-then-recycled under the epoch scheme must never surface a
//!   torn or stale value to a concurrent reader.
//!
//! The whole file also runs under `--features seqcst_audit` (CI builds
//! both), so a fenced-only failure localizes to a demotion.

use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;

use big_atomics::atomics::{
    BigAtomic, CachedMemEff, CachedWaitFree, CachedWritable, HtmSim, Indirect, LockPool, SeqLock,
    SimpLock, Words,
};
use big_atomics::hash::{CacheHash, ConcurrentMap, LinkVal};
use big_atomics::smr::hazard::{protected_snapshot, HazardPointer};
use big_atomics::smr::{epoch, Epoch, RegionSmr};
use big_atomics::util::ordering::OrderingPolicy;

/// Readers assert every load is word-uniform while writers run a heavy
/// store/CAS mix over values of the form [x; 4] — any torn assembly that
/// survives the version protocol trips the assert.
fn torn_value_stress<A: BigAtomic<Words<4>> + 'static>() {
    let a: Arc<A> = Arc::new(A::new(Words([0; 4])));
    let stop = Arc::new(AtomicBool::new(false));
    let readers: Vec<_> = (0..2)
        .map(|_| {
            let a = Arc::clone(&a);
            let stop = Arc::clone(&stop);
            std::thread::spawn(move || {
                while !stop.load(Ordering::Relaxed) {
                    let v = a.load();
                    assert!(
                        v.0.iter().all(|&w| w == v.0[0]),
                        "torn read on {}: {:?}",
                        A::name(),
                        v.0
                    );
                }
            })
        })
        .collect();
    let writers: Vec<_> = (0..2)
        .map(|t| {
            let a = Arc::clone(&a);
            std::thread::spawn(move || {
                let mut cur = a.load();
                for i in 1..4_000u64 {
                    let x = i * 4 + t;
                    if i % 2 == 0 {
                        // Store side of the mix.
                        a.store(Words([x; 4]));
                        cur = Words([x; 4]);
                    } else {
                        // CAS side: witness-fed retry, bounded attempts
                        // (losing is fine — the mix is the point).
                        for _ in 0..4 {
                            match a.compare_exchange(cur, Words([x; 4])) {
                                Ok(_) => break,
                                Err(w) => cur = w,
                            }
                        }
                    }
                }
            })
        })
        .collect();
    for w in writers {
        w.join().unwrap();
    }
    stop.store(true, Ordering::SeqCst);
    for r in readers {
        r.join().unwrap();
    }
}

/// Writers increment word 0 via `fetch_update` (word 1 mirrors it so
/// tearing is also visible here); observers assert that the sequence of
/// values they see — through plain loads *and* through failed-CAS
/// witnesses — never goes backwards.
fn witness_monotonicity<A: BigAtomic<Words<2>> + 'static>() {
    let a: Arc<A> = Arc::new(A::new(Words([0, 0])));
    let stop = Arc::new(AtomicBool::new(false));
    let observers: Vec<_> = (0..2)
        .map(|o| {
            let a = Arc::clone(&a);
            let stop = Arc::clone(&stop);
            std::thread::spawn(move || {
                let mut last = 0u64;
                while !stop.load(Ordering::Relaxed) {
                    let v = if o == 0 {
                        a.load()
                    } else {
                        // A CAS that can never succeed: its Err witness
                        // must still be a linearizable read.
                        match a.compare_exchange(Words([u64::MAX, 0]), Words([0, 0])) {
                            Ok(v) | Err(v) => v,
                        }
                    };
                    assert_eq!(v.0[0], v.0[1], "torn witness on {}: {:?}", A::name(), v.0);
                    assert!(
                        v.0[0] >= last,
                        "witness went backwards on {}: {} -> {}",
                        A::name(),
                        last,
                        v.0[0]
                    );
                    last = v.0[0];
                }
            })
        })
        .collect();
    let writers: Vec<_> = (0..3)
        .map(|_| {
            let a = Arc::clone(&a);
            std::thread::spawn(move || {
                for _ in 0..2_000u64 {
                    let _ = a
                        .fetch_update(|mut v| {
                            v.0[0] += 1;
                            v.0[1] = v.0[0];
                            Some(v)
                        })
                        .expect("unconditional update");
                }
            })
        })
        .collect();
    for w in writers {
        w.join().unwrap();
    }
    stop.store(true, Ordering::SeqCst);
    for o in observers {
        o.join().unwrap();
    }
    assert_eq!(a.load(), Words([6_000, 6_000]));
}

macro_rules! fence_stress {
    ($name:ident, $w4:ty, $w2:ty) => {
        mod $name {
            use super::*;

            #[test]
            fn torn_values() {
                torn_value_stress::<$w4>();
            }

            #[test]
            fn witness_monotonic() {
                witness_monotonicity::<$w2>();
            }
        }
    };
}

fence_stress!(seqlock, SeqLock<Words<4>>, SeqLock<Words<2>>);
fence_stress!(simplock, SimpLock<Words<4>>, SimpLock<Words<2>>);
fence_stress!(lockpool, LockPool<Words<4>>, LockPool<Words<2>>);
fence_stress!(indirect, Indirect<Words<4>>, Indirect<Words<2>>);
fence_stress!(
    cached_waitfree,
    CachedWaitFree<Words<4>>,
    CachedWaitFree<Words<2>>
);
fence_stress!(cached_memeff, CachedMemEff<Words<4>>, CachedMemEff<Words<2>>);
fence_stress!(
    cached_writable,
    CachedWritable<Words<4>>,
    CachedWritable<Words<2>>
);
fence_stress!(htm_sim, HtmSim<Words<4>>, HtmSim<Words<2>>);

#[test]
fn protected_snapshot_sees_cross_thread_relaxed_announce() {
    // The diet demotes the announce store to Relaxed + SeqCst fence; a
    // snapshot taken by a *different* thread after the announce (ordered
    // here via channels) must still contain it.
    const ADDR: usize = 0x5A5A_0000;
    let (ready_tx, ready_rx) = std::sync::mpsc::channel::<()>();
    let (done_tx, done_rx) = std::sync::mpsc::channel::<()>();
    let announcer = std::thread::spawn(move || {
        let h = HazardPointer::new();
        h.announce(ADDR);
        ready_tx.send(()).unwrap();
        done_rx.recv().unwrap();
        h.clear();
    });
    ready_rx.recv().unwrap();
    let mut buf = Vec::new();
    protected_snapshot(&mut buf);
    assert!(
        buf.contains(&ADDR),
        "cross-thread announcement missing from snapshot: {buf:?}"
    );
    done_tx.send(()).unwrap();
    announcer.join().unwrap();
}

/// The epoch mirror of the hazard announce-visibility case: a pin made
/// on another thread (ordered here via channels) must be visible to the
/// advance scan — i.e. it stalls the global epoch at most one advance
/// away. A lost relaxed-announce (missing pin fence) would let the
/// advancer run the epoch arbitrarily far past the pinned reader.
fn epoch_pin_blocks_cross_thread_advance<P: OrderingPolicy>() {
    let (pinned_tx, pinned_rx) = std::sync::mpsc::channel::<()>();
    let (done_tx, done_rx) = std::sync::mpsc::channel::<()>();
    let pinner = std::thread::spawn(move || {
        let _g = Epoch::<P>::pin();
        pinned_tx.send(()).unwrap();
        done_rx.recv().unwrap();
    });
    pinned_rx.recv().unwrap();
    let e0 = epoch::global_epoch();
    for _ in 0..64 {
        Epoch::<P>::try_advance_and_collect();
    }
    let now = epoch::global_epoch();
    assert!(
        now <= e0 + 1,
        "advance ignored a cross-thread pin ({}): {e0} -> {now}",
        P::NAME
    );
    done_tx.send(()).unwrap();
    pinner.join().unwrap();
}

#[test]
fn epoch_pin_blocks_cross_thread_advance_fenced_policy() {
    use big_atomics::util::ordering::Fenced;
    epoch_pin_blocks_cross_thread_advance::<Fenced>();
}

#[test]
fn epoch_pin_blocks_cross_thread_advance_seqcst_audit_policy() {
    // The seqcst_audit leg of the same case, runnable in any build: the
    // blanket-SeqCst policy instantiation shares the protocol state.
    use big_atomics::util::ordering::SeqCstEverywhere;
    epoch_pin_blocks_cross_thread_advance::<SeqCstEverywhere>();
}

/// Torn-free reads of retired-then-recycled links: a contended CacheHash
/// bucket churns chain nodes (retire on every remove, reallocation on
/// every insert — maximum address reuse pressure on the epoch scheme)
/// while readers validate the key→value invariant. A reclamation
/// ordering bug surfaces as a stale or torn value.
fn retired_link_read_integrity<S: RegionSmr>() {
    let t: Arc<CacheHash<CachedMemEff<LinkVal>, u64, u64, S>> = Arc::new(CacheHash::new(2));
    let stop = Arc::new(AtomicBool::new(false));
    // Every present key k maps to k * 31 + 7 — readers check or absent.
    let readers: Vec<_> = (0..2)
        .map(|_| {
            let t = Arc::clone(&t);
            let stop = Arc::clone(&stop);
            std::thread::spawn(move || {
                while !stop.load(Ordering::Relaxed) {
                    for k in 0..32u64 {
                        if let Some(v) = t.find(k) {
                            assert_eq!(v, k * 31 + 7, "stale/torn link value for key {k}");
                        }
                    }
                }
            })
        })
        .collect();
    let writers: Vec<_> = (0..2)
        .map(|w| {
            let t = Arc::clone(&t);
            std::thread::spawn(move || {
                for round in 0..400u64 {
                    for k in (w % 2..32).step_by(2) {
                        if round % 2 == 0 {
                            let _ = t.insert(k, k * 31 + 7);
                        } else {
                            let _ = t.remove(k);
                        }
                    }
                }
            })
        })
        .collect();
    for w in writers {
        w.join().unwrap();
    }
    stop.store(true, Ordering::SeqCst);
    for r in readers {
        r.join().unwrap();
    }
}

#[test]
fn retired_link_reads_untorn_fenced_epoch() {
    use big_atomics::util::ordering::Fenced;
    retired_link_read_integrity::<Epoch<Fenced>>();
}

#[test]
fn retired_link_reads_untorn_seqcst_epoch() {
    use big_atomics::util::ordering::SeqCstEverywhere;
    retired_link_read_integrity::<Epoch<SeqCstEverywhere>>();
}

#[test]
fn seqcst_audit_and_fenced_agree_on_semantics() {
    // Explicit-policy instantiations (the ablation pair) must satisfy
    // the exact same witness contract as the build default.
    use big_atomics::util::ordering::{Fenced, SeqCstEverywhere};
    fn check<A: BigAtomic<Words<2>>>() {
        let a = A::new(Words([1, 2]));
        assert_eq!(a.compare_exchange(Words([1, 2]), Words([3, 4])), Ok(Words([1, 2])));
        assert_eq!(a.compare_exchange(Words([1, 2]), Words([9, 9])), Err(Words([3, 4])));
        assert_eq!(a.swap(Words([5, 6])), Words([3, 4]));
        assert_eq!(a.load(), Words([5, 6]));
    }
    check::<SeqLock<Words<2>, Fenced>>();
    check::<SeqLock<Words<2>, SeqCstEverywhere>>();
    check::<CachedWaitFree<Words<2>, Fenced>>();
    check::<CachedWaitFree<Words<2>, SeqCstEverywhere>>();
}
