//! Telemetry contract tests, run in their own process so counter
//! arithmetic can be *exact* (the lib's unit tests run concurrently
//! with instrumented code and can only assert lower bounds).
//!
//! This binary deliberately never drives the atomics/hash/SMR layers:
//! the only counter writers here are the explicit `counter!` calls
//! below, so with `--features telemetry` the multithreaded totals must
//! match the increment count exactly, and without the feature every
//! total must stay zero (the macro compiles to nothing).

use big_atomics::obs::{telemetry, Event, Histogram, ObsSnapshot};

const TELEMETRY_ON: bool = cfg!(feature = "telemetry");

#[test]
fn test_counter_snapshot_equals_total_increments_multithreaded() {
    let threads = 8u64;
    let per = 25_000u64;
    let before = telemetry::total(Event::HelpRecache);
    assert_eq!(before, 0, "no other writer exists in this binary");
    std::thread::scope(|s| {
        for _ in 0..threads {
            s.spawn(|| {
                for _ in 0..per {
                    big_atomics::counter!(HelpRecache);
                }
            });
        }
    });
    let after = telemetry::total(Event::HelpRecache);
    if TELEMETRY_ON {
        assert_eq!(after, threads * per, "sharded cells lost increments");
        assert_eq!(telemetry::totals()[Event::HelpRecache as usize], threads * per);
    } else {
        assert_eq!(after, 0, "telemetry-off build recorded an event");
    }
}

#[test]
fn test_counter_macro_count_form_and_lazy_count_expr() {
    let before = telemetry::total(Event::LockAcquire);
    let mut evaluated = false;
    big_atomics::counter!(LockAcquire, {
        evaluated = true;
        7u64
    });
    let after = telemetry::total(Event::LockAcquire);
    if TELEMETRY_ON {
        assert!(evaluated, "count expression must run with the feature on");
        assert!(after >= before + 7);
    } else {
        // No-op expansion: zero instructions, count expression captured
        // but never evaluated.
        assert!(!evaluated, "no-op macro evaluated its count expression");
        assert_eq!(after, 0);
    }
}

#[test]
fn test_histogram_quantiles_within_one_sub_bucket() {
    // Uniform 1..=N: the true q-quantile is ceil(q*N); the histogram
    // answers with its bucket's lower bound, so the estimate may only
    // undershoot, by at most one sub-bucket (1/16 relative).
    let h = Histogram::new();
    let n = 10_000u64;
    for v in 1..=n {
        h.record(v);
    }
    let snap = h.snapshot();
    assert_eq!(snap.count, n);
    assert_eq!(snap.min, 1);
    assert_eq!(snap.max, n);
    for (q, p) in [
        (0.50, snap.p50()),
        (0.90, snap.p90()),
        (0.99, snap.p99()),
        (0.999, snap.p999()),
    ] {
        let truth = (q * n as f64).ceil() as u64;
        assert!(p <= truth, "q={q}: estimate {p} overshoots {truth}");
        assert!(
            truth as f64 <= p as f64 * (1.0 + 1.0 / 16.0) + 1.0,
            "q={q}: estimate {p} more than a sub-bucket below {truth}"
        );
    }
    // A heavy-tailed shape exercises the log buckets the same way.
    let h2 = Histogram::new();
    for i in 0..64u32 {
        h2.record(1u64 << (i % 40));
    }
    let s2 = h2.snapshot();
    assert_eq!(s2.count, 64);
    assert!(s2.p999() <= s2.max);
}

#[test]
fn test_obs_snapshot_json_well_formed() {
    let snap = ObsSnapshot::capture();
    let json = snap.to_json();
    assert!(json.contains("\"counters\""));
    assert!(json.contains("\"histograms\""));
    // Every event name and global histogram appears as a key.
    for e in telemetry::ALL {
        assert!(json.contains(&format!("\"{}\"", e.name())), "missing {}", e.name());
    }
    for name in ["kv_latency_ns", "kv_batch", "kv_queue_depth", "kv_shard_depth"] {
        assert!(json.contains(&format!("\"{name}\"")), "missing {name}");
    }
    let opens = json.matches('{').count();
    let closes = json.matches('}').count();
    assert_eq!(opens, closes, "unbalanced JSON braces");
    assert!(!json.contains("NaN") && !json.contains("inf"));
    // A snapshot differenced with itself is empty.
    assert!(snap.delta_since(&snap).is_empty());
}
