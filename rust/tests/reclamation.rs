//! Reclamation stress suite for the unified `Smr` layer.
//!
//! Proves the contracts both schemes promise, with drop-counter types:
//!
//! * **protection** — nothing is freed while a guard protects it, and it
//!   is freed (eventually) after the guard drops, under *both* `Smr`
//!   impls (the hazard/epoch cross-check: one generic scenario);
//! * **the epoch distance rule** — a node retired with stamp `e` is
//!   never freed before the global epoch advances two (in fact three —
//!   two reader epochs plus the stamp-slack epoch) past `e`, and a
//!   pinned reader stalls the epoch (hence all frees) at most one
//!   advance away;
//! * **orphan-bag handoff** — garbage retired by a thread that exits
//!   without collecting is absorbed by the registry exit hook and freed
//!   by a later collect on another thread, under both schemes;
//! * **scheme-generic backends** — `CachedMemEff` over the epoch scheme
//!   (the stamp-based recycler) stays exact under concurrency.
//!
//! Tests in this binary run in parallel and share the process-wide epoch
//! and hazard domains, so every "eventually freed" assertion retries
//! (another test's short-lived pin may block one advance) and every
//! "not freed" assertion only inspects this test's own drop counter.

use std::sync::atomic::{AtomicPtr, AtomicUsize, Ordering};
use std::sync::Arc;

use big_atomics::atomics::{BigAtomic, CachedMemEff, Words};
use big_atomics::hash::{CacheHash, Chaining, ConcurrentMap, LinkVal};
use big_atomics::smr::pool::{self, PageBatch};
use big_atomics::smr::{epoch, Epoch, Hazard, Smr};
use big_atomics::util::ordering::{DefaultPolicy, Fenced, SeqCstEverywhere};

/// A heap value whose drop increments a test-owned counter.
struct Counted {
    drops: Arc<AtomicUsize>,
    payload: u64,
}

impl Drop for Counted {
    fn drop(&mut self) {
        self.drops.fetch_add(1, Ordering::SeqCst);
    }
}

fn counted(drops: &Arc<AtomicUsize>, payload: u64) -> *mut Counted {
    Box::into_raw(Box::new(Counted {
        drops: Arc::clone(drops),
        payload,
    }))
}

/// Retry a collect-then-check loop until `drops` reaches `want` (bounded
/// by a generous iteration count so a wedged scheme still fails loudly).
fn collect_until<S: Smr>(drops: &Arc<AtomicUsize>, want: usize, what: &str) {
    for _ in 0..100_000 {
        S::collect();
        if drops.load(Ordering::SeqCst) >= want {
            return;
        }
        std::thread::yield_now();
    }
    panic!(
        "{what} ({}): only {}/{want} freed after bounded collects",
        S::NAME,
        drops.load(Ordering::SeqCst)
    );
}

/// The cross-check scenario, identical under both schemes: protect a
/// pointer, retire it, prove it survives collects; release, prove it is
/// freed.
fn protected_then_released<S: Smr>() {
    let drops = Arc::new(AtomicUsize::new(0));
    let node = counted(&drops, 7);
    let src = AtomicPtr::new(node);
    let g = S::pin();
    let p = g.protect_ptr(&src);
    assert_eq!(unsafe { (*p).payload }, 7);
    // Unlink + retire while protected: collects must not free it.
    src.store(std::ptr::null_mut(), Ordering::SeqCst);
    unsafe { S::retire_box(p) };
    for _ in 0..64 {
        S::collect();
    }
    assert_eq!(
        drops.load(Ordering::SeqCst),
        0,
        "{}: freed while protected",
        S::NAME
    );
    // Protected reads stay valid right up to the release.
    assert_eq!(unsafe { (*p).payload }, 7);
    drop(g);
    collect_until::<S>(&drops, 1, "release-then-free");
}

#[test]
fn test_protected_then_released_hazard() {
    protected_then_released::<Hazard>();
}

#[test]
fn test_protected_then_released_epoch() {
    protected_then_released::<Epoch>();
}

#[test]
fn test_protected_then_released_epoch_seqcst_policy() {
    // The audit-policy epoch instantiation shares the same protocol
    // state and must satisfy the same contract.
    protected_then_released::<Epoch<SeqCstEverywhere>>();
}

#[test]
fn test_epoch_advance_distance_rule() {
    // Nothing retired with stamp s may be freed before the global epoch
    // passes s by the scheme's free distance (two reader epochs + one
    // stamp-slack epoch = 3) — observed from the outside: the retire
    // stamp is >= the epoch we read just before retiring (coherence),
    // the item sits in *our* unflushed thread bag so only our own
    // collects can free it, and the iteration that observes the drop
    // reads the global epoch after the freeing collect.
    let drops = Arc::new(AtomicUsize::new(0));
    let retired_at = epoch::global_epoch();
    unsafe { Epoch::<Fenced>::retire_box(counted(&drops, 1)) };
    for _ in 0..1_000_000 {
        let now = epoch::global_epoch();
        let freed = drops.load(Ordering::SeqCst);
        if freed > 0 {
            assert!(
                now >= retired_at + 2,
                "freed at epoch {now}, retired at >= {retired_at}: distance rule broken"
            );
            return;
        }
        Epoch::<Fenced>::try_advance_and_collect();
        std::thread::yield_now();
    }
    panic!("retired node never freed (epoch wedged?)");
}

#[test]
fn test_epoch_pinned_reader_blocks_frees() {
    // While a reader is pinned, garbage retired after its pin is never
    // freed (the epoch stalls one advance away at most).
    let drops = Arc::new(AtomicUsize::new(0));
    let (pinned_tx, pinned_rx) = std::sync::mpsc::channel::<()>();
    let (done_tx, done_rx) = std::sync::mpsc::channel::<()>();
    let reader = std::thread::spawn(move || {
        let _g = Epoch::<Fenced>::pin();
        pinned_tx.send(()).unwrap();
        done_rx.recv().unwrap();
    });
    pinned_rx.recv().unwrap();
    // Retire *after* the reader is pinned: its epoch stamp is at least
    // pin_epoch, so the free needs the full distance past the pin —
    // blocked while the pin lives.
    unsafe { Epoch::<Fenced>::retire_box(counted(&drops, 2)) };
    for _ in 0..256 {
        Epoch::<Fenced>::try_advance_and_collect();
    }
    assert_eq!(
        drops.load(Ordering::SeqCst),
        0,
        "garbage freed under a live pin"
    );
    done_tx.send(()).unwrap();
    reader.join().unwrap();
    collect_until::<Epoch>(&drops, 1, "post-unpin free");
}

/// Orphan handoff: a thread retires garbage and exits without flushing
/// or collecting; the registry exit hook must park it on the orphan
/// list, and a collect from the main thread must free it.
fn orphan_handoff_on_thread_exit<S: Smr>() {
    let drops = Arc::new(AtomicUsize::new(0));
    let n = 32;
    {
        let drops = Arc::clone(&drops);
        std::thread::spawn(move || {
            for i in 0..n {
                unsafe { S::retire_box(counted(&drops, i as u64)) };
            }
            // No flush, no collect: exit does the handoff.
        })
        .join()
        .unwrap();
    }
    collect_until::<S>(&drops, n, "orphan handoff");
}

#[test]
fn test_orphan_handoff_hazard() {
    orphan_handoff_on_thread_exit::<Hazard>();
}

#[test]
fn test_orphan_handoff_epoch() {
    orphan_handoff_on_thread_exit::<Epoch>();
}

#[test]
fn test_flush_thread_bag_then_collect_elsewhere() {
    // Explicit flush (the table-drop path): garbage retired here is
    // freeable by a collect after the flush, without a thread exit.
    fn run<S: Smr>() {
        let drops = Arc::new(AtomicUsize::new(0));
        for i in 0..8 {
            unsafe { S::retire_box(counted(&drops, i as u64)) };
        }
        S::flush_thread_bag();
        collect_until::<S>(&drops, 8, "flushed-bag collect");
    }
    run::<Hazard>();
    run::<Epoch>();
}

#[test]
fn test_pending_reclaims_visible() {
    // Retired-but-unfreed garbage shows up in the census for both
    // schemes (exact counts are racy across parallel tests; >= 1 while
    // we hold protection is robust for our own node).
    fn run<S: Smr>() {
        let drops = Arc::new(AtomicUsize::new(0));
        let node = counted(&drops, 3);
        let src = AtomicPtr::new(node);
        let g = S::pin();
        let p = g.protect_ptr(&src);
        unsafe { S::retire_box(p) };
        assert!(S::pending_reclaims() >= 1, "{}", S::NAME);
        drop(g);
        collect_until::<S>(&drops, 1, "pending census");
    }
    run::<Hazard>();
    run::<Epoch>();
}

#[test]
fn test_concurrent_protect_no_use_after_free_both_schemes() {
    // The classic UAF storm, generic over the scheme: one writer swaps
    // and retires; readers protect and validate payloads. A reclamation
    // bug shows up as a corrupt payload (or a crash under ASan/Miri).
    fn run<S: Smr>() {
        let drops = Arc::new(AtomicUsize::new(0));
        let src = Arc::new(AtomicPtr::new(counted(&drops, 1)));
        let stop = Arc::new(std::sync::atomic::AtomicBool::new(false));
        let mut handles = Vec::new();
        for _ in 0..3 {
            let src = Arc::clone(&src);
            let stop = Arc::clone(&stop);
            handles.push(std::thread::spawn(move || {
                while !stop.load(Ordering::Relaxed) {
                    let g = S::pin();
                    let p = g.protect_ptr(&src);
                    let v = unsafe { (*p).payload };
                    assert!(v >= 1 && v < 1 << 40, "corrupt read {v:#x}");
                }
                S::flush_thread_bag();
            }));
        }
        for gen in 2..3_000u64 {
            let new = counted(&drops, gen);
            let old = src.swap(new, Ordering::SeqCst);
            unsafe { S::retire_box(old) };
        }
        stop.store(true, Ordering::SeqCst);
        for h in handles {
            h.join().unwrap();
        }
        let last = src.load(Ordering::SeqCst);
        unsafe { S::retire_box(last) };
        S::flush_thread_bag();
    }
    run::<Hazard>();
    run::<Epoch>();
}

#[test]
fn test_table_growth_reclaims_through_epoch_under_churn() {
    // Grow-under-churn: a capacity-64 table is pushed through repeated
    // doublings by concurrent insert/remove churn while readers validate
    // key-derived values the whole time. Every drained table and every
    // migrated chain travels through `Epoch` — a premature free shows up
    // as a corrupt read (values are derivable from keys) or a crash
    // under ASan/Miri; a wedged epoch shows up as the liveness probe at
    // the end never freeing.
    let t: Arc<CacheHash<CachedMemEff<LinkVal>>> = Arc::new(CacheHash::new(64));
    let threads = 3u64;
    let per = 20_000u64;
    let stop = Arc::new(std::sync::atomic::AtomicBool::new(false));
    let mut handles = Vec::new();
    for tix in 0..threads {
        let t = Arc::clone(&t);
        handles.push(std::thread::spawn(move || {
            let base = tix * 1_000_000;
            for i in 0..per {
                let k = base + i;
                assert!(t.insert(k, big_atomics::util::rng::mix64(k)));
                if i % 2 == 1 {
                    assert!(t.remove(base + i - 1), "churned key lost");
                }
            }
        }));
    }
    {
        // Reader racing migration and reclamation: any value it sees
        // must be exactly the key-derived one.
        let t = Arc::clone(&t);
        let stop = Arc::clone(&stop);
        handles.push(std::thread::spawn(move || {
            let mut i = 0u64;
            while !stop.load(Ordering::Relaxed) {
                let k = (i % threads) * 1_000_000 + (i / threads) % per;
                if let Some(v) = t.find(k) {
                    assert_eq!(v, big_atomics::util::rng::mix64(k), "corrupt value for {k}");
                }
                i += 1;
            }
        }));
    }
    for h in handles.drain(..threads as usize) {
        h.join().unwrap();
    }
    stop.store(true, Ordering::Release);
    for h in handles {
        h.join().unwrap();
    }
    t.finish_resizes();
    assert!(!t.resize_in_flight());
    assert!(t.capacity() > 64, "no growth under churn");
    assert!(
        t.generation() >= 1,
        "no drained table was retired through Epoch"
    );
    // Half the keys survive the churn with exact values.
    for tix in 0..threads {
        let base = tix * 1_000_000;
        for i in (1..per).step_by(2) {
            let k = base + i;
            assert_eq!(t.find(k), Some(big_atomics::util::rng::mix64(k)), "key {k}");
        }
    }
    // Liveness probe: the epoch scheme must still advance and free after
    // the growth retired tables/chains (a stuck announcement or lost
    // descriptor would wedge it).
    let drops = Arc::new(AtomicUsize::new(0));
    unsafe { Epoch::<Fenced>::retire_box(counted(&drops, 42)) };
    collect_until::<Epoch>(&drops, 1, "post-growth epoch liveness");
}

#[test]
fn test_memeff_epoch_recycler_exact_under_concurrency() {
    // Algorithm 2 over the epoch scheme: the stamp-based recycler must
    // preserve CAS exactness exactly like the hazard announcement scan.
    let a: Arc<CachedMemEff<Words<4>, DefaultPolicy, Epoch>> =
        Arc::new(CachedMemEff::new(Words([0; 4])));
    let threads = 4;
    let rounds = 1_500u64;
    let wins = Arc::new(std::sync::atomic::AtomicU64::new(0));
    let handles: Vec<_> = (0..threads)
        .map(|t| {
            let a = Arc::clone(&a);
            let wins = Arc::clone(&wins);
            std::thread::spawn(move || {
                for r in 0..rounds {
                    let cur = a.load();
                    let next = Words([cur.0[0] + 1, r + 1, t as u64, cur.0[3] ^ r]);
                    if a.compare_exchange(cur, next).is_ok() {
                        wins.fetch_add(1, Ordering::Relaxed);
                    }
                }
            })
        })
        .collect();
    for h in handles {
        h.join().unwrap();
    }
    assert_eq!(a.load().0[0], wins.load(Ordering::SeqCst));
}

// ---------------------------------------------------------------------------
// Ingress claim-queue nodes: grow-under-churn reclamation.
//
// Queue nodes are epoch-retired by the drainer (`detach` walks the
// claimed chain, takes each payload, retires the node), while
// concurrent *peekers* pin the epoch and dereference the current head
// node's stamp (`peek_stamp`) — the exact use-after-free window the
// epoch protocol must close: a node another thread just claimed and
// retired must stay mapped until every pin from before the retire
// drains. Assertions follow this file's conventions: exact counts only
// on our own drop counter, liveness via bounded retries.
// ---------------------------------------------------------------------------

#[test]
fn test_claim_queue_nodes_reclaimed_under_churn() {
    use big_atomics::ingress::ClaimQueue;
    use std::sync::atomic::AtomicU64;

    const PRODUCERS: usize = 3;
    const PEEKERS: usize = 2;
    const PER_PRODUCER: u64 = 3_000;

    let drops = Arc::new(AtomicUsize::new(0));
    let q: Arc<ClaimQueue<Counted>> = Arc::new(ClaimQueue::new(0));
    let live = Arc::new(AtomicU64::new(PRODUCERS as u64));
    let epoch_before = epoch::global_epoch();

    let mut handles = Vec::new();
    for p in 0..PRODUCERS {
        let q = Arc::clone(&q);
        let drops = Arc::clone(&drops);
        let live = Arc::clone(&live);
        handles.push(std::thread::spawn(move || {
            for seq in 0..PER_PRODUCER {
                let item = Counted {
                    drops: Arc::clone(&drops),
                    payload: (p as u64) << 32 | seq,
                };
                if q.try_push(item).is_err() {
                    panic!("unbounded push failed");
                }
            }
            live.fetch_sub(1, Ordering::AcqRel);
        }));
    }
    // Peekers: pin + deref the head node's stamp while drainers retire
    // nodes under them. A stamp can never come from the future.
    let stop_peek = Arc::new(std::sync::atomic::AtomicBool::new(false));
    for _ in 0..PEEKERS {
        let q = Arc::clone(&q);
        let stop = Arc::clone(&stop_peek);
        handles.push(std::thread::spawn(move || {
            while !stop.load(Ordering::Acquire) {
                if let Some(stamp) = q.peek_stamp() {
                    assert!(
                        stamp <= epoch::global_epoch(),
                        "node stamp {stamp} from the future"
                    );
                }
            }
        }));
    }
    // Drainer (this thread): claim runs until the producers are done
    // and the queue is empty; dropping each drained Vec drops the
    // payloads — our exact conservation signal.
    let mut served = 0u64;
    loop {
        match q.try_claim() {
            Some(mut run) => {
                served += run.len() as u64;
                drop(run.drain().collect::<Vec<_>>());
            }
            None => {
                if live.load(Ordering::Acquire) == 0 && q.is_idle() {
                    break;
                }
                std::thread::yield_now();
            }
        }
    }
    stop_peek.store(true, Ordering::Release);
    for h in handles {
        h.join().unwrap();
    }

    let total = PRODUCERS as u64 * PER_PRODUCER;
    assert_eq!(served, total, "lost or duplicated queue items");
    assert_eq!(
        drops.load(Ordering::SeqCst) as u64,
        total,
        "payload drop conservation broke under churn"
    );
    // Liveness: the run-release hook (`Run::drop` →
    // try_advance_and_collect) must have kept the epoch turning under
    // churn — pinned peekers may stall one advance, never all of them.
    // Retry: a parallel test's pin can hold the epoch briefly.
    let mut advanced = false;
    for _ in 0..100_000 {
        epoch::try_advance_and_collect();
        if epoch::global_epoch() > epoch_before {
            advanced = true;
            break;
        }
        std::thread::yield_now();
    }
    assert!(advanced, "global epoch never advanced across the churn");

    // The not-claimed path: items still chained when the queue drops are
    // freed (and their payloads dropped) by ClaimQueue::drop, exactly.
    let tail_drops = Arc::new(AtomicUsize::new(0));
    let q2: ClaimQueue<Counted> = ClaimQueue::new(0);
    for i in 0..50u64 {
        let _ = q2.try_push(Counted {
            drops: Arc::clone(&tail_drops),
            payload: i,
        });
    }
    drop(q2);
    assert_eq!(tail_drops.load(Ordering::SeqCst), 50, "queue drop leaked payloads");
}

// ---------------------------------------------------------------------------
// Guard panic-safety audit: an unwinding operation must release its
// hazard slot / epoch pin through the RAII drops, or the survivor
// threads inherit a process wedged forever (hazard: a leaked
// announcement pins one address and leaks one of the four fixed slots
// per panic until the thread exits; epoch: a leaked pin blocks every
// advance — and therefore every free — process-wide).
// ---------------------------------------------------------------------------

#[test]
fn test_hazard_slot_released_on_unwind() {
    use big_atomics::smr::hazard::{protected_snapshot, HazardPointer, SLOTS_PER_THREAD};
    use std::panic::{catch_unwind, AssertUnwindSafe};

    // Far more panics than fixed slots: any leaked bitmap bit or stale
    // announcement accumulates and the later assertions catch it.
    for round in 0..3 * SLOTS_PER_THREAD {
        let sentinel = 0xBAD_0000 + round;
        let r = catch_unwind(AssertUnwindSafe(|| {
            let h = HazardPointer::new();
            h.announce(sentinel);
            panic!("die while announcing");
        }));
        assert!(r.is_err());
        let mut buf = Vec::new();
        protected_snapshot(&mut buf);
        assert!(
            !buf.contains(&sentinel),
            "announcement {sentinel:#x} survived the guard's unwind"
        );
    }
    // All fixed slots must still be claimable — none leaked to panics.
    // (An overflow lease here would mean a fixed slot's bitmap bit was
    // never returned; overflow guards work, but they are the spill
    // path, not the steady state.)
    let guards: Vec<HazardPointer> = (0..SLOTS_PER_THREAD).map(|_| HazardPointer::new()).collect();
    let mut buf = Vec::new();
    for (i, g) in guards.iter().enumerate() {
        g.announce(0xF00D_0 + i);
    }
    protected_snapshot(&mut buf);
    for i in 0..SLOTS_PER_THREAD {
        assert!(buf.contains(&(0xF00D_0 + i)), "slot {i} lost after panics");
    }
}

#[test]
fn test_epoch_pin_released_on_unwind() {
    use std::panic::{catch_unwind, AssertUnwindSafe};

    // Panic under a pin (nested, to exercise the depth bookkeeping) on
    // a scoped thread, then prove the epoch still advances: a leaked
    // announcement from the dead frame would block it forever.
    std::thread::scope(|s| {
        s.spawn(|| {
            let r = catch_unwind(AssertUnwindSafe(|| {
                let _outer = epoch::pin();
                let _inner = epoch::pin();
                panic!("die while pinned");
            }));
            assert!(r.is_err());
            // Same thread, post-unwind: a fresh pin/unpin must behave
            // (depth back to zero, slot quiescent afterwards).
            drop(epoch::pin());
        })
        .join()
        .unwrap();
    });

    let drops = Arc::new(AtomicUsize::new(0));
    unsafe { Epoch::<DefaultPolicy>::retire_box(counted(&drops, 1)) };
    // Eventually freed ⇒ the epoch advanced FREE_DISTANCE times past
    // the stamp ⇒ no announcement from the panicked frames remains.
    collect_until::<Epoch<DefaultPolicy>>(&drops, 1, "post-panic epoch advance");
}

// ---------------------------------------------------------------------------
// smr::pool — the page-pool node allocator + batched retirement.
//
// Determinism notes: a thread's free list is TLS and LIFO, so
// single-threaded slot-reuse assertions are exact *between* collects;
// during a collect, orphan drains may recycle other tests' nodes onto
// this thread's list, so reuse scans are bounded searches rather than
// head-equality. `pool::stats()` counters are global and monotonic —
// only lower-bound deltas are asserted.
// ---------------------------------------------------------------------------

/// Alloc→retire churn through the pool, several pages deep, generic
/// over the scheme: every node's payload must drop exactly once.
fn pool_alloc_retire_churn<S: Smr>() {
    let drops = Arc::new(AtomicUsize::new(0));
    let s0 = pool::stats();
    let rounds = 4 * pool::PAGE_SLOTS;
    for i in 0..rounds {
        let p = pool::alloc_node(Counted {
            drops: Arc::clone(&drops),
            payload: i as u64,
        });
        assert_eq!(unsafe { (*p).payload }, i as u64);
        unsafe { pool::retire_node::<S, Counted>(p) };
    }
    collect_until::<S>(&drops, rounds, "pool alloc/retire churn");
    let s1 = pool::stats();
    assert!(s1.pages >= s0.pages, "page counter went backwards");
}

#[test]
fn test_pool_alloc_retire_churn_hazard() {
    pool_alloc_retire_churn::<Hazard>();
}

#[test]
fn test_pool_alloc_retire_churn_epoch() {
    pool_alloc_retire_churn::<Epoch>();
}

/// While a hazard pointer protects a pooled node, the node is never
/// freed and its slot is never handed back out; after release it is
/// freed and (LIFO list) eventually re-issued.
#[test]
fn test_pool_protected_slot_not_reused_hazard() {
    let drops = Arc::new(AtomicUsize::new(0));
    let scratch = Arc::new(AtomicUsize::new(0));
    let node = pool::alloc_node(Counted {
        drops: Arc::clone(&drops),
        payload: 11,
    });
    let addr = node as usize;
    let src = AtomicPtr::new(node);
    let g = Hazard::pin();
    let p = g.protect_ptr(&src);
    assert_eq!(p, node);
    unsafe { pool::retire_node::<Hazard, Counted>(p) };
    for _ in 0..64 {
        Hazard::collect();
    }
    assert_eq!(drops.load(Ordering::SeqCst), 0, "freed while protected");
    // The retired-but-protected slot is in neither the free list nor
    // any claimable page: no same-class allocation may return it.
    let mut held = Vec::new();
    for i in 0..2 * pool::PAGE_SLOTS {
        let q = pool::alloc_node(Counted {
            drops: Arc::clone(&scratch),
            payload: 1_000 + i as u64,
        });
        assert_ne!(q as usize, addr, "protected slot handed out");
        held.push(q);
    }
    for q in held {
        unsafe { pool::free_node_now(q) };
    }
    drop(g);
    collect_until::<Hazard>(&drops, 1, "post-release pool free");
    // The slot is back on this thread's LIFO list now (possibly below
    // nodes recycled from orphan drains during the collects): a bounded
    // scan of fresh allocations must re-issue the exact address.
    let mut seen = Vec::new();
    let mut reissued = false;
    for i in 0..100_000 {
        let q = pool::alloc_node(Counted {
            drops: Arc::clone(&scratch),
            payload: 2_000 + i as u64,
        });
        let hit = q as usize == addr;
        seen.push(q);
        if hit {
            reissued = true;
            break;
        }
    }
    for q in seen {
        unsafe { pool::free_node_now(q) };
    }
    assert!(reissued, "released slot never recycled");
}

/// Epoch flavor: this thread's own pin stalls the epoch, so a node
/// retired under it can never be freed or re-issued until the unpin.
#[test]
fn test_pool_protected_slot_not_reused_epoch() {
    let drops = Arc::new(AtomicUsize::new(0));
    let scratch = Arc::new(AtomicUsize::new(0));
    let g = epoch::pin();
    let node = pool::alloc_node(Counted {
        drops: Arc::clone(&drops),
        payload: 13,
    });
    let addr = node as usize;
    unsafe { pool::retire_node::<Epoch, Counted>(node) };
    for _ in 0..64 {
        Epoch::collect();
    }
    assert_eq!(drops.load(Ordering::SeqCst), 0, "freed under a live pin");
    let mut held = Vec::new();
    for i in 0..2 * pool::PAGE_SLOTS {
        let q = pool::alloc_node(Counted {
            drops: Arc::clone(&scratch),
            payload: 3_000 + i as u64,
        });
        assert_ne!(q as usize, addr, "pinned-retired slot handed out");
        held.push(q);
    }
    for q in held {
        unsafe { pool::free_node_now(q) };
    }
    drop(g);
    collect_until::<Epoch>(&drops, 1, "post-unpin pool free");
}

/// A `retire_page` batch is one unit under the hazard scan: one
/// protected interior slot keeps EVERY slot of the batch live (the
/// page-granularity `probe_batch`), and the release frees them all.
#[test]
fn test_retire_page_whole_batch_live_while_one_slot_protected() {
    const N: usize = 8;
    let drops = Arc::new(AtomicUsize::new(0));
    let ptrs: Vec<*mut Counted> = (0..N)
        .map(|i| {
            pool::alloc_node(Counted {
                drops: Arc::clone(&drops),
                payload: i as u64,
            })
        })
        .collect();
    // Protect one interior node, then retire the whole page batch.
    let src = AtomicPtr::new(ptrs[N / 2]);
    let g = Hazard::pin();
    let p = g.protect_ptr(&src);
    assert_eq!(p, ptrs[N / 2]);
    let mut batch = PageBatch::with_capacity(N);
    for q in &ptrs {
        unsafe { batch.push(*q) };
    }
    assert_eq!(batch.len(), N);
    let s0 = pool::stats();
    unsafe { Hazard::retire_page(batch) };
    let s1 = pool::stats();
    assert!(s1.batches > s0.batches, "batch not counted");
    assert!(
        s1.batch_slots - s0.batch_slots >= N as u64,
        "batch slots not counted"
    );
    for _ in 0..64 {
        Hazard::collect();
    }
    assert_eq!(
        drops.load(Ordering::SeqCst),
        0,
        "batch slots freed while one was protected"
    );
    assert_eq!(unsafe { (*p).payload }, (N / 2) as u64);
    drop(g);
    collect_until::<Hazard>(&drops, N, "post-release batch free");
}

/// Epoch flavor: the batch carries one stamp (§3.2-style), so this
/// thread's pin blocks the whole batch; the unpin releases all of it.
#[test]
fn test_retire_page_batch_blocked_by_pin_epoch() {
    const N: usize = 8;
    let drops = Arc::new(AtomicUsize::new(0));
    let g = epoch::pin();
    let mut batch = PageBatch::with_capacity(N);
    for i in 0..N {
        let p = pool::alloc_node(Counted {
            drops: Arc::clone(&drops),
            payload: i as u64,
        });
        unsafe { batch.push(p) };
    }
    unsafe { Epoch::retire_page(batch) };
    for _ in 0..64 {
        Epoch::collect();
    }
    assert_eq!(drops.load(Ordering::SeqCst), 0, "batch freed under a pin");
    drop(g);
    collect_until::<Epoch>(&drops, N, "post-unpin batch free");
}

/// Census: a page batch is ONE retired entry, not slot-count entries —
/// the whole point of the batching (`pending_reclaims` counts entries
/// in the thread bag). Orphan traffic from parallel tests can inflate a
/// single measurement, so retry until a quiet window.
#[test]
fn test_retire_page_is_one_census_entry() {
    const N: usize = 8;
    let drops = Arc::new(AtomicUsize::new(0));
    let mut queued = 0usize;
    let mut quiet = false;
    for _ in 0..100 {
        let before = Hazard::pending_reclaims();
        let mut batch = PageBatch::with_capacity(N);
        for i in 0..N {
            let p = pool::alloc_node(Counted {
                drops: Arc::clone(&drops),
                payload: i as u64,
            });
            unsafe { batch.push(p) };
        }
        unsafe { Hazard::retire_page(batch) };
        queued += N;
        let delta = Hazard::pending_reclaims().saturating_sub(before);
        if delta < N {
            quiet = true;
            break;
        }
    }
    assert!(quiet, "retire_page showed up as >= slot-count census entries");
    collect_until::<Hazard>(&drops, queued, "census batch drain");
}

/// Empty chains must not inflate the batch census: retiring an empty
/// batch is a no-op on every counter.
#[test]
fn test_retire_page_empty_batch_is_noop() {
    let s0 = pool::stats();
    unsafe { Hazard::retire_page(PageBatch::new()) };
    unsafe { Epoch::retire_page(PageBatch::new()) };
    let s1 = pool::stats();
    // Monotonic global counters: other tests may add batches in
    // parallel, but OUR empty batches added zero slots — the strongest
    // race-free claim is that slots grew only if batches did.
    assert!(s1.batch_slots >= s0.batch_slots);
    if s1.batches == s0.batches {
        assert_eq!(s1.batch_slots, s0.batch_slots, "slots counted without a batch");
    }
}

/// The no-inline chaining table pushed through growth by concurrent
/// churn: every migrated chain rides the pool and every drained chain
/// rides `retire_page`, while readers validate key-derived values. A
/// premature page recycle shows up as a corrupt read or a crash.
#[test]
fn test_chaining_pool_growth_under_churn() {
    let t: Arc<Chaining> = Arc::new(Chaining::new(64));
    let threads = 3u64;
    let per = 8_000u64;
    let stop = Arc::new(std::sync::atomic::AtomicBool::new(false));
    let s0 = pool::stats();
    let mut handles = Vec::new();
    for tix in 0..threads {
        let t = Arc::clone(&t);
        handles.push(std::thread::spawn(move || {
            let base = tix * 1_000_000;
            for i in 0..per {
                let k = base + i;
                assert!(t.insert(k, big_atomics::util::rng::mix64(k)));
                if i % 2 == 1 {
                    assert!(t.remove(base + i - 1), "churned key lost");
                }
            }
        }));
    }
    {
        let t = Arc::clone(&t);
        let stop = Arc::clone(&stop);
        handles.push(std::thread::spawn(move || {
            let mut i = 0u64;
            while !stop.load(Ordering::Relaxed) {
                let k = (i % threads) * 1_000_000 + (i / threads) % per;
                if let Some(v) = t.find(k) {
                    assert_eq!(v, big_atomics::util::rng::mix64(k), "corrupt value for {k}");
                }
                i += 1;
            }
        }));
    }
    for h in handles.drain(..threads as usize) {
        h.join().unwrap();
    }
    stop.store(true, Ordering::Release);
    for h in handles {
        h.join().unwrap();
    }
    t.finish_resizes();
    assert!(!t.resize_in_flight());
    assert!(t.capacity() > 64, "no growth under churn");
    // Half the keys survive with exact values.
    for tix in 0..threads {
        let base = tix * 1_000_000;
        for i in (1..per).step_by(2) {
            let k = base + i;
            assert_eq!(t.find(k), Some(big_atomics::util::rng::mix64(k)), "key {k}");
        }
    }
    // The churn had to claim pages, and the growth had to retire at
    // least one drained chain as a batch.
    let s1 = pool::stats();
    assert!(s1.pages > s0.pages, "churn never claimed a pool page");
    assert!(s1.batches > s0.batches, "growth never batch-retired a chain");
}

// ---------------------------------------------------------------------------
// Retire-bag regression tests: the three drop-path bugs this suite pins.
// ---------------------------------------------------------------------------

/// Regression (re-entrant retire): a retired value whose own Drop
/// retires MORE garbage. Pre-fix, `RetireBag::with_items` freed while
/// the RefCell borrow was held, so the nested `retire` re-borrowed the
/// same bag and panicked (`BorrowMutError`) in the middle of a free.
#[test]
fn test_reentrant_retire_from_drop() {
    struct Cascade<S: Smr + 'static> {
        drops: Arc<AtomicUsize>,
        depth: u32,
        _scheme: std::marker::PhantomData<S>,
    }
    impl<S: Smr + 'static> Drop for Cascade<S> {
        fn drop(&mut self) {
            self.drops.fetch_add(1, Ordering::SeqCst);
            if self.depth > 0 {
                // The re-entrant call: this runs INSIDE a collect's free
                // loop, on the same thread, against the same bag.
                unsafe {
                    S::retire_box(Box::into_raw(Box::new(Cascade::<S> {
                        drops: Arc::clone(&self.drops),
                        depth: self.depth - 1,
                        _scheme: std::marker::PhantomData,
                    })))
                };
            }
        }
    }
    fn run<S: Smr + 'static>() {
        let drops = Arc::new(AtomicUsize::new(0));
        unsafe {
            S::retire_box(Box::into_raw(Box::new(Cascade::<S> {
                drops: Arc::clone(&drops),
                depth: 3,
                _scheme: std::marker::PhantomData,
            })))
        };
        // Depth 3 cascade = 4 drops total, each freed by a later collect.
        collect_until::<S>(&drops, 4, "re-entrant retire cascade");
    }
    run::<Hazard>();
    run::<Epoch>();
}

/// Regression (§5.5 census undercount): `pending_reclaims` used
/// `try_lock().unwrap_or(0)` for the orphan column and silently
/// reported zero whenever a concurrent collector held the lock. Park a
/// protected node on the orphan list (scans keep protected survivors in
/// place), hammer the lock with collectors, and require the census to
/// NEVER lose it — post-fix the census takes the lock; pre-fix this
/// flaked to an undercount exactly under contention.
#[test]
fn test_census_counts_orphans_under_lock_contention() {
    let drops = Arc::new(AtomicUsize::new(0));
    let node = counted(&drops, 9);
    let src = AtomicPtr::new(node);
    let g = Hazard::pin();
    let p = g.protect_ptr(&src);
    unsafe { Hazard::retire_box(p) };
    Hazard::flush_thread_bag(); // park it on the shared orphan list
    let stop = Arc::new(std::sync::atomic::AtomicBool::new(false));
    let hammer: Vec<_> = (0..2)
        .map(|_| {
            let stop = Arc::clone(&stop);
            std::thread::spawn(move || {
                while !stop.load(Ordering::Relaxed) {
                    Hazard::collect();
                }
            })
        })
        .collect();
    for _ in 0..5_000 {
        assert!(
            Hazard::pending_reclaims() >= 1,
            "census lost a live orphan under lock contention"
        );
    }
    stop.store(true, Ordering::Release);
    for h in hammer {
        h.join().unwrap();
    }
    drop(g);
    collect_until::<Hazard>(&drops, 1, "census-contention cleanup");
}

/// Regression (poisoned drop paths): a panic unwinding out of a node's
/// Drop mid-collect may poison the bag/orphan mutexes on this thread.
/// Pre-fix, the next `flush`/`Drop`/census hit `unwrap()` on the
/// poisoned lock and aborted the process; now every orphan-lock site
/// recovers via `PoisonError::into_inner`. The bomb never leaves its
/// own unflushed thread bag, so no other test can trip it.
#[test]
fn test_unwind_in_drop_does_not_wedge_reclamation() {
    use std::panic::{catch_unwind, AssertUnwindSafe};
    use std::sync::atomic::AtomicBool;
    static ARMED: AtomicBool = AtomicBool::new(false);
    struct Bomb {
        drops: Arc<AtomicUsize>,
    }
    impl Drop for Bomb {
        fn drop(&mut self) {
            self.drops.fetch_add(1, Ordering::SeqCst);
            if ARMED.swap(false, Ordering::SeqCst) {
                panic!("armed drop: unwind through the collect path");
            }
        }
    }
    fn run<S: Smr + 'static>() {
        let drops = Arc::new(AtomicUsize::new(0));
        {
            let drops = Arc::clone(&drops);
            std::thread::spawn(move || {
                ARMED.store(true, Ordering::SeqCst);
                unsafe {
                    S::retire_box(Box::into_raw(Box::new(Bomb {
                        drops: Arc::clone(&drops),
                    })))
                };
                // NO flush: the bomb stays in this thread's local bag,
                // so only these collects can fire it.
                let _ = catch_unwind(AssertUnwindSafe(|| {
                    for _ in 0..100_000 {
                        S::collect();
                        if drops.load(Ordering::SeqCst) >= 1 {
                            break;
                        }
                        std::thread::yield_now();
                    }
                }));
                // Disarm before the exit hook can hand any survivor to
                // the orphan list where another test would free it.
                ARMED.store(false, Ordering::SeqCst);
            })
            .join()
            .unwrap();
        }
        // Whatever the unwind poisoned, the scheme must keep working:
        // retire + flush + free from fresh thread state must succeed.
        let after = Arc::new(AtomicUsize::new(0));
        unsafe { S::retire_box(counted(&after, 6)) };
        S::flush_thread_bag();
        collect_until::<S>(&after, 1, "post-unwind reclamation");
    }
    run::<Hazard>();
    run::<Epoch>();
}
