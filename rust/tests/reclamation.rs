//! Reclamation stress suite for the unified `Smr` layer.
//!
//! Proves the contracts both schemes promise, with drop-counter types:
//!
//! * **protection** — nothing is freed while a guard protects it, and it
//!   is freed (eventually) after the guard drops, under *both* `Smr`
//!   impls (the hazard/epoch cross-check: one generic scenario);
//! * **the epoch distance rule** — a node retired with stamp `e` is
//!   never freed before the global epoch advances two (in fact three —
//!   two reader epochs plus the stamp-slack epoch) past `e`, and a
//!   pinned reader stalls the epoch (hence all frees) at most one
//!   advance away;
//! * **orphan-bag handoff** — garbage retired by a thread that exits
//!   without collecting is absorbed by the registry exit hook and freed
//!   by a later collect on another thread, under both schemes;
//! * **scheme-generic backends** — `CachedMemEff` over the epoch scheme
//!   (the stamp-based recycler) stays exact under concurrency.
//!
//! Tests in this binary run in parallel and share the process-wide epoch
//! and hazard domains, so every "eventually freed" assertion retries
//! (another test's short-lived pin may block one advance) and every
//! "not freed" assertion only inspects this test's own drop counter.

use std::sync::atomic::{AtomicPtr, AtomicUsize, Ordering};
use std::sync::Arc;

use big_atomics::atomics::{BigAtomic, CachedMemEff, Words};
use big_atomics::hash::{CacheHash, ConcurrentMap, LinkVal};
use big_atomics::smr::{epoch, Epoch, Hazard, Smr};
use big_atomics::util::ordering::{DefaultPolicy, Fenced, SeqCstEverywhere};

/// A heap value whose drop increments a test-owned counter.
struct Counted {
    drops: Arc<AtomicUsize>,
    payload: u64,
}

impl Drop for Counted {
    fn drop(&mut self) {
        self.drops.fetch_add(1, Ordering::SeqCst);
    }
}

fn counted(drops: &Arc<AtomicUsize>, payload: u64) -> *mut Counted {
    Box::into_raw(Box::new(Counted {
        drops: Arc::clone(drops),
        payload,
    }))
}

/// Retry a collect-then-check loop until `drops` reaches `want` (bounded
/// by a generous iteration count so a wedged scheme still fails loudly).
fn collect_until<S: Smr>(drops: &Arc<AtomicUsize>, want: usize, what: &str) {
    for _ in 0..100_000 {
        S::collect();
        if drops.load(Ordering::SeqCst) >= want {
            return;
        }
        std::thread::yield_now();
    }
    panic!(
        "{what} ({}): only {}/{want} freed after bounded collects",
        S::NAME,
        drops.load(Ordering::SeqCst)
    );
}

/// The cross-check scenario, identical under both schemes: protect a
/// pointer, retire it, prove it survives collects; release, prove it is
/// freed.
fn protected_then_released<S: Smr>() {
    let drops = Arc::new(AtomicUsize::new(0));
    let node = counted(&drops, 7);
    let src = AtomicPtr::new(node);
    let g = S::pin();
    let p = g.protect_ptr(&src);
    assert_eq!(unsafe { (*p).payload }, 7);
    // Unlink + retire while protected: collects must not free it.
    src.store(std::ptr::null_mut(), Ordering::SeqCst);
    unsafe { S::retire_box(p) };
    for _ in 0..64 {
        S::collect();
    }
    assert_eq!(
        drops.load(Ordering::SeqCst),
        0,
        "{}: freed while protected",
        S::NAME
    );
    // Protected reads stay valid right up to the release.
    assert_eq!(unsafe { (*p).payload }, 7);
    drop(g);
    collect_until::<S>(&drops, 1, "release-then-free");
}

#[test]
fn test_protected_then_released_hazard() {
    protected_then_released::<Hazard>();
}

#[test]
fn test_protected_then_released_epoch() {
    protected_then_released::<Epoch>();
}

#[test]
fn test_protected_then_released_epoch_seqcst_policy() {
    // The audit-policy epoch instantiation shares the same protocol
    // state and must satisfy the same contract.
    protected_then_released::<Epoch<SeqCstEverywhere>>();
}

#[test]
fn test_epoch_advance_distance_rule() {
    // Nothing retired with stamp s may be freed before the global epoch
    // passes s by the scheme's free distance (two reader epochs + one
    // stamp-slack epoch = 3) — observed from the outside: the retire
    // stamp is >= the epoch we read just before retiring (coherence),
    // the item sits in *our* unflushed thread bag so only our own
    // collects can free it, and the iteration that observes the drop
    // reads the global epoch after the freeing collect.
    let drops = Arc::new(AtomicUsize::new(0));
    let retired_at = epoch::global_epoch();
    unsafe { Epoch::<Fenced>::retire_box(counted(&drops, 1)) };
    for _ in 0..1_000_000 {
        let now = epoch::global_epoch();
        let freed = drops.load(Ordering::SeqCst);
        if freed > 0 {
            assert!(
                now >= retired_at + 2,
                "freed at epoch {now}, retired at >= {retired_at}: distance rule broken"
            );
            return;
        }
        Epoch::<Fenced>::try_advance_and_collect();
        std::thread::yield_now();
    }
    panic!("retired node never freed (epoch wedged?)");
}

#[test]
fn test_epoch_pinned_reader_blocks_frees() {
    // While a reader is pinned, garbage retired after its pin is never
    // freed (the epoch stalls one advance away at most).
    let drops = Arc::new(AtomicUsize::new(0));
    let (pinned_tx, pinned_rx) = std::sync::mpsc::channel::<()>();
    let (done_tx, done_rx) = std::sync::mpsc::channel::<()>();
    let reader = std::thread::spawn(move || {
        let _g = Epoch::<Fenced>::pin();
        pinned_tx.send(()).unwrap();
        done_rx.recv().unwrap();
    });
    pinned_rx.recv().unwrap();
    // Retire *after* the reader is pinned: its epoch stamp is at least
    // pin_epoch, so the free needs the full distance past the pin —
    // blocked while the pin lives.
    unsafe { Epoch::<Fenced>::retire_box(counted(&drops, 2)) };
    for _ in 0..256 {
        Epoch::<Fenced>::try_advance_and_collect();
    }
    assert_eq!(
        drops.load(Ordering::SeqCst),
        0,
        "garbage freed under a live pin"
    );
    done_tx.send(()).unwrap();
    reader.join().unwrap();
    collect_until::<Epoch>(&drops, 1, "post-unpin free");
}

/// Orphan handoff: a thread retires garbage and exits without flushing
/// or collecting; the registry exit hook must park it on the orphan
/// list, and a collect from the main thread must free it.
fn orphan_handoff_on_thread_exit<S: Smr>() {
    let drops = Arc::new(AtomicUsize::new(0));
    let n = 32;
    {
        let drops = Arc::clone(&drops);
        std::thread::spawn(move || {
            for i in 0..n {
                unsafe { S::retire_box(counted(&drops, i as u64)) };
            }
            // No flush, no collect: exit does the handoff.
        })
        .join()
        .unwrap();
    }
    collect_until::<S>(&drops, n, "orphan handoff");
}

#[test]
fn test_orphan_handoff_hazard() {
    orphan_handoff_on_thread_exit::<Hazard>();
}

#[test]
fn test_orphan_handoff_epoch() {
    orphan_handoff_on_thread_exit::<Epoch>();
}

#[test]
fn test_flush_thread_bag_then_collect_elsewhere() {
    // Explicit flush (the table-drop path): garbage retired here is
    // freeable by a collect after the flush, without a thread exit.
    fn run<S: Smr>() {
        let drops = Arc::new(AtomicUsize::new(0));
        for i in 0..8 {
            unsafe { S::retire_box(counted(&drops, i as u64)) };
        }
        S::flush_thread_bag();
        collect_until::<S>(&drops, 8, "flushed-bag collect");
    }
    run::<Hazard>();
    run::<Epoch>();
}

#[test]
fn test_pending_reclaims_visible() {
    // Retired-but-unfreed garbage shows up in the census for both
    // schemes (exact counts are racy across parallel tests; >= 1 while
    // we hold protection is robust for our own node).
    fn run<S: Smr>() {
        let drops = Arc::new(AtomicUsize::new(0));
        let node = counted(&drops, 3);
        let src = AtomicPtr::new(node);
        let g = S::pin();
        let p = g.protect_ptr(&src);
        unsafe { S::retire_box(p) };
        assert!(S::pending_reclaims() >= 1, "{}", S::NAME);
        drop(g);
        collect_until::<S>(&drops, 1, "pending census");
    }
    run::<Hazard>();
    run::<Epoch>();
}

#[test]
fn test_concurrent_protect_no_use_after_free_both_schemes() {
    // The classic UAF storm, generic over the scheme: one writer swaps
    // and retires; readers protect and validate payloads. A reclamation
    // bug shows up as a corrupt payload (or a crash under ASan/Miri).
    fn run<S: Smr>() {
        let drops = Arc::new(AtomicUsize::new(0));
        let src = Arc::new(AtomicPtr::new(counted(&drops, 1)));
        let stop = Arc::new(std::sync::atomic::AtomicBool::new(false));
        let mut handles = Vec::new();
        for _ in 0..3 {
            let src = Arc::clone(&src);
            let stop = Arc::clone(&stop);
            handles.push(std::thread::spawn(move || {
                while !stop.load(Ordering::Relaxed) {
                    let g = S::pin();
                    let p = g.protect_ptr(&src);
                    let v = unsafe { (*p).payload };
                    assert!(v >= 1 && v < 1 << 40, "corrupt read {v:#x}");
                }
                S::flush_thread_bag();
            }));
        }
        for gen in 2..3_000u64 {
            let new = counted(&drops, gen);
            let old = src.swap(new, Ordering::SeqCst);
            unsafe { S::retire_box(old) };
        }
        stop.store(true, Ordering::SeqCst);
        for h in handles {
            h.join().unwrap();
        }
        let last = src.load(Ordering::SeqCst);
        unsafe { S::retire_box(last) };
        S::flush_thread_bag();
    }
    run::<Hazard>();
    run::<Epoch>();
}

#[test]
fn test_table_growth_reclaims_through_epoch_under_churn() {
    // Grow-under-churn: a capacity-64 table is pushed through repeated
    // doublings by concurrent insert/remove churn while readers validate
    // key-derived values the whole time. Every drained table and every
    // migrated chain travels through `Epoch` — a premature free shows up
    // as a corrupt read (values are derivable from keys) or a crash
    // under ASan/Miri; a wedged epoch shows up as the liveness probe at
    // the end never freeing.
    let t: Arc<CacheHash<CachedMemEff<LinkVal>>> = Arc::new(CacheHash::new(64));
    let threads = 3u64;
    let per = 20_000u64;
    let stop = Arc::new(std::sync::atomic::AtomicBool::new(false));
    let mut handles = Vec::new();
    for tix in 0..threads {
        let t = Arc::clone(&t);
        handles.push(std::thread::spawn(move || {
            let base = tix * 1_000_000;
            for i in 0..per {
                let k = base + i;
                assert!(t.insert(k, big_atomics::util::rng::mix64(k)));
                if i % 2 == 1 {
                    assert!(t.remove(base + i - 1), "churned key lost");
                }
            }
        }));
    }
    {
        // Reader racing migration and reclamation: any value it sees
        // must be exactly the key-derived one.
        let t = Arc::clone(&t);
        let stop = Arc::clone(&stop);
        handles.push(std::thread::spawn(move || {
            let mut i = 0u64;
            while !stop.load(Ordering::Relaxed) {
                let k = (i % threads) * 1_000_000 + (i / threads) % per;
                if let Some(v) = t.find(k) {
                    assert_eq!(v, big_atomics::util::rng::mix64(k), "corrupt value for {k}");
                }
                i += 1;
            }
        }));
    }
    for h in handles.drain(..threads as usize) {
        h.join().unwrap();
    }
    stop.store(true, Ordering::Release);
    for h in handles {
        h.join().unwrap();
    }
    t.finish_resizes();
    assert!(!t.resize_in_flight());
    assert!(t.capacity() > 64, "no growth under churn");
    assert!(
        t.generation() >= 1,
        "no drained table was retired through Epoch"
    );
    // Half the keys survive the churn with exact values.
    for tix in 0..threads {
        let base = tix * 1_000_000;
        for i in (1..per).step_by(2) {
            let k = base + i;
            assert_eq!(t.find(k), Some(big_atomics::util::rng::mix64(k)), "key {k}");
        }
    }
    // Liveness probe: the epoch scheme must still advance and free after
    // the growth retired tables/chains (a stuck announcement or lost
    // descriptor would wedge it).
    let drops = Arc::new(AtomicUsize::new(0));
    unsafe { Epoch::<Fenced>::retire_box(counted(&drops, 42)) };
    collect_until::<Epoch>(&drops, 1, "post-growth epoch liveness");
}

#[test]
fn test_memeff_epoch_recycler_exact_under_concurrency() {
    // Algorithm 2 over the epoch scheme: the stamp-based recycler must
    // preserve CAS exactness exactly like the hazard announcement scan.
    let a: Arc<CachedMemEff<Words<4>, DefaultPolicy, Epoch>> =
        Arc::new(CachedMemEff::new(Words([0; 4])));
    let threads = 4;
    let rounds = 1_500u64;
    let wins = Arc::new(std::sync::atomic::AtomicU64::new(0));
    let handles: Vec<_> = (0..threads)
        .map(|t| {
            let a = Arc::clone(&a);
            let wins = Arc::clone(&wins);
            std::thread::spawn(move || {
                for r in 0..rounds {
                    let cur = a.load();
                    let next = Words([cur.0[0] + 1, r + 1, t as u64, cur.0[3] ^ r]);
                    if a.compare_exchange(cur, next).is_ok() {
                        wins.fetch_add(1, Ordering::Relaxed);
                    }
                }
            })
        })
        .collect();
    for h in handles {
        h.join().unwrap();
    }
    assert_eq!(a.load().0[0], wins.load(Ordering::SeqCst));
}

// ---------------------------------------------------------------------------
// Ingress claim-queue nodes: grow-under-churn reclamation.
//
// Queue nodes are epoch-retired by the drainer (`detach` walks the
// claimed chain, takes each payload, retires the node), while
// concurrent *peekers* pin the epoch and dereference the current head
// node's stamp (`peek_stamp`) — the exact use-after-free window the
// epoch protocol must close: a node another thread just claimed and
// retired must stay mapped until every pin from before the retire
// drains. Assertions follow this file's conventions: exact counts only
// on our own drop counter, liveness via bounded retries.
// ---------------------------------------------------------------------------

#[test]
fn test_claim_queue_nodes_reclaimed_under_churn() {
    use big_atomics::ingress::ClaimQueue;
    use std::sync::atomic::AtomicU64;

    const PRODUCERS: usize = 3;
    const PEEKERS: usize = 2;
    const PER_PRODUCER: u64 = 3_000;

    let drops = Arc::new(AtomicUsize::new(0));
    let q: Arc<ClaimQueue<Counted>> = Arc::new(ClaimQueue::new(0));
    let live = Arc::new(AtomicU64::new(PRODUCERS as u64));
    let epoch_before = epoch::global_epoch();

    let mut handles = Vec::new();
    for p in 0..PRODUCERS {
        let q = Arc::clone(&q);
        let drops = Arc::clone(&drops);
        let live = Arc::clone(&live);
        handles.push(std::thread::spawn(move || {
            for seq in 0..PER_PRODUCER {
                let item = Counted {
                    drops: Arc::clone(&drops),
                    payload: (p as u64) << 32 | seq,
                };
                if q.try_push(item).is_err() {
                    panic!("unbounded push failed");
                }
            }
            live.fetch_sub(1, Ordering::AcqRel);
        }));
    }
    // Peekers: pin + deref the head node's stamp while drainers retire
    // nodes under them. A stamp can never come from the future.
    let stop_peek = Arc::new(std::sync::atomic::AtomicBool::new(false));
    for _ in 0..PEEKERS {
        let q = Arc::clone(&q);
        let stop = Arc::clone(&stop_peek);
        handles.push(std::thread::spawn(move || {
            while !stop.load(Ordering::Acquire) {
                if let Some(stamp) = q.peek_stamp() {
                    assert!(
                        stamp <= epoch::global_epoch(),
                        "node stamp {stamp} from the future"
                    );
                }
            }
        }));
    }
    // Drainer (this thread): claim runs until the producers are done
    // and the queue is empty; dropping each drained Vec drops the
    // payloads — our exact conservation signal.
    let mut served = 0u64;
    loop {
        match q.try_claim() {
            Some(mut run) => {
                served += run.len() as u64;
                drop(run.drain().collect::<Vec<_>>());
            }
            None => {
                if live.load(Ordering::Acquire) == 0 && q.is_idle() {
                    break;
                }
                std::thread::yield_now();
            }
        }
    }
    stop_peek.store(true, Ordering::Release);
    for h in handles {
        h.join().unwrap();
    }

    let total = PRODUCERS as u64 * PER_PRODUCER;
    assert_eq!(served, total, "lost or duplicated queue items");
    assert_eq!(
        drops.load(Ordering::SeqCst) as u64,
        total,
        "payload drop conservation broke under churn"
    );
    // Liveness: the run-release hook (`Run::drop` →
    // try_advance_and_collect) must have kept the epoch turning under
    // churn — pinned peekers may stall one advance, never all of them.
    // Retry: a parallel test's pin can hold the epoch briefly.
    let mut advanced = false;
    for _ in 0..100_000 {
        epoch::try_advance_and_collect();
        if epoch::global_epoch() > epoch_before {
            advanced = true;
            break;
        }
        std::thread::yield_now();
    }
    assert!(advanced, "global epoch never advanced across the churn");

    // The not-claimed path: items still chained when the queue drops are
    // freed (and their payloads dropped) by ClaimQueue::drop, exactly.
    let tail_drops = Arc::new(AtomicUsize::new(0));
    let q2: ClaimQueue<Counted> = ClaimQueue::new(0);
    for i in 0..50u64 {
        let _ = q2.try_push(Counted {
            drops: Arc::clone(&tail_drops),
            payload: i,
        });
    }
    drop(q2);
    assert_eq!(tail_drops.load(Ordering::SeqCst), 50, "queue drop leaked payloads");
}

// ---------------------------------------------------------------------------
// Guard panic-safety audit: an unwinding operation must release its
// hazard slot / epoch pin through the RAII drops, or the survivor
// threads inherit a process wedged forever (hazard: a leaked
// announcement pins one address and leaks one of the four fixed slots
// per panic until the thread exits; epoch: a leaked pin blocks every
// advance — and therefore every free — process-wide).
// ---------------------------------------------------------------------------

#[test]
fn test_hazard_slot_released_on_unwind() {
    use big_atomics::smr::hazard::{protected_snapshot, HazardPointer, SLOTS_PER_THREAD};
    use std::panic::{catch_unwind, AssertUnwindSafe};

    // Far more panics than fixed slots: any leaked bitmap bit or stale
    // announcement accumulates and the later assertions catch it.
    for round in 0..3 * SLOTS_PER_THREAD {
        let sentinel = 0xBAD_0000 + round;
        let r = catch_unwind(AssertUnwindSafe(|| {
            let h = HazardPointer::new();
            h.announce(sentinel);
            panic!("die while announcing");
        }));
        assert!(r.is_err());
        let mut buf = Vec::new();
        protected_snapshot(&mut buf);
        assert!(
            !buf.contains(&sentinel),
            "announcement {sentinel:#x} survived the guard's unwind"
        );
    }
    // All fixed slots must still be claimable — none leaked to panics.
    // (An overflow lease here would mean a fixed slot's bitmap bit was
    // never returned; overflow guards work, but they are the spill
    // path, not the steady state.)
    let guards: Vec<HazardPointer> = (0..SLOTS_PER_THREAD).map(|_| HazardPointer::new()).collect();
    let mut buf = Vec::new();
    for (i, g) in guards.iter().enumerate() {
        g.announce(0xF00D_0 + i);
    }
    protected_snapshot(&mut buf);
    for i in 0..SLOTS_PER_THREAD {
        assert!(buf.contains(&(0xF00D_0 + i)), "slot {i} lost after panics");
    }
}

#[test]
fn test_epoch_pin_released_on_unwind() {
    use std::panic::{catch_unwind, AssertUnwindSafe};

    // Panic under a pin (nested, to exercise the depth bookkeeping) on
    // a scoped thread, then prove the epoch still advances: a leaked
    // announcement from the dead frame would block it forever.
    std::thread::scope(|s| {
        s.spawn(|| {
            let r = catch_unwind(AssertUnwindSafe(|| {
                let _outer = epoch::pin();
                let _inner = epoch::pin();
                panic!("die while pinned");
            }));
            assert!(r.is_err());
            // Same thread, post-unwind: a fresh pin/unpin must behave
            // (depth back to zero, slot quiescent afterwards).
            drop(epoch::pin());
        })
        .join()
        .unwrap();
    });

    let drops = Arc::new(AtomicUsize::new(0));
    unsafe { Epoch::<DefaultPolicy>::retire_box(counted(&drops, 1)) };
    // Eventually freed ⇒ the epoch advanced FREE_DISTANCE times past
    // the stamp ⇒ no announcement from the panicked frames remains.
    collect_until::<Epoch<DefaultPolicy>>(&drops, 1, "post-panic epoch advance");
}
