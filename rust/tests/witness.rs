//! Cross-backend witness-consistency suite.
//!
//! The witnessing `compare_exchange` contract says `Err(w)` hands back a
//! value that was *actually observable* — a linearizable read, never a
//! torn or fabricated one. These tests enforce that on every backend:
//!
//! 1. **Checksummed witnesses**: every value any writer installs carries
//!    a 4-word internal checksum; every `Err(w)` must satisfy it. A torn
//!    witness (words from two different values) or an invented one fails
//!    with overwhelming probability.
//! 2. **`fetch_update` exactness**: a contended counter where every
//!    retry is fed by the witness — the sum must equal the op count
//!    exactly on all eight backends.
//! 3. **`swap` chain**: concurrent exchanges must hand each installed
//!    value to exactly one observer (the returned previous values plus
//!    the final value form a permutation of everything installed).
//! 4. **`Words<K>` round-trips** across widths and backends, for
//!    arbitrary bit patterns.

use std::sync::Arc;

use big_atomics::atomics::{
    BigAtomic, CachedMemEff, CachedWaitFree, CachedWritable, HtmSim, Indirect, LockPool, SeqLock,
    SimpLock, Words,
};
use big_atomics::hash::{CacheHash, ConcurrentMap, Link};
use big_atomics::util::props::forall;

const MAGIC: u64 = 0xD1CE_BA5E_0DD5_EED5;

/// Encode a (thread, seq) pair into a self-checking 4-word value.
fn encode(t: u64, s: u64) -> Words<4> {
    let x = (t << 48) | s;
    let w1 = x.wrapping_mul(3);
    let w2 = x ^ MAGIC;
    Words([x, w1, w2, x ^ w1 ^ w2])
}

/// A value is "observable" iff some writer actually installed it.
fn check(label: &str, w: Words<4>) {
    assert_eq!(w.0[1], w.0[0].wrapping_mul(3), "{label}: fabricated witness {:?}", w.0);
    assert_eq!(w.0[2], w.0[0] ^ MAGIC, "{label}: torn witness {:?}", w.0);
    assert_eq!(w.0[3], w.0[0] ^ w.0[1] ^ w.0[2], "{label}: bad checksum {:?}", w.0);
}

fn witness_observable<A: BigAtomic<Words<4>> + 'static>(label: &'static str) {
    let a: Arc<A> = Arc::new(A::new(encode(0, 0)));
    let threads = 4u64;
    let per = 2_000u64;
    let handles: Vec<_> = (0..threads)
        .map(|t| {
            let a = Arc::clone(&a);
            std::thread::spawn(move || {
                let mut cur = a.load();
                for s in 1..=per {
                    let desired = encode(t + 1, s);
                    loop {
                        check(label, cur);
                        match a.compare_exchange(cur, desired) {
                            Ok(prev) => {
                                check(label, prev);
                                cur = desired;
                                break;
                            }
                            Err(w) => {
                                // The witness must be a real, untorn,
                                // installed value.
                                check(label, w);
                                cur = w;
                            }
                        }
                    }
                }
            })
        })
        .collect();
    for h in handles {
        h.join().unwrap();
    }
    check(label, a.load());
}

#[test]
fn test_witness_observable_all_backends() {
    witness_observable::<SeqLock<Words<4>>>("SeqLock");
    witness_observable::<SimpLock<Words<4>>>("SimpLock");
    witness_observable::<LockPool<Words<4>>>("LockPool");
    witness_observable::<Indirect<Words<4>>>("Indirect");
    witness_observable::<CachedWaitFree<Words<4>>>("Cached-WaitFree");
    witness_observable::<CachedMemEff<Words<4>>>("Cached-MemEff");
    witness_observable::<CachedWritable<Words<4>>>("Cached-Writable");
    witness_observable::<HtmSim<Words<4>>>("HTM(sim)");
}

fn counter_exact<A: BigAtomic<Words<2>> + 'static>(label: &'static str) {
    let a: Arc<A> = Arc::new(A::new(Words([0, 0])));
    let threads = 4u64;
    let per = 2_500u64;
    let handles: Vec<_> = (0..threads)
        .map(|t| {
            let a = Arc::clone(&a);
            std::thread::spawn(move || {
                for i in 0..per {
                    let r = a.fetch_update(|v| {
                        Some(Words([v.0[0] + 1, v.0[1].wrapping_add(t * per + i)]))
                    });
                    assert!(r.is_ok(), "{label}: unconditional update failed");
                }
            })
        })
        .collect();
    for h in handles {
        h.join().unwrap();
    }
    assert_eq!(
        a.load().0[0],
        threads * per,
        "{label}: fetch_update lost or duplicated increments"
    );
}

#[test]
fn test_fetch_update_counter_exact_all_backends() {
    counter_exact::<SeqLock<Words<2>>>("SeqLock");
    counter_exact::<SimpLock<Words<2>>>("SimpLock");
    counter_exact::<LockPool<Words<2>>>("LockPool");
    counter_exact::<Indirect<Words<2>>>("Indirect");
    counter_exact::<CachedWaitFree<Words<2>>>("Cached-WaitFree");
    counter_exact::<CachedMemEff<Words<2>>>("Cached-MemEff");
    counter_exact::<CachedWritable<Words<2>>>("Cached-Writable");
    counter_exact::<HtmSim<Words<2>>>("HTM(sim)");
}

fn swap_chain<A: BigAtomic<Words<2>> + 'static>(label: &'static str) {
    // Every thread swaps in unique values and keeps what it got back;
    // (returned values) + (final value) must be a permutation of
    // (initial value) + (all installed values). The initial value must
    // satisfy the same word1 == !word0 invariant as installed ones.
    let a: Arc<A> = Arc::new(A::new(Words([0, !0])));
    let threads = 4u64;
    let per = 2_000u64;
    let handles: Vec<_> = (0..threads)
        .map(|t| {
            let a = Arc::clone(&a);
            std::thread::spawn(move || {
                let mut got: Vec<u64> = Vec::with_capacity(per as usize);
                for s in 0..per {
                    let unique = ((t + 1) << 48) | (s + 1);
                    let prev = a.swap(Words([unique, !unique]));
                    assert_eq!(prev.0[1], !prev.0[0], "{label}: torn swap result");
                    got.push(prev.0[0]);
                }
                got
            })
        })
        .collect();
    let mut seen: Vec<u64> = handles
        .into_iter()
        .flat_map(|h| h.join().unwrap())
        .collect();
    seen.push(a.load().0[0]);
    seen.sort_unstable();
    let mut expect: Vec<u64> = vec![0]; // the initial value
    for t in 0..threads {
        for s in 0..per {
            expect.push(((t + 1) << 48) | (s + 1));
        }
    }
    expect.sort_unstable();
    assert_eq!(seen, expect, "{label}: swap dropped or duplicated a value");
}

#[test]
fn test_swap_chain_all_backends() {
    swap_chain::<SeqLock<Words<2>>>("SeqLock");
    swap_chain::<SimpLock<Words<2>>>("SimpLock");
    swap_chain::<LockPool<Words<2>>>("LockPool");
    swap_chain::<Indirect<Words<2>>>("Indirect");
    swap_chain::<CachedWaitFree<Words<2>>>("Cached-WaitFree");
    swap_chain::<CachedMemEff<Words<2>>>("Cached-MemEff");
    swap_chain::<CachedWritable<Words<2>>>("Cached-Writable");
    swap_chain::<HtmSim<Words<2>>>("HTM(sim)");
}

// ---------------------------------------------------------------------
// Wide-table sweeps (ROADMAP): CacheHash<_, Words<4>, Words<4>> covered
// by correctness tests, not just the fig3_wide bench panel.
// ---------------------------------------------------------------------

/// Derive the only legal value for a wide key: each word mixes the key's
/// corresponding word, so any torn/stale read fails loudly.
fn wide_value_for(key: Words<4>) -> Words<4> {
    Words([
        key.0[0].wrapping_mul(3).wrapping_add(1),
        key.0[1] ^ MAGIC,
        key.0[2].rotate_left(9),
        !key.0[3],
    ])
}

fn wide_key(i: u64) -> Words<4> {
    Words([i, i ^ 0xA5A5, i.rotate_left(23), !i])
}

fn wide_map_checksummed_values<A>()
where
    A: BigAtomic<Link<Words<4>, Words<4>>> + 'static,
{
    // Tiny table: every bucket develops 9-word-link chains, so the
    // inline fast path, the chain walk, and the path-copying remove all
    // run at the wide instantiation.
    let t: Arc<CacheHash<A, Words<4>, Words<4>>> = Arc::new(CacheHash::new(4));
    let threads = 4u64;
    let keys = 48u64;
    let handles: Vec<_> = (0..threads)
        .map(|w| {
            let t = Arc::clone(&t);
            std::thread::spawn(move || {
                for round in 0..200u64 {
                    for i in (w % 2..keys).step_by(2) {
                        let k = wide_key(i);
                        if round % 2 == 0 {
                            let _ = t.insert(k, wide_value_for(k));
                        } else {
                            let _ = t.remove(k);
                        }
                        // Every observation must satisfy the checksum.
                        if let Some(v) = t.find(k) {
                            assert_eq!(v, wide_value_for(k), "torn wide value for {:?}", k.0);
                        }
                    }
                }
            })
        })
        .collect();
    for h in handles {
        h.join().unwrap();
    }
    // Deterministic tail: fill and verify every key.
    for i in 0..keys {
        let k = wide_key(i);
        let _ = t.insert(k, wide_value_for(k));
        assert_eq!(t.find(k), Some(wide_value_for(k)));
    }
}

#[test]
fn test_wide_map_checksummed_values_memeff() {
    wide_map_checksummed_values::<CachedMemEff<Link<Words<4>, Words<4>>>>();
}

#[test]
fn test_wide_map_checksummed_values_seqlock() {
    wide_map_checksummed_values::<SeqLock<Link<Words<4>, Words<4>>>>();
}

#[test]
fn test_wide_map_duplicate_inserts_one_winner() {
    // The §5.3 wide instantiation under duplicate-insert races: exactly
    // one winner per key (the witness-fed duplicate check at 9 words).
    let t: Arc<CacheHash<CachedMemEff<Link<Words<4>, Words<4>>>, Words<4>, Words<4>>> =
        Arc::new(CacheHash::new(2));
    let wins = Arc::new(std::sync::atomic::AtomicU64::new(0));
    let handles: Vec<_> = (0..2)
        .map(|_| {
            let t = Arc::clone(&t);
            let wins = Arc::clone(&wins);
            std::thread::spawn(move || {
                for i in 0..300u64 {
                    let k = wide_key(i);
                    if t.insert(k, wide_value_for(k)) {
                        wins.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
                    }
                }
            })
        })
        .collect();
    for h in handles {
        h.join().unwrap();
    }
    assert_eq!(wins.load(std::sync::atomic::Ordering::SeqCst), 300);
    for i in 0..300u64 {
        let k = wide_key(i);
        assert_eq!(t.find(k), Some(wide_value_for(k)), "key {i}");
    }
}

#[test]
fn test_words_roundtrip_arbitrary_bits_across_widths() {
    fn roundtrip<const K: usize, A: BigAtomic<Words<K>>>(bits: [u64; K]) -> bool {
        let a = A::new(Words(bits));
        if a.load() != Words(bits) {
            return false;
        }
        let flipped = Words(bits.map(|w| !w));
        a.store(flipped);
        a.load() == flipped
    }
    forall::<[u64; 1], _>(301, 200, |b| roundtrip::<1, SeqLock<Words<1>>>(*b));
    forall::<[u64; 3], _>(302, 200, |b| roundtrip::<3, CachedWaitFree<Words<3>>>(*b));
    forall::<[u64; 5], _>(303, 200, |b| roundtrip::<5, CachedMemEff<Words<5>>>(*b));
    forall::<[u64; 8], _>(304, 100, |b| roundtrip::<8, CachedWritable<Words<8>>>(*b));
    forall::<[u64; 16], _>(305, 50, |b| roundtrip::<16, HtmSim<Words<16>>>(*b));
}
