//! Cross-module integration tests: hash tables over every big-atomic
//! strategy, the bench driver end to end, the coordinator's figure jobs,
//! and the KV service.

use std::sync::Arc;
use std::time::Duration;

use big_atomics::atomics::{
    CachedMemEff, CachedWaitFree, CachedWritable, HtmSim, Indirect, LockPool, SeqLock, SimpLock,
    Words,
};
use big_atomics::bench::driver::{
    run_atomics, run_fetch_update, run_map, run_map_wide, widen_key, AtomicImpl, MapImpl,
    OpSource,
};
use big_atomics::bench::figures::{fig2_z, FigureCfg};
use big_atomics::bench::workload::WorkloadSpec;
use big_atomics::coordinator::kv_service::{self, KvConfig};
use big_atomics::hash::{CacheHash, ConcurrentMap, Link, LinkVal};
use big_atomics::util::rng::Xoshiro256;

/// Exhaustive hash-table semantics check against std::HashMap, with a
/// mixed random op sequence — run over every big-atomic strategy.
fn model_check_table<M: ConcurrentMap>(table: M, seed: u64, ops: usize) {
    use std::collections::HashMap;
    let mut model: HashMap<u64, u64> = HashMap::new();
    let mut rng = Xoshiro256::seeded(seed);
    for i in 0..ops {
        let key = rng.next_below(200) as u64;
        match rng.next_below(3) {
            0 => {
                assert_eq!(
                    table.find(key),
                    model.get(&key).copied(),
                    "find({key}) mismatch at op {i} on {}",
                    table.map_name()
                );
            }
            1 => {
                let v = i as u64;
                let want = !model.contains_key(&key);
                assert_eq!(
                    table.insert(key, v),
                    want,
                    "insert({key}) mismatch at op {i} on {}",
                    table.map_name()
                );
                model.entry(key).or_insert(v);
            }
            _ => {
                let want = model.remove(&key).is_some();
                assert_eq!(
                    table.remove(key),
                    want,
                    "remove({key}) mismatch at op {i} on {}",
                    table.map_name()
                );
            }
        }
    }
}

#[test]
fn test_cachehash_model_check_all_strategies() {
    model_check_table(CacheHash::<SeqLock<LinkVal>>::new(64), 1, 20_000);
    model_check_table(CacheHash::<SimpLock<LinkVal>>::new(64), 2, 20_000);
    model_check_table(CacheHash::<LockPool<LinkVal>>::new(64), 3, 20_000);
    model_check_table(CacheHash::<Indirect<LinkVal>>::new(64), 4, 20_000);
    model_check_table(CacheHash::<CachedWaitFree<LinkVal>>::new(64), 5, 20_000);
    model_check_table(CacheHash::<CachedMemEff<LinkVal>>::new(64), 6, 20_000);
    model_check_table(CacheHash::<CachedWritable<LinkVal>>::new(64), 7, 20_000);
    model_check_table(CacheHash::<HtmSim<LinkVal>>::new(64), 8, 20_000);
}

#[test]
fn test_chaining_and_comparators_model_check() {
    model_check_table(big_atomics::hash::Chaining::new(64), 9, 20_000);
    model_check_table(big_atomics::hash::ShardedLockMap::new(64, 8), 10, 20_000);
    model_check_table(big_atomics::hash::GlobalLockMap::new(64), 11, 20_000);
}

/// The same exhaustive semantics check against std::HashMap, but with
/// 4-word keys and 4-word values — the §5.3 arbitrary-length
/// instantiation of every table family, run over every big-atomic
/// strategy (the acceptance bar for the generic-value API).
fn model_check_wide<M: ConcurrentMap<Words<4>, Words<4>>>(table: M, seed: u64, ops: usize) {
    use std::collections::HashMap;
    let mut model: HashMap<u64, Words<4>> = HashMap::new();
    let mut rng = Xoshiro256::seeded(seed);
    for i in 0..ops {
        let kid = rng.next_below(200) as u64;
        let key = widen_key(kid);
        match rng.next_below(3) {
            0 => {
                assert_eq!(
                    table.find(key),
                    model.get(&kid).copied(),
                    "find({kid}) mismatch at op {i} on {}",
                    table.map_name()
                );
            }
            1 => {
                let v = Words([i as u64; 4]);
                let want = !model.contains_key(&kid);
                assert_eq!(
                    table.insert(key, v),
                    want,
                    "insert({kid}) mismatch at op {i} on {}",
                    table.map_name()
                );
                model.entry(kid).or_insert(v);
            }
            _ => {
                let want = model.remove(&kid).is_some();
                assert_eq!(
                    table.remove(key),
                    want,
                    "remove({kid}) mismatch at op {i} on {}",
                    table.map_name()
                );
            }
        }
    }
}

#[test]
fn test_cachehash_wide_model_check_all_strategies() {
    type L = Link<Words<4>, Words<4>>;
    type W = Words<4>;
    model_check_wide(CacheHash::<SeqLock<L>, W, W>::new(64), 21, 10_000);
    model_check_wide(CacheHash::<SimpLock<L>, W, W>::new(64), 22, 10_000);
    model_check_wide(CacheHash::<LockPool<L>, W, W>::new(64), 23, 10_000);
    model_check_wide(CacheHash::<Indirect<L>, W, W>::new(64), 24, 10_000);
    model_check_wide(CacheHash::<CachedWaitFree<L>, W, W>::new(64), 25, 10_000);
    model_check_wide(CacheHash::<CachedMemEff<L>, W, W>::new(64), 26, 10_000);
    model_check_wide(CacheHash::<CachedWritable<L>, W, W>::new(64), 27, 10_000);
    model_check_wide(CacheHash::<HtmSim<L>, W, W>::new(64), 28, 10_000);
}

#[test]
fn test_comparators_wide_model_check() {
    type W = Words<4>;
    model_check_wide(big_atomics::hash::Chaining::<W, W>::new(64), 29, 10_000);
    model_check_wide(big_atomics::hash::ShardedLockMap::<W, W>::new(64, 8), 30, 10_000);
    model_check_wide(big_atomics::hash::GlobalLockMap::<W, W>::new(64), 31, 10_000);
}

/// Concurrent wide-table exactness: disjoint key ranges, 4-word values.
#[test]
fn test_cachehash_wide_concurrent_ownership() {
    type L = Link<Words<4>, Words<4>>;
    let t: Arc<CacheHash<CachedMemEff<L>, Words<4>, Words<4>>> = Arc::new(CacheHash::new(1024));
    let threads = 4;
    let per = 1_000u64;
    let handles: Vec<_> = (0..threads)
        .map(|tix| {
            let t = Arc::clone(&t);
            std::thread::spawn(move || {
                let base = tix as u64 * 10_000_000;
                for i in 0..per {
                    let k = Words([base + i, i, tix as u64, 1]);
                    assert!(t.insert(k, Words([i; 4])));
                }
                for i in 0..per {
                    let k = Words([base + i, i, tix as u64, 1]);
                    assert_eq!(t.find(k), Some(Words([i; 4])));
                }
            })
        })
        .collect();
    for h in handles {
        h.join().unwrap();
    }
}

/// Concurrent per-key counters: each thread owns a disjoint key range on
/// one shared CacheHash; final contents must be exact.
#[test]
fn test_cachehash_concurrent_ownership() {
    let t: Arc<CacheHash<CachedMemEff<LinkVal>>> = Arc::new(CacheHash::new(4096));
    let threads = 8; // oversubscribed on this host
    let per = 1_500u64;
    let handles: Vec<_> = (0..threads)
        .map(|tix| {
            let t = Arc::clone(&t);
            std::thread::spawn(move || {
                let base = tix as u64 * 10_000_000;
                for i in 0..per {
                    assert!(t.insert(base + i, i * 2));
                }
                for i in 0..per {
                    assert_eq!(t.find(base + i), Some(i * 2));
                }
                for i in 0..per {
                    if i % 3 == 0 {
                        assert!(t.remove(base + i));
                    }
                }
            })
        })
        .collect();
    for h in handles {
        h.join().unwrap();
    }
    for tix in 0..threads {
        let base = tix as u64 * 10_000_000;
        for i in 0..per {
            let want = if i % 3 == 0 { None } else { Some(i * 2) };
            assert_eq!(t.find(base + i), want);
        }
    }
}

#[test]
fn test_driver_all_impls_under_oversubscription() {
    // 8 threads on a small array: every impl must stay correct and make
    // progress (the lock-based ones are slow here — that's the paper).
    let spec = WorkloadSpec {
        n: 512,
        theta: 0.9,
        update_pct: 50,
        seed: 77,
    };
    for imp in AtomicImpl::ALL {
        let r = run_atomics(imp, 3, &spec, 8, Duration::from_millis(60), &OpSource::Rust).unwrap();
        assert!(
            r.total_ops > 500,
            "{} made no progress oversubscribed: {} ops",
            imp.name(),
            r.total_ops
        );
    }
}

#[test]
fn test_driver_all_maps_smoke() {
    let spec = WorkloadSpec {
        n: 1024,
        theta: 0.5,
        update_pct: 30,
        seed: 78,
    };
    for imp in [
        MapImpl::CacheHashSeqLock,
        MapImpl::CacheHashSimpLock,
        MapImpl::CacheHashIndirect,
        MapImpl::CacheHashWaitFree,
        MapImpl::CacheHashMemEff,
        MapImpl::CacheHashWritable,
        MapImpl::CacheHashHtm,
        MapImpl::Chaining,
        MapImpl::ShardedLock,
        MapImpl::GlobalLock,
    ] {
        let r = run_map(imp, &spec, 3, Duration::from_millis(40), &OpSource::Rust);
        assert!(r.total_ops > 100, "{}: {} ops", imp.name(), r.total_ops);
    }
}

#[test]
fn test_driver_wide_map_and_fetch_update_workloads() {
    // The §5.3 wide workload and the fetch_update mix both run through
    // the same timed driver as every other figure series.
    let spec = WorkloadSpec {
        n: 512,
        theta: 0.5,
        update_pct: 50,
        seed: 80,
    };
    let r = run_map_wide(
        AtomicImpl::CachedMemEff,
        &spec,
        3,
        Duration::from_millis(40),
        &OpSource::Rust,
    );
    assert!(r.total_ops > 100, "wide map: {} ops", r.total_ops);
    let r = run_fetch_update(
        AtomicImpl::CachedMemEff,
        3,
        &spec,
        3,
        Duration::from_millis(40),
        &OpSource::Rust,
    )
    .unwrap();
    assert!(r.total_ops > 100, "fetch_update: {} ops", r.total_ops);
}

#[test]
fn test_figure_runner_writes_csv() {
    let dir = std::env::temp_dir().join("big_atomics_itest_reports");
    let cfg = FigureCfg {
        secs_per_point: 0.01,
        n: 256,
        report_dir: dir.display().to_string(),
        use_artifact: false,
    };
    let rep = fig2_z(&cfg, &OpSource::Rust, false);
    let path = rep.save(&cfg.report_dir).unwrap();
    let text = std::fs::read_to_string(path).unwrap();
    assert!(text.lines().count() > 10);
    assert!(text.starts_with("z,impl,mops"));
}

#[test]
fn test_kv_service_end_to_end_no_artifacts() {
    let cfg = KvConfig {
        n: 2048,
        workers: 3,
        batch: 128,
        duration: Duration::from_millis(150),
        update_pct: 40,
        theta: 0.7,
        seed: 99,
        ..KvConfig::default()
    };
    let rep = kv_service::run(&cfg, None).unwrap();
    assert!(rep.total_requests > 500);
    assert_eq!(rep.total_requests, rep.finds + rep.inserts + rep.deletes);
    assert!(rep.sample_count > 0);
    // Native (histogram-backed) latency summary in artifact-less builds.
    let lat = rep.latency.expect("native latency summary");
    assert!(lat.p99 >= lat.p50 && lat.max >= lat.p99);
    assert!(rep.latency_p999_ns.unwrap() >= lat.p99 as u64);
    // The bounded reservoir never outgrows its config.
    assert!(rep.retained_samples <= KvConfig::default().reservoir + cfg.workers);
}
