//! Chaos linearizability suite: the fault-injection scenarios from
//! `fault::chaos`, run as pinned-seed regression tests.
//!
//! This lives in its **own** integration binary on purpose: the armed
//! [`FaultPlan`](big_atomics::fault::FaultPlan) is process-global, so a
//! kill plan would panic unrelated tests running concurrently in the
//! same process. Here every test serializes on the scenario lock inside
//! `fault::chaos` and the only threads in the process are the
//! scenario's own.
//!
//! Without `--features fault` the scenarios still run — no fault ever
//! fires, so they degrade to plain concurrency tests of the same
//! invariants (and the injected-count assertions are gated off).

use big_atomics::fault::chaos::{
    self, jitter, kill_allocator, kill_copier, kill_copier_shrink, kill_migrator, kill_worker,
    stall_drainer,
};

/// Fail with the full report (notes + violations) — `assert!(rep.ok())`
/// alone would hide the violation list.
fn assert_survived(rep: &chaos::ChaosReport) {
    assert!(rep.ok(), "{rep}");
}

#[test]
fn test_chaos_kill_copier_pinned_seeds() {
    for seed in [0xC4A0_5u64, 7, 0xDEAD_BEEF] {
        let rep = kill_copier(seed);
        assert_survived(&rep);
        // The plan kills the first copier to seal a FROZEN bucket; with
        // 4 inserter threads forcing resizes, at least one injection is
        // guaranteed when the feature is on.
        #[cfg(feature = "fault")]
        assert!(rep.injected > 0, "kill-copier plan never fired: {rep}");
    }
}

#[test]
fn test_chaos_stall_drainer_pinned_seeds() {
    for seed in [0xC4A0_5u64, 11] {
        let rep = stall_drainer(seed);
        assert_survived(&rep);
        // Phase 1 engineers a lease takeover deterministically, feature
        // or not — the takeover assertion lives inside the scenario.
    }
}

#[test]
fn test_chaos_kill_worker_pinned_seed() {
    let rep = kill_worker(0xC4A0_5, 0.3);
    assert_survived(&rep);
    // The scenario itself asserts conservation and, when the plan
    // fired, that worker_panics recorded the kill.
}

#[test]
fn test_chaos_kill_allocator_pinned_seeds() {
    for seed in [0xC4A0_5u64, 13] {
        let rep = kill_allocator(seed);
        assert_survived(&rep);
        // Every scenario thread starts with empty free lists, so the
        // first chain-node allocation walks the page-claim path and the
        // one-shot kill is guaranteed a window under the feature.
        #[cfg(feature = "fault")]
        assert!(rep.injected > 0, "kill-allocator plan never fired: {rep}");
    }
}

#[test]
fn test_chaos_kill_copier_shrink_pinned_seeds() {
    for seed in [0xC4A0_5u64, 17] {
        let rep = kill_copier_shrink(seed);
        assert_survived(&rep);
        // The grow phase completes before the plan is armed, so every
        // seal the one-shot kill can hit belongs to a shrink migration;
        // the mass drain guarantees at least one such seal.
        #[cfg(feature = "fault")]
        assert!(rep.injected > 0, "kill-copier-shrink plan never fired: {rep}");
    }
}

#[test]
fn test_chaos_kill_migrator_pinned_seeds() {
    for seed in [0xC4A0_5u64, 19] {
        let rep = kill_migrator(seed);
        assert_survived(&rep);
        // The drained table guarantees a shrink with non-empty chains,
        // so the per-entry-copy kill window is reached on the migrator's
        // first converging pass.
        #[cfg(feature = "fault")]
        assert!(rep.injected > 0, "kill-migrator plan never fired: {rep}");
    }
}

#[test]
fn test_chaos_jitter_pinned_seed() {
    let rep = jitter(0xC4A0_5, 0.3);
    assert_survived(&rep);
    #[cfg(feature = "fault")]
    assert!(rep.injected > 0, "jitter plan never fired: {rep}");
}

#[test]
fn test_chaos_run_all_dispatch() {
    let reports = chaos::run(3, "all", 0.2).expect("'all' is a valid plan name");
    assert_eq!(reports.len(), 7, "all = every scenario");
    for rep in &reports {
        assert_survived(rep);
    }
}
