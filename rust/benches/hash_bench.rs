//! `cargo bench` — hash-table benchmarks (custom harness).
//!
//! Part 1: per-operation latencies for find/insert/remove on CacheHash
//! (per big-atomic strategy), Chaining, and the comparator stand-ins.
//! Part 2: quick versions of the Fig 3/4 throughput sweeps.

use std::time::Duration;

use big_atomics::bench::driver::{widen_key, OpSource};
use big_atomics::bench::figures::{fig3, fig3_wide, fig4, FigureCfg};
use big_atomics::bench::memory::memory_census;
use big_atomics::atomics::{CachedMemEff, CachedWaitFree, Indirect, SeqLock, Words};
use big_atomics::hash::{
    CacheHash, Chaining, ConcurrentMap, GlobalLockMap, Link, LinkVal, ShardedLockMap,
};
use big_atomics::util::{ns_per_op, time_for};
use big_atomics::util::rng::mix64;

const MEASURE: Duration = Duration::from_millis(200);
const N: usize = 1 << 14;

fn bench_map<M: ConcurrentMap>(map: M) {
    // Half-full table, like the figure benchmarks.
    for r in (0..N).step_by(2) {
        map.insert(mix64(r as u64), r as u64);
    }
    let mut i = 0u64;

    // find (hit half the time)
    let (iters, el) = time_for(MEASURE, || {
        i = i.wrapping_add(0x9E3779B97F4A7C15);
        std::hint::black_box(map.find(mix64((i as usize % N) as u64)));
    });
    let find_ns = ns_per_op(iters, el);

    // insert/remove toggle on a private key range (always succeed)
    let mut toggle = false;
    let mut j = 0u64;
    let (iters, el) = time_for(MEASURE, || {
        let key = mix64(1_000_000 + (j % 4096));
        if toggle {
            map.remove(key);
        } else {
            map.insert(key, j);
        }
        if j % 4096 == 4095 {
            toggle = !toggle;
        }
        j += 1;
    });
    let upd_ns = ns_per_op(iters, el);

    println!(
        "{:<28} find {:>8.1} ns   insert/remove {:>8.1} ns",
        map.map_name(),
        find_ns,
        upd_ns
    );
}

type W4 = Words<4>;
type WideLink = Link<W4, W4>;

fn bench_wide_map<M: ConcurrentMap<W4, W4>>(map: M) {
    for r in (0..N).step_by(2) {
        map.insert(widen_key(mix64(r as u64)), Words([r as u64; 4]));
    }
    let mut i = 0u64;
    let (iters, el) = time_for(MEASURE, || {
        i = i.wrapping_add(0x9E3779B97F4A7C15);
        std::hint::black_box(map.find(widen_key(mix64((i as usize % N) as u64))));
    });
    let find_ns = ns_per_op(iters, el);
    let mut toggle = false;
    let mut j = 0u64;
    let (iters, el) = time_for(MEASURE, || {
        let key = widen_key(mix64(1_000_000 + (j % 4096)));
        if toggle {
            map.remove(key);
        } else {
            map.insert(key, Words([j; 4]));
        }
        if j % 4096 == 4095 {
            toggle = !toggle;
        }
        j += 1;
    });
    let upd_ns = ns_per_op(iters, el);
    println!(
        "{:<28} find {:>8.1} ns   insert/remove {:>8.1} ns",
        format!("{}[wide]", map.map_name()),
        find_ns,
        upd_ns
    );
}

fn main() {
    println!("== hash table per-op latency, n=16K, single thread ==");
    bench_map(CacheHash::<SeqLock<LinkVal>>::new(N));
    bench_map(CacheHash::<CachedMemEff<LinkVal>>::new(N));
    bench_map(CacheHash::<CachedWaitFree<LinkVal>>::new(N));
    bench_map(CacheHash::<Indirect<LinkVal>>::new(N));
    bench_map(Chaining::new(N));
    bench_map(ShardedLockMap::new(N, 16));
    bench_map(GlobalLockMap::new(N));

    println!("\n== wide (4-word key/value) table per-op latency ==");
    bench_wide_map(CacheHash::<CachedMemEff<WideLink>, W4, W4>::new(N));
    bench_wide_map(CacheHash::<SeqLock<WideLink>, W4, W4>::new(N));
    bench_wide_map(Chaining::<W4, W4>::new(N));

    let cfg = FigureCfg {
        secs_per_point: 0.08,
        n: 1 << 14,
        report_dir: "reports/bench".into(),
        use_artifact: false,
    };
    let src = OpSource::Rust;
    let _ = fig3(&cfg, &src, "u", false).save(&cfg.report_dir);
    let _ = fig3(&cfg, &src, "u", true).save(&cfg.report_dir);
    let _ = fig3(&cfg, &src, "z", true).save(&cfg.report_dir);
    let _ = fig3_wide(&cfg, &src).save(&cfg.report_dir);
    let (a, b) = fig4(&cfg, &src);
    let _ = a.save(&cfg.report_dir);
    let _ = b.save(&cfg.report_dir);
    let _ = memory_census(&cfg).save(&cfg.report_dir);
    println!("\nhash bench done (CSV in reports/bench/)");
}
