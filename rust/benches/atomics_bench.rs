//! `cargo bench` — big-atomic benchmarks (custom harness; criterion is
//! not in the offline crate set, DESIGN.md §Substitutions).
//!
//! Part 1: per-operation latencies (ns/op) for load and cas on every
//! implementation — the hot-path numbers the §Perf pass optimizes.
//! Part 2: quick versions of the Fig 1/2/5 throughput sweeps so
//! `cargo bench` alone regenerates the paper's microbenchmark shapes.
//!
//! Full-resolution figures: `./target/release/repro all --secs 1`.

use std::time::Duration;

use big_atomics::atomics::{
    BigAtomic, CachedMemEff, CachedWaitFree, CachedWritable, HtmSim, Indirect, LockPool, SeqLock,
    SimpLock, Words,
};
use big_atomics::bench::driver::OpSource;
use big_atomics::bench::figures::{
    fig1, fig2_fetch_update, fig2_p, fig2_u, fig2_w, fig2_z, fig5, FigureCfg,
};
use big_atomics::util::{ns_per_op, time_for};

const WARMUP: Duration = Duration::from_millis(30);
const MEASURE: Duration = Duration::from_millis(200);

fn bench_ops<A: BigAtomic<Words<4>>>(name: &str) {
    let a = A::new(Words([1, 2, 3, 4]));

    // load (fast path / cached)
    time_for(WARMUP, || {
        std::hint::black_box(a.load());
    });
    let (iters, el) = time_for(MEASURE, || {
        std::hint::black_box(a.load());
    });
    let load_ns = ns_per_op(iters, el);

    // successful compare_exchange (value changes every time)
    let mut i = 0u64;
    time_for(WARMUP, || {
        let cur = a.load();
        i += 1;
        let _ = a.compare_exchange(cur, Words([i, i ^ 1, i ^ 2, i ^ 3]));
    });
    let (iters, el) = time_for(MEASURE, || {
        let cur = a.load();
        i += 1;
        let _ = a.compare_exchange(cur, Words([i, i ^ 1, i ^ 2, i ^ 3]));
    });
    let cas_ns = ns_per_op(iters, el);

    // failing compare_exchange (stale expected; returns the witness)
    let stale = Words([u64::MAX, 0, 0, 0]);
    let (iters, el) = time_for(MEASURE, || {
        let _ = a.compare_exchange(stale, Words([0, 0, 0, 0]));
    });
    let fail_ns = ns_per_op(iters, el);

    // fetch_update (closure increment; the packaged retry loop)
    let (iters, el) = time_for(MEASURE, || {
        let _ = a.fetch_update(|mut v| {
            v.0[0] = v.0[0].wrapping_add(1);
            Some(v)
        });
    });
    let fu_ns = ns_per_op(iters, el);

    println!(
        "{name:<26} load {load_ns:>7.1} ns   cx(ok) {cas_ns:>7.1} ns   cx(fail) {fail_ns:>7.1} ns   fetch_update {fu_ns:>7.1} ns"
    );
}

fn main() {
    println!("== per-op latency, k=4 (32-byte values), single thread ==");
    bench_ops::<SeqLock<Words<4>>>("SeqLock");
    bench_ops::<SimpLock<Words<4>>>("SimpLock");
    bench_ops::<LockPool<Words<4>>>("LockPool(std::atomic)");
    bench_ops::<Indirect<Words<4>>>("Indirect");
    bench_ops::<CachedWaitFree<Words<4>>>("Cached-WaitFree");
    bench_ops::<CachedMemEff<Words<4>>>("Cached-MemEff");
    bench_ops::<CachedWritable<Words<4>>>("Cached-WF-Writable");
    bench_ops::<HtmSim<Words<4>>>("HTM(sim)");

    // Quick paper-shape sweeps (scaled; CSV under reports/bench/).
    let cfg = FigureCfg {
        secs_per_point: 0.08,
        n: 1 << 14,
        report_dir: "reports/bench".into(),
        use_artifact: false,
    };
    let src = OpSource::Rust;
    let _ = fig1(&cfg, &src).save(&cfg.report_dir);
    let _ = fig2_u(&cfg, &src, false).save(&cfg.report_dir);
    let _ = fig2_u(&cfg, &src, true).save(&cfg.report_dir);
    let _ = fig2_z(&cfg, &src, true).save(&cfg.report_dir);
    let _ = fig2_w(&cfg, &src).save(&cfg.report_dir);
    let _ = fig2_p(&cfg, &src).save(&cfg.report_dir);
    let _ = fig2_fetch_update(&cfg, &src).save(&cfg.report_dir);
    for r in fig5(&cfg, &src) {
        let _ = r.save(&cfg.report_dir);
    }
    println!("\natomics bench done (CSV in reports/bench/)");
}
