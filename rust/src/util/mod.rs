//! Shared utilities: RNG, thread registry, timing, cache padding, error
//! plumbing, and a mini property-testing harness (stand-ins for
//! proptest / crossbeam-utils / anyhow, which are not in the offline
//! crate set — see DESIGN.md §Substitutions).

pub mod backoff;
pub mod cache_padded;
pub mod error;
pub mod ordering;
pub mod props;
pub mod registry;
pub mod rng;

pub use backoff::Backoff;
pub use cache_padded::CachePadded;
pub use ordering::{DefaultPolicy, Fenced, OrderingPolicy, SeqCstEverywhere};

use std::time::{Duration, Instant};

/// Run `f` repeatedly for at least `dur`, returning (iterations, elapsed).
///
/// The workhorse of the custom bench harness (`rust/benches/*`).
pub fn time_for<F: FnMut()>(dur: Duration, mut f: F) -> (u64, Duration) {
    let start = Instant::now();
    let mut iters = 0u64;
    loop {
        // Batch checks of the clock to avoid timing overhead dominating.
        for _ in 0..64 {
            f();
        }
        iters += 64;
        let el = start.elapsed();
        if el >= dur {
            return (iters, el);
        }
    }
}

/// Nanoseconds helper for report rows.
pub fn ns_per_op(iters: u64, elapsed: Duration) -> f64 {
    elapsed.as_nanos() as f64 / iters.max(1) as f64
}
