//! Minimal error plumbing — a stand-in for `anyhow` (offline crate set,
//! DESIGN.md §Substitutions).
//!
//! Provides the same surface the crate uses: a string-backed [`Error`],
//! [`Result`], a [`Context`] extension trait for `Result`/`Option`, and
//! the [`crate::anyhow!`], [`crate::bail!`], [`crate::ensure!`] macros.

use std::fmt;

/// A type-erased, message-carrying error.
#[derive(Debug)]
pub struct Error {
    msg: String,
}

impl Error {
    /// Build an error from anything displayable.
    pub fn msg(m: impl fmt::Display) -> Self {
        Self { msg: m.to_string() }
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

// NOTE: `Error` deliberately does NOT implement `std::error::Error`, so
// this blanket conversion (the `?` workhorse) cannot overlap the
// reflexive `From<Error> for Error`.
impl<E: std::error::Error + Send + Sync + 'static> From<E> for Error {
    fn from(e: E) -> Self {
        Error::msg(e)
    }
}

/// Crate-wide result type.
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Attach context to failures, `anyhow`-style.
pub trait Context<T> {
    /// Wrap the error with a fixed context message.
    fn context<C: fmt::Display>(self, ctx: C) -> Result<T>;
    /// Wrap the error with a lazily built context message.
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T>;
}

impl<T, E: fmt::Display> Context<T> for std::result::Result<T, E> {
    fn context<C: fmt::Display>(self, ctx: C) -> Result<T> {
        self.map_err(|e| Error::msg(format!("{ctx}: {e}")))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.map_err(|e| Error::msg(format!("{}: {e}", f())))
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: fmt::Display>(self, ctx: C) -> Result<T> {
        self.ok_or_else(|| Error::msg(ctx))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// Construct an [`Error`](crate::util::error::Error) from a format string.
#[macro_export]
macro_rules! anyhow {
    ($($arg:tt)*) => {
        $crate::util::error::Error::msg(format!($($arg)*))
    };
}

/// Return early with a formatted [`Error`](crate::util::error::Error).
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return Err($crate::anyhow!($($arg)*))
    };
}

/// Bail unless `cond` holds.
#[macro_export]
macro_rules! ensure {
    ($cond:expr $(,)?) => {
        if !$cond {
            $crate::bail!("condition failed: {}", stringify!($cond));
        }
    };
    ($cond:expr, $($arg:tt)*) => {
        if !$cond {
            $crate::bail!($($arg)*);
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::{bail, ensure};

    fn parse(s: &str) -> Result<u64> {
        let n: u64 = s.parse()?; // blanket From<ParseIntError>
        ensure!(n > 0, "want positive, got {n}");
        Ok(n)
    }

    #[test]
    fn test_question_mark_and_ensure() {
        assert_eq!(parse("7").unwrap(), 7);
        assert!(parse("x").is_err());
        assert!(parse("0").unwrap_err().to_string().contains("positive"));
    }

    #[test]
    fn test_context_on_result_and_option() {
        let r: std::result::Result<(), std::fmt::Error> = Err(std::fmt::Error);
        let e = r.context("saving report").unwrap_err();
        assert!(e.to_string().starts_with("saving report:"));
        let o: Option<u32> = None;
        let e = o.with_context(|| format!("slot {}", 3)).unwrap_err();
        assert_eq!(e.to_string(), "slot 3");
    }

    #[test]
    fn test_bail_macro() {
        fn f(flag: bool) -> Result<u32> {
            if flag {
                bail!("flagged {}", 1 + 1);
            }
            Ok(5)
        }
        assert_eq!(f(false).unwrap(), 5);
        assert_eq!(f(true).unwrap_err().to_string(), "flagged 2");
    }
}
