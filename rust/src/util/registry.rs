//! Global thread registry: dense small thread ids.
//!
//! All SMR machinery (hazard slots, epoch slots, Algorithm 2's
//! thread-private node slabs) indexes per-thread state by a dense id in
//! `0..MAX_THREADS`.  Ids are leased on first use and returned when the
//! thread exits, so long-running processes that churn threads (the
//! oversubscription benchmarks spawn hundreds) do not exhaust the space.

use std::cell::Cell;
use std::sync::atomic::{AtomicBool, Ordering};

use crate::MAX_THREADS;

static CLAIMED: [AtomicBool; MAX_THREADS] = {
    #[allow(clippy::declare_interior_mutable_const)]
    const F: AtomicBool = AtomicBool::new(false);
    [F; MAX_THREADS]
};

/// One past the largest id ever claimed: SMR scans (hazard snapshots,
/// epoch advances) only need to look at `0..high_water()` instead of all
/// MAX_THREADS slots — a large constant factor on small machines.
static HIGH_WATER: std::sync::atomic::AtomicUsize = std::sync::atomic::AtomicUsize::new(0);

/// Upper bound (exclusive) on ids that have ever been claimed.
#[inline]
pub fn high_water() -> usize {
    HIGH_WATER.load(Ordering::Acquire)
}

thread_local! {
    static TID: Cell<usize> = const { Cell::new(usize::MAX) };
    // Dropped at thread exit; releases the leased id.
    static LEASE: Lease = Lease::acquire();
}

struct Lease {
    id: usize,
}

impl Lease {
    fn acquire() -> Self {
        for (i, slot) in CLAIMED.iter().enumerate() {
            if !slot.load(Ordering::Relaxed)
                && slot
                    .compare_exchange(false, true, Ordering::AcqRel, Ordering::Relaxed)
                    .is_ok()
            {
                HIGH_WATER.fetch_max(i + 1, Ordering::AcqRel);
                return Lease { id: i };
            }
        }
        panic!("thread registry exhausted ({MAX_THREADS} threads)");
    }
}

impl Drop for Lease {
    fn drop(&mut self) {
        // Both SMR schemes get the exit hook: orphan-bag handoff plus
        // announcement-slot clearing, so a churned thread can neither
        // leak garbage nor wedge reclamation for the survivors.
        crate::smr::hazard::on_thread_exit(self.id);
        crate::smr::epoch::on_thread_exit(self.id);
        CLAIMED[self.id].store(false, Ordering::Release);
    }
}

/// This thread's dense id in `0..MAX_THREADS` (leased on first call).
#[inline]
pub fn tid() -> usize {
    TID.with(|t| {
        let v = t.get();
        if v != usize::MAX {
            return v;
        }
        let id = LEASE.with(|l| l.id);
        t.set(id);
        id
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn test_tid_stable_within_thread() {
        let a = tid();
        let b = tid();
        assert_eq!(a, b);
        assert!(a < MAX_THREADS);
    }

    #[test]
    fn test_tids_distinct_across_live_threads() {
        use std::sync::{Arc, Barrier, Mutex};
        let n = 8;
        let barrier = Arc::new(Barrier::new(n));
        let ids = Arc::new(Mutex::new(Vec::new()));
        let handles: Vec<_> = (0..n)
            .map(|_| {
                let barrier = Arc::clone(&barrier);
                let ids = Arc::clone(&ids);
                std::thread::spawn(move || {
                    let id = tid();
                    ids.lock().unwrap().push(id);
                    // Hold the thread alive until everyone registered so
                    // ids cannot be reused mid-test.
                    barrier.wait();
                })
            })
            .collect();
        for h in handles {
            h.join().unwrap();
        }
        let mut ids = ids.lock().unwrap().clone();
        ids.sort_unstable();
        ids.dedup();
        assert_eq!(ids.len(), n, "duplicate tids among concurrent threads");
    }

    #[test]
    fn test_ids_reused_after_exit() {
        // Serially spawned threads may reuse ids; the registry must not
        // leak them (we spawn far more threads than MAX_THREADS).
        for _ in 0..(MAX_THREADS * 2) {
            std::thread::spawn(|| {
                let _ = tid();
            })
            .join()
            .unwrap();
        }
    }
}
