//! Deterministic pseudo-random number generation for workloads and tests.
//!
//! SplitMix64 for seeding / mixing (also the key-mix function shared with
//! the L1 `hashmix` Pallas kernel) and xoshiro256** as the stream
//! generator — both tiny, allocation-free, and reproducible across runs,
//! which the figure harness relies on.

/// murmur3 fmix64 / SplitMix64 finalizer-style 64-bit mixer.
///
/// Bit-for-bit identical to `python/compile/kernels/hashmix.py`; the
/// cross-language agreement is asserted by `rust/tests/runtime_artifacts.rs`
/// and by `test_mix64_known_vectors` below.
#[inline]
pub fn mix64(mut x: u64) -> u64 {
    x ^= x >> 33;
    x = x.wrapping_mul(0xFF51_AFD7_ED55_8CCD);
    x ^= x >> 33;
    x = x.wrapping_mul(0xC4CE_B9FE_1A85_EC53);
    x ^= x >> 33;
    x
}

/// SplitMix64: stateful seeder (Vigna). Used to derive per-thread seeds.
#[derive(Clone, Debug)]
pub struct SplitMix64 {
    state: u64,
}

impl SplitMix64 {
    pub fn new(seed: u64) -> Self {
        Self { state: seed }
    }

    #[inline]
    pub fn next(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E37_79B9_7F4A_7C15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
        z ^ (z >> 31)
    }
}

/// xoshiro256** 1.0 (Blackman & Vigna) — the workload stream generator.
#[derive(Clone, Debug)]
pub struct Xoshiro256 {
    s: [u64; 4],
}

impl Xoshiro256 {
    /// Seed via SplitMix64 per the reference implementation's guidance.
    pub fn seeded(seed: u64) -> Self {
        let mut sm = SplitMix64::new(seed);
        Self {
            s: [sm.next(), sm.next(), sm.next(), sm.next()],
        }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let result = self.s[1]
            .wrapping_mul(5)
            .rotate_left(7)
            .wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    #[inline]
    pub fn next_u32(&mut self) -> u32 {
        (self.next_u64() >> 32) as u32
    }

    /// Uniform f64 in [0, 1).
    #[inline]
    pub fn next_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform usize in [0, bound) via Lemire's multiply-shift.
    #[inline]
    pub fn next_below(&mut self, bound: usize) -> usize {
        ((self.next_u64() as u128 * bound as u128) >> 64) as usize
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn test_mix64_known_vectors() {
        // Shared with python/tests/test_hashmix.py::test_known_vectors.
        assert_eq!(mix64(0), 0);
        assert_eq!(mix64(1), 0xB456_BCFC_34C2_CB2C);
        assert_eq!(mix64(0xDEAD_BEEF), 0xD24B_D59F_862A_1DAC);
    }

    #[test]
    fn test_mix64_injective_sample() {
        let mut seen = std::collections::HashSet::new();
        for x in 0..100_000u64 {
            assert!(seen.insert(mix64(x)));
        }
    }

    #[test]
    fn test_splitmix_deterministic() {
        let mut a = SplitMix64::new(42);
        let mut b = SplitMix64::new(42);
        for _ in 0..100 {
            assert_eq!(a.next(), b.next());
        }
    }

    #[test]
    fn test_xoshiro_deterministic_and_distinct_streams() {
        let mut a = Xoshiro256::seeded(1);
        let mut b = Xoshiro256::seeded(1);
        let mut c = Xoshiro256::seeded(2);
        let mut same = 0;
        for _ in 0..64 {
            let (x, y, z) = (a.next_u64(), b.next_u64(), c.next_u64());
            assert_eq!(x, y);
            if x == z {
                same += 1;
            }
        }
        assert!(same < 2);
    }

    #[test]
    fn test_next_below_bounds() {
        let mut r = Xoshiro256::seeded(7);
        for bound in [1usize, 2, 3, 10, 1000] {
            for _ in 0..1000 {
                assert!(r.next_below(bound) < bound);
            }
        }
    }

    #[test]
    fn test_next_f64_range_and_mean() {
        let mut r = Xoshiro256::seeded(11);
        let mut sum = 0.0;
        for _ in 0..10_000 {
            let v = r.next_f64();
            assert!((0.0..1.0).contains(&v));
            sum += v;
        }
        let mean = sum / 10_000.0;
        assert!((mean - 0.5).abs() < 0.02, "mean={mean}");
    }
}
