//! Contention-adaptive truncated-exponential backoff for CAS retry
//! loops, after Dice, Hendler & Mirsky, *Lightweight Contention
//! Management for Efficient Compare-and-Swap Operations*.
//!
//! A failed CAS means another thread just wrote the same cache line;
//! immediately retrying re-acquires the line in exclusive mode and
//! steals it from whoever is about to make progress — under p-thread
//! contention, bare retry loops collapse to coherence-traffic throughput.
//! Backing off for a bounded, exponentially growing window lets the
//! winner's successor complete before the line bounces.
//!
//! The Dice et al. refinement kept here is the *constant per-thread
//! state*: each thread remembers how much backoff its recent operations
//! needed ([`Backoff::adaptive`]) and starts the next operation there,
//! so a thread on a contended object does not re-learn the contention
//! level from zero on every call, and a thread on a quiet object decays
//! back to zero-cost fast paths.
//!
//! The escalation ladder is crossbeam-shaped: spin `2^step` iterations
//! while `step <= SPIN_LIMIT`, then `yield_now` (so oversubscribed runs
//! — the paper's §5.1 pathology — cannot livelock behind a descheduled
//! winner).
//!
//! [`set_enabled`] is a process-global kill-switch used by
//! `repro ablate --panel ordering` to measure the fenced vs.
//! fenced+backoff variants in one binary.  Disabled, [`Backoff::snooze`]
//! degrades to the seed's behavior: a bare `spin_loop` with a
//! scheduler-quantum yield safety valve.

use std::cell::Cell;
use std::sync::atomic::{AtomicBool, Ordering};

/// Maximum spin exponent: a single snooze spins at most `2^SPIN_LIMIT`
/// (= 64) `spin_loop` hints before escalating to yields.
pub const SPIN_LIMIT: u32 = 6;
/// Ladder cap: `step` saturates here; every snooze at or beyond
/// `SPIN_LIMIT` yields the CPU.
pub const YIELD_LIMIT: u32 = 10;

/// Seed-equivalent safety valve for the disabled path: bare spins per
/// yield (≈ a scheduler quantum, matching the seed's spin constants).
const DISABLED_SPINS_PER_YIELD: u32 = 1 << 20;

/// Process-global backoff switch (`true` by default).
static ENABLED: AtomicBool = AtomicBool::new(true);

/// Enable/disable backoff process-wide (ablation harness only; not a
/// synchronization point — readers sample it once per operation).
pub fn set_enabled(on: bool) {
    ENABLED.store(on, Ordering::Relaxed);
}

/// Whether backoff is currently enabled.
pub fn enabled() -> bool {
    ENABLED.load(Ordering::Relaxed)
}

thread_local! {
    /// Dice et al.'s constant per-thread contention state: the backoff
    /// level recent operations on this thread settled at.
    static LEARNED: Cell<u32> = const { Cell::new(0) };
}

/// Back off through a lazily-created adaptive [`Backoff`]: hot paths
/// keep an `Option<Backoff>` that stays `None` (zero TLS traffic) until
/// the first failed attempt.
#[inline]
pub fn snooze_lazy(slot: &mut Option<Backoff>) {
    slot.get_or_insert_with(Backoff::adaptive).snooze();
}

/// Per-operation backoff state. Create one outside the retry loop,
/// [`snooze`](Backoff::snooze) on every failed attempt (or keep an
/// `Option` and use [`snooze_lazy`] so the uncontended path pays
/// nothing).
pub struct Backoff {
    /// Current ladder position (spin exponent, then yield band).
    step: u32,
    /// Failed attempts this operation (0 ⇒ the op was uncontended).
    fails: u32,
    /// Whether this instance writes back to the thread's learned level.
    adaptive: bool,
    enabled: bool,
    /// Disabled-path spin counter (seed-equivalent quantum yielding).
    raw_spins: u32,
}

impl Backoff {
    /// Fresh non-adaptive backoff starting at the bottom of the ladder.
    #[inline]
    pub fn new() -> Self {
        Self {
            step: 0,
            fails: 0,
            adaptive: false,
            enabled: enabled(),
            raw_spins: 0,
        }
    }

    /// Contention-adaptive backoff: starts at the thread's learned
    /// level and writes the level it settles at back on drop
    /// (escalating on contention, halving when uncontended).
    #[inline]
    pub fn adaptive() -> Self {
        let start = LEARNED.with(|l| l.get());
        Self {
            step: start,
            fails: 0,
            adaptive: true,
            enabled: enabled(),
            raw_spins: 0,
        }
    }

    /// Back off once: spin `2^step` hints (escalating), then yield once
    /// the ladder passes [`SPIN_LIMIT`]. Call after each failed attempt.
    #[inline]
    pub fn snooze(&mut self) {
        self.fails = self.fails.saturating_add(1);
        if !self.enabled {
            // Seed behavior: bare spin with a quantum-sized yield valve.
            self.raw_spins += 1;
            if self.raw_spins >= DISABLED_SPINS_PER_YIELD {
                self.raw_spins = 0;
                std::thread::yield_now();
            } else {
                std::hint::spin_loop();
            }
            return;
        }
        if self.step <= SPIN_LIMIT {
            for _ in 0..1u32 << self.step {
                std::hint::spin_loop();
            }
        } else {
            // The spin→yield escalation the oversubscription figures care
            // about: each bump is one ceded scheduler quantum.
            crate::counter!(BackoffYield);
            std::thread::yield_now();
        }
        if self.step < YIELD_LIMIT {
            self.step += 1;
        }
    }

    /// Whether the ladder has escalated past pure spinning (callers that
    /// must not yield — e.g. wait-free paths — can switch strategy).
    #[inline]
    pub fn is_yielding(&self) -> bool {
        self.enabled && self.step > SPIN_LIMIT
    }
}

impl Default for Backoff {
    fn default() -> Self {
        Self::new()
    }
}

impl Drop for Backoff {
    fn drop(&mut self) {
        if !self.adaptive || !self.enabled {
            return;
        }
        // Dice-style adaptation: an uncontended op halves the learned
        // level (decay toward the free fast path); a contended op moves
        // it halfway to the level this op needed. try_with: a guard
        // dropped during TLS teardown just skips the write-back.
        let _ = LEARNED.try_with(|l| {
            let old = l.get();
            let new = if self.fails == 0 {
                old / 2
            } else {
                ((old + self.step) / 2).min(YIELD_LIMIT)
            };
            l.set(new);
        });
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn learned() -> u32 {
        LEARNED.with(|l| l.get())
    }

    /// A Backoff with an explicit enabled flag, independent of the
    /// process-global switch (which parallel ablation tests may toggle).
    fn forced(enabled: bool) -> Backoff {
        Backoff {
            step: 0,
            fails: 0,
            adaptive: false,
            enabled,
            raw_spins: 0,
        }
    }

    #[test]
    fn test_snooze_escalates_and_caps() {
        let mut b = forced(true);
        for _ in 0..(YIELD_LIMIT + 5) {
            b.snooze();
        }
        assert_eq!(b.step, YIELD_LIMIT);
        assert!(b.is_yielding());
    }

    #[test]
    fn test_adaptive_learns_and_decays() {
        // TLS is per-thread and the harness runs each test on its own
        // thread, so this state is isolated; force `enabled` so a
        // parallel ablation toggling the global switch cannot race us.
        LEARNED.with(|l| l.set(0));
        {
            let mut b = Backoff::adaptive();
            b.enabled = true;
            for _ in 0..8 {
                b.snooze();
            }
        }
        let after_contended = learned();
        assert!(after_contended > 0, "contention must raise the level");
        // Uncontended ops decay it back down.
        for _ in 0..10 {
            let mut b = Backoff::adaptive();
            b.enabled = true;
            drop(b);
        }
        assert_eq!(learned(), 0);
    }

    #[test]
    fn test_disabled_backoff_still_makes_progress() {
        let mut b = forced(false);
        for _ in 0..1000 {
            b.snooze();
        }
        assert_eq!(b.step, 0, "disabled backoff must not escalate");
    }
}
