//! Mini property-testing harness (offline stand-in for proptest).
//!
//! `forall` runs a property over `cases` pseudo-random inputs from a
//! deterministic seed; on failure it re-runs a crude shrink loop (halving
//! integer magnitudes) and reports the smallest failing input it found
//! plus the seed to reproduce.

use crate::util::rng::Xoshiro256;

/// A generated value plus a shrink iterator (smaller candidates).
pub trait Arbitrary: Sized + Clone + std::fmt::Debug {
    fn generate(rng: &mut Xoshiro256) -> Self;
    fn shrink(&self) -> Vec<Self>;
}

impl Arbitrary for u64 {
    fn generate(rng: &mut Xoshiro256) -> Self {
        // Bias towards small values and bit patterns near powers of two —
        // the interesting cases for tagged pointers / version arithmetic.
        match rng.next_below(4) {
            0 => rng.next_below(16) as u64,
            1 => 1u64 << rng.next_below(64),
            2 => (1u64 << rng.next_below(64)).wrapping_sub(1),
            _ => rng.next_u64(),
        }
    }

    fn shrink(&self) -> Vec<Self> {
        let mut out = Vec::new();
        if *self > 0 {
            out.push(self / 2);
            out.push(self - 1);
        }
        out
    }
}

impl Arbitrary for usize {
    fn generate(rng: &mut Xoshiro256) -> Self {
        u64::generate(rng) as usize
    }
    fn shrink(&self) -> Vec<Self> {
        (*self as u64).shrink().into_iter().map(|v| v as usize).collect()
    }
}

impl<const K: usize> Arbitrary for [u64; K] {
    fn generate(rng: &mut Xoshiro256) -> Self {
        std::array::from_fn(|_| u64::generate(rng))
    }
    fn shrink(&self) -> Vec<Self> {
        let mut out = Vec::new();
        for i in 0..K {
            for smaller in self[i].shrink() {
                let mut c = *self;
                c[i] = smaller;
                out.push(c);
            }
        }
        out
    }
}

impl<A: Arbitrary, B: Arbitrary> Arbitrary for (A, B) {
    fn generate(rng: &mut Xoshiro256) -> Self {
        (A::generate(rng), B::generate(rng))
    }
    fn shrink(&self) -> Vec<Self> {
        let mut out: Vec<Self> = self
            .0
            .shrink()
            .into_iter()
            .map(|a| (a, self.1.clone()))
            .collect();
        out.extend(self.1.shrink().into_iter().map(|b| (self.0.clone(), b)));
        out
    }
}

/// Check `prop` over `cases` generated inputs; panic with the minimal
/// found counterexample on failure.
pub fn forall<T: Arbitrary, F: Fn(&T) -> bool>(seed: u64, cases: usize, prop: F) {
    let mut rng = Xoshiro256::seeded(seed);
    for case in 0..cases {
        let input = T::generate(&mut rng);
        if !prop(&input) {
            let minimal = shrink_loop(input, &prop);
            panic!(
                "property failed (seed={seed}, case={case}); minimal counterexample: {minimal:?}"
            );
        }
    }
}

fn shrink_loop<T: Arbitrary, F: Fn(&T) -> bool>(mut failing: T, prop: &F) -> T {
    'outer: for _ in 0..64 {
        for cand in failing.shrink() {
            if !prop(&cand) {
                failing = cand;
                continue 'outer;
            }
        }
        break;
    }
    failing
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn test_forall_passes_trivial() {
        forall::<u64, _>(1, 200, |_| true);
        forall::<(u64, u64), _>(2, 200, |(a, b)| a.wrapping_add(*b) == b.wrapping_add(*a));
    }

    #[test]
    #[should_panic(expected = "minimal counterexample")]
    fn test_forall_finds_counterexample() {
        forall::<u64, _>(3, 1000, |x| *x < 1 << 20);
    }

    #[test]
    fn test_shrink_minimizes() {
        // Failing property: x >= 10. Shrinker should land near 10.
        let min = shrink_loop(1_000_000u64, &|x: &u64| *x < 10);
        assert_eq!(min, 10);
    }

    #[test]
    fn test_array_arbitrary_roundtrip() {
        let mut rng = Xoshiro256::seeded(9);
        for _ in 0..50 {
            let v = <[u64; 4]>::generate(&mut rng);
            for s in v.shrink() {
                assert_ne!(s, v);
            }
        }
    }
}
