//! `CachePadded<T>` — pad-and-align a value to its own cache-line pair.
//!
//! Stand-in for `crossbeam_utils::CachePadded` (the crate set is offline
//! — DESIGN.md §Substitutions). 128-byte alignment covers the adjacent-
//! line ("spatial") prefetcher on modern x86, which otherwise couples
//! logically independent atomics two lines apart — the false-sharing
//! pathology the paper's §5.1 layout ("elements aligned to cache-line
//! boundaries") exists to avoid.

/// Pads and aligns `T` so distinct values never share a cache-line pair.
#[derive(Debug, Default, Clone, Copy, PartialEq, Eq)]
#[repr(align(128))]
pub struct CachePadded<T> {
    value: T,
}

impl<T> CachePadded<T> {
    /// Wrap `value` in its own aligned slot.
    pub const fn new(value: T) -> Self {
        Self { value }
    }

    /// Unwrap, consuming the padding.
    pub fn into_inner(self) -> T {
        self.value
    }
}

impl<T> std::ops::Deref for CachePadded<T> {
    type Target = T;

    #[inline]
    fn deref(&self) -> &T {
        &self.value
    }
}

impl<T> std::ops::DerefMut for CachePadded<T> {
    #[inline]
    fn deref_mut(&mut self) -> &mut T {
        &mut self.value
    }
}

impl<T> From<T> for CachePadded<T> {
    fn from(value: T) -> Self {
        Self::new(value)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn test_alignment_and_size() {
        assert_eq!(std::mem::align_of::<CachePadded<u64>>(), 128);
        assert!(std::mem::size_of::<CachePadded<u64>>() >= 128);
        // Large values keep their own size (rounded to the alignment).
        assert_eq!(std::mem::size_of::<CachePadded<[u64; 32]>>(), 256);
    }

    #[test]
    fn test_deref_roundtrip() {
        let mut p = CachePadded::new(41u64);
        *p += 1;
        assert_eq!(*p, 42);
        assert_eq!(p.into_inner(), 42);
    }
}
