//! The crate's memory-ordering policy — the "ordering diet" switch.
//!
//! The seed paid `SeqCst` on every load/store/CAS in all eight big-atomic
//! backends. Schweizer et al. ("Evaluating the Cost of Atomic Operations
//! on Modern Architectures") measure why that hurts: on weakly-ordered
//! hardware every `SeqCst` op is a full barrier, and the seqlock-style
//! protocols here already carry their own validation, so most of those
//! barriers buy nothing.  This module centralizes the diet:
//!
//! * [`Fenced`] — the default policy: Acquire/Release on version words
//!   and node flags, Relaxed where the version protocol re-validates,
//!   and explicit `fence(SeqCst)` **only** at the four store-load points
//!   that need it (hazard announce→revalidate and the retire-side scan
//!   — see `smr::hazard`; epoch pin→validate-global and the
//!   advance-side announcement scan — see `smr::epoch`).  Every demoted
//!   site in the crate carries an `// Ordering:` comment naming the
//!   happens-before edge it preserves.
//! * [`SeqCstEverywhere`] — the audit policy: every constant collapses
//!   back to `SeqCst` (the seed's behavior), so the full test suite can
//!   run against blanket sequential consistency and any diet bug shows
//!   up as a fenced-only failure.
//!
//! [`DefaultPolicy`] selects between them at compile time via the
//! `seqcst_audit` cargo feature (`cargo test --features seqcst_audit`
//! restores the seed's blanket `SeqCst`).  Backends that matter for the
//! ordering ablation ([`crate::atomics::SeqLock`],
//! [`crate::atomics::CachedWaitFree`], [`crate::atomics::CachedMemEff`])
//! and the epoch reclamation scheme ([`crate::smr::Epoch`]) additionally
//! take the policy as a defaulted type parameter, so `repro ablate
//! --panel ordering` / `--panel smr` can compare both policies inside
//! one (fenced) binary.
//!
//! The four `fence(SeqCst)` points are deliberately **not** part of the
//! policy: under the diet the announce *store* is `Relaxed`, and only
//! the fence makes it totally ordered against the reclaimer's scan —
//! remove it and the demoted protocol is unsound. (Under the audit
//! policy the all-`SeqCst` accesses alone would also be correct, as in
//! the seed; the fences stay in both builds so the two variants run
//! one protocol shape and differ only in per-access strength.)

use std::sync::atomic::Ordering;

/// Compile-time selection of the memory orderings used at every demoted
/// site in the synchronization core.
///
/// Implementors are zero-sized tags; all methods are `#[inline]` consts
/// so the policy vanishes at codegen.
pub trait OrderingPolicy: Copy + Clone + Send + Sync + Default + 'static {
    /// Policy name for reports (`ablation_ordering` rows).
    const NAME: &'static str;
    /// Loads that must observe a releasing writer (version words,
    /// published pointers).
    const ACQUIRE: Ordering;
    /// Stores/RMW-success that publish prior writes (unlock stores,
    /// install CASes).
    const RELEASE: Ordering;
    /// Both-ways RMW (linearization-point CASes whose old value is
    /// dereferenced).
    const ACQREL: Ordering;
    /// Accesses the surrounding version protocol already validates
    /// (cache words, re-check loads, owner-private flags).
    const RELAXED: Ordering;
    /// Fence ordering for the reader-side load-load edge of the seqlock
    /// protocol (data reads before the version re-check).
    const FENCE_ACQUIRE: Ordering;
    /// Fence ordering for the writer-side store-store edge of the
    /// seqlock protocol (odd version before data writes).
    const FENCE_RELEASE: Ordering;
}

/// The ordering diet (default): weakest sound ordering per site, plus
/// the four mandatory `SeqCst` fences in `smr` (hazard + epoch pairs).
#[derive(Copy, Clone, Default, Debug)]
pub struct Fenced;

impl OrderingPolicy for Fenced {
    const NAME: &'static str = "fenced";
    const ACQUIRE: Ordering = Ordering::Acquire;
    const RELEASE: Ordering = Ordering::Release;
    const ACQREL: Ordering = Ordering::AcqRel;
    const RELAXED: Ordering = Ordering::Relaxed;
    const FENCE_ACQUIRE: Ordering = Ordering::Acquire;
    const FENCE_RELEASE: Ordering = Ordering::Release;
}

/// The audit policy: the seed's blanket `SeqCst` at every site.
///
/// Note CAS *failure* orderings also map here: `SeqCst` is a legal
/// failure ordering, so the audit build is strictly stronger than the
/// diet at every site.
#[derive(Copy, Clone, Default, Debug)]
pub struct SeqCstEverywhere;

impl OrderingPolicy for SeqCstEverywhere {
    const NAME: &'static str = "seqcst";
    const ACQUIRE: Ordering = Ordering::SeqCst;
    const RELEASE: Ordering = Ordering::SeqCst;
    const ACQREL: Ordering = Ordering::SeqCst;
    const RELAXED: Ordering = Ordering::SeqCst;
    const FENCE_ACQUIRE: Ordering = Ordering::SeqCst;
    const FENCE_RELEASE: Ordering = Ordering::SeqCst;
}

/// The crate-wide policy: [`Fenced`] normally, [`SeqCstEverywhere`]
/// under `--features seqcst_audit`.
#[cfg(not(feature = "seqcst_audit"))]
pub type DefaultPolicy = Fenced;
/// The crate-wide policy: [`Fenced`] normally, [`SeqCstEverywhere`]
/// under `--features seqcst_audit`.
#[cfg(feature = "seqcst_audit")]
pub type DefaultPolicy = SeqCstEverywhere;

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn test_policies_are_legal_failure_orderings() {
        // CAS failure orderings may be Relaxed/Acquire/SeqCst but never
        // Release/AcqRel; the diet uses RELAXED and ACQUIRE on failure
        // paths, which must stay legal under both policies.
        for ord in [
            Fenced::RELAXED,
            Fenced::ACQUIRE,
            SeqCstEverywhere::RELAXED,
            SeqCstEverywhere::ACQUIRE,
        ] {
            assert!(!matches!(ord, Ordering::Release | Ordering::AcqRel));
        }
    }

    #[test]
    fn test_audit_policy_is_blanket_seqcst() {
        assert_eq!(SeqCstEverywhere::ACQUIRE, Ordering::SeqCst);
        assert_eq!(SeqCstEverywhere::RELEASE, Ordering::SeqCst);
        assert_eq!(SeqCstEverywhere::RELAXED, Ordering::SeqCst);
        assert_eq!(SeqCstEverywhere::FENCE_ACQUIRE, Ordering::SeqCst);
    }

    #[test]
    fn test_fences_never_relaxed() {
        // `fence(Relaxed)` panics at runtime; the policy constants must
        // never map a fence there.
        for ord in [
            Fenced::FENCE_ACQUIRE,
            Fenced::FENCE_RELEASE,
            SeqCstEverywhere::FENCE_ACQUIRE,
            SeqCstEverywhere::FENCE_RELEASE,
        ] {
            assert!(!matches!(ord, Ordering::Relaxed));
        }
    }
}
