//! Applications of big atomics (paper §2, "Applications of big atomics").
//!
//! The paper argues big atomics simplify a family of classic concurrent
//! constructions that otherwise need clever packing or indirection.
//! This module implements two of them on top of [`crate::atomics`]:
//!
//! * [`llsc`] — load-linked / store-conditional from a (value, tag)
//!   2-field big atomic (cf. [39]'s 4-field construction; the tag makes
//!   SC's "no intervening store" check a plain value compare);
//! * [`stats`] — a multi-field statistics cell (count, sum, min, max)
//!   updated atomically in one CAS — the kind of 4-field record that is
//!   impossible with hardware atomics and painful with packing.
//!
//! A third application, concurrent union-find with (parent, rank) in one
//! atomic (cf. [30]), lives in `examples/union_find.rs`.

pub mod llsc;
pub mod stats;
