//! Load-linked / store-conditional over a big atomic (paper §2).
//!
//! LL returns the value plus a *link tag*; SC(link, new) is exactly one
//! [`BigAtomic::compare_exchange`] — the monotonically increasing tag
//! rules out ABA entirely, which is the whole difficulty of
//! LL/SC-from-CAS constructions on single words ([36], [10], and the
//! Blelloch–Wei LL/SC-from-CAS construction).  A failed SC returns the
//! *witnessed* current cell, so [`LlSc::fetch_update`] — the canonical
//! LL/SC retry loop — never re-loads between attempts.
//!
//! Generic over the big-atomic implementation, so the paper's claim
//! ("LL/SC trivially from big atomics") is testable against every
//! backend.

use crate::atomics::BigAtomic;

/// (value, tag) cell. The tag increments on every successful SC.
#[repr(C, align(8))]
#[derive(Copy, Clone, PartialEq, Eq, Debug, Default)]
pub struct Tagged {
    pub value: u64,
    pub tag: u64,
}

crate::impl_atomic_value!(Tagged);

/// A link witness returned by [`LlSc::load_linked`].
#[derive(Copy, Clone, Debug)]
pub struct Link {
    snapshot: Tagged,
}

impl Link {
    pub fn value(&self) -> u64 {
        self.snapshot.value
    }
}

/// Load-linked / store-conditional object.
pub struct LlSc<A: BigAtomic<Tagged>> {
    cell: A,
}

impl<A: BigAtomic<Tagged>> LlSc<A> {
    pub fn new(value: u64) -> Self {
        Self {
            cell: A::new(Tagged { value, tag: 0 }),
        }
    }

    /// Load-linked: read the value and take a link on it.
    pub fn load_linked(&self) -> Link {
        Link {
            snapshot: self.cell.load(),
        }
    }

    /// Plain read (does not link).
    pub fn load(&self) -> u64 {
        self.cell.load().value
    }

    /// Store-conditional: succeeds iff no successful SC happened since
    /// `link` was taken — one witnessing `compare_exchange`.
    pub fn store_conditional(&self, link: Link, new: u64) -> bool {
        self.try_store_conditional(link, new).is_ok()
    }

    /// Store-conditional returning the witnessed current cell as a fresh
    /// [`Link`] on failure, so retry loops skip the re-LL.
    pub fn try_store_conditional(&self, link: Link, new: u64) -> Result<(), Link> {
        match self.cell.compare_exchange(
            link.snapshot,
            Tagged {
                value: new,
                tag: link.snapshot.tag + 1,
            },
        ) {
            Ok(_) => Ok(()),
            Err(snapshot) => Err(Link { snapshot }),
        }
    }

    /// The canonical LL/SC loop, packaged: apply `f` to the current
    /// value until an SC lands; returns the previous value. Failed SCs
    /// feed their witness straight into the next attempt.
    pub fn fetch_update<F: FnMut(u64) -> u64>(&self, mut f: F) -> u64 {
        let mut link = self.load_linked();
        loop {
            match self.try_store_conditional(link, f(link.value())) {
                Ok(()) => return link.value(),
                Err(fresh) => link = fresh,
            }
        }
    }

    /// Validate: is the link still current?
    pub fn validate(&self, link: Link) -> bool {
        self.cell.load().tag == link.snapshot.tag
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::atomics::{CachedMemEff, CachedWaitFree, SeqLock};
    use std::sync::Arc;

    fn basic<A: BigAtomic<Tagged>>() {
        let c: LlSc<A> = LlSc::new(5);
        let l = c.load_linked();
        assert_eq!(l.value(), 5);
        assert!(c.validate(l));
        assert!(c.store_conditional(l, 6));
        assert!(!c.validate(l), "link must break after a successful SC");
        assert!(!c.store_conditional(l, 7), "stale link must fail");
        assert_eq!(c.load(), 6);
    }

    #[test]
    fn test_llsc_basic_all_backends() {
        basic::<SeqLock<Tagged>>();
        basic::<CachedWaitFree<Tagged>>();
        basic::<CachedMemEff<Tagged>>();
    }

    #[test]
    fn test_llsc_same_value_sc_still_breaks_link() {
        // SC writing the SAME value must still invalidate other links
        // (the tag bump) — the subtlety plain CAS gets wrong (ABA).
        let c: LlSc<CachedMemEff<Tagged>> = LlSc::new(1);
        let link_a = c.load_linked();
        let link_b = c.load_linked();
        assert!(c.store_conditional(link_a, 1)); // A:  1 -> 1
        assert!(
            !c.store_conditional(link_b, 2),
            "B's link predates A's SC and must fail even though the value matches"
        );
    }

    #[test]
    fn test_llsc_fetch_increment_exact() {
        // The canonical LL/SC use: a contended fetch-and-increment,
        // driven by the packaged witness-fed loop.
        let c: Arc<LlSc<CachedMemEff<Tagged>>> = Arc::new(LlSc::new(0));
        let threads = 4;
        let per = 5_000u64;
        let handles: Vec<_> = (0..threads)
            .map(|_| {
                let c = Arc::clone(&c);
                std::thread::spawn(move || {
                    for _ in 0..per {
                        let _ = c.fetch_update(|v| v + 1);
                    }
                })
            })
            .collect();
        for h in handles {
            h.join().unwrap();
        }
        assert_eq!(c.load(), threads * per);
    }

    #[test]
    fn test_try_store_conditional_witness_is_fresh() {
        let c: LlSc<SeqLock<Tagged>> = LlSc::new(10);
        let stale = c.load_linked();
        assert!(c.store_conditional(stale, 11));
        // A stale SC fails but hands back a usable fresh link.
        let fresh = c.try_store_conditional(stale, 99).unwrap_err();
        assert_eq!(fresh.value(), 11);
        assert!(c.validate(fresh));
        assert!(c.store_conditional(fresh, 12));
        assert_eq!(c.load(), 12);
    }
}
