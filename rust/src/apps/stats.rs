//! An atomically-updated statistics cell: (count, sum, min, max) in one
//! 4-word big atomic — the "handful of fields updated together" shape
//! the paper's §2 applications all share.
//!
//! Without big atomics this needs a lock or four separate atomics whose
//! combination can be observed torn (count updated, max not yet);
//! with one CAS the snapshot any reader takes is always consistent:
//! `min <= sum/count <= max` holds at every instant.

use crate::atomics::BigAtomic;

/// The atomically-consistent record.
#[repr(C, align(8))]
#[derive(Copy, Clone, PartialEq, Eq, Debug)]
pub struct Snapshot {
    pub count: u64,
    pub sum: u64,
    pub min: u64,
    pub max: u64,
}

impl Default for Snapshot {
    fn default() -> Self {
        Self {
            count: 0,
            sum: 0,
            min: u64::MAX,
            max: 0,
        }
    }
}

crate::impl_atomic_value!(Snapshot);

impl Snapshot {
    pub fn mean(&self) -> Option<f64> {
        (self.count > 0).then(|| self.sum as f64 / self.count as f64)
    }
}

/// A concurrent statistics accumulator over any big-atomic backend.
pub struct StatsCell<A: BigAtomic<Snapshot>> {
    cell: A,
}

impl<A: BigAtomic<Snapshot>> Default for StatsCell<A> {
    fn default() -> Self {
        Self::new()
    }
}

impl<A: BigAtomic<Snapshot>> StatsCell<A> {
    pub fn new() -> Self {
        Self {
            cell: A::new(Snapshot::default()),
        }
    }

    /// Record one sample (lock-free if the backend is): one
    /// `fetch_update` — the whole load/modify/CAS retry loop, with
    /// failed attempts continuing from the witness instead of
    /// re-loading.
    pub fn record(&self, sample: u64) {
        let _ = self
            .cell
            .fetch_update(|cur| {
                Some(Snapshot {
                    count: cur.count + 1,
                    sum: cur.sum.wrapping_add(sample),
                    min: cur.min.min(sample),
                    max: cur.max.max(sample),
                })
            })
            .expect("unconditional update always lands");
    }

    /// A consistent snapshot of all four fields.
    pub fn snapshot(&self) -> Snapshot {
        self.cell.load()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::atomics::{CachedMemEff, SeqLock};
    use std::sync::Arc;

    #[test]
    fn test_single_thread_exact() {
        let s: StatsCell<SeqLock<Snapshot>> = StatsCell::new();
        for v in [5u64, 1, 9, 3] {
            s.record(v);
        }
        let snap = s.snapshot();
        assert_eq!(snap.count, 4);
        assert_eq!(snap.sum, 18);
        assert_eq!(snap.min, 1);
        assert_eq!(snap.max, 9);
        assert_eq!(snap.mean(), Some(4.5));
    }

    #[test]
    fn test_concurrent_consistent_snapshots() {
        let s: Arc<StatsCell<CachedMemEff<Snapshot>>> = Arc::new(StatsCell::new());
        let stop = Arc::new(std::sync::atomic::AtomicBool::new(false));
        // Readers: every snapshot must be internally consistent.
        let readers: Vec<_> = (0..2)
            .map(|_| {
                let s = Arc::clone(&s);
                let stop = Arc::clone(&stop);
                std::thread::spawn(move || {
                    while !stop.load(std::sync::atomic::Ordering::Relaxed) {
                        let snap = s.snapshot();
                        if snap.count > 0 {
                            let mean = snap.mean().unwrap();
                            assert!(
                                snap.min as f64 <= mean && mean <= snap.max as f64,
                                "torn stats snapshot: {snap:?}"
                            );
                            assert!(snap.sum >= snap.max);
                        }
                    }
                })
            })
            .collect();
        let writers: Vec<_> = (0..3)
            .map(|t| {
                let s = Arc::clone(&s);
                std::thread::spawn(move || {
                    for i in 0..5_000u64 {
                        s.record(10 + ((i * 7 + t) % 100));
                    }
                })
            })
            .collect();
        for w in writers {
            w.join().unwrap();
        }
        stop.store(true, std::sync::atomic::Ordering::SeqCst);
        for r in readers {
            r.join().unwrap();
        }
        let snap = s.snapshot();
        assert_eq!(snap.count, 15_000);
        assert!(snap.min >= 10 && snap.max <= 109);
    }
}
