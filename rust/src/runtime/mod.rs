//! PJRT runtime: load and execute the AOT-compiled JAX/Pallas artifacts.
//!
//! `make artifacts` runs `python/compile/aot.py` **once** (build time) to
//! lower the L2 workload/stats models — which call the L1 Pallas kernels
//! — to HLO text.  This module loads that text, compiles it on the PJRT
//! CPU client, and executes it from Rust.  Python never runs on any
//! benchmark or request path.
//!
//! HLO *text* is the interchange format: jax ≥ 0.5 emits protos with
//! 64-bit instruction ids that xla_extension 0.5.1 rejects; the text
//! parser reassigns ids (see /opt/xla-example/README.md).
//!
//! ## The `pjrt` feature
//!
//! The PJRT client comes from the offline `xla` crate, which the
//! default (dependency-free) build cannot resolve. Without
//! `--features pjrt` this module compiles to stubs: [`Runtime::new`]
//! returns an error, and every caller falls back to the pure-Rust
//! workload generator — `cargo test` / `cargo bench` stay green with no
//! artifacts and no XLA.

pub mod workload_gen;

use std::collections::HashMap;
use std::path::{Path, PathBuf};

use crate::util::error::{Context, Result};

/// Parsed `artifacts/manifest.txt` — the shape contract with aot.py.
#[derive(Clone, Debug)]
pub struct Manifest {
    pub batch: usize,
    pub n_cdf: usize,
    raw: HashMap<String, String>,
}

impl Manifest {
    pub fn load(dir: &Path) -> Result<Self> {
        let text = std::fs::read_to_string(dir.join("manifest.txt")).with_context(|| {
            format!("reading {}/manifest.txt (run `make artifacts`)", dir.display())
        })?;
        let mut raw = HashMap::new();
        for line in text.lines() {
            if let Some((k, v)) = line.split_once('=') {
                raw.insert(k.trim().to_string(), v.trim().to_string());
            }
        }
        let get_usize = |k: &str| -> Result<usize> {
            raw.get(k)
                .ok_or_else(|| crate::anyhow!("manifest missing key {k}"))?
                .split_whitespace()
                .next()
                .unwrap_or_default()
                .parse()
                .with_context(|| format!("manifest key {k}"))
        };
        Ok(Self {
            batch: get_usize("batch")?,
            n_cdf: get_usize("n_cdf")?,
            raw,
        })
    }

    pub fn get(&self, key: &str) -> Option<&str> {
        self.raw.get(key).map(|s| s.as_str())
    }
}

/// Default artifact directory: `$REPRO_ARTIFACTS` or `./artifacts`.
pub fn default_artifact_dir() -> PathBuf {
    std::env::var_os("REPRO_ARTIFACTS")
        .map(PathBuf::from)
        .unwrap_or_else(|| PathBuf::from("artifacts"))
}

#[derive(Copy, Clone, Debug)]
pub struct LatencySummary {
    pub mean: f32,
    pub p50: f32,
    pub p90: f32,
    pub p99: f32,
    pub max: f32,
}

impl std::fmt::Display for LatencySummary {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "mean={:.0}ns p50={:.0}ns p90={:.0}ns p99={:.0}ns max={:.0}ns",
            self.mean, self.p50, self.p90, self.p99, self.max
        )
    }
}

#[cfg(feature = "pjrt")]
mod pjrt_impl {
    use super::{LatencySummary, Manifest};
    use crate::util::error::{Context, Result};
    use std::path::PathBuf;

    /// A compiled HLO artifact on the PJRT CPU client.
    pub struct Executable {
        exe: xla::PjRtLoadedExecutable,
    }

    impl Executable {
        pub fn execute(&self, args: &[xla::Literal]) -> Result<xla::Literal> {
            let results = self.exe.execute::<xla::Literal>(args)?;
            Ok(results[0][0].to_literal_sync()?)
        }
    }

    /// The process-wide PJRT client plus the compiled artifacts.
    pub struct Runtime {
        client: xla::PjRtClient,
        dir: PathBuf,
        pub manifest: Manifest,
    }

    impl Runtime {
        /// Create a CPU PJRT client and read the manifest.
        pub fn new(dir: impl Into<PathBuf>) -> Result<Self> {
            let dir = dir.into();
            let manifest = Manifest::load(&dir)?;
            let client = xla::PjRtClient::cpu()?;
            Ok(Self {
                client,
                dir,
                manifest,
            })
        }

        pub fn platform(&self) -> String {
            self.client.platform_name()
        }

        /// Load + compile one `<name>.hlo.txt` artifact.
        pub fn load(&self, name: &str) -> Result<Executable> {
            let path = self.dir.join(format!("{name}.hlo.txt"));
            let proto = xla::HloModuleProto::from_text_file(
                path.to_str().ok_or_else(|| crate::anyhow!("bad path"))?,
            )
            .with_context(|| format!("parsing {}", path.display()))?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = self.client.compile(&comp)?;
            Ok(Executable { exe })
        }

        /// The stats model: f32[batch] latencies → [mean, p50, p90, p99, max].
        pub fn stats_engine(&self) -> Result<StatsEngine> {
            Ok(StatsEngine {
                exe: self.load("stats")?,
                batch: self.manifest.batch,
            })
        }
    }

    /// Latency summarizer backed by `stats.hlo.txt` (L2 `stats_model`).
    pub struct StatsEngine {
        exe: Executable,
        batch: usize,
    }

    impl StatsEngine {
        /// Summarize latencies (ns). Input is padded/truncated to the
        /// artifact's fixed batch by cycling samples (benchmarks collect
        /// ≥ batch samples anyway, so padding rarely triggers).
        pub fn summarize(&self, latencies_ns: &[f32]) -> Result<LatencySummary> {
            if latencies_ns.is_empty() {
                return Err(crate::anyhow!("no latency samples"));
            }
            let mut buf: Vec<f32> = Vec::with_capacity(self.batch);
            for i in 0..self.batch {
                buf.push(latencies_ns[i % latencies_ns.len()]);
            }
            let lit = xla::Literal::vec1(&buf);
            let out = self.exe.execute(&[lit])?.to_tuple1()?;
            let v = out.to_vec::<f32>()?;
            Ok(LatencySummary {
                mean: v[0],
                p50: v[1],
                p90: v[2],
                p99: v[3],
                max: v[4],
            })
        }
    }
}

#[cfg(feature = "pjrt")]
pub use pjrt_impl::{Executable, Runtime, StatsEngine};

#[cfg(not(feature = "pjrt"))]
mod pjrt_stub {
    use super::{LatencySummary, Manifest};
    use crate::util::error::Result;
    use std::path::PathBuf;

    fn unavailable<T>() -> Result<T> {
        Err(crate::anyhow!(
            "PJRT runtime not compiled in: rebuild with `--features pjrt` \
             (requires the offline `xla` crate — see DESIGN.md §Substitutions)"
        ))
    }

    /// Stub: the real type lives behind the `pjrt` feature.
    pub struct Executable;

    /// Stub runtime — [`Runtime::new`] always errors, so no instance
    /// (and none of the placeholder method bodies below) is reachable.
    pub struct Runtime {
        pub manifest: Manifest,
    }

    impl Runtime {
        pub fn new(_dir: impl Into<PathBuf>) -> Result<Self> {
            unavailable()
        }

        pub fn platform(&self) -> String {
            "unavailable".to_string()
        }

        pub fn load(&self, _name: &str) -> Result<Executable> {
            unavailable()
        }

        pub fn stats_engine(&self) -> Result<StatsEngine> {
            unavailable()
        }
    }

    /// Stub latency summarizer.
    pub struct StatsEngine;

    impl StatsEngine {
        pub fn summarize(&self, _latencies_ns: &[f32]) -> Result<LatencySummary> {
            unavailable()
        }
    }
}

#[cfg(not(feature = "pjrt"))]
pub use pjrt_stub::{Executable, Runtime, StatsEngine};

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn test_manifest_load_missing_dir_errors() {
        let err = Manifest::load(Path::new("/definitely/not/here")).unwrap_err();
        assert!(err.to_string().contains("manifest.txt"));
    }

    #[cfg(not(feature = "pjrt"))]
    #[test]
    fn test_stub_runtime_reports_feature() {
        let err = Runtime::new("artifacts").unwrap_err();
        assert!(err.to_string().contains("pjrt"), "{err}");
    }
}
