//! Operation-stream generation through the AOT workload artifact.
//!
//! One `workload.hlo.txt` execution maps a batch of uniform random words
//! to (Zipfian slot, op kind, mixed key) — the L1 Pallas kernels doing
//! the paper's §5 workload math.  The Rust side supplies the CDF table
//! and random bits, then applies the tail spread for n > N_CDF (see
//! `bench::workload`).  Bit-exact with `bench::workload::generate_rust`
//! (asserted by `rust/tests/runtime_artifacts.rs`).
//!
//! Like the rest of [`crate::runtime`], the executing engine needs the
//! `pjrt` feature; the default build gets a stub whose constructor
//! errors (and is unreachable anyway, since the stub `Runtime` cannot
//! be built).

#[cfg(feature = "pjrt")]
pub use real::WorkloadEngine;

#[cfg(feature = "pjrt")]
mod real {
    use crate::bench::workload::{GenOp, Op, WorkloadSpec, ZipfCdf, N_CDF};
    use crate::runtime::{Executable, Runtime};
    use crate::util::error::Result;
    use crate::util::rng::{mix64, Xoshiro256};

    /// Workload generator backed by the compiled L2 model.
    pub struct WorkloadEngine {
        exe: Executable,
        batch: usize,
    }

    impl WorkloadEngine {
        pub fn new(rt: &Runtime) -> Result<Self> {
            crate::ensure!(
                rt.manifest.n_cdf == N_CDF,
                "artifact CDF resolution {} != crate N_CDF {}",
                rt.manifest.n_cdf,
                N_CDF
            );
            Ok(Self {
                exe: rt.load("workload")?,
                batch: rt.manifest.batch,
            })
        }

        /// Artifact batch size (ops per execution).
        pub fn batch(&self) -> usize {
            self.batch
        }

        /// Execute the model over explicit random words (the cross-validation
        /// entry point). Returns (slots, op codes, keys) of length `batch`.
        pub fn run_raw(
            &self,
            bits: &[u32],
            op_bits: &[u32],
            cdf: &[f32],
            u_frac: f32,
        ) -> Result<(Vec<i32>, Vec<i32>, Vec<u64>)> {
            crate::ensure!(bits.len() == self.batch && op_bits.len() == self.batch);
            crate::ensure!(cdf.len() == N_CDF);
            let out = self.exe.execute(&[
                xla::Literal::vec1(bits),
                xla::Literal::vec1(op_bits),
                xla::Literal::vec1(cdf),
                xla::Literal::scalar(u_frac),
            ])?;
            let (idx, op, key) = out.to_tuple3()?;
            Ok((idx.to_vec()?, op.to_vec()?, key.to_vec()?))
        }

        /// Generate `count` ops for `spec`, drawing randomness exactly like
        /// `generate_rust` (same rng stream), batched through the artifact.
        pub fn generate(
            &self,
            spec: &WorkloadSpec,
            count: usize,
            thread_seed: u64,
        ) -> Result<Vec<GenOp>> {
            let cdf_table = ZipfCdf::new(spec.n, spec.theta);
            let mut rng = Xoshiro256::seeded(spec.seed ^ mix64(thread_seed.wrapping_add(1)));
            let mut out = Vec::with_capacity(count);
            let mut bits = vec![0u32; self.batch];
            let mut op_bits = vec![0u32; self.batch];
            let mut extras: Vec<u64> = vec![0; self.batch];
            while out.len() < count {
                // Interleaved draws matching generate_rust's per-op order:
                // (index bits, op bits[, tail extra]).
                for i in 0..self.batch {
                    bits[i] = rng.next_u32();
                    op_bits[i] = rng.next_u32();
                    if spec.n > N_CDF {
                        extras[i] = rng.next_u64();
                    }
                }
                let (slots, ops, keys) =
                    self.run_raw(&bits, &op_bits, cdf_table.cdf(), spec.u_frac())?;
                let take = (count - out.len()).min(self.batch);
                for i in 0..take {
                    let rank = cdf_table.spread(slots[i] as u32, extras[i]) as u32;
                    // The artifact's key is mix64(slot); after tail spreading
                    // the key must track the final rank.
                    let key = if spec.n > N_CDF {
                        mix64(rank as u64)
                    } else {
                        keys[i]
                    };
                    out.push(GenOp {
                        op: Op::from_code(ops[i]),
                        rank,
                        key,
                    });
                }
            }
            Ok(out)
        }
    }
}

#[cfg(not(feature = "pjrt"))]
pub use stub::WorkloadEngine;

#[cfg(not(feature = "pjrt"))]
mod stub {
    use crate::bench::workload::{GenOp, WorkloadSpec};
    use crate::runtime::Runtime;
    use crate::util::error::Result;

    /// Stub engine — unconstructible in practice (the stub [`Runtime`]
    /// cannot be built), present so `OpSource::Artifact` type-checks.
    pub struct WorkloadEngine;

    impl WorkloadEngine {
        pub fn new(_rt: &Runtime) -> Result<Self> {
            Err(crate::anyhow!(
                "PJRT workload engine not compiled in: rebuild with `--features pjrt`"
            ))
        }

        pub fn batch(&self) -> usize {
            0
        }

        pub fn generate(
            &self,
            _spec: &WorkloadSpec,
            _count: usize,
            _thread_seed: u64,
        ) -> Result<Vec<GenOp>> {
            Err(crate::anyhow!(
                "PJRT workload engine not compiled in: rebuild with `--features pjrt`"
            ))
        }
    }
}
