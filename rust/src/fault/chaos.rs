//! Chaos scenarios: seeded fault campaigns with machine-checked
//! survival criteria (`repro chaos`).
//!
//! Each scenario arms a named [`FaultPlan`], drives one subsystem
//! through the injected faults, and verifies the invariants that must
//! hold on *every* schedule:
//!
//! * [`kill_copier`] — kill resize copiers right after they claim a
//!   stripe / seal a bucket FROZEN; every confirmed insert must still
//!   be found after rivals take the copy over and the resize completes
//!   (linearizability across copier death).
//! * [`stall_drainer`] — stall a `ClaimQueue` drainer while it holds
//!   the claim word; the lease must let a rival take over, and every
//!   pushed item must be drained exactly once (no loss, no dup).
//! * [`kill_worker`] — kill a KV worker mid-batch; the supervisor must
//!   catch it, the conservation ledger must balance with the abandoned
//!   batch counted, and the run must finish.
//! * [`kill_allocator`] — kill a thread at the top of the page pool's
//!   claim path during chain-heavy churn; the pool must stay live (no
//!   lock or page leaked by the dying claimant) and the table exact.
//! * [`kill_copier_shrink`] — the kill-copier windows armed while the
//!   migration runs in the *shrink* direction: a drained table's
//!   maintenance passes die at stripe claims and FROZEN seals, yet the
//!   table must converge below its peak with every kept key exact.
//! * [`kill_migrator`] — kill the background migrator mid-copy and at
//!   the DONE publish; its per-pass supervision must absorb the deaths
//!   and a later pass must still drive the table to convergence.
//! * [`jitter`] — no kills, broad delays/yields/spurious CAS failures
//!   over a full KV run; pure schedule-shaking, same ledger checks.
//!
//! The scenarios also run (and their invariants also hold) **without**
//! `--features fault` — the failpoints are compiled out, so nothing
//! fires and the checks degenerate to a plain stress pass. The CLI
//! treats `injected == 0` under the feature as a failure (the harness
//! itself would be broken); without the feature it only warns.
//!
//! A process-global mutex serializes scenarios: the armed plan is
//! process-wide, so two scenarios (or a scenario and a stray test in
//! the same binary) must not overlap. Keep chaos tests in their own
//! integration binary for the same reason.

use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Mutex, MutexGuard, PoisonError};
use std::time::{Duration, Instant};

use crate::atomics::CachedMemEff;
use crate::coordinator::kv_service::{self, IngressMode, KvConfig};
use crate::hash::{CacheHash, ConcurrentMap, LinkVal};
use crate::ingress::ClaimQueue;
use crate::smr::pool;
use crate::util::error::Result;
use crate::util::rng::mix64;

use super::{clear_plan, injected, FaultPlan};

/// Outcome of one scenario.
#[derive(Debug)]
pub struct ChaosReport {
    pub scenario: &'static str,
    pub seed: u64,
    /// Faults fired during this scenario (0 without `--features fault`).
    pub injected: u64,
    /// Invariant breaches — empty means the protocols survived.
    pub violations: Vec<String>,
    /// Non-fatal observations (takeover counts, panics caught, …).
    pub notes: Vec<String>,
}

impl ChaosReport {
    pub fn ok(&self) -> bool {
        self.violations.is_empty()
    }
}

impl std::fmt::Display for ChaosReport {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        writeln!(
            f,
            "chaos[{}] seed={:#x}: {} fault(s) injected — {}",
            self.scenario,
            self.seed,
            self.injected,
            if self.ok() { "survived" } else { "VIOLATED" }
        )?;
        for n in &self.notes {
            writeln!(f, "  note: {n}")?;
        }
        for v in &self.violations {
            writeln!(f, "  violation: {v}")?;
        }
        Ok(())
    }
}

/// Serializes scenarios: the armed [`FaultPlan`] is process-global.
static SCENARIO: Mutex<()> = Mutex::new(());

fn scenario_lock() -> MutexGuard<'static, ()> {
    SCENARIO.lock().unwrap_or_else(PoisonError::into_inner)
}

/// Disarms the plan when the scenario frame exits, unwind included — a
/// scenario bug must not leave kills armed for whatever runs next.
struct ClearGuard;

impl Drop for ClearGuard {
    fn drop(&mut self) {
        clear_plan();
    }
}

/// Kill-the-copier: hash-table resize under copier death.
///
/// Four inserter threads drive an undersized [`CacheHash`] through
/// several doublings while the `kill-copier` plan kills a copier right
/// after a stripe claim and right after a FROZEN seal. Each insert runs
/// under `catch_unwind`: a confirmed insert (returned `true`) must be
/// found afterwards; an in-flight insert killed mid-call is ambiguous
/// and must be *either* present with the right value or re-insertable.
/// Afterwards [`CacheHash::finish_resizes`] must complete every
/// migration the dead copiers abandoned, and removals must stay removed
/// (no resurrection from a straggling copy).
pub fn kill_copier(seed: u64) -> ChaosReport {
    let _serial = scenario_lock();
    let _disarm = ClearGuard;
    let injected0 = injected();
    if let Some(plan) = FaultPlan::named("kill-copier", seed) {
        plan.install();
    }

    const THREADS: u64 = 4;
    const PER: u64 = 2048;
    let value_of = |k: u64| k ^ 0xA5A5_A5A5;
    let table: CacheHash<CachedMemEff<LinkVal>> = CacheHash::new(32);
    let mut violations = Vec::new();
    let mut notes = Vec::new();

    // (confirmed, ambiguous, duplicate-violations) per thread.
    let per_thread: Vec<(Vec<u64>, Vec<u64>, u64)> = std::thread::scope(|s| {
        let handles: Vec<_> = (0..THREADS)
            .map(|t| {
                let table = &table;
                s.spawn(move || {
                    let mut confirmed = Vec::new();
                    let mut ambiguous = Vec::new();
                    let mut dups = 0u64;
                    for i in 0..PER {
                        let key = mix64(t * PER + i + 1);
                        // Per-key supervision: a killed insert leaves
                        // the key ambiguous and the thread carries on.
                        match catch_unwind(AssertUnwindSafe(|| {
                            table.insert(key, value_of(key))
                        })) {
                            Ok(true) => confirmed.push(key),
                            Ok(false) => dups += 1,
                            Err(_) => ambiguous.push(key),
                        }
                    }
                    (confirmed, ambiguous, dups)
                })
            })
            .collect();
        handles.into_iter().map(|h| h.join().unwrap()).collect()
    });

    // Disarm before verification: the checks below must not be killed.
    clear_plan();
    // Dead copiers may have left stripes unmigrated and buckets FROZEN;
    // this must converge regardless (the sweep re-covers their work).
    table.finish_resizes();

    let mut confirmed_total = 0u64;
    let mut ambiguous_total = 0u64;
    for (confirmed, ambiguous, dups) in &per_thread {
        if *dups > 0 {
            violations.push(format!(
                "{dups} fresh key(s) reported already-present (duplicate insert)"
            ));
        }
        confirmed_total += confirmed.len() as u64;
        ambiguous_total += ambiguous.len() as u64;
        for &key in confirmed {
            match table.find(key) {
                Some(v) if v == value_of(key) => {}
                Some(v) => violations.push(format!(
                    "confirmed key {key:#x}: wrong value {v:#x}"
                )),
                None => violations.push(format!(
                    "confirmed key {key:#x} lost across copier death"
                )),
            }
        }
        for &key in ambiguous {
            // Killed mid-insert: the op either took effect or it
            // didn't — both are linearizable, limbo is not.
            match table.find(key) {
                Some(v) if v == value_of(key) => {}
                Some(v) => violations.push(format!(
                    "ambiguous key {key:#x}: torn value {v:#x}"
                )),
                None => {
                    if !table.insert(key, value_of(key)) {
                        violations.push(format!(
                            "ambiguous key {key:#x}: absent yet not insertable"
                        ));
                    }
                }
            }
        }
    }

    // No resurrection: a removal after the takeover era must stick.
    let mut removed_checked = 0u64;
    for (confirmed, _, _) in &per_thread {
        for &key in confirmed.iter().take(64) {
            if !table.remove(key) {
                violations.push(format!("confirmed key {key:#x}: remove failed"));
            } else if table.find(key).is_some() {
                violations.push(format!("key {key:#x} resurrected after remove"));
            }
            removed_checked += 1;
        }
    }

    let fired = injected() - injected0;
    notes.push(format!(
        "{confirmed_total} confirmed, {ambiguous_total} ambiguous (killed mid-insert), \
         {removed_checked} removals re-checked, final capacity {}",
        table.capacity()
    ));
    ChaosReport {
        scenario: "kill-copier",
        seed,
        injected: fired,
        violations,
        notes,
    }
}

/// Stall-the-drainer: `ClaimQueue` lease takeover under a held claim.
///
/// Phase 1 is deterministic: drainer A claims a run and sits on the
/// claim past the lease; drainer B must take the queue over (takeover
/// counted) and drain what was pushed meanwhile, and A's detached run
/// still drains exactly its own items. Phase 2 arms the
/// `stall-drainer` plan and fuzzes multi-producer/multi-drainer
/// traffic; across both phases every item is drained **exactly once**.
pub fn stall_drainer(seed: u64) -> ChaosReport {
    let _serial = scenario_lock();
    let _disarm = ClearGuard;
    let injected0 = injected();
    let mut violations = Vec::new();
    let mut notes = Vec::new();

    // Phase 1: engineered stall, no plan needed — deterministic.
    const LEASE_NS: u64 = 200_000; // 200µs
    let q: ClaimQueue<u64> = ClaimQueue::with_lease(1 << 20, LEASE_NS);
    for i in 0..100u64 {
        q.try_push(i).map_err(|_| ()).expect("bounded far above 100");
    }
    let seen = Mutex::new(Vec::<u64>::new());
    std::thread::scope(|s| {
        let held = &AtomicU64::new(0);
        s.spawn(|| {
            let mut run = q.try_claim().expect("first claim");
            let mine: Vec<u64> = run.drain().collect();
            held.store(1, Ordering::Release);
            // Sit on the claim well past the lease while B works.
            while held.load(Ordering::Acquire) == 1 {
                std::thread::yield_now();
            }
            seen.lock().unwrap_or_else(PoisonError::into_inner).extend(mine);
            // Dropping the run releases a claim that was taken over —
            // the epoch check must make that release a no-op.
        });
        while held.load(Ordering::Acquire) == 0 {
            std::thread::yield_now();
        }
        for i in 100..200u64 {
            q.try_push(i).map_err(|_| ()).expect("bounded far above 200");
        }
        std::thread::sleep(Duration::from_micros(2 * LEASE_NS / 1000));
        // B: the lease has expired under A — this claim must succeed by
        // takeover, not wait for A.
        let deadline = Instant::now() + Duration::from_secs(10);
        let mut got = Vec::new();
        while got.len() < 100 {
            if let Some(mut run) = q.try_claim() {
                got.extend(run.drain());
            }
            if Instant::now() > deadline {
                break;
            }
            std::thread::yield_now();
        }
        if got.len() < 100 {
            violations.push(format!(
                "takeover drainer stuck behind a stalled claim ({} of 100 drained)",
                got.len()
            ));
        }
        seen.lock().unwrap_or_else(PoisonError::into_inner).extend(got);
        held.store(2, Ordering::Release);
    });
    if q.lease_takeovers() == 0 {
        violations.push("claim held past the lease was never taken over".into());
    }
    notes.push(format!(
        "phase1: {} takeover(s) of a deliberately stalled claim",
        q.lease_takeovers()
    ));

    // Phase 2: armed stalls, multi-producer / multi-drainer exactness.
    if let Some(plan) = FaultPlan::named("stall-drainer", seed) {
        plan.install();
    }
    const PRODUCERS: u64 = 2;
    const DRAINERS: usize = 2;
    const PER: u64 = 4000;
    let q2: ClaimQueue<u64> = ClaimQueue::with_lease(1 << 20, 2_000);
    let drained = AtomicU64::new(0);
    std::thread::scope(|s| {
        for p in 0..PRODUCERS {
            let q2 = &q2;
            s.spawn(move || {
                for i in 0..PER {
                    // Offset past the 0..200 ids phase 1 used, so the
                    // exactly-once check spans both phases unambiguously.
                    let id = 1000 + p * PER + i;
                    let mut item = id;
                    // Spurious-CAS-tolerant push (bound is huge).
                    loop {
                        match q2.try_push(item) {
                            Ok(_) => break,
                            Err((back, _)) => {
                                item = back;
                                std::thread::yield_now();
                            }
                        }
                    }
                }
            });
        }
        for _ in 0..DRAINERS {
            let (q2, drained, seen) = (&q2, &drained, &seen);
            s.spawn(move || {
                let deadline = Instant::now() + Duration::from_secs(10);
                while drained.load(Ordering::Acquire) < PRODUCERS * PER
                    && Instant::now() < deadline
                {
                    if let Some(mut run) = q2.try_claim() {
                        let items: Vec<u64> = run.drain().collect();
                        drained.fetch_add(items.len() as u64, Ordering::AcqRel);
                        seen.lock()
                            .unwrap_or_else(PoisonError::into_inner)
                            .extend(items);
                    } else {
                        std::thread::yield_now();
                    }
                }
            });
        }
    });
    clear_plan();

    // Exactness over both phases: 200 + PRODUCERS*PER distinct ids,
    // each drained exactly once.
    let mut all = seen.into_inner().unwrap_or_else(PoisonError::into_inner);
    let expected = 200 + PRODUCERS * PER;
    if all.len() as u64 != expected {
        violations.push(format!(
            "drained {} items, pushed {expected} (lost or duplicated)",
            all.len()
        ));
    }
    let before_dedup = all.len();
    all.sort_unstable();
    all.dedup();
    if all.len() != before_dedup {
        violations.push(format!(
            "{} item(s) drained more than once",
            before_dedup - all.len()
        ));
    }
    notes.push(format!(
        "phase2: {} takeover(s), {} requeue(s) under injected stalls",
        q2.lease_takeovers(),
        q2.requeued()
    ));

    ChaosReport {
        scenario: "stall-drainer",
        seed,
        injected: injected() - injected0,
        violations,
        notes,
    }
}

/// Panic-one-worker: the KV service under an injected worker kill.
///
/// Arms `kill-worker` (one kill at `KvServeBatch`) and runs the
/// lock-free arm with drainer leases on. The supervisor must catch the
/// panic ([`kv_service::KvReport::worker_panics`]), the batch that died
/// mid-serve must be *counted* abandoned, and the conservation ledger
/// must balance — nothing silently lost, nothing double-served.
pub fn kill_worker(seed: u64, secs: f64) -> ChaosReport {
    let _serial = scenario_lock();
    let _disarm = ClearGuard;
    let injected0 = injected();
    if let Some(plan) = FaultPlan::named("kill-worker", seed) {
        plan.install();
    }

    let cfg = KvConfig {
        n: 1 << 12,
        workers: 3,
        batch: 128,
        duration: Duration::from_secs_f64(secs.max(0.2)),
        seed,
        reservoir: 64,
        ingress: IngressMode::Lockfree,
        shards: 2,
        clients: 2,
        lease_ms: 5,
        ..KvConfig::default()
    };
    let mut violations = Vec::new();
    let mut notes = Vec::new();
    match kv_service::run(&cfg, None) {
        Ok(rep) => {
            if rep.enqueued_batches
                != rep.sample_count as u64 + rep.shed_batches + rep.abandoned_batches
            {
                violations.push(format!(
                    "conservation broke: {} offered != {} served + {} shed + {} abandoned",
                    rep.enqueued_batches,
                    rep.sample_count,
                    rep.shed_batches,
                    rep.abandoned_batches
                ));
            }
            if rep.total_requests != rep.finds + rep.inserts + rep.deletes {
                violations.push("request accounting mismatch".into());
            }
            let fired = injected() - injected0;
            if fired > 0 && rep.worker_panics == 0 {
                violations.push(
                    "a kill fired but no worker panic was caught (supervision hole)".into(),
                );
            }
            notes.push(format!(
                "{} panic(s) caught, {} batch(es) abandoned, {} requeued, {} lease takeover(s)",
                rep.worker_panics,
                rep.abandoned_batches,
                rep.requeued_batches,
                rep.lease_takeovers
            ));
        }
        Err(e) => violations.push(format!("kv run failed outright: {e}")),
    }
    clear_plan();

    ChaosReport {
        scenario: "kill-worker",
        seed,
        injected: injected() - injected0,
        violations,
        notes,
    }
}

/// Kill-the-allocator: page-pool churn under a claim-path death.
///
/// Arms `kill-allocator` (one kill at `PoolClaimPage` — the very top of
/// the pool's page-claim path, before any lock is taken or memory
/// allocated) and drives chain-heavy insert/remove churn on an
/// undersized [`CacheHash`]. Every spawned thread starts with empty
/// free lists, so its first chain-node allocation walks the claim path
/// and the kill is guaranteed a window. Each op runs under
/// `catch_unwind`: the killed op leaves its key ambiguous; every other
/// key must be exact (kept keys found, churned keys gone). Afterwards
/// the pool must still hand out slots — the dying claimant leaked
/// nothing — and page/batch accounting must have moved.
pub fn kill_allocator(seed: u64) -> ChaosReport {
    let _serial = scenario_lock();
    let _disarm = ClearGuard;
    let injected0 = injected();
    let pool0 = pool::stats();
    if let Some(plan) = FaultPlan::named("kill-allocator", seed) {
        plan.install();
    }

    const THREADS: u64 = 4;
    const PER: u64 = 1024;
    let value_of = |k: u64| k ^ 0x5EED_F00D;
    // Tiny table: most inserts chain, so every op leans on the pool.
    let table: CacheHash<CachedMemEff<LinkVal>> = CacheHash::new(8);
    let mut violations = Vec::new();
    let mut notes = Vec::new();

    // (kept, churned, ambiguous, duplicate-violations) per thread.
    let per_thread: Vec<(Vec<u64>, Vec<u64>, Vec<u64>, u64)> = std::thread::scope(|s| {
        let handles: Vec<_> = (0..THREADS)
            .map(|t| {
                let table = &table;
                s.spawn(move || {
                    let mut kept = Vec::new();
                    let mut churned = Vec::new();
                    let mut ambiguous = Vec::new();
                    let mut dups = 0u64;
                    for i in 0..PER {
                        let key = mix64(t * PER + i + 1);
                        // Half the keys churn straight back out, feeding
                        // their slots to the free lists mid-run.
                        let churn = i % 2 == 0;
                        match catch_unwind(AssertUnwindSafe(|| {
                            if !table.insert(key, value_of(key)) {
                                return Err(());
                            }
                            if churn && !table.remove(key) {
                                return Err(());
                            }
                            Ok(())
                        })) {
                            Ok(Ok(())) => {
                                if churn {
                                    churned.push(key);
                                } else {
                                    kept.push(key);
                                }
                            }
                            Ok(Err(())) => dups += 1,
                            Err(_) => ambiguous.push(key),
                        }
                    }
                    (kept, churned, ambiguous, dups)
                })
            })
            .collect();
        handles.into_iter().map(|h| h.join().unwrap()).collect()
    });

    // Disarm before verification: the checks below must not be killed.
    clear_plan();
    table.finish_resizes();

    let mut ambiguous_total = 0u64;
    for (kept, churned, ambiguous, dups) in &per_thread {
        if *dups > 0 {
            violations.push(format!(
                "{dups} fresh key(s) misbehaved (duplicate insert or failed remove)"
            ));
        }
        ambiguous_total += ambiguous.len() as u64;
        for &key in kept {
            match table.find(key) {
                Some(v) if v == value_of(key) => {}
                Some(v) => violations.push(format!("kept key {key:#x}: wrong value {v:#x}")),
                None => violations.push(format!("kept key {key:#x} lost across allocator death")),
            }
        }
        for &key in churned {
            if table.find(key).is_some() {
                violations.push(format!("churned key {key:#x} resurrected after remove"));
            }
        }
        for &key in ambiguous {
            // Killed mid insert-or-remove: presence is ambiguous, but a
            // present value must be untorn.
            if let Some(v) = table.find(key) {
                if v != value_of(key) {
                    violations.push(format!("ambiguous key {key:#x}: torn value {v:#x}"));
                }
            }
        }
    }

    // Pool liveness across the kill: fresh chain-heavy inserts must
    // still claim slots (nothing wedged, no page or lock leaked).
    for i in 0..(2 * pool::PAGE_SLOTS as u64) {
        let key = mix64(0xF00D_0000 + i + 1);
        if !table.insert(key, value_of(key)) || table.find(key) != Some(value_of(key)) {
            violations.push(format!("post-kill alloc {i}: pool claim path wedged"));
            break;
        }
    }

    let pool1 = pool::stats();
    if pool1.pages == pool0.pages && pool0.pages == 0 {
        violations.push("churn allocated from the pool without ever claiming a page".into());
    }
    notes.push(format!(
        "{ambiguous_total} op(s) killed mid-flight; pool Δ: {} page(s), {} batch(es), {} batched slot(s)",
        pool1.pages - pool0.pages,
        pool1.batches - pool0.batches,
        pool1.batch_slots - pool0.batch_slots
    ));

    ChaosReport {
        scenario: "kill-allocator",
        seed,
        injected: injected() - injected0,
        violations,
        notes,
    }
}

/// Kill-the-copier, shrink direction: a drained table converging
/// through maintenance while copiers die in the seal/claim windows.
///
/// Grows an undersized [`CacheHash`] to several thousand keys
/// (unarmed), drains 15/16 of them (still unarmed, so presence is
/// exact), then arms `kill-copier-shrink` and drives [`Maintain`]
/// passes under per-pass `catch_unwind` — the failpoints are
/// direction-agnostic, and with the grow phase already complete every
/// hit lands inside a *shrink* migration. The kills abandon claimed
/// stripes and sealed buckets mid-shrink; later passes must re-cover
/// them (the same takeover/sweep machinery as grow), and the table
/// must converge below its peak with at least one shrink generation,
/// every kept key exact, and every drained key still absent.
pub fn kill_copier_shrink(seed: u64) -> ChaosReport {
    use crate::hash::Maintain;

    let _serial = scenario_lock();
    let _disarm = ClearGuard;
    let injected0 = injected();

    const N: u64 = 4096;
    let value_of = |k: u64| k ^ 0x5811_11E5;
    let table: CacheHash<CachedMemEff<LinkVal>> = CacheHash::new(2);
    let mut violations = Vec::new();
    let mut notes = Vec::new();

    // Unarmed grow + drain: presence below is exact, and every armed
    // failpoint hit afterwards belongs to a shrink-direction migration.
    for i in 0..N {
        table.insert(mix64(i + 1), value_of(mix64(i + 1)));
    }
    table.finish_resizes();
    let peak = table.capacity();
    for i in 0..N {
        if i % 16 != 0 {
            table.remove(mix64(i + 1));
        }
    }

    if let Some(plan) = FaultPlan::named("kill-copier-shrink", seed) {
        plan.install();
    }
    let mut panics = 0u64;
    let deadline = Instant::now() + Duration::from_secs(10);
    let mut cap = table.capacity();
    loop {
        // A killed pass abandons its stripe mid-shrink; the next pass
        // must take the orphaned work over.
        let idle = match catch_unwind(AssertUnwindSafe(|| table.maintain())) {
            Ok(idle) => idle,
            Err(_) => {
                panics += 1;
                false
            }
        };
        let now = table.capacity();
        if idle && now == cap {
            break;
        }
        cap = now;
        if Instant::now() > deadline {
            violations.push("shrink never converged across copier deaths".into());
            break;
        }
    }
    clear_plan();
    table.finish_resizes();

    if table.shrink_generation() == 0 {
        violations.push("no shrink generation completed".into());
    }
    if table.capacity() >= peak {
        violations.push(format!(
            "capacity {} not below peak {peak} after mass drain",
            table.capacity()
        ));
    }
    for i in 0..N {
        let key = mix64(i + 1);
        match (i % 16 == 0, table.find(key)) {
            (true, Some(v)) if v == value_of(key) => {}
            (true, Some(v)) => {
                violations.push(format!("kept key {key:#x}: wrong value {v:#x}"))
            }
            (true, None) => {
                violations.push(format!("kept key {key:#x} lost across shrink kills"))
            }
            (false, Some(_)) => {
                violations.push(format!("drained key {key:#x} resurrected by shrink"))
            }
            (false, None) => {}
        }
    }
    notes.push(format!(
        "{panics} maintenance pass(es) killed; {peak} → {} buckets over {} shrink gen(s)",
        table.capacity(),
        table.shrink_generation()
    ));

    ChaosReport {
        scenario: "kill-copier-shrink",
        seed,
        injected: injected() - injected0,
        violations,
        notes,
    }
}

/// Kill-the-migrator: the [`BackgroundMigrator`] thread under injected
/// deaths inside its own `finish_resizes` passes.
///
/// Same grow-then-drain setup as [`kill_copier_shrink`], but the
/// convergence is driven entirely by a spawned [`BackgroundMigrator`]
/// (zero foreground help) while `kill-migrator` kills its passes
/// between per-entry copies and at the DONE publish. The migrator's
/// per-pass supervision must count the deaths and keep going, and the
/// quiescent table must still reach `resize_in_flight() == false`
/// below its peak capacity with every surviving key exact.
pub fn kill_migrator(seed: u64) -> ChaosReport {
    use crate::hash::{BackgroundMigrator, Maintain};
    use std::sync::Arc;

    let _serial = scenario_lock();
    let _disarm = ClearGuard;
    let injected0 = injected();

    const N: u64 = 4096;
    let value_of = |k: u64| k ^ 0x317_A702; // "MIGRATOR"-ish
    let table: Arc<CacheHash<CachedMemEff<LinkVal>>> = Arc::new(CacheHash::new(2));
    let mut violations = Vec::new();
    let mut notes = Vec::new();

    for i in 0..N {
        table.insert(mix64(i + 1), value_of(mix64(i + 1)));
    }
    table.finish_resizes();
    let peak = table.capacity();
    for i in 0..N {
        if i % 16 != 0 {
            table.remove(mix64(i + 1));
        }
    }

    if let Some(plan) = FaultPlan::named("kill-migrator", seed) {
        plan.install();
    }
    let migrator = BackgroundMigrator::spawn(
        vec![Arc::clone(&table) as Arc<dyn Maintain>],
        Duration::from_micros(200),
    );
    // Zero foreground ops from here: the migrator alone must converge,
    // absorbing its own injected deaths. Stability = idle and capacity
    // unchanged across a few consecutive polls.
    let deadline = Instant::now() + Duration::from_secs(10);
    let mut stable = 0u32;
    let mut cap = table.capacity();
    while stable < 5 {
        std::thread::sleep(Duration::from_millis(2));
        let now = table.capacity();
        if !table.resize_in_flight() && now == cap {
            stable += 1;
        } else {
            stable = 0;
        }
        cap = now;
        if Instant::now() > deadline {
            violations.push("background migrator never converged across kills".into());
            break;
        }
    }
    let pass_deaths = migrator.panics();
    migrator.stop();
    clear_plan();
    table.finish_resizes();

    let fired = injected() - injected0;
    if fired > 0 && pass_deaths == 0 {
        violations
            .push("a kill fired but no migrator pass death was caught (supervision hole)".into());
    }
    if table.shrink_generation() == 0 {
        violations.push("no shrink generation completed".into());
    }
    if table.capacity() >= peak {
        violations.push(format!(
            "capacity {} not below peak {peak} after quiescent convergence",
            table.capacity()
        ));
    }
    for i in 0..N {
        let key = mix64(i + 1);
        match (i % 16 == 0, table.find(key)) {
            (true, Some(v)) if v == value_of(key) => {}
            (true, Some(v)) => {
                violations.push(format!("kept key {key:#x}: wrong value {v:#x}"))
            }
            (true, None) => {
                violations.push(format!("kept key {key:#x} lost across migrator death"))
            }
            (false, Some(_)) => {
                violations.push(format!("drained key {key:#x} resurrected by migrator"))
            }
            (false, None) => {}
        }
    }
    notes.push(format!(
        "{pass_deaths} migrator pass(es) killed; {peak} → {} buckets over {} shrink gen(s)",
        table.capacity(),
        table.shrink_generation()
    ));

    ChaosReport {
        scenario: "kill-migrator",
        seed,
        injected: fired,
        violations,
        notes,
    }
}

/// Jitter: no kills — broad delays/yields/spurious CAS failures across
/// every protocol point during a full KV run. Shakes out interleavings;
/// the ledger and accounting checks are the same as [`kill_worker`]'s.
pub fn jitter(seed: u64, secs: f64) -> ChaosReport {
    let _serial = scenario_lock();
    let _disarm = ClearGuard;
    let injected0 = injected();
    if let Some(plan) = FaultPlan::named("jitter", seed) {
        plan.install();
    }

    let cfg = KvConfig {
        n: 1 << 12,
        workers: 4,
        batch: 128,
        duration: Duration::from_secs_f64(secs.max(0.2)),
        seed,
        reservoir: 64,
        ingress: IngressMode::Lockfree,
        shards: 2,
        clients: 2,
        initial_capacity: 64, // grow online under jitter too
        ..KvConfig::default()
    };
    let mut violations = Vec::new();
    let mut notes = Vec::new();
    match kv_service::run(&cfg, None) {
        Ok(rep) => {
            if rep.enqueued_batches
                != rep.sample_count as u64 + rep.shed_batches + rep.abandoned_batches
            {
                violations.push(format!(
                    "conservation broke under jitter: {} offered != {} + {} + {}",
                    rep.enqueued_batches,
                    rep.sample_count,
                    rep.shed_batches,
                    rep.abandoned_batches
                ));
            }
            if rep.worker_panics != 0 {
                violations.push(format!(
                    "{} worker panic(s) under a kill-free plan",
                    rep.worker_panics
                ));
            }
            notes.push(format!(
                "{} requests, table {} → {} buckets",
                rep.total_requests, rep.initial_buckets, rep.final_buckets
            ));
        }
        Err(e) => violations.push(format!("kv run failed outright: {e}")),
    }
    clear_plan();

    ChaosReport {
        scenario: "jitter",
        seed,
        injected: injected() - injected0,
        violations,
        notes,
    }
}

/// Run one named scenario (`plan` = `kill-copier` | `stall-drainer` |
/// `kill-worker` | `kill-allocator` | `kill-copier-shrink` |
/// `kill-migrator` | `jitter`), or all of them when `plan` is empty.
pub fn run(seed: u64, plan: &str, secs: f64) -> Result<Vec<ChaosReport>> {
    let reports = match plan {
        "" | "all" => vec![
            kill_copier(seed),
            stall_drainer(seed),
            kill_worker(seed, secs),
            kill_allocator(seed),
            kill_copier_shrink(seed),
            kill_migrator(seed),
            jitter(seed, secs),
        ],
        "kill-copier" => vec![kill_copier(seed)],
        "stall-drainer" => vec![stall_drainer(seed)],
        "kill-worker" => vec![kill_worker(seed, secs)],
        "kill-allocator" => vec![kill_allocator(seed)],
        "kill-copier-shrink" => vec![kill_copier_shrink(seed)],
        "kill-migrator" => vec![kill_migrator(seed)],
        "jitter" => vec![jitter(seed, secs)],
        other => crate::bail!(
            "chaos plan {other}: use kill-copier|stall-drainer|kill-worker|kill-allocator|\
             kill-copier-shrink|kill-migrator|jitter|all"
        ),
    };
    Ok(reports)
}

#[cfg(test)]
mod tests {
    use super::*;

    // The full scenarios run in tests/chaos.rs (their own process: the
    // armed plan is global). Here: only the plumbing.

    #[test]
    fn test_run_rejects_unknown_plan() {
        assert!(run(1, "no-such-plan", 0.1).is_err());
    }

    #[test]
    fn test_report_display_mentions_outcome() {
        let ok = ChaosReport {
            scenario: "x",
            seed: 1,
            injected: 0,
            violations: vec![],
            notes: vec!["fine".into()],
        };
        assert!(format!("{ok}").contains("survived"));
        let bad = ChaosReport {
            scenario: "x",
            seed: 1,
            injected: 2,
            violations: vec!["boom".into()],
            notes: vec![],
        };
        assert!(format!("{bad}").contains("VIOLATED"));
    }
}
