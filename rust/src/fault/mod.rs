//! Deterministic fault injection for crash-tolerance testing.
//!
//! The paper's headline robustness claim — lock-free big atomics keep the
//! system live when the scheduler misbehaves — is only testable if we can
//! *make* the scheduler misbehave on demand. This module plants ~24 named
//! [`Point`]s at the narrowest windows of every protocol in the crate (the
//! atomics backends' install/recache windows, SMR pin/retire/scan, both
//! resize engines' seal/copy/publish phases, the `ClaimQueue`
//! enqueue/claim/drain/release windows, and the KV worker loop) and lets a
//! seeded [`FaultPlan`] fire a [`FaultAction`] at any of them: an extra
//! delay, a forced yield, a long stall, a spurious CAS failure, or an
//! outright kill (a panic that unwinds the thread mid-protocol).
//!
//! Everything is deterministic given `(seed, plan, schedule)`: the decision
//! whether hit number `i` at point `p` fires is a pure function of the plan
//! seed, so a failing chaos run replays from its seed. The invariants the
//! chaos suites assert (linearizability, conservation, progress) must hold
//! on *every* schedule, so scheduling noise cannot turn a passing seed into
//! a false failure — only into a different interleaving that must also pass.
//!
//! # Overhead expectations
//!
//! Mirrors `obs/`'s contract: in default builds (no `--features fault`) the
//! [`failpoint!`] and [`failcas!`] macros expand to `()` and `false`
//! respectively — zero instructions, zero branches, bit-for-bit identical
//! codegen to a tree without the hooks. With the feature enabled but no
//! plan installed, each hit is one `Acquire` load of a null pointer and a
//! predictable branch. With a plan installed, each hit adds one relaxed
//! `fetch_add` and a `mix64` — still cheap enough to leave armed through a
//! full workload.
//!
//! # Kill safety
//!
//! Not every window tolerates a thread dying in it: the seqlock and spin
//! locks are explicitly not panic-safe (a kill while holding one would wedge
//! every other thread — a *harness* artifact, not a protocol bug), and a
//! kill between a `ClaimQueue` claim CAS and the `Run` taking ownership
//! would leak the detached chain. [`Point::kill_safe`] encodes the
//! distinction and [`FaultPlan::with_rule`] refuses `Kill` rules at unsafe
//! points, so every kill the harness performs models a real preemption-
//! or-crash the protocols are required to survive.

use core::sync::atomic::{AtomicPtr, AtomicU32, AtomicU64, Ordering};

use crate::util::rng::mix64;

pub mod chaos;

/// Named protocol points a [`FaultPlan`] can target.
///
/// Dense `repr(usize)` in declaration order, like `obs::telemetry::Event`;
/// [`Point::ALL`] and [`NUM_POINTS`] must move together with the enum.
#[derive(Clone, Copy, PartialEq, Eq, Debug)]
#[repr(usize)]
pub enum Point {
    /// SeqLock writer holds the odd version word (NOT kill-safe).
    SeqLockWriteLocked = 0,
    /// A `SpinLock` critical section has been entered (NOT kill-safe).
    /// Covers `SimpLock`, `LockPool`, and the HtmSim fallback path.
    SpinLockAcquired,
    /// `Indirect` is about to CAS its fresh boxed value into the root.
    IndirectInstall,
    /// Alg 1 (`CachedWaitFree`) is about to install a descriptor.
    Alg1Install,
    /// Alg 1 is about to bid for the recache version lock.
    Alg1Recache,
    /// Alg 2 (`CachedMemEff`) is about to take a slab node for install.
    Alg2Install,
    /// Alg 2 is about to bid for the recache seqlock.
    Alg2Recache,
    /// Alg 3 (`CachedWritable`) is about to help a pending transfer.
    Alg3Transfer,
    /// HtmSim is at the top of a transaction attempt, before tx_begin.
    HtmTxCommit,
    /// A hazard slot announcement has been published, pre-revalidation.
    HazardAnnounce,
    /// A node is about to join the hazard retire list.
    HazardRetire,
    /// A hazard scan is about to snapshot the announcement table.
    HazardScan,
    /// An epoch pin announcement is being revalidated (NOT kill-safe:
    /// the announcement is published but the RAII guard not yet built).
    EpochPin,
    /// A node is about to join the epoch retire bag.
    EpochRetire,
    /// `try_advance_and_collect` is about to scan announcements.
    EpochAdvance,
    /// A resize copier just won a stripe-claim CAS on the cursor.
    ResizeStripeClaim,
    /// A resize copier just sealed a bucket FROZEN.
    ResizeSealFrozen,
    /// A resize copier is between per-entry copies of a frozen bucket.
    ResizeCopyEntry,
    /// A resize copier is about to CAS a frozen bucket to DONE.
    ResizePublishDone,
    /// `ClaimQueue::try_push` is about to box and link a node.
    IngressEnqueue,
    /// `ClaimQueue::try_claim` is about to bid for the claim word.
    IngressClaim,
    /// A drainer just won the claim CAS and owns the detached chain
    /// (NOT kill-safe: dying here would leak the chain from the ledger).
    IngressDrain,
    /// `Run::drop` is about to release the claim word (NOT kill-safe:
    /// a panic during unwind aborts the process).
    IngressRelease,
    /// Top of a KV worker's claim/serve loop.
    KvWorkerLoop,
    /// A KV worker is about to serve a claimed batch.
    KvServeBatch,
    /// `smr::pool` is about to claim a fresh page (before any lock or
    /// allocation, so a kill here leaks nothing).
    PoolClaimPage,
}

/// Number of named points; `Point::PoolClaimPage` is the anchor.
pub const NUM_POINTS: usize = Point::PoolClaimPage as usize + 1;

impl Point {
    /// Every point, in discriminant order (pinned by `test_points_dense`).
    pub const ALL: [Point; NUM_POINTS] = [
        Point::SeqLockWriteLocked,
        Point::SpinLockAcquired,
        Point::IndirectInstall,
        Point::Alg1Install,
        Point::Alg1Recache,
        Point::Alg2Install,
        Point::Alg2Recache,
        Point::Alg3Transfer,
        Point::HtmTxCommit,
        Point::HazardAnnounce,
        Point::HazardRetire,
        Point::HazardScan,
        Point::EpochPin,
        Point::EpochRetire,
        Point::EpochAdvance,
        Point::ResizeStripeClaim,
        Point::ResizeSealFrozen,
        Point::ResizeCopyEntry,
        Point::ResizePublishDone,
        Point::IngressEnqueue,
        Point::IngressClaim,
        Point::IngressDrain,
        Point::IngressRelease,
        Point::KvWorkerLoop,
        Point::KvServeBatch,
        Point::PoolClaimPage,
    ];

    /// Stable snake_case name, for plan parsing and reports.
    pub fn name(self) -> &'static str {
        match self {
            Point::SeqLockWriteLocked => "seqlock_write_locked",
            Point::SpinLockAcquired => "spinlock_acquired",
            Point::IndirectInstall => "indirect_install",
            Point::Alg1Install => "alg1_install",
            Point::Alg1Recache => "alg1_recache",
            Point::Alg2Install => "alg2_install",
            Point::Alg2Recache => "alg2_recache",
            Point::Alg3Transfer => "alg3_transfer",
            Point::HtmTxCommit => "htm_tx_commit",
            Point::HazardAnnounce => "hazard_announce",
            Point::HazardRetire => "hazard_retire",
            Point::HazardScan => "hazard_scan",
            Point::EpochPin => "epoch_pin",
            Point::EpochRetire => "epoch_retire",
            Point::EpochAdvance => "epoch_advance",
            Point::ResizeStripeClaim => "resize_stripe_claim",
            Point::ResizeSealFrozen => "resize_seal_frozen",
            Point::ResizeCopyEntry => "resize_copy_entry",
            Point::ResizePublishDone => "resize_publish_done",
            Point::IngressEnqueue => "ingress_enqueue",
            Point::IngressClaim => "ingress_claim",
            Point::IngressDrain => "ingress_drain",
            Point::IngressRelease => "ingress_release",
            Point::KvWorkerLoop => "kv_worker_loop",
            Point::KvServeBatch => "kv_serve_batch",
            Point::PoolClaimPage => "pool_claim_page",
        }
    }

    /// Whether a thread may die (panic) at this point without wedging
    /// other threads or corrupting a conservation ledger. `Kill` rules
    /// are only accepted at kill-safe points; everywhere else the
    /// harness is limited to delays, yields, stalls, and spurious CAS
    /// failures — which is exactly what a real preemption can do there.
    pub fn kill_safe(self) -> bool {
        !matches!(
            self,
            Point::SeqLockWriteLocked
                | Point::SpinLockAcquired
                | Point::EpochPin
                | Point::IngressDrain
                | Point::IngressRelease
        )
    }
}

/// What a matched [`Rule`] does to the hitting thread.
#[derive(Clone, Copy, Debug)]
pub enum FaultAction {
    /// Busy-spin for roughly `n * 64` `spin_loop` hints.
    Delay(u32),
    /// One `thread::yield_now`, handing the core to a rival.
    Yield,
    /// `n` consecutive `thread::yield_now`s — a long preemption.
    Stall(u32),
    /// Report a CAS failure that never happened (only observed at
    /// [`failcas!`] points; plain [`failpoint!`]s treat it as a no-op).
    SpuriousCasFail,
    /// Unwind the thread here via `panic_any(`[`FaultKill`]`)`.
    Kill,
}

/// One plan entry: at `point`, fire `action` on roughly 1-in-`one_in`
/// hits, at most `max` times (`max == 0` means unlimited).
#[derive(Clone, Copy, Debug)]
pub struct Rule {
    pub point: Point,
    pub action: FaultAction,
    pub one_in: u64,
    pub max: u32,
}

/// Panic payload carried by [`FaultAction::Kill`]; chaos scenarios
/// downcast it to tell an injected death from a genuine bug.
#[derive(Clone, Copy, Debug)]
pub struct FaultKill {
    pub point: Point,
}

#[allow(clippy::declare_interior_mutable_const)]
const ZERO64: AtomicU64 = AtomicU64::new(0);
#[allow(clippy::declare_interior_mutable_const)]
const ZERO32: AtomicU32 = AtomicU32::new(0);

/// A seeded, installable set of fault [`Rule`]s plus per-point hit and
/// fired accounting. Build with [`FaultPlan::new`] + [`FaultPlan::with_rule`]
/// (or a named preset), then [`FaultPlan::install`] to arm it globally.
#[derive(Debug)]
pub struct FaultPlan {
    seed: u64,
    rules: Vec<Rule>,
    hits: [AtomicU64; NUM_POINTS],
    fired: [AtomicU32; NUM_POINTS],
}

impl FaultPlan {
    pub fn new(seed: u64) -> Self {
        Self {
            seed,
            rules: Vec::new(),
            hits: [ZERO64; NUM_POINTS],
            fired: [ZERO32; NUM_POINTS],
        }
    }

    /// Add a rule. Panics if a `Kill` targets a non-kill-safe point —
    /// that would model a fault no schedule can produce (threads don't
    /// evaporate inside a spinlock) and would wedge the harness itself.
    pub fn with_rule(mut self, rule: Rule) -> Self {
        if matches!(rule.action, FaultAction::Kill) {
            assert!(
                rule.point.kill_safe(),
                "Kill rule at non-kill-safe point {}",
                rule.point.name()
            );
        }
        self.rules.push(rule);
        self
    }

    /// Named presets, the vocabulary of `repro chaos --plan`:
    ///
    /// - `kill-copier`: kill a resize copier once right after it seals a
    ///   bucket FROZEN, and once right after it wins a stripe claim.
    /// - `stall-drainer`: long stalls on a drainer that just won the
    ///   claim word, so the shard's lease expires while it holds runs.
    /// - `kill-worker`: kill a KV worker mid-serve, once.
    /// - `kill-allocator`: kill a thread at the top of the pool's
    ///   page-claim path, once — modeling a crash at an allocation miss.
    /// - `kill-copier-shrink`: same windows as `kill-copier`, armed
    ///   while the migration runs in the *shrink* direction (the
    ///   failpoints are direction-agnostic; the scenario provides the
    ///   drained table).
    /// - `kill-migrator`: kill a background maintenance pass mid-copy
    ///   (between per-entry copies) and at the DONE publish — the
    ///   migrator thread must absorb the death and converge anyway.
    /// - `jitter`: no kills — broad delays/yields/spurious CAS failures
    ///   across every retry-loop point, shaking out interleavings.
    pub fn named(name: &str, seed: u64) -> Option<Self> {
        let plan = match name {
            "kill-copier" => Self::new(seed)
                .with_rule(Rule {
                    point: Point::ResizeSealFrozen,
                    action: FaultAction::Kill,
                    one_in: 1,
                    max: 1,
                })
                .with_rule(Rule {
                    point: Point::ResizeStripeClaim,
                    action: FaultAction::Kill,
                    one_in: 2,
                    max: 1,
                }),
            "stall-drainer" => Self::new(seed).with_rule(Rule {
                point: Point::IngressDrain,
                action: FaultAction::Stall(64),
                one_in: 2,
                max: 0,
            }),
            "kill-worker" => Self::new(seed).with_rule(Rule {
                point: Point::KvServeBatch,
                action: FaultAction::Kill,
                one_in: 1,
                max: 1,
            }),
            "kill-allocator" => Self::new(seed).with_rule(Rule {
                point: Point::PoolClaimPage,
                action: FaultAction::Kill,
                one_in: 1,
                max: 1,
            }),
            "kill-copier-shrink" => Self::new(seed)
                .with_rule(Rule {
                    point: Point::ResizeSealFrozen,
                    action: FaultAction::Kill,
                    one_in: 1,
                    max: 1,
                })
                .with_rule(Rule {
                    point: Point::ResizeStripeClaim,
                    action: FaultAction::Kill,
                    one_in: 2,
                    max: 1,
                }),
            "kill-migrator" => Self::new(seed)
                .with_rule(Rule {
                    point: Point::ResizeCopyEntry,
                    action: FaultAction::Kill,
                    one_in: 1,
                    max: 1,
                })
                .with_rule(Rule {
                    point: Point::ResizePublishDone,
                    action: FaultAction::Kill,
                    one_in: 3,
                    max: 1,
                }),
            "jitter" => {
                let mut plan = Self::new(seed);
                for p in Point::ALL {
                    plan = plan.with_rule(Rule {
                        point: p,
                        action: FaultAction::Yield,
                        one_in: 7,
                        max: 0,
                    });
                }
                plan.with_rule(Rule {
                    point: Point::IngressEnqueue,
                    action: FaultAction::SpuriousCasFail,
                    one_in: 5,
                    max: 0,
                })
                .with_rule(Rule {
                    point: Point::IngressClaim,
                    action: FaultAction::Delay(8),
                    one_in: 3,
                    max: 0,
                })
            }
            _ => return None,
        };
        Some(plan)
    }

    /// Hits observed at `point` (fired or not) since install.
    pub fn hits_at(&self, point: Point) -> u64 {
        self.hits[point as usize].load(Ordering::Relaxed)
    }

    /// Faults actually fired at `point` since install.
    pub fn fired_at(&self, point: Point) -> u32 {
        self.fired[point as usize].load(Ordering::Relaxed)
    }

    /// Arm this plan globally and return a handle to its accounting.
    ///
    /// The previous plan (if any) is intentionally leaked: a racing
    /// thread may be mid-`hit` in it, and the harness is test-only, so
    /// a few hundred bytes per install beats a use-after-free.
    pub fn install(self) -> &'static FaultPlan {
        let fresh = Box::leak(Box::new(self));
        PLAN.store(fresh as *const FaultPlan as *mut FaultPlan, Ordering::Release);
        fresh
    }

    /// The 1-in-`one_in` coin for hit number `idx` at `point`: a pure
    /// function of the plan seed, so runs replay from their seed.
    fn decides(&self, rule: &Rule, point: Point, idx: u64) -> bool {
        if rule.one_in <= 1 {
            return true;
        }
        mix64(self.seed ^ ((point as u64 + 1) << 40) ^ idx) % rule.one_in == 0
    }

    /// Consult the plan at `point`; returns the action to perform, if any.
    fn draw(&self, point: Point) -> Option<FaultAction> {
        let idx = self.hits[point as usize].fetch_add(1, Ordering::Relaxed);
        let rule = self.rules.iter().find(|r| r.point == point)?;
        if rule.max != 0 && self.fired[point as usize].load(Ordering::Relaxed) >= rule.max {
            return None;
        }
        if !self.decides(rule, point, idx) {
            return None;
        }
        if rule.max != 0 {
            // Claim one of the bounded firings; a racing loser backs off.
            if self.fired[point as usize].fetch_add(1, Ordering::Relaxed) >= rule.max {
                return None;
            }
        } else {
            self.fired[point as usize].fetch_add(1, Ordering::Relaxed);
        }
        INJECTED.fetch_add(1, Ordering::Relaxed);
        crate::counter!(FaultInject);
        Some(rule.action)
    }
}

/// The armed plan; null when disarmed. Swapped-out plans leak (see
/// [`FaultPlan::install`]).
static PLAN: AtomicPtr<FaultPlan> = AtomicPtr::new(core::ptr::null_mut());

/// Total faults fired process-wide, across all plans ever installed.
static INJECTED: AtomicU64 = AtomicU64::new(0);

/// Disarm fault injection (hits become a null-check again).
pub fn clear_plan() {
    PLAN.store(core::ptr::null_mut(), Ordering::Release);
}

/// Total faults fired process-wide since start.
pub fn injected() -> u64 {
    INJECTED.load(Ordering::Relaxed)
}

#[inline]
fn active() -> Option<&'static FaultPlan> {
    let p = PLAN.load(Ordering::Acquire);
    if p.is_null() {
        None
    } else {
        Some(unsafe { &*p })
    }
}

fn perform(action: FaultAction, point: Point) {
    match action {
        FaultAction::Delay(n) => {
            for _ in 0..(n as u64 * 64) {
                core::hint::spin_loop();
            }
        }
        FaultAction::Yield => std::thread::yield_now(),
        FaultAction::Stall(n) => {
            for _ in 0..n {
                std::thread::yield_now();
            }
        }
        // A spurious CAS failure is meaningless at a unit failpoint;
        // treat it as the preemption blip it models.
        FaultAction::SpuriousCasFail => std::thread::yield_now(),
        FaultAction::Kill => std::panic::panic_any(FaultKill { point }),
    }
}

/// Runtime behind [`failpoint!`]: consult the armed plan and perform
/// whatever action it draws for this hit.
#[inline]
pub fn hit(point: Point) {
    if let Some(plan) = active() {
        if let Some(action) = plan.draw(point) {
            perform(action, point);
        }
    }
}

/// Runtime behind [`failcas!`]: like [`hit`], but `SpuriousCasFail`
/// returns `true` ("pretend your CAS just failed") instead of yielding.
#[inline]
pub fn hit_cas(point: Point) -> bool {
    if let Some(plan) = active() {
        if let Some(action) = plan.draw(point) {
            if matches!(action, FaultAction::SpuriousCasFail) {
                return true;
            }
            perform(action, point);
        }
    }
    false
}

/// Fire a named failpoint. Expands to `()` without `--features fault`.
///
/// ```ignore
/// crate::failpoint!(ResizeSealFrozen);
/// ```
#[cfg(feature = "fault")]
#[macro_export]
macro_rules! failpoint {
    ($p:ident) => {
        $crate::fault::hit($crate::fault::Point::$p)
    };
}

/// Fire a named failpoint. Expands to `()` without `--features fault`.
#[cfg(not(feature = "fault"))]
#[macro_export]
macro_rules! failpoint {
    ($p:ident) => {
        ()
    };
}

/// Fire a named failpoint that can report a spurious CAS failure:
/// evaluates to `true` when the plan says "pretend the CAS failed".
/// Expands to the constant `false` without `--features fault`, so the
/// guarded branch folds away entirely.
#[cfg(feature = "fault")]
#[macro_export]
macro_rules! failcas {
    ($p:ident) => {
        $crate::fault::hit_cas($crate::fault::Point::$p)
    };
}

/// Fire a named failpoint that can report a spurious CAS failure.
/// Expands to the constant `false` without `--features fault`.
#[cfg(not(feature = "fault"))]
#[macro_export]
macro_rules! failcas {
    ($p:ident) => {
        false
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn test_points_dense() {
        for (i, p) in Point::ALL.iter().enumerate() {
            assert_eq!(*p as usize, i, "{} out of order", p.name());
        }
        let mut names = std::collections::HashSet::new();
        for p in Point::ALL {
            assert!(names.insert(p.name()), "duplicate name {}", p.name());
        }
        assert_eq!(NUM_POINTS, Point::ALL.len());
    }

    #[test]
    fn test_kill_safety_split() {
        // The non-kill-safe set is exactly the lock-held / mid-handoff
        // windows; everything else must accept Kill rules.
        let unsafe_points = [
            Point::SeqLockWriteLocked,
            Point::SpinLockAcquired,
            Point::EpochPin,
            Point::IngressDrain,
            Point::IngressRelease,
        ];
        for p in Point::ALL {
            assert_eq!(p.kill_safe(), !unsafe_points.contains(&p), "{}", p.name());
        }
    }

    #[test]
    #[should_panic(expected = "non-kill-safe")]
    fn test_kill_rule_rejected_at_unsafe_point() {
        let _ = FaultPlan::new(1).with_rule(Rule {
            point: Point::SeqLockWriteLocked,
            action: FaultAction::Kill,
            one_in: 1,
            max: 0,
        });
    }

    #[test]
    fn test_decides_is_deterministic_and_roughly_fair() {
        let plan = FaultPlan::new(0xC0FFEE);
        let rule = Rule {
            point: Point::IngressEnqueue,
            action: FaultAction::Yield,
            one_in: 8,
            max: 0,
        };
        let mut fired = 0u64;
        for idx in 0..8000 {
            let a = plan.decides(&rule, Point::IngressEnqueue, idx);
            let b = plan.decides(&rule, Point::IngressEnqueue, idx);
            assert_eq!(a, b, "decision not deterministic at idx {idx}");
            fired += a as u64;
        }
        // ~1000 expected; generous bounds, it's a hash not a dice table.
        assert!((500..2000).contains(&fired), "fired={fired}");
    }

    #[test]
    fn test_named_plans_exist_and_unknown_rejected() {
        for name in [
            "kill-copier",
            "stall-drainer",
            "kill-worker",
            "kill-allocator",
            "kill-copier-shrink",
            "kill-migrator",
            "jitter",
        ] {
            assert!(FaultPlan::named(name, 7).is_some(), "{name} missing");
        }
        assert!(FaultPlan::named("no-such-plan", 7).is_none());
    }
}
