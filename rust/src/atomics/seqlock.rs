//! `SeqLock<T>` — sequence-lock big atomic (paper §2, the strongest
//! classic baseline in §5).
//!
//! A version word guards an inline value: odd = locked.  Loads read
//! version / value / version and retry on change; updates increment to
//! odd, write, increment to even.  Loads block only while a writer holds
//! the lock (which is why oversubscription hurts: a descheduled writer
//! stalls every reader — the paper's headline failure mode).
//!
//! ## Ordering contract
//!
//! The seqlock needs exactly four edges, all named inline below:
//! reader `ACQUIRE` on the first version read, a reader-side
//! `FENCE_ACQUIRE` between the data reads and the version re-check,
//! writer `ACQUIRE` on the lock CAS plus a writer-side `FENCE_RELEASE`
//! before the data writes, and a `RELEASE` unlock.  The writer-side
//! fence deserves a note: the seed relied on the lock CAS alone, but a
//! CAS's release half orders *prior* accesses, not the data stores that
//! follow it — without the explicit fence a reader on a weakly-ordered
//! machine can observe new data words with a stale (even) version and
//! return a torn value.  The policy parameter `P` (default
//! [`DefaultPolicy`]) exists for the ordering ablation: instantiate
//! `SeqLock<T, SeqCstEverywhere>` to measure the blanket-`SeqCst` seed
//! behavior against the diet in one binary.
//!
//! Waiting (lock acquisition, reader retry) goes through the adaptive
//! [`crate::util::backoff::Backoff`]; with backoff disabled
//! (`util::backoff::set_enabled`) it degrades to the seed's
//! spin-a-quantum-then-yield pathology that the oversubscription
//! figures measure.

use std::marker::PhantomData;
use std::sync::atomic::{fence, AtomicU64};

use super::bytewise::WordBuf;
use super::{AtomicValue, BigAtomic};
use crate::util::backoff::snooze_lazy;
use crate::util::ordering::{DefaultPolicy, OrderingPolicy};

pub struct SeqLock<T: AtomicValue, P: OrderingPolicy = DefaultPolicy> {
    version: AtomicU64,
    data: WordBuf<T>,
    _policy: PhantomData<P>,
}

impl<T: AtomicValue, P: OrderingPolicy> SeqLock<T, P> {
    /// Acquire the write lock; returns the (even) version observed.
    /// On return, the odd version is fenced before any subsequent data
    /// write (the writer-side store-store edge).
    #[inline]
    fn lock(&self) -> u64 {
        // Lazy: the uncontended acquire pays no backoff/TLS cost.
        let mut bo = None;
        loop {
            // Ordering: RELAXED — a stale read only wastes one CAS
            // attempt; the CAS itself (re)validates.
            let v = self.version.load(P::RELAXED);
            if v % 2 == 0
                && self
                    .version
                    // Ordering: ACQUIRE on success — pairs with the
                    // previous holder's RELEASE unlock so their data
                    // writes happen-before ours; RELAXED on failure
                    // (retry re-reads).
                    .compare_exchange_weak(v, v + 1, P::ACQUIRE, P::RELAXED)
                    .is_ok()
            {
                // Ordering: FENCE_RELEASE — store-store edge: the odd
                // version must be visible before any data word, else a
                // reader pairs new data with a stale even version and
                // returns a torn value (pairs with the reader's
                // FENCE_ACQUIRE).
                fence(P::FENCE_RELEASE);
                crate::counter!(LockAcquire);
                // Fault window: the version word is odd — every reader
                // and writer is blocked on this thread (NOT kill-safe).
                crate::failpoint!(SeqLockWriteLocked);
                return v;
            }
            crate::counter!(CasRetry);
            snooze_lazy(&mut bo);
        }
    }

    #[inline]
    fn unlock(&self, v: u64) {
        // Ordering: RELEASE — all data writes happen-before the even
        // version a reader ACQUIREs.
        self.version.store(v + 2, P::RELEASE);
    }
}

impl<T: AtomicValue, P: OrderingPolicy> BigAtomic<T> for SeqLock<T, P> {
    fn new(init: T) -> Self {
        Self {
            version: AtomicU64::new(0),
            data: WordBuf::new(init),
            _policy: PhantomData,
        }
    }

    #[inline]
    fn load(&self) -> T {
        // Lazy: the common single-iteration read pays no backoff cost.
        let mut bo = None;
        loop {
            // Ordering: ACQUIRE — pairs with the RELEASE unlock of the
            // writer that published version v1, making its data writes
            // visible to the reads below.
            let v1 = self.version.load(P::ACQUIRE);
            if v1 % 2 == 0 {
                let val = self.data.read_p::<P>();
                // Ordering: FENCE_ACQUIRE — load-load edge: the data
                // reads must complete before the version re-check;
                // pairs with the writer's post-lock FENCE_RELEASE so a
                // torn read implies v2 != v1.
                fence(P::FENCE_ACQUIRE);
                // Ordering: RELAXED — ordered after the data reads by
                // the fence above.
                let v2 = self.version.load(P::RELAXED);
                if v1 == v2 {
                    crate::counter!(FastPathHit);
                    return val;
                }
            }
            // A writer held (or took) the lock during the read window.
            crate::counter!(FastPathMiss);
            snooze_lazy(&mut bo);
        }
    }

    #[inline]
    fn store(&self, val: T) {
        let v = self.lock();
        self.data.write_p::<P>(val);
        self.unlock(v);
    }

    #[inline]
    fn compare_exchange(&self, expected: T, desired: T) -> Result<T, T> {
        let v = self.lock();
        let cur = self.data.read_p::<P>();
        let ok = cur == expected;
        if ok {
            self.data.write_p::<P>(desired);
        }
        self.unlock(v);
        if ok {
            Ok(cur)
        } else {
            Err(cur)
        }
    }

    /// Native exchange: one lock round-trip, exact previous value.
    #[inline]
    fn swap(&self, new: T) -> T {
        let v = self.lock();
        let cur = self.data.read_p::<P>();
        self.data.write_p::<P>(new);
        self.unlock(v);
        cur
    }

    // `fetch_update` deliberately keeps the default (load + CAS loop):
    // a native override would run the user closure while holding the
    // version lock, and the lock is not panic-safe — a panicking `f`
    // would wedge every other operation on this atomic forever.

    fn name() -> &'static str {
        "SeqLock"
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::atomics::Words;
    use crate::util::ordering::SeqCstEverywhere;
    use std::sync::{atomic::Ordering, Arc};

    #[test]
    fn test_load_store_roundtrip() {
        let a: SeqLock<Words<3>> = SeqLock::new(Words([1, 2, 3]));
        assert_eq!(a.load(), Words([1, 2, 3]));
        a.store(Words([4, 5, 6]));
        assert_eq!(a.load(), Words([4, 5, 6]));
    }

    #[test]
    fn test_compare_exchange_witness() {
        let a: SeqLock<Words<2>> = SeqLock::new(Words([0, 0]));
        // Failure witnesses the exact current value.
        assert_eq!(a.compare_exchange(Words([9, 9]), Words([1, 1])), Err(Words([0, 0])));
        assert_eq!(a.compare_exchange(Words([0, 0]), Words([1, 1])), Ok(Words([0, 0])));
        assert_eq!(a.load(), Words([1, 1]));
    }

    #[test]
    fn test_swap_and_fetch_update() {
        let a: SeqLock<Words<2>> = SeqLock::new(Words([3, 4]));
        assert_eq!(a.swap(Words([5, 6])), Words([3, 4]));
        assert_eq!(a.fetch_update(|v| Some(Words([v.0[0] + 1, v.0[1]]))), Ok(Words([5, 6])));
        assert_eq!(a.fetch_update(|_| None), Err(Words([6, 6])));
        assert_eq!(a.load(), Words([6, 6]));
    }

    #[test]
    fn test_explicit_seqcst_policy_variant() {
        // The audit-policy instantiation (used by the ordering ablation)
        // must behave identically.
        let a: SeqLock<Words<2>, SeqCstEverywhere> = SeqLock::new(Words([1, 2]));
        assert_eq!(a.load(), Words([1, 2]));
        assert_eq!(a.compare_exchange(Words([1, 2]), Words([3, 4])), Ok(Words([1, 2])));
        assert_eq!(a.swap(Words([5, 6])), Words([3, 4]));
        assert_eq!(a.load(), Words([5, 6]));
    }

    #[test]
    fn test_no_torn_reads_under_contention() {
        // Writers store (i, i, i, i); readers must never see mixed words.
        let a: Arc<SeqLock<Words<4>>> = Arc::new(SeqLock::new(Words([0; 4])));
        let stop = Arc::new(std::sync::atomic::AtomicBool::new(false));
        let readers: Vec<_> = (0..3)
            .map(|_| {
                let a = Arc::clone(&a);
                let stop = Arc::clone(&stop);
                std::thread::spawn(move || {
                    while !stop.load(Ordering::Relaxed) {
                        let v = a.load();
                        assert!(
                            v.0.iter().all(|&w| w == v.0[0]),
                            "torn read: {:?}",
                            v.0
                        );
                    }
                })
            })
            .collect();
        for i in 1..20_000u64 {
            a.store(Words([i; 4]));
        }
        stop.store(true, Ordering::SeqCst);
        for r in readers {
            r.join().unwrap();
        }
    }
}
