//! `SeqLock<T>` — sequence-lock big atomic (paper §2, the strongest
//! classic baseline in §5).
//!
//! A version word guards an inline value: odd = locked.  Loads read
//! version / value / version and retry on change; updates increment to
//! odd, write, increment to even.  Loads block only while a writer holds
//! the lock (which is why oversubscription hurts: a descheduled writer
//! stalls every reader — the paper's headline failure mode).

use std::sync::atomic::{fence, AtomicU64, Ordering};

use super::bytewise::WordBuf;
use super::{AtomicValue, BigAtomic};

// Spin a whole scheduler quantum before yielding — see spin.rs: faithful
// to the paper's (spinning) seqlock, whose readers stall behind a
// descheduled writer under oversubscription.
const SPINS_BEFORE_YIELD: u32 = 1 << 20;

pub struct SeqLock<T: AtomicValue> {
    version: AtomicU64,
    data: WordBuf<T>,
}

impl<T: AtomicValue> SeqLock<T> {
    /// Acquire the write lock; returns the (even) version observed.
    #[inline]
    fn lock(&self) -> u64 {
        let mut spins = 0u32;
        loop {
            let v = self.version.load(Ordering::Relaxed);
            if v % 2 == 0
                && self
                    .version
                    .compare_exchange_weak(v, v + 1, Ordering::Acquire, Ordering::Relaxed)
                    .is_ok()
            {
                return v;
            }
            spins += 1;
            if spins >= SPINS_BEFORE_YIELD {
                std::thread::yield_now();
                spins = 0;
            } else {
                std::hint::spin_loop();
            }
        }
    }

    #[inline]
    fn unlock(&self, v: u64) {
        self.version.store(v + 2, Ordering::Release);
    }
}

impl<T: AtomicValue> BigAtomic<T> for SeqLock<T> {
    fn new(init: T) -> Self {
        Self {
            version: AtomicU64::new(0),
            data: WordBuf::new(init),
        }
    }

    #[inline]
    fn load(&self) -> T {
        let mut spins = 0u32;
        loop {
            let v1 = self.version.load(Ordering::Acquire);
            if v1 % 2 == 0 {
                let val = self.data.read();
                fence(Ordering::Acquire);
                let v2 = self.version.load(Ordering::Relaxed);
                if v1 == v2 {
                    return val;
                }
            }
            spins += 1;
            if spins >= SPINS_BEFORE_YIELD {
                std::thread::yield_now();
                spins = 0;
            } else {
                std::hint::spin_loop();
            }
        }
    }

    #[inline]
    fn store(&self, val: T) {
        let v = self.lock();
        self.data.write(val);
        self.unlock(v);
    }

    #[inline]
    fn compare_exchange(&self, expected: T, desired: T) -> Result<T, T> {
        let v = self.lock();
        let cur = self.data.read();
        let ok = cur == expected;
        if ok {
            self.data.write(desired);
        }
        self.unlock(v);
        if ok {
            Ok(cur)
        } else {
            Err(cur)
        }
    }

    /// Native exchange: one lock round-trip, exact previous value.
    #[inline]
    fn swap(&self, new: T) -> T {
        let v = self.lock();
        let cur = self.data.read();
        self.data.write(new);
        self.unlock(v);
        cur
    }

    // `fetch_update` deliberately keeps the default (load + CAS loop):
    // a native override would run the user closure while holding the
    // version lock, and the lock is not panic-safe — a panicking `f`
    // would wedge every other operation on this atomic forever.

    fn name() -> &'static str {
        "SeqLock"
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::atomics::Words;
    use std::sync::Arc;

    #[test]
    fn test_load_store_roundtrip() {
        let a: SeqLock<Words<3>> = SeqLock::new(Words([1, 2, 3]));
        assert_eq!(a.load(), Words([1, 2, 3]));
        a.store(Words([4, 5, 6]));
        assert_eq!(a.load(), Words([4, 5, 6]));
    }

    #[test]
    fn test_compare_exchange_witness() {
        let a: SeqLock<Words<2>> = SeqLock::new(Words([0, 0]));
        // Failure witnesses the exact current value.
        assert_eq!(a.compare_exchange(Words([9, 9]), Words([1, 1])), Err(Words([0, 0])));
        assert_eq!(a.compare_exchange(Words([0, 0]), Words([1, 1])), Ok(Words([0, 0])));
        assert_eq!(a.load(), Words([1, 1]));
    }

    #[test]
    fn test_swap_and_fetch_update() {
        let a: SeqLock<Words<2>> = SeqLock::new(Words([3, 4]));
        assert_eq!(a.swap(Words([5, 6])), Words([3, 4]));
        assert_eq!(a.fetch_update(|v| Some(Words([v.0[0] + 1, v.0[1]]))), Ok(Words([5, 6])));
        assert_eq!(a.fetch_update(|_| None), Err(Words([6, 6])));
        assert_eq!(a.load(), Words([6, 6]));
    }

    #[test]
    fn test_no_torn_reads_under_contention() {
        // Writers store (i, i, i, i); readers must never see mixed words.
        let a: Arc<SeqLock<Words<4>>> = Arc::new(SeqLock::new(Words([0; 4])));
        let stop = Arc::new(std::sync::atomic::AtomicBool::new(false));
        let readers: Vec<_> = (0..3)
            .map(|_| {
                let a = Arc::clone(&a);
                let stop = Arc::clone(&stop);
                std::thread::spawn(move || {
                    while !stop.load(Ordering::Relaxed) {
                        let v = a.load();
                        assert!(
                            v.0.iter().all(|&w| w == v.0[0]),
                            "torn read: {:?}",
                            v.0
                        );
                    }
                })
            })
            .collect();
        for i in 1..20_000u64 {
            a.store(Words([i; 4]));
        }
        stop.store(true, Ordering::SeqCst);
        for r in readers {
            r.join().unwrap();
        }
    }
}
