//! Bytewise-atomic (word-wise) loads and stores.
//!
//! The paper's algorithms read and write the inlined cache with
//! "bytewise-atomic" memory operations: individually atomic word accesses
//! whose *combination* is made consistent by the surrounding version
//! protocol.  In Rust (as in C++, Boehm [11]) the UB-free rendering is
//! relaxed per-word atomic accesses through `AtomicU64`, with the seqlock
//! version check deciding whether the assembled value is used.
//!
//! `WordBuf<T>` is the inline storage: an `UnsafeCell<T>` whose words are
//! accessed as `AtomicU64`s. It adds zero indirection — the whole point
//! of the paper's cached fast path.
//!
//! ## Ordering contract
//!
//! Word accesses are `P::RELAXED` (plain `Relaxed` on the default
//! [`Fenced`](crate::util::ordering::Fenced) policy, `SeqCst` under the
//! `seqcst_audit` feature).  Relaxed is sound **only** inside a seqlock
//! bracket: the caller must order these accesses with the version word —
//! readers via `version(Acquire) … read … fence(Acquire) …
//! version(Relaxed)`, writers via `lock-CAS(Acquire) … fence(Release) …
//! write … unlock-store(Release)`.  The fences are the load-load and
//! store-store edges per-word `Relaxed` cannot provide; without them a
//! reader can assemble a torn value *and* miss the version bump that
//! would discard it.

use std::cell::UnsafeCell;
use std::mem::MaybeUninit;
use std::sync::atomic::AtomicU64;

use super::AtomicValue;
use crate::util::ordering::{DefaultPolicy, OrderingPolicy};

/// Inline k-word storage with word-wise atomic access.
#[repr(C)]
pub struct WordBuf<T: AtomicValue> {
    data: UnsafeCell<T>,
}

// SAFETY: all access goes through word-wise atomics.
unsafe impl<T: AtomicValue> Send for WordBuf<T> {}
unsafe impl<T: AtomicValue> Sync for WordBuf<T> {}

impl<T: AtomicValue> WordBuf<T> {
    pub fn new(init: T) -> Self {
        debug_assert_eq!(std::mem::align_of::<T>(), 8);
        debug_assert!(std::mem::size_of::<T>() % 8 == 0 && std::mem::size_of::<T>() > 0);
        Self {
            data: UnsafeCell::new(init),
        }
    }

    #[inline]
    fn words(&self) -> *const AtomicU64 {
        // SAFETY: AtomicU64 is repr(transparent) over u64; T is pod with
        // align 8 and size a multiple of 8 (AtomicValue contract).
        self.data.get() as *const AtomicU64
    }

    /// Word-wise read under the crate default policy. See [`read_p`](Self::read_p).
    #[inline]
    pub fn read(&self) -> T {
        self.read_p::<DefaultPolicy>()
    }

    /// Word-wise `P::RELAXED` read of the whole value. The caller's
    /// version protocol decides whether the (possibly torn) result is
    /// used — see the module-level ordering contract.
    #[inline]
    pub fn read_p<P: OrderingPolicy>(&self) -> T {
        let mut out = MaybeUninit::<T>::uninit();
        let src = self.words();
        let dst = out.as_mut_ptr() as *mut u64;
        for i in 0..T::WORDS {
            // Ordering: RELAXED — atomicity per word only; the seqlock
            // bracket (Acquire version read before, Acquire fence +
            // version re-check after) discards torn assemblies.
            // SAFETY: i < WORDS words of valid storage on both sides.
            unsafe { *dst.add(i) = (*src.add(i)).load(P::RELAXED) };
        }
        // SAFETY: T is pod (AtomicValue) — any word combination is a
        // valid bit pattern; torn values are discarded by the caller.
        unsafe { out.assume_init() }
    }

    /// Word-wise write under the crate default policy. See [`write_p`](Self::write_p).
    #[inline]
    pub fn write(&self, val: T) {
        self.write_p::<DefaultPolicy>(val)
    }

    /// Word-wise `P::RELAXED` write. Caller must hold the write side of
    /// the version protocol (seqlock lock bit etc.) and must have issued
    /// a Release fence after taking it — see the module-level contract.
    #[inline]
    pub fn write_p<P: OrderingPolicy>(&self, val: T) {
        let dst = self.words();
        let src = &val as *const T as *const u64;
        for i in 0..T::WORDS {
            // Ordering: RELAXED — the writer's post-lock Release fence
            // orders the odd version before these stores, and the
            // Release unlock orders them before the even version.
            // SAFETY: as in read_p().
            unsafe { (*dst.add(i)).store(*src.add(i), P::RELAXED) };
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::atomics::Words;
    use crate::util::ordering::SeqCstEverywhere;

    #[test]
    fn test_read_write_roundtrip() {
        let buf: WordBuf<Words<4>> = WordBuf::new(Words([1, 2, 3, 4]));
        assert_eq!(buf.read(), Words([1, 2, 3, 4]));
        buf.write(Words([9, 8, 7, 6]));
        assert_eq!(buf.read(), Words([9, 8, 7, 6]));
    }

    #[test]
    fn test_single_word() {
        let buf: WordBuf<Words<1>> = WordBuf::new(Words([42]));
        assert_eq!(buf.read(), Words([42]));
        buf.write(Words([7]));
        assert_eq!(buf.read(), Words([7]));
    }

    #[test]
    fn test_explicit_policy_roundtrip() {
        // The audit policy must be usable explicitly regardless of the
        // build's default (the ordering ablation instantiates it).
        let buf: WordBuf<Words<2>> = WordBuf::new(Words([1, 2]));
        buf.write_p::<SeqCstEverywhere>(Words([3, 4]));
        assert_eq!(buf.read_p::<SeqCstEverywhere>(), Words([3, 4]));
    }

    #[test]
    fn test_no_indirection() {
        // The buffer must be exactly the value, inline (fast-path claim).
        assert_eq!(
            std::mem::size_of::<WordBuf<Words<8>>>(),
            std::mem::size_of::<Words<8>>()
        );
    }
}
