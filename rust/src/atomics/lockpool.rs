//! `LockPool<T>` — big atomic guarded by a small *shared* pool of locks
//! keyed by address, the mechanism GNU libatomic uses for
//! `std::atomic<T>` beyond two words (paper §5.1: "a very small set of
//! shared locks causing very high contention").
//!
//! Deliberately faithful to the pathology: unrelated atomics that hash to
//! the same pool entry contend with each other, which is why libatomic
//! is "dead last" across the paper's benchmarks.
//!
//! ## Ordering contract
//!
//! As in `SimpLock`: plain data guarded entirely by the pool
//! [`SpinLock`]'s `ACQUIRE`/`RELEASE` pair — the lock is shared across
//! unrelated atomics, but the happens-before edge per critical section
//! is the same. Waiting uses the adaptive `util::backoff::Backoff`
//! inside `SpinLock::lock`.

use std::cell::UnsafeCell;

use super::spin::SpinLock;
use super::{AtomicValue, BigAtomic};
use crate::util::rng::mix64;

/// Pool size: libatomic uses a page of locks (64 on common builds).
const POOL: usize = 64;

static LOCKS: [SpinLock; POOL] = {
    #[allow(clippy::declare_interior_mutable_const)]
    const L: SpinLock = SpinLock::new();
    [L; POOL]
};

#[inline]
fn lock_for(addr: usize) -> &'static SpinLock {
    // libatomic hashes the object address; mix to spread allocations.
    &LOCKS[(mix64(addr as u64) as usize) % POOL]
}

pub struct LockPool<T: AtomicValue> {
    data: UnsafeCell<T>,
}

// SAFETY: data only touched under the pool lock for self's address.
unsafe impl<T: AtomicValue> Send for LockPool<T> {}
unsafe impl<T: AtomicValue> Sync for LockPool<T> {}

impl<T: AtomicValue> LockPool<T> {
    #[inline]
    fn lock(&self) -> &'static SpinLock {
        lock_for(self.data.get() as usize)
    }
}

impl<T: AtomicValue> BigAtomic<T> for LockPool<T> {
    fn new(init: T) -> Self {
        Self {
            data: UnsafeCell::new(init),
        }
    }

    #[inline]
    fn load(&self) -> T {
        // SAFETY: exclusive under the address's pool lock.
        self.lock().with(|| unsafe { *self.data.get() })
    }

    #[inline]
    fn store(&self, val: T) {
        self.lock().with(|| unsafe { *self.data.get() = val });
    }

    #[inline]
    fn compare_exchange(&self, expected: T, desired: T) -> Result<T, T> {
        self.lock().with(|| {
            // SAFETY: exclusive under the address's pool lock.
            let cur = unsafe { *self.data.get() };
            if cur == expected {
                unsafe { *self.data.get() = desired };
                Ok(cur)
            } else {
                Err(cur)
            }
        })
    }

    /// Native exchange under the pool lock.
    ///
    /// `fetch_update` deliberately keeps the default (load + CAS loop):
    /// the locks here are *shared* across unrelated atomics, so running
    /// a user closure under one invites cross-object deadlock — the
    /// same reason libatomic exposes no closure primitive.
    #[inline]
    fn swap(&self, new: T) -> T {
        self.lock().with(|| {
            // SAFETY: exclusive under the address's pool lock.
            let cur = unsafe { *self.data.get() };
            unsafe { *self.data.get() = new };
            cur
        })
    }

    fn name() -> &'static str {
        "LockPool(std::atomic)"
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::atomics::Words;
    use std::sync::Arc;

    #[test]
    fn test_roundtrip() {
        let a: LockPool<Words<4>> = LockPool::new(Words([1, 2, 3, 4]));
        assert_eq!(a.load(), Words([1, 2, 3, 4]));
        a.store(Words([5, 6, 7, 8]));
        assert_eq!(
            a.compare_exchange(Words([5, 6, 7, 8]), Words([0, 0, 0, 1])),
            Ok(Words([5, 6, 7, 8]))
        );
        assert_eq!(
            a.compare_exchange(Words([5, 6, 7, 8]), Words([9; 4])),
            Err(Words([0, 0, 0, 1]))
        );
        assert_eq!(a.load(), Words([0, 0, 0, 1]));
    }

    #[test]
    fn test_distinct_atomics_share_pool_correctly() {
        // Two atomics that may share a pool lock must still be correct.
        let a: Arc<LockPool<Words<1>>> = Arc::new(LockPool::new(Words([0])));
        let b: Arc<LockPool<Words<1>>> = Arc::new(LockPool::new(Words([0])));
        let handles: Vec<_> = (0..4)
            .map(|i| {
                let a = Arc::clone(&a);
                let b = Arc::clone(&b);
                std::thread::spawn(move || {
                    let target = if i % 2 == 0 { a } else { b };
                    for _ in 0..5_000 {
                        let _ = target
                            .fetch_update(|v| Some(Words([v.0[0] + 1])))
                            .expect("unconditional update");
                    }
                })
            })
            .collect();
        for h in handles {
            h.join().unwrap();
        }
        assert_eq!(a.load().0[0] + b.load().0[0], 20_000);
    }
}
