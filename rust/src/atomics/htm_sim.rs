//! `HtmSim<T>` — big atomic via (simulated) hardware transactional
//! memory, the §5.4 comparison point.
//!
//! Real Intel RTM has been fused off since 2021 (the paper itself had to
//! use a legacy four-socket machine), so this is a behavioural software
//! simulation — see DESIGN.md §Substitutions.  It preserves the dynamics
//! the paper measures:
//!
//! * optimistic execution that commits iff no conflicting writer ran
//!   (per-atomic version validation — the cache-line-granularity
//!   conflict detection of RTM at this object's granularity);
//! * **bounded retries** ([`MAX_TX_RETRIES`], the paper uses 10) with no
//!   waiting between attempts — aborts are wasted work, which is why HTM
//!   collapses as contention rises (§5.4);
//! * a **spinlock fallback** after exhausting retries (RTM is never
//!   guaranteed to commit), mutually excluded with transactions: a held
//!   fallback aborts all in-flight transactions, exactly like the
//!   lock-subscription idiom real RTM code uses.

use std::sync::atomic::{fence, AtomicU64, Ordering};

use super::bytewise::WordBuf;
use super::spin::SpinLock;
use super::{AtomicValue, BigAtomic};

/// Transaction attempts before taking the fallback lock (paper: 10).
pub const MAX_TX_RETRIES: usize = 10;

pub struct HtmSim<T: AtomicValue> {
    /// Even = no writer committing; odd = commit in progress.
    version: AtomicU64,
    fallback: SpinLock,
    data: WordBuf<T>,
}

impl<T: AtomicValue> HtmSim<T> {
    /// "Transaction begin": returns the snapshot version, or None
    /// (= abort) if a writer or fallback holder is active.
    #[inline]
    fn tx_begin(&self) -> Option<u64> {
        if self.fallback.is_locked() {
            return None;
        }
        let v = self.version.load(Ordering::Acquire);
        if v % 2 != 0 {
            return None;
        }
        Some(v)
    }

    /// "Transaction commit" for read-only transactions: validate no
    /// conflicting commit and no fallback acquisition happened.
    #[inline]
    fn tx_validate(&self, v: u64) -> bool {
        fence(Ordering::Acquire);
        self.version.load(Ordering::Relaxed) == v && !self.fallback.is_locked()
    }

    /// Acquire exclusive access on the fallback path: take the lock and
    /// the version (odd), aborting all concurrent transactions.
    fn fallback_enter(&self) -> u64 {
        self.fallback.lock();
        loop {
            let v = self.version.load(Ordering::Relaxed);
            if v % 2 == 0
                && self
                    .version
                    .compare_exchange(v, v + 1, Ordering::Acquire, Ordering::Relaxed)
                    .is_ok()
            {
                return v;
            }
            std::hint::spin_loop();
        }
    }

    fn fallback_exit(&self, v: u64) {
        self.version.store(v + 2, Ordering::Release);
        self.fallback.unlock();
    }

    /// Run `op` transactionally; `op` gets the current value and returns
    /// the value to write (or None for read-only). Returns the value
    /// read by the successful attempt.
    fn transact<F: FnMut(T) -> Option<T>>(&self, mut op: F) -> T {
        for _ in 0..MAX_TX_RETRIES {
            let Some(v) = self.tx_begin() else {
                std::hint::spin_loop();
                continue;
            };
            let cur = self.data.read();
            match op(cur) {
                None => {
                    if self.tx_validate(v) {
                        return cur; // read-only commit
                    }
                }
                Some(next) => {
                    // Write transaction: "commit" = CAS the version to
                    // odd (conflict detection), apply, release.
                    if self
                        .version
                        .compare_exchange(v, v + 1, Ordering::AcqRel, Ordering::Relaxed)
                        .is_ok()
                    {
                        if self.fallback.is_locked() {
                            // Fallback holder appeared: abort (undo lock).
                            self.version.store(v, Ordering::Release);
                            continue;
                        }
                        self.data.write(next);
                        self.version.store(v + 2, Ordering::Release);
                        return cur;
                    }
                }
            }
            // Abort: retry immediately (RTM has no intrinsic backoff).
        }
        // Fallback path.
        let v = self.fallback_enter();
        let cur = self.data.read();
        if let Some(next) = op(cur) {
            self.data.write(next);
        }
        self.fallback_exit(v);
        cur
    }
}

impl<T: AtomicValue> BigAtomic<T> for HtmSim<T> {
    fn new(init: T) -> Self {
        Self {
            version: AtomicU64::new(0),
            fallback: SpinLock::new(),
            data: WordBuf::new(init),
        }
    }

    #[inline]
    fn load(&self) -> T {
        self.transact(|_| None)
    }

    #[inline]
    fn store(&self, val: T) {
        self.transact(|_| Some(val));
    }

    #[inline]
    fn compare_exchange(&self, expected: T, desired: T) -> Result<T, T> {
        // AA rule: an equal desired commits read-only — a physical
        // rewrite of identical bytes would bump the version and
        // spuriously abort every concurrent transaction for nothing.
        let seen = self.transact(|cur| {
            if cur == expected && expected != desired {
                Some(desired)
            } else {
                None
            }
        });
        if seen == expected {
            Ok(seen)
        } else {
            Err(seen) // the value the committed transaction read — exact
        }
    }

    /// Native exchange: one write transaction, previous value from the
    /// committed read.
    #[inline]
    fn swap(&self, new: T) -> T {
        self.transact(|_| Some(new))
    }

    // `fetch_update` keeps the default (load + CAS loop): a native
    // override would run the user closure inside `transact`, whose
    // fallback path holds the non-panic-safe fallback lock — a
    // panicking `f` would wedge the atomic. The internal closures used
    // by load/store/compare_exchange/swap never panic, so those stay
    // transactional.

    fn name() -> &'static str {
        "HTM(sim)"
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::atomics::Words;
    use std::sync::Arc;

    #[test]
    fn test_roundtrip_and_cas() {
        let a: HtmSim<Words<2>> = HtmSim::new(Words([1, 2]));
        assert_eq!(a.load(), Words([1, 2]));
        a.store(Words([3, 4]));
        assert_eq!(a.compare_exchange(Words([3, 4]), Words([5, 6])), Ok(Words([3, 4])));
        assert_eq!(a.compare_exchange(Words([3, 4]), Words([7, 8])), Err(Words([5, 6])));
        assert_eq!(a.load(), Words([5, 6]));
    }

    #[test]
    fn test_concurrent_cas_counter() {
        let a: Arc<HtmSim<Words<3>>> = Arc::new(HtmSim::new(Words([0; 3])));
        let threads = 4;
        let per = 4_000u64;
        let handles: Vec<_> = (0..threads)
            .map(|_| {
                let a = Arc::clone(&a);
                std::thread::spawn(move || {
                    for _ in 0..per {
                        let _ = a
                            .fetch_update(|cur| {
                                Some(Words([cur.0[0] + 1, cur.0[1] + 2, cur.0[2] + 3]))
                            })
                            .expect("unconditional update");
                    }
                })
            })
            .collect();
        for h in handles {
            h.join().unwrap();
        }
        let v = a.load();
        assert_eq!(v.0[0], threads as u64 * per);
        assert_eq!(v.0[1], 2 * threads as u64 * per);
    }

    #[test]
    fn test_no_torn_reads() {
        let a: Arc<HtmSim<Words<4>>> = Arc::new(HtmSim::new(Words([0; 4])));
        let stop = Arc::new(std::sync::atomic::AtomicBool::new(false));
        let readers: Vec<_> = (0..2)
            .map(|_| {
                let a = Arc::clone(&a);
                let stop = Arc::clone(&stop);
                std::thread::spawn(move || {
                    while !stop.load(Ordering::Relaxed) {
                        let v = a.load();
                        assert!(v.0.iter().all(|&w| w == v.0[0]), "torn: {:?}", v.0);
                    }
                })
            })
            .collect();
        for i in 1..10_000u64 {
            a.store(Words([i; 4]));
        }
        stop.store(true, Ordering::SeqCst);
        for r in readers {
            r.join().unwrap();
        }
    }
}
