//! `HtmSim<T>` — big atomic via (simulated) hardware transactional
//! memory, the §5.4 comparison point.
//!
//! Real Intel RTM has been fused off since 2021 (the paper itself had to
//! use a legacy four-socket machine), so this is a behavioural software
//! simulation — see DESIGN.md §Substitutions.  It preserves the dynamics
//! the paper measures:
//!
//! * optimistic execution that commits iff no conflicting writer ran
//!   (per-atomic version validation — the cache-line-granularity
//!   conflict detection of RTM at this object's granularity);
//! * **bounded retries** ([`MAX_TX_RETRIES`], the paper uses 10) with a
//!   **spurious-abort** path: like `compare_exchange_weak` (and like
//!   real RTM, which aborts on interrupts, capacity, and false sharing),
//!   a transaction can fail even without a logical conflict — so the
//!   retry loop and the contention-management layer get exercised
//!   realistically ([`spurious_aborts`] counts them);
//! * retries go through the adaptive [`Backoff`] (Dice et al.): raw RTM
//!   has no intrinsic backoff — the seed retried bare, which is exactly
//!   why HTM collapses as contention rises (§5.4).  Disable backoff
//!   (`util::backoff::set_enabled(false)`) to recover that behavior;
//! * a **spinlock fallback** after exhausting retries (RTM is never
//!   guaranteed to commit), mutually excluded with transactions: a held
//!   fallback aborts all in-flight transactions, exactly like the
//!   lock-subscription idiom real RTM code uses.
//!
//! ## Ordering contract
//!
//! The version word is a seqlock: read-only transactions use the reader
//! protocol (`ACQUIRE` begin, `FENCE_ACQUIRE` + `RELAXED` validate),
//! write commits use the writer protocol (`ACQREL` commit-CAS,
//! `FENCE_RELEASE` before the data writes, `RELEASE` version release).
//! Fallback-lock subscription reads are `RELAXED` — they are a fairness
//! signal only; exclusion is enforced by the version word.

use std::cell::Cell;
use std::sync::atomic::{fence, AtomicU64, Ordering};

use super::bytewise::WordBuf;
use super::spin::SpinLock;
use super::{AtomicValue, BigAtomic};
use crate::util::backoff::{snooze_lazy, Backoff};
use crate::util::ordering::{DefaultPolicy as P, OrderingPolicy};

/// Transaction attempts before taking the fallback lock (paper: 10).
pub const MAX_TX_RETRIES: usize = 10;

/// 1-in-2^SPURIOUS_SHIFT transaction attempts abort spuriously.
const SPURIOUS_SHIFT: u32 = 7;

/// Process-wide count of injected spurious aborts (observability + tests).
static SPURIOUS_ABORTS: AtomicU64 = AtomicU64::new(0);

/// Total spurious aborts injected so far, process-wide.
pub fn spurious_aborts() -> u64 {
    SPURIOUS_ABORTS.load(Ordering::Relaxed)
}

/// `compare_exchange_weak`-style spurious failure: a cheap thread-local
/// xorshift decides whether this attempt aborts for no logical reason
/// (≈ 1/128 of attempts).
#[inline]
fn spurious_abort() -> bool {
    thread_local! {
        static STATE: Cell<u64> = const { Cell::new(0x9E37_79B9_7F4A_7C15) };
    }
    STATE.with(|s| {
        let mut x = s.get();
        x ^= x << 13;
        x ^= x >> 7;
        x ^= x << 17;
        s.set(x);
        let hit = x & ((1 << SPURIOUS_SHIFT) - 1) == 0;
        if hit {
            SPURIOUS_ABORTS.fetch_add(1, Ordering::Relaxed);
        }
        hit
    })
}

pub struct HtmSim<T: AtomicValue> {
    /// Even = no writer committing; odd = commit in progress.
    version: AtomicU64,
    fallback: SpinLock,
    data: WordBuf<T>,
}

impl<T: AtomicValue> HtmSim<T> {
    /// "Transaction begin": returns the snapshot version, or None
    /// (= abort) if a writer or fallback holder is active.
    #[inline]
    fn tx_begin(&self) -> Option<u64> {
        if self.fallback.is_locked() {
            return None;
        }
        // Ordering: ACQUIRE — pairs with the committing writer's RELEASE
        // version release, so the data this transaction reads is at
        // least as new as version v.
        let v = self.version.load(P::ACQUIRE);
        if v % 2 != 0 {
            return None;
        }
        Some(v)
    }

    /// "Transaction commit" for read-only transactions: validate no
    /// conflicting commit and no fallback acquisition happened.
    #[inline]
    fn tx_validate(&self, v: u64) -> bool {
        // Ordering: FENCE_ACQUIRE — load-load edge: the data reads must
        // complete before this validation read; pairs with the writer's
        // post-commit-CAS FENCE_RELEASE.
        fence(P::FENCE_ACQUIRE);
        // Ordering: RELAXED — ordered by the fence above.
        self.version.load(P::RELAXED) == v && !self.fallback.is_locked()
    }

    /// Acquire exclusive access on the fallback path: take the lock and
    /// the version (odd), aborting all concurrent transactions.
    fn fallback_enter(&self) -> u64 {
        self.fallback.lock();
        let mut bo = Backoff::new();
        loop {
            // Ordering: RELAXED — the CAS re-validates.
            let v = self.version.load(P::RELAXED);
            if v % 2 == 0
                && self
                    .version
                    // Ordering: ACQUIRE on success — pairs with the
                    // previous committer's RELEASE; RELAXED failure.
                    .compare_exchange(v, v + 1, P::ACQUIRE, P::RELAXED)
                    .is_ok()
            {
                // Ordering: FENCE_RELEASE — odd version visible before
                // the fallback path's data writes (seqlock writer edge).
                fence(P::FENCE_RELEASE);
                return v;
            }
            bo.snooze();
        }
    }

    fn fallback_exit(&self, v: u64) {
        // Ordering: RELEASE — fallback data writes happen-before the
        // even version (and before the lock release below).
        self.version.store(v + 2, P::RELEASE);
        self.fallback.unlock();
    }

    /// Run `op` transactionally; `op` gets the current value and returns
    /// the value to write (or None for read-only). Returns the value
    /// read by the successful attempt.
    fn transact<F: FnMut(T) -> Option<T>>(&self, mut op: F) -> T {
        // Lazy: a first-attempt commit pays no backoff/TLS cost.
        let mut bo = None;
        for _ in 0..MAX_TX_RETRIES {
            // Fault window: attempt about to begin — a yield/delay here
            // widens the conflict window (more aborts, more fallback
            // takes); kill-safe because no version state is held yet.
            crate::failpoint!(HtmTxCommit);
            let Some(v) = self.tx_begin() else {
                crate::counter!(TxRetry);
                snooze_lazy(&mut bo);
                continue;
            };
            if spurious_abort() {
                // compare_exchange_weak-style failure: no conflict, but
                // the attempt dies anyway (interrupt/capacity in real
                // RTM). Costs one backoff step like any abort.
                crate::counter!(TxRetry);
                snooze_lazy(&mut bo);
                continue;
            }
            let cur = self.data.read_p::<P>();
            match op(cur) {
                None => {
                    if self.tx_validate(v) {
                        return cur; // read-only commit
                    }
                }
                Some(next) => {
                    // Write transaction: "commit" = CAS the version to
                    // odd (conflict detection), apply, release.
                    // Ordering: ACQREL on success — ACQUIRE pairs with
                    // the previous committer's RELEASE (we overwrite
                    // their data), RELEASE orders our pre-CAS reads
                    // before the odd version; RELAXED failure (abort).
                    if self
                        .version
                        .compare_exchange(v, v + 1, P::ACQREL, P::RELAXED)
                        .is_ok()
                    {
                        if self.fallback.is_locked() {
                            // Fallback holder appeared: abort (undo lock).
                            // Ordering: RELEASE — nothing written yet,
                            // but the even version must not be reordered
                            // before the CAS above.
                            self.version.store(v, P::RELEASE);
                            crate::counter!(TxRetry);
                            snooze_lazy(&mut bo);
                            continue;
                        }
                        // Ordering: FENCE_RELEASE — seqlock writer edge:
                        // odd version visible before any data word, so
                        // readers pair torn data with a changed version.
                        fence(P::FENCE_RELEASE);
                        self.data.write_p::<P>(next);
                        // Ordering: RELEASE — data writes happen-before
                        // the even version readers ACQUIRE.
                        self.version.store(v + 2, P::RELEASE);
                        return cur;
                    }
                }
            }
            // Abort: back off before retrying (Dice et al. — the seed
            // retried bare, which is RTM-faithful but collapses under
            // contention; disable backoff to measure that).
            crate::counter!(TxRetry);
            snooze_lazy(&mut bo);
        }
        // Fallback path.
        crate::counter!(TxFallback);
        let v = self.fallback_enter();
        let cur = self.data.read_p::<P>();
        if let Some(next) = op(cur) {
            self.data.write_p::<P>(next);
        }
        self.fallback_exit(v);
        cur
    }
}

impl<T: AtomicValue> BigAtomic<T> for HtmSim<T> {
    fn new(init: T) -> Self {
        Self {
            version: AtomicU64::new(0),
            fallback: SpinLock::new(),
            data: WordBuf::new(init),
        }
    }

    #[inline]
    fn load(&self) -> T {
        self.transact(|_| None)
    }

    #[inline]
    fn store(&self, val: T) {
        self.transact(|_| Some(val));
    }

    #[inline]
    fn compare_exchange(&self, expected: T, desired: T) -> Result<T, T> {
        // AA rule: an equal desired commits read-only — a physical
        // rewrite of identical bytes would bump the version and
        // spuriously abort every concurrent transaction for nothing.
        let seen = self.transact(|cur| {
            if cur == expected && expected != desired {
                Some(desired)
            } else {
                None
            }
        });
        if seen == expected {
            Ok(seen)
        } else {
            Err(seen) // the value the committed transaction read — exact
        }
    }

    /// Native exchange: one write transaction, previous value from the
    /// committed read.
    #[inline]
    fn swap(&self, new: T) -> T {
        self.transact(|_| Some(new))
    }

    // `fetch_update` keeps the default (load + CAS loop): a native
    // override would run the user closure inside `transact`, whose
    // fallback path holds the non-panic-safe fallback lock — a
    // panicking `f` would wedge the atomic. The internal closures used
    // by load/store/compare_exchange/swap never panic, so those stay
    // transactional.

    fn name() -> &'static str {
        "HTM(sim)"
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::atomics::Words;
    use std::sync::Arc;

    #[test]
    fn test_roundtrip_and_cas() {
        let a: HtmSim<Words<2>> = HtmSim::new(Words([1, 2]));
        assert_eq!(a.load(), Words([1, 2]));
        a.store(Words([3, 4]));
        assert_eq!(a.compare_exchange(Words([3, 4]), Words([5, 6])), Ok(Words([3, 4])));
        assert_eq!(a.compare_exchange(Words([3, 4]), Words([7, 8])), Err(Words([5, 6])));
        assert_eq!(a.load(), Words([5, 6]));
    }

    #[test]
    fn test_spurious_aborts_fire_and_are_survivable() {
        // ~1/128 of attempts abort spuriously: across 20k single-thread
        // ops the injector must have fired, and every op still completed
        // with the right answer (retry loop + fallback absorb them).
        let a: HtmSim<Words<2>> = HtmSim::new(Words([0, 0]));
        let before = spurious_aborts();
        for i in 1..20_000u64 {
            a.store(Words([i, i]));
            debug_assert_eq!(a.load(), Words([i, i]));
        }
        assert_eq!(a.load(), Words([19_999, 19_999]));
        assert!(
            spurious_aborts() > before,
            "spurious-abort path never exercised"
        );
    }

    #[test]
    fn test_concurrent_cas_counter() {
        let a: Arc<HtmSim<Words<3>>> = Arc::new(HtmSim::new(Words([0; 3])));
        let threads = 4;
        let per = 4_000u64;
        let handles: Vec<_> = (0..threads)
            .map(|_| {
                let a = Arc::clone(&a);
                std::thread::spawn(move || {
                    for _ in 0..per {
                        let _ = a
                            .fetch_update(|cur| {
                                Some(Words([cur.0[0] + 1, cur.0[1] + 2, cur.0[2] + 3]))
                            })
                            .expect("unconditional update");
                    }
                })
            })
            .collect();
        for h in handles {
            h.join().unwrap();
        }
        let v = a.load();
        assert_eq!(v.0[0], threads as u64 * per);
        assert_eq!(v.0[1], 2 * threads as u64 * per);
    }

    #[test]
    fn test_no_torn_reads() {
        let a: Arc<HtmSim<Words<4>>> = Arc::new(HtmSim::new(Words([0; 4])));
        let stop = Arc::new(std::sync::atomic::AtomicBool::new(false));
        let readers: Vec<_> = (0..2)
            .map(|_| {
                let a = Arc::clone(&a);
                let stop = Arc::clone(&stop);
                std::thread::spawn(move || {
                    while !stop.load(Ordering::Relaxed) {
                        let v = a.load();
                        assert!(v.0.iter().all(|&w| w == v.0[0]), "torn: {:?}", v.0);
                    }
                })
            })
            .collect();
        for i in 1..10_000u64 {
            a.store(Words([i; 4]));
        }
        stop.store(true, Ordering::SeqCst);
        for r in readers {
            r.join().unwrap();
        }
    }
}
