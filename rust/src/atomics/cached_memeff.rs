//! `CachedMemEff<T>` — **Algorithm 2**: the paper's lock-free,
//! memory-efficient big atomic supporting `load`, `store`, and a
//! witnessing `compare_exchange` (§3.2) — the implementation that wins
//! the paper's evaluation. Its `Err` witness is exact (never equal to
//! `expected`): the install loop retries internally until it either
//! wins or reads a definitely different value.
//!
//! Differences from Algorithm 1:
//! * the backup pointer is *usually null*: after an update's value is
//!   copied to the cache, the backup is replaced by a **tagged null**
//!   (a version number with the low bit set) — so the steady state
//!   stores only the inline value (`nk + O(n + p(p+k))` total space,
//!   with the node pool independent of the number of atomics);
//! * updates **help** each other re-cache until the backup is null again
//!   ("re-caching until success"), so the number of live backup nodes is
//!   bounded by the number of in-progress writes;
//! * nodes come from **thread-private slabs** recycled by a custom
//!   hazard-pointer scheme with two owner-private flags
//!   (`was_installed` / `is_protected`) — the paper's §3.2 recycler,
//!   including the subtle two-phase rule (snapshot `is_installed`
//!   *before* scanning announcements).
//!
//! ## Ordering contract
//!
//! Every demoted site names its edge inline; the shape is:
//! * **seqlock** over `version`+`cache` (reader `ACQUIRE` /
//!   `FENCE_ACQUIRE` / `RELAXED` re-check; writer `ACQUIRE` lock-CAS,
//!   `FENCE_RELEASE`, `RELEASE` unlock) — `load`'s fast path,
//!   `try_load_indirect`'s cached branch, and `try_seqlock`;
//! * **node publication**: the install CAS and the null-restoring CAS
//!   are `RELEASE`, paired with the `ACQUIRE` validating load in
//!   `protect_backup`;
//! * **recycler flags**: `is_installed` is `RELEASE`-stored /
//!   `ACQUIRE`-snapshotted; `was_installed` / `is_protected` / `in_free`
//!   are owner-private `RELAXED`. The snapshot-before-scan edge of the
//!   two-phase rule is the mandatory `SeqCst` fence inside the scheme's
//!   [`Smr::reclaim_protected`] (hazard `protected_snapshot` /
//!   epoch advance — see `smr`), sequenced after phase 1.
//!
//! The ordering policy `P` (default [`DefaultPolicy`]) is threaded
//! through the whole algorithm *and* its shared domain, so the ordering
//! ablation can instantiate a blanket-`SeqCst`
//! `CachedMemEff<T, SeqCstEverywhere>` inside a fenced binary; the
//! scheme parameter `S` (default hazard) picks the reclamation scheme
//! the slab recycler answers to — see the recycler hooks on [`Smr`].

use std::any::{Any, TypeId};
use std::collections::HashMap;
use std::sync::atomic::{fence, AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::{Arc, Mutex, OnceLock};

use super::bytewise::WordBuf;
use super::{AtomicValue, BigAtomic};
use crate::smr::{Hazard, Smr};
use crate::util::backoff::{snooze_lazy, Backoff};
use crate::util::ordering::{DefaultPolicy, OrderingPolicy};
use crate::util::registry::tid;
use crate::util::CachePadded;
use crate::MAX_THREADS;

/// Slab capacity per thread: 3p (paper §3.2 — at most p installed +
/// p installed-during-scan + p protected, so a full scan of 3p nodes
/// always recovers at least p).  Grown lazily; exceeding it is tolerated
/// (with accounting) rather than fatal, since MAX_THREADS bounds p from
/// far above the benchmark's actual thread counts.
const SLAB_CAP: usize = 3 * MAX_THREADS;

const TAG: usize = 1;

#[inline]
fn tagged_null(version: u64) -> usize {
    ((version as usize) << 1) | TAG
}

#[inline]
fn is_null(raw: usize) -> bool {
    raw & TAG == TAG
}

/// A pool node. `value` uses word-wise atomics because a stale (but
/// guard-protected) reader may still be reading while the owner has not
/// yet recycled it; all flag traffic is explicit.
#[repr(C, align(8))]
pub(crate) struct Node<T: AtomicValue> {
    value: WordBuf<T>,
    /// Set by the installer; cleared by whoever uninstalls the node from
    /// a backup pointer. The recycler's phase-1 snapshot reads it.
    is_installed: AtomicBool,
    /// Owner-private (relaxed): phase-1 snapshot of `is_installed`.
    was_installed: AtomicBool,
    /// Owner-private (relaxed): marked during the announcement scan.
    is_protected: AtomicBool,
    /// Owner-private: already sitting in the owner's free list.
    in_free: AtomicBool,
    /// Scheme stamp written at uninstall ([`Smr::reclaim_stamp`]):
    /// under epochs a node may only be recycled once the global epoch
    /// has advanced the scheme's full free distance past it (two reader
    /// epochs + one stamp-slack epoch — `epoch::FREE_DISTANCE`); hazard
    /// ignores it (address scans).
    retired_at: AtomicU64,
}

struct Pool<T: AtomicValue> {
    /// Stable-addressed nodes owned by one thread.
    slab: Vec<Box<Node<T>>>,
    free: Vec<*mut Node<T>>,
    /// Sorted addresses for O(log) membership tests during scans.
    addrs: Vec<usize>,
    scan_buf: Vec<usize>,
    /// Beyond-bound allocations (§5.5 census + bound regression tests).
    overflow_allocs: u64,
    /// Deamortized-reclaim pass state: phase (0 = idle, 1 = snapshot,
    /// 2 = announce-scan, 3 = sweep) and the slab cursor within it.
    pass_phase: u8,
    pass_cursor: usize,
}

impl<T: AtomicValue> Pool<T> {
    fn new() -> Self {
        Self {
            slab: Vec::new(),
            free: Vec::new(),
            addrs: Vec::new(),
            scan_buf: Vec::new(),
            overflow_allocs: 0,
            pass_phase: 0,
            pass_cursor: 0,
        }
    }
}

/// Shared per-(value-type, policy, scheme) domain: every thread's node
/// pool. All `CachedMemEff<T, P, S>` in the process share one domain
/// (node memory is O(p²k), independent of the number of atomics — the
/// paper's headline space property).  Domains are keyed by the full
/// `(T, P, S)` triple: pools recycled under one scheme's rules must
/// never serve readers protected by the other.
pub struct MemEffDomain<T: AtomicValue, P: OrderingPolicy = DefaultPolicy, S: Smr = Hazard> {
    pools: Vec<CachePadded<std::cell::UnsafeCell<Pool<T>>>>,
    live_nodes: AtomicU64,
    /// §3.2 deamortization: spread the reclamation scan over allocations
    /// (O(1) worst-case per op) instead of running it in one burst
    /// (O(1) amortized). See [`MemEffDomain::new_deamortized`].
    deamortized: bool,
    _tags: std::marker::PhantomData<fn() -> (P, S)>,
}

// SAFETY: pool i is only accessed by the thread whose registry tid is i
// (owner-private data), except for Node flag fields which are atomics.
unsafe impl<T: AtomicValue, P: OrderingPolicy, S: Smr> Send for MemEffDomain<T, P, S> {}
unsafe impl<T: AtomicValue, P: OrderingPolicy, S: Smr> Sync for MemEffDomain<T, P, S> {}

impl<T: AtomicValue, P: OrderingPolicy, S: Smr> Default for MemEffDomain<T, P, S> {
    fn default() -> Self {
        Self::new()
    }
}

impl<T: AtomicValue, P: OrderingPolicy, S: Smr> MemEffDomain<T, P, S> {
    pub fn new() -> Self {
        Self {
            pools: (0..MAX_THREADS)
                .map(|_| CachePadded::new(std::cell::UnsafeCell::new(Pool::new())))
                .collect(),
            live_nodes: AtomicU64::new(0),
            deamortized: false,
            _tags: std::marker::PhantomData,
        }
    }

    /// The paper's §3.2 deamortized variant: every allocation performs a
    /// bounded number of reclamation-pass steps ([`DEAMORTIZED_STEPS`]),
    /// so no single operation ever runs a full scan — O(1) worst-case
    /// rather than O(1) amortized, at the cost of a somewhat larger
    /// steady-state slab (the paper uses 6p rather than 3p nodes).
    pub fn new_deamortized() -> Self {
        Self {
            deamortized: true,
            ..Self::new()
        }
    }

    /// The process-wide shared domain for the `(T, P, S)` triple.
    pub fn global() -> Arc<Self> {
        static REGISTRY: OnceLock<Mutex<HashMap<TypeId, Arc<dyn Any + Send + Sync>>>> =
            OnceLock::new();
        let reg = REGISTRY.get_or_init(|| Mutex::new(HashMap::new()));
        let mut map = reg.lock().unwrap();
        let entry = map.entry(TypeId::of::<(T, P, S)>()).or_insert_with(|| {
            Arc::new(MemEffDomain::<T, P, S>::new()) as Arc<dyn Any + Send + Sync>
        });
        Arc::clone(entry).downcast::<MemEffDomain<T, P, S>>().unwrap()
    }

    /// Total nodes allocated across all pools (§5.5: must stay O(p²)).
    pub fn allocated_nodes(&self) -> u64 {
        self.live_nodes.load(Ordering::Relaxed)
    }

    #[allow(clippy::mut_from_ref)]
    #[inline]
    fn my_pool(&self) -> &mut Pool<T> {
        // SAFETY: indexed by the caller's unique registry tid; only the
        // owner thread ever touches its pool.
        unsafe { &mut *self.pools[tid()].get() }
    }

    fn grow_one(&self, pool: &mut Pool<T>) {
        if pool.slab.len() >= SLAB_CAP {
            // Beyond the 3p bound: keep growing (liveness over an assert
            // in production) but count it for the §5.5 census and the
            // bound regression tests.
            pool.overflow_allocs += 1;
        }
        let node = Box::new(Node {
            value: WordBuf::new(T::default()),
            is_installed: AtomicBool::new(false),
            was_installed: AtomicBool::new(false),
            is_protected: AtomicBool::new(false),
            in_free: AtomicBool::new(true),
            retired_at: AtomicU64::new(0),
        });
        let ptr = &*node as *const Node<T> as *mut Node<T>;
        pool.slab.push(node);
        let pos = pool.addrs.binary_search(&(ptr as usize)).unwrap_err();
        pool.addrs.insert(pos, ptr as usize);
        pool.free.push(ptr);
        self.live_nodes.fetch_add(1, Ordering::Relaxed);
    }

    /// Paper's `get_free_node`: pop from the private free list, running
    /// the reclamation scan when empty.
    ///
    /// Amortization (§Perf): the paper gives each thread a fixed 3p-node
    /// slab so one O(slab + announcements) scan recovers ≥ p nodes.  A
    /// naively lazy slab defeats that (a 1-node slab scans on *every*
    /// allocation — measured 1µs/cas).  We grow to a minimum batch before
    /// scanning, and grow geometrically whenever a scan recovers less
    /// than a quarter of the slab, so scan cost stays O(1) amortized
    /// while the slab remains O(installed + protected) = O(p).
    fn get_free_node(&self, val: T) -> *mut Node<T> {
        const MIN_SLAB_BEFORE_SCAN: usize = 128;
        /// Pass steps per allocation in deamortized mode (paper: 6).
        const DEAMORTIZED_STEPS: usize = 6;
        let pool = self.my_pool();
        if self.deamortized {
            Self::reclaim_step(pool, DEAMORTIZED_STEPS);
            if pool.free.is_empty() {
                self.grow_one(pool);
            }
        } else if pool.free.is_empty() {
            if pool.slab.len() >= MIN_SLAB_BEFORE_SCAN {
                Self::reclaim(pool);
            }
            if pool.free.len() * 4 < pool.slab.len() + 4 {
                // Scan recovered little (or slab still small): grow.
                self.grow_one(pool);
            }
        }
        let node = pool.free.pop().expect("free list refilled above");
        // SAFETY: node is owned (in free list => not installed, not
        // readable by anyone — see reclaim()'s two-phase rule).
        unsafe {
            // Ordering: RELAXED — owner-private flags; only this thread
            // writes them and only this thread's recycler reads them
            // (program order suffices).
            (*node).in_free.store(false, P::RELAXED);
            // Deamortized interleaving rule: a node allocated while a
            // pass is active must not be swept by that pass.
            if self.deamortized && pool.pass_phase != 0 {
                (*node).was_installed.store(true, P::RELAXED);
            }
            (*node).value.write_p::<P>(val);
            // Ordering: RELEASE — the value words above happen-before
            // anyone who ACQUIREs is_installed (the recycler's phase-1
            // snapshot); the node itself is published to readers by the
            // backup install CAS, which is also RELEASE.
            (*node).is_installed.store(true, P::RELEASE);
        }
        node
    }

    /// Return an unpublished node (failed CAS) straight to the free list.
    fn free_node(&self, node: *mut Node<T>) {
        // SAFETY: never published; owner thread only.
        unsafe {
            // Ordering: RELEASE uninstall signal (pairs with the
            // recycler's ACQUIRE snapshot); RELAXED for the owner-
            // private free flag.
            (*node).is_installed.store(false, P::RELEASE);
            (*node).in_free.store(true, P::RELAXED);
        }
        self.my_pool().free.push(node);
    }

    /// One bounded slice of the deamortized reclamation pass (§3.2).
    ///
    /// Safety of interleaving (the paper's footnote 3): only the owner
    /// installs its own nodes, and nodes handed out *during* a pass are
    /// poisoned (`was_installed = true`, see `get_free_node`), so a node
    /// is swept only if it was free or uninstalled at snapshot time and
    /// stayed unreachable for the whole pass — no reader can have
    /// protected it after the announce scan.
    fn reclaim_step(pool: &mut Pool<T>, budget: usize) {
        let mut steps = budget;
        while steps > 0 {
            match pool.pass_phase {
                0 => {
                    // Start a pass only when the free list is low.
                    if pool.free.len() * 4 >= pool.slab.len() {
                        return;
                    }
                    pool.pass_phase = 1;
                    pool.pass_cursor = 0;
                }
                1 => {
                    // Phase 1: snapshot is_installed, a few nodes per step.
                    let end = (pool.pass_cursor + 1).min(pool.slab.len());
                    for node in &pool.slab[pool.pass_cursor..end] {
                        // Ordering: ACQUIRE — pairs with the RELEASE
                        // (un)install stores; the snapshot→scan ordering
                        // that makes the two-phase rule sound comes from
                        // the SeqCst fence inside S::reclaim_protected
                        // (phase 2), sequenced after this read.
                        node.was_installed
                            .store(node.is_installed.load(P::ACQUIRE), P::RELAXED);
                    }
                    pool.pass_cursor = end;
                    steps -= 1;
                    if pool.pass_cursor >= pool.slab.len() {
                        pool.pass_phase = 2;
                    }
                }
                2 => {
                    // Phase 2: protection scan (hazard: announcement
                    // array, bounded by the registry high-water mark;
                    // epoch: temporal — one advance attempt instead).
                    // Counts as one step like the paper's per-write
                    // iteration batch.
                    let mut buf = std::mem::take(&mut pool.scan_buf);
                    S::reclaim_protected(&mut buf);
                    for &addr in buf.iter() {
                        if pool.addrs.binary_search(&addr).is_ok() {
                            // SAFETY: addr is one of our live slab nodes.
                            unsafe {
                                (*(addr as *mut Node<T>)).is_protected.store(true, P::RELAXED)
                            };
                        }
                    }
                    pool.scan_buf = buf;
                    pool.pass_phase = 3;
                    pool.pass_cursor = 0;
                    steps -= 1;
                }
                _ => {
                    // Phase 3: sweep — snapshotted-uninstalled, not
                    // scheme-protected (address scan under hazard,
                    // stamp expiry under epochs), and not already free.
                    let end = (pool.pass_cursor + 1).min(pool.slab.len());
                    for i in pool.pass_cursor..end {
                        let node = &pool.slab[i];
                        let reclaimable = !node.was_installed.load(P::RELAXED)
                            && !node.is_protected.load(P::RELAXED)
                            && !node.in_free.load(P::RELAXED)
                            && S::reclaim_stamp_expired(node.retired_at.load(P::RELAXED));
                        node.is_protected.store(false, P::RELAXED);
                        if reclaimable {
                            node.in_free.store(true, P::RELAXED);
                            pool.free.push(&**node as *const Node<T> as *mut Node<T>);
                        }
                    }
                    pool.pass_cursor = end;
                    steps -= 1;
                    if pool.pass_cursor >= pool.slab.len() {
                        pool.pass_phase = 0;
                        return;
                    }
                }
            }
        }
    }

    /// The §3.2 recycler. Two-phase rule: a node may be reclaimed only if
    /// it was observed uninstalled *before* the protection scan — this
    /// guarantees any protector announced (hazard) or pinned (epoch)
    /// before the uninstall and is therefore visible to the scan / still
    /// blocking the stamp's expiry (the paper calls out that checking
    /// `!is_installed && !is_protected` without the snapshot is a
    /// use-after-free bug).
    fn reclaim(pool: &mut Pool<T>) {
        // Phase 1: snapshot installed flags.
        for node in pool.slab.iter() {
            // Ordering: ACQUIRE/RELAXED — as in reclaim_step phase 1:
            // the uninstall signal is RELEASE'd by writers, and the
            // snapshot-before-scan edge is the SeqCst fence inside
            // S::reclaim_protected below.
            node.was_installed
                .store(node.is_installed.load(P::ACQUIRE), P::RELAXED);
        }
        // Phase 2: scheme protection scan; mark our nodes (hazard) or
        // advance the epoch so stamp expiry can progress.
        let mut buf = std::mem::take(&mut pool.scan_buf);
        S::reclaim_protected(&mut buf);
        for &addr in buf.iter() {
            if pool.addrs.binary_search(&addr).is_ok() {
                // SAFETY: addr is one of our live slab nodes.
                unsafe { (*(addr as *mut Node<T>)).is_protected.store(true, P::RELAXED) };
            }
        }
        pool.scan_buf = buf;
        // Phase 3: recycle everything neither snapshotted-installed nor
        // scheme-protected (and not already free).
        for node in pool.slab.iter() {
            let reclaimable = !node.was_installed.load(P::RELAXED)
                && !node.is_protected.load(P::RELAXED)
                && !node.in_free.load(P::RELAXED)
                && S::reclaim_stamp_expired(node.retired_at.load(P::RELAXED));
            node.is_protected.store(false, P::RELAXED);
            if reclaimable {
                node.in_free.store(true, P::RELAXED);
                pool.free
                    .push(&**node as *const Node<T> as *mut Node<T>);
            }
        }
    }
}

/// Outcome of the paper's `try_load_indirect` (out-params flattened).
enum Tli<T> {
    /// Read through a protected non-null backup (ver unchanged by callee).
    Indirect { raw: usize, val: T },
    /// Read a stable cache under a (tagged-)null backup.
    Cached { ver: u64, raw: usize, val: T },
    /// Raced; the value was changing.
    Fail,
}

pub struct CachedMemEff<T: AtomicValue, P: OrderingPolicy = DefaultPolicy, S: Smr = Hazard> {
    version: AtomicU64,
    /// Tagged pointer: low bit set ⇒ "null" carrying a version tag
    /// (defends the install CAS against null-ABA); else a `Node<T>`.
    backup: AtomicUsize,
    cache: WordBuf<T>,
    domain: Arc<MemEffDomain<T, P, S>>,
}

impl<T: AtomicValue, P: OrderingPolicy, S: Smr> CachedMemEff<T, P, S> {
    /// Construct against an explicit (shared) domain.
    pub fn with_domain(init: T, domain: Arc<MemEffDomain<T, P, S>>) -> Self {
        Self {
            version: AtomicU64::new(0),
            backup: AtomicUsize::new(tagged_null(0)),
            cache: WordBuf::new(init),
            domain,
        }
    }

    /// ABLATION ONLY (`repro ablate`): a load that never uses the cached
    /// fast path — every read goes through the guard-protected indirect
    /// route (re-caching disabled from the reader side).  Quantifies the
    /// paper's central design claim: the value of the inlined cache.
    pub fn load_no_fast_path(&self) -> T {
        let g = S::pin();
        let mut bo = Backoff::new();
        loop {
            match self.try_load_indirect(&g) {
                Tli::Indirect { val, .. } | Tli::Cached { val, .. } => return val,
                Tli::Fail => bo.snooze(),
            }
        }
    }

    /// Protect the backup, announcing node addresses only (tagged nulls
    /// announce 0 = nothing; a no-op under region schemes).
    #[inline]
    fn protect_backup(&self, g: &S::Guard) -> usize {
        // Ordering: ACQUIRE — the validating call pairs with the
        // installer's RELEASE CAS so node contents are visible before
        // node_value dereferences them; the scheme's store-load SeqCst
        // fence is inside the guard (hazard) or was paid at pin time
        // (epoch).
        g.protect_raw(
            || self.backup.load(P::ACQUIRE),
            |r| if is_null(r) { 0 } else { r },
        )
    }

    #[inline]
    fn node_value(raw: usize) -> T {
        debug_assert!(!is_null(raw));
        // SAFETY: guard-protected node (or never-recycled under the
        // two-phase rule).
        unsafe { (*(raw as *const Node<T>)).value.read_p::<P>() }
    }

    /// Stamp + signal the uninstall of `raw_p` (any thread may do this —
    /// whoever removes the node from a backup pointer).
    ///
    /// # Safety
    /// `raw_p` must be a guard-protected slab node just unlinked by a
    /// successful backup CAS.
    #[inline]
    unsafe fn uninstall(raw_p: usize) {
        let node = unsafe { &*(raw_p as *const Node<T>) };
        // Ordering: RELAXED — published by the RELEASE uninstall signal
        // below (the recycler's ACQUIRE phase-1 snapshot of a false
        // is_installed makes this stamp visible to its phase-3 check).
        node.retired_at.store(S::reclaim_stamp(), P::RELAXED);
        // Ordering: RELEASE — pairs with the recycler's ACQUIRE
        // snapshot (recycle only after the uninstall is visible).
        node.is_installed.store(false, P::RELEASE);
    }

    fn try_load_indirect(&self, g: &S::Guard) -> Tli<T> {
        let raw = self.protect_backup(g);
        if !is_null(raw) {
            return Tli::Indirect {
                raw,
                val: Self::node_value(raw),
            };
        }
        // Seqlock-shaped re-check under a null backup — same edges as
        // the fast path in `load` (see the Ordering comments there).
        let ver = self.version.load(P::ACQUIRE);
        let val = self.cache.read_p::<P>();
        let p2 = self.backup.load(P::RELAXED);
        fence(P::FENCE_ACQUIRE);
        if is_null(p2) && ver == self.version.load(P::RELAXED) {
            Tli::Cached { ver, raw: p2, val }
        } else {
            Tli::Fail
        }
    }

    /// "Re-caching until success" (§3.2): copy `desired` into the cache
    /// under the seqlock, then try to null out the backup; if a newer
    /// writer installed meanwhile, help cache *their* value, looping
    /// until the backup is null or someone else holds the lock.
    fn try_seqlock(&self, mut ver: u64, mut desired: T, mut raw_p: usize, g: &S::Guard) {
        // Fault window: about to re-cache — skipping (or dawdling) here
        // leaves the backup non-null, which only costs readers the
        // indirect path until a later writer helps ("re-caching until
        // success" makes this crash-tolerant by design).
        crate::failpoint!(Alg2Recache);
        loop {
            // Ordering: RELAXED pre-check — advisory only; the lock CAS
            // below re-validates against the same version.
            if ver % 2 != 0
                || ver != self.version.load(P::RELAXED)
                || self
                    .version
                    // Ordering: ACQUIRE on success — seqlock writer lock
                    // (pairs with the previous RELEASE unlock); RELAXED
                    // on failure — the loser returns without touching
                    // the cache.
                    .compare_exchange(ver, ver + 1, P::ACQUIRE, P::RELAXED)
                    .is_err()
            {
                // Someone else took the lock; they are responsible for
                // restoring cache/backup consistency.
                return;
            }
            // Ordering: FENCE_RELEASE — odd version visible before the
            // cache words (pairs with readers' FENCE_ACQUIRE: a torn
            // cache read implies the version re-check fails).
            fence(P::FENCE_RELEASE);
            self.cache.write_p::<P>(desired);
            ver += 2;
            // Ordering: RELEASE — cache writes happen-before the even
            // version.
            self.version.store(ver, P::RELEASE);
            let new_null = tagged_null(ver);
            match self
                .backup
                // Ordering: RELEASE on success — the fresh cache and
                // even version happen-before the null a fast-path
                // reader pairs with them; RELAXED on failure — `actual`
                // is inspected for nullness only, and the help path
                // re-synchronizes through protect_backup.
                .compare_exchange(raw_p, new_null, P::RELEASE, P::RELAXED)
            {
                Ok(_) => {
                    // SAFETY: raw_p is a node we (or a helper chain)
                    // protected, unlinked by the successful null CAS;
                    // stamp + uninstall signal for its owner's recycler.
                    unsafe { Self::uninstall(raw_p) };
                    return;
                }
                Err(actual) => {
                    if is_null(actual) {
                        return; // consistency restored by someone else
                    }
                    // Help the newer writer: protect + read their value
                    // and loop to cache it. One bump per helped writer —
                    // the counter is a help-chain-length proxy.
                    let raw2 = self.protect_backup(g);
                    if is_null(raw2) {
                        return;
                    }
                    crate::counter!(HelpRecache);
                    desired = Self::node_value(raw2);
                    raw_p = raw2;
                }
            }
        }
    }
}

impl<T: AtomicValue, P: OrderingPolicy, S: Smr> BigAtomic<T> for CachedMemEff<T, P, S> {
    fn new(init: T) -> Self {
        Self::with_domain(init, MemEffDomain::global())
    }

    #[inline]
    fn load(&self) -> T {
        // The fast-path version / cache / backup / version re-check —
        // seqlock reader edges:
        // Ordering: ACQUIRE — pairs with the re-cacher's RELEASE unlock,
        // making the cache words for version `ver` visible below.
        let ver = self.version.load(P::ACQUIRE);
        let val = self.cache.read_p::<P>();
        // Ordering: RELAXED — validated by the fence + re-check: if this
        // observed a RELEASE'd null whose cache we missed, the version
        // re-check fails.
        let raw = self.backup.load(P::RELAXED);
        // Ordering: FENCE_ACQUIRE — load-load edge: cache and backup
        // reads complete before the version re-check; pairs with the
        // writer-side FENCE_RELEASE in try_seqlock and the RELEASE
        // null-CAS.
        fence(P::FENCE_ACQUIRE);
        // Ordering: RELAXED — ordered by the fence above.
        if is_null(raw) && ver == self.version.load(P::RELAXED) {
            crate::counter!(FastPathHit);
            return val; // fast path: no indirection, no SMR
        }
        // Lock-free slow path: each retry implies an update completed.
        crate::counter!(FastPathMiss);
        let g = S::pin();
        let mut bo = Backoff::new();
        loop {
            match self.try_load_indirect(&g) {
                Tli::Indirect { val, .. } | Tli::Cached { val, .. } => return val,
                Tli::Fail => bo.snooze(),
            }
        }
    }

    #[inline]
    fn store(&self, val: T) {
        // Paper line 60: lock-free store as a CAS loop (linearizes at the
        // first successful CAS; same-value fast-out is the AA rule). The
        // witness feeds the retry instead of a fresh load, and failures
        // back off adaptively before touching the hot line again.
        let mut cur = self.load();
        let mut bo = None;
        loop {
            if cur == val {
                return;
            }
            match self.compare_exchange(cur, val) {
                Ok(_) => return,
                Err(w) => {
                    cur = w;
                    snooze_lazy(&mut bo);
                }
            }
        }
    }

    fn compare_exchange(&self, expected: T, desired: T) -> Result<T, T> {
        let g = S::pin();
        // Lazy: the uncontended install pays no backoff/TLS cost.
        let mut bo = None;
        loop {
            // Ordering: ACQUIRE — this pre-read version is only trusted
            // when try_load_indirect returns Indirect (the install path
            // hands it to try_seqlock, whose lock CAS re-validates it).
            let mut ver = self.version.load(P::ACQUIRE);
            let (raw, val) = match self.try_load_indirect(&g) {
                Tli::Indirect { raw, val } => (raw, val),
                Tli::Cached { ver: v, raw, val } => {
                    ver = v;
                    (raw, val)
                }
                // The value was changing during the read — another
                // update is mid-flight (global progress); back off and
                // retry for a definite witness.
                Tli::Fail => {
                    crate::counter!(CasRetry);
                    snooze_lazy(&mut bo);
                    continue;
                }
            };
            if val != expected {
                return Err(val); // exact witness: a linearizable read
            }
            if expected == desired {
                return Ok(val);
            }

            let new_node = self.domain.get_free_node(desired);
            let new_raw = new_node as usize;
            debug_assert!(!is_null(new_raw));
            // Fault window: slab node taken + value written, install CAS
            // next — a kill here strands the node installed-but-unlinked
            // until its owner's next reclamation scan; a stall forces
            // rivals to back off against a hot backup line.
            crate::failpoint!(Alg2Install);

            match self
                .backup
                // Ordering: RELEASE on success — the install is the
                // linearization point and publishes the node's value
                // words (written in get_free_node) before its address;
                // readers pair via protect_backup's ACQUIRE validating
                // load. RELAXED on failure — the loser re-reads through
                // try_load_indirect, which re-synchronizes.
                .compare_exchange(raw, new_raw, P::RELEASE, P::RELAXED)
            {
                Ok(_) => {
                    crate::counter!(SlowPathInstall);
                    if !is_null(raw) {
                        // SAFETY: protected node unlinked by our install
                        // CAS; stamp + uninstall signal for its owner.
                        unsafe { Self::uninstall(raw) };
                    }
                    self.try_seqlock(ver, desired, new_raw, &g);
                    return Ok(val);
                }
                Err(_) => {
                    crate::counter!(CasRetry);
                    // A competing update won the install (or cached our
                    // node's predecessor and nulled the backup). Return
                    // the node, back off (the line is hot — Dice et al.)
                    // and re-read: the next iteration either witnesses a
                    // different value (Err) or sees `expected` restored
                    // and retries the install — against the *exact*
                    // tagged null it just read, so its version tag
                    // defeats null-ABA. Lock-free: every iteration
                    // implies a completed competing update.
                    self.domain.free_node(new_node);
                    snooze_lazy(&mut bo);
                }
            }
        }
    }

    fn name() -> &'static str {
        "Cached-MemEff"
    }

    fn indirect_bytes(&self) -> usize {
        // Nodes are pooled per-thread and accounted at domain level; an
        // individual atomic holds none in steady state.
        0
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::atomics::Words;
    use std::sync::Arc;

    #[test]
    fn test_roundtrip_and_cas() {
        let a: CachedMemEff<Words<3>> = CachedMemEff::new(Words([1, 2, 3]));
        assert_eq!(a.load(), Words([1, 2, 3]));
        assert_eq!(
            a.compare_exchange(Words([1, 2, 3]), Words([4, 5, 6])),
            Ok(Words([1, 2, 3]))
        );
        assert_eq!(
            a.compare_exchange(Words([1, 2, 3]), Words([9, 9, 9])),
            Err(Words([4, 5, 6]))
        );
        a.store(Words([7, 7, 7]));
        assert_eq!(a.load(), Words([7, 7, 7]));
    }

    #[test]
    fn test_backup_null_in_steady_state() {
        let a: CachedMemEff<Words<2>> = CachedMemEff::new(Words([0, 0]));
        for i in 1..100u64 {
            assert!(a.compare_exchange(a.load(), Words([i, i])).is_ok());
        }
        // Quiescent: the backup must be a tagged null (memory-efficient
        // steady state — this is the algorithm's defining property).
        assert!(is_null(a.backup.load(Ordering::SeqCst)));
        assert_eq!(a.load(), Words([99, 99]));
    }

    #[test]
    fn test_node_pool_bounded() {
        let domain: Arc<MemEffDomain<Words<2>>> = Arc::new(MemEffDomain::new());
        let atomics: Vec<CachedMemEff<Words<2>>> = (0..64)
            .map(|i| CachedMemEff::with_domain(Words([i, i]), Arc::clone(&domain)))
            .collect();
        for round in 1..200u64 {
            for a in &atomics {
                let cur = a.load();
                assert!(a.compare_exchange(cur, Words([cur.0[0] + round, round])).is_ok());
            }
        }
        // Single-threaded: nodes must be recycled — bounded by the slab
        // batch minimum (128), not by the 12800 ops performed.
        assert!(
            domain.allocated_nodes() <= 132,
            "pool grew to {} nodes single-threaded",
            domain.allocated_nodes()
        );
    }

    #[test]
    fn test_concurrent_cas_exactly_one_winner() {
        let a: Arc<CachedMemEff<Words<4>>> = Arc::new(CachedMemEff::new(Words([0; 4])));
        let threads = 4;
        let rounds = 2_000u64;
        let wins = Arc::new(std::sync::atomic::AtomicU64::new(0));
        let handles: Vec<_> = (0..threads)
            .map(|t| {
                let a = Arc::clone(&a);
                let wins = Arc::clone(&wins);
                std::thread::spawn(move || {
                    for r in 0..rounds {
                        let cur = a.load();
                        let next = Words([cur.0[0] + 1, r + 1, t as u64, cur.0[3] ^ (r + 7)]);
                        if a.compare_exchange(cur, next).is_ok() {
                            wins.fetch_add(1, Ordering::Relaxed);
                        }
                    }
                })
            })
            .collect();
        for h in handles {
            h.join().unwrap();
        }
        assert_eq!(a.load().0[0], wins.load(Ordering::SeqCst));
    }

    #[test]
    fn test_no_torn_reads_with_stores() {
        let a: Arc<CachedMemEff<Words<4>>> = Arc::new(CachedMemEff::new(Words([1; 4])));
        let stop = Arc::new(std::sync::atomic::AtomicBool::new(false));
        let readers: Vec<_> = (0..2)
            .map(|_| {
                let a = Arc::clone(&a);
                let stop = Arc::clone(&stop);
                std::thread::spawn(move || {
                    while !stop.load(Ordering::Relaxed) {
                        let v = a.load();
                        assert!(v.0.iter().all(|&w| w == v.0[0]), "torn: {:?}", v.0);
                    }
                })
            })
            .collect();
        let writers: Vec<_> = (0..2)
            .map(|t| {
                let a = Arc::clone(&a);
                std::thread::spawn(move || {
                    for i in 1..5_000u64 {
                        a.store(Words([i * 4 + t; 4]));
                    }
                })
            })
            .collect();
        for w in writers {
            w.join().unwrap();
        }
        stop.store(true, Ordering::SeqCst);
        for r in readers {
            r.join().unwrap();
        }
    }

    #[test]
    fn test_epoch_smr_roundtrip_and_cas() {
        use crate::smr::Epoch;
        use crate::util::ordering::DefaultPolicy;
        let a: CachedMemEff<Words<3>, DefaultPolicy, Epoch> = CachedMemEff::new(Words([1, 2, 3]));
        assert_eq!(a.load(), Words([1, 2, 3]));
        assert_eq!(
            a.compare_exchange(Words([1, 2, 3]), Words([4, 5, 6])),
            Ok(Words([1, 2, 3]))
        );
        assert_eq!(
            a.compare_exchange(Words([1, 2, 3]), Words([9, 9, 9])),
            Err(Words([4, 5, 6]))
        );
        a.store(Words([7, 7, 7]));
        assert_eq!(a.load(), Words([7, 7, 7]));
    }

    #[test]
    fn test_epoch_smr_concurrent_cas_exactly_one_winner() {
        use crate::smr::Epoch;
        use crate::util::ordering::DefaultPolicy;
        let a: Arc<CachedMemEff<Words<4>, DefaultPolicy, Epoch>> =
            Arc::new(CachedMemEff::new(Words([0; 4])));
        let threads = 4;
        let rounds = 2_000u64;
        let wins = Arc::new(std::sync::atomic::AtomicU64::new(0));
        let handles: Vec<_> = (0..threads)
            .map(|t| {
                let a = Arc::clone(&a);
                let wins = Arc::clone(&wins);
                std::thread::spawn(move || {
                    for r in 0..rounds {
                        let cur = a.load();
                        let next = Words([cur.0[0] + 1, r + 1, t as u64, cur.0[3] ^ (r + 7)]);
                        if a.compare_exchange(cur, next).is_ok() {
                            wins.fetch_add(1, Ordering::Relaxed);
                        }
                    }
                })
            })
            .collect();
        for h in handles {
            h.join().unwrap();
        }
        assert_eq!(a.load().0[0], wins.load(Ordering::SeqCst));
    }

    #[test]
    fn test_epoch_smr_nodes_recycle() {
        // The stamp rule must actually recycle nodes once epochs
        // advance. Other tests in this binary may hold short-lived pins
        // that stall stamp expiry, so instead of asserting a hard pool
        // bound, drive update batches until one whole batch allocates
        // zero fresh nodes — proof the recycler is feeding the free
        // list — and fail only if that never happens.
        use crate::smr::Epoch;
        use crate::util::ordering::DefaultPolicy;
        let domain: Arc<MemEffDomain<Words<2>, DefaultPolicy, Epoch>> =
            Arc::new(MemEffDomain::new());
        let a = CachedMemEff::with_domain(Words([0, 0]), Arc::clone(&domain));
        let mut total = 0u64;
        let mut last_alloc = domain.allocated_nodes();
        let mut recycled = false;
        for _batch in 0..60 {
            for _ in 0..400u64 {
                total += 1;
                let cur = a.load();
                assert!(a.compare_exchange(cur, Words([cur.0[0] + 1, total])).is_ok());
            }
            let now_alloc = domain.allocated_nodes();
            if now_alloc == last_alloc {
                recycled = true; // 400 updates, zero new nodes
                break;
            }
            last_alloc = now_alloc;
            std::thread::yield_now();
        }
        assert!(
            recycled,
            "epoch-scheme recycler never recycled: {} nodes after {} updates",
            domain.allocated_nodes(),
            total
        );
        assert_eq!(a.load(), Words([total, total]));
    }

    #[test]
    fn test_deamortized_roundtrip_and_recycling() {
        // §3.2 deamortized variant: same semantics, bounded per-op scan.
        let domain: Arc<MemEffDomain<Words<2>>> = Arc::new(MemEffDomain::new_deamortized());
        let atomics: Vec<CachedMemEff<Words<2>>> = (0..64)
            .map(|i| CachedMemEff::with_domain(Words([i, 0]), Arc::clone(&domain)))
            .collect();
        for round in 1..500u64 {
            for a in &atomics {
                let cur = a.load();
                assert!(a.compare_exchange(cur, Words([cur.0[0] + 1, round])).is_ok());
            }
        }
        for (i, a) in atomics.iter().enumerate() {
            assert_eq!(a.load(), Words([i as u64 + 499, 499]));
        }
        // Nodes must be recycled by the incremental passes, not grow
        // with the 32K updates performed.
        assert!(
            domain.allocated_nodes() <= 512,
            "deamortized pool grew to {}",
            domain.allocated_nodes()
        );
    }

    #[test]
    fn test_deamortized_concurrent_correctness() {
        let domain: Arc<MemEffDomain<Words<4>>> = Arc::new(MemEffDomain::new_deamortized());
        let a = Arc::new(CachedMemEff::with_domain(Words([0; 4]), Arc::clone(&domain)));
        let threads = 4;
        let rounds = 2_000u64;
        let wins = Arc::new(std::sync::atomic::AtomicU64::new(0));
        let handles: Vec<_> = (0..threads)
            .map(|t| {
                let a = Arc::clone(&a);
                let wins = Arc::clone(&wins);
                std::thread::spawn(move || {
                    for r in 0..rounds {
                        let cur = a.load();
                        let next = Words([cur.0[0] + 1, r + 1, t, cur.0[3] ^ (r + 3)]);
                        if a.compare_exchange(cur, next).is_ok() {
                            wins.fetch_add(1, Ordering::Relaxed);
                        }
                    }
                })
            })
            .collect();
        for h in handles {
            h.join().unwrap();
        }
        assert_eq!(a.load().0[0], wins.load(Ordering::SeqCst));
    }

    #[test]
    fn test_many_atomics_share_domain_nodes() {
        // The defining space property: node memory independent of n.
        let domain: Arc<MemEffDomain<Words<8>>> = Arc::new(MemEffDomain::new());
        let n = 10_000;
        let arr: Vec<CachedMemEff<Words<8>>> = (0..n)
            .map(|_| CachedMemEff::with_domain(Words([0; 8]), Arc::clone(&domain)))
            .collect();
        for (i, a) in arr.iter().enumerate() {
            assert!(a.compare_exchange(Words([0; 8]), Words([i as u64 + 1; 8])).is_ok());
        }
        // 10_000 atomics, but the node pool stays at the per-thread slab
        // batch (≤ 132): memory independent of n — the §3.2 property.
        assert!(
            domain.allocated_nodes() <= 132,
            "nodes {} not independent of n",
            domain.allocated_nodes()
        );
        for (i, a) in arr.iter().enumerate() {
            assert_eq!(a.load(), Words([i as u64 + 1; 8]));
        }
    }
}
