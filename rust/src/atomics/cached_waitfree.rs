//! `CachedWaitFree<T>` — **Algorithm 1**: the paper's wait-free big
//! atomic supporting `load` + `cas` in O(k) time (§3.1).
//!
//! Layout per atomic: a seqlock-style `version`, a `backup` pointer that
//! *always* references a heap node holding the current value, and an
//! inlined `cache`.  The backup pointer carries a mark bit: marked ⇒ the
//! cache is invalid.  Loads take the fast path (version / cache / backup
//! / version — no indirection, no hazard) whenever the pointer is
//! unmarked and the version is stable; otherwise they do one protected
//! read through the backup.  Updates linearize on the single-word CAS
//! that installs a new (marked) backup node, then opportunistically copy
//! the value into the cache and validate the pointer.
//!
//! Key invariants (proof sketch of Theorem 3.1):
//! 1. the current backup node always holds the current value;
//! 2. whenever the backup pointer is unmarked, cache == backup value.

use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};

use super::bytewise::WordBuf;
use super::{AtomicValue, BigAtomic};
use crate::smr::hazard::{retire_box, HazardPointer};

#[repr(C, align(8))]
struct Node<T> {
    value: T,
}

const MARK: usize = 1;

#[inline]
fn unmark(raw: usize) -> usize {
    raw & !MARK
}

#[inline]
fn is_marked(raw: usize) -> bool {
    raw & MARK == MARK
}

pub struct CachedWaitFree<T: AtomicValue> {
    version: AtomicU64,
    /// Marked pointer to `Node<T>`; mark set ⇒ cache invalid.
    backup: AtomicUsize,
    cache: WordBuf<T>,
}

impl<T: AtomicValue> CachedWaitFree<T> {
    #[inline]
    fn node_value(raw: usize) -> T {
        // SAFETY: caller protected `unmark(raw)` with a hazard pointer
        // (or owns it exclusively); nodes are immutable after publish.
        unsafe { (*(unmark(raw) as *const Node<T>)).value }
    }

    /// Protect the current backup, announcing the *unmarked* node address
    /// (the address reclaimers compare against).
    #[inline]
    fn protect_backup(&self, h: &HazardPointer) -> usize {
        h.protect_raw_with(|| self.backup.load(Ordering::SeqCst), unmark)
    }
}

impl<T: AtomicValue> Drop for CachedWaitFree<T> {
    fn drop(&mut self) {
        let raw = self.backup.load(Ordering::Relaxed);
        // SAFETY: exclusive in Drop; backup is always a live node.
        drop(unsafe { Box::from_raw(unmark(raw) as *mut Node<T>) });
    }
}

impl<T: AtomicValue> BigAtomic<T> for CachedWaitFree<T> {
    fn new(init: T) -> Self {
        Self {
            version: AtomicU64::new(0),
            // Unmarked: cache starts valid and equal to the backup.
            backup: AtomicUsize::new(Box::into_raw(Box::new(Node { value: init })) as usize),
            cache: WordBuf::new(init),
        }
    }

    #[inline]
    fn load(&self) -> T {
        let ver = self.version.load(Ordering::SeqCst);
        let val = self.cache.read();
        let raw = self.backup.load(Ordering::SeqCst);
        if !is_marked(raw) && ver == self.version.load(Ordering::SeqCst) {
            // Fast path: cache was valid and untouched through the window.
            return val;
        }
        // Slow path: one protected indirect read. The backup always holds
        // the current value, so no loop — wait-free.
        let h = HazardPointer::new();
        let raw = self.protect_backup(&h);
        Self::node_value(raw)
    }

    #[inline]
    fn store(&self, val: T) {
        // Table 1: the load+cas variant has no native store; this CAS
        // loop is lock-free (each failure implies another update won)
        // and feeds the witness back instead of re-loading.
        let mut cur = self.load();
        loop {
            if cur == val {
                return;
            }
            match self.compare_exchange(cur, val) {
                Ok(_) => return,
                Err(w) => cur = w,
            }
        }
    }

    fn compare_exchange(&self, expected: T, desired: T) -> Result<T, T> {
        let h = HazardPointer::new();
        let ver = self.version.load(Ordering::SeqCst);
        let mut val = self.cache.read();
        // Protect early: the install CAS below must only succeed if the
        // backup hasn't changed since this read (hazard prevents the
        // address being recycled — no ABA).
        let raw = self.protect_backup(&h);
        if is_marked(raw) || ver != self.version.load(Ordering::SeqCst) {
            val = Self::node_value(raw);
        }
        if val != expected {
            return Err(val);
        }
        if expected == desired {
            // Never replace a value by an equal one: the backup pointer
            // would change and spuriously fail a concurrent CAS (§3.1).
            return Ok(val);
        }

        let new_node = Box::into_raw(Box::new(Node { value: desired }));
        let new_marked = new_node as usize | MARK; // cache invalid until copied
        let installed = match self.backup.compare_exchange(
            raw,
            new_marked,
            Ordering::SeqCst,
            Ordering::SeqCst,
        ) {
            Ok(_) => true,
            Err(actual) => {
                // The first attempt may have failed only because the old
                // pointer was validated (marked -> unmarked) in between;
                // retry expecting the validated form.
                is_marked(raw)
                    && actual == unmark(raw)
                    && self
                        .backup
                        .compare_exchange(actual, new_marked, Ordering::SeqCst, Ordering::SeqCst)
                        .is_ok()
            }
        };

        if !installed {
            // CAS failed: the value changed (linearize at the competing
            // update). The node was never published.
            // SAFETY: unpublished, uniquely owned.
            drop(unsafe { Box::from_raw(new_node) });
            // Witness: one protected read of the node the winner
            // installed. Wait-free (no loop); may rarely equal
            // `expected` again if later updates restored it — see the
            // module docs' witness contract.
            let raw2 = self.protect_backup(&h);
            return Err(Self::node_value(raw2));
        }

        // Linearized at the install. Retire the old node (still hazard-
        // protected by us, so it outlives this call).
        // SAFETY: unlinked by the successful install.
        unsafe { retire_box(unmark(raw) as *mut Node<T>) };

        // Try to copy into the cache: seqlock acquire, but additionally
        // require the version unchanged since *before* our install so we
        // never overwrite a more recent update's cache (§3.1).
        if ver % 2 == 0
            && ver == self.version.load(Ordering::SeqCst)
            && self
                .version
                .compare_exchange(ver, ver + 1, Ordering::SeqCst, Ordering::SeqCst)
                .is_ok()
        {
            self.cache.write(desired);
            self.version.store(ver + 2, Ordering::Release);
            // Validate: only if our node is still the backup.
            let _ = self.backup.compare_exchange(
                new_marked,
                unmark(new_marked),
                Ordering::SeqCst,
                Ordering::SeqCst,
            );
        }
        // If validation was skipped/failed the cache stays invalid until
        // a later uncontended CAS validates — permitted by the invariants.
        Ok(expected)
    }

    fn name() -> &'static str {
        "Cached-WaitFree"
    }

    fn indirect_bytes(&self) -> usize {
        std::mem::size_of::<Node<T>>()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::atomics::Words;
    use std::sync::Arc;

    #[test]
    fn test_roundtrip() {
        let a: CachedWaitFree<Words<3>> = CachedWaitFree::new(Words([1, 2, 3]));
        assert_eq!(a.load(), Words([1, 2, 3]));
        assert_eq!(
            a.compare_exchange(Words([1, 2, 3]), Words([4, 5, 6])),
            Ok(Words([1, 2, 3]))
        );
        assert_eq!(a.load(), Words([4, 5, 6]));
        assert_eq!(
            a.compare_exchange(Words([1, 2, 3]), Words([0, 0, 0])),
            Err(Words([4, 5, 6]))
        );
    }

    #[test]
    fn test_store_via_cas_loop() {
        let a: CachedWaitFree<Words<2>> = CachedWaitFree::new(Words([0, 0]));
        a.store(Words([3, 4]));
        assert_eq!(a.load(), Words([3, 4]));
        a.store(Words([3, 4])); // idempotent same-value store
        assert_eq!(a.load(), Words([3, 4]));
    }

    #[test]
    fn test_cache_validated_after_uncontended_cas() {
        let a: CachedWaitFree<Words<2>> = CachedWaitFree::new(Words([0, 0]));
        assert!(a.compare_exchange(Words([0, 0]), Words([1, 1])).is_ok());
        // Uncontended: pointer must be validated so loads take the fast
        // path. We can't observe the path directly, but the pointer mark
        // is visible through a debug read.
        let raw = a.backup.load(Ordering::SeqCst);
        assert!(!is_marked(raw), "cache should be validated when uncontended");
        assert_eq!(a.load(), Words([1, 1]));
    }

    #[test]
    fn test_concurrent_cas_exactly_one_winner() {
        // All threads CAS from the same snapshot; exactly one must win
        // per round.
        let a: Arc<CachedWaitFree<Words<4>>> = Arc::new(CachedWaitFree::new(Words([0; 4])));
        let threads = 4;
        let rounds = 2_000u64;
        let wins = Arc::new(std::sync::atomic::AtomicU64::new(0));
        let handles: Vec<_> = (0..threads)
            .map(|t| {
                let a = Arc::clone(&a);
                let wins = Arc::clone(&wins);
                std::thread::spawn(move || {
                    for r in 0..rounds {
                        let cur = a.load();
                        let next = Words([cur.0[0] + 1, r, t as u64, cur.0[3] ^ r]);
                        if a.compare_exchange(cur, next).is_ok() {
                            wins.fetch_add(1, Ordering::Relaxed);
                        }
                    }
                })
            })
            .collect();
        for h in handles {
            h.join().unwrap();
        }
        assert_eq!(a.load().0[0], wins.load(Ordering::SeqCst));
    }

    #[test]
    fn test_no_torn_reads_under_update_storm() {
        let a: Arc<CachedWaitFree<Words<4>>> = Arc::new(CachedWaitFree::new(Words([0; 4])));
        let stop = Arc::new(std::sync::atomic::AtomicBool::new(false));
        let readers: Vec<_> = (0..2)
            .map(|_| {
                let a = Arc::clone(&a);
                let stop = Arc::clone(&stop);
                std::thread::spawn(move || {
                    while !stop.load(Ordering::Relaxed) {
                        let v = a.load();
                        assert!(v.0.iter().all(|&w| w == v.0[0]), "torn: {:?}", v.0);
                    }
                })
            })
            .collect();
        let writers: Vec<_> = (0..2)
            .map(|t| {
                let a = Arc::clone(&a);
                std::thread::spawn(move || {
                    for i in 1..5_000u64 {
                        let cur = a.load();
                        let _ = a.compare_exchange(cur, Words([i * 2 + t; 4]));
                    }
                })
            })
            .collect();
        for w in writers {
            w.join().unwrap();
        }
        stop.store(true, Ordering::SeqCst);
        for r in readers {
            r.join().unwrap();
        }
    }
}
