//! `CachedWaitFree<T>` — **Algorithm 1**: the paper's wait-free big
//! atomic supporting `load` + `cas` in O(k) time (§3.1).
//!
//! Layout per atomic: a seqlock-style `version`, a `backup` pointer that
//! *always* references a heap node holding the current value, and an
//! inlined `cache`.  The backup pointer carries a mark bit: marked ⇒ the
//! cache is invalid.  Loads take the fast path (version / cache / backup
//! / version — no indirection, no hazard) whenever the pointer is
//! unmarked and the version is stable; otherwise they do one protected
//! read through the backup.  Updates linearize on the single-word CAS
//! that installs a new (marked) backup node, then opportunistically copy
//! the value into the cache and validate the pointer.
//!
//! Key invariants (proof sketch of Theorem 3.1):
//! 1. the current backup node always holds the current value;
//! 2. whenever the backup pointer is unmarked, cache == backup value.
//!
//! ## Ordering contract
//!
//! Three protocols compose here, each with its edges named inline:
//!
//! * the **seqlock** over `version`+`cache` (reader: `ACQUIRE` /
//!   `FENCE_ACQUIRE` / `RELAXED` re-check; writer: `ACQUIRE` lock-CAS,
//!   `FENCE_RELEASE`, `RELEASE` unlock) — exactly as in
//!   [`super::SeqLock`];
//! * **node publication**: the install CAS and the validate CAS are
//!   `RELEASE` so node contents (and, for validation, the fresh cache)
//!   happen-before the pointer value that reveals them; readers pair via
//!   the `ACQUIRE` validating load in `HazardPointer::protect_raw_with`
//!   or the pre-`FENCE_ACQUIRE` backup load of the fast path;
//! * **SMR store-load** — the mandatory `SeqCst` fences live in the
//!   scheme modules (`smr::hazard` announce→revalidate, `smr::epoch`
//!   pin→validate), not here.
//!
//! The policy parameter `P` (default [`DefaultPolicy`]) lets the
//! ordering ablation instantiate a blanket-`SeqCst` variant in a fenced
//! binary; the scheme parameter `S` (default [`Hazard`]) does the same
//! for the reclamation ablation (`repro ablate --panel smr`).

use std::marker::PhantomData;
use std::sync::atomic::{fence, AtomicU64, AtomicUsize, Ordering};

use super::bytewise::WordBuf;
use super::{AtomicValue, BigAtomic};
use crate::smr::{Hazard, Smr};
use crate::util::ordering::{DefaultPolicy, OrderingPolicy};

#[repr(C, align(8))]
struct Node<T> {
    value: T,
}

const MARK: usize = 1;

#[inline]
fn unmark(raw: usize) -> usize {
    raw & !MARK
}

#[inline]
fn is_marked(raw: usize) -> bool {
    raw & MARK == MARK
}

pub struct CachedWaitFree<T: AtomicValue, P: OrderingPolicy = DefaultPolicy, S: Smr = Hazard> {
    version: AtomicU64,
    /// Marked pointer to `Node<T>`; mark set ⇒ cache invalid.
    backup: AtomicUsize,
    cache: WordBuf<T>,
    _policy: PhantomData<fn() -> (P, S)>,
}

impl<T: AtomicValue, P: OrderingPolicy, S: Smr> CachedWaitFree<T, P, S> {
    #[inline]
    fn node_value(raw: usize) -> T {
        // SAFETY: caller protected `unmark(raw)` through an SMR guard
        // (or owns it exclusively); nodes are immutable after publish.
        unsafe { (*(unmark(raw) as *const Node<T>)).value }
    }

    /// Protect the current backup, announcing the *unmarked* node address
    /// (the address reclaimers compare against; a no-op under region
    /// schemes, whose pin covers everything).
    #[inline]
    fn protect_backup(&self, g: &S::Guard) -> usize {
        // Ordering: ACQUIRE — the validating call of this load inside
        // protect_raw pairs with the installer's RELEASE CAS, so the
        // node's contents are visible before node_value dereferences
        // it. The scheme's store-load SeqCst fence is inside the guard
        // (hazard) or was paid at pin time (epoch).
        g.protect_raw(|| self.backup.load(P::ACQUIRE), unmark)
    }
}

impl<T: AtomicValue, P: OrderingPolicy, S: Smr> Drop for CachedWaitFree<T, P, S> {
    fn drop(&mut self) {
        let raw = self.backup.load(Ordering::Relaxed);
        // SAFETY: exclusive in Drop; backup is always a live node.
        drop(unsafe { Box::from_raw(unmark(raw) as *mut Node<T>) });
    }
}

impl<T: AtomicValue, P: OrderingPolicy, S: Smr> BigAtomic<T> for CachedWaitFree<T, P, S> {
    fn new(init: T) -> Self {
        Self {
            version: AtomicU64::new(0),
            // Unmarked: cache starts valid and equal to the backup.
            backup: AtomicUsize::new(Box::into_raw(Box::new(Node { value: init })) as usize),
            cache: WordBuf::new(init),
            _policy: PhantomData,
        }
    }

    #[inline]
    fn load(&self) -> T {
        // Ordering: ACQUIRE — pairs with the RELEASE version unlock of
        // the writer that published v1's cache.
        let ver = self.version.load(P::ACQUIRE);
        let val = self.cache.read_p::<P>();
        // Ordering: RELAXED — validated through the fence + version
        // re-check below: if this read observed a validate-CAS'd
        // (unmarked) pointer whose cache we missed, the fence makes the
        // version bump visible and the re-check fails.
        let raw = self.backup.load(P::RELAXED);
        // Ordering: FENCE_ACQUIRE — load-load edge: cache and backup
        // reads complete before the version re-check; pairs with the
        // writer-side FENCE_RELEASE (cache copy) and the RELEASE
        // validate CAS.
        fence(P::FENCE_ACQUIRE);
        // Ordering: RELAXED — ordered by the fence above.
        if !is_marked(raw) && ver == self.version.load(P::RELAXED) {
            // Fast path: cache was valid and untouched through the window.
            crate::counter!(FastPathHit);
            return val;
        }
        // Slow path: one protected indirect read. The backup always holds
        // the current value, so no loop — wait-free.
        crate::counter!(FastPathMiss);
        let g = S::pin();
        let raw = self.protect_backup(&g);
        Self::node_value(raw)
    }

    #[inline]
    fn store(&self, val: T) {
        // Table 1: the load+cas variant has no native store; this CAS
        // loop is lock-free (each failure implies another update won)
        // and feeds the witness back instead of re-loading, backing off
        // adaptively between attempts.
        let mut cur = self.load();
        let mut bo = None;
        loop {
            if cur == val {
                return;
            }
            match self.compare_exchange(cur, val) {
                Ok(_) => return,
                Err(w) => {
                    cur = w;
                    crate::util::backoff::snooze_lazy(&mut bo);
                }
            }
        }
    }

    fn compare_exchange(&self, expected: T, desired: T) -> Result<T, T> {
        let g = S::pin();
        // Ordering: ACQUIRE — as in load's fast path.
        let ver = self.version.load(P::ACQUIRE);
        let mut val = self.cache.read_p::<P>();
        // Protect early: the install CAS below must only succeed if the
        // backup hasn't changed since this read (the guard prevents the
        // address being recycled — no ABA).
        let raw = self.protect_backup(&g);
        // Ordering: ACQUIRE — the SeqCst fence inside protect_backup
        // already orders this after the reads above; ACQUIRE keeps the
        // cache-validity decision paired with the version unlock.
        if is_marked(raw) || ver != self.version.load(P::ACQUIRE) {
            val = Self::node_value(raw);
        }
        if val != expected {
            return Err(val);
        }
        if expected == desired {
            // Never replace a value by an equal one: the backup pointer
            // would change and spuriously fail a concurrent CAS (§3.1).
            return Ok(val);
        }

        let new_node = Box::into_raw(Box::new(Node { value: desired }));
        let new_marked = new_node as usize | MARK; // cache invalid until copied
        // Fault window: marked node built, install CAS next — a kill
        // here leaks only the unpublished node; a stall forces rivals
        // onto the slow path until the cache is recached.
        crate::failpoint!(Alg1Install);
        // Ordering: RELEASE on success — the new node's contents must
        // happen-before its address is observable (readers ACQUIRE it);
        // RELAXED on failure — `actual` is only compared, and the retry
        // path re-synchronizes through protect/node_value.
        let installed = match self
            .backup
            .compare_exchange(raw, new_marked, P::RELEASE, P::RELAXED)
        {
            Ok(_) => true,
            Err(actual) => {
                // The first attempt may have failed only because the old
                // pointer was validated (marked -> unmarked) in between;
                // retry expecting the validated form.
                is_marked(raw)
                    && actual == unmark(raw)
                    && self
                        .backup
                        // Ordering: as the first install attempt.
                        .compare_exchange(actual, new_marked, P::RELEASE, P::RELAXED)
                        .is_ok()
            }
        };

        if !installed {
            // CAS failed: the value changed (linearize at the competing
            // update). The node was never published.
            crate::counter!(CasRetry);
            // SAFETY: unpublished, uniquely owned.
            drop(unsafe { Box::from_raw(new_node) });
            // Witness: one protected read of the node the winner
            // installed. Wait-free (no loop); may rarely equal
            // `expected` again if later updates restored it — see the
            // module docs' witness contract.
            let raw2 = self.protect_backup(&g);
            return Err(Self::node_value(raw2));
        }

        // Linearized at the install. Retire the old node (still
        // guard-protected by us, so it outlives this call).
        crate::counter!(SlowPathInstall);
        // SAFETY: unlinked by the successful install.
        unsafe { S::retire_box(unmark(raw) as *mut Node<T>) };

        // Try to copy into the cache: seqlock acquire, but additionally
        // require the version unchanged since *before* our install so we
        // never overwrite a more recent update's cache (§3.1).
        // Ordering: ACQUIRE re-check + ACQUIRE lock-CAS (RELAXED on
        // failure: we simply skip the copy) — the seqlock writer
        // protocol, as in SeqLock::lock.
        // Fault window: about to bid for the recache lock — skipping
        // (or dawdling) here just leaves the cache invalid, which the
        // invariants permit.
        crate::failpoint!(Alg1Recache);
        if ver % 2 == 0
            && ver == self.version.load(P::ACQUIRE)
            && self
                .version
                .compare_exchange(ver, ver + 1, P::ACQUIRE, P::RELAXED)
                .is_ok()
        {
            // Ordering: FENCE_RELEASE — odd version visible before the
            // cache words (pairs with the fast-path reader's
            // FENCE_ACQUIRE: a torn cache read implies a version change).
            fence(P::FENCE_RELEASE);
            self.cache.write_p::<P>(desired);
            // Ordering: RELEASE — cache writes happen-before the even
            // version.
            self.version.store(ver + 2, P::RELEASE);
            // Validate: only if our node is still the backup.
            // Ordering: RELEASE on success — the fresh cache and even
            // version happen-before the unmarked pointer a fast-path
            // reader pairs with them; RELAXED on failure (a newer
            // update owns the cache now).
            let validated = self
                .backup
                .compare_exchange(new_marked, unmark(new_marked), P::RELEASE, P::RELAXED)
                .is_ok();
            if validated {
                // The cache copy revalidated the pointer — the re-cache
                // half of the §3.1 help protocol.
                crate::counter!(HelpRecache);
            }
        }
        // If validation was skipped/failed the cache stays invalid until
        // a later uncontended CAS validates — permitted by the invariants.
        Ok(expected)
    }

    fn name() -> &'static str {
        "Cached-WaitFree"
    }

    fn indirect_bytes(&self) -> usize {
        std::mem::size_of::<Node<T>>()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::atomics::Words;
    use crate::util::ordering::SeqCstEverywhere;
    use std::sync::Arc;

    #[test]
    fn test_roundtrip() {
        let a: CachedWaitFree<Words<3>> = CachedWaitFree::new(Words([1, 2, 3]));
        assert_eq!(a.load(), Words([1, 2, 3]));
        assert_eq!(
            a.compare_exchange(Words([1, 2, 3]), Words([4, 5, 6])),
            Ok(Words([1, 2, 3]))
        );
        assert_eq!(a.load(), Words([4, 5, 6]));
        assert_eq!(
            a.compare_exchange(Words([1, 2, 3]), Words([0, 0, 0])),
            Err(Words([4, 5, 6]))
        );
    }

    #[test]
    fn test_store_via_cas_loop() {
        let a: CachedWaitFree<Words<2>> = CachedWaitFree::new(Words([0, 0]));
        a.store(Words([3, 4]));
        assert_eq!(a.load(), Words([3, 4]));
        a.store(Words([3, 4])); // idempotent same-value store
        assert_eq!(a.load(), Words([3, 4]));
    }

    #[test]
    fn test_explicit_seqcst_policy_variant() {
        // The ablation's blanket-SeqCst instantiation must behave
        // identically.
        let a: CachedWaitFree<Words<2>, SeqCstEverywhere> = CachedWaitFree::new(Words([0, 0]));
        assert_eq!(a.compare_exchange(Words([0, 0]), Words([1, 2])), Ok(Words([0, 0])));
        assert_eq!(a.load(), Words([1, 2]));
        a.store(Words([3, 4]));
        assert_eq!(a.load(), Words([3, 4]));
    }

    #[test]
    fn test_explicit_epoch_smr_variant() {
        // The region-scheme instantiation (used by the smr ablation)
        // must behave identically.
        use crate::smr::Epoch;
        let a: CachedWaitFree<Words<2>, DefaultPolicy, Epoch> = CachedWaitFree::new(Words([0, 0]));
        assert_eq!(a.compare_exchange(Words([0, 0]), Words([1, 2])), Ok(Words([0, 0])));
        assert_eq!(a.compare_exchange(Words([9, 9]), Words([3, 3])), Err(Words([1, 2])));
        a.store(Words([3, 4]));
        assert_eq!(a.load(), Words([3, 4]));
    }

    #[test]
    fn test_cache_validated_after_uncontended_cas() {
        let a: CachedWaitFree<Words<2>> = CachedWaitFree::new(Words([0, 0]));
        assert!(a.compare_exchange(Words([0, 0]), Words([1, 1])).is_ok());
        // Uncontended: pointer must be validated so loads take the fast
        // path. We can't observe the path directly, but the pointer mark
        // is visible through a debug read.
        let raw = a.backup.load(Ordering::SeqCst);
        assert!(!is_marked(raw), "cache should be validated when uncontended");
        assert_eq!(a.load(), Words([1, 1]));
    }

    #[test]
    fn test_concurrent_cas_exactly_one_winner() {
        // All threads CAS from the same snapshot; exactly one must win
        // per round.
        let a: Arc<CachedWaitFree<Words<4>>> = Arc::new(CachedWaitFree::new(Words([0; 4])));
        let threads = 4;
        let rounds = 2_000u64;
        let wins = Arc::new(std::sync::atomic::AtomicU64::new(0));
        let handles: Vec<_> = (0..threads)
            .map(|t| {
                let a = Arc::clone(&a);
                let wins = Arc::clone(&wins);
                std::thread::spawn(move || {
                    for r in 0..rounds {
                        let cur = a.load();
                        let next = Words([cur.0[0] + 1, r, t as u64, cur.0[3] ^ r]);
                        if a.compare_exchange(cur, next).is_ok() {
                            wins.fetch_add(1, Ordering::Relaxed);
                        }
                    }
                })
            })
            .collect();
        for h in handles {
            h.join().unwrap();
        }
        assert_eq!(a.load().0[0], wins.load(Ordering::SeqCst));
    }

    #[test]
    fn test_no_torn_reads_under_update_storm() {
        let a: Arc<CachedWaitFree<Words<4>>> = Arc::new(CachedWaitFree::new(Words([0; 4])));
        let stop = Arc::new(std::sync::atomic::AtomicBool::new(false));
        let readers: Vec<_> = (0..2)
            .map(|_| {
                let a = Arc::clone(&a);
                let stop = Arc::clone(&stop);
                std::thread::spawn(move || {
                    while !stop.load(Ordering::Relaxed) {
                        let v = a.load();
                        assert!(v.0.iter().all(|&w| w == v.0[0]), "torn: {:?}", v.0);
                    }
                })
            })
            .collect();
        let writers: Vec<_> = (0..2)
            .map(|t| {
                let a = Arc::clone(&a);
                std::thread::spawn(move || {
                    for i in 1..5_000u64 {
                        let cur = a.load();
                        let _ = a.compare_exchange(cur, Words([i * 2 + t; 4]));
                    }
                })
            })
            .collect();
        for w in writers {
            w.join().unwrap();
        }
        stop.store(true, Ordering::SeqCst);
        for r in readers {
            r.join().unwrap();
        }
    }
}
