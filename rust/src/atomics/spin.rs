//! Test-and-test-and-set spinlock with contention-adaptive backoff and
//! yield-after-spin — the lock under `SimpLock`, `LockPool`, and the
//! `HtmSim` fallback path.
//!
//! Waiters go through [`crate::util::backoff::Backoff`]
//! (truncated-exponential spin, then yield): the yield matters for the
//! paper's oversubscription
//! experiments — a descheduled lock holder must eventually run again —
//! and the Dice-et-al. adaptive spin keeps the uncontended fast path at
//! a single CAS.  Disabling backoff (`util::backoff::set_enabled(false)`)
//! restores the seed's spin-a-full-quantum-then-yield behavior, the
//! §5.1 pathology the ablation quantifies.
//!
//! ## Ordering contract
//!
//! The lock word is the only synchronization: `ACQUIRE` on a successful
//! acquisition pairs with the `RELEASE` unlock of the previous holder,
//! so everything done inside the previous critical section
//! happens-before this one.  All waiting-side reads are `RELAXED` — they
//! decide nothing; the CAS re-validates.

use std::sync::atomic::AtomicBool;

use crate::util::backoff::snooze_lazy;
use crate::util::ordering::{DefaultPolicy as P, OrderingPolicy};

/// A one-word spinlock.
pub struct SpinLock {
    locked: AtomicBool,
}

impl SpinLock {
    pub const fn new() -> Self {
        Self {
            locked: AtomicBool::new(false),
        }
    }

    /// Try once (test-and-set only if observed free).
    #[inline]
    pub fn try_lock(&self) -> bool {
        // Ordering: RELAXED test — a stale `false` costs one failed CAS;
        // the CAS decides.
        !self.locked.load(P::RELAXED)
            && self
                .locked
                // Ordering: ACQUIRE on success — pairs with the RELEASE
                // unlock of the previous holder (critical-section
                // happens-before); RELAXED on failure (nothing learned).
                .compare_exchange(false, true, P::ACQUIRE, P::RELAXED)
                .is_ok()
    }

    /// Acquire, spinning with adaptive backoff then yielding.
    #[inline]
    pub fn lock(&self) {
        // Lazy: the uncontended acquire pays no backoff/TLS cost.
        let mut bo = None;
        loop {
            if self.try_lock() {
                // Counts SimpLock, LockPool, and HtmSim-fallback
                // acquisitions alike (the callers share this lock).
                crate::counter!(LockAcquire);
                // Fault window: critical section entered — a stall here
                // models the descheduled-holder pathology (NOT
                // kill-safe: the lock has no owner-death recovery).
                crate::failpoint!(SpinLockAcquired);
                return;
            }
            crate::counter!(CasRetry);
            // Ordering: RELAXED wait-test — purely advisory; the
            // acquiring CAS in try_lock re-validates.
            while self.locked.load(P::RELAXED) {
                snooze_lazy(&mut bo);
            }
        }
    }

    /// Whether the lock is currently held (used by `HtmSim`'s
    /// lock-subscription emulation).
    #[inline]
    pub fn is_locked(&self) -> bool {
        // Ordering: RELAXED — advisory only: HtmSim's transactions use
        // this to abort early/fairly; mutual exclusion is enforced by
        // the version word, not this read.
        self.locked.load(P::RELAXED)
    }

    #[inline]
    pub fn unlock(&self) {
        // Ordering: RELEASE — the critical section happens-before the
        // next ACQUIRE acquisition.
        self.locked.store(false, P::RELEASE);
    }

    /// Scoped acquisition.
    #[inline]
    pub fn with<R>(&self, f: impl FnOnce() -> R) -> R {
        self.lock();
        let r = f();
        self.unlock();
        r
    }
}

impl Default for SpinLock {
    fn default() -> Self {
        Self::new()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::Arc;

    #[test]
    fn test_lock_unlock() {
        let l = SpinLock::new();
        l.lock();
        assert!(!l.try_lock());
        l.unlock();
        assert!(l.try_lock());
        l.unlock();
    }

    #[test]
    fn test_mutual_exclusion_counter() {
        // Classic non-atomic counter under the lock: any exclusion bug
        // loses increments.
        let lock = Arc::new(SpinLock::new());
        let counter = Arc::new(std::cell::UnsafeCell::new(0u64));
        struct SendCell(Arc<std::cell::UnsafeCell<u64>>);
        unsafe impl Send for SendCell {}
        let threads = 4;
        let per = 20_000u64;
        let handles: Vec<_> = (0..threads)
            .map(|_| {
                let lock = Arc::clone(&lock);
                let cell = SendCell(Arc::clone(&counter));
                std::thread::spawn(move || {
                    let cell = cell; // capture the whole Send wrapper
                    for _ in 0..per {
                        lock.with(|| unsafe { *cell.0.get() += 1 });
                    }
                })
            })
            .collect();
        for h in handles {
            h.join().unwrap();
        }
        assert_eq!(unsafe { *counter.get() }, threads as u64 * per);
    }
}
