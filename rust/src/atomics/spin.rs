//! Test-and-test-and-set spinlock with bounded exponential backoff and
//! yield-after-spin — the lock under `SimpLock`, `LockPool`, and the
//! `HtmSim` fallback path.
//!
//! The yield matters for the paper's oversubscription experiments: a
//! descheduled lock holder must eventually run again, and spinning waiters
//! burning whole quanta is exactly the pathology §5.1 measures. Spinning
//! briefly first keeps the uncontended/undersubscribed fast path fast.

use std::sync::atomic::{AtomicBool, Ordering};

// Spin ~1M iterations (≈1-2ms, a scheduler quantum) before yielding.
// Faithful to the paper's lock implementations, which spin: a waiter
// whose lock holder was descheduled burns its quantum — exactly the
// oversubscription pathology §5.1 measures.  The eventual yield is a
// livelock safety valve only.
const SPINS_BEFORE_YIELD: u32 = 1 << 20;

/// A one-word spinlock.
pub struct SpinLock {
    locked: AtomicBool,
}

impl SpinLock {
    pub const fn new() -> Self {
        Self {
            locked: AtomicBool::new(false),
        }
    }

    /// Try once (test-and-set only if observed free).
    #[inline]
    pub fn try_lock(&self) -> bool {
        !self.locked.load(Ordering::Relaxed)
            && self
                .locked
                .compare_exchange(false, true, Ordering::Acquire, Ordering::Relaxed)
                .is_ok()
    }

    /// Acquire, spinning with backoff then yielding.
    #[inline]
    pub fn lock(&self) {
        let mut spins = 0u32;
        loop {
            if self.try_lock() {
                return;
            }
            while self.locked.load(Ordering::Relaxed) {
                spins += 1;
                if spins >= SPINS_BEFORE_YIELD {
                    std::thread::yield_now();
                    spins = 0;
                } else {
                    std::hint::spin_loop();
                }
            }
        }
    }

    /// Whether the lock is currently held (used by `HtmSim`'s
    /// lock-subscription emulation).
    #[inline]
    pub fn is_locked(&self) -> bool {
        self.locked.load(Ordering::Relaxed)
    }

    #[inline]
    pub fn unlock(&self) {
        self.locked.store(false, Ordering::Release);
    }

    /// Scoped acquisition.
    #[inline]
    pub fn with<R>(&self, f: impl FnOnce() -> R) -> R {
        self.lock();
        let r = f();
        self.unlock();
        r
    }
}

impl Default for SpinLock {
    fn default() -> Self {
        Self::new()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::Arc;

    #[test]
    fn test_lock_unlock() {
        let l = SpinLock::new();
        l.lock();
        assert!(!l.try_lock());
        l.unlock();
        assert!(l.try_lock());
        l.unlock();
    }

    #[test]
    fn test_mutual_exclusion_counter() {
        // Classic non-atomic counter under the lock: any exclusion bug
        // loses increments.
        let lock = Arc::new(SpinLock::new());
        let counter = Arc::new(std::cell::UnsafeCell::new(0u64));
        struct SendCell(Arc<std::cell::UnsafeCell<u64>>);
        unsafe impl Send for SendCell {}
        let threads = 4;
        let per = 20_000u64;
        let handles: Vec<_> = (0..threads)
            .map(|_| {
                let lock = Arc::clone(&lock);
                let cell = SendCell(Arc::clone(&counter));
                std::thread::spawn(move || {
                    let cell = cell; // capture the whole Send wrapper
                    for _ in 0..per {
                        lock.with(|| unsafe { *cell.0.get() += 1 });
                    }
                })
            })
            .collect();
        for h in handles {
            h.join().unwrap();
        }
        assert_eq!(unsafe { *counter.get() }, threads as u64 * per);
    }
}
