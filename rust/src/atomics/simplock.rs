//! `SimpLock<T>` — the simplest lock-based big atomic (paper §2):
//! one spinlock per atomic, acquired by *every* operation, loads
//! included.  The paper's worst classic baseline at low update rates
//! (loads contend with each other) and under oversubscription.

use std::cell::UnsafeCell;

use super::spin::SpinLock;
use super::{AtomicValue, BigAtomic};

pub struct SimpLock<T: AtomicValue> {
    lock: SpinLock,
    data: UnsafeCell<T>,
}

// SAFETY: data is only touched while `lock` is held.
unsafe impl<T: AtomicValue> Send for SimpLock<T> {}
unsafe impl<T: AtomicValue> Sync for SimpLock<T> {}

impl<T: AtomicValue> BigAtomic<T> for SimpLock<T> {
    fn new(init: T) -> Self {
        Self {
            lock: SpinLock::new(),
            data: UnsafeCell::new(init),
        }
    }

    #[inline]
    fn load(&self) -> T {
        // SAFETY: exclusive under the lock.
        self.lock.with(|| unsafe { *self.data.get() })
    }

    #[inline]
    fn store(&self, val: T) {
        self.lock.with(|| unsafe { *self.data.get() = val });
    }

    #[inline]
    fn cas(&self, expected: T, desired: T) -> bool {
        self.lock.with(|| {
            // SAFETY: exclusive under the lock.
            let cur = unsafe { *self.data.get() };
            if cur == expected {
                unsafe { *self.data.get() = desired };
                true
            } else {
                false
            }
        })
    }

    fn name() -> &'static str {
        "SimpLock"
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::atomics::Words;
    use std::sync::Arc;

    #[test]
    fn test_roundtrip_and_cas() {
        let a: SimpLock<Words<2>> = SimpLock::new(Words([7, 8]));
        assert_eq!(a.load(), Words([7, 8]));
        a.store(Words([1, 2]));
        assert!(a.cas(Words([1, 2]), Words([3, 4])));
        assert!(!a.cas(Words([1, 2]), Words([9, 9])));
        assert_eq!(a.load(), Words([3, 4]));
    }

    #[test]
    fn test_concurrent_cas_counter() {
        // Each thread increments word0 via cas; total must be exact.
        let a: Arc<SimpLock<Words<2>>> = Arc::new(SimpLock::new(Words([0, 0])));
        let threads = 4;
        let per = 5_000u64;
        let handles: Vec<_> = (0..threads)
            .map(|_| {
                let a = Arc::clone(&a);
                std::thread::spawn(move || {
                    for _ in 0..per {
                        loop {
                            let cur = a.load();
                            let next = Words([cur.0[0] + 1, cur.0[1] + 3]);
                            if a.cas(cur, next) {
                                break;
                            }
                        }
                    }
                })
            })
            .collect();
        for h in handles {
            h.join().unwrap();
        }
        let v = a.load();
        assert_eq!(v.0[0], threads as u64 * per);
        assert_eq!(v.0[1], 3 * threads as u64 * per);
    }
}
