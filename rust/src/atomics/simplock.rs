//! `SimpLock<T>` — the simplest lock-based big atomic (paper §2):
//! one spinlock per atomic, acquired by *every* operation, loads
//! included.  The paper's worst classic baseline at low update rates
//! (loads contend with each other) and under oversubscription.
//!
//! ## Ordering contract
//!
//! The data is a plain (non-atomic) `UnsafeCell`, so the lock word is
//! the *only* synchronization: `ACQUIRE` acquisition / `RELEASE` unlock
//! in [`SpinLock`] make each critical section happen-before the next —
//! nothing here can be demoted further (and nothing needs `SeqCst`).
//! Lock waiting goes through the adaptive `util::backoff::Backoff`
//! inside `SpinLock::lock`.

use std::cell::UnsafeCell;

use super::spin::SpinLock;
use super::{AtomicValue, BigAtomic};

pub struct SimpLock<T: AtomicValue> {
    lock: SpinLock,
    data: UnsafeCell<T>,
}

// SAFETY: data is only touched while `lock` is held.
unsafe impl<T: AtomicValue> Send for SimpLock<T> {}
unsafe impl<T: AtomicValue> Sync for SimpLock<T> {}

impl<T: AtomicValue> BigAtomic<T> for SimpLock<T> {
    fn new(init: T) -> Self {
        Self {
            lock: SpinLock::new(),
            data: UnsafeCell::new(init),
        }
    }

    #[inline]
    fn load(&self) -> T {
        // SAFETY: exclusive under the lock.
        self.lock.with(|| unsafe { *self.data.get() })
    }

    #[inline]
    fn store(&self, val: T) {
        self.lock.with(|| unsafe { *self.data.get() = val });
    }

    #[inline]
    fn compare_exchange(&self, expected: T, desired: T) -> Result<T, T> {
        self.lock.with(|| {
            // SAFETY: exclusive under the lock.
            let cur = unsafe { *self.data.get() };
            if cur == expected {
                unsafe { *self.data.get() = desired };
                Ok(cur)
            } else {
                Err(cur)
            }
        })
    }

    /// Native exchange under the per-object lock.
    #[inline]
    fn swap(&self, new: T) -> T {
        self.lock.with(|| {
            // SAFETY: exclusive under the lock.
            let cur = unsafe { *self.data.get() };
            unsafe { *self.data.get() = new };
            cur
        })
    }

    // `fetch_update` keeps the default (load + CAS loop): running the
    // user closure under the non-panic-safe spinlock would wedge the
    // atomic if `f` panics.

    fn name() -> &'static str {
        "SimpLock"
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::atomics::Words;
    use std::sync::Arc;

    #[test]
    fn test_roundtrip_and_compare_exchange() {
        let a: SimpLock<Words<2>> = SimpLock::new(Words([7, 8]));
        assert_eq!(a.load(), Words([7, 8]));
        a.store(Words([1, 2]));
        assert_eq!(a.compare_exchange(Words([1, 2]), Words([3, 4])), Ok(Words([1, 2])));
        assert_eq!(a.compare_exchange(Words([1, 2]), Words([9, 9])), Err(Words([3, 4])));
        assert_eq!(a.load(), Words([3, 4]));
        assert_eq!(a.swap(Words([5, 5])), Words([3, 4]));
    }

    #[test]
    fn test_concurrent_cas_counter() {
        // Each thread increments word0 via a witness-fed CAS loop; the
        // total must be exact.
        let a: Arc<SimpLock<Words<2>>> = Arc::new(SimpLock::new(Words([0, 0])));
        let threads = 4;
        let per = 5_000u64;
        let handles: Vec<_> = (0..threads)
            .map(|_| {
                let a = Arc::clone(&a);
                std::thread::spawn(move || {
                    for _ in 0..per {
                        let mut cur = a.load();
                        loop {
                            let next = Words([cur.0[0] + 1, cur.0[1] + 3]);
                            match a.compare_exchange(cur, next) {
                                Ok(_) => break,
                                Err(w) => cur = w,
                            }
                        }
                    }
                })
            })
            .collect();
        for h in handles {
            h.join().unwrap();
        }
        let v = a.load();
        assert_eq!(v.0[0], threads as u64 * per);
        assert_eq!(v.0[1], 3 * threads as u64 * per);
    }
}
