//! `CachedWritable<T>` — **Algorithm 3** (WD-LSC): wait-free big atomic
//! supporting `load`, `store`, *and* `cas`, built over the Load/CAS big
//! atomic of Algorithm 1 (§3.3).
//!
//! The central variable `Z` is a [`CachedWaitFree`] holding the triple
//! `(value, seq, mark)`.  Stores buffer their value in the single
//! write-buffer pointer `W` (whose mark bit, compared with `Z.mark`,
//! encodes "a write is pending") and are *transferred* into `Z` by
//! helpers — every store and every cas helps, so a buffered write lands
//! within two `help_write` attempts and all operations are O(k).
//!
//! ## Ordering contract
//!
//! All heavy lifting is inside the inner [`CachedWaitFree`] (whose own
//! contract applies to `Z`); the only orderings owned here govern the
//! write-buffer pointer `W`: `RELEASE` on the buffering CAS (the new
//! `WNode`'s contents happen-before its address) pairing with the
//! `ACQUIRE` validating load inside `protect_w`, plus the reclamation
//! scheme's own store-load fence (in `smr`).  The scheme parameter `S`
//! (default [`Hazard`]) is threaded through to the inner `Z` as well,
//! so `CachedWritable<T, Epoch>` runs entirely on epochs.

use std::marker::PhantomData;
use std::sync::atomic::{AtomicUsize, Ordering};

use super::cached_waitfree::CachedWaitFree;
use super::{AtomicValue, BigAtomic};
use crate::smr::{Hazard, Smr};
use crate::util::ordering::{DefaultPolicy, OrderingPolicy};

type P = DefaultPolicy;

/// The triple stored in Z. `seq` defeats ABA on transfers; `mark`
/// (0 or 1), compared against W's pointer mark, encodes write-pending.
#[repr(C, align(8))]
#[derive(Copy, Clone, PartialEq)]
struct ZVal<T: AtomicValue> {
    value: T,
    seq: u64,
    mark: u64,
}

impl<T: AtomicValue> Default for ZVal<T> {
    fn default() -> Self {
        Self {
            value: T::default(),
            seq: 0,
            mark: 0,
        }
    }
}

// SAFETY: repr(C) of pod fields; size = k+2 words, align 8.
unsafe impl<T: AtomicValue> AtomicValue for ZVal<T> {}

#[repr(C, align(8))]
struct WNode<T> {
    value: T,
}

const MARK: usize = 1;

pub struct CachedWritable<T: AtomicValue, S: Smr = Hazard> {
    z: CachedWaitFree<ZVal<T>, P, S>,
    /// Marked pointer to `WNode<T>` — the write buffer.
    w: AtomicUsize,
    _smr: PhantomData<fn() -> S>,
}

impl<T: AtomicValue, S: Smr> CachedWritable<T, S> {
    #[inline]
    fn w_value(raw: usize) -> T {
        // SAFETY: caller protected the unmarked node through an SMR guard.
        unsafe { (*((raw & !MARK) as *const WNode<T>)).value }
    }

    #[inline]
    fn protect_w(&self, g: &S::Guard) -> usize {
        // Ordering: ACQUIRE — the validating call pairs with the
        // buffering CAS's RELEASE so the WNode contents are visible
        // before w_value dereferences them; the scheme's store-load
        // SeqCst fence is inside the guard (hazard) or was paid at pin
        // time (epoch).
        g.protect_raw(|| self.w.load(P::ACQUIRE), |r| r & !MARK)
    }

    /// Transfer a pending buffered write from W into Z (§3.3).
    /// Returns false only if a concurrent successful CAS changed Z while
    /// a write was pending — which can happen at most once per pending
    /// write, hence callers try twice.
    fn help_write(&self) -> bool {
        // Fault window: a helper about to transfer W into Z — dying or
        // dawdling here is harmless because every store and cas helps
        // (a pending write lands within two attempts by *someone*).
        crate::failpoint!(Alg3Transfer);
        let z = self.z.load();
        let g = S::pin();
        let wr = self.protect_w(&g);
        let w_mark = (wr & MARK) as u64;
        if z.mark != w_mark {
            // Pending: move W's value into Z and re-match the marks.
            let transferred = self
                .z
                .compare_exchange(
                    z,
                    ZVal {
                        value: Self::w_value(wr),
                        seq: z.seq + 1,
                        mark: w_mark,
                    },
                )
                .is_ok();
            if transferred {
                // A buffered store landed via the §3.3 help protocol
                // (by its owner or a helper — both count).
                crate::counter!(HelpWrite);
            }
            transferred
        } else {
            true
        }
    }
}

impl<T: AtomicValue, S: Smr> Drop for CachedWritable<T, S> {
    fn drop(&mut self) {
        let raw = self.w.load(Ordering::Relaxed);
        // SAFETY: exclusive in Drop.
        drop(unsafe { Box::from_raw((raw & !MARK) as *mut WNode<T>) });
    }
}

impl<T: AtomicValue, S: Smr> BigAtomic<T> for CachedWritable<T, S> {
    fn new(init: T) -> Self {
        Self {
            z: CachedWaitFree::new(ZVal {
                value: init,
                seq: 0,
                mark: 0,
            }),
            // Unmarked node matching z.mark = 0: no pending write.
            w: AtomicUsize::new(Box::into_raw(Box::new(WNode { value: init })) as usize),
            _smr: PhantomData,
        }
    }

    #[inline]
    fn load(&self) -> T {
        self.z.load().value
    }

    fn store(&self, desired: T) {
        let g = S::pin();
        let wr = self.protect_w(&g);
        let z = self.z.load();
        if z.value == desired {
            return; // silent linearization at the Z read
        }
        if z.mark == (wr & MARK) as u64 {
            // No pending write: try to buffer ours with mismatched mark.
            let n = Box::into_raw(Box::new(WNode { value: desired }));
            let new_w = (n as usize) | ((1 - z.mark) as usize);
            if self
                .w
                // Ordering: RELEASE on success — the buffered WNode's
                // contents happen-before its address (helpers ACQUIRE it
                // through protect_w); RELAXED on failure — the loser
                // only frees its unpublished node and helps.
                .compare_exchange(wr, new_w, P::RELEASE, P::RELAXED)
                .is_ok()
            {
                // SAFETY: old buffer node unlinked (guard-protected
                // readers may remain).
                unsafe { S::retire_box((wr & !MARK) as *mut WNode<T>) };
            } else {
                // Another writer buffered first; we linearize silently
                // just before their transfer.
                // SAFETY: never published.
                drop(unsafe { Box::from_raw(n) });
            }
        }
        // Ensure any pending write (ours or the one that beat us) is
        // transferred: one retry suffices (§3.3).
        if !self.help_write() {
            self.help_write();
        }
    }

    fn compare_exchange(&self, expected: T, desired: T) -> Result<T, T> {
        // The inner CAS's witness feeds each retry — Z is loaded exactly
        // once, never re-loaded.
        let mut z = self.z.load();
        for _ in 0..2 {
            if z.value != expected {
                return Err(z.value); // witness from the Z read
            }
            if expected == desired {
                return Ok(z.value);
            }
            // Help writers first so we never starve a buffered store.
            self.help_write();
            match self.z.compare_exchange(
                z,
                ZVal {
                    value: desired,
                    seq: z.seq + 1,
                    mark: z.mark,
                },
            ) {
                Ok(_) => return Ok(expected),
                Err(w) => {
                    crate::counter!(CasRetry);
                    z = w;
                }
            }
            // Failure may be a same-value transfer bumping seq; Z.value
            // can have stayed == expected at most once (§3.3), so retry
            // exactly once before giving up (wait-freedom).
        }
        // Both bounded attempts lost; the last witness may, rarely,
        // equal `expected` again (see the module docs' witness
        // contract) — callers treat Err as "retry from here".
        Err(z.value)
    }

    fn name() -> &'static str {
        "Cached-WaitFree-Writable"
    }

    fn indirect_bytes(&self) -> usize {
        self.z.indirect_bytes() + std::mem::size_of::<WNode<T>>()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::atomics::Words;
    use std::sync::Arc;

    #[test]
    fn test_roundtrip_all_three_ops() {
        let a: CachedWritable<Words<2>> = CachedWritable::new(Words([1, 2]));
        assert_eq!(a.load(), Words([1, 2]));
        a.store(Words([3, 4]));
        assert_eq!(a.load(), Words([3, 4]));
        assert_eq!(a.compare_exchange(Words([3, 4]), Words([5, 6])), Ok(Words([3, 4])));
        assert_eq!(a.compare_exchange(Words([3, 4]), Words([7, 8])), Err(Words([5, 6])));
        assert_eq!(a.load(), Words([5, 6]));
    }

    #[test]
    fn test_roundtrip_under_epoch_smr() {
        use crate::smr::Epoch;
        let a: CachedWritable<Words<2>, Epoch> = CachedWritable::new(Words([1, 2]));
        a.store(Words([3, 4]));
        assert_eq!(a.load(), Words([3, 4]));
        assert_eq!(a.compare_exchange(Words([3, 4]), Words([5, 6])), Ok(Words([3, 4])));
        assert_eq!(a.load(), Words([5, 6]));
    }

    #[test]
    fn test_store_same_value_noop() {
        let a: CachedWritable<Words<1>> = CachedWritable::new(Words([9]));
        a.store(Words([9]));
        assert_eq!(a.load(), Words([9]));
    }

    #[test]
    fn test_store_visible_despite_competing_cas() {
        // Writers (stores) must not starve: after every store returns,
        // some load must have been able to see it or a later value
        // (here single-threaded: immediate visibility).
        let a: CachedWritable<Words<2>> = CachedWritable::new(Words([0, 0]));
        for i in 1..500u64 {
            a.store(Words([i, i * 2]));
            assert_eq!(a.load(), Words([i, i * 2]));
        }
    }

    #[test]
    fn test_concurrent_stores_and_cas_consistency() {
        // CAS counter on word0 while stores rewrite word1; every read
        // must be a value some operation actually wrote (word1 is either
        // a store payload or a cas payload, tagged by high bit).
        let a: Arc<CachedWritable<Words<2>>> = Arc::new(CachedWritable::new(Words([0, 0])));
        let casers: Vec<_> = (0..2)
            .map(|_| {
                let a = Arc::clone(&a);
                std::thread::spawn(move || {
                    let mut wins = 0u64;
                    let mut cur = a.load();
                    while wins < 2_000 {
                        match a.compare_exchange(cur, Words([cur.0[0] + 1, cur.0[1]])) {
                            Ok(prev) => {
                                wins += 1;
                                cur = Words([prev.0[0] + 1, prev.0[1]]);
                            }
                            Err(w) => cur = w,
                        }
                    }
                })
            })
            .collect();
        let storer = {
            let a = Arc::clone(&a);
            std::thread::spawn(move || {
                for i in 1..2_000u64 {
                    let cur = a.load();
                    a.store(Words([cur.0[0], i | (1 << 63)]));
                }
            })
        };
        for c in casers {
            c.join().unwrap();
        }
        storer.join().unwrap();
        let v = a.load();
        assert!(v.0[0] >= 4_000, "cas wins lost: {}", v.0[0]);
    }

    #[test]
    fn test_no_torn_reads() {
        let a: Arc<CachedWritable<Words<4>>> = Arc::new(CachedWritable::new(Words([0; 4])));
        let stop = Arc::new(std::sync::atomic::AtomicBool::new(false));
        let readers: Vec<_> = (0..2)
            .map(|_| {
                let a = Arc::clone(&a);
                let stop = Arc::clone(&stop);
                std::thread::spawn(move || {
                    while !stop.load(Ordering::Relaxed) {
                        let v = a.load();
                        assert!(v.0.iter().all(|&w| w == v.0[0]), "torn: {:?}", v.0);
                    }
                })
            })
            .collect();
        for i in 1..4_000u64 {
            a.store(Words([i; 4]));
        }
        stop.store(true, Ordering::SeqCst);
        for r in readers {
            r.join().unwrap();
        }
    }
}
