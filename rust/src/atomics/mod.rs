//! Big atomics: `std::atomic`-shaped operations — `load` / `store` /
//! `compare_exchange` / `swap` / `fetch_update` — over k adjacent
//! 64-bit words.
//!
//! The eight implementations the paper evaluates, all behind one
//! [`BigAtomic`] trait so the §5 harness drives them uniformly:
//!
//! * classic baselines — [`SeqLock`], [`SimpLock`], [`LockPool`],
//!   [`Indirect`], [`HtmSim`];
//! * the paper's contributions — [`CachedWaitFree`] (Algorithm 1),
//!   [`CachedMemEff`] (Algorithm 2), [`CachedWritable`] (Algorithm 3).
//!
//! Values are plain-old-data types implementing [`AtomicValue`]; the
//! provided [`Words`] carries `K` raw words and is what the benchmarks
//! instantiate (`w` sweep of Fig 2). `u64` also implements
//! [`AtomicValue`], so single-word keys/values compose with the generic
//! [`crate::hash`] tables.
//!
//! ## The witnessing CAS
//!
//! The primitive update operation is
//! [`compare_exchange`](BigAtomic::compare_exchange):
//!
//! ```
//! use big_atomics::atomics::{BigAtomic, CachedMemEff, Words};
//!
//! let a: CachedMemEff<Words<4>> = CachedMemEff::new(Words([1, 2, 3, 4]));
//! let v = a.load();
//! // Success returns the consumed value...
//! assert_eq!(a.compare_exchange(v, Words([5, 6, 7, 8])), Ok(v));
//! // ...failure returns the *witnessed* current value, so retry loops
//! // never re-load (the dominant cost under contention).
//! assert_eq!(a.compare_exchange(v, Words([0; 4])), Err(Words([5, 6, 7, 8])));
//! // The closure form packages the whole retry loop:
//! let prev = a
//!     .fetch_update(|mut cur| {
//!         cur.0[0] += 1;
//!         Some(cur)
//!     })
//!     .unwrap();
//! assert_eq!(prev, Words([5, 6, 7, 8]));
//! assert_eq!(a.swap(Words([9; 4])), Words([6, 6, 7, 8]));
//! ```
//!
//! **Witness contract.** `Err(w)` means the CAS failed and `w` is a
//! linearizable read of the value taken *during the call*. On the exact
//! (lock-based) backends `w != expected` always holds. On the wait-free
//! cached backends ([`CachedWaitFree`], [`CachedWritable`]) a competing
//! update can change the value away from `expected` (failing the CAS)
//! and a later one can restore it before the witness read — so `w` may,
//! rarely, equal `expected` again. Treat `Err(w)` as "retry from `w`"
//! (what [`fetch_update`](BigAtomic::fetch_update) does), never as a
//! proof that `w != expected`. [`CachedMemEff`] and [`Indirect`] retry
//! internally (they are lock-free regardless) and guarantee
//! `w != expected`.
//!
//! **AA rule.** `compare_exchange(v, v)` with `v` current returns
//! `Ok(v)` *without* performing a physical update: the cached algorithms
//! must never replace a value by an equal one (§3.1 — it would disturb
//! concurrent CASes for no observable effect).
//!
//! ## Ordering contract
//!
//! The backends are on a **memory-ordering diet** (see
//! [`crate::util::ordering`]): no operation issues `SeqCst` accesses.
//! The entire core is built from three reusable edge patterns, and every
//! demoted site carries an `// Ordering:` comment naming its edge:
//!
//! 1. **Seqlock bracket** — readers: `ACQUIRE` version read →
//!    `RELAXED` data words → `FENCE_ACQUIRE` → `RELAXED` version
//!    re-check; writers: `ACQUIRE` lock-CAS → `FENCE_RELEASE` →
//!    `RELAXED` data words → `RELEASE` unlock. The two fences are the
//!    load-load and store-store edges per-word relaxed accesses cannot
//!    provide.
//! 2. **Pointer publication** — installing CAS/swap is `RELEASE`
//!    (node contents happen-before the address), readers `ACQUIRE` the
//!    pointer before dereferencing.
//! 3. **SMR store-load** — the crate's only `fence(SeqCst)` points live
//!    in [`crate::smr`]: the hazard pair (announce→revalidate and
//!    retire→scan, `smr::hazard`) and the epoch pair (pin→validate and
//!    advance→scan, `smr::epoch`); all four are mandatory under *both*
//!    policies.
//!
//! `cargo build --features seqcst_audit` restores the seed's blanket
//! `SeqCst` at every demoted site (the fences widen to `SeqCst` too), so
//! the full suite can be run against sequential consistency when
//! auditing a suspected ordering bug.
//!
//! ## Contention management
//!
//! Every retry loop (the default [`swap`](BigAtomic::swap) /
//! [`fetch_update`](BigAtomic::fetch_update) combinators, each backend's
//! internal install/store loops, and the consumers' witness-fed loops)
//! backs off through the contention-adaptive
//! [`Backoff`](crate::util::backoff::Backoff) instead of hammering the
//! contended line — per Dice, Hendler & Mirsky, failed-CAS retries that
//! re-acquire the line immediately collapse into coherence traffic.
//! `util::backoff::set_enabled(false)` restores the seed's bare-retry
//! behavior; `repro ablate --panel ordering` reports all variants.

pub mod bytewise;
pub mod cached_memeff;
pub mod cached_waitfree;
pub mod cached_writable;
pub mod htm_sim;
pub mod indirect;
pub mod lockpool;
pub mod seqlock;
pub mod simplock;
pub mod spin;

pub use cached_memeff::{CachedMemEff, MemEffDomain};
pub use cached_waitfree::CachedWaitFree;
pub use cached_writable::CachedWritable;
pub use htm_sim::HtmSim;
pub use indirect::Indirect;
pub use lockpool::LockPool;
pub use seqlock::SeqLock;
pub use simplock::SimpLock;

/// A value storable in a big atomic.
///
/// # Safety
/// Implementors guarantee:
/// * `size_of::<Self>()` is a nonzero multiple of 8 and
///   `align_of::<Self>() == 8` (the slots are accessed word-wise);
/// * every bit pattern produced by word-wise copies of a valid value is
///   itself valid (plain old data, no padding that `PartialEq` inspects);
/// * `PartialEq` is an equivalence relation on the bit level (the
///   algorithms' AA-freedom argument compares values, and the hash
///   tables hash values word-wise).
pub unsafe trait AtomicValue:
    Copy + PartialEq + Default + Send + Sync + 'static
{
    /// Size in 64-bit words (the paper's `k`).
    const WORDS: usize = std::mem::size_of::<Self>() / 8;
}

/// `K` raw 64-bit words — the benchmark value type (flag + payload in §5.1).
#[repr(C, align(8))]
#[derive(Copy, Clone, PartialEq, Eq, Debug)]
pub struct Words<const K: usize>(pub [u64; K]);

impl<const K: usize> Default for Words<K> {
    fn default() -> Self {
        Words([0; K])
    }
}

// SAFETY: repr(C) array of u64 — no padding, align 8, bitwise Eq.
unsafe impl<const K: usize> AtomicValue for Words<K> {}

// SAFETY: one word, bitwise Eq; the align assertion below guards exotic
// 32-bit targets where u64 is only 4-byte aligned.
unsafe impl AtomicValue for u64 {}
const _: () = assert!(std::mem::align_of::<u64>() == 8);

/// Implement [`AtomicValue`] for a `#[repr(C)]` pod struct made of
/// 8-byte fields. The macro adds compile-time layout assertions.
#[macro_export]
macro_rules! impl_atomic_value {
    ($ty:ty) => {
        // SAFETY: asserted below — size multiple of 8, align exactly 8.
        unsafe impl $crate::atomics::AtomicValue for $ty {}
        const _: () = {
            assert!(std::mem::size_of::<$ty>() % 8 == 0);
            assert!(std::mem::size_of::<$ty>() > 0);
            assert!(std::mem::align_of::<$ty>() == 8);
        };
    };
}

/// The common interface of all big-atomic implementations — deliberately
/// `std::atomic`-shaped (the paper's implementations share the
/// `std::atomic` interface, §1). See the [module docs](self) for the
/// witness contract and the AA rule.
pub trait BigAtomic<T: AtomicValue>: Send + Sync {
    /// Construct holding `init`.
    fn new(init: T) -> Self
    where
        Self: Sized;

    /// Linearizable read of the whole k-word value.
    fn load(&self) -> T;

    /// Linearizable write. On [`CachedWaitFree`] this is a CAS loop
    /// (lock-free, not wait-free — Table 1's load+cas row).
    fn store(&self, val: T);

    /// Linearizable compare-and-swap with a witness: iff the current
    /// value equals `expected`, replace it with `desired` and return
    /// `Ok(expected)`; otherwise return `Err(w)` where `w` is the
    /// current value read during the call (see the module docs for the
    /// exactness caveat on the wait-free backends). The witness is what
    /// retry loops continue from — no separate re-load.
    #[must_use = "the Err witness is the re-load a retry loop would otherwise pay for; use \
                  `.is_ok()` if only success matters"]
    fn compare_exchange(&self, expected: T, desired: T) -> Result<T, T>;

    /// Atomically replace the value with `new`, returning the previous
    /// value. Storing a value equal to the current one returns it
    /// unchanged (the AA rule). The default is a witness-fed CAS loop;
    /// backends with a cheap native exchange override it.
    #[must_use = "swap returns the previous value; use `store` to discard it"]
    fn swap(&self, new: T) -> T {
        let mut cur = self.load();
        let mut bo = None;
        loop {
            if cur == new {
                return cur;
            }
            match self.compare_exchange(cur, new) {
                Ok(prev) => return prev,
                Err(w) => {
                    // Witness-fed retry: no re-load, and back off before
                    // re-touching the contended line (Dice et al.).
                    crate::counter!(CasRetry);
                    cur = w;
                    crate::util::backoff::snooze_lazy(&mut bo);
                }
            }
        }
    }

    /// Atomic try-update: feed the current value to `f`; if `f` returns
    /// `Some(next)`, CAS it in, retrying from the witness on failure.
    /// Returns `Ok(prev)` with the value `f` mapped to the installed
    /// result, or `Err(cur)` once `f` returns `None`.
    ///
    /// This is the atomic-try-update idiom (and the building block of
    /// LL/SC-from-CAS constructions — see `apps::llsc`); `f` may run
    /// several times and must be side-effect free.
    #[must_use = "fetch_update reports whether the update was applied and the value it acted on"]
    fn fetch_update<F>(&self, mut f: F) -> Result<T, T>
    where
        Self: Sized,
        F: FnMut(T) -> Option<T>,
    {
        let mut prev = self.load();
        let mut bo = None;
        loop {
            match f(prev) {
                Some(next) => match self.compare_exchange(prev, next) {
                    Ok(witnessed) => return Ok(witnessed),
                    Err(w) => {
                        // Witness-fed retry with adaptive backoff — the
                        // canonical Dice-et-al. CAS retry loop.
                        crate::counter!(CasRetry);
                        prev = w;
                        crate::util::backoff::snooze_lazy(&mut bo);
                    }
                },
                None => return Err(prev),
            }
        }
    }

    /// Boolean compare-and-swap (legacy shim).
    #[deprecated(
        since = "0.2.0",
        note = "use `compare_exchange(expected, desired)`: it returns the witnessed current \
                value on failure so retry loops skip the re-load; `.is_ok()` recovers this bool"
    )]
    fn cas(&self, expected: T, desired: T) -> bool {
        self.compare_exchange(expected, desired).is_ok()
    }

    /// Implementation name for reports.
    fn name() -> &'static str
    where
        Self: Sized;

    /// Heap bytes attributable to this atomic beyond its inline struct
    /// (§5.5 memory census). Shared/per-thread pools report 0 here and
    /// are accounted globally by `bench::memory`.
    fn indirect_bytes(&self) -> usize {
        0
    }
}

/// An array of big atomics — the §5.1 microbenchmark object (a map from
/// `0..n` to values, each slot independently atomic and cache-padded the
/// way the paper aligns elements to 64-byte boundaries).
pub struct AtomicArray<T: AtomicValue, A: BigAtomic<T>> {
    slots: Box<[crate::util::CachePadded<A>]>,
    _marker: std::marker::PhantomData<T>,
}

impl<T: AtomicValue, A: BigAtomic<T>> AtomicArray<T, A> {
    pub fn new(n: usize, init: T) -> Self {
        let slots = (0..n)
            .map(|_| crate::util::CachePadded::new(A::new(init)))
            .collect();
        Self {
            slots,
            _marker: std::marker::PhantomData,
        }
    }

    #[inline]
    pub fn len(&self) -> usize {
        self.slots.len()
    }

    #[inline]
    pub fn is_empty(&self) -> bool {
        self.slots.is_empty()
    }

    /// The slot at `i`; panics when `i >= len()` (bounds-checked like a
    /// slice — use [`try_get`](Self::try_get) for fallible access).
    #[inline]
    pub fn get(&self, i: usize) -> &A {
        &self.slots[i]
    }

    /// The slot at `i`, or `None` out of bounds.
    #[inline]
    pub fn try_get(&self, i: usize) -> Option<&A> {
        self.slots.get(i).map(|s| &**s)
    }

    /// §5.5 census: sum of per-slot indirect bytes.
    pub fn indirect_bytes(&self) -> usize {
        self.slots.iter().map(|s| s.indirect_bytes()).sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn test_words_default_and_eq() {
        let z: Words<4> = Words::default();
        assert_eq!(z, Words([0; 4]));
        assert_ne!(z, Words([0, 0, 0, 1]));
        assert_eq!(<Words<4> as AtomicValue>::WORDS, 4);
    }

    #[test]
    fn test_u64_is_atomic_value() {
        assert_eq!(<u64 as AtomicValue>::WORDS, 1);
        let a: SeqLock<u64> = SeqLock::new(7);
        assert_eq!(a.load(), 7);
        assert_eq!(a.compare_exchange(7, 9), Ok(7));
        assert_eq!(a.load(), 9);
    }

    #[test]
    fn test_impl_atomic_value_macro() {
        #[repr(C, align(8))]
        #[derive(Copy, Clone, PartialEq, Default)]
        struct Pair {
            a: u64,
            b: u64,
        }
        impl_atomic_value!(Pair);
        assert_eq!(<Pair as AtomicValue>::WORDS, 2);
    }

    #[test]
    fn test_cas_shim_matches_compare_exchange() {
        let a: SeqLock<Words<2>> = SeqLock::new(Words([1, 2]));
        #[allow(deprecated)]
        {
            assert!(!a.cas(Words([0, 0]), Words([3, 4])));
            assert!(a.cas(Words([1, 2]), Words([3, 4])));
        }
        assert_eq!(a.load(), Words([3, 4]));
    }

    #[test]
    fn test_atomic_array_try_get_in_and_out_of_bounds() {
        let arr: AtomicArray<Words<2>, SeqLock<Words<2>>> = AtomicArray::new(4, Words([1, 1]));
        assert_eq!(arr.len(), 4);
        assert!(arr.try_get(3).is_some());
        assert!(arr.try_get(4).is_none());
        assert!(arr.try_get(usize::MAX).is_none());
        assert_eq!(arr.try_get(2).unwrap().load(), Words([1, 1]));
    }

    #[test]
    #[should_panic]
    fn test_atomic_array_get_out_of_bounds_panics() {
        let arr: AtomicArray<Words<1>, SeqLock<Words<1>>> = AtomicArray::new(2, Words([0]));
        let _ = arr.get(2);
    }

    #[test]
    fn test_default_swap_and_fetch_update() {
        // Exercised through a backend that does NOT override the
        // provided combinators (CachedWaitFree), so the defaults
        // themselves are under test.
        let a: CachedWaitFree<Words<2>> = CachedWaitFree::new(Words([1, 0]));
        assert_eq!(a.swap(Words([2, 0])), Words([1, 0]));
        assert_eq!(a.swap(Words([2, 0])), Words([2, 0])); // AA: no-op
        let r = a.fetch_update(|mut v| {
            v.0[1] = v.0[0] * 10;
            Some(v)
        });
        assert_eq!(r, Ok(Words([2, 0])));
        assert_eq!(a.load(), Words([2, 20]));
        let r = a.fetch_update(|_| None);
        assert_eq!(r, Err(Words([2, 20])));
    }
}
