//! Big atomics: `load` / `store` / `cas` over k adjacent 64-bit words.
//!
//! The eight implementations the paper evaluates, all behind one
//! [`BigAtomic`] trait so the §5 harness drives them uniformly:
//!
//! * classic baselines — [`SeqLock`], [`SimpLock`], [`LockPool`],
//!   [`Indirect`], [`HtmSim`];
//! * the paper's contributions — [`CachedWaitFree`] (Algorithm 1),
//!   [`CachedMemEff`] (Algorithm 2), [`CachedWritable`] (Algorithm 3).
//!
//! Values are plain-old-data types implementing [`AtomicValue`]; the
//! provided [`Words`] carries `K` raw words and is what the benchmarks
//! instantiate (`w` sweep of Fig 2).

pub mod bytewise;
pub mod cached_memeff;
pub mod cached_waitfree;
pub mod cached_writable;
pub mod htm_sim;
pub mod indirect;
pub mod lockpool;
pub mod seqlock;
pub mod simplock;
pub mod spin;

pub use cached_memeff::{CachedMemEff, MemEffDomain};
pub use cached_waitfree::CachedWaitFree;
pub use cached_writable::CachedWritable;
pub use htm_sim::HtmSim;
pub use indirect::Indirect;
pub use lockpool::LockPool;
pub use seqlock::SeqLock;
pub use simplock::SimpLock;

/// A value storable in a big atomic.
///
/// # Safety
/// Implementors guarantee:
/// * `size_of::<Self>()` is a nonzero multiple of 8 and
///   `align_of::<Self>() == 8` (the slots are accessed word-wise);
/// * every bit pattern produced by word-wise copies of a valid value is
///   itself valid (plain old data, no padding that `PartialEq` inspects);
/// * `PartialEq` is an equivalence relation on the bit level (the
///   algorithms' AA-freedom argument compares values).
pub unsafe trait AtomicValue:
    Copy + PartialEq + Default + Send + Sync + 'static
{
    /// Size in 64-bit words (the paper's `k`).
    const WORDS: usize = std::mem::size_of::<Self>() / 8;
}

/// `K` raw 64-bit words — the benchmark value type (flag + payload in §5.1).
#[repr(C, align(8))]
#[derive(Copy, Clone, PartialEq, Eq, Debug)]
pub struct Words<const K: usize>(pub [u64; K]);

impl<const K: usize> Default for Words<K> {
    fn default() -> Self {
        Words([0; K])
    }
}

// SAFETY: repr(C) array of u64 — no padding, align 8, bitwise Eq.
unsafe impl<const K: usize> AtomicValue for Words<K> {}

/// Implement [`AtomicValue`] for a `#[repr(C)]` pod struct made of
/// 8-byte fields. The macro adds compile-time layout assertions.
#[macro_export]
macro_rules! impl_atomic_value {
    ($ty:ty) => {
        // SAFETY: asserted below — size multiple of 8, align exactly 8.
        unsafe impl $crate::atomics::AtomicValue for $ty {}
        const _: () = {
            assert!(std::mem::size_of::<$ty>() % 8 == 0);
            assert!(std::mem::size_of::<$ty>() > 0);
            assert!(std::mem::align_of::<$ty>() == 8);
        };
    };
}

/// The common interface of all big-atomic implementations — deliberately
/// `std::atomic`-shaped (the paper's implementations share the
/// `std::atomic` interface, §1).
pub trait BigAtomic<T: AtomicValue>: Send + Sync {
    /// Construct holding `init`.
    fn new(init: T) -> Self
    where
        Self: Sized;

    /// Linearizable read of the whole k-word value.
    fn load(&self) -> T;

    /// Linearizable write. On [`CachedWaitFree`] this is a CAS loop
    /// (lock-free, not wait-free — Table 1's load+cas row).
    fn store(&self, val: T);

    /// Linearizable compare-and-swap: iff the current value equals
    /// `expected`, replace with `desired` and return true.
    fn cas(&self, expected: T, desired: T) -> bool;

    /// Implementation name for reports.
    fn name() -> &'static str
    where
        Self: Sized;

    /// Heap bytes attributable to this atomic beyond its inline struct
    /// (§5.5 memory census). Shared/per-thread pools report 0 here and
    /// are accounted globally by `bench::memory`.
    fn indirect_bytes(&self) -> usize {
        0
    }
}

/// An array of big atomics — the §5.1 microbenchmark object (a map from
/// `0..n` to values, each slot independently atomic and cache-padded the
/// way the paper aligns elements to 64-byte boundaries).
pub struct AtomicArray<T: AtomicValue, A: BigAtomic<T>> {
    slots: Box<[crossbeam_utils::CachePadded<A>]>,
    _marker: std::marker::PhantomData<T>,
}

impl<T: AtomicValue, A: BigAtomic<T>> AtomicArray<T, A> {
    pub fn new(n: usize, init: T) -> Self {
        let slots = (0..n)
            .map(|_| crossbeam_utils::CachePadded::new(A::new(init)))
            .collect();
        Self {
            slots,
            _marker: std::marker::PhantomData,
        }
    }

    #[inline]
    pub fn len(&self) -> usize {
        self.slots.len()
    }

    #[inline]
    pub fn is_empty(&self) -> bool {
        self.slots.is_empty()
    }

    #[inline]
    pub fn get(&self, i: usize) -> &A {
        &self.slots[i]
    }

    /// §5.5 census: sum of per-slot indirect bytes.
    pub fn indirect_bytes(&self) -> usize {
        self.slots.iter().map(|s| s.indirect_bytes()).sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn test_words_default_and_eq() {
        let z: Words<4> = Words::default();
        assert_eq!(z, Words([0; 4]));
        assert_ne!(z, Words([0, 0, 0, 1]));
        assert_eq!(<Words<4> as AtomicValue>::WORDS, 4);
    }

    #[test]
    fn test_impl_atomic_value_macro() {
        #[repr(C, align(8))]
        #[derive(Copy, Clone, PartialEq, Default)]
        struct Pair {
            a: u64,
            b: u64,
        }
        impl_atomic_value!(Pair);
        assert_eq!(<Pair as AtomicValue>::WORDS, 2);
    }
}
