//! `Indirect<T>` — the classic lock-free big atomic (paper §2): an
//! atomic pointer to a heap-allocated immutable value.
//!
//! Loads read through the pointer (two dependent cache misses — the
//! performance problem the paper's cached algorithms exist to fix);
//! updates install a fresh node with a single-word CAS.  The reclamation
//! scheme is pluggable ([`Smr`]): hazard pointers by default (the
//! paper's choice), or `Indirect<T, Epoch>` to defer reclamation to
//! epoch advances instead of per-pointer announcements — `repro ablate
//! --panel smr` measures the difference.
//!
//! ## Ordering contract
//!
//! Nodes are immutable after publish, so one edge does all the work:
//! `RELEASE` on every installing CAS/swap (node contents happen-before
//! the pointer is observable) pairing with the `ACQUIRE` validating load
//! inside [`protect_ptr`](crate::smr::SmrGuard::protect_ptr).  The
//! scheme's own store-load
//! fences (hazard announce→revalidate, epoch pin→validate) live in
//! `smr`, not here.

use std::marker::PhantomData;
use std::sync::atomic::{AtomicPtr, Ordering};

use super::{AtomicValue, BigAtomic};
use crate::smr::{Hazard, Smr};
use crate::util::backoff::snooze_lazy;
use crate::util::ordering::{DefaultPolicy as P, OrderingPolicy};

struct Node<T> {
    value: T,
}

pub struct Indirect<T: AtomicValue, S: Smr = Hazard> {
    ptr: AtomicPtr<Node<T>>,
    _smr: PhantomData<fn() -> S>,
}

impl<T: AtomicValue, S: Smr> Drop for Indirect<T, S> {
    fn drop(&mut self) {
        let p = self.ptr.load(Ordering::Relaxed);
        if !p.is_null() {
            // SAFETY: exclusive in Drop; no concurrent readers remain.
            drop(unsafe { Box::from_raw(p) });
        }
    }
}

impl<T: AtomicValue, S: Smr> BigAtomic<T> for Indirect<T, S> {
    fn new(init: T) -> Self {
        Self {
            ptr: AtomicPtr::new(Box::into_raw(Box::new(Node { value: init }))),
            _smr: PhantomData,
        }
    }

    #[inline]
    fn load(&self) -> T {
        let g = S::pin();
        let p = g.protect_ptr(&self.ptr);
        // SAFETY: protected from reclamation by the guard.
        unsafe { (*p).value }
    }

    #[inline]
    fn store(&self, val: T) {
        // Not `swap`: the previous value is unwanted, and reading it
        // would add a dependent dereference of the cold old node.
        let new = Box::into_raw(Box::new(Node { value: val }));
        // Ordering: ACQREL — RELEASE publishes the new node's contents
        // before its address; ACQUIRE pairs with the previous
        // installer's RELEASE even though the old *value* is not read:
        // retiring leads to deallocation, and freeing (then reusing)
        // the old node's memory must happen-after its initializing
        // writes.
        let old = self.ptr.swap(new, P::ACQREL);
        crate::counter!(SlowPathInstall);
        // SAFETY: old is unlinked and was uniquely owned by this atomic.
        unsafe { S::retire_box(old) };
    }

    fn compare_exchange(&self, expected: T, desired: T) -> Result<T, T> {
        let g = S::pin();
        let mut p = g.protect_ptr(&self.ptr);
        // Lazy: the uncontended install pays no backoff/TLS cost.
        let mut bo = None;
        loop {
            // SAFETY: protected.
            let cur = unsafe { (*p).value };
            if cur != expected {
                return Err(cur); // exact witness: atomically read just now
            }
            if expected == desired {
                // Never replace a value with an equal one (AA-freedom;
                // also avoids disturbing concurrent CASes, §3.1).
                return Ok(cur);
            }
            let new = Box::into_raw(Box::new(Node { value: desired }));
            // Fault window: fresh node built, install CAS next — a kill
            // here leaks only the unpublished node.
            crate::failpoint!(IndirectInstall);
            // The guard's protection of p prevents its address being
            // recycled (hazard: announced; epoch: retired-under-pin
            // garbage is never freed while we stay pinned), so this CAS
            // succeeding means the logical value is still `expected`
            // (no ABA).
            // Ordering: RELEASE on success — publish the new node before
            // its address (no Acquire half: p's contents were already
            // acquired by the protecting load). RELAXED on failure
            // — the retry goes back through protect_ptr, whose ACQUIRE
            // load re-synchronizes.
            match self.ptr.compare_exchange(p, new, P::RELEASE, P::RELAXED) {
                Ok(_) => {
                    crate::counter!(SlowPathInstall);
                    // SAFETY: p is now unlinked.
                    unsafe { S::retire_box(p) };
                    return Ok(cur);
                }
                Err(_) => {
                    crate::counter!(CasRetry);
                    // SAFETY: new was never published.
                    drop(unsafe { Box::from_raw(new) });
                    // A competing update owns the line; back off before
                    // re-protecting (Dice et al. contention management).
                    snooze_lazy(&mut bo);
                    // Re-protect the new current node and re-compare:
                    // either the witness now differs (Err) or a value-
                    // level ABA restored `expected` and we retry the
                    // install. Lock-free: every iteration implies a
                    // competing update succeeded.
                    p = g.protect_ptr(&self.ptr);
                }
            }
        }
    }

    /// Native exchange: one pointer swap, previous value read from the
    /// node this thread just unlinked (safe: only the unlinker retires).
    fn swap(&self, val: T) -> T {
        let new = Box::into_raw(Box::new(Node { value: val }));
        // Ordering: ACQREL — RELEASE publishes the new node's contents;
        // ACQUIRE pairs with the previous installer's RELEASE so the old
        // node's value read below is sound.
        let old = self.ptr.swap(new, P::ACQREL);
        crate::counter!(SlowPathInstall);
        // SAFETY: old is unlinked by us and not yet retired; nodes are
        // immutable after publish.
        let prev = unsafe { (*old).value };
        // SAFETY: old is unlinked and was uniquely owned by this atomic.
        unsafe { S::retire_box(old) };
        prev
    }

    fn name() -> &'static str {
        "Indirect"
    }

    fn indirect_bytes(&self) -> usize {
        std::mem::size_of::<Node<T>>()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::atomics::Words;
    use crate::smr::Epoch;
    use std::sync::Arc;

    #[test]
    fn test_roundtrip_and_compare_exchange() {
        let a: Indirect<Words<3>> = Indirect::new(Words([1, 2, 3]));
        assert_eq!(a.load(), Words([1, 2, 3]));
        a.store(Words([4, 5, 6]));
        // Failed CAS witnesses the exact current value.
        assert_eq!(
            a.compare_exchange(Words([1, 2, 3]), Words([0, 0, 0])),
            Err(Words([4, 5, 6]))
        );
        assert_eq!(
            a.compare_exchange(Words([4, 5, 6]), Words([7, 8, 9])),
            Ok(Words([4, 5, 6]))
        );
        assert_eq!(a.load(), Words([7, 8, 9]));
        assert_eq!(a.swap(Words([1, 1, 1])), Words([7, 8, 9]));
    }

    #[test]
    fn test_roundtrip_under_epoch_smr() {
        // The same algorithm over the region scheme: identical semantics.
        let a: Indirect<Words<3>, Epoch> = Indirect::new(Words([1, 2, 3]));
        assert_eq!(a.load(), Words([1, 2, 3]));
        a.store(Words([4, 5, 6]));
        assert_eq!(
            a.compare_exchange(Words([4, 5, 6]), Words([7, 8, 9])),
            Ok(Words([4, 5, 6]))
        );
        assert_eq!(a.swap(Words([1, 1, 1])), Words([7, 8, 9]));
        Epoch::<crate::util::ordering::DefaultPolicy>::try_advance_and_collect();
    }

    #[test]
    fn test_cas_equal_value_is_noop_ok() {
        let a: Indirect<Words<1>> = Indirect::new(Words([5]));
        assert_eq!(a.compare_exchange(Words([5]), Words([5])), Ok(Words([5])));
        assert_eq!(a.load(), Words([5]));
    }

    #[test]
    fn test_concurrent_witness_fed_cas_total() {
        // The retry loop consumes the Err witness instead of re-loading;
        // the counter still must be exact — under both SMR schemes.
        fn run<S: Smr>() {
            let a: Arc<Indirect<Words<4>, S>> = Arc::new(Indirect::new(Words([0; 4])));
            let threads = 4;
            let per = 3_000u64;
            let handles: Vec<_> = (0..threads)
                .map(|t| {
                    let a = Arc::clone(&a);
                    std::thread::spawn(move || {
                        let mut wins = 0u64;
                        let mut cur = a.load();
                        while wins < per {
                            let mut next = cur;
                            next.0[0] += 1;
                            next.0[1 + (t % 3)] ^= wins + 1;
                            match a.compare_exchange(cur, next) {
                                Ok(_) => {
                                    wins += 1;
                                    cur = next;
                                }
                                Err(w) => cur = w,
                            }
                        }
                    })
                })
                .collect();
            for h in handles {
                h.join().unwrap();
            }
            assert_eq!(a.load().0[0], threads as u64 * per, "{}", S::NAME);
        }
        run::<Hazard>();
        run::<Epoch>();
    }

    #[test]
    fn test_no_torn_reads() {
        let a: Arc<Indirect<Words<4>>> = Arc::new(Indirect::new(Words([0; 4])));
        let stop = Arc::new(std::sync::atomic::AtomicBool::new(false));
        let readers: Vec<_> = (0..2)
            .map(|_| {
                let a = Arc::clone(&a);
                let stop = Arc::clone(&stop);
                std::thread::spawn(move || {
                    while !stop.load(Ordering::Relaxed) {
                        let v = a.load();
                        assert!(v.0.iter().all(|&w| w == v.0[0]));
                    }
                })
            })
            .collect();
        for i in 1..10_000u64 {
            a.store(Words([i; 4]));
        }
        stop.store(true, Ordering::SeqCst);
        for r in readers {
            r.join().unwrap();
        }
    }
}
