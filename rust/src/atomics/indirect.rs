//! `Indirect<T>` — the classic lock-free big atomic (paper §2): an
//! atomic pointer to a heap-allocated immutable value.
//!
//! Loads read through the pointer (two dependent cache misses — the
//! performance problem the paper's cached algorithms exist to fix);
//! updates install a fresh node with a single-word CAS.  Hazard pointers
//! protect readers from reclamation races.
//!
//! ## Ordering contract
//!
//! Nodes are immutable after publish, so one edge does all the work:
//! `RELEASE` on every installing CAS/swap (node contents happen-before
//! the pointer is observable) pairing with the `ACQUIRE` validating load
//! inside [`HazardPointer::protect`].  The announce→revalidate
//! store-load fence lives in `smr::hazard`, not here.

use std::sync::atomic::{AtomicPtr, Ordering};

use super::{AtomicValue, BigAtomic};
use crate::smr::hazard::{retire_box, HazardPointer};
use crate::util::backoff::snooze_lazy;
use crate::util::ordering::{DefaultPolicy as P, OrderingPolicy};

struct Node<T> {
    value: T,
}

pub struct Indirect<T: AtomicValue> {
    ptr: AtomicPtr<Node<T>>,
}

impl<T: AtomicValue> Drop for Indirect<T> {
    fn drop(&mut self) {
        let p = self.ptr.load(Ordering::Relaxed);
        if !p.is_null() {
            // SAFETY: exclusive in Drop; no concurrent readers remain.
            drop(unsafe { Box::from_raw(p) });
        }
    }
}

impl<T: AtomicValue> BigAtomic<T> for Indirect<T> {
    fn new(init: T) -> Self {
        Self {
            ptr: AtomicPtr::new(Box::into_raw(Box::new(Node { value: init }))),
        }
    }

    #[inline]
    fn load(&self) -> T {
        let h = HazardPointer::new();
        let p = h.protect(&self.ptr);
        // SAFETY: protected from reclamation by the hazard pointer.
        unsafe { (*p).value }
    }

    #[inline]
    fn store(&self, val: T) {
        // Not `swap`: the previous value is unwanted, and reading it
        // would add a dependent dereference of the cold old node.
        let new = Box::into_raw(Box::new(Node { value: val }));
        // Ordering: ACQREL — RELEASE publishes the new node's contents
        // before its address; ACQUIRE pairs with the previous
        // installer's RELEASE even though the old *value* is not read:
        // retiring leads to deallocation, and freeing (then reusing)
        // the old node's memory must happen-after its initializing
        // writes.
        let old = self.ptr.swap(new, P::ACQREL);
        // SAFETY: old is unlinked and was uniquely owned by this atomic.
        unsafe { retire_box(old) };
    }

    fn compare_exchange(&self, expected: T, desired: T) -> Result<T, T> {
        let h = HazardPointer::new();
        let mut p = h.protect(&self.ptr);
        // Lazy: the uncontended install pays no backoff/TLS cost.
        let mut bo = None;
        loop {
            // SAFETY: protected.
            let cur = unsafe { (*p).value };
            if cur != expected {
                return Err(cur); // exact witness: atomically read just now
            }
            if expected == desired {
                // Never replace a value with an equal one (AA-freedom;
                // also avoids disturbing concurrent CASes, §3.1).
                return Ok(cur);
            }
            let new = Box::into_raw(Box::new(Node { value: desired }));
            // The hazard on p prevents its address being recycled, so
            // this CAS succeeding means the logical value is still
            // `expected` (no ABA).
            // Ordering: RELEASE on success — publish the new node before
            // its address (no Acquire half: p's contents were already
            // acquired by protect's validating load). RELAXED on failure
            // — the retry goes back through protect, whose ACQUIRE load
            // re-synchronizes.
            match self.ptr.compare_exchange(p, new, P::RELEASE, P::RELAXED) {
                Ok(_) => {
                    // SAFETY: p is now unlinked.
                    unsafe { retire_box(p) };
                    return Ok(cur);
                }
                Err(_) => {
                    // SAFETY: new was never published.
                    drop(unsafe { Box::from_raw(new) });
                    // A competing update owns the line; back off before
                    // re-protecting (Dice et al. contention management).
                    snooze_lazy(&mut bo);
                    // Re-protect the new current node and re-compare:
                    // either the witness now differs (Err) or a value-
                    // level ABA restored `expected` and we retry the
                    // install. Lock-free: every iteration implies a
                    // competing update succeeded.
                    p = h.protect(&self.ptr);
                }
            }
        }
    }

    /// Native exchange: one pointer swap, previous value read from the
    /// node this thread just unlinked (safe: only the unlinker retires).
    fn swap(&self, val: T) -> T {
        let new = Box::into_raw(Box::new(Node { value: val }));
        // Ordering: ACQREL — RELEASE publishes the new node's contents;
        // ACQUIRE pairs with the previous installer's RELEASE so the old
        // node's value read below is sound.
        let old = self.ptr.swap(new, P::ACQREL);
        // SAFETY: old is unlinked by us and not yet retired; nodes are
        // immutable after publish.
        let prev = unsafe { (*old).value };
        // SAFETY: old is unlinked and was uniquely owned by this atomic.
        unsafe { retire_box(old) };
        prev
    }

    fn name() -> &'static str {
        "Indirect"
    }

    fn indirect_bytes(&self) -> usize {
        std::mem::size_of::<Node<T>>()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::atomics::Words;
    use std::sync::Arc;

    #[test]
    fn test_roundtrip_and_compare_exchange() {
        let a: Indirect<Words<3>> = Indirect::new(Words([1, 2, 3]));
        assert_eq!(a.load(), Words([1, 2, 3]));
        a.store(Words([4, 5, 6]));
        // Failed CAS witnesses the exact current value.
        assert_eq!(
            a.compare_exchange(Words([1, 2, 3]), Words([0, 0, 0])),
            Err(Words([4, 5, 6]))
        );
        assert_eq!(
            a.compare_exchange(Words([4, 5, 6]), Words([7, 8, 9])),
            Ok(Words([4, 5, 6]))
        );
        assert_eq!(a.load(), Words([7, 8, 9]));
        assert_eq!(a.swap(Words([1, 1, 1])), Words([7, 8, 9]));
    }

    #[test]
    fn test_cas_equal_value_is_noop_ok() {
        let a: Indirect<Words<1>> = Indirect::new(Words([5]));
        assert_eq!(a.compare_exchange(Words([5]), Words([5])), Ok(Words([5])));
        assert_eq!(a.load(), Words([5]));
    }

    #[test]
    fn test_concurrent_witness_fed_cas_total() {
        // The retry loop consumes the Err witness instead of re-loading;
        // the counter still must be exact.
        let a: Arc<Indirect<Words<4>>> = Arc::new(Indirect::new(Words([0; 4])));
        let threads = 4;
        let per = 3_000u64;
        let handles: Vec<_> = (0..threads)
            .map(|t| {
                let a = Arc::clone(&a);
                std::thread::spawn(move || {
                    let mut wins = 0u64;
                    let mut cur = a.load();
                    while wins < per {
                        let mut next = cur;
                        next.0[0] += 1;
                        next.0[1 + (t % 3)] ^= wins + 1;
                        match a.compare_exchange(cur, next) {
                            Ok(_) => {
                                wins += 1;
                                cur = next;
                            }
                            Err(w) => cur = w,
                        }
                    }
                })
            })
            .collect();
        for h in handles {
            h.join().unwrap();
        }
        assert_eq!(a.load().0[0], threads as u64 * per);
    }

    #[test]
    fn test_no_torn_reads() {
        let a: Arc<Indirect<Words<4>>> = Arc::new(Indirect::new(Words([0; 4])));
        let stop = Arc::new(std::sync::atomic::AtomicBool::new(false));
        let readers: Vec<_> = (0..2)
            .map(|_| {
                let a = Arc::clone(&a);
                let stop = Arc::clone(&stop);
                std::thread::spawn(move || {
                    while !stop.load(Ordering::Relaxed) {
                        let v = a.load();
                        assert!(v.0.iter().all(|&w| w == v.0[0]));
                    }
                })
            })
            .collect();
        for i in 1..10_000u64 {
            a.store(Words([i; 4]));
        }
        stop.store(true, Ordering::SeqCst);
        for r in readers {
            r.join().unwrap();
        }
    }
}
