//! Lock-free log-linear histogram (HdrHistogram-shaped, dependency-free).
//!
//! Values are bucketed into power-of-two **major** buckets, each split
//! into [`SUB_BUCKETS`] = 16 linear **sub**-buckets, so the relative
//! quantization error is bounded by one sub-bucket: `width / lower <=
//! 1/16` for every value ≥ 16 (values below 16 get exact unit buckets).
//! That is the same shape HdrHistogram uses with a significant-figures
//! setting of ~1.2 decimal digits — plenty for p50/p90/p99/p999 latency
//! reporting, and small enough (976 buckets, ~7.6 KiB) to sit in a
//! `static`.
//!
//! Recording is one `Relaxed` `fetch_add` on the bucket plus the
//! count/sum/min/max bookkeeping — wait-free, no locks, safe from any
//! thread including the kv_service hot loop. Reading happens through
//! [`Histogram::snapshot`], which takes an unsynchronized (racy but
//! monotone) copy; per-run numbers are computed as snapshot *deltas*
//! (see [`HistogramSnapshot::delta_since`]), so concurrent recording
//! during a snapshot can only shift a sample between adjacent reports,
//! never lose it.

use std::sync::atomic::{AtomicU64, Ordering};

/// log2 of the linear sub-buckets per power-of-two major bucket.
pub const SUB_BITS: u32 = 4;
/// Linear sub-buckets per major bucket (16).
pub const SUB_BUCKETS: usize = 1 << SUB_BITS;
/// Total buckets: 16 exact unit buckets for `0..16`, then 60 major
/// buckets (`msb` 4..=63) × 16 sub-buckets.
pub const N_BUCKETS: usize = SUB_BUCKETS + (64 - SUB_BITS as usize) * SUB_BUCKETS;

/// Bucket index for `v` — monotone in `v`, contiguous across the
/// unit/log boundary (15 → 15, 16 → 16, 31 → 31, 32 → 32).
#[inline]
pub fn bucket_index(v: u64) -> usize {
    if v < SUB_BUCKETS as u64 {
        return v as usize;
    }
    let msb = 63 - v.leading_zeros(); // >= SUB_BITS
    let sub = ((v >> (msb - SUB_BITS)) & (SUB_BUCKETS as u64 - 1)) as usize;
    let major = (msb - SUB_BITS + 1) as usize;
    major * SUB_BUCKETS + sub
}

/// Smallest value mapping to bucket `i` (inverse of [`bucket_index`]).
#[inline]
pub fn bucket_lower(i: usize) -> u64 {
    debug_assert!(i < N_BUCKETS);
    let major = i / SUB_BUCKETS;
    let sub = (i % SUB_BUCKETS) as u64;
    if major == 0 {
        return sub;
    }
    (SUB_BUCKETS as u64 + sub) << (major - 1)
}

/// A concurrent log-linear histogram over `AtomicU64` buckets.
///
/// `const`-constructible, so it can live in a `static` (the obs layer's
/// named global histograms) or on the heap for per-run instances.
pub struct Histogram {
    buckets: [AtomicU64; N_BUCKETS],
    count: AtomicU64,
    sum: AtomicU64,
    min: AtomicU64,
    max: AtomicU64,
}

impl Histogram {
    pub const fn new() -> Self {
        #[allow(clippy::declare_interior_mutable_const)]
        const Z: AtomicU64 = AtomicU64::new(0);
        Self {
            buckets: [Z; N_BUCKETS],
            count: AtomicU64::new(0),
            sum: AtomicU64::new(0),
            min: AtomicU64::new(u64::MAX),
            max: AtomicU64::new(0),
        }
    }

    /// Record one sample. Wait-free; a handful of `Relaxed` RMWs.
    #[inline]
    pub fn record(&self, v: u64) {
        // Ordering: RELAXED throughout — counters are commutative and
        // read only through racy snapshots whose consumers tolerate a
        // sample landing in either of two adjacent reports.
        self.buckets[bucket_index(v)].fetch_add(1, Ordering::Relaxed);
        self.count.fetch_add(1, Ordering::Relaxed);
        self.sum.fetch_add(v, Ordering::Relaxed);
        self.min.fetch_min(v, Ordering::Relaxed);
        self.max.fetch_max(v, Ordering::Relaxed);
    }

    /// Racy-but-monotone copy of the current state.
    pub fn snapshot(&self) -> HistogramSnapshot {
        let counts: Vec<u64> = self
            .buckets
            .iter()
            .map(|b| b.load(Ordering::Relaxed))
            .collect();
        HistogramSnapshot {
            counts,
            count: self.count.load(Ordering::Relaxed),
            sum: self.sum.load(Ordering::Relaxed),
            min: self.min.load(Ordering::Relaxed),
            max: self.max.load(Ordering::Relaxed),
        }
    }
}

impl Default for Histogram {
    fn default() -> Self {
        Self::new()
    }
}

/// A plain (non-atomic) copy of a [`Histogram`], supporting merges,
/// deltas, and quantile extraction.
#[derive(Clone, Debug)]
pub struct HistogramSnapshot {
    counts: Vec<u64>,
    pub count: u64,
    pub sum: u64,
    pub min: u64,
    pub max: u64,
}

impl HistogramSnapshot {
    pub fn empty() -> Self {
        Self {
            counts: vec![0; N_BUCKETS],
            count: 0,
            sum: 0,
            min: u64::MAX,
            max: 0,
        }
    }

    pub fn is_empty(&self) -> bool {
        self.count == 0
    }

    /// Fold `other` into `self` (exact: bucket-wise sum).
    pub fn merge(&mut self, other: &HistogramSnapshot) {
        for (a, b) in self.counts.iter_mut().zip(&other.counts) {
            *a += b;
        }
        self.count += other.count;
        self.sum += other.sum;
        self.min = self.min.min(other.min);
        self.max = self.max.max(other.max);
    }

    /// The samples recorded between `earlier` and `self` (both taken
    /// from the same growing [`Histogram`]). min/max cannot be
    /// differenced, so the delta keeps `self`'s cumulative min/max —
    /// correct whenever the earlier snapshot was empty, conservative
    /// otherwise.
    pub fn delta_since(&self, earlier: &HistogramSnapshot) -> HistogramSnapshot {
        let counts = self
            .counts
            .iter()
            .zip(&earlier.counts)
            .map(|(a, b)| a.saturating_sub(*b))
            .collect();
        HistogramSnapshot {
            counts,
            count: self.count.saturating_sub(earlier.count),
            sum: self.sum.saturating_sub(earlier.sum),
            min: self.min,
            max: self.max,
        }
    }

    pub fn mean(&self) -> f64 {
        if self.count == 0 {
            0.0
        } else {
            self.sum as f64 / self.count as f64
        }
    }

    /// The `q`-quantile (`0.0..=1.0`) as the lower bound of the bucket
    /// holding the sample of that rank — within one sub-bucket
    /// (≤ 1/16 relative error) of the true order statistic. Returns 0 on
    /// an empty snapshot.
    pub fn quantile(&self, q: f64) -> u64 {
        if self.count == 0 {
            return 0;
        }
        let rank = ((q * self.count as f64).ceil() as u64).clamp(1, self.count);
        let mut cum = 0u64;
        for (i, &c) in self.counts.iter().enumerate() {
            cum += c;
            if cum >= rank {
                // Clamp into the recorded range: the top bucket's lower
                // bound can undershoot min when all samples share one
                // bucket.
                return bucket_lower(i).max(self.min.min(self.max));
            }
        }
        self.max
    }

    pub fn p50(&self) -> u64 {
        self.quantile(0.50)
    }

    pub fn p90(&self) -> u64 {
        self.quantile(0.90)
    }

    pub fn p99(&self) -> u64 {
        self.quantile(0.99)
    }

    pub fn p999(&self) -> u64 {
        self.quantile(0.999)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn test_bucket_index_monotone_and_contiguous() {
        // Unit buckets below 16, then contiguous across every power of
        // two boundary.
        let mut prev = bucket_index(0);
        assert_eq!(prev, 0);
        for v in 1..100_000u64 {
            let i = bucket_index(v);
            assert!(i == prev || i == prev + 1, "gap at {v}: {prev} -> {i}");
            prev = i;
        }
        assert_eq!(bucket_index(15), 15);
        assert_eq!(bucket_index(16), 16);
        assert_eq!(bucket_index(31), 31);
        assert_eq!(bucket_index(32), 32);
        assert!(bucket_index(u64::MAX) < N_BUCKETS);
    }

    #[test]
    fn test_bucket_lower_inverts_index() {
        for i in 0..N_BUCKETS {
            let lo = bucket_lower(i);
            assert_eq!(bucket_index(lo), i, "lower({i}) = {lo} maps back wrong");
            if lo > 0 {
                assert!(bucket_index(lo - 1) == i - 1, "lower({i}) not minimal");
            }
        }
    }

    #[test]
    fn test_relative_error_bounded() {
        // Every value's bucket lower bound is within 1/16 of the value.
        for v in [17u64, 100, 1_000, 123_456, 1 << 40, u64::MAX / 3] {
            let lo = bucket_lower(bucket_index(v));
            assert!(lo <= v);
            let width = v - lo;
            assert!(
                (width as f64) <= (v as f64) / 16.0 + 1.0,
                "v={v} lo={lo} width={width}"
            );
        }
    }

    #[test]
    fn test_record_and_exact_small_quantiles() {
        let h = Histogram::new();
        for v in 1..=100u64 {
            // 1..=15 are exact unit buckets; larger values quantized.
            h.record(v);
        }
        let s = h.snapshot();
        assert_eq!(s.count, 100);
        assert_eq!(s.min, 1);
        assert_eq!(s.max, 100);
        assert_eq!(s.sum, 5050);
        // p50 of 1..=100 is 50; bucket for 50 is [48, 51].
        let p50 = s.p50();
        assert!((48..=50).contains(&p50), "p50={p50}");
        let p99 = s.p99();
        assert!((96..=99).contains(&p99), "p99={p99}");
    }

    #[test]
    fn test_merge_and_delta() {
        let h = Histogram::new();
        h.record(10);
        h.record(20);
        let early = h.snapshot();
        h.record(30);
        h.record(40);
        let late = h.snapshot();
        let delta = late.delta_since(&early);
        assert_eq!(delta.count, 2);
        assert_eq!(delta.sum, 70);
        let mut merged = early.clone();
        merged.merge(&delta);
        assert_eq!(merged.count, late.count);
        assert_eq!(merged.sum, late.sum);
    }

    #[test]
    fn test_empty_snapshot_quantiles_zero() {
        let s = HistogramSnapshot::empty();
        assert!(s.is_empty());
        assert_eq!(s.p50(), 0);
        assert_eq!(s.p999(), 0);
        assert_eq!(s.mean(), 0.0);
    }

    #[test]
    fn test_concurrent_record_counts_exact() {
        use std::sync::Arc;
        let h = Arc::new(Histogram::new());
        let threads = 8;
        let per = 10_000u64;
        let handles: Vec<_> = (0..threads)
            .map(|t| {
                let h = Arc::clone(&h);
                std::thread::spawn(move || {
                    for i in 0..per {
                        h.record(t * 1_000 + (i % 97));
                    }
                })
            })
            .collect();
        for hd in handles {
            hd.join().unwrap();
        }
        let s = h.snapshot();
        assert_eq!(s.count, threads * per);
        assert_eq!(s.counts.iter().sum::<u64>(), threads * per);
    }
}
