//! Point-in-time telemetry snapshots + the machine-readable JSON dump.
//!
//! An [`ObsSnapshot`] captures every event counter ([`telemetry`]) and
//! every named global histogram in one racy-but-monotone pass. Per-run
//! numbers are always **deltas** between a snapshot taken before the
//! run and one taken after ([`ObsSnapshot::delta_since`]) — the
//! underlying cells are cumulative for the process (thread ids are
//! reused, counters never reset).
//!
//! [`ObsSnapshot::to_json`] hand-rolls the JSON (the crate is
//! dependency-free — no serde): all keys are static identifiers and all
//! values are numbers, so no escaping is needed. This is the payload
//! `repro stats` prints and `--telemetry` runs dump next to their
//! exhibits (`*.obs.json`).

use super::histogram::HistogramSnapshot;
use super::telemetry::{self, NUM_EVENTS};

/// A point-in-time copy of all counters + named histograms.
#[derive(Clone, Debug)]
pub struct ObsSnapshot {
    /// Cell order matches [`telemetry::ALL`].
    pub counters: [u64; NUM_EVENTS],
    /// Named global histograms (currently the kv_service set).
    pub hists: Vec<(&'static str, HistogramSnapshot)>,
}

impl ObsSnapshot {
    /// Capture the current process-cumulative state.
    pub fn capture() -> Self {
        Self {
            counters: telemetry::totals(),
            hists: super::global_histograms()
                .iter()
                .map(|(name, h)| (*name, h.snapshot()))
                .collect(),
        }
    }

    /// Everything recorded between `earlier` and `self`.
    pub fn delta_since(&self, earlier: &ObsSnapshot) -> ObsSnapshot {
        let mut counters = [0u64; NUM_EVENTS];
        for (i, c) in counters.iter_mut().enumerate() {
            *c = self.counters[i].saturating_sub(earlier.counters[i]);
        }
        let hists = self
            .hists
            .iter()
            .map(|(name, h)| {
                let base = earlier
                    .hists
                    .iter()
                    .find(|(n, _)| n == name)
                    .map(|(_, b)| b.clone())
                    .unwrap_or_else(HistogramSnapshot::empty);
                (*name, h.delta_since(&base))
            })
            .collect();
        ObsSnapshot { counters, hists }
    }

    /// The counter for `e`.
    pub fn counter(&self, e: telemetry::Event) -> u64 {
        self.counters[e as usize]
    }

    /// The named histogram, if captured.
    pub fn histogram(&self, name: &str) -> Option<&HistogramSnapshot> {
        self.hists.iter().find(|(n, _)| *n == name).map(|(_, h)| h)
    }

    /// True when nothing at all was recorded.
    pub fn is_empty(&self) -> bool {
        self.counters.iter().all(|&c| c == 0) && self.hists.iter().all(|(_, h)| h.is_empty())
    }

    /// Pretty-printed JSON:
    /// `{"counters": {...}, "histograms": {name: {count, sum, min, max,
    /// mean, p50, p90, p99, p999}}}`.
    pub fn to_json(&self) -> String {
        let mut s = String::with_capacity(2048);
        s.push_str("{\n  \"counters\": {\n");
        for (i, e) in telemetry::ALL.iter().enumerate() {
            s.push_str(&format!("    \"{}\": {}", e.name(), self.counters[i]));
            s.push_str(if i + 1 < NUM_EVENTS { ",\n" } else { "\n" });
        }
        s.push_str("  },\n  \"histograms\": {\n");
        for (i, (name, h)) in self.hists.iter().enumerate() {
            let min = if h.count == 0 { 0 } else { h.min };
            s.push_str(&format!(
                "    \"{}\": {{\"count\": {}, \"sum\": {}, \"min\": {}, \"max\": {}, \
                 \"mean\": {:.1}, \"p50\": {}, \"p90\": {}, \"p99\": {}, \"p999\": {}}}",
                name,
                h.count,
                h.sum,
                min,
                h.max,
                h.mean(),
                h.p50(),
                h.p90(),
                h.p99(),
                h.p999(),
            ));
            s.push_str(if i + 1 < self.hists.len() { ",\n" } else { "\n" });
        }
        s.push_str("  }\n}\n");
        s
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::obs::telemetry::Event;

    #[test]
    fn test_capture_delta_and_lookup() {
        let before = ObsSnapshot::capture();
        telemetry::incr_by(Event::ResizeFinish, 7);
        crate::obs::KV_LATENCY_NS.record(1000);
        let after = ObsSnapshot::capture();
        let d = after.delta_since(&before);
        // Other tests may run concurrently; deltas are lower bounds.
        assert!(d.counter(Event::ResizeFinish) >= 7);
        assert!(d.histogram("kv_latency_ns").unwrap().count >= 1);
        assert!(d.histogram("no_such_histogram").is_none());
        assert!(!d.is_empty());
    }

    #[test]
    fn test_json_shape() {
        let snap = ObsSnapshot::capture();
        let j = snap.to_json();
        // Structurally valid for the CI smoke: balanced braces, both
        // top-level keys, one entry per event.
        assert_eq!(
            j.matches('{').count(),
            j.matches('}').count(),
            "unbalanced braces"
        );
        assert!(j.contains("\"counters\""));
        assert!(j.contains("\"histograms\""));
        for e in telemetry::ALL.iter() {
            assert!(j.contains(&format!("\"{}\":", e.name())), "{} missing", e.name());
        }
        assert!(j.contains("\"kv_latency_ns\""));
        assert!(!j.contains("NaN") && !j.contains("inf"));
    }

    #[test]
    fn test_empty_delta_is_empty() {
        let a = ObsSnapshot::capture();
        let d = a.delta_since(&a);
        assert!(d.counters.iter().all(|&c| c == 0));
        assert!(d.hists.iter().all(|(_, h)| h.count == 0));
    }
}
