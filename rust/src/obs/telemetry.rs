//! Named event counters, sharded per thread (Folly thread-cached style).
//!
//! One cache-padded row of `AtomicU64` cells per registered thread,
//! indexed by [`crate::util::registry::tid`]. The hot-path increment is
//! an owner-only `Relaxed` load + `Relaxed` store (no RMW, no contended
//! line — each thread writes only its own row), and snapshots sum the
//! rows bounded by [`crate::util::registry::high_water`].
//!
//! The cells are **cumulative for the process**: thread ids are leased
//! and reused, and a reused id inherits the previous tenant's counts.
//! That is fine — totals only ever grow, and every consumer reports
//! *deltas* between two [`crate::obs::ObsSnapshot`]s.
//!
//! Instrumentation goes through the [`counter!`](crate::counter) macro,
//! which expands to [`incr`] only under the `telemetry` cargo feature —
//! default builds carry zero extra instructions on the hot paths (the
//! PR 3 ordering-diet numbers are unperturbed). This module itself
//! always compiles, so snapshot plumbing and the `repro stats` output
//! are feature-independent (counters simply stay zero without the
//! feature).

use std::sync::atomic::{AtomicU64, Ordering};

use crate::util::registry;
use crate::util::CachePadded;
use crate::MAX_THREADS;

/// Every event the crate instruments. Grouped by subsystem; the
/// discriminant is the cell index, so variants must stay dense from 0.
#[derive(Copy, Clone, Debug, PartialEq, Eq)]
#[repr(usize)]
pub enum Event {
    // -- atomics/ backends ------------------------------------------------
    /// Cached read served inline (no SMR, no indirection) — Alg 1/2 load
    /// fast path, seqlock first-try read.
    FastPathHit = 0,
    /// Inline read failed validation; retried or took the slow path.
    FastPathMiss,
    /// Successful install of a new node/backup (the update slow path's
    /// linearization CAS) — Alg 1/2/3 installs, `Indirect` CAS wins.
    SlowPathInstall,
    /// Witness-fed CAS retry (any backend, incl. the default
    /// `swap`/`fetch_update` combinators and lock-CAS contention).
    CasRetry,
    /// Helped re-cache another writer's value (Alg 1 cache validate,
    /// Alg 2 "re-caching until success" help loop) — a proxy for
    /// help-chain length: N helps in one call bump this N times.
    HelpRecache,
    /// Alg 3 `help_write`: transferred a buffered write to the backup.
    HelpWrite,
    /// Lock taken (SimpLock / LockPool / seqlock writer / HTM fallback).
    LockAcquire,
    /// Simulated HTM transaction aborted and retried.
    TxRetry,
    /// Simulated HTM gave up after max retries — fallback lock path.
    TxFallback,
    // -- util::backoff ----------------------------------------------------
    /// Adaptive backoff exhausted its spin budget and yielded the CPU.
    BackoffYield,
    // -- smr/ (per scheme) ------------------------------------------------
    /// Hazard slot acquired (slow-path pointer protection began).
    HazardPin,
    /// Hazard acquisition overflowed the fixed per-thread slots.
    HazardOverflow,
    /// Node handed to the hazard retire bag.
    HazardRetire,
    /// Hazard announcement scan (retire-threshold or recycler-driven).
    HazardScan,
    /// Node freed by the hazard scheme.
    HazardFree,
    /// Retire bag spilled to the orphan list (thread exit / flush).
    HazardOrphanSpill,
    /// Outermost epoch pin.
    EpochPin,
    /// Node handed to the epoch retire bag.
    EpochRetire,
    /// Global epoch advanced.
    EpochAdvance,
    /// Epoch advance/collect attempt (announcement scan).
    EpochScan,
    /// Node freed by the epoch scheme.
    EpochFree,
    /// Epoch retire bag spilled to the orphan list.
    EpochOrphanSpill,
    // -- hash/ online resize ----------------------------------------------
    /// A grow was published (ResizeState installed).
    ResizeGrowBegin,
    /// A migration stripe claimed via the witnessing CAS.
    ResizeStripeClaim,
    /// One source bucket sealed FROZEN and migrated by a helper.
    ResizeBucketMigrate,
    /// An update landed on a FROZEN bucket and had to wait out the copy.
    ResizeFrozenWait,
    /// A resize fully retired its old table (generation bumped).
    ResizeFinish,
    // -- coordinator/kv_service -------------------------------------------
    /// Request enqueued to a worker mailbox.
    KvRequest,
    /// Batch drained and served by a worker.
    KvBatch,
    /// Shutdown-phase steal of another worker's leftover mailbox.
    KvSteal,
    // -- ingress/ (lock-free claim-queue front door) -----------------------
    /// Batch admitted to a shard queue (the enqueue-and-tally CAS won).
    KvEnqueue,
    /// A drainer claimed a whole run (the claim-and-detach CAS won).
    KvClaim,
    /// Batch rejected by a full shard under the Shed admission policy.
    KvShed,
    /// A producer entered the Wait admission backoff on a full shard.
    KvAdmitWait,
    /// A worker claimed a run from a non-affinity shard (steal-on-idle).
    KvStealRun,
    // -- fault tolerance / chaos -------------------------------------------
    /// An update helped copy a FROZEN bucket instead of waiting it out.
    ResizeTakeover,
    /// A KV worker panicked and was respawned by the supervisor.
    KvWorkerPanic,
    /// A dropped run re-pushed undrained batches back to its shard.
    KvRequeue,
    /// An expired drainer lease was CASed away by a second worker.
    KvLeaseTakeover,
    /// A fault plan fired an injected fault (`--features fault` only).
    FaultInject,
    // -- smr::pool (page-pool node allocator) -------------------------------
    /// A fresh page was carved from the system allocator (pool miss).
    PoolPageAlloc,
    /// A node slot returned to a free list (pool hit on the free path).
    PoolRecycle,
    /// A drained page handed to an SMR scheme in one `retire_page` call.
    RetireBatch,
    /// The global orphan list's mutex was acquired (spill, drain, or
    /// census) — the traffic `retire_page` amortizes by the batch size.
    OrphanLock,
    // -- hash/ online resize, shrink direction ------------------------------
    // Grow keeps the original undirected names above (stable JSON keys);
    // shrink-direction traffic lands here instead, so `repro stats`
    // deltas separate the two migrations cleanly.
    /// A shrink was published (half-size ResizeState installed).
    ResizeShrinkBegin,
    /// A migration stripe claimed while shrinking.
    ResizeShrinkStripeClaim,
    /// One source bucket sealed FROZEN and migrated while shrinking.
    ResizeShrinkBucketMigrate,
    /// An update waited on a FROZEN bucket of a shrinking table.
    ResizeShrinkFrozenWait,
    /// A shrink fully retired its old table (shrink generation bumped).
    ResizeShrinkFinish,
}

/// Number of events (cells per thread row).
pub const NUM_EVENTS: usize = Event::ResizeShrinkFinish as usize + 1;

/// All events in cell order — drives snapshot naming; `test_all_dense`
/// pins the `ALL[i] as usize == i` invariant.
pub const ALL: [Event; NUM_EVENTS] = [
    Event::FastPathHit,
    Event::FastPathMiss,
    Event::SlowPathInstall,
    Event::CasRetry,
    Event::HelpRecache,
    Event::HelpWrite,
    Event::LockAcquire,
    Event::TxRetry,
    Event::TxFallback,
    Event::BackoffYield,
    Event::HazardPin,
    Event::HazardOverflow,
    Event::HazardRetire,
    Event::HazardScan,
    Event::HazardFree,
    Event::HazardOrphanSpill,
    Event::EpochPin,
    Event::EpochRetire,
    Event::EpochAdvance,
    Event::EpochScan,
    Event::EpochFree,
    Event::EpochOrphanSpill,
    Event::ResizeGrowBegin,
    Event::ResizeStripeClaim,
    Event::ResizeBucketMigrate,
    Event::ResizeFrozenWait,
    Event::ResizeFinish,
    Event::KvRequest,
    Event::KvBatch,
    Event::KvSteal,
    Event::KvEnqueue,
    Event::KvClaim,
    Event::KvShed,
    Event::KvAdmitWait,
    Event::KvStealRun,
    Event::ResizeTakeover,
    Event::KvWorkerPanic,
    Event::KvRequeue,
    Event::KvLeaseTakeover,
    Event::FaultInject,
    Event::PoolPageAlloc,
    Event::PoolRecycle,
    Event::RetireBatch,
    Event::OrphanLock,
    Event::ResizeShrinkBegin,
    Event::ResizeShrinkStripeClaim,
    Event::ResizeShrinkBucketMigrate,
    Event::ResizeShrinkFrozenWait,
    Event::ResizeShrinkFinish,
];

impl Event {
    /// snake_case name used as the JSON key.
    pub fn name(self) -> &'static str {
        match self {
            Event::FastPathHit => "fast_path_hit",
            Event::FastPathMiss => "fast_path_miss",
            Event::SlowPathInstall => "slow_path_install",
            Event::CasRetry => "cas_retry",
            Event::HelpRecache => "help_recache",
            Event::HelpWrite => "help_write",
            Event::LockAcquire => "lock_acquire",
            Event::TxRetry => "tx_retry",
            Event::TxFallback => "tx_fallback",
            Event::BackoffYield => "backoff_yield",
            Event::HazardPin => "hazard_pin",
            Event::HazardOverflow => "hazard_overflow",
            Event::HazardRetire => "hazard_retire",
            Event::HazardScan => "hazard_scan",
            Event::HazardFree => "hazard_free",
            Event::HazardOrphanSpill => "hazard_orphan_spill",
            Event::EpochPin => "epoch_pin",
            Event::EpochRetire => "epoch_retire",
            Event::EpochAdvance => "epoch_advance",
            Event::EpochScan => "epoch_scan",
            Event::EpochFree => "epoch_free",
            Event::EpochOrphanSpill => "epoch_orphan_spill",
            Event::ResizeGrowBegin => "resize_grow_begin",
            Event::ResizeStripeClaim => "resize_stripe_claim",
            Event::ResizeBucketMigrate => "resize_bucket_migrate",
            Event::ResizeFrozenWait => "resize_frozen_wait",
            Event::ResizeFinish => "resize_finish",
            Event::KvRequest => "kv_request",
            Event::KvBatch => "kv_batch",
            Event::KvSteal => "kv_steal",
            Event::KvEnqueue => "kv_enqueue",
            Event::KvClaim => "kv_claim",
            Event::KvShed => "kv_shed",
            Event::KvAdmitWait => "kv_admit_wait",
            Event::KvStealRun => "kv_steal_run",
            Event::ResizeTakeover => "resize_takeover",
            Event::KvWorkerPanic => "kv_worker_panic",
            Event::KvRequeue => "kv_requeue",
            Event::KvLeaseTakeover => "kv_lease_takeover",
            Event::FaultInject => "fault_inject",
            Event::PoolPageAlloc => "pool_page_alloc",
            Event::PoolRecycle => "pool_recycle",
            Event::RetireBatch => "retire_batch",
            Event::OrphanLock => "orphan_lock",
            Event::ResizeShrinkBegin => "resize_shrink_begin",
            Event::ResizeShrinkStripeClaim => "resize_shrink_stripe_claim",
            Event::ResizeShrinkBucketMigrate => "resize_shrink_bucket_migrate",
            Event::ResizeShrinkFrozenWait => "resize_shrink_frozen_wait",
            Event::ResizeShrinkFinish => "resize_shrink_finish",
        }
    }
}

/// One thread's row of event cells.
struct Cells([AtomicU64; NUM_EVENTS]);

static CELLS: [CachePadded<Cells>; MAX_THREADS] = {
    #[allow(clippy::declare_interior_mutable_const)]
    const Z: AtomicU64 = AtomicU64::new(0);
    #[allow(clippy::declare_interior_mutable_const)]
    const ROW: CachePadded<Cells> = CachePadded::new(Cells([Z; NUM_EVENTS]));
    [ROW; MAX_THREADS]
};

/// Bump this thread's cell for `e` by one. Prefer the
/// [`counter!`](crate::counter) macro, which compiles this away without
/// the `telemetry` feature.
#[inline]
pub fn incr(e: Event) {
    incr_by(e, 1);
}

/// Bump this thread's cell for `e` by `n`.
#[inline]
pub fn incr_by(e: Event, n: u64) {
    let cell = &CELLS[registry::tid()].0[e as usize];
    // Ordering: RELAXED load + store (not an RMW) — the cell is written
    // only by its owning thread (registry tids are exclusive while
    // leased), so program order alone keeps it exact; readers are racy
    // snapshot sums that tolerate boundary skew.
    cell.store(cell.load(Ordering::Relaxed).wrapping_add(n), Ordering::Relaxed);
}

/// Sum every thread's cell for `e` (cumulative for the process).
pub fn total(e: Event) -> u64 {
    let hw = registry::high_water().min(MAX_THREADS);
    CELLS[..hw]
        .iter()
        .map(|row| row.0[e as usize].load(Ordering::Relaxed))
        .sum()
}

/// Sum all cells — one pass, cell order matches [`ALL`].
pub fn totals() -> [u64; NUM_EVENTS] {
    let hw = registry::high_water().min(MAX_THREADS);
    let mut out = [0u64; NUM_EVENTS];
    for row in &CELLS[..hw] {
        for (o, c) in out.iter_mut().zip(row.0.iter()) {
            *o = o.wrapping_add(c.load(Ordering::Relaxed));
        }
    }
    out
}

/// Count named events on the hot paths.
///
/// * `counter!(FastPathHit)` — bump by one.
/// * `counter!(HelpRecache, n)` — bump by `n` (`n: u64`).
///
/// With the `telemetry` cargo feature this is one owner-private
/// `Relaxed` load+store ([`obs::telemetry::incr`](incr)); without it
/// the macro expands to nothing — the count expression is **not
/// evaluated** (it is captured by a never-called closure so its
/// bindings still count as used).
#[cfg(feature = "telemetry")]
#[macro_export]
macro_rules! counter {
    ($e:ident) => {
        $crate::obs::telemetry::incr($crate::obs::telemetry::Event::$e)
    };
    ($e:ident, $n:expr) => {
        $crate::obs::telemetry::incr_by($crate::obs::telemetry::Event::$e, $n)
    };
}

/// No-op expansion (`telemetry` feature off): zero instructions, and
/// the count expression is not evaluated.
#[cfg(not(feature = "telemetry"))]
#[macro_export]
macro_rules! counter {
    ($e:ident) => {
        ()
    };
    ($e:ident, $n:expr) => {{
        // Capture (never call) so `$n`'s bindings stay "used" without
        // evaluating the expression.
        let _ = || {
            let _ = &$n;
        };
    }};
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn test_all_dense() {
        assert_eq!(ALL.len(), NUM_EVENTS);
        for (i, e) in ALL.iter().enumerate() {
            assert_eq!(*e as usize, i, "ALL[{i}] = {e:?} out of order");
        }
        // Names are unique (they become JSON keys).
        let mut names: Vec<_> = ALL.iter().map(|e| e.name()).collect();
        names.sort_unstable();
        names.dedup();
        assert_eq!(names.len(), NUM_EVENTS);
    }

    /// With the `telemetry` feature on, concurrently running lib tests
    /// also bump instrumented events, so deltas are lower bounds there
    /// and exact only in default builds (where instrumentation is
    /// compiled out and these direct calls are the sole writers). The
    /// guaranteed-exclusive exactness test lives in `tests/obs.rs`.
    fn assert_delta(actual: u64, expected: u64) {
        if cfg!(feature = "telemetry") {
            assert!(actual >= expected, "delta {actual} < {expected}");
        } else {
            assert_eq!(actual, expected);
        }
    }

    #[test]
    fn test_incr_and_total_single_thread() {
        let before = total(Event::KvSteal);
        incr(Event::KvSteal);
        incr_by(Event::KvSteal, 4);
        assert_delta(total(Event::KvSteal) - before, 5);
        assert_delta(totals()[Event::KvSteal as usize] - before, 5);
    }

    #[test]
    fn test_multithreaded_totals_exact() {
        use std::sync::Arc;
        let threads = 8u64;
        let per = 50_000u64;
        let before = total(Event::TxRetry);
        let barrier = Arc::new(std::sync::Barrier::new(threads as usize));
        let handles: Vec<_> = (0..threads)
            .map(|_| {
                let barrier = Arc::clone(&barrier);
                std::thread::spawn(move || {
                    barrier.wait();
                    for _ in 0..per {
                        incr(Event::TxRetry);
                    }
                    // Hold until everyone finished so no tid is reused
                    // mid-test (reuse is fine for sums, but keeping the
                    // rows distinct exercises the sharding).
                    barrier.wait();
                })
            })
            .collect();
        for h in handles {
            h.join().unwrap();
        }
        assert_delta(total(Event::TxRetry) - before, threads * per);
    }

    #[test]
    fn test_macro_compiles_both_forms() {
        let before = total(Event::TxFallback);
        let n = 3u64;
        crate::counter!(TxFallback);
        crate::counter!(TxFallback, n);
        let after = total(Event::TxFallback);
        if cfg!(feature = "telemetry") {
            assert!(after >= before + 4);
        } else {
            // No-op expansion: nothing recorded, `n` not evaluated.
            assert_eq!(after, before);
        }
    }
}
