//! Crate-native observability: sharded event counters, lock-free
//! latency histograms, and JSON snapshots.
//!
//! Three pieces:
//!
//! * [`telemetry`] — named per-thread event counters behind the
//!   [`counter!`](crate::counter) macro. The macro is real only under
//!   the `telemetry` cargo feature; default builds compile it to
//!   nothing, so the hot paths (and the PR 3 ordering-diet numbers)
//!   are untouched.
//! * [`histogram`] — a log-linear (power-of-two majors × 16 linear
//!   sub-buckets) concurrent histogram with p50/p90/p99/p999
//!   extraction. Always compiled: `repro kv` uses it for native
//!   latency quantiles even in default builds.
//! * [`snapshot`] — [`ObsSnapshot`]: capture counters + histograms,
//!   difference two captures for per-run numbers, dump JSON
//!   (`repro stats`, `--telemetry` runs' `*.obs.json` exhibits).
//!
//! The module-level [`set_enabled`]/[`enabled`] flag is the *reporting*
//! switch (set by `--telemetry`): it decides whether runs capture and
//! dump snapshots, not whether counters count — counting is a
//! compile-time decision (the cargo feature), reporting a runtime one.

pub mod histogram;
pub mod snapshot;
pub mod telemetry;

pub use histogram::{Histogram, HistogramSnapshot};
pub use snapshot::ObsSnapshot;
pub use telemetry::Event;

use std::sync::atomic::{AtomicBool, Ordering};

/// Per-batch service latency in nanoseconds (kv_service; per-request =
/// batch total / batch len, recorded once per batch to keep the serve
/// loop cheap). Always recorded — this feeds the native `repro kv`
/// p50/p99/p999 report in default builds.
pub static KV_LATENCY_NS: Histogram = Histogram::new();
/// Batch sizes drained by kv workers.
pub static KV_BATCH: Histogram = Histogram::new();
/// Mailbox depth observed at each enqueue (before the push).
pub static KV_QUEUE_DEPTH: Histogram = Histogram::new();
/// Lock-free ingress: shard queue tally right after each admitted batch
/// (always-on — records in default builds like the rest of the
/// histograms; `repro kv` folds its quantiles into the report).
pub static KV_SHARD_DEPTH: Histogram = Histogram::new();

/// Every named global histogram, in snapshot order.
pub fn global_histograms() -> [(&'static str, &'static Histogram); 4] {
    [
        ("kv_latency_ns", &KV_LATENCY_NS),
        ("kv_batch", &KV_BATCH),
        ("kv_queue_depth", &KV_QUEUE_DEPTH),
        ("kv_shard_depth", &KV_SHARD_DEPTH),
    ]
}

static ENABLED: AtomicBool = AtomicBool::new(false);

/// Turn snapshot reporting on/off for this process (the `--telemetry`
/// CLI flag). Counters/histograms record regardless; this only gates
/// whether reports capture deltas and write `*.obs.json`.
pub fn set_enabled(on: bool) {
    ENABLED.store(on, Ordering::Relaxed);
}

/// Whether snapshot reporting is on.
pub fn enabled() -> bool {
    ENABLED.load(Ordering::Relaxed)
}
