//! The benchmark coordinator (leader) and the KV service.
//!
//! The coordinator owns process lifecycle: it loads the PJRT runtime
//! once, builds the workload engine from the AOT artifacts, schedules
//! figure jobs, and writes the report index.  The paper's contribution
//! is the memory-layer algorithms, so per DESIGN.md L3's coordination
//! role here is a driver: CLI + job orchestration + the
//! [`kv_service`] request loop that exercises the full stack end to end.

pub mod kv_service;

use crate::util::error::Result;

use crate::bench::driver::OpSource;
use crate::bench::figures::{self, FigureCfg};
use crate::bench::workload::WorkloadSpec;
use crate::runtime::workload_gen::WorkloadEngine;
use crate::runtime::{default_artifact_dir, Runtime};

/// Lazily-initialized runtime + engine (artifacts are optional: every
/// benchmark falls back to the pure-Rust generator when absent).
pub struct Coordinator {
    pub runtime: Option<Runtime>,
    pub engine: Option<WorkloadEngine>,
}

impl Coordinator {
    /// `use_artifact`: require and load the AOT artifacts.
    pub fn new(use_artifact: bool) -> Result<Self> {
        if !use_artifact {
            return Ok(Self {
                runtime: None,
                engine: None,
            });
        }
        let rt = Runtime::new(default_artifact_dir())?;
        let engine = WorkloadEngine::new(&rt)?;
        eprintln!(
            "coordinator: PJRT platform={} artifact batch={}",
            rt.platform(),
            engine.batch()
        );
        Ok(Self {
            runtime: Some(rt),
            engine: Some(engine),
        })
    }

    pub fn op_source(&self) -> OpSource<'_> {
        match &self.engine {
            Some(e) => OpSource::Artifact(e),
            None => OpSource::Rust,
        }
    }

    /// Run one named figure job; returns saved CSV paths.
    pub fn run_figure(&self, name: &str, cfg: &FigureCfg, panel: &str, oversub: bool) -> Result<Vec<String>> {
        let source = self.op_source();
        let mut saved = Vec::new();
        let mut save = |r: figures::Report| -> Result<()> {
            saved.push(r.save(&cfg.report_dir)?);
            Ok(())
        };
        match name {
            "fig1" => save(figures::fig1(cfg, &source))?,
            "fig2" => match panel {
                "u" => save(figures::fig2_u(cfg, &source, oversub))?,
                "z" => save(figures::fig2_z(cfg, &source, oversub))?,
                "n" => save(figures::fig2_n(cfg, &source, oversub))?,
                "w" => save(figures::fig2_w(cfg, &source))?,
                "p" => save(figures::fig2_p(cfg, &source))?,
                "fu" => save(figures::fig2_fetch_update(cfg, &source))?,
                "" | "all" => {
                    for ov in [false, true] {
                        save(figures::fig2_u(cfg, &source, ov))?;
                        save(figures::fig2_z(cfg, &source, ov))?;
                        save(figures::fig2_n(cfg, &source, ov))?;
                    }
                    save(figures::fig2_w(cfg, &source))?;
                    save(figures::fig2_p(cfg, &source))?;
                    save(figures::fig2_fetch_update(cfg, &source))?;
                }
                other => crate::bail!("fig2 panel {other}: use u|z|n|w|p|fu"),
            },
            "fig3" => match panel {
                "" | "all" => {
                    for pn in ["u", "z", "n"] {
                        for ov in [false, true] {
                            save(figures::fig3(cfg, &source, pn, ov))?;
                        }
                    }
                    save(figures::fig3_wide(cfg, &source))?;
                }
                "wide" => save(figures::fig3_wide(cfg, &source))?,
                pn => save(figures::fig3(cfg, &source, pn, oversub))?,
            },
            "fig4" => {
                let (a, b) = figures::fig4(cfg, &source);
                save(a)?;
                save(b)?;
            }
            "fig5" => {
                for r in figures::fig5(cfg, &source) {
                    save(r)?;
                }
            }
            "table1" => save(figures::table1())?,
            "memory" => save(crate::bench::memory::memory_census(cfg))?,
            "ablate" => match panel {
                "ordering" => save(crate::bench::ablation::run_ordering_ablation(cfg))?,
                "smr" => {
                    save(crate::bench::ablation::run_smr_ablation(cfg))?;
                    save(crate::bench::ablation::run_smr_table_ablation(cfg, &source))?;
                }
                "resize" => save(crate::bench::ablation::run_resize_ablation(cfg, &source))?,
                "ingress" => save(crate::bench::ablation::run_ingress_ablation(cfg))?,
                "alloc" => save(crate::bench::ablation::run_alloc_ablation(cfg, &source))?,
                "" | "all" => {
                    save(crate::bench::ablation::run_ablations(cfg, &source))?;
                    save(crate::bench::ablation::run_ordering_ablation(cfg))?;
                    save(crate::bench::ablation::run_smr_ablation(cfg))?;
                    save(crate::bench::ablation::run_smr_table_ablation(cfg, &source))?;
                    save(crate::bench::ablation::run_resize_ablation(cfg, &source))?;
                    save(crate::bench::ablation::run_ingress_ablation(cfg))?;
                    save(crate::bench::ablation::run_alloc_ablation(cfg, &source))?;
                }
                other => {
                    crate::bail!(
                        "ablate panel {other}: use ordering|smr|resize|ingress|alloc (or omit for all)"
                    )
                }
            },
            "all" => {
                saved.extend(figures::run_all(cfg, &source));
                saved.push(
                    crate::bench::ablation::run_ablations(cfg, &source).save(&cfg.report_dir)?,
                );
                saved.push(
                    crate::bench::ablation::run_ordering_ablation(cfg).save(&cfg.report_dir)?,
                );
                saved.push(
                    crate::bench::ablation::run_smr_ablation(cfg).save(&cfg.report_dir)?,
                );
                saved.push(
                    crate::bench::ablation::run_smr_table_ablation(cfg, &source)
                        .save(&cfg.report_dir)?,
                );
                saved.push(
                    crate::bench::ablation::run_resize_ablation(cfg, &source)
                        .save(&cfg.report_dir)?,
                );
                saved.push(
                    crate::bench::ablation::run_ingress_ablation(cfg).save(&cfg.report_dir)?,
                );
                saved.push(
                    crate::bench::ablation::run_alloc_ablation(cfg, &source)
                        .save(&cfg.report_dir)?,
                );
            }
            other => crate::bail!("unknown figure {other}"),
        }
        Ok(saved)
    }

    /// Cross-validate the AOT workload artifact against the pure-Rust
    /// generator, bit for bit. Returns the number of ops compared.
    pub fn validate_workload(&self, count: usize) -> Result<usize> {
        let engine = self
            .engine
            .as_ref()
            .ok_or_else(|| crate::anyhow!("validation requires --artifact (run `make artifacts`)"))?;
        let specs = [
            WorkloadSpec { n: 100, theta: 0.0, update_pct: 50, seed: 1 },
            WorkloadSpec { n: 4096, theta: 0.99, update_pct: 10, seed: 2 },
            WorkloadSpec { n: 1 << 20, theta: 0.75, update_pct: 100, seed: 3 },
        ];
        let mut compared = 0;
        for spec in &specs {
            for t in 0..2u64 {
                let ours = crate::bench::workload::generate_rust(spec, count, t);
                let theirs = engine.generate(spec, count, t)?;
                crate::ensure!(ours.len() == theirs.len());
                for (i, (a, b)) in ours.iter().zip(&theirs).enumerate() {
                    crate::ensure!(
                        a.op == b.op && a.rank == b.rank && a.key == b.key,
                        "mismatch spec n={} z={} t={t} op#{i}: rust=({:?},{},{:#x}) hlo=({:?},{},{:#x})",
                        spec.n, spec.theta, a.op, a.rank, a.key, b.op, b.rank, b.key
                    );
                }
                compared += ours.len();
            }
        }
        Ok(compared)
    }
}
