//! A miniature KV service over CacheHash — the end-to-end driver.
//!
//! Shape: **multi-producer simulated clients** generate request batches
//! (via the AOT workload artifact when available) and feed them to
//! worker threads executing against a shared `CacheHash<CachedMemEff>`
//! table, through one of two ingress arms ([`KvConfig::ingress`]):
//!
//! * **`lockfree`** (default) — the [`crate::ingress`] subsystem:
//!   clients route each request by key hash to one of N shard
//!   [`ClaimQueue`]s (enqueue-and-tally in one witnessing CAS, bounded
//!   tally with shed-or-wait admission), and workers claim whole runs
//!   with exactly-one-drainer semantics — affinity shard first, then
//!   steal-on-idle. No `Mutex`/`Condvar` anywhere on this path.
//! * **`mailbox`** — the retained baseline: bounded per-worker
//!   `Mutex`+`Condvar` mailboxes fed round-robin. A producer scans for
//!   a non-full sibling before parking on its round-robin target (the
//!   head-of-line-blocking fix), and on shutdown workers drain their
//!   own mailbox then steal siblings' leftovers.
//!
//! Both arms share the serve loop, the latency pipeline, and the
//! **conservation contract**: every batch offered to the ingress is
//! either admitted or shed, and every admitted batch is served exactly
//! once or (if a fault kills the serving worker mid-batch) counted
//! abandoned — `enqueued_batches == sample_count + shed_batches +
//! abandoned_batches` in every [`KvReport`] (`abandoned_batches` is
//! zero outside `--features fault` chaos runs). `repro ablate --panel
//! ingress` compares the arms across thread counts up to 4× cores.
//!
//! Workers are **panic-isolated**: each loop iteration runs under
//! `catch_unwind`, so a panicking worker (an injected kill, or a real
//! bug) is counted in [`KvReport::worker_panics`] and the thread
//! resumes serving in place instead of poisoning the run. All mailbox
//! and reservoir mutexes take their guards poison-tolerantly — a
//! panicked sibling never wedges the service.
//!
//! The table may be constructed deliberately undersized
//! ([`KvConfig::initial_capacity`]) to exercise the online-resize path
//! end to end: the warm fill and the serving inserts drive the table
//! through its doublings while finds stream lock-free.
//!
//! The latency summary is computed by the `stats.hlo.txt` artifact
//! (the L2 stats model) when a runtime is supplied; each worker
//! reservoir-samples its own served batches and the per-worker
//! reservoirs are merged *weighted by each worker's `seen` count*, so
//! busy workers (and stealers) don't over-weight the retained sample.
//!
//! This is deliberately the whole stack in one loop: L1/L2 artifacts →
//! PJRT runtime → big atomics → ingress → CacheHash → throughput/latency
//! report (recorded in EXPERIMENTS.md §End-to-end).

use std::collections::VecDeque;
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Condvar, Mutex, MutexGuard, PoisonError};
use std::time::{Duration, Instant};

use crate::apps::stats::{Snapshot, StatsCell};
use crate::atomics::CachedMemEff;
use crate::bench::workload::{generate_rust, GenOp, Op, WorkloadSpec};
use crate::hash::{CacheHash, ConcurrentMap, LinkVal};
use crate::ingress::{admit, Admitted, AdmissionPolicy, ShardRouter};
use crate::obs::Histogram;
use crate::runtime::{LatencySummary, Runtime};
use crate::util::backoff::snooze_lazy;
use crate::util::error::Result;
use crate::util::rng::Xoshiro256;

/// Which front door feeds the workers.
#[derive(Copy, Clone, Debug, PartialEq, Eq, Default)]
pub enum IngressMode {
    /// The [`crate::ingress`] claim-queue subsystem (sharded, lock-free).
    #[default]
    Lockfree,
    /// The bounded `Mutex`+`Condvar` per-worker mailboxes (baseline arm).
    Mailbox,
}

impl IngressMode {
    /// Parse a CLI spelling (`lockfree` | `mailbox`).
    pub fn parse(s: &str) -> Result<Self> {
        match s {
            "lockfree" => Ok(Self::Lockfree),
            "mailbox" => Ok(Self::Mailbox),
            other => crate::bail!("ingress mode {other}: use lockfree|mailbox"),
        }
    }

    pub fn name(self) -> &'static str {
        match self {
            Self::Lockfree => "lockfree",
            Self::Mailbox => "mailbox",
        }
    }
}

#[derive(Clone, Debug)]
pub struct KvConfig {
    /// Key-space size.
    pub n: usize,
    /// Worker threads serving requests (capped so workers + clients stay
    /// within the thread registry, [`crate::MAX_THREADS`]).
    pub workers: usize,
    /// Requests per client-generated batch (the lock-free arm re-cuts
    /// each batch into per-shard sub-batches by key hash).
    pub batch: usize,
    /// Total run duration.
    pub duration: Duration,
    pub update_pct: u32,
    pub theta: f64,
    pub seed: u64,
    /// Initial table capacity; 0 ⇒ sized for `n`. Set small (e.g. 64)
    /// to serve from a deliberately undersized table and exercise
    /// online growth under live traffic.
    pub initial_capacity: usize,
    /// Bound on the raw latency samples retained for offline analysis
    /// (reservoir-sampled across the run; 0 ⇒ the default bound). The
    /// exact per-batch summary ([`KvReport::latency_stats`] and the
    /// histogram-backed quantiles) always sees every sample — only the
    /// raw-sample vector is bounded.
    pub reservoir: usize,
    /// Ingress arm: the lock-free claim-queue subsystem or the mailbox
    /// baseline.
    pub ingress: IngressMode,
    /// Ingress shards (lock-free arm); 0 ⇒ one per worker, rounded to a
    /// power of two and capped at [`MAX_SHARDS`].
    pub shards: usize,
    /// Simulated client (producer) threads; 0 ⇒ 1 (the old single
    /// leader). Capped alongside `workers` to fit the registry.
    pub clients: usize,
    /// What a producer does when its shard queue is full (lock-free
    /// arm): wait (backpressure) or shed. The mailbox arm always waits
    /// (its bounded push blocks).
    pub admission: AdmissionPolicy,
    /// Drainer-lease bound in milliseconds for the lock-free arm's
    /// shard queues (0 ⇒ leases off, the default). With a lease, a
    /// claim held past the bound may be taken over by another worker —
    /// the crash-tolerance knob the chaos scenarios turn on.
    pub lease_ms: u64,
}

/// Default [`KvConfig::reservoir`] bound.
pub const DEFAULT_RESERVOIR: usize = 4096;

/// Queued sub-batches per ingress shard before admission pushes back —
/// the lock-free analog of [`MAILBOX_CAP`]; deeper because sub-batches
/// are a shard's slice of a batch, not a whole one.
const SHARD_BOUND: u64 = 32;

/// Shard-count ceiling when [`KvConfig::shards`] == 0 sizes one shard
/// per worker.
const MAX_SHARDS: usize = 64;

/// Thread-budget caps: workers + clients + the coordinating thread must
/// stay well inside the registry ([`crate::MAX_THREADS`] = 256), which
/// epoch pins and telemetry rows lease per live thread.
const MAX_SERVICE_WORKERS: usize = 160;
const MAX_SERVICE_CLIENTS: usize = 48;

impl Default for KvConfig {
    fn default() -> Self {
        Self {
            n: 1 << 16,
            workers: 4,
            batch: 512,
            duration: Duration::from_secs(2),
            update_pct: 30,
            theta: 0.5,
            seed: 0x4B56, // "KV"
            initial_capacity: 0,
            reservoir: DEFAULT_RESERVOIR,
            ingress: IngressMode::Lockfree,
            shards: 0,
            clients: 0,
            admission: AdmissionPolicy::Wait,
            lease_ms: 0,
        }
    }
}

/// Poison-tolerant lock acquisition: a panicking worker is already
/// counted ([`KvReport::worker_panics`]) and isolated — its poison bit
/// must not cascade into every sibling that shares the mutex.
fn lock_ignore_poison<T>(m: &Mutex<T>) -> MutexGuard<'_, T> {
    m.lock().unwrap_or_else(PoisonError::into_inner)
}

/// Bounded uniform sample of a stream (Vitter's Algorithm R): the
/// first `cap` values fill the buffer; the `t`-th value thereafter
/// replaces a random slot with probability `cap/t`. Memory is O(cap)
/// regardless of run length — the fix for the old unbounded per-request
/// `Vec` that grew with duration.
struct Reservoir {
    cap: usize,
    seen: u64,
    samples: Vec<f32>,
    rng: Xoshiro256,
}

impl Reservoir {
    fn new(cap: usize, seed: u64) -> Self {
        let cap = cap.max(1);
        Self {
            cap,
            seen: 0,
            samples: Vec::with_capacity(cap.min(DEFAULT_RESERVOIR)),
            rng: Xoshiro256::seeded(seed),
        }
    }

    fn push(&mut self, v: f32) {
        self.seen += 1;
        if self.samples.len() < self.cap {
            self.samples.push(v);
        } else {
            let j = self.rng.next_below(self.seen as usize);
            if j < self.cap {
                self.samples[j] = v;
            }
        }
    }
}

/// Merge per-worker reservoirs into one `cap`-bounded sample, weighted
/// by each worker's `seen` count: a retained sample from a worker that
/// saw `seen` batches over `len` slots represents `seen/len` of the
/// stream, so samples are kept by the Efraimidis–Spirakis A-Res rule
/// (largest `u^(1/w)` keys win). The old blind `extend` gave every
/// retained sample equal weight, over-representing workers that served
/// few batches — and under-representing the heavily-loaded (or
/// steal-heavy) workers whose reservoirs were most compressed.
fn merge_reservoirs(parts: Vec<Reservoir>, cap: usize, seed: u64) -> (u64, Vec<f32>) {
    let cap = cap.max(1);
    let mut rng = Xoshiro256::seeded(seed ^ 0x4D52_4745); // "MRGE"
    let mut total_seen = 0u64;
    let mut keyed: Vec<(f64, f32)> = Vec::new();
    for r in parts {
        total_seen += r.seen;
        if r.samples.is_empty() {
            continue;
        }
        let w = (r.seen as f64 / r.samples.len() as f64).max(1.0);
        for s in r.samples {
            let u = rng.next_f64().max(f64::MIN_POSITIVE);
            keyed.push((u.powf(1.0 / w), s));
        }
    }
    keyed.sort_by(|a, b| b.0.total_cmp(&a.0));
    keyed.truncate(cap);
    (total_seen, keyed.into_iter().map(|(_, s)| s).collect())
}

#[derive(Debug)]
pub struct KvReport {
    pub total_requests: u64,
    pub elapsed: Duration,
    pub finds: u64,
    pub inserts: u64,
    pub deletes: u64,
    pub latency: Option<LatencySummary>,
    /// p99.9 of the per-request latency (ns), from the lock-free
    /// log-linear histogram that sees every sample (not the bounded
    /// reservoir); `None` only when no batch completed.
    pub latency_p999_ns: Option<u64>,
    /// Exact number of per-batch latency samples observed (== batches
    /// served). The *retained* raw-sample vector is reservoir-bounded
    /// ([`KvConfig::reservoir`]), but this count, `latency_stats`, and
    /// the histogram quantiles are computed over every sample.
    pub sample_count: usize,
    /// Raw samples actually retained after the weighted reservoir merge
    /// (≤ [`KvConfig::reservoir`]).
    pub retained_samples: usize,
    /// Always-consistent (count, sum, min, max) of the per-request
    /// latency (ns), accumulated by every worker through one big-atomic
    /// `fetch_update` cell — no lock, no torn snapshot, no artifacts
    /// needed.
    pub latency_stats: Snapshot,
    /// Batches served by each worker (all > 0 ⇔ the fan-out fanned out).
    pub worker_batches: Vec<u64>,
    /// Maximum number of workers observed mid-batch simultaneously.
    pub peak_concurrent_workers: u64,
    /// Table buckets at construction / after the run (growth proof when
    /// `initial_capacity` undersizes the table).
    pub initial_buckets: usize,
    pub final_buckets: usize,
    /// Which ingress arm ran (`lockfree` | `mailbox`).
    pub ingress: &'static str,
    /// Batches offered to the ingress (admitted **plus** shed).
    /// Conservation: `enqueued_batches == sample_count + shed_batches
    /// + abandoned_batches` — nothing lost, nothing double-served.
    pub enqueued_batches: u64,
    /// Batches rejected by full shards under the Shed policy.
    pub shed_batches: u64,
    /// Admissions that had to back off at least once (Wait policy).
    pub admit_waits: u64,
    /// Runs claimed by drainers (lock-free arm).
    pub claim_runs: u64,
    /// Runs claimed from a non-affinity shard (steal-on-idle).
    pub steal_runs: u64,
    /// Batches served per ingress shard (lock-free arm; empty for the
    /// mailbox baseline). All > 0 ⇔ every shard made progress.
    pub shard_batches: Vec<u64>,
    /// Worker/producer thread panics caught by the supervisor (injected
    /// kills under `--features fault`, or real bugs). The thread keeps
    /// serving — a panic costs at most the batch it was holding.
    pub worker_panics: u64,
    /// Batches a panicking (or displaced) drainer handed back to its
    /// shard queue on unwind. These re-enter the queue and are served
    /// later, so they are a delay, not a conservation term.
    pub requeued_batches: u64,
    /// Batches lost mid-serve to a worker panic (counted, not silently
    /// dropped — the third conservation term). Zero without faults.
    pub abandoned_batches: u64,
    /// Expired drainer claims taken over by another worker (lock-free
    /// arm with [`KvConfig::lease_ms`] > 0).
    pub lease_takeovers: u64,
}

impl KvReport {
    pub fn mops(&self) -> f64 {
        self.total_requests as f64 / self.elapsed.as_secs_f64() / 1e6
    }
}

/// Batches buffered per worker mailbox before a producer blocks.
const MAILBOX_CAP: usize = 8;

type Batch = (Instant, Vec<GenOp>);

/// One worker's bounded mailbox (the baseline arm). A producer's
/// bounded `push` and the worker's blocking `pop` meet on one
/// short-held mutex; `steal` is the shutdown-drain path for siblings.
struct Mailbox {
    q: Mutex<VecDeque<Batch>>,
    /// Batch arrived (or shutdown flagged).
    ready: Condvar,
    /// Space freed.
    space: Condvar,
}

impl Mailbox {
    fn new() -> Self {
        Self {
            q: Mutex::new(VecDeque::with_capacity(MAILBOX_CAP)),
            ready: Condvar::new(),
            space: Condvar::new(),
        }
    }

    /// Producer side: non-blocking bounded push; a full mailbox hands
    /// the batch back so the producer can try a sibling.
    fn try_push(&self, item: Batch) -> std::result::Result<(), Batch> {
        let mut q = lock_ignore_poison(&self.q);
        if q.len() >= MAILBOX_CAP {
            return Err(item);
        }
        q.push_back(item);
        // Producer-side gauge: mailbox depth right after the enqueue
        // (the global histogram is always-on; one record, off the
        // worker hot path).
        crate::obs::KV_QUEUE_DEPTH.record(q.len() as u64);
        drop(q);
        self.ready.notify_one();
        Ok(())
    }

    /// Producer side: blocking bounded push (the last resort once every
    /// sibling is full too — see [`push_to_first_free`]).
    fn push(&self, item: Batch) {
        let mut q = lock_ignore_poison(&self.q);
        while q.len() >= MAILBOX_CAP {
            q = self.space.wait(q).unwrap_or_else(PoisonError::into_inner);
        }
        q.push_back(item);
        crate::obs::KV_QUEUE_DEPTH.record(q.len() as u64);
        drop(q);
        self.ready.notify_one();
    }

    /// Owner side: pop, blocking until a batch arrives; `None` once the
    /// mailbox is empty and shutdown is flagged.
    fn pop(&self, done: &AtomicBool) -> Option<Batch> {
        let mut q = lock_ignore_poison(&self.q);
        loop {
            if let Some(item) = q.pop_front() {
                drop(q);
                self.space.notify_one();
                return Some(item);
            }
            // Ordering: Acquire — pairs with the producers' Release
            // store so every pre-shutdown push is visible before we
            // give up.
            if done.load(Ordering::Acquire) {
                return None;
            }
            q = self.ready.wait(q).unwrap_or_else(PoisonError::into_inner);
        }
    }

    /// Shutdown drain: non-blocking steal by a sibling.
    fn steal(&self) -> Option<Batch> {
        let item = lock_ignore_poison(&self.q).pop_front();
        if item.is_some() {
            crate::counter!(KvSteal);
            self.space.notify_one();
        }
        item
    }

    /// Shutdown wakeup. Must take the mailbox mutex: `pop`'s
    /// check-empty-then-park is atomic only under that lock (Condvar
    /// wait releases it when parking), so a bare `notify_all` could
    /// land between a worker's `done` check and its park and be lost
    /// forever — the classic lost-wakeup deadlock.
    fn wake_all(&self) {
        let _q = lock_ignore_poison(&self.q);
        self.ready.notify_all();
    }
}

/// Head-of-line-blocking fix: the round-robin target being full must
/// not park the producer while a sibling mailbox has space — scan once
/// from the target for a non-full sibling, and only park (on the
/// original target) when every mailbox is full.
fn push_to_first_free(mailboxes: &[Mailbox], target: usize, item: Batch) {
    let n = mailboxes.len();
    let mut item = item;
    for i in 0..n {
        match mailboxes[(target + i) % n].try_push(item) {
            Ok(()) => return,
            Err(back) => item = back,
        }
    }
    mailboxes[target].push(item);
}

/// Everything the worker/client threads share, borrowed for the scope
/// of one run.
struct Shared<'a> {
    cfg: &'a KvConfig,
    table: &'a CacheHash<CachedMemEff<LinkVal>>,
    stream: &'a [GenOp],
    per_worker_cap: usize,
    finds: AtomicU64,
    inserts: AtomicU64,
    deletes: AtomicU64,
    served: AtomicU64,
    lat_stats: StatsCell<CachedMemEff<Snapshot>>,
    lat_hist: Histogram,
    active: AtomicU64,
    peak_active: AtomicU64,
    batch_counts: Vec<AtomicU64>,
    shard_batches: Vec<AtomicU64>,
    enqueued: AtomicU64,
    shed: AtomicU64,
    admit_waits: AtomicU64,
    claim_runs: AtomicU64,
    steal_runs: AtomicU64,
    worker_panics: AtomicU64,
    abandoned: AtomicU64,
    requeued: AtomicU64,
    lease_takeovers: AtomicU64,
    reservoirs: Mutex<Vec<Reservoir>>,
    done: AtomicBool,
}

/// Unwind accounting for one in-flight batch: arms at serve entry,
/// disarms once the batch's latency sample is recorded. If the worker
/// panics in between, the drop (during unwind) books the batch as
/// abandoned — the conservation ledger stays balanced — and releases
/// the concurrency gauge either way.
struct ServeGuard<'a, 'b> {
    sh: &'a Shared<'b>,
    abandoned: bool,
}

impl Drop for ServeGuard<'_, '_> {
    fn drop(&mut self) {
        if self.abandoned {
            self.sh.abandoned.fetch_add(1, Ordering::Relaxed);
        }
        self.sh.active.fetch_sub(1, Ordering::AcqRel);
    }
}

impl Shared<'_> {
    /// Execute one batch against the table and record its latency —
    /// identical for both ingress arms.
    fn serve(&self, w: usize, local_lat: &mut Reservoir, (enqueued, batch): Batch) {
        // Concurrency gauge: how many workers are mid-batch.
        let now = self.active.fetch_add(1, Ordering::AcqRel) + 1;
        self.peak_active.fetch_max(now, Ordering::AcqRel);
        // Armed before the first fallible step: a panic anywhere below
        // (until the sample is recorded) books this batch abandoned.
        let mut guard = ServeGuard { sh: self, abandoned: true };
        crate::failpoint!(KvServeBatch);
        for req in &batch {
            match req.op {
                Op::Find => {
                    std::hint::black_box(self.table.find(req.key));
                    self.finds.fetch_add(1, Ordering::Relaxed);
                }
                Op::Insert => {
                    self.table.insert(req.key, req.rank as u64);
                    self.inserts.fetch_add(1, Ordering::Relaxed);
                }
                Op::Delete => {
                    self.table.remove(req.key);
                    self.deletes.fetch_add(1, Ordering::Relaxed);
                }
            }
        }
        self.served.fetch_add(batch.len() as u64, Ordering::Relaxed);
        self.batch_counts[w].fetch_add(1, Ordering::Relaxed);
        crate::counter!(KvBatch);
        crate::counter!(KvRequest, batch.len() as u64);
        crate::obs::KV_BATCH.record(batch.len() as u64);
        // Per-request latency ≈ (queueing + service) / batch.
        let total_ns = enqueued.elapsed().as_nanos() as f32;
        let per_req = total_ns / (batch.len().max(1)) as f32;
        local_lat.push(per_req);
        self.lat_stats.record(per_req as u64);
        self.lat_hist.record(per_req as u64);
        crate::obs::KV_LATENCY_NS.record(per_req as u64);
        // Sampled: the batch is in the ledger as served, not abandoned.
        guard.abandoned = false;
    }

    /// Record one caught worker panic (supervision — both arms).
    fn note_panic(&self) {
        self.worker_panics.fetch_add(1, Ordering::Relaxed);
        crate::counter!(KvWorkerPanic);
    }
}

/// The next `batch` ops of the pre-generated stream, wrapping.
fn next_batch(stream: &[GenOp], cursor: &mut usize, batch: usize) -> Vec<GenOp> {
    let out: Vec<GenOp> = stream[*cursor..]
        .iter()
        .chain(stream.iter())
        .take(batch)
        .copied()
        .collect();
    *cursor = (*cursor + batch) % stream.len().max(1);
    out
}

/// The lock-free arm: clients route per-shard sub-batches through the
/// claim queues; workers claim runs (affinity first, then steal).
fn run_lockfree(sh: &Shared<'_>, workers: usize, clients: usize, nshards: usize) -> Duration {
    let router: ShardRouter<Batch> =
        ShardRouter::with_lease(nshards, SHARD_BOUND, sh.cfg.lease_ms.saturating_mul(1_000_000));
    let elapsed = std::thread::scope(|s| {
        for w in 0..workers {
            let router = &router;
            s.spawn(move || {
                let mut local_lat =
                    Reservoir::new(sh.per_worker_cap, sh.cfg.seed ^ (w as u64 + 1));
                let home = w % router.shards();
                let mut bo = None;
                loop {
                    // Supervision: one claim-and-serve round per
                    // catch_unwind, so a panic (injected kill or real
                    // bug) costs at most the batch in flight — the run
                    // guard requeues the rest — and the worker resumes
                    // in place.
                    let round = catch_unwind(AssertUnwindSafe(|| {
                        crate::failpoint!(KvWorkerLoop);
                        match router.claim_from(home) {
                            Some((shard, stolen, mut run)) => {
                                bo = None; // contention cleared; restart adaptation
                                sh.claim_runs.fetch_add(1, Ordering::Relaxed);
                                if stolen {
                                    sh.steal_runs.fetch_add(1, Ordering::Relaxed);
                                }
                                sh.shard_batches[shard]
                                    .fetch_add(run.len() as u64, Ordering::Relaxed);
                                // Serve the whole run while holding the
                                // claim: per-producer order across runs
                                // depends on run-at-a-time service.
                                for batch in run.drain() {
                                    sh.serve(w, &mut local_lat, batch);
                                }
                                false
                            }
                            None => {
                                // Ordering: Acquire — pairs with the
                                // coordinator's Release store: every
                                // admitted batch happens-before `done`, so
                                // done + all-idle means all served.
                                if sh.done.load(Ordering::Acquire) && router.all_idle() {
                                    return true;
                                }
                                snooze_lazy(&mut bo);
                                false
                            }
                        }
                    }));
                    match round {
                        Ok(true) => break,
                        Ok(false) => {}
                        Err(_) => sh.note_panic(),
                    }
                }
                lock_ignore_poison(&sh.reservoirs).push(local_lat);
            });
        }

        let t0 = Instant::now();
        let producers: Vec<_> = (0..clients)
            .map(|c| {
                let router = &router;
                s.spawn(move || {
                    let stream_len = sh.stream.len().max(1);
                    let mut cursor = (stream_len / clients) * c % stream_len;
                    let (mut enq, mut shed, mut waits) = (0u64, 0u64, 0u64);
                    let mut per_shard: Vec<Vec<GenOp>> =
                        (0..router.shards()).map(|_| Vec::new()).collect();
                    while t0.elapsed() < sh.cfg.duration {
                        // Decode: cut the batch into per-shard
                        // sub-batches by key hash.
                        for op in next_batch(sh.stream, &mut cursor, sh.cfg.batch) {
                            per_shard[router.shard_of_key(op.key)].push(op);
                        }
                        let stamp = Instant::now();
                        for (shard, buf) in per_shard.iter_mut().enumerate() {
                            if buf.is_empty() {
                                continue;
                            }
                            let sub = std::mem::take(buf);
                            enq += 1; // offered (conservation numerator)
                            match admit(router.queue(shard), sh.cfg.admission, (stamp, sub)) {
                                Admitted::Enqueued { waited, .. } => waits += waited as u64,
                                Admitted::Shed(_) => shed += 1,
                            }
                        }
                    }
                    sh.enqueued.fetch_add(enq, Ordering::Relaxed);
                    sh.shed.fetch_add(shed, Ordering::Relaxed);
                    sh.admit_waits.fetch_add(waits, Ordering::Relaxed);
                })
            })
            .collect();
        for p in producers {
            // A producer panic is reported, not propagated: the workers
            // still drain everything the producer did admit.
            if p.join().is_err() {
                sh.note_panic();
            }
        }
        // Ordering: Release — every admitted push above happens-before a
        // worker observes the shutdown flag.
        sh.done.store(true, Ordering::Release);
        t0.elapsed()
    });
    // Workers have joined (scope end): the requeue/takeover tallies are
    // final. Flushed here because the router dies with this frame.
    sh.requeued.store(router.requeued(), Ordering::Relaxed);
    sh.lease_takeovers.store(router.lease_takeovers(), Ordering::Relaxed);
    elapsed
}

/// The mailbox baseline arm: bounded per-worker mailboxes fed
/// round-robin by the clients (with the sibling-scan fix), drained and
/// stolen on shutdown.
fn run_mailbox(sh: &Shared<'_>, workers: usize, clients: usize) -> Duration {
    let mailboxes: Vec<Mailbox> = (0..workers).map(|_| Mailbox::new()).collect();
    std::thread::scope(|s| {
        for w in 0..workers {
            let mailboxes = &mailboxes;
            s.spawn(move || {
                let mut local_lat =
                    Reservoir::new(sh.per_worker_cap, sh.cfg.seed ^ (w as u64 + 1));
                // Serve the own mailbox until shutdown... (supervised:
                // a panic mid-batch is counted and the worker resumes).
                loop {
                    let round = catch_unwind(AssertUnwindSafe(|| {
                        crate::failpoint!(KvWorkerLoop);
                        match mailboxes[w].pop(&sh.done) {
                            Some(batch) => {
                                sh.serve(w, &mut local_lat, batch);
                                false
                            }
                            None => true,
                        }
                    }));
                    match round {
                        Ok(true) => break,
                        Ok(false) => {}
                        Err(_) => sh.note_panic(),
                    }
                }
                // ...then drain-and-steal so no sibling strands work
                // (same supervision: a panicking steal round retries).
                loop {
                    let round = catch_unwind(AssertUnwindSafe(|| {
                        let mut got = false;
                        for mb in mailboxes.iter() {
                            while let Some(batch) = mb.steal() {
                                sh.serve(w, &mut local_lat, batch);
                                got = true;
                            }
                        }
                        got
                    }));
                    match round {
                        Ok(false) => break,
                        Ok(true) => {}
                        Err(_) => sh.note_panic(),
                    }
                }
                lock_ignore_poison(&sh.reservoirs).push(local_lat);
            });
        }

        let t0 = Instant::now();
        let producers: Vec<_> = (0..clients)
            .map(|c| {
                let mailboxes = &mailboxes;
                s.spawn(move || {
                    let stream_len = sh.stream.len().max(1);
                    let mut cursor = (stream_len / clients) * c % stream_len;
                    let mut rr = c;
                    let mut enq = 0u64;
                    while t0.elapsed() < sh.cfg.duration {
                        let batch = next_batch(sh.stream, &mut cursor, sh.cfg.batch);
                        push_to_first_free(mailboxes, rr % workers, (Instant::now(), batch));
                        enq += 1;
                        rr += 1;
                    }
                    sh.enqueued.fetch_add(enq, Ordering::Relaxed);
                })
            })
            .collect();
        for p in producers {
            if p.join().is_err() {
                sh.note_panic();
            }
        }
        // Ordering: Release — every push above happens-before a worker
        // observes the shutdown flag.
        sh.done.store(true, Ordering::Release);
        for mb in &mailboxes {
            mb.wake_all();
        }
        t0.elapsed()
    })
}

/// Run the service; `runtime` enables artifact-backed generation and the
/// HLO stats summary.
pub fn run(cfg: &KvConfig, runtime: Option<&Runtime>) -> Result<KvReport> {
    let cap = if cfg.initial_capacity > 0 {
        cfg.initial_capacity
    } else {
        cfg.n
    };
    let table: CacheHash<CachedMemEff<LinkVal>> = CacheHash::new(cap);
    let initial_buckets = table.capacity();
    // Warm the table to ~half occupancy (undersized tables grow here
    // already — and keep growing under the serving load below).
    for rank in (0..cfg.n).step_by(2) {
        table.insert(crate::util::rng::mix64(rank as u64), rank as u64);
    }

    let spec = WorkloadSpec {
        n: cfg.n,
        theta: cfg.theta,
        update_pct: cfg.update_pct,
        seed: cfg.seed,
    };

    // Pre-generate the request stream (client-side, pre-clock), via the
    // AOT artifact when available.
    let engine = match runtime {
        Some(rt) => Some(crate::runtime::workload_gen::WorkloadEngine::new(rt)?),
        None => None,
    };
    let stream_len = (cfg.batch * 256).max(1 << 15);
    let stream: Vec<GenOp> = match &engine {
        Some(e) => e.generate(&spec, stream_len, 0)?,
        None => generate_rust(&spec, stream_len, 0),
    };

    let workers = cfg.workers.clamp(1, MAX_SERVICE_WORKERS);
    let clients = cfg.clients.clamp(1, MAX_SERVICE_CLIENTS);
    let nshards = if cfg.shards == 0 {
        workers.next_power_of_two().min(MAX_SHARDS)
    } else {
        cfg.shards.next_power_of_two().min(4 * MAX_SHARDS)
    };
    // Bounded raw-sample retention: each worker reservoir-samples the
    // batches it serves; the per-worker reservoirs are merged at
    // shutdown weighted by each worker's seen count.
    let per_worker_cap = (cfg.reservoir.max(1)).div_ceil(workers);

    let sh = Shared {
        cfg,
        table: &table,
        stream: &stream,
        per_worker_cap,
        finds: AtomicU64::new(0),
        inserts: AtomicU64::new(0),
        deletes: AtomicU64::new(0),
        served: AtomicU64::new(0),
        lat_stats: StatsCell::new(),
        // Run-local latency histogram: sees *every* per-request sample
        // (unlike the reservoir) and backs the native quantile summary
        // in runs without the PJRT stats artifact.
        lat_hist: Histogram::new(),
        active: AtomicU64::new(0),
        peak_active: AtomicU64::new(0),
        batch_counts: (0..workers).map(|_| AtomicU64::new(0)).collect(),
        shard_batches: match cfg.ingress {
            IngressMode::Lockfree => (0..nshards).map(|_| AtomicU64::new(0)).collect(),
            IngressMode::Mailbox => Vec::new(),
        },
        enqueued: AtomicU64::new(0),
        shed: AtomicU64::new(0),
        admit_waits: AtomicU64::new(0),
        claim_runs: AtomicU64::new(0),
        steal_runs: AtomicU64::new(0),
        worker_panics: AtomicU64::new(0),
        abandoned: AtomicU64::new(0),
        requeued: AtomicU64::new(0),
        lease_takeovers: AtomicU64::new(0),
        reservoirs: Mutex::new(Vec::new()),
        done: AtomicBool::new(false),
    };

    let elapsed = match cfg.ingress {
        IngressMode::Lockfree => run_lockfree(&sh, workers, clients, nshards),
        IngressMode::Mailbox => run_mailbox(&sh, workers, clients),
    };

    let (_seen, lat_samples) = merge_reservoirs(
        sh.reservoirs
            .into_inner()
            .unwrap_or_else(PoisonError::into_inner),
        cfg.reservoir.max(1),
        cfg.seed,
    );
    let hist = sh.lat_hist.snapshot();
    let latency = match runtime {
        Some(rt) if !lat_samples.is_empty() => Some(rt.stats_engine()?.summarize(&lat_samples)?),
        // No stats artifact: summarize natively from the histogram,
        // which saw every sample (quantile error ≤ one sub-bucket).
        _ if hist.count > 0 => Some(LatencySummary {
            mean: hist.mean() as f32,
            p50: hist.p50() as f32,
            p90: hist.p90() as f32,
            p99: hist.p99() as f32,
            max: hist.max as f32,
        }),
        _ => None,
    };

    Ok(KvReport {
        total_requests: sh.served.load(Ordering::SeqCst),
        elapsed,
        finds: sh.finds.load(Ordering::SeqCst),
        inserts: sh.inserts.load(Ordering::SeqCst),
        deletes: sh.deletes.load(Ordering::SeqCst),
        latency,
        latency_p999_ns: if hist.count > 0 { Some(hist.p999()) } else { None },
        sample_count: hist.count as usize,
        retained_samples: lat_samples.len(),
        latency_stats: sh.lat_stats.snapshot(),
        worker_batches: sh.batch_counts.iter().map(|c| c.load(Ordering::SeqCst)).collect(),
        peak_concurrent_workers: sh.peak_active.load(Ordering::SeqCst),
        initial_buckets,
        final_buckets: table.capacity(),
        ingress: cfg.ingress.name(),
        enqueued_batches: sh.enqueued.load(Ordering::SeqCst),
        shed_batches: sh.shed.load(Ordering::SeqCst),
        admit_waits: sh.admit_waits.load(Ordering::SeqCst),
        claim_runs: sh.claim_runs.load(Ordering::SeqCst),
        steal_runs: sh.steal_runs.load(Ordering::SeqCst),
        shard_batches: sh.shard_batches.iter().map(|c| c.load(Ordering::SeqCst)).collect(),
        worker_panics: sh.worker_panics.load(Ordering::SeqCst),
        requeued_batches: sh.requeued.load(Ordering::SeqCst),
        abandoned_batches: sh.abandoned.load(Ordering::SeqCst),
        lease_takeovers: sh.lease_takeovers.load(Ordering::SeqCst),
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Conservation: every offered batch is exactly one of served,
    /// shed, or abandoned-to-a-fault, in every report of every arm.
    /// Without `--features fault` abandonment is impossible, and these
    /// tests also pin that no worker panicked.
    fn assert_conservation(rep: &KvReport) {
        assert_eq!(
            rep.enqueued_batches,
            rep.sample_count as u64 + rep.shed_batches + rep.abandoned_batches,
            "lost or duplicated batches: {rep:?}"
        );
        #[cfg(not(feature = "fault"))]
        {
            assert_eq!(rep.worker_panics, 0, "worker panicked without faults: {rep:?}");
            assert_eq!(rep.abandoned_batches, 0, "abandoned without faults: {rep:?}");
        }
    }

    #[test]
    fn test_kv_service_smoke_rust_gen() {
        let cfg = KvConfig {
            n: 1024,
            workers: 2,
            batch: 64,
            duration: Duration::from_millis(100),
            update_pct: 30,
            theta: 0.5,
            seed: 7,
            initial_capacity: 0,
            reservoir: DEFAULT_RESERVOIR,
            ..KvConfig::default()
        };
        let rep = run(&cfg, None).unwrap();
        assert_eq!(rep.ingress, "lockfree");
        assert!(rep.total_requests > 100, "{rep:?}");
        // Satellite: without the PJRT stats artifact the summary must
        // still be present, computed natively from the histogram.
        let lat = rep.latency.as_ref().expect("native latency summary");
        assert!(lat.p50 <= lat.p90 && lat.p90 <= lat.p99);
        assert!(lat.p99 as u64 <= rep.latency_p999_ns.unwrap());
        assert!(lat.max >= lat.p99);
        assert_eq!(rep.total_requests, rep.finds + rep.inserts + rep.deletes);
        // ~30% updates
        let upd = (rep.inserts + rep.deletes) as f64 / rep.total_requests as f64;
        assert!((upd - 0.30).abs() < 0.05, "update frac {upd}");
        // The fetch_update stats cell saw every batch, consistently.
        assert_eq!(rep.latency_stats.count as usize, rep.sample_count);
        if rep.latency_stats.count > 0 {
            let mean = rep.latency_stats.mean().unwrap();
            assert!(rep.latency_stats.min as f64 <= mean && mean <= rep.latency_stats.max as f64);
        }
        // Every batch is accounted to exactly one worker, and the
        // ingress conserved the stream.
        assert_eq!(rep.worker_batches.len(), 2);
        assert_eq!(rep.worker_batches.iter().sum::<u64>() as usize, rep.sample_count);
        assert_eq!(rep.shed_batches, 0, "Wait policy shed: {rep:?}");
        assert_conservation(&rep);
        assert_eq!(
            rep.shard_batches.iter().sum::<u64>() as usize,
            rep.sample_count,
            "shard accounting mismatch"
        );
    }

    #[test]
    fn test_kv_workers_serve_concurrently_and_table_grows() {
        // Regression for the shared Mutex<Receiver> dequeue: with
        // per-worker mailboxes every worker must serve batches, and at
        // least two must be observed mid-batch simultaneously. The
        // undersized table must also grow under live traffic. (Pinned
        // to the mailbox baseline: the lock-free arm hands whole runs
        // to one drainer at a time, so "every worker served" is not its
        // contract — per-shard progress is, tested below.)
        let cfg = KvConfig {
            n: 1 << 12,
            workers: 4,
            batch: 256,
            duration: Duration::from_millis(250),
            update_pct: 50,
            theta: 0.0,
            seed: 9,
            initial_capacity: 64,
            // Tiny bound: the retained raw samples must be capped while
            // sample_count stays exact.
            reservoir: 8,
            ingress: IngressMode::Mailbox,
            ..KvConfig::default()
        };
        let rep = run(&cfg, None).unwrap();
        assert_eq!(rep.ingress, "mailbox");
        assert_eq!(rep.worker_batches.len(), 4);
        assert!(
            rep.worker_batches.iter().all(|&b| b > 0),
            "a worker served nothing: {:?}",
            rep.worker_batches
        );
        assert!(
            rep.peak_concurrent_workers >= 2,
            "workers serialized: peak {}",
            rep.peak_concurrent_workers
        );
        // The weighted merge caps the retained samples at the
        // configured bound while the exact count keeps every batch.
        assert!(
            rep.retained_samples <= 8,
            "reservoir overflowed: {} retained",
            rep.retained_samples
        );
        assert!(rep.sample_count >= rep.retained_samples);
        assert_eq!(rep.latency_stats.count as usize, rep.sample_count);
        assert_eq!(rep.initial_buckets, 64);
        assert!(
            rep.final_buckets > rep.initial_buckets,
            "undersized table never grew: {} -> {}",
            rep.initial_buckets,
            rep.final_buckets
        );
        assert_eq!(rep.total_requests, rep.finds + rep.inserts + rep.deletes);
        assert_conservation(&rep);
        assert!(rep.shard_batches.is_empty(), "mailbox arm has no shards");
    }

    #[test]
    fn test_kv_lockfree_multi_client_conservation_and_shards() {
        // The tentpole end to end: several producers, sharded claim
        // queues, exactly-one-drainer runs — nothing lost, nothing
        // double-served, every shard progressed.
        let cfg = KvConfig {
            n: 1 << 12,
            workers: 4,
            batch: 256,
            duration: Duration::from_millis(250),
            update_pct: 40,
            theta: 0.0, // uniform: every shard sees traffic
            seed: 11,
            initial_capacity: 0,
            reservoir: 64,
            ingress: IngressMode::Lockfree,
            shards: 4,
            clients: 3,
            admission: AdmissionPolicy::Wait,
            lease_ms: 0,
        };
        let rep = run(&cfg, None).unwrap();
        assert!(rep.total_requests > 500, "{rep:?}");
        assert_conservation(&rep);
        assert_eq!(rep.shed_batches, 0);
        assert!(rep.claim_runs > 0, "no run ever claimed: {rep:?}");
        assert_eq!(rep.shard_batches.len(), 4);
        assert!(
            rep.shard_batches.iter().all(|&b| b > 0),
            "a shard starved: {:?}",
            rep.shard_batches
        );
        assert_eq!(
            rep.shard_batches.iter().sum::<u64>() as usize,
            rep.sample_count
        );
        assert_eq!(rep.total_requests, rep.finds + rep.inserts + rep.deletes);
    }

    #[test]
    fn test_kv_lockfree_shed_policy_conserves() {
        // Shed admission under pressure: tiny shard count + many
        // clients force rejects; conservation must still balance
        // (enqueued == served, and attempts == enqueued + shed).
        let cfg = KvConfig {
            n: 1 << 10,
            workers: 1,
            batch: 512,
            duration: Duration::from_millis(150),
            update_pct: 50,
            theta: 0.9,
            seed: 13,
            initial_capacity: 0,
            reservoir: 32,
            ingress: IngressMode::Lockfree,
            shards: 1,
            clients: 4,
            admission: AdmissionPolicy::Shed,
            lease_ms: 0,
        };
        let rep = run(&cfg, None).unwrap();
        assert_eq!(rep.ingress, "lockfree");
        // Every admitted batch was served exactly once, independent of
        // how many were shed at the door.
        assert_conservation(&rep);
        assert_eq!(rep.admit_waits, 0, "Shed policy waited: {rep:?}");
        assert_eq!(rep.total_requests, rep.finds + rep.inserts + rep.deletes);
    }

    #[test]
    fn test_kv_lockfree_with_lease_conserves() {
        // Drainer leases on, aggressive bound: even if a slow drainer's
        // claim is taken over mid-run, nothing is double-served (the
        // displaced run's items were detached at claim time) and the
        // ledger still balances.
        let cfg = KvConfig {
            n: 1 << 10,
            workers: 2,
            batch: 128,
            duration: Duration::from_millis(150),
            seed: 19,
            reservoir: 32,
            ingress: IngressMode::Lockfree,
            shards: 2,
            clients: 2,
            lease_ms: 1,
            ..KvConfig::default()
        };
        let rep = run(&cfg, None).unwrap();
        assert_conservation(&rep);
        assert_eq!(rep.total_requests, rep.finds + rep.inserts + rep.deletes);
    }

    #[test]
    fn test_kv_oversubscribed_workers_progress_on_every_shard() {
        // Oversubscription smoke (the paper's headline regime): workers
        // at 4x the hardware parallelism, all shards must still make
        // progress and conservation must hold. Capped to stay inside
        // the thread registry (MAX_THREADS = 256).
        let par = std::thread::available_parallelism().map(|p| p.get()).unwrap_or(4);
        // min(96): leave registry headroom for tests running in
        // parallel in the same binary on very wide machines.
        let workers = (4 * par).min(96);
        let cfg = KvConfig {
            n: 1 << 12,
            workers,
            batch: 256,
            duration: Duration::from_millis(300),
            update_pct: 30,
            theta: 0.0,
            seed: 17,
            initial_capacity: 0,
            reservoir: 128,
            ingress: IngressMode::Lockfree,
            shards: 8,
            clients: 4,
            admission: AdmissionPolicy::Wait,
            lease_ms: 0,
        };
        let rep = run(&cfg, None).unwrap();
        assert_eq!(rep.worker_batches.len(), workers);
        assert_eq!(rep.shard_batches.len(), 8);
        assert!(
            rep.shard_batches.iter().all(|&b| b > 0),
            "a shard starved under oversubscription: {:?}",
            rep.shard_batches
        );
        assert_conservation(&rep);
        assert!(rep.total_requests > 0);
    }
}
