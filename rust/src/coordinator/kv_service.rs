//! A miniature KV service over CacheHash — the end-to-end driver.
//!
//! Shape: a leader thread generates request batches (via the AOT
//! workload artifact when available), pushes them through a bounded
//! queue to worker threads that execute them against a shared
//! `CacheHash<CachedMemEff>` table, and collects per-batch latencies.
//! The latency summary is computed by the `stats.hlo.txt` artifact
//! (the L2 stats model) when a runtime is supplied.
//!
//! This is deliberately the whole stack in one loop: L1/L2 artifacts →
//! PJRT runtime → big atomics → CacheHash → throughput/latency report
//! (recorded in EXPERIMENTS.md §End-to-end).

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::mpsc::{sync_channel, Receiver};
use std::sync::Mutex;
use std::time::{Duration, Instant};

use crate::apps::stats::{Snapshot, StatsCell};
use crate::atomics::CachedMemEff;
use crate::bench::workload::{generate_rust, GenOp, Op, WorkloadSpec};
use crate::hash::{CacheHash, ConcurrentMap, LinkVal};
use crate::runtime::{LatencySummary, Runtime};
use crate::util::error::Result;

#[derive(Clone, Debug)]
pub struct KvConfig {
    /// Key-space / table size.
    pub n: usize,
    /// Worker threads serving requests.
    pub workers: usize,
    /// Requests per batch (one queue message).
    pub batch: usize,
    /// Total run duration.
    pub duration: Duration,
    pub update_pct: u32,
    pub theta: f64,
    pub seed: u64,
}

impl Default for KvConfig {
    fn default() -> Self {
        Self {
            n: 1 << 16,
            workers: 4,
            batch: 512,
            duration: Duration::from_secs(2),
            update_pct: 30,
            theta: 0.5,
            seed: 0x4B56, // "KV"
        }
    }
}

#[derive(Debug)]
pub struct KvReport {
    pub total_requests: u64,
    pub elapsed: Duration,
    pub finds: u64,
    pub inserts: u64,
    pub deletes: u64,
    pub latency: Option<LatencySummary>,
    /// Raw per-request latency samples (ns), for offline analysis.
    pub sample_count: usize,
    /// Always-consistent (count, sum, min, max) of the per-request
    /// latency (ns), accumulated by every worker through one big-atomic
    /// `fetch_update` cell — no lock, no torn snapshot, no artifacts
    /// needed.
    pub latency_stats: Snapshot,
}

impl KvReport {
    pub fn mops(&self) -> f64 {
        self.total_requests as f64 / self.elapsed.as_secs_f64() / 1e6
    }
}

/// Run the service; `runtime` enables artifact-backed generation and the
/// HLO stats summary.
pub fn run(cfg: &KvConfig, runtime: Option<&Runtime>) -> Result<KvReport> {
    let table: CacheHash<CachedMemEff<LinkVal>> = CacheHash::new(cfg.n);
    // Warm the table to ~half occupancy.
    for rank in (0..cfg.n).step_by(2) {
        table.insert(crate::util::rng::mix64(rank as u64), rank as u64);
    }

    let spec = WorkloadSpec {
        n: cfg.n,
        theta: cfg.theta,
        update_pct: cfg.update_pct,
        seed: cfg.seed,
    };

    // Pre-generate the request stream (leader-side, pre-clock), via the
    // AOT artifact when available.
    let engine = match runtime {
        Some(rt) => Some(crate::runtime::workload_gen::WorkloadEngine::new(rt)?),
        None => None,
    };
    let stream_len = (cfg.batch * 256).max(1 << 15);
    let stream: Vec<GenOp> = match &engine {
        Some(e) => e.generate(&spec, stream_len, 0)?,
        None => generate_rust(&spec, stream_len, 0),
    };

    let finds = AtomicU64::new(0);
    let lat_stats: StatsCell<CachedMemEff<Snapshot>> = StatsCell::new();
    let inserts = AtomicU64::new(0);
    let deletes = AtomicU64::new(0);
    let served = AtomicU64::new(0);
    let latencies: Mutex<Vec<f32>> = Mutex::new(Vec::new());

    let (tx, rx) = sync_channel::<(Instant, Vec<GenOp>)>(cfg.workers * 4);
    let rx = Mutex::new(rx);
    let elapsed = std::thread::scope(|s| {

        for _ in 0..cfg.workers {
            let rx: &Mutex<Receiver<(Instant, Vec<GenOp>)>> = &rx;
            let table = &table;
            let finds = &finds;
            let inserts = &inserts;
            let deletes = &deletes;
            let served = &served;
            let latencies = &latencies;
            let lat_stats = &lat_stats;
            s.spawn(move || {
                let mut local_lat: Vec<f32> = Vec::new();
                loop {
                    let msg = { rx.lock().unwrap().recv() };
                    let Ok((enqueued, batch)) = msg else { break };
                    for req in &batch {
                        match req.op {
                            Op::Find => {
                                std::hint::black_box(table.find(req.key));
                                finds.fetch_add(1, Ordering::Relaxed);
                            }
                            Op::Insert => {
                                table.insert(req.key, req.rank as u64);
                                inserts.fetch_add(1, Ordering::Relaxed);
                            }
                            Op::Delete => {
                                table.remove(req.key);
                                deletes.fetch_add(1, Ordering::Relaxed);
                            }
                        }
                    }
                    served.fetch_add(batch.len() as u64, Ordering::Relaxed);
                    // Per-request latency ≈ (queueing + service) / batch.
                    let total_ns = enqueued.elapsed().as_nanos() as f32;
                    let per_req = total_ns / batch.len() as f32;
                    local_lat.push(per_req);
                    lat_stats.record(per_req as u64);
                }
                latencies.lock().unwrap().extend(local_lat);
            });
        }

        // Leader: feed batches for the configured duration.
        let t0 = Instant::now();
        let mut cursor = 0usize;
        while t0.elapsed() < cfg.duration {
            let batch: Vec<GenOp> = stream[cursor..]
                .iter()
                .chain(stream.iter())
                .take(cfg.batch)
                .copied()
                .collect();
            cursor = (cursor + cfg.batch) % stream.len();
            if tx.send((Instant::now(), batch)).is_err() {
                break;
            }
        }
        drop(tx); // close the queue; workers drain and exit
        t0.elapsed()
    });

    let lat_samples = latencies.into_inner().unwrap();
    let latency = match runtime {
        Some(rt) if !lat_samples.is_empty() => Some(rt.stats_engine()?.summarize(&lat_samples)?),
        _ => None,
    };

    Ok(KvReport {
        total_requests: served.load(Ordering::SeqCst),
        elapsed,
        finds: finds.load(Ordering::SeqCst),
        inserts: inserts.load(Ordering::SeqCst),
        deletes: deletes.load(Ordering::SeqCst),
        latency,
        sample_count: lat_samples.len(),
        latency_stats: lat_stats.snapshot(),
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn test_kv_service_smoke_rust_gen() {
        let cfg = KvConfig {
            n: 1024,
            workers: 2,
            batch: 64,
            duration: Duration::from_millis(100),
            update_pct: 30,
            theta: 0.5,
            seed: 7,
        };
        let rep = run(&cfg, None).unwrap();
        assert!(rep.total_requests > 100, "{rep:?}");
        assert_eq!(
            rep.total_requests,
            rep.finds + rep.inserts + rep.deletes
        );
        // ~30% updates
        let upd = (rep.inserts + rep.deletes) as f64 / rep.total_requests as f64;
        assert!((upd - 0.30).abs() < 0.05, "update frac {upd}");
        // The fetch_update stats cell saw every batch, consistently.
        assert_eq!(rep.latency_stats.count as usize, rep.sample_count);
        if rep.latency_stats.count > 0 {
            let mean = rep.latency_stats.mean().unwrap();
            assert!(rep.latency_stats.min as f64 <= mean && mean <= rep.latency_stats.max as f64);
        }
    }
}
