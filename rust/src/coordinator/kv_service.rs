//! A miniature KV service over CacheHash — the end-to-end driver.
//!
//! Shape: a leader thread generates request batches (via the AOT
//! workload artifact when available) and feeds them **round-robin into
//! per-worker bounded mailboxes**; workers execute them against a shared
//! `CacheHash<CachedMemEff>` table and collect per-batch latencies.
//! The seed instead pushed every batch through one shared
//! `Mutex<Receiver>` whose guard was held across a *blocking* `recv()`
//! — serializing all workers on a single dequeue and wedging idle
//! workers behind a blocked one. With per-worker queues the only shared
//! structure is the table itself; on shutdown each worker drains its own
//! mailbox and then steals siblings' leftovers, so one slow worker
//! cannot strand batches. The report carries per-worker batch counts
//! and the observed peak service concurrency so the fan-out is a
//! number, not a hope.
//!
//! The table may be constructed deliberately undersized
//! ([`KvConfig::initial_capacity`]) to exercise the online-resize path
//! end to end: the warm fill and the serving inserts drive the table
//! through its doublings while finds stream lock-free.
//!
//! The latency summary is computed by the `stats.hlo.txt` artifact
//! (the L2 stats model) when a runtime is supplied.
//!
//! This is deliberately the whole stack in one loop: L1/L2 artifacts →
//! PJRT runtime → big atomics → CacheHash → throughput/latency report
//! (recorded in EXPERIMENTS.md §End-to-end).

use std::collections::VecDeque;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Condvar, Mutex};
use std::time::{Duration, Instant};

use crate::apps::stats::{Snapshot, StatsCell};
use crate::atomics::CachedMemEff;
use crate::bench::workload::{generate_rust, GenOp, Op, WorkloadSpec};
use crate::hash::{CacheHash, ConcurrentMap, LinkVal};
use crate::obs::Histogram;
use crate::runtime::{LatencySummary, Runtime};
use crate::util::error::Result;
use crate::util::rng::Xoshiro256;

#[derive(Clone, Debug)]
pub struct KvConfig {
    /// Key-space size.
    pub n: usize,
    /// Worker threads serving requests.
    pub workers: usize,
    /// Requests per batch (one mailbox message).
    pub batch: usize,
    /// Total run duration.
    pub duration: Duration,
    pub update_pct: u32,
    pub theta: f64,
    pub seed: u64,
    /// Initial table capacity; 0 ⇒ sized for `n`. Set small (e.g. 64)
    /// to serve from a deliberately undersized table and exercise
    /// online growth under live traffic.
    pub initial_capacity: usize,
    /// Bound on the raw latency samples retained for offline analysis
    /// (reservoir-sampled across the run; 0 ⇒ the default bound). The
    /// exact per-batch summary ([`KvReport::latency_stats`] and the
    /// histogram-backed quantiles) always sees every sample — only the
    /// raw-sample vector is bounded.
    pub reservoir: usize,
}

/// Default [`KvConfig::reservoir`] bound.
pub const DEFAULT_RESERVOIR: usize = 4096;

impl Default for KvConfig {
    fn default() -> Self {
        Self {
            n: 1 << 16,
            workers: 4,
            batch: 512,
            duration: Duration::from_secs(2),
            update_pct: 30,
            theta: 0.5,
            seed: 0x4B56, // "KV"
            initial_capacity: 0,
            reservoir: DEFAULT_RESERVOIR,
        }
    }
}

/// Bounded uniform sample of a stream (Vitter's Algorithm R): the
/// first `cap` values fill the buffer; the `t`-th value thereafter
/// replaces a random slot with probability `cap/t`. Memory is O(cap)
/// regardless of run length — the fix for the old unbounded per-request
/// `Vec` that grew with duration.
struct Reservoir {
    cap: usize,
    seen: u64,
    samples: Vec<f32>,
    rng: Xoshiro256,
}

impl Reservoir {
    fn new(cap: usize, seed: u64) -> Self {
        let cap = cap.max(1);
        Self {
            cap,
            seen: 0,
            samples: Vec::with_capacity(cap.min(DEFAULT_RESERVOIR)),
            rng: Xoshiro256::seeded(seed),
        }
    }

    fn push(&mut self, v: f32) {
        self.seen += 1;
        if self.samples.len() < self.cap {
            self.samples.push(v);
        } else {
            let j = self.rng.next_below(self.seen as usize);
            if j < self.cap {
                self.samples[j] = v;
            }
        }
    }
}

#[derive(Debug)]
pub struct KvReport {
    pub total_requests: u64,
    pub elapsed: Duration,
    pub finds: u64,
    pub inserts: u64,
    pub deletes: u64,
    pub latency: Option<LatencySummary>,
    /// p99.9 of the per-request latency (ns), from the lock-free
    /// log-linear histogram that sees every sample (not the bounded
    /// reservoir); `None` only when no batch completed.
    pub latency_p999_ns: Option<u64>,
    /// Exact number of per-batch latency samples observed (== batches
    /// served). The *retained* raw-sample vector is reservoir-bounded
    /// ([`KvConfig::reservoir`]), but this count, `latency_stats`, and
    /// the histogram quantiles are computed over every sample.
    pub sample_count: usize,
    /// Raw samples actually retained after reservoir sampling
    /// (≤ ~[`KvConfig::reservoir`], and < `sample_count` on long runs).
    pub retained_samples: usize,
    /// Always-consistent (count, sum, min, max) of the per-request
    /// latency (ns), accumulated by every worker through one big-atomic
    /// `fetch_update` cell — no lock, no torn snapshot, no artifacts
    /// needed.
    pub latency_stats: Snapshot,
    /// Batches served by each worker (all > 0 ⇔ the fan-out fanned out).
    pub worker_batches: Vec<u64>,
    /// Maximum number of workers observed mid-batch simultaneously.
    pub peak_concurrent_workers: u64,
    /// Table buckets at construction / after the run (growth proof when
    /// `initial_capacity` undersizes the table).
    pub initial_buckets: usize,
    pub final_buckets: usize,
}

impl KvReport {
    pub fn mops(&self) -> f64 {
        self.total_requests as f64 / self.elapsed.as_secs_f64() / 1e6
    }
}

/// Batches buffered per worker mailbox before the leader blocks.
const MAILBOX_CAP: usize = 8;

type Batch = (Instant, Vec<GenOp>);

/// One worker's bounded mailbox. The leader's bounded `push` and the
/// worker's blocking `pop` meet on one short-held mutex; `steal` is the
/// shutdown-drain path for siblings.
struct Mailbox {
    q: Mutex<VecDeque<Batch>>,
    /// Batch arrived (or shutdown flagged).
    ready: Condvar,
    /// Space freed.
    space: Condvar,
}

impl Mailbox {
    fn new() -> Self {
        Self {
            q: Mutex::new(VecDeque::with_capacity(MAILBOX_CAP)),
            ready: Condvar::new(),
            space: Condvar::new(),
        }
    }

    /// Leader side: blocking bounded push.
    fn push(&self, item: Batch) {
        let mut q = self.q.lock().unwrap();
        while q.len() >= MAILBOX_CAP {
            q = self.space.wait(q).unwrap();
        }
        q.push_back(item);
        // Leader-side gauge: mailbox depth right after the enqueue (the
        // global histogram is always-on; one fetch_add, off the worker
        // hot path).
        crate::obs::KV_QUEUE_DEPTH.record(q.len() as u64);
        drop(q);
        self.ready.notify_one();
    }

    /// Owner side: pop, blocking until a batch arrives; `None` once the
    /// mailbox is empty and shutdown is flagged.
    fn pop(&self, done: &AtomicBool) -> Option<Batch> {
        let mut q = self.q.lock().unwrap();
        loop {
            if let Some(item) = q.pop_front() {
                drop(q);
                self.space.notify_one();
                return Some(item);
            }
            // Ordering: Acquire — pairs with the leader's Release store
            // so every pre-shutdown push is visible before we give up.
            if done.load(Ordering::Acquire) {
                return None;
            }
            q = self.ready.wait(q).unwrap();
        }
    }

    /// Shutdown drain: non-blocking steal by a sibling.
    fn steal(&self) -> Option<Batch> {
        let item = self.q.lock().unwrap().pop_front();
        if item.is_some() {
            crate::counter!(KvSteal);
            self.space.notify_one();
        }
        item
    }

    /// Shutdown wakeup. Must take the mailbox mutex: `pop`'s
    /// check-empty-then-park is atomic only under that lock (Condvar
    /// wait releases it when parking), so a bare `notify_all` could
    /// land between a worker's `done` check and its park and be lost
    /// forever — the classic lost-wakeup deadlock.
    fn wake_all(&self) {
        let _q = self.q.lock().unwrap();
        self.ready.notify_all();
    }
}

/// Run the service; `runtime` enables artifact-backed generation and the
/// HLO stats summary.
pub fn run(cfg: &KvConfig, runtime: Option<&Runtime>) -> Result<KvReport> {
    let cap = if cfg.initial_capacity > 0 {
        cfg.initial_capacity
    } else {
        cfg.n
    };
    let table: CacheHash<CachedMemEff<LinkVal>> = CacheHash::new(cap);
    let initial_buckets = table.capacity();
    // Warm the table to ~half occupancy (undersized tables grow here
    // already — and keep growing under the serving load below).
    for rank in (0..cfg.n).step_by(2) {
        table.insert(crate::util::rng::mix64(rank as u64), rank as u64);
    }

    let spec = WorkloadSpec {
        n: cfg.n,
        theta: cfg.theta,
        update_pct: cfg.update_pct,
        seed: cfg.seed,
    };

    // Pre-generate the request stream (leader-side, pre-clock), via the
    // AOT artifact when available.
    let engine = match runtime {
        Some(rt) => Some(crate::runtime::workload_gen::WorkloadEngine::new(rt)?),
        None => None,
    };
    let stream_len = (cfg.batch * 256).max(1 << 15);
    let stream: Vec<GenOp> = match &engine {
        Some(e) => e.generate(&spec, stream_len, 0)?,
        None => generate_rust(&spec, stream_len, 0),
    };

    let workers = cfg.workers.max(1);
    let finds = AtomicU64::new(0);
    let lat_stats: StatsCell<CachedMemEff<Snapshot>> = StatsCell::new();
    let inserts = AtomicU64::new(0);
    let deletes = AtomicU64::new(0);
    let served = AtomicU64::new(0);
    // Bounded raw-sample retention: each worker reservoir-samples its
    // own share of the stream (the leader round-robins batches, so the
    // shares are near-equal and the concatenation approximates one
    // uniform sample of the whole run), merged here at shutdown.
    let per_worker_cap = ((cfg.reservoir.max(1)) + workers - 1) / workers;
    let latencies: Mutex<Vec<f32>> = Mutex::new(Vec::new());
    // Run-local latency histogram: sees *every* per-request sample
    // (unlike the reservoir) and backs the native quantile summary in
    // runs without the PJRT stats artifact.
    let lat_hist = Histogram::new();
    let mailboxes: Vec<Mailbox> = (0..workers).map(|_| Mailbox::new()).collect();
    let done = AtomicBool::new(false);
    let active = AtomicU64::new(0);
    let peak_active = AtomicU64::new(0);
    let batch_counts: Vec<AtomicU64> = (0..workers).map(|_| AtomicU64::new(0)).collect();

    let elapsed = std::thread::scope(|s| {
        for w in 0..workers {
            let mailboxes = &mailboxes;
            let done = &done;
            let active = &active;
            let peak_active = &peak_active;
            let batch_counts = &batch_counts;
            let table = &table;
            let finds = &finds;
            let inserts = &inserts;
            let deletes = &deletes;
            let served = &served;
            let latencies = &latencies;
            let lat_stats = &lat_stats;
            let lat_hist = &lat_hist;
            s.spawn(move || {
                let mut local_lat = Reservoir::new(per_worker_cap, cfg.seed ^ (w as u64 + 1));
                let mut serve = |(enqueued, batch): Batch| {
                    // Concurrency gauge: how many workers are mid-batch.
                    let now = active.fetch_add(1, Ordering::AcqRel) + 1;
                    peak_active.fetch_max(now, Ordering::AcqRel);
                    for req in &batch {
                        match req.op {
                            Op::Find => {
                                std::hint::black_box(table.find(req.key));
                                finds.fetch_add(1, Ordering::Relaxed);
                            }
                            Op::Insert => {
                                table.insert(req.key, req.rank as u64);
                                inserts.fetch_add(1, Ordering::Relaxed);
                            }
                            Op::Delete => {
                                table.remove(req.key);
                                deletes.fetch_add(1, Ordering::Relaxed);
                            }
                        }
                    }
                    served.fetch_add(batch.len() as u64, Ordering::Relaxed);
                    batch_counts[w].fetch_add(1, Ordering::Relaxed);
                    crate::counter!(KvBatch);
                    crate::counter!(KvRequest, batch.len() as u64);
                    crate::obs::KV_BATCH.record(batch.len() as u64);
                    // Per-request latency ≈ (queueing + service) / batch.
                    let total_ns = enqueued.elapsed().as_nanos() as f32;
                    let per_req = total_ns / batch.len() as f32;
                    local_lat.push(per_req);
                    lat_stats.record(per_req as u64);
                    lat_hist.record(per_req as u64);
                    crate::obs::KV_LATENCY_NS.record(per_req as u64);
                    active.fetch_sub(1, Ordering::AcqRel);
                };
                // Serve the own mailbox until shutdown...
                while let Some(batch) = mailboxes[w].pop(done) {
                    serve(batch);
                }
                // ...then drain-and-steal so no sibling strands work.
                loop {
                    let mut got = false;
                    for mb in mailboxes.iter() {
                        while let Some(batch) = mb.steal() {
                            serve(batch);
                            got = true;
                        }
                    }
                    if !got {
                        break;
                    }
                }
                latencies.lock().unwrap().extend(local_lat.samples);
            });
        }

        // Leader: feed batches round-robin for the configured duration.
        let t0 = Instant::now();
        let mut cursor = 0usize;
        let mut rr = 0usize;
        while t0.elapsed() < cfg.duration {
            let batch: Vec<GenOp> = stream[cursor..]
                .iter()
                .chain(stream.iter())
                .take(cfg.batch)
                .copied()
                .collect();
            cursor = (cursor + cfg.batch) % stream.len();
            mailboxes[rr % workers].push((Instant::now(), batch));
            rr += 1;
        }
        // Ordering: Release — every push above happens-before a worker
        // observes the shutdown flag.
        done.store(true, Ordering::Release);
        for mb in &mailboxes {
            mb.wake_all();
        }
        t0.elapsed()
    });

    let lat_samples = latencies.into_inner().unwrap();
    let hist = lat_hist.snapshot();
    let latency = match runtime {
        Some(rt) if !lat_samples.is_empty() => Some(rt.stats_engine()?.summarize(&lat_samples)?),
        // No stats artifact: summarize natively from the histogram,
        // which saw every sample (quantile error ≤ one sub-bucket).
        _ if hist.count > 0 => Some(LatencySummary {
            mean: hist.mean() as f32,
            p50: hist.p50() as f32,
            p90: hist.p90() as f32,
            p99: hist.p99() as f32,
            max: hist.max as f32,
        }),
        _ => None,
    };

    Ok(KvReport {
        total_requests: served.load(Ordering::SeqCst),
        elapsed,
        finds: finds.load(Ordering::SeqCst),
        inserts: inserts.load(Ordering::SeqCst),
        deletes: deletes.load(Ordering::SeqCst),
        latency,
        latency_p999_ns: if hist.count > 0 { Some(hist.p999()) } else { None },
        sample_count: hist.count as usize,
        retained_samples: lat_samples.len(),
        latency_stats: lat_stats.snapshot(),
        worker_batches: batch_counts.iter().map(|c| c.load(Ordering::SeqCst)).collect(),
        peak_concurrent_workers: peak_active.load(Ordering::SeqCst),
        initial_buckets,
        final_buckets: table.capacity(),
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn test_kv_service_smoke_rust_gen() {
        let cfg = KvConfig {
            n: 1024,
            workers: 2,
            batch: 64,
            duration: Duration::from_millis(100),
            update_pct: 30,
            theta: 0.5,
            seed: 7,
            initial_capacity: 0,
            reservoir: DEFAULT_RESERVOIR,
        };
        let rep = run(&cfg, None).unwrap();
        assert!(rep.total_requests > 100, "{rep:?}");
        // Satellite: without the PJRT stats artifact the summary must
        // still be present, computed natively from the histogram.
        let lat = rep.latency.as_ref().expect("native latency summary");
        assert!(lat.p50 <= lat.p90 && lat.p90 <= lat.p99);
        assert!(lat.p99 as u64 <= rep.latency_p999_ns.unwrap());
        assert!(lat.max >= lat.p99);
        assert_eq!(
            rep.total_requests,
            rep.finds + rep.inserts + rep.deletes
        );
        // ~30% updates
        let upd = (rep.inserts + rep.deletes) as f64 / rep.total_requests as f64;
        assert!((upd - 0.30).abs() < 0.05, "update frac {upd}");
        // The fetch_update stats cell saw every batch, consistently.
        assert_eq!(rep.latency_stats.count as usize, rep.sample_count);
        if rep.latency_stats.count > 0 {
            let mean = rep.latency_stats.mean().unwrap();
            assert!(rep.latency_stats.min as f64 <= mean && mean <= rep.latency_stats.max as f64);
        }
        // Every batch is accounted to exactly one worker.
        assert_eq!(rep.worker_batches.len(), 2);
        assert_eq!(
            rep.worker_batches.iter().sum::<u64>() as usize,
            rep.sample_count
        );
    }

    #[test]
    fn test_kv_workers_serve_concurrently_and_table_grows() {
        // Regression for the shared Mutex<Receiver> dequeue: with
        // per-worker mailboxes every worker must serve batches, and at
        // least two must be observed mid-batch simultaneously. The
        // undersized table must also grow under live traffic.
        let cfg = KvConfig {
            n: 1 << 12,
            workers: 4,
            batch: 256,
            duration: Duration::from_millis(250),
            update_pct: 50,
            theta: 0.0,
            seed: 9,
            initial_capacity: 64,
            // Tiny bound: the retained raw samples must be capped while
            // sample_count stays exact.
            reservoir: 8,
        };
        let rep = run(&cfg, None).unwrap();
        assert_eq!(rep.worker_batches.len(), 4);
        assert!(
            rep.worker_batches.iter().all(|&b| b > 0),
            "a worker served nothing: {:?}",
            rep.worker_batches
        );
        assert!(
            rep.peak_concurrent_workers >= 2,
            "workers serialized: peak {}",
            rep.peak_concurrent_workers
        );
        // The reservoir bound holds (per-worker caps round up, so allow
        // up to one extra slot per worker) while the exact sample count
        // keeps counting every batch.
        assert!(
            rep.retained_samples <= 8 + 4,
            "reservoir overflowed: {} retained",
            rep.retained_samples
        );
        assert!(rep.sample_count >= rep.retained_samples);
        assert_eq!(rep.latency_stats.count as usize, rep.sample_count);
        assert_eq!(rep.initial_buckets, 64);
        assert!(
            rep.final_buckets > rep.initial_buckets,
            "undersized table never grew: {} -> {}",
            rep.initial_buckets,
            rep.final_buckets
        );
        assert_eq!(rep.total_requests, rep.finds + rep.inserts + rep.deletes);
    }
}
