//! `repro` — the Big Atomics reproduction CLI (leader entrypoint).
//!
//! ```text
//! repro fig1|fig2|fig3|fig4|fig5|table1|memory|ablate|all   regenerate paper exhibits + ablations
//!       [--panel u|z|n|w|p|ordering|smr] [--oversub] [--secs S] [--n N]
//!       [--artifact] [--reports DIR]
//! repro kv [--workers W] [--secs S] [--n N] [--cap C] [--u PCT] [--z Z] [--artifact]
//! repro validate [--count C]        cross-check AOT artifact vs Rust generator
//! repro smoke                       PJRT + artifact load check
//! ```
//!
//! (Hand-rolled argument parsing: clap is not in the offline crate set —
//! DESIGN.md §Substitutions.)

use big_atomics::bail;
use big_atomics::util::error::Result;
use big_atomics::bench::figures::FigureCfg;
use big_atomics::coordinator::{kv_service, Coordinator};
use big_atomics::runtime::{default_artifact_dir, Runtime};

#[derive(Debug)]
struct Args {
    command: String,
    panel: String,
    oversub: bool,
    secs: f64,
    n: usize,
    artifact: bool,
    reports: String,
    workers: usize,
    cap: usize,
    update_pct: u32,
    theta: f64,
    count: usize,
}

fn parse_args() -> Result<Args> {
    let mut args = Args {
        command: String::new(),
        panel: String::new(),
        oversub: false,
        secs: 0.3,
        n: 1 << 16,
        artifact: false,
        reports: "reports".into(),
        workers: 4,
        cap: 0,
        update_pct: 30,
        theta: 0.5,
        count: 1 << 14,
    };
    let mut it = std::env::args().skip(1);
    while let Some(a) = it.next() {
        let mut next = |flag: &str| -> Result<String> {
            it.next()
                .ok_or_else(|| big_atomics::anyhow!("{flag} needs a value"))
        };
        match a.as_str() {
            "--panel" => args.panel = next("--panel")?,
            "--oversub" => args.oversub = true,
            "--secs" => args.secs = next("--secs")?.parse()?,
            "--n" => args.n = next("--n")?.parse()?,
            "--artifact" => args.artifact = true,
            "--reports" => args.reports = next("--reports")?,
            "--workers" => args.workers = next("--workers")?.parse()?,
            "--cap" => args.cap = next("--cap")?.parse()?,
            "--u" => args.update_pct = next("--u")?.parse()?,
            "--z" => args.theta = next("--z")?.parse()?,
            "--count" => args.count = next("--count")?.parse()?,
            "--help" | "-h" => {
                args.command = "help".into();
                return Ok(args);
            }
            cmd if !cmd.starts_with('-') && args.command.is_empty() => {
                args.command = cmd.to_string();
            }
            other => bail!("unknown argument {other} (try --help)"),
        }
    }
    if args.command.is_empty() {
        args.command = "help".into();
    }
    Ok(args)
}

const HELP: &str = "\
repro — Big Atomics (Anderson, Blelloch, Jayanti 2025) reproduction

USAGE:
  repro <fig1|fig2|fig3|fig4|fig5|table1|memory|ablate|all> [options]
  repro kv [--workers W] [--secs S] [--n N] [--cap C] [--u PCT] [--z Z] [--artifact]
  repro validate [--count C]
  repro smoke

OPTIONS:
  --panel PANEL       figure panel (fig2: u|z|n|w|p|fu; fig3: u|z|n|wide;
                      ablate: ordering|smr|resize; default: all panels)
  --oversub           run the 4x-oversubscribed variant of the panel
  --secs S            seconds per measured point      [0.3]
  --n N               elements / key-space size       [65536]
  --cap C             kv: initial table buckets (0 = sized for N; set
                      small, e.g. 64, to exercise online growth)
  --artifact          generate op streams via the AOT HLO artifact
  --reports DIR       CSV output directory            [reports]
";

fn main() -> Result<()> {
    let args = parse_args()?;
    match args.command.as_str() {
        "help" => {
            print!("{HELP}");
            Ok(())
        }
        "smoke" => {
            let rt = Runtime::new(default_artifact_dir())?;
            println!("PJRT platform: {}", rt.platform());
            let engine = big_atomics::runtime::workload_gen::WorkloadEngine::new(&rt)?;
            println!("workload artifact loaded: batch={}", engine.batch());
            rt.stats_engine()?;
            println!("stats artifact loaded");
            println!("smoke OK");
            Ok(())
        }
        "validate" => {
            let coord = Coordinator::new(true)?;
            let compared = coord.validate_workload(args.count)?;
            println!("workload cross-validation OK: {compared} ops bit-exact (HLO == Rust)");
            Ok(())
        }
        "kv" => {
            let rt = if args.artifact {
                Some(Runtime::new(default_artifact_dir())?)
            } else {
                None
            };
            let cfg = kv_service::KvConfig {
                n: args.n,
                workers: args.workers,
                batch: 512,
                duration: std::time::Duration::from_secs_f64(args.secs.max(1.0)),
                update_pct: args.update_pct,
                theta: args.theta,
                seed: 0x4B56,
                initial_capacity: args.cap,
            };
            let rep = kv_service::run(&cfg, rt.as_ref())?;
            println!(
                "kv: {} requests in {:.2}s = {:.3} Mop/s (find={} insert={} delete={})",
                rep.total_requests,
                rep.elapsed.as_secs_f64(),
                rep.mops(),
                rep.finds,
                rep.inserts,
                rep.deletes
            );
            println!(
                "kv workers: batches per worker {:?}, peak concurrent {}",
                rep.worker_batches, rep.peak_concurrent_workers
            );
            if rep.final_buckets != rep.initial_buckets {
                println!(
                    "kv table grew online: {} -> {} buckets",
                    rep.initial_buckets, rep.final_buckets
                );
            }
            if let Some(lat) = rep.latency {
                println!("kv latency ({} batch samples): {}", rep.sample_count, lat);
            }
            Ok(())
        }
        fig => {
            let coord = Coordinator::new(args.artifact)?;
            let cfg = FigureCfg {
                secs_per_point: args.secs,
                n: args.n,
                report_dir: args.reports.clone(),
                use_artifact: args.artifact,
            };
            let saved = coord.run_figure(fig, &cfg, &args.panel, args.oversub)?;
            eprintln!("\nsaved: {}", saved.join(" "));
            Ok(())
        }
    }
}
