//! `repro` — the Big Atomics reproduction CLI (leader entrypoint).
//!
//! ```text
//! repro fig1|fig2|fig3|fig4|fig5|table1|memory|ablate|all   regenerate paper exhibits + ablations
//!       [--panel u|z|n|w|p|ordering|smr|resize|ingress|alloc] [--oversub] [--secs S]
//!       [--n N] [--artifact] [--reports DIR]
//! repro kv [--workers W] [--clients C] [--secs S] [--n N] [--cap C] [--u PCT]
//!          [--z Z] [--ingress lockfree|mailbox] [--shards S] [--lease-ms MS]
//!          [--admission wait|shed] [--reservoir R] [--artifact] [--telemetry]
//! repro chaos [--seed S] [--plan P] [--secs S]   fault-injection campaigns
//! repro stats                       exercise the stack, print telemetry JSON
//! repro validate [--count C]        cross-check AOT artifact vs Rust generator
//! repro smoke                       PJRT + artifact load check
//! ```
//!
//! (Hand-rolled argument parsing: clap is not in the offline crate set —
//! DESIGN.md §Substitutions.)

use big_atomics::bail;
use big_atomics::util::error::Result;
use big_atomics::bench::figures::FigureCfg;
use big_atomics::coordinator::{kv_service, Coordinator};
use big_atomics::runtime::{default_artifact_dir, Runtime};

#[derive(Debug)]
struct Args {
    command: String,
    panel: String,
    oversub: bool,
    secs: f64,
    n: usize,
    artifact: bool,
    reports: String,
    workers: usize,
    cap: usize,
    update_pct: u32,
    theta: f64,
    count: usize,
    telemetry: bool,
    reservoir: usize,
    ingress: String,
    shards: usize,
    clients: usize,
    admission: String,
    seed: u64,
    plan: String,
    lease_ms: u64,
}

fn parse_args() -> Result<Args> {
    let mut args = Args {
        command: String::new(),
        panel: String::new(),
        oversub: false,
        secs: 0.3,
        n: 1 << 16,
        artifact: false,
        reports: "reports".into(),
        workers: 4,
        cap: 0,
        update_pct: 30,
        theta: 0.5,
        count: 1 << 14,
        telemetry: false,
        reservoir: kv_service::DEFAULT_RESERVOIR,
        ingress: "lockfree".into(),
        shards: 0,
        clients: 0,
        admission: "wait".into(),
        seed: 0xC4A0_5,
        plan: String::new(),
        lease_ms: 0,
    };
    let mut it = std::env::args().skip(1);
    while let Some(a) = it.next() {
        let mut next = |flag: &str| -> Result<String> {
            it.next()
                .ok_or_else(|| big_atomics::anyhow!("{flag} needs a value"))
        };
        match a.as_str() {
            "--panel" => args.panel = next("--panel")?,
            "--oversub" => args.oversub = true,
            "--secs" => args.secs = next("--secs")?.parse()?,
            "--n" => args.n = next("--n")?.parse()?,
            "--artifact" => args.artifact = true,
            "--reports" => args.reports = next("--reports")?,
            "--workers" => args.workers = next("--workers")?.parse()?,
            "--cap" => args.cap = next("--cap")?.parse()?,
            "--u" => args.update_pct = next("--u")?.parse()?,
            "--z" => args.theta = next("--z")?.parse()?,
            "--count" => args.count = next("--count")?.parse()?,
            "--telemetry" => args.telemetry = true,
            "--reservoir" => args.reservoir = next("--reservoir")?.parse()?,
            "--ingress" => args.ingress = next("--ingress")?,
            "--shards" => args.shards = next("--shards")?.parse()?,
            "--clients" => args.clients = next("--clients")?.parse()?,
            "--admission" => args.admission = next("--admission")?,
            "--seed" => args.seed = next("--seed")?.parse()?,
            "--plan" => args.plan = next("--plan")?,
            "--lease-ms" => args.lease_ms = next("--lease-ms")?.parse()?,
            "--help" | "-h" => {
                args.command = "help".into();
                return Ok(args);
            }
            cmd if !cmd.starts_with('-') && args.command.is_empty() => {
                args.command = cmd.to_string();
            }
            other => bail!("unknown argument {other} (try --help)"),
        }
    }
    if args.command.is_empty() {
        args.command = "help".into();
    }
    Ok(args)
}

const HELP: &str = "\
repro — Big Atomics (Anderson, Blelloch, Jayanti 2025) reproduction

USAGE:
  repro <fig1|fig2|fig3|fig4|fig5|table1|memory|ablate|all> [options]
  repro kv [--workers W] [--clients C] [--secs S] [--n N] [--cap C] [--u PCT]
           [--z Z] [--ingress lockfree|mailbox] [--shards S] [--lease-ms MS]
           [--admission wait|shed] [--reservoir R] [--artifact] [--telemetry]
  repro chaos [--seed S] [--plan P] [--secs S]
  repro stats                       exercise each subsystem, print telemetry JSON
  repro validate [--count C]
  repro smoke

OPTIONS:
  --panel PANEL       figure panel (fig2: u|z|n|w|p|fu; fig3: u|z|n|wide;
                      ablate: ordering|smr|resize|ingress|alloc; default: all panels)
  --oversub           run the 4x-oversubscribed variant of the panel
  --secs S            seconds per measured point      [0.3]
  --n N               elements / key-space size       [65536]
  --cap C             kv: initial table buckets (0 = sized for N; set
                      small, e.g. 64, to exercise online growth)
  --ingress MODE      kv: front door — lockfree (sharded claim queues,
                      the default) or mailbox (the Mutex+Condvar baseline)
  --shards S          kv: ingress shards (lockfree; 0 = one per worker)
  --clients C         kv: producer threads             [1]
  --admission POLICY  kv: full-shard policy — wait (backpressure) | shed
  --lease-ms MS       kv: drainer-lease bound for the lockfree shards
                      (0 = leases off; expired claims are taken over)
  --reservoir R       kv: max raw latency samples retained [4096]
  --seed S            chaos: plan seed (decisions replay from it)
  --plan P            chaos: kill-copier|stall-drainer|kill-worker|
                      kill-allocator|kill-copier-shrink|kill-migrator|
                      jitter
                      (default: run all scenarios)
                      fault injection needs `--features fault`; without
                      it the scenarios run as a plain stress pass
  --artifact          generate op streams via the AOT HLO artifact
  --telemetry         capture an event-counter/histogram snapshot per run
                      and write it as JSON next to the exhibits (full
                      counter coverage needs `--features telemetry`)
  --reports DIR       CSV output directory            [reports]
";

fn main() -> Result<()> {
    let args = parse_args()?;
    match args.command.as_str() {
        "help" => {
            print!("{HELP}");
            Ok(())
        }
        "smoke" => {
            let rt = Runtime::new(default_artifact_dir())?;
            println!("PJRT platform: {}", rt.platform());
            let engine = big_atomics::runtime::workload_gen::WorkloadEngine::new(&rt)?;
            println!("workload artifact loaded: batch={}", engine.batch());
            rt.stats_engine()?;
            println!("stats artifact loaded");
            println!("smoke OK");
            Ok(())
        }
        "validate" => {
            let coord = Coordinator::new(true)?;
            let compared = coord.validate_workload(args.count)?;
            println!("workload cross-validation OK: {compared} ops bit-exact (HLO == Rust)");
            Ok(())
        }
        "chaos" => {
            let reports = big_atomics::fault::chaos::run(args.seed, &args.plan, args.secs)?;
            let mut failed = false;
            let mut injected_total = 0u64;
            for rep in &reports {
                print!("{rep}");
                failed |= !rep.ok();
                injected_total += rep.injected;
            }
            if cfg!(feature = "fault") && injected_total == 0 {
                bail!("fault feature is on but no fault ever fired — harness broken");
            }
            if !cfg!(feature = "fault") {
                eprintln!(
                    "note: built without --features fault; scenarios ran as a \
                     stress pass with zero injections"
                );
            }
            if failed {
                bail!("chaos invariant violations (see above)");
            }
            println!("chaos OK: {} scenario(s) survived", reports.len());
            Ok(())
        }
        "stats" => {
            big_atomics::obs::set_enabled(true);
            let before = big_atomics::obs::ObsSnapshot::capture();
            exercise_subsystems(args.n.min(1 << 14));
            let delta = big_atomics::obs::ObsSnapshot::capture().delta_since(&before);
            println!("{}", delta.to_json());
            Ok(())
        }
        "kv" => {
            let rt = if args.artifact {
                Some(Runtime::new(default_artifact_dir())?)
            } else {
                None
            };
            if args.telemetry {
                big_atomics::obs::set_enabled(true);
            }
            let obs_before = if args.telemetry {
                Some(big_atomics::obs::ObsSnapshot::capture())
            } else {
                None
            };
            let cfg = kv_service::KvConfig {
                n: args.n,
                workers: args.workers,
                batch: 512,
                duration: std::time::Duration::from_secs_f64(args.secs.max(1.0)),
                update_pct: args.update_pct,
                theta: args.theta,
                seed: 0x4B56,
                initial_capacity: args.cap,
                reservoir: args.reservoir,
                ingress: kv_service::IngressMode::parse(&args.ingress)?,
                shards: args.shards,
                clients: args.clients,
                admission: big_atomics::ingress::AdmissionPolicy::parse(&args.admission)?,
                lease_ms: args.lease_ms,
            };
            let rep = kv_service::run(&cfg, rt.as_ref())?;
            println!(
                "kv: {} requests in {:.2}s = {:.3} Mop/s (find={} insert={} delete={})",
                rep.total_requests,
                rep.elapsed.as_secs_f64(),
                rep.mops(),
                rep.finds,
                rep.inserts,
                rep.deletes
            );
            println!(
                "kv ingress [{}]: {} batches offered = {} served + {} shed \
                 (waits={} claim_runs={} steal_runs={})",
                rep.ingress,
                rep.enqueued_batches,
                rep.sample_count,
                rep.shed_batches,
                rep.admit_waits,
                rep.claim_runs,
                rep.steal_runs,
            );
            if rep.worker_panics + rep.abandoned_batches + rep.requeued_batches
                + rep.lease_takeovers
                > 0
            {
                println!(
                    "kv faults: {} worker panic(s), {} abandoned, {} requeued, \
                     {} lease takeover(s)",
                    rep.worker_panics,
                    rep.abandoned_batches,
                    rep.requeued_batches,
                    rep.lease_takeovers
                );
            }
            if !rep.shard_batches.is_empty() {
                println!("kv shards: batches per shard {:?}", rep.shard_batches);
                let depth = big_atomics::obs::KV_SHARD_DEPTH.snapshot();
                if depth.count > 0 {
                    println!(
                        "kv shard depth: mean {:.1}, p50 {}, p99 {}, max {}",
                        depth.mean(),
                        depth.p50(),
                        depth.p99(),
                        depth.max
                    );
                }
            }
            println!(
                "kv workers: batches per worker {:?}, peak concurrent {}",
                rep.worker_batches, rep.peak_concurrent_workers
            );
            if rep.final_buckets != rep.initial_buckets {
                println!(
                    "kv table grew online: {} -> {} buckets",
                    rep.initial_buckets, rep.final_buckets
                );
            }
            if let Some(lat) = rep.latency {
                println!(
                    "kv latency ({} batch samples, {} retained): {}",
                    rep.sample_count, rep.retained_samples, lat
                );
            }
            if let Some(p999) = rep.latency_p999_ns {
                println!("kv latency p999: {p999} ns");
            }
            if let Some(before) = obs_before {
                let delta = big_atomics::obs::ObsSnapshot::capture().delta_since(&before);
                std::fs::create_dir_all(&args.reports)?;
                let path = format!("{}/kv.obs.json", args.reports);
                std::fs::write(&path, delta.to_json())?;
                eprintln!("telemetry snapshot: {path}");
            }
            Ok(())
        }
        fig => {
            // With --telemetry each figure Report folds an ObsSnapshot
            // delta in and saves it as `<id>.obs.json` beside the CSV.
            if args.telemetry {
                big_atomics::obs::set_enabled(true);
            }
            let coord = Coordinator::new(args.artifact)?;
            let cfg = FigureCfg {
                secs_per_point: args.secs,
                n: args.n,
                report_dir: args.reports.clone(),
                use_artifact: args.artifact,
            };
            let saved = coord.run_figure(fig, &cfg, &args.panel, args.oversub)?;
            eprintln!("\nsaved: {}", saved.join(" "));
            Ok(())
        }
    }
}

/// Drive every instrumented subsystem briefly so `repro stats` has
/// non-zero counters to print even outside a benchmark run: contended
/// big-atomic traffic (fast/slow paths, CAS retries, hazard SMR), then
/// an undersized hash table grown online under mixed operations (resize
/// machinery + epoch SMR).
fn exercise_subsystems(n: usize) {
    use big_atomics::atomics::{BigAtomic, CachedWaitFree, SeqLock, Words};
    use big_atomics::hash::{CacheHash, ConcurrentMap, LinkVal};

    let a: CachedWaitFree<Words<4>> = CachedWaitFree::new(Words([0; 4]));
    let b: SeqLock<Words<4>> = SeqLock::new(Words([0; 4]));
    std::thread::scope(|s| {
        for _ in 0..4 {
            s.spawn(|| {
                for i in 0..2_000u64 {
                    let cur = a.load();
                    let _ = a.compare_exchange(cur, Words([i; 4]));
                    b.store(Words([i; 4]));
                    std::hint::black_box(b.load());
                }
            });
        }
    });

    let t: CacheHash<big_atomics::atomics::CachedMemEff<LinkVal>> = CacheHash::new(64);
    for rank in 0..n.max(1 << 10) {
        let k = big_atomics::util::rng::mix64(rank as u64);
        t.insert(k, rank as u64);
        if rank % 3 == 0 {
            t.remove(k);
        } else {
            std::hint::black_box(t.find(k));
        }
    }
    // Drain most of what survived and let maintenance walk the capacity
    // back down, so the shrink-direction counters show up in the JSON.
    use big_atomics::hash::Maintain;
    for rank in 0..n.max(1 << 10) {
        if rank % 3 != 0 && rank % 8 != 1 {
            t.remove(big_atomics::util::rng::mix64(rank as u64));
        }
    }
    let mut cap = t.capacity();
    loop {
        let idle = t.maintain();
        let now = t.capacity();
        if idle && now == cap {
            break;
        }
        cap = now;
    }
}
