//! One runner per paper exhibit (§5): Figures 1–5, Table 1, and the
//! §5.5 memory census.  Each prints the paper's rows/series and writes
//! `reports/<id>.csv`.
//!
//! Scale: the paper ran 10M elements on 96 hardware threads; this
//! harness auto-scales to the host (`hw_threads()`, default n = 64K,
//! duration per point configurable) and reports Mop/s.  The *shapes* —
//! who wins, where oversubscription crossovers fall — are the
//! reproduction target (EXPERIMENTS.md holds paper-vs-measured notes).

use std::fmt::Write as _;
use std::fs;
use std::path::Path;
use std::time::Duration;

use super::driver::{
    hw_threads, run_atomics, run_fetch_update, run_map, run_map_wide, AtomicImpl, MapImpl,
    OpSource, RunResult,
};
use super::workload::WorkloadSpec;

/// Global knobs for a figure run.
#[derive(Clone, Debug)]
pub struct FigureCfg {
    /// Seconds per measured point.
    pub secs_per_point: f64,
    /// Default element count (paper: 10M; scaled for this host).
    pub n: usize,
    /// Output directory for CSV rows.
    pub report_dir: String,
    /// Use the AOT artifact for stream generation when available.
    pub use_artifact: bool,
}

impl Default for FigureCfg {
    fn default() -> Self {
        Self {
            secs_per_point: 0.3,
            n: 1 << 16,
            report_dir: "reports".to_string(),
            use_artifact: false,
        }
    }
}

impl FigureCfg {
    pub(crate) fn dur(&self) -> Duration {
        Duration::from_secs_f64(self.secs_per_point)
    }
}

/// A collected table of rows, printed and persisted.
pub struct Report {
    id: String,
    header: Vec<String>,
    rows: Vec<Vec<String>>,
    /// Telemetry baseline, captured at construction when the runtime
    /// reporting switch ([`crate::obs::set_enabled`]) is on; `save`
    /// writes the run's delta as `<id>.obs.json` beside the CSV.
    obs_start: Option<crate::obs::ObsSnapshot>,
}

impl Report {
    pub fn new(id: &str, header: &[&str]) -> Self {
        println!("\n=== {id} ===");
        println!("{}", header.join("\t"));
        Self {
            id: id.to_string(),
            header: header.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
            obs_start: if crate::obs::enabled() {
                Some(crate::obs::ObsSnapshot::capture())
            } else {
                None
            },
        }
    }

    pub fn row(&mut self, cells: Vec<String>) {
        println!("{}", cells.join("\t"));
        self.rows.push(cells);
    }

    pub fn save(&self, dir: &str) -> std::io::Result<String> {
        fs::create_dir_all(dir)?;
        let path = Path::new(dir).join(format!("{}.csv", self.id));
        let mut out = String::new();
        writeln!(out, "{}", self.header.join(",")).unwrap();
        for r in &self.rows {
            writeln!(out, "{}", r.join(",")).unwrap();
        }
        fs::write(&path, out)?;
        if let Some(start) = &self.obs_start {
            let delta = crate::obs::ObsSnapshot::capture().delta_since(start);
            let obs_path = Path::new(dir).join(format!("{}.obs.json", self.id));
            fs::write(&obs_path, delta.to_json())?;
        }
        Ok(path.display().to_string())
    }

    pub fn rows(&self) -> &[Vec<String>] {
        &self.rows
    }
}

fn fmt_mops(r: &RunResult) -> String {
    format!("{:.3}", r.mops())
}

/// The thread counts representing "full subscription" and the paper's
/// 4x oversubscription point on this host.
pub fn subscription_points() -> (usize, usize) {
    let p = hw_threads();
    (p, 4 * p)
}

// ---------------------------------------------------------------------
// Figure 1 — headline cross-section: atomics + hash, u=50, z=0,
// p = {P, 4P}.
// ---------------------------------------------------------------------
pub fn fig1(cfg: &FigureCfg, source: &OpSource) -> Report {
    let (p, p_over) = subscription_points();
    let mut rep = Report::new(
        "fig1_headline",
        &["impl", "atomics_mops_p", "atomics_mops_4p", "hash_mops_p", "hash_mops_4p"],
    );
    let spec = WorkloadSpec {
        n: cfg.n,
        theta: 0.0,
        update_pct: 50,
        seed: 0xF1,
    };
    let pairs: [(AtomicImpl, MapImpl); 5] = [
        (AtomicImpl::SeqLock, MapImpl::CacheHashSeqLock),
        (AtomicImpl::SimpLock, MapImpl::CacheHashSimpLock),
        (AtomicImpl::Indirect, MapImpl::CacheHashIndirect),
        (AtomicImpl::CachedWaitFree, MapImpl::CacheHashWaitFree),
        (AtomicImpl::CachedMemEff, MapImpl::CacheHashMemEff),
    ];
    for (ai, mi) in pairs {
        let a1 = run_atomics(ai, 3, &spec, p, cfg.dur(), source)
                .expect("k from SUPPORTED_K");
        let a4 = run_atomics(ai, 3, &spec, p_over, cfg.dur(), source)
                .expect("k from SUPPORTED_K");
        let h1 = run_map(mi, &spec, p, cfg.dur(), source);
        let h4 = run_map(mi, &spec, p_over, cfg.dur(), source);
        rep.row(vec![
            ai.name().into(),
            fmt_mops(&a1),
            fmt_mops(&a4),
            fmt_mops(&h1),
            fmt_mops(&h4),
        ]);
    }
    rep
}

// ---------------------------------------------------------------------
// Figure 2 — microbenchmark sweeps (8 panels): u, z, n (each at P and
// 4P), w, p.
// ---------------------------------------------------------------------

pub fn fig2_u(cfg: &FigureCfg, source: &OpSource, oversub: bool) -> Report {
    let (p, p_over) = subscription_points();
    let threads = if oversub { p_over } else { p };
    let id = if oversub { "fig2_u_oversub" } else { "fig2_u" };
    let mut rep = Report::new(id, &["u_pct", "impl", "mops"]);
    for u in [0u32, 10, 25, 50, 75, 100] {
        let spec = WorkloadSpec {
            n: cfg.n,
            theta: 0.0,
            update_pct: u,
            seed: 0xF2,
        };
        for imp in AtomicImpl::CORE {
            let r = run_atomics(imp, 3, &spec, threads, cfg.dur(), source)
                .expect("k from SUPPORTED_K");
            rep.row(vec![u.to_string(), imp.name().into(), fmt_mops(&r)]);
        }
    }
    rep
}

pub fn fig2_z(cfg: &FigureCfg, source: &OpSource, oversub: bool) -> Report {
    let (p, p_over) = subscription_points();
    let threads = if oversub { p_over } else { p };
    let id = if oversub { "fig2_z_oversub" } else { "fig2_z" };
    let mut rep = Report::new(id, &["z", "impl", "mops"]);
    for z in [0.0f64, 0.5, 0.75, 0.9, 0.99] {
        let spec = WorkloadSpec {
            n: cfg.n,
            theta: z,
            update_pct: 5,
            seed: 0xF3,
        };
        for imp in AtomicImpl::CORE {
            let r = run_atomics(imp, 3, &spec, threads, cfg.dur(), source)
                .expect("k from SUPPORTED_K");
            rep.row(vec![format!("{z}"), imp.name().into(), fmt_mops(&r)]);
        }
    }
    rep
}

pub fn fig2_n(cfg: &FigureCfg, source: &OpSource, oversub: bool) -> Report {
    let (p, p_over) = subscription_points();
    let threads = if oversub { p_over } else { p };
    let id = if oversub { "fig2_n_oversub" } else { "fig2_n" };
    let mut rep = Report::new(id, &["n", "impl", "mops"]);
    for n in [1usize << 10, 1 << 13, 1 << 16, 1 << 20] {
        let spec = WorkloadSpec {
            n,
            theta: 0.0,
            update_pct: 5,
            seed: 0xF4,
        };
        for imp in AtomicImpl::CORE {
            let r = run_atomics(imp, 3, &spec, threads, cfg.dur(), source)
                .expect("k from SUPPORTED_K");
            rep.row(vec![n.to_string(), imp.name().into(), fmt_mops(&r)]);
        }
    }
    rep
}

pub fn fig2_w(cfg: &FigureCfg, source: &OpSource) -> Report {
    let (p, _) = subscription_points();
    let mut rep = Report::new("fig2_w", &["k_words", "impl", "mops"]);
    for k in [1usize, 2, 4, 8, 16] {
        let spec = WorkloadSpec {
            n: cfg.n,
            theta: 0.0,
            update_pct: 5,
            seed: 0xF5,
        };
        for imp in AtomicImpl::CORE {
            let r = run_atomics(imp, k, &spec, p, cfg.dur(), source)
                .expect("k from SUPPORTED_K");
            rep.row(vec![k.to_string(), imp.name().into(), fmt_mops(&r)]);
        }
    }
    rep
}

pub fn fig2_p(cfg: &FigureCfg, source: &OpSource) -> Report {
    let (p, p_over) = subscription_points();
    let mut rep = Report::new("fig2_p", &["threads", "impl", "mops"]);
    let mut points = vec![1usize, 2, 4];
    for t in [p, 2 * p, p_over, 8 * p] {
        if !points.contains(&t) {
            points.push(t);
        }
    }
    points.sort_unstable();
    points.dedup();
    for threads in points {
        let spec = WorkloadSpec {
            n: cfg.n,
            theta: 0.0,
            update_pct: 5,
            seed: 0xF6,
        };
        for imp in AtomicImpl::CORE {
            let r = run_atomics(imp, 3, &spec, threads, cfg.dur(), source)
                .expect("k from SUPPORTED_K");
            rep.row(vec![threads.to_string(), imp.name().into(), fmt_mops(&r)]);
        }
    }
    rep
}

// ---------------------------------------------------------------------
// Figure 3 — CacheHash vs Chaining sweeps: u, z, n (each at P, 4P).
// ---------------------------------------------------------------------
pub fn fig3(cfg: &FigureCfg, source: &OpSource, panel: &str, oversub: bool) -> Report {
    let (p, p_over) = subscription_points();
    let threads = if oversub { p_over } else { p };
    let suffix = if oversub { "_oversub" } else { "" };
    let mut rep = Report::new(
        &format!("fig3_{panel}{suffix}"),
        &[panel, "impl", "mops"],
    );
    let sweep: Vec<(String, WorkloadSpec)> = match panel {
        "u" => [0u32, 10, 25, 50, 75, 100]
            .iter()
            .map(|&u| {
                (
                    u.to_string(),
                    WorkloadSpec {
                        n: cfg.n,
                        theta: 0.0,
                        update_pct: u,
                        seed: 0xF7,
                    },
                )
            })
            .collect(),
        "z" => [0.0f64, 0.5, 0.75, 0.9, 0.99]
            .iter()
            .map(|&z| {
                (
                    format!("{z}"),
                    WorkloadSpec {
                        n: cfg.n,
                        theta: z,
                        update_pct: 5,
                        seed: 0xF8,
                    },
                )
            })
            .collect(),
        "n" => [1usize << 10, 1 << 13, 1 << 16, 1 << 20]
            .iter()
            .map(|&n| {
                (
                    n.to_string(),
                    WorkloadSpec {
                        n,
                        theta: 0.0,
                        update_pct: 5,
                        seed: 0xF9,
                    },
                )
            })
            .collect(),
        other => panic!("unknown fig3 panel {other} (use u|z|n)"),
    };
    for (x, spec) in sweep {
        for imp in MapImpl::FIG3 {
            let r = run_map(imp, &spec, threads, cfg.dur(), source);
            rep.row(vec![x.clone(), imp.name().into(), fmt_mops(&r)]);
        }
    }
    rep
}

// ---------------------------------------------------------------------
// Figure 3w — the §5.3 arbitrary-length rows: CacheHash with 4-word
// keys AND 4-word values (9-word inlined links) across the big-atomic
// strategies, with the u64 table as the narrow reference.
// ---------------------------------------------------------------------
pub fn fig3_wide(cfg: &FigureCfg, source: &OpSource) -> Report {
    let (p, _) = subscription_points();
    let mut rep = Report::new("fig3_wide", &["u_pct", "impl", "mops"]);
    for u in [0u32, 25, 50, 100] {
        let spec = WorkloadSpec {
            n: cfg.n,
            theta: 0.0,
            update_pct: u,
            seed: 0x3A,
        };
        for imp in [
            AtomicImpl::SeqLock,
            AtomicImpl::CachedWaitFree,
            AtomicImpl::CachedMemEff,
        ] {
            let r = run_map_wide(imp, &spec, p, cfg.dur(), source);
            rep.row(vec![u.to_string(), r.label.clone(), fmt_mops(&r)]);
        }
        // Narrow (u64 → u64) reference at matched parameters.
        let r = run_map(MapImpl::CacheHashMemEff, &spec, p, cfg.dur(), source);
        rep.row(vec![u.to_string(), format!("{}[u64]", r.label), fmt_mops(&r)]);
    }
    rep
}

// ---------------------------------------------------------------------
// Figure 2fu — the fetch_update op mix (read-modify-write updates that
// must land) across the update-fraction sweep.
// ---------------------------------------------------------------------
pub fn fig2_fetch_update(cfg: &FigureCfg, source: &OpSource) -> Report {
    let (p, _) = subscription_points();
    let mut rep = Report::new("fig2_fetch_update", &["u_pct", "impl", "mops"]);
    for u in [5u32, 25, 50, 100] {
        let spec = WorkloadSpec {
            n: cfg.n,
            theta: 0.0,
            update_pct: u,
            seed: 0x2F,
        };
        for imp in AtomicImpl::CORE {
            let r = run_fetch_update(imp, 3, &spec, p, cfg.dur(), source)
                .expect("k from SUPPORTED_K");
            rep.row(vec![u.to_string(), imp.name().into(), fmt_mops(&r)]);
        }
    }
    rep
}

// ---------------------------------------------------------------------
// Figure 4 — vs open-source stand-ins: vary p and z.
// ---------------------------------------------------------------------
pub fn fig4(cfg: &FigureCfg, source: &OpSource) -> (Report, Report) {
    let (p, p_over) = subscription_points();
    let mut rep_p = Report::new("fig4_p", &["threads", "impl", "mops"]);
    let mut points = vec![1usize, 2, 4];
    for t in [p, p_over] {
        if !points.contains(&t) {
            points.push(t);
        }
    }
    points.sort_unstable();
    points.dedup();
    for threads in points {
        let spec = WorkloadSpec {
            n: cfg.n,
            theta: 0.0,
            update_pct: 10,
            seed: 0xFA,
        };
        for imp in MapImpl::FIG4 {
            let r = run_map(imp, &spec, threads, cfg.dur(), source);
            rep_p.row(vec![threads.to_string(), imp.name().into(), fmt_mops(&r)]);
        }
    }
    let mut rep_z = Report::new("fig4_z", &["z", "impl", "mops"]);
    for z in [0.0f64, 0.5, 0.75, 0.9, 0.99] {
        let spec = WorkloadSpec {
            n: cfg.n,
            theta: z,
            update_pct: 10,
            seed: 0xFB,
        };
        for imp in MapImpl::FIG4 {
            let r = run_map(imp, &spec, p, cfg.dur(), source);
            rep_z.row(vec![format!("{z}"), imp.name().into(), fmt_mops(&r)]);
        }
    }
    (rep_p, rep_z)
}

// ---------------------------------------------------------------------
// Figure 5 — HTM comparison: vary p, z, u, n (with HtmSim).
// ---------------------------------------------------------------------
pub fn fig5(cfg: &FigureCfg, source: &OpSource) -> Vec<Report> {
    let (p, p_over) = subscription_points();
    let impls = [
        AtomicImpl::HtmSim,
        AtomicImpl::SeqLock,
        AtomicImpl::SimpLock,
        AtomicImpl::CachedMemEff,
    ];
    let mut reports = Vec::new();

    let mut rep = Report::new("fig5_p", &["threads", "impl", "mops"]);
    for threads in [1usize, 2, p.max(2), p_over] {
        let spec = WorkloadSpec {
            n: cfg.n,
            theta: 0.0,
            update_pct: 5,
            seed: 0xFC,
        };
        for imp in impls {
            let r = run_atomics(imp, 3, &spec, threads, cfg.dur(), source)
                .expect("k from SUPPORTED_K");
            rep.row(vec![threads.to_string(), imp.name().into(), fmt_mops(&r)]);
        }
    }
    reports.push(rep);

    let mut rep = Report::new("fig5_z", &["z", "impl", "mops"]);
    for z in [0.0f64, 0.5, 0.75, 0.9, 0.99] {
        let spec = WorkloadSpec {
            n: cfg.n,
            theta: z,
            update_pct: 5,
            seed: 0xFD,
        };
        for imp in impls {
            let r = run_atomics(imp, 3, &spec, p, cfg.dur(), source)
                .expect("k from SUPPORTED_K");
            rep.row(vec![format!("{z}"), imp.name().into(), fmt_mops(&r)]);
        }
    }
    reports.push(rep);

    let mut rep = Report::new("fig5_u", &["u_pct", "impl", "mops"]);
    for u in [0u32, 25, 50, 75, 100] {
        let spec = WorkloadSpec {
            n: cfg.n,
            theta: 0.0,
            update_pct: u,
            seed: 0xFE,
        };
        for imp in impls {
            let r = run_atomics(imp, 3, &spec, p, cfg.dur(), source)
                .expect("k from SUPPORTED_K");
            rep.row(vec![u.to_string(), imp.name().into(), fmt_mops(&r)]);
        }
    }
    reports.push(rep);

    let mut rep = Report::new("fig5_n", &["n", "impl", "mops"]);
    for n in [1usize << 10, 1 << 13, 1 << 16, 1 << 20] {
        let spec = WorkloadSpec {
            n,
            theta: 0.0,
            update_pct: 5,
            seed: 0xFF,
        };
        for imp in impls {
            let r = run_atomics(imp, 3, &spec, p, cfg.dur(), source)
                .expect("k from SUPPORTED_K");
            rep.row(vec![n.to_string(), imp.name().into(), fmt_mops(&r)]);
        }
    }
    reports.push(rep);
    reports
}

// ---------------------------------------------------------------------
// Table 1 — properties (static) + operation-support verification.
// ---------------------------------------------------------------------
pub fn table1() -> Report {
    let mut rep = Report::new(
        "table1_properties",
        &["approach", "progress", "space", "indirect", "operations"],
    );
    let rows: [[&str; 5]; 6] = [
        ["Indirect", "lock-free (HP)", "nk + O(n + p(p+k))", "always", "load+store+cas"],
        ["SimpLock/LockPool", "always block", "nk + O(n)", "never", "load+store+cas"],
        ["SeqLock", "block on race", "nk + O(n)", "never", "load+store+cas"],
        ["Cached-WaitFree", "wait-free", "2nk + O(n + p(p+k))", "on prior race", "load+cas"],
        ["Cached-MemEff", "lock-free", "nk + O(n + p(p+k))", "on race", "load+store+cas"],
        ["Cached-WF-Writable", "wait-free", "3nk + O(n + p(p+k))", "on prior race", "load+store+cas"],
    ];
    for r in rows {
        rep.row(r.iter().map(|s| s.to_string()).collect());
    }
    rep
}

/// Run every figure (the `repro all` path).
pub fn run_all(cfg: &FigureCfg, source: &OpSource) -> Vec<String> {
    let mut saved = Vec::new();
    let mut save = |r: Report| {
        if let Ok(p) = r.save(&cfg.report_dir) {
            saved.push(p);
        }
    };
    save(fig1(cfg, source));
    for oversub in [false, true] {
        save(fig2_u(cfg, source, oversub));
        save(fig2_z(cfg, source, oversub));
        save(fig2_n(cfg, source, oversub));
    }
    save(fig2_w(cfg, source));
    save(fig2_p(cfg, source));
    save(fig2_fetch_update(cfg, source));
    for panel in ["u", "z", "n"] {
        for oversub in [false, true] {
            save(fig3(cfg, source, panel, oversub));
        }
    }
    save(fig3_wide(cfg, source));
    let (a, b) = fig4(cfg, source);
    save(a);
    save(b);
    for r in fig5(cfg, source) {
        save(r);
    }
    save(table1());
    save(super::memory::memory_census(cfg));
    saved
}

#[cfg(test)]
mod tests {
    use super::*;

    fn quick_cfg() -> FigureCfg {
        FigureCfg {
            secs_per_point: 0.01,
            n: 512,
            report_dir: std::env::temp_dir()
                .join("big_atomics_fig_test")
                .display()
                .to_string(),
            use_artifact: false,
        }
    }

    #[test]
    fn test_fig1_shape() {
        let rep = fig1(&quick_cfg(), &OpSource::Rust);
        assert_eq!(rep.rows().len(), 5);
        for row in rep.rows() {
            for cell in &row[1..] {
                assert!(cell.parse::<f64>().unwrap() > 0.0);
            }
        }
    }

    #[test]
    fn test_table1_static() {
        let rep = table1();
        assert_eq!(rep.rows().len(), 6);
    }

    #[test]
    fn test_fig3_wide_shape() {
        let rep = fig3_wide(&quick_cfg(), &OpSource::Rust);
        // 4 u-points x (3 wide series + 1 narrow reference).
        assert_eq!(rep.rows().len(), 16);
        assert!(rep.rows().iter().any(|r| r[1].contains("wide")));
        for row in rep.rows() {
            assert!(row[2].parse::<f64>().unwrap() > 0.0);
        }
    }

    #[test]
    fn test_report_save() {
        let cfg = quick_cfg();
        let mut rep = Report::new("unit_test_report", &["a", "b"]);
        rep.row(vec!["1".into(), "2".into()]);
        let path = rep.save(&cfg.report_dir).unwrap();
        let text = std::fs::read_to_string(path).unwrap();
        assert!(text.contains("a,b"));
        assert!(text.contains("1,2"));
    }
}
