//! The §5 benchmark harness: workload generation, the multi-threaded
//! throughput driver, and one runner per paper figure/table.
//!
//! * [`workload`] — Zipfian/op-mix streams (pure Rust + the shared
//!   contract with the AOT artifact).
//! * [`driver`] — targets (atomic arrays, hash maps) and the timed
//!   p-thread loop reporting Mop/s.
//! * [`figures`] — `fig1` … `fig5`, `table1` — prints the paper's rows
//!   and writes `reports/*.csv`.
//! * [`memory`] — the §5.5 live-memory census.

pub mod ablation;
pub mod driver;
pub mod figures;
pub mod memory;
pub mod workload;
