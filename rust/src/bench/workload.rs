//! Workload generation — the paper's §5 methodology as data.
//!
//! Operations target indices drawn from a Zipfian(θ) distribution over n
//! items (θ = the paper's contention knob z; 0 = uniform), with an update
//! fraction u split evenly between inserts and deletes (§5.1).
//!
//! Two interchangeable generators produce the streams:
//! * this module's pure-Rust sampler, and
//! * the AOT-compiled JAX/Pallas workload model executed via PJRT
//!   ([`crate::runtime`]).
//!
//! They share a **bit-exact contract**: the same quantized CDF table
//! (`N_CDF` = 4096 f32 entries — Rust builds it, both search it), the
//! same u32→f32 uniform mapping, the same op encoding
//! (0 find / 1 insert / 2 delete), and the same mix64 key derivation.
//! `rust/tests/runtime_artifacts.rs` asserts the two agree bit-for-bit.
//!
//! For n > N_CDF the table is *head-exact + stratified tail*: the hot
//! head ranks (where Zipfian contention lives) get exact per-rank CDF
//! entries; the cold tail is split into equal-rank strata spread
//! uniformly at sample time. Head hit-rates — the quantity the paper's
//! z-sweeps measure — are preserved exactly.

use crate::util::rng::{mix64, Xoshiro256};

/// CDF table resolution — must equal `zipfian.N_CDF` in the L1 kernel.
pub const N_CDF: usize = 4096;

/// Exact per-rank head entries when n > N_CDF (the rest are strata).
const HEAD: usize = 3584;

const INV_2_32: f32 = 2.328_306_4e-10;

/// Operation kinds, encoded as in `artifacts/manifest.txt`.
#[derive(Copy, Clone, PartialEq, Eq, Debug)]
pub enum Op {
    Find = 0,
    Insert = 1,
    Delete = 2,
}

impl Op {
    #[inline]
    pub fn from_code(code: i32) -> Op {
        match code {
            1 => Op::Insert,
            2 => Op::Delete,
            _ => Op::Find,
        }
    }

    /// Whether this op mutates (insert/delete — the paper's update
    /// fraction `u`; the `fetch_update` mix maps these to
    /// read-modify-write increments).
    #[inline]
    pub fn is_update(self) -> bool {
        !matches!(self, Op::Find)
    }
}

/// A quantized Zipfian sampler over `0..n` with exponent `theta`.
pub struct ZipfCdf {
    cdf: Vec<f32>,
    n: usize,
    /// Ranks covered exactly (n when n <= N_CDF).
    head: usize,
    /// Ranks per tail stratum (0 when no tail).
    stride: usize,
}

impl ZipfCdf {
    /// Build the table. P(rank i) ∝ 1/(i+1)^θ (YCSB-style [13]).
    pub fn new(n: usize, theta: f64) -> Self {
        assert!(n >= 1);
        let (head, stride) = if n <= N_CDF {
            (n, 0)
        } else {
            let tail = n - HEAD;
            let strata = N_CDF - HEAD;
            (HEAD, tail.div_ceil(strata))
        };
        // Exact head weights + per-stratum tail lumps, in f64.
        let mut weights: Vec<f64> = Vec::with_capacity(N_CDF);
        for i in 0..head {
            weights.push(1.0 / ((i + 1) as f64).powf(theta));
        }
        if stride > 0 {
            let mut rank = head;
            while rank < n {
                let hi = (rank + stride).min(n);
                // Integral approximation of sum_{r=rank..hi} r^-θ — exact
                // enough for the cold tail (each lump ≪ head mass).
                let mass: f64 = if theta == 0.0 {
                    (hi - rank) as f64
                } else {
                    (rank..hi).step_by((hi - rank).div_ceil(8).max(1)).map(|r| {
                        let step = ((hi - rank).div_ceil(8)).max(1) as f64;
                        step / ((r + 1) as f64).powf(theta)
                    }).sum()
                };
                weights.push(mass);
                rank = hi;
            }
        }
        let total: f64 = weights.iter().sum();
        let mut cdf = Vec::with_capacity(N_CDF);
        let mut acc = 0.0f64;
        for w in &weights {
            acc += w / total;
            cdf.push(acc as f32);
        }
        let used = cdf.len();
        if used > 0 {
            cdf[used - 1] = 1.0;
        }
        cdf.resize(N_CDF, 1.0);
        Self {
            cdf,
            n,
            head,
            stride,
        }
    }

    /// The f32 table (input to both samplers — Rust and the HLO artifact).
    pub fn cdf(&self) -> &[f32] {
        &self.cdf
    }

    pub fn n(&self) -> usize {
        self.n
    }

    /// Table-slot search: identical semantics to the Pallas kernel
    /// (`count of entries <= u`, clamped). Bit-exact with the HLO.
    #[inline]
    pub fn search(&self, bits: u32) -> u32 {
        let u = bits as f32 * INV_2_32;
        // Branch-free unrolled binary search over the fixed-size table —
        // the same 12 steps the kernel lowers to.
        let mut lo = 0usize;
        let mut step = N_CDF / 2;
        while step >= 1 {
            let probe = lo + step - 1;
            if self.cdf[probe] <= u {
                lo += step;
            }
            step /= 2;
        }
        lo.min(N_CDF - 1) as u32
    }

    /// Map a table slot (+ extra randomness for tail strata) to a final
    /// rank in `0..n`.
    #[inline]
    pub fn spread(&self, slot: u32, extra: u64) -> usize {
        let slot = slot as usize;
        if slot < self.head {
            return slot.min(self.n - 1);
        }
        let stratum = slot - self.head;
        let base = self.head + stratum * self.stride;
        let width = self.stride.min(self.n.saturating_sub(base)).max(1);
        (base + (extra as usize % width)).min(self.n - 1)
    }

    /// Draw one rank.
    #[inline]
    pub fn sample(&self, rng: &mut Xoshiro256) -> usize {
        let slot = self.search(rng.next_u32());
        let extra = if self.stride > 0 { rng.next_u64() } else { 0 };
        self.spread(slot, extra)
    }
}

/// Full benchmark workload parameters (one §5 configuration point).
#[derive(Clone, Debug)]
pub struct WorkloadSpec {
    /// Number of items (atomics / keys) — paper's n.
    pub n: usize,
    /// Zipfian parameter — paper's z.
    pub theta: f64,
    /// Update percentage 0..=100 — paper's u.
    pub update_pct: u32,
    pub seed: u64,
}

impl WorkloadSpec {
    pub fn u_frac(&self) -> f32 {
        self.update_pct as f32 / 100.0
    }
}

/// A pre-generated operation: kind, target rank, derived key.
#[derive(Copy, Clone, Debug)]
pub struct GenOp {
    pub op: Op,
    pub rank: u32,
    pub key: u64,
}

/// Classify op-kind randomness exactly like the L2 model
/// (`model.workload_model`): update iff `op_bits * 2^-32 < u`, updates
/// split insert/delete on the low bit.
#[inline]
pub fn classify(op_bits: u32, u_frac: f32) -> Op {
    let r = op_bits as f32 * INV_2_32;
    if r < u_frac {
        if op_bits & 1 == 0 {
            Op::Insert
        } else {
            Op::Delete
        }
    } else {
        Op::Find
    }
}

/// Generate `count` operations with the pure-Rust sampler.
pub fn generate_rust(spec: &WorkloadSpec, count: usize, thread_seed: u64) -> Vec<GenOp> {
    let cdf = ZipfCdf::new(spec.n, spec.theta);
    let mut rng = Xoshiro256::seeded(spec.seed ^ mix64(thread_seed.wrapping_add(1)));
    let u = spec.u_frac();
    (0..count)
        .map(|_| {
            let slot = cdf.search(rng.next_u32());
            let op = classify(rng.next_u32(), u);
            let extra = if spec.n > N_CDF { rng.next_u64() } else { 0 };
            let rank = cdf.spread(slot, extra) as u32;
            GenOp {
                op,
                rank,
                key: mix64(rank as u64),
            }
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn test_cdf_monotone_complete() {
        for (n, theta) in [(1, 0.5), (16, 0.0), (1000, 0.99), (4096, 0.75), (100_000, 0.9)] {
            let z = ZipfCdf::new(n, theta);
            let c = z.cdf();
            assert_eq!(c.len(), N_CDF);
            assert!(c.windows(2).all(|w| w[0] <= w[1] + 1e-7));
            assert!((c[N_CDF - 1] - 1.0).abs() < 1e-6);
        }
    }

    #[test]
    fn test_samples_in_range() {
        for n in [1usize, 2, 100, 4096, 50_000] {
            let z = ZipfCdf::new(n, 0.9);
            let mut rng = Xoshiro256::seeded(3);
            for _ in 0..5_000 {
                assert!(z.sample(&mut rng) < n);
            }
        }
    }

    #[test]
    fn test_u_one_edge_clamped() {
        let z = ZipfCdf::new(100, 0.5);
        // bits that round to u == 1.0 in f32
        let slot = z.search(u32::MAX);
        assert_eq!(slot, (N_CDF - 1) as u32);
        assert!(z.spread(slot, 0) < 100);
    }

    #[test]
    fn test_uniform_theta_zero() {
        let n = 64;
        let z = ZipfCdf::new(n, 0.0);
        let mut rng = Xoshiro256::seeded(5);
        let mut counts = vec![0u32; n];
        let samples = 1 << 16;
        for _ in 0..samples {
            counts[z.sample(&mut rng)] += 1;
        }
        let expected = samples as f64 / n as f64;
        for (i, &c) in counts.iter().enumerate() {
            assert!(
                (c as f64 - expected).abs() < 6.0 * expected.sqrt() + 10.0,
                "bucket {i}: {c} vs {expected}"
            );
        }
    }

    #[test]
    fn test_zipf_head_dominates() {
        let z = ZipfCdf::new(1000, 0.99);
        let mut rng = Xoshiro256::seeded(7);
        let mut head = 0usize;
        let total = 1 << 15;
        for _ in 0..total {
            if z.sample(&mut rng) == 0 {
                head += 1;
            }
        }
        let share = head as f64 / total as f64;
        assert!(share > 0.10, "head share {share}");
    }

    #[test]
    fn test_large_n_head_exact_tail_covered() {
        let n = 1_000_000;
        let z = ZipfCdf::new(n, 0.75);
        let mut rng = Xoshiro256::seeded(11);
        let mut saw_tail = false;
        for _ in 0..20_000 {
            let s = z.sample(&mut rng);
            assert!(s < n);
            if s >= HEAD {
                saw_tail = true;
            }
        }
        assert!(saw_tail, "tail never sampled at theta=0.75, n=1M");
    }

    #[test]
    fn test_classify_fractions() {
        let mut rng = Xoshiro256::seeded(13);
        for u_pct in [0u32, 5, 50, 100] {
            let u = u_pct as f32 / 100.0;
            let total = 20_000;
            let mut upd = 0;
            let (mut ins, mut del) = (0, 0);
            for _ in 0..total {
                match classify(rng.next_u32(), u) {
                    Op::Find => {}
                    Op::Insert => {
                        upd += 1;
                        ins += 1;
                    }
                    Op::Delete => {
                        upd += 1;
                        del += 1;
                    }
                }
            }
            let frac = upd as f64 / total as f64;
            assert!((frac - u as f64).abs() < 0.02, "u={u} frac={frac}");
            if u_pct >= 50 {
                assert!((ins as f64 - del as f64).abs() / total as f64 <= 0.02);
            }
        }
    }

    #[test]
    fn test_op_is_update() {
        assert!(!Op::Find.is_update());
        assert!(Op::Insert.is_update());
        assert!(Op::Delete.is_update());
    }

    #[test]
    fn test_generate_rust_deterministic() {
        let spec = WorkloadSpec {
            n: 1000,
            theta: 0.9,
            update_pct: 30,
            seed: 42,
        };
        let a = generate_rust(&spec, 500, 1);
        let b = generate_rust(&spec, 500, 1);
        let c = generate_rust(&spec, 500, 2);
        assert!(a.iter().zip(&b).all(|(x, y)| x.rank == y.rank && x.op == y.op));
        assert!(a.iter().zip(&c).any(|(x, y)| x.rank != y.rank));
        for op in &a {
            assert_eq!(op.key, mix64(op.rank as u64));
        }
    }
}
