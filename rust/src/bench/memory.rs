//! §5.5 memory census: measured live bytes per implementation vs the
//! paper's closed forms.
//!
//! Paper constants (k = element words, n = atomics, p = threads, c_h =
//! hazard collector slack): Indirect `n(k+1) + c_h·p(p+k)`, SimpLock
//! `n(k+1)`, SeqLock `n(k+1)`, Cached-WaitFree `2n(k+2) + c_h·p(p+k)`,
//! Cached-MemEff `n(k+2) + c_h·p(p+k)`.  We measure the three
//! components we can observe directly: inline slot bytes, live indirect
//! node bytes, and pool/retire bytes.
//!
//! Each row also reports the `smr::pool` delta its workload generated:
//! fresh pages claimed from the system allocator (the allocation rate),
//! page batches handed to an SMR scheme via `Smr::retire_page`, and the
//! mean slots per batch (the amortization factor per scheme).

use std::sync::Arc;

use super::figures::{FigureCfg, Report};
use crate::atomics::{
    AtomicArray, BigAtomic, CachedMemEff, CachedWaitFree, Indirect, MemEffDomain, SeqLock,
    SimpLock, Words,
};
use crate::hash::{CacheHash, Chaining, ConcurrentMap, LinkVal, Maintain};
use crate::smr::{epoch, hazard, pool};

const K: usize = 4; // census element size (words)

fn census_one<A: BigAtomic<Words<K>>>(n: usize) -> (usize, usize) {
    let arr: AtomicArray<Words<K>, A> = AtomicArray::new(n, Words([7; K]));
    // Touch every slot with an update so indirect structures are live.
    for i in 0..n {
        let cur = arr.get(i).load();
        let _ = arr.get(i).compare_exchange(cur, Words([i as u64 + 1; K]));
    }
    let inline = n * std::mem::size_of::<A>();
    let indirect = arr.indirect_bytes();
    (inline, indirect)
}

/// Produce the §5.5 table (also a regression test for the space bounds:
/// `rust/tests/properties.rs` asserts the measured/formula ratios).
///
/// Every row reports the retired-but-unfreed census of **both** SMR
/// schemes: the seed printed only `hazard::pending_reclaims()`, which
/// silently under-counted any epoch-backed configuration (the hash
/// tables' chain links and drained resize tables) as zero.
pub fn memory_census(_cfg: &FigureCfg) -> Report {
    let n = 1 << 14;
    let mut rep = Report::new(
        "memory_census",
        &[
            "impl",
            "n",
            "k",
            "inline_bytes",
            "indirect_bytes",
            "pool_bytes",
            "retired_hazard",
            "retired_epoch",
            "alloc_pages",
            "retire_batches",
            "batch_avg_slots",
            "shrink_gens",
            "final_buckets",
        ],
    );
    let mut row = |imp: &str,
                   k: usize,
                   inline: usize,
                   indirect: usize,
                   pool_bytes: usize,
                   p0: pool::PoolStats,
                   shrink_gens: usize,
                   final_buckets: usize| {
        // Pool delta over this row's workload. The counters are global
        // and monotonic, so a concurrent test can only inflate them —
        // never hide a page or batch this row produced.
        let p1 = pool::stats();
        let batches = p1.batches - p0.batches;
        let slots = p1.batch_slots - p0.batch_slots;
        let avg = if batches > 0 { slots as f64 / batches as f64 } else { 0.0 };
        rep.row(vec![
            imp.into(),
            n.to_string(),
            k.to_string(),
            inline.to_string(),
            indirect.to_string(),
            pool_bytes.to_string(),
            hazard::pending_reclaims().to_string(),
            epoch::pending_reclaims().to_string(),
            (p1.pages - p0.pages).to_string(),
            batches.to_string(),
            format!("{avg:.1}"),
            shrink_gens.to_string(),
            final_buckets.to_string(),
        ]);
    };

    let p0 = pool::stats();
    let (inline, ind) = census_one::<SeqLock<Words<K>>>(n);
    row("SeqLock", K, inline, ind, 0, p0, 0, 0);

    let p0 = pool::stats();
    let (inline, ind) = census_one::<SimpLock<Words<K>>>(n);
    row("SimpLock", K, inline, ind, 0, p0, 0, 0);

    let p0 = pool::stats();
    let (inline, ind) = census_one::<Indirect<Words<K>>>(n);
    row("Indirect", K, inline, ind, 0, p0, 0, 0);

    let p0 = pool::stats();
    let (inline, ind) = census_one::<CachedWaitFree<Words<K>>>(n);
    row("Cached-WaitFree", K, inline, ind, 0, p0, 0, 0);

    // MemEff: use a private domain so the pool is attributable.
    let p0 = pool::stats();
    let domain: Arc<MemEffDomain<Words<K>>> = Arc::new(MemEffDomain::new());
    let arr: Vec<CachedMemEff<Words<K>>> = (0..n)
        .map(|_| CachedMemEff::with_domain(Words([7; K]), Arc::clone(&domain)))
        .collect();
    for (i, a) in arr.iter().enumerate() {
        let cur = a.load();
        let _ = a.compare_exchange(cur, Words([i as u64 + 1; K]));
    }
    let inline = n * std::mem::size_of::<CachedMemEff<Words<K>>>();
    let pool_nodes = domain.allocated_nodes() as usize;
    // Node overhead: four flag bytes padded to words + the uninstall
    // stamp (see atomics::cached_memeff::Node).
    let pool_bytes = pool_nodes * (std::mem::size_of::<Words<K>>() + 40);
    row("Cached-MemEff", K, inline, 0, pool_bytes, p0, 0, 0);

    // Churn a hash table and let the shrink trigger return its peak
    // footprint: grow from undersized, delete 15/16 of the keys (well
    // below the hysteresis band), then drive maintenance until the
    // resize engine goes idle at a stable capacity.
    fn churn_and_converge<M: ConcurrentMap + Maintain>(table: &M, n: u64) -> usize {
        for i in 0..n {
            table.insert(crate::util::rng::mix64(i), i);
        }
        for i in 0..n * 15 / 16 {
            table.remove(crate::util::rng::mix64(i));
        }
        let mut cap = table.capacity();
        loop {
            let idle = table.maintain();
            let now = table.capacity();
            if idle && now == cap {
                return now;
            }
            cap = now;
        }
    }

    // The epoch-backed configuration (§4: chain links protected by
    // epochs): start the table undersized so the n inserts force online
    // growth — each drained chain becomes one `retire_page` batch — then
    // delete most entries so the path-copied prefixes and promoted heads
    // become epoch garbage the hazard column cannot see, and the shrink
    // columns prove memory is actually returned. LinkVal is 3 words
    // (the k column).
    let p0 = pool::stats();
    let table: CacheHash<CachedMemEff<LinkVal>> = CacheHash::new(64);
    let cap = churn_and_converge(&table, n as u64);
    let inline = cap * std::mem::size_of::<CachedMemEff<LinkVal>>();
    row("CacheHash(MemEff)", 3, inline, 0, 0, p0, table.shrink_generation(), cap);

    // The no-inline chaining table under the same churn: every entry
    // lives in a pooled chain node, so its allocation-rate and batch
    // columns isolate the pool's behavior without the inline-slot tier.
    let p0 = pool::stats();
    let table: Chaining = Chaining::new(64);
    let cap = churn_and_converge(&table, n as u64);
    let inline = cap * std::mem::size_of::<usize>();
    row("Chaining(no-inline)", 3, inline, 0, 0, p0, table.shrink_generation(), cap);

    rep
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn test_census_runs_and_memeff_pool_tiny() {
        // The batch-count assertions below need the pool live for the
        // whole census; serialize against the alloc-ablation test's
        // boxed arm, which disables it process-wide.
        let _toggle = pool::TOGGLE_TEST_LOCK
            .lock()
            .unwrap_or_else(std::sync::PoisonError::into_inner);
        let rep = memory_census(&FigureCfg::default());
        let rows = rep.rows();
        assert_eq!(rows.len(), 7);
        // Both reclamation columns and the pool-delta columns must be
        // present and parseable on every row (the epoch column was
        // silently missing pre-fix).
        for r in rows {
            let _hazard: usize = r[6].parse().unwrap();
            let _epoch: usize = r[7].parse().unwrap();
            let _pages: u64 = r[8].parse().unwrap();
            let _batches: u64 = r[9].parse().unwrap();
            let _avg: f64 = r[10].parse().unwrap();
            let _shrinks: usize = r[11].parse().unwrap();
            let _final_buckets: usize = r[12].parse().unwrap();
        }
        // Both hash-table rows start undersized, so growth is forced and
        // every drained chain rides a retire_page batch: pages claimed
        // and batches retired must both be visible in the census.
        for imp in ["CacheHash(MemEff)", "Chaining(no-inline)"] {
            let r = rows.iter().find(|r| r[0] == imp).unwrap();
            let pages: u64 = r[8].parse().unwrap();
            let batches: u64 = r[9].parse().unwrap();
            assert!(pages > 0, "{imp}: no pool page claimed");
            assert!(batches > 0, "{imp}: no retire_page batch recorded");
        }
        // Cached-MemEff's pool bytes must be tiny vs inline (§3.2's
        // n-independence).
        let memeff = rows.iter().find(|r| r[0] == "Cached-MemEff").unwrap();
        let inline: usize = memeff[3].parse().unwrap();
        let pool: usize = memeff[5].parse().unwrap();
        assert!(pool * 100 < inline, "pool {pool} vs inline {inline}");
        // Cached-WaitFree must hold ~2x the value bytes (backup always
        // populated).
        let wf = rows.iter().find(|r| r[0] == "Cached-WaitFree").unwrap();
        let indirect: usize = wf[4].parse().unwrap();
        assert!(indirect >= (1 << 14) * K * 8);
        // The epoch-backed hash-table row must actually surface epoch
        // garbage: the deletions just retired thousands of chain links
        // on this thread, and at least the newest (< FREE_DISTANCE old)
        // cannot have been freed yet.
        let ch = rows.iter().find(|r| r[0] == "CacheHash(MemEff)").unwrap();
        let retired_epoch: usize = ch[7].parse().unwrap();
        assert!(retired_epoch > 0, "epoch census column still blind");
        // Both hash rows drain 15/16 of their keys then converge through
        // maintenance: the shrink columns must prove the peak footprint
        // was returned (at least one shrink, final capacity below peak).
        for imp in ["CacheHash(MemEff)", "Chaining(no-inline)"] {
            let r = rows.iter().find(|r| r[0] == imp).unwrap();
            let shrinks: usize = r[11].parse().unwrap();
            let final_buckets: usize = r[12].parse().unwrap();
            assert!(shrinks >= 1, "{imp}: no shrink generation completed");
            assert!(
                final_buckets < 1 << 14,
                "{imp}: capacity {final_buckets} not below peak"
            );
        }
    }
}
