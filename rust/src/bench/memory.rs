//! §5.5 memory census: measured live bytes per implementation vs the
//! paper's closed forms.
//!
//! Paper constants (k = element words, n = atomics, p = threads, c_h =
//! hazard collector slack): Indirect `n(k+1) + c_h·p(p+k)`, SimpLock
//! `n(k+1)`, SeqLock `n(k+1)`, Cached-WaitFree `2n(k+2) + c_h·p(p+k)`,
//! Cached-MemEff `n(k+2) + c_h·p(p+k)`.  We measure the three
//! components we can observe directly: inline slot bytes, live indirect
//! node bytes, and pool/retire bytes.

use std::sync::Arc;

use super::figures::{FigureCfg, Report};
use crate::atomics::{
    AtomicArray, BigAtomic, CachedMemEff, CachedWaitFree, Indirect, MemEffDomain, SeqLock,
    SimpLock, Words,
};
use crate::hash::{CacheHash, ConcurrentMap, LinkVal};
use crate::smr::{epoch, hazard};

const K: usize = 4; // census element size (words)

fn census_one<A: BigAtomic<Words<K>>>(n: usize) -> (usize, usize) {
    let arr: AtomicArray<Words<K>, A> = AtomicArray::new(n, Words([7; K]));
    // Touch every slot with an update so indirect structures are live.
    for i in 0..n {
        let cur = arr.get(i).load();
        let _ = arr.get(i).compare_exchange(cur, Words([i as u64 + 1; K]));
    }
    let inline = n * std::mem::size_of::<A>();
    let indirect = arr.indirect_bytes();
    (inline, indirect)
}

/// Produce the §5.5 table (also a regression test for the space bounds:
/// `rust/tests/properties.rs` asserts the measured/formula ratios).
///
/// Every row reports the retired-but-unfreed census of **both** SMR
/// schemes: the seed printed only `hazard::pending_reclaims()`, which
/// silently under-counted any epoch-backed configuration (the hash
/// tables' chain links and drained resize tables) as zero.
pub fn memory_census(_cfg: &FigureCfg) -> Report {
    let n = 1 << 14;
    let mut rep = Report::new(
        "memory_census",
        &[
            "impl",
            "n",
            "k",
            "inline_bytes",
            "indirect_bytes",
            "pool_bytes",
            "retired_hazard",
            "retired_epoch",
        ],
    );
    let mut row = |imp: &str, k: usize, inline: usize, indirect: usize, pool: usize| {
        rep.row(vec![
            imp.into(),
            n.to_string(),
            k.to_string(),
            inline.to_string(),
            indirect.to_string(),
            pool.to_string(),
            hazard::pending_reclaims().to_string(),
            epoch::pending_reclaims().to_string(),
        ]);
    };

    let (inline, ind) = census_one::<SeqLock<Words<K>>>(n);
    row("SeqLock", K, inline, ind, 0);

    let (inline, ind) = census_one::<SimpLock<Words<K>>>(n);
    row("SimpLock", K, inline, ind, 0);

    let (inline, ind) = census_one::<Indirect<Words<K>>>(n);
    row("Indirect", K, inline, ind, 0);

    let (inline, ind) = census_one::<CachedWaitFree<Words<K>>>(n);
    row("Cached-WaitFree", K, inline, ind, 0);

    // MemEff: use a private domain so the pool is attributable.
    let domain: Arc<MemEffDomain<Words<K>>> = Arc::new(MemEffDomain::new());
    let arr: Vec<CachedMemEff<Words<K>>> = (0..n)
        .map(|_| CachedMemEff::with_domain(Words([7; K]), Arc::clone(&domain)))
        .collect();
    for (i, a) in arr.iter().enumerate() {
        let cur = a.load();
        let _ = a.compare_exchange(cur, Words([i as u64 + 1; K]));
    }
    let inline = n * std::mem::size_of::<CachedMemEff<Words<K>>>();
    let pool_nodes = domain.allocated_nodes() as usize;
    // Node overhead: four flag bytes padded to words + the uninstall
    // stamp (see atomics::cached_memeff::Node).
    let pool_bytes = pool_nodes * (std::mem::size_of::<Words<K>>() + 40);
    row("Cached-MemEff", K, inline, 0, pool_bytes);

    // The epoch-backed configuration (§4: chain links protected by
    // epochs): insert n keys, delete half — the path-copied prefixes and
    // promoted heads become epoch garbage that the hazard column cannot
    // see. LinkVal is 3 words (the k column).
    let table: CacheHash<CachedMemEff<LinkVal>> = CacheHash::new(n);
    for i in 0..n as u64 {
        table.insert(crate::util::rng::mix64(i), i);
    }
    for i in 0..n as u64 / 2 {
        table.remove(crate::util::rng::mix64(i));
    }
    let inline = table.capacity() * std::mem::size_of::<CachedMemEff<LinkVal>>();
    row("CacheHash(MemEff)", 3, inline, 0, 0);

    rep
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn test_census_runs_and_memeff_pool_tiny() {
        let rep = memory_census(&FigureCfg::default());
        let rows = rep.rows();
        assert_eq!(rows.len(), 6);
        // Both reclamation columns must be present and parseable on
        // every row (the epoch column was silently missing pre-fix).
        for r in rows {
            let _hazard: usize = r[6].parse().unwrap();
            let _epoch: usize = r[7].parse().unwrap();
        }
        // Cached-MemEff's pool bytes must be tiny vs inline (§3.2's
        // n-independence).
        let memeff = rows.iter().find(|r| r[0] == "Cached-MemEff").unwrap();
        let inline: usize = memeff[3].parse().unwrap();
        let pool: usize = memeff[5].parse().unwrap();
        assert!(pool * 100 < inline, "pool {pool} vs inline {inline}");
        // Cached-WaitFree must hold ~2x the value bytes (backup always
        // populated).
        let wf = rows.iter().find(|r| r[0] == "Cached-WaitFree").unwrap();
        let indirect: usize = wf[4].parse().unwrap();
        assert!(indirect >= (1 << 14) * K * 8);
        // The epoch-backed hash-table row must actually surface epoch
        // garbage: the deletions just retired thousands of chain links
        // on this thread, and at least the newest (< FREE_DISTANCE old)
        // cannot have been freed yet.
        let ch = rows.iter().find(|r| r[0] == "CacheHash(MemEff)").unwrap();
        let retired_epoch: usize = ch[7].parse().unwrap();
        assert!(retired_epoch > 0, "epoch census column still blind");
    }
}
