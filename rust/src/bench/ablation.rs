//! Ablation studies for the design choices DESIGN.md calls out.
//!
//! 1. **Cached fast path** (the paper's core idea, §3): Cached-MemEff
//!    loads with the inlined cache vs. forced through the indirect
//!    (hazard-protected) route — isolates what inlining buys.
//! 2. **Inlined first link** (§4): CacheHash vs Chaining at matched
//!    parameters — the hash-level version of the same ablation.
//! 3. **Seqlock read concurrency** (§2): SeqLock (lock-free reads) vs
//!    SimpLock (locked reads) on a read-only workload — why sequence
//!    locks beat plain locks for load-heavy mixes.
//! 4. **Memory-ordering diet + contention management**
//!    (`--panel ordering`): blanket-`SeqCst` (the seed) vs the fenced
//!    diet vs fenced+adaptive-backoff, measured in one binary via the
//!    explicit `OrderingPolicy` instantiations of `SeqLock` and
//!    `CachedWaitFree` and the runtime backoff switch — the win of the
//!    diet is a number in the report, not a claim.
//! 5. **Reclamation scheme** (`--panel smr`): hazard pointers vs epochs
//!    on every pointer-protect backend (the `Smr` parameter), plus the
//!    epoch ordering-policy pair (`Epoch<Fenced>` vs
//!    `Epoch<SeqCstEverywhere>`) on the hash tables — the reclamation
//!    leg of the ordering diet, measured not claimed.
//! 6. **Growth under load** (`--panel resize`): tables constructed
//!    deliberately undersized (64 buckets for a `cfg.n`-key workload)
//!    vs pre-sized, driven update-heavy from empty — the cost of online
//!    resizing is a number, and the growth itself is reported (final
//!    bucket count + live-entry estimate per row).
//! 7. **Ingress arm** (`--panel ingress`): the KV service driven
//!    end-to-end through the lock-free sharded claim-queue front door
//!    vs the mailbox baseline, at worker counts from 1× up to 4× the
//!    hardware parallelism (the paper's oversubscription regime) —
//!    throughput plus p50/p99/p999 per-request latency and the shed
//!    count per row; the peak sustained ops/s of an arm is the max of
//!    its rows.
//! 8. **Page-pool allocation** (`--panel alloc`): both hash tables
//!    driven update-heavy (pure churn — the allocator-bound regime)
//!    with chain nodes served by the `smr::pool` page pool vs the
//!    headered boxed fallback, at 1×/2×/4× hardware parallelism. Each
//!    row reports throughput plus the orphan-lock-acquisition and
//!    retire-batch counter deltas (telemetry builds), so the batching
//!    claim — page-wise retirement amortizes the orphan traffic — is a
//!    number per row, not an assertion.
//!
//! Run with `repro ablate [--panel ordering|smr|resize|ingress|alloc]`.

use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::time::Duration;

use super::driver::{hw_threads, run_map, run_throughput, MapImpl, MapTarget, OpSource};
use super::figures::{FigureCfg, Report};
use super::workload::{WorkloadSpec, ZipfCdf};
use crate::atomics::{
    BigAtomic, CachedMemEff, CachedWaitFree, CachedWritable, Indirect, SeqLock, SimpLock, Words,
};
use crate::atomics::AtomicValue;
use crate::hash::{CacheHash, Chaining, ConcurrentMap, Link, LinkVal, Maintain};
use crate::smr::{Epoch, Hazard, Smr};
use crate::util::backoff;
use crate::util::ordering::{DefaultPolicy, Fenced, SeqCstEverywhere};
use crate::util::rng::Xoshiro256;
use crate::util::{ns_per_op, time_for};

const MEASURE: Duration = Duration::from_millis(250);

/// Ablation 1: load latency with vs without the cached fast path, at
/// varying "dirtiness" (fraction of slots with an in-flight update —
/// approximated here by quiescent slots, the fast path's best case,
/// which is exactly what the paper's common case is).
fn ablate_fast_path(rep: &mut Report) {
    let n = 1 << 12;
    let arr: Vec<CachedMemEff<Words<4>>> =
        (0..n).map(|i| CachedMemEff::new(Words([i as u64; 4]))).collect();
    let cdf = ZipfCdf::new(n, 0.0);
    let mut rng = Xoshiro256::seeded(123);

    let (iters, el) = time_for(MEASURE, || {
        let i = cdf.sample(&mut rng);
        std::hint::black_box(arr[i].load());
    });
    let fast_ns = ns_per_op(iters, el);

    let mut rng = Xoshiro256::seeded(123);
    let (iters, el) = time_for(MEASURE, || {
        let i = cdf.sample(&mut rng);
        std::hint::black_box(arr[i].load_no_fast_path());
    });
    let slow_ns = ns_per_op(iters, el);

    rep.row(vec![
        "memeff_load_cached_fast_path".into(),
        format!("{fast_ns:.1}"),
        format!("{slow_ns:.1}"),
        format!("{:.2}x", slow_ns / fast_ns),
    ]);
}

/// Ablation 3: read-only throughput, lock-free reads (SeqLock) vs
/// locked reads (SimpLock).
fn ablate_read_locking(rep: &mut Report) {
    let a: SeqLock<Words<4>> = SeqLock::new(Words([7; 4]));
    let b: SimpLock<Words<4>> = SimpLock::new(Words([7; 4]));
    let (iters, el) = time_for(MEASURE, || {
        std::hint::black_box(a.load());
    });
    let seq_ns = ns_per_op(iters, el);
    let (iters, el) = time_for(MEASURE, || {
        std::hint::black_box(b.load());
    });
    let simp_ns = ns_per_op(iters, el);
    rep.row(vec![
        "read_without_lock(seqlock_vs_simplock)".into(),
        format!("{seq_ns:.1}"),
        format!("{simp_ns:.1}"),
        format!("{:.2}x", simp_ns / seq_ns),
    ]);
}

/// One measurement point of ablation 4: p threads hammer one shared
/// atomic with witness-fed CAS-loop increments (contended Mop/s), then a
/// single thread measures quiescent load latency (uncontended ns/op).
fn ordering_point<A: BigAtomic<Words<4>>>(threads: usize, dur: Duration) -> (f64, f64) {
    let a = A::new(Words([0; 4]));
    let stop = AtomicBool::new(false);
    let total = AtomicU64::new(0);
    std::thread::scope(|s| {
        for _ in 0..threads {
            s.spawn(|| {
                let mut ops = 0u64;
                while !stop.load(Ordering::Relaxed) {
                    let _ = a.fetch_update(|mut v| {
                        v.0[0] = v.0[0].wrapping_add(1);
                        Some(v)
                    });
                    ops += 1;
                }
                total.fetch_add(ops, Ordering::Relaxed);
            });
        }
        std::thread::sleep(dur);
        stop.store(true, Ordering::SeqCst);
    });
    let mops = total.load(Ordering::SeqCst) as f64 / dur.as_secs_f64().max(1e-9) / 1e6;
    let (it, el) = time_for(dur.min(Duration::from_millis(100)), || {
        std::hint::black_box(a.load());
    });
    (mops, ns_per_op(it, el))
}

/// Ablation 4 (`repro ablate --panel ordering`): the three-variant
/// comparison — seqcst-everywhere vs fenced vs fenced+backoff — on the
/// two policy-parametric backends. The seqcst and fenced rows run with
/// backoff disabled so the ordering effect is isolated; the third row
/// re-enables the adaptive backoff on the fenced variant.
pub fn run_ordering_ablation(cfg: &FigureCfg) -> Report {
    let threads = hw_threads().max(2);
    let dur = cfg.dur();
    let mut rep = Report::new(
        "ablation_ordering",
        &["variant", "impl", "contended_casloop_mops", "uncontended_load_ns"],
    );
    let prev = backoff::enabled();
    {
        let mut row = |variant: &str, imp: &str, (mops, ns): (f64, f64)| {
            rep.row(vec![
                variant.into(),
                imp.into(),
                format!("{mops:.3}"),
                format!("{ns:.1}"),
            ]);
        };
        backoff::set_enabled(false);
        row(
            "seqcst",
            "SeqLock",
            ordering_point::<SeqLock<Words<4>, SeqCstEverywhere>>(threads, dur),
        );
        row(
            "seqcst",
            "Cached-WaitFree",
            ordering_point::<CachedWaitFree<Words<4>, SeqCstEverywhere>>(threads, dur),
        );
        row(
            "fenced",
            "SeqLock",
            ordering_point::<SeqLock<Words<4>, Fenced>>(threads, dur),
        );
        row(
            "fenced",
            "Cached-WaitFree",
            ordering_point::<CachedWaitFree<Words<4>, Fenced>>(threads, dur),
        );
        backoff::set_enabled(true);
        row(
            "fenced+backoff",
            "SeqLock",
            ordering_point::<SeqLock<Words<4>, Fenced>>(threads, dur),
        );
        row(
            "fenced+backoff",
            "Cached-WaitFree",
            ordering_point::<CachedWaitFree<Words<4>, Fenced>>(threads, dur),
        );
    }
    backoff::set_enabled(prev);
    rep
}

/// Ablation 5a (`repro ablate --panel smr`): hazard vs epoch on every
/// pointer-protect backend — contended witness-fed CAS-loop Mop/s and
/// uncontended load ns per (scheme, backend) pair, in one binary via the
/// `Smr` type parameter.
pub fn run_smr_ablation(cfg: &FigureCfg) -> Report {
    let threads = hw_threads().max(2);
    let dur = cfg.dur();
    let mut rep = Report::new(
        "ablation_smr",
        &["scheme", "impl", "contended_casloop_mops", "uncontended_load_ns"],
    );
    fn scheme_rows<S: Smr>(rep: &mut Report, threads: usize, dur: Duration) {
        let mut row = |imp: &str, (mops, ns): (f64, f64)| {
            rep.row(vec![
                S::NAME.into(),
                imp.into(),
                format!("{mops:.3}"),
                format!("{ns:.1}"),
            ]);
        };
        row("Indirect", ordering_point::<Indirect<Words<4>, S>>(threads, dur));
        row(
            "Cached-WaitFree",
            ordering_point::<CachedWaitFree<Words<4>, DefaultPolicy, S>>(threads, dur),
        );
        row(
            "Cached-MemEff",
            ordering_point::<CachedMemEff<Words<4>, DefaultPolicy, S>>(threads, dur),
        );
        row(
            "Cached-WF-Writable",
            ordering_point::<CachedWritable<Words<4>, S>>(threads, dur),
        );
    }
    scheme_rows::<Hazard>(&mut rep, threads, dur);
    scheme_rows::<Epoch>(&mut rep, threads, dur);
    rep
}

/// Ablation 5b: the epoch ordering-policy pair on the epoch consumers —
/// hash-table throughput under `Epoch<Fenced>` vs
/// `Epoch<SeqCstEverywhere>` (the reclamation leg of the ordering diet,
/// where the hash tables are the real workload).
pub fn run_smr_table_ablation(cfg: &FigureCfg, source: &OpSource) -> Report {
    let threads = hw_threads().max(2);
    let spec = WorkloadSpec {
        n: cfg.n,
        theta: 0.0,
        update_pct: 50,
        seed: 0x53,
    };
    let mut rep = Report::new("ablation_smr_tables", &["epoch_policy", "map", "mops"]);
    let mut point = |policy: &str, label: &str, map: Box<dyn ConcurrentMap>| {
        let target = MapTarget::new(map, &spec);
        let r = run_throughput(&target, &spec, threads, cfg.dur(), source);
        rep.row(vec![policy.into(), label.into(), format!("{:.3}", r.mops())]);
    };
    point(
        "fenced",
        "CacheHash(MemEff)",
        Box::new(CacheHash::<CachedMemEff<LinkVal>, u64, u64, Epoch<Fenced>>::new(spec.n)),
    );
    point(
        "seqcst",
        "CacheHash(MemEff)",
        Box::new(CacheHash::<CachedMemEff<LinkVal>, u64, u64, Epoch<SeqCstEverywhere>>::new(
            spec.n,
        )),
    );
    point(
        "fenced",
        "Chaining(no-inline)",
        Box::new(Chaining::<u64, u64, Epoch<Fenced>>::new(spec.n)),
    );
    point(
        "seqcst",
        "Chaining(no-inline)",
        Box::new(Chaining::<u64, u64, Epoch<SeqCstEverywhere>>::new(spec.n)),
    );
    rep
}

/// One shrink arm of ablation 6: grow a deliberately undersized table
/// to its workload peak, drain 15/16 of the keys (well below the
/// hysteresis band), then drive maintenance until the resize engine is
/// idle at a stable capacity. Returns (peak buckets, converged buckets,
/// live-entry estimate, Mop/s over the whole churn, shrink generations).
fn shrink_arm<K, V, M, FK, FV>(
    map: M,
    n: u64,
    key: FK,
    val: FV,
) -> (usize, usize, usize, f64, usize)
where
    K: AtomicValue,
    V: AtomicValue,
    M: ConcurrentMap<K, V> + Maintain,
    FK: Fn(u64) -> K,
    FV: Fn(u64) -> V,
{
    let t0 = std::time::Instant::now();
    for i in 0..n {
        map.insert(key(i), val(i));
    }
    let peak = map.capacity();
    for i in 0..n * 15 / 16 {
        map.remove(key(i));
    }
    let mut cap = map.capacity();
    loop {
        let idle = map.maintain();
        let now = map.capacity();
        if idle && now == cap {
            break;
        }
        cap = now;
    }
    let ops = (n + n * 15 / 16) as f64;
    let mops = ops / t0.elapsed().as_secs_f64().max(1e-9) / 1e6;
    (peak, cap, map.occupancy(), mops, map.shrink_generation())
}

/// Ablation 6 (`repro ablate --panel resize`): the resize panel, both
/// directions. The grow rows drive the update-heavy workload (u=100
/// over the full `cfg.n` key space) against an *empty* table, once
/// constructed undersized at 64 buckets (so the timed region absorbs
/// every doubling up to the steady-state size) and once pre-sized for
/// `cfg.n` — the throughput ratio is the online-resize toll, and the
/// reported final bucket count proves the growth actually ran. The
/// shrink rows ([`shrink_arm`]) grow, mass-drain, and converge through
/// maintenance — their `shrink_gens` column must be ≥ 1 and
/// `final_buckets` below `initial_buckets` (the peak), proving memory
/// is actually returned; the wide arm runs the same cycle on
/// `Words<4> → Words<4>` rows (§5.3's multi-word regime).
pub fn run_resize_ablation(cfg: &FigureCfg, source: &OpSource) -> Report {
    let threads = hw_threads().max(2);
    let spec = WorkloadSpec {
        n: cfg.n,
        theta: 0.0,
        update_pct: 100,
        seed: 0x5253, // "RS"
    };
    let mut rep = Report::new(
        "ablation_resize",
        &["map", "initial_buckets", "final_buckets", "entries_est", "mops", "shrink_gens"],
    );
    let mut point = |label: &str, map: Box<dyn ConcurrentMap>| {
        let initial = map.capacity();
        let target = MapTarget::new_unfilled(map);
        let r = run_throughput(&target, &spec, threads, cfg.dur(), source);
        let m = target.map();
        rep.row(vec![
            label.into(),
            initial.to_string(),
            m.capacity().to_string(),
            m.occupancy().to_string(),
            format!("{:.3}", r.mops()),
            m.shrink_generation().to_string(),
        ]);
    };
    point(
        "CacheHash(MemEff)/undersized",
        Box::new(CacheHash::<CachedMemEff<LinkVal>>::new(64)),
    );
    point(
        "CacheHash(MemEff)/presized",
        Box::new(CacheHash::<CachedMemEff<LinkVal>>::new(cfg.n)),
    );
    point("Chaining(no-inline)/undersized", Box::new(Chaining::new(64)));
    point("Chaining(no-inline)/presized", Box::new(Chaining::new(cfg.n)));

    type ShrinkStats = (usize, usize, usize, f64, usize);
    let mut shrink_row = |label: &str, (peak, fin, occ, mops, gens): ShrinkStats| {
        rep.row(vec![
            label.into(),
            peak.to_string(),
            fin.to_string(),
            occ.to_string(),
            format!("{mops:.3}"),
            gens.to_string(),
        ]);
    };
    let n = cfg.n as u64;
    let mix = crate::util::rng::mix64;
    shrink_row(
        "CacheHash(MemEff)/shrink",
        shrink_arm(
            CacheHash::<CachedMemEff<LinkVal>>::new(64),
            n,
            mix,
            |i| i,
        ),
    );
    shrink_row(
        "Chaining(no-inline)/shrink",
        shrink_arm(Chaining::new(64), n, mix, |i| i),
    );
    // Wide arm: checksummed 4-word rows through the same grow → drain →
    // converge cycle (the §5.3 k-word regime under shrink).
    type W = Words<4>;
    shrink_row(
        "CacheHash(Words4)/shrink-wide",
        shrink_arm(
            CacheHash::<CachedMemEff<Link<W, W>>, W, W>::new(64),
            n,
            |i| Words([mix(i), i, 0, 0]),
            |i| Words([i, i.wrapping_mul(3), !i, i ^ i.wrapping_mul(3) ^ !i]),
        ),
    );
    rep
}

/// Ablation 7 (`repro ablate --panel ingress`): lock-free claim-queue
/// ingress vs the mailbox baseline on the end-to-end KV service, at
/// 1×/2×/4× hardware-parallelism worker counts (the 4× point is the
/// oversubscribed regime the claim pattern is built for: a preempted
/// drainer never wedges producers, they just tally onto the head).
/// Each row reports throughput, histogram-exact latency quantiles, and
/// the shed count (zero here — admission waits, so the arms serve
/// identical offered load).
pub fn run_ingress_ablation(cfg: &FigureCfg) -> Report {
    use crate::coordinator::kv_service::{self, IngressMode, KvConfig};

    let base = hw_threads().max(2);
    let mut rep = Report::new(
        "ablation_ingress",
        &["ingress", "workers", "clients", "mops", "p50_ns", "p99_ns", "p999_ns", "shed"],
    );
    for mode in [IngressMode::Lockfree, IngressMode::Mailbox] {
        for mult in [1usize, 2, 4] {
            // Clamped to keep workers + clients well inside the thread
            // registry (MAX_THREADS = 256) even on very wide machines —
            // the shape test shares the registry with other parallel
            // tests in the same binary.
            let workers = (base * mult).min(96);
            let clients = (workers / 2).clamp(2, 12);
            let kv = KvConfig {
                n: cfg.n.max(1024),
                workers,
                clients,
                batch: 256,
                duration: cfg.dur(),
                theta: 0.0,
                ingress: mode,
                ..KvConfig::default()
            };
            let r = kv_service::run(&kv, None).expect("kv ingress ablation run");
            let (p50, p99) = match &r.latency {
                Some(l) => (l.p50, l.p99),
                None => (0.0, 0.0),
            };
            rep.row(vec![
                mode.name().into(),
                workers.to_string(),
                clients.to_string(),
                format!("{:.3}", r.mops()),
                format!("{p50:.0}"),
                format!("{p99:.0}"),
                r.latency_p999_ns.unwrap_or(0).to_string(),
                r.shed_batches.to_string(),
            ]);
        }
    }
    rep
}

/// Ablation 8 (`repro ablate --panel alloc`): pooled vs boxed chain-node
/// allocation under pure churn. The boxed arm flips the pool's runtime
/// toggle off (the per-slot provenance header keeps mixed populations
/// safe across the flip, exactly like the backoff switch in the
/// ordering panel), so both arms run identical table code — the only
/// variable is the allocation discipline. Counter columns are telemetry
/// deltas (zero without the feature): `orphan_locks` is the amortization
/// target, `retire_batches` proves page-wise retirement actually ran in
/// the pooled arm.
pub fn run_alloc_ablation(cfg: &FigureCfg, source: &OpSource) -> Report {
    use crate::obs::telemetry::{self, Event};

    let base = hw_threads().max(2);
    let spec = WorkloadSpec {
        n: cfg.n,
        theta: 0.0,
        update_pct: 100,
        seed: 0xA110C, // "ALLOC"
    };
    let mut rep = Report::new(
        "ablation_alloc",
        &["alloc", "map", "threads", "mops", "orphan_locks", "retire_batches"],
    );
    let prev = crate::smr::pool::enabled();
    for (arm, pooled) in [("pooled", true), ("boxed", false)] {
        crate::smr::pool::set_enabled(pooled);
        let mut point = |label: &str, threads: usize, map: Box<dyn ConcurrentMap>| {
            let target = MapTarget::new_unfilled(map);
            let locks0 = telemetry::total(Event::OrphanLock);
            let batches0 = telemetry::total(Event::RetireBatch);
            let r = run_throughput(&target, &spec, threads, cfg.dur(), source);
            let locks = telemetry::total(Event::OrphanLock) - locks0;
            let batches = telemetry::total(Event::RetireBatch) - batches0;
            rep.row(vec![
                arm.into(),
                label.into(),
                threads.to_string(),
                format!("{:.3}", r.mops()),
                locks.to_string(),
                batches.to_string(),
            ]);
        };
        for mult in [1usize, 2, 4] {
            let threads = base * mult;
            point(
                "CacheHash(MemEff)",
                threads,
                Box::new(CacheHash::<CachedMemEff<LinkVal>>::new(cfg.n)),
            );
            point("Chaining(no-inline)", threads, Box::new(Chaining::new(cfg.n)));
        }
    }
    crate::smr::pool::set_enabled(prev);
    rep
}

/// Run all ablations; returns the report (saved by the coordinator).
pub fn run_ablations(cfg: &FigureCfg, source: &OpSource) -> Report {
    let mut rep = Report::new(
        "ablations",
        &["ablation", "with_ns_or_mops", "without_ns_or_mops", "factor"],
    );
    ablate_fast_path(&mut rep);
    ablate_read_locking(&mut rep);

    // Ablation 2: inline vs no-inline hash at u=50, oversubscribed —
    // measured as throughput (Mop/s), higher is better.
    let spec = WorkloadSpec {
        n: cfg.n,
        theta: 0.0,
        update_pct: 50,
        seed: 0xAB,
    };
    let threads = 4 * super::driver::hw_threads();
    let with = run_map(MapImpl::CacheHashMemEff, &spec, threads, cfg.dur(), source);
    let without = run_map(MapImpl::Chaining, &spec, threads, cfg.dur(), source);
    rep.row(vec![
        "hash_inlined_first_link(oversub,u=50)".into(),
        format!("{:.3}", with.mops()),
        format!("{:.3}", without.mops()),
        format!("{:.2}x", with.mops() / without.mops()),
    ]);
    rep
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn test_ablations_run() {
        let cfg = FigureCfg {
            secs_per_point: 0.02,
            n: 512,
            report_dir: std::env::temp_dir()
                .join("big_atomics_ablate_test")
                .display()
                .to_string(),
            use_artifact: false,
        };
        let rep = run_ablations(&cfg, &OpSource::Rust);
        assert_eq!(rep.rows().len(), 3);
    }

    #[test]
    fn test_ordering_ablation_shape() {
        let cfg = FigureCfg {
            secs_per_point: 0.02,
            n: 256,
            report_dir: std::env::temp_dir()
                .join("big_atomics_ablate_ordering_test")
                .display()
                .to_string(),
            use_artifact: false,
        };
        let rep = run_ordering_ablation(&cfg);
        // 3 variants x 2 impls.
        assert_eq!(rep.rows().len(), 6);
        let variants: Vec<&str> = rep.rows().iter().map(|r| r[0].as_str()).collect();
        for v in ["seqcst", "fenced", "fenced+backoff"] {
            assert_eq!(variants.iter().filter(|x| **x == v).count(), 2, "{v}");
        }
        for row in rep.rows() {
            assert!(row[2].parse::<f64>().unwrap() > 0.0, "{row:?}");
            assert!(row[3].parse::<f64>().unwrap() > 0.0, "{row:?}");
        }
        // The toggle must be restored for the rest of the suite.
        assert!(backoff::enabled());
    }

    #[test]
    fn test_smr_ablation_shape() {
        let cfg = FigureCfg {
            secs_per_point: 0.02,
            n: 256,
            report_dir: std::env::temp_dir()
                .join("big_atomics_ablate_smr_test")
                .display()
                .to_string(),
            use_artifact: false,
        };
        let rep = run_smr_ablation(&cfg);
        // 2 schemes x 4 backends.
        assert_eq!(rep.rows().len(), 8);
        let schemes: Vec<&str> = rep.rows().iter().map(|r| r[0].as_str()).collect();
        for s in ["hazard", "epoch"] {
            assert_eq!(schemes.iter().filter(|x| **x == s).count(), 4, "{s}");
        }
        for row in rep.rows() {
            assert!(row[2].parse::<f64>().unwrap() > 0.0, "{row:?}");
            assert!(row[3].parse::<f64>().unwrap() > 0.0, "{row:?}");
        }
    }

    #[test]
    fn test_smr_table_ablation_shape() {
        let cfg = FigureCfg {
            secs_per_point: 0.02,
            n: 256,
            report_dir: std::env::temp_dir()
                .join("big_atomics_ablate_smr_tables_test")
                .display()
                .to_string(),
            use_artifact: false,
        };
        let rep = run_smr_table_ablation(&cfg, &OpSource::Rust);
        // 2 policies x 2 maps.
        assert_eq!(rep.rows().len(), 4);
        for row in rep.rows() {
            assert!(row[2].parse::<f64>().unwrap() > 0.0, "{row:?}");
        }
    }

    #[test]
    fn test_resize_ablation_shape_and_growth() {
        let cfg = FigureCfg {
            secs_per_point: 0.05,
            n: 4096,
            report_dir: std::env::temp_dir()
                .join("big_atomics_ablate_resize_test")
                .display()
                .to_string(),
            use_artifact: false,
        };
        let rep = run_resize_ablation(&cfg, &OpSource::Rust);
        // 2 maps x {undersized, presized} + 2 shrink arms + 1 wide arm.
        assert_eq!(rep.rows().len(), 7);
        for row in rep.rows() {
            let initial: usize = row[1].parse().unwrap();
            let fin: usize = row[2].parse().unwrap();
            let _entries: usize = row[3].parse().unwrap();
            assert!(row[4].parse::<f64>().unwrap() > 0.0, "{row:?}");
            let shrinks: usize = row[5].parse().unwrap();
            if row[0].contains("/shrink") {
                // Shrink arms: the engine must have returned memory.
                assert!(shrinks >= 1, "no shrink generation: {row:?}");
                assert!(fin < initial, "capacity not below peak: {row:?}");
                assert!(initial > 64, "shrink arm never grew: {row:?}");
            } else {
                assert!(fin >= initial, "grow arm shrank? {row:?}");
                if row[0].ends_with("undersized") {
                    assert_eq!(initial, 64, "{row:?}");
                    assert!(fin > 64, "undersized table never grew: {row:?}");
                }
            }
        }
    }

    #[test]
    fn test_ingress_ablation_shape() {
        let cfg = FigureCfg {
            secs_per_point: 0.05,
            n: 1024,
            report_dir: std::env::temp_dir()
                .join("big_atomics_ablate_ingress_test")
                .display()
                .to_string(),
            use_artifact: false,
        };
        let rep = run_ingress_ablation(&cfg);
        // 2 arms x 3 worker multipliers.
        assert_eq!(rep.rows().len(), 6);
        let arms: Vec<&str> = rep.rows().iter().map(|r| r[0].as_str()).collect();
        for a in ["lockfree", "mailbox"] {
            assert_eq!(arms.iter().filter(|x| **x == a).count(), 3, "{a}");
        }
        for row in rep.rows() {
            assert!(row[1].parse::<usize>().unwrap() >= 2, "{row:?}");
            assert!(row[2].parse::<usize>().unwrap() >= 2, "{row:?}");
            assert!(row[3].parse::<f64>().unwrap() > 0.0, "{row:?}");
            // Wait admission: nothing shed in either arm.
            assert_eq!(row[7], "0", "{row:?}");
        }
    }

    #[test]
    fn test_alloc_ablation_shape() {
        // The boxed arm disables the pool process-wide; serialize
        // against lib tests whose assertions need it live throughout.
        let _toggle = crate::smr::pool::TOGGLE_TEST_LOCK
            .lock()
            .unwrap_or_else(std::sync::PoisonError::into_inner);
        let cfg = FigureCfg {
            secs_per_point: 0.05,
            n: 1024,
            report_dir: std::env::temp_dir()
                .join("big_atomics_ablate_alloc_test")
                .display()
                .to_string(),
            use_artifact: false,
        };
        let rep = run_alloc_ablation(&cfg, &OpSource::Rust);
        // 2 arms x 2 maps x 3 thread multipliers.
        assert_eq!(rep.rows().len(), 12);
        let arms: Vec<&str> = rep.rows().iter().map(|r| r[0].as_str()).collect();
        for a in ["pooled", "boxed"] {
            assert_eq!(arms.iter().filter(|x| **x == a).count(), 6, "{a}");
        }
        for row in rep.rows() {
            assert!(row[2].parse::<usize>().unwrap() >= 2, "{row:?}");
            assert!(row[3].parse::<f64>().unwrap() > 0.0, "{row:?}");
            // Counter columns parse even when telemetry is off (zeros).
            let _locks: u64 = row[4].parse().unwrap();
            let _batches: u64 = row[5].parse().unwrap();
        }
        // The toggle must be restored for the rest of the suite.
        assert!(crate::smr::pool::enabled());
    }

    #[test]
    fn test_fast_path_is_faster() {
        // The ablated (indirect-only) load must be measurably slower —
        // this is the paper's core claim in one assert.
        let a: CachedMemEff<Words<4>> = CachedMemEff::new(Words([1; 4]));
        let (it_f, el_f) = time_for(Duration::from_millis(60), || {
            std::hint::black_box(a.load());
        });
        let (it_s, el_s) = time_for(Duration::from_millis(60), || {
            std::hint::black_box(a.load_no_fast_path());
        });
        let fast = ns_per_op(it_f, el_f);
        let slow = ns_per_op(it_s, el_s);
        assert!(
            slow > fast * 1.5,
            "fast path buys nothing? fast={fast:.1}ns slow={slow:.1}ns"
        );
    }
}
