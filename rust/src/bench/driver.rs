//! Benchmark targets and the timed multi-thread driver.
//!
//! A [`BenchTarget`] is something that executes one generated operation;
//! the two families are [`ArrayTarget`] (the §5.1 microbenchmark: a map
//! from `0..n` to big-atomic elements with a full/empty flag) and
//! [`MapTarget`] (the §5.2/5.3 hash-table benchmark).  The driver
//! pre-generates per-thread operation buffers (so stream generation —
//! Rust or the AOT artifact — is *outside* the timed region), then runs
//! p threads against the target for a fixed duration and reports Mop/s.

use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::time::{Duration, Instant};

use crate::atomics::{
    AtomicArray, BigAtomic, CachedMemEff, CachedWaitFree, CachedWritable, HtmSim, Indirect,
    LockPool, SeqLock, SimpLock, Words,
};
use crate::hash::{
    CacheHash, Chaining, ConcurrentMap, GlobalLockMap, Link, LinkVal, ShardedLockMap,
};
use crate::runtime::workload_gen::WorkloadEngine;
use crate::util::error::Result;
use crate::util::rng::mix64;

use super::workload::{generate_rust, GenOp, Op, WorkloadSpec};

/// Executes generated operations.
pub trait BenchTarget: Send + Sync {
    fn exec(&self, op: &GenOp);
    fn label(&self) -> String;
}

// ---------------------------------------------------------------------
// §5.1 microbenchmark target: array of big atomics with full/empty flag.
// ---------------------------------------------------------------------

/// Array element layout: word0 = full flag, words 1.. = payload.
pub struct ArrayTarget<const K: usize, A: BigAtomic<Words<K>>> {
    arr: AtomicArray<Words<K>, A>,
}

impl<const K: usize, A: BigAtomic<Words<K>>> ArrayTarget<K, A> {
    /// Half the slots start full (even ranks) so inserts and deletes both
    /// have work in steady state.
    pub fn new(n: usize) -> Self {
        let arr: AtomicArray<Words<K>, A> = AtomicArray::new(n, Words([0; K]));
        for i in (0..n).step_by(2) {
            let mut v = [0u64; K];
            v[0] = 1;
            if K > 1 {
                v[1] = i as u64;
            }
            arr.get(i).store(Words(v));
        }
        Self { arr }
    }

    pub fn array(&self) -> &AtomicArray<Words<K>, A> {
        &self.arr
    }
}

impl<const K: usize, A: BigAtomic<Words<K>>> BenchTarget for ArrayTarget<K, A> {
    #[inline]
    fn exec(&self, op: &GenOp) {
        let slot = self.arr.get(op.rank as usize);
        match op.op {
            Op::Find => {
                let v = slot.load();
                std::hint::black_box(v);
            }
            Op::Insert => {
                let cur = slot.load();
                if cur.0[0] == 0 {
                    let mut v = [0u64; K];
                    v[0] = 1;
                    if K > 1 {
                        v[1] = op.key;
                    }
                    // Single attempt, paper semantics: a lost race means
                    // the slot is no longer empty. The witness is
                    // discarded (no retry) by design.
                    let _ = slot.compare_exchange(cur, Words(v));
                }
            }
            Op::Delete => {
                let cur = slot.load();
                if cur.0[0] == 1 {
                    let _ = slot.compare_exchange(cur, Words([0; K]));
                }
            }
        }
    }

    fn label(&self) -> String {
        format!("{}[k={}]", A::name(), K)
    }
}

/// The `fetch_update` op mix: updates are read-modify-write increments
/// (the paper's §2 "handful of fields updated together" shape) instead
/// of blind flag CASes — every update *must* land, so contention cost is
/// the witness-fed retry loop itself. Finds stay plain loads.
pub struct FetchUpdateTarget<const K: usize, A: BigAtomic<Words<K>>> {
    arr: AtomicArray<Words<K>, A>,
}

impl<const K: usize, A: BigAtomic<Words<K>>> FetchUpdateTarget<K, A> {
    pub fn new(n: usize) -> Self {
        Self {
            arr: AtomicArray::new(n, Words([0; K])),
        }
    }

    /// Sum of word-0 counters (equals the number of update ops executed
    /// — the driver test's exactness check).
    pub fn counter_sum(&self) -> u64 {
        (0..self.arr.len())
            .map(|i| self.arr.get(i).load().0[0])
            .sum()
    }
}

impl<const K: usize, A: BigAtomic<Words<K>>> BenchTarget for FetchUpdateTarget<K, A> {
    #[inline]
    fn exec(&self, op: &GenOp) {
        let slot = self.arr.get(op.rank as usize);
        if op.op.is_update() {
            let _ = slot
                .fetch_update(|mut v| {
                    v.0[0] = v.0[0].wrapping_add(1);
                    if K > 1 {
                        v.0[K - 1] = op.key;
                    }
                    Some(v)
                })
                .expect("unconditional update");
        } else {
            std::hint::black_box(slot.load());
        }
    }

    fn label(&self) -> String {
        format!("{}[k={},fetch_update]", A::name(), K)
    }
}

/// The big-atomic implementations under test (paper Table 1 rows).
#[derive(Copy, Clone, PartialEq, Eq, Debug)]
pub enum AtomicImpl {
    SeqLock,
    SimpLock,
    LockPool,
    Indirect,
    CachedWaitFree,
    CachedMemEff,
    CachedWritable,
    HtmSim,
}

impl AtomicImpl {
    /// The §5.1 comparison set, in the paper's legend order.
    pub const ALL: [AtomicImpl; 8] = [
        AtomicImpl::SeqLock,
        AtomicImpl::SimpLock,
        AtomicImpl::LockPool,
        AtomicImpl::Indirect,
        AtomicImpl::CachedWaitFree,
        AtomicImpl::CachedMemEff,
        AtomicImpl::CachedWritable,
        AtomicImpl::HtmSim,
    ];

    /// The headline subset most figures sweep.
    pub const CORE: [AtomicImpl; 6] = [
        AtomicImpl::SeqLock,
        AtomicImpl::SimpLock,
        AtomicImpl::LockPool,
        AtomicImpl::Indirect,
        AtomicImpl::CachedWaitFree,
        AtomicImpl::CachedMemEff,
    ];

    pub fn name(&self) -> &'static str {
        match self {
            AtomicImpl::SeqLock => "SeqLock",
            AtomicImpl::SimpLock => "SimpLock",
            AtomicImpl::LockPool => "LockPool(std::atomic)",
            AtomicImpl::Indirect => "Indirect",
            AtomicImpl::CachedWaitFree => "Cached-WaitFree",
            AtomicImpl::CachedMemEff => "Cached-MemEff",
            AtomicImpl::CachedWritable => "Cached-WF-Writable",
            AtomicImpl::HtmSim => "HTM(sim)",
        }
    }

    pub fn from_name(s: &str) -> Option<AtomicImpl> {
        Self::ALL.iter().copied().find(|i| {
            i.name().eq_ignore_ascii_case(s)
                || i.name().to_lowercase().starts_with(&s.to_lowercase())
        })
    }
}

/// Element sizes (words) the monomorphized targets support — the
/// paper's w sweep points (3 = the hash-link size used by the
/// cross-section figures).
pub const SUPPORTED_K: &[usize] = &[1, 2, 3, 4, 8, 16];

/// Build an array target for (implementation, element words k, size n).
/// `k` outside [`SUPPORTED_K`] is an `Err` (the element size selects a
/// monomorphized instantiation; it cannot be constructed at runtime).
pub fn make_array_target(imp: AtomicImpl, k: usize, n: usize) -> Result<Box<dyn BenchTarget>> {
    macro_rules! for_k {
        ($kk:literal) => {{
            match imp {
                AtomicImpl::SeqLock => {
                    Box::new(ArrayTarget::<$kk, SeqLock<Words<$kk>>>::new(n)) as Box<dyn BenchTarget>
                }
                AtomicImpl::SimpLock => Box::new(ArrayTarget::<$kk, SimpLock<Words<$kk>>>::new(n)),
                AtomicImpl::LockPool => Box::new(ArrayTarget::<$kk, LockPool<Words<$kk>>>::new(n)),
                AtomicImpl::Indirect => Box::new(ArrayTarget::<$kk, Indirect<Words<$kk>>>::new(n)),
                AtomicImpl::CachedWaitFree => {
                    Box::new(ArrayTarget::<$kk, CachedWaitFree<Words<$kk>>>::new(n))
                }
                AtomicImpl::CachedMemEff => {
                    Box::new(ArrayTarget::<$kk, CachedMemEff<Words<$kk>>>::new(n))
                }
                AtomicImpl::CachedWritable => {
                    Box::new(ArrayTarget::<$kk, CachedWritable<Words<$kk>>>::new(n))
                }
                AtomicImpl::HtmSim => Box::new(ArrayTarget::<$kk, HtmSim<Words<$kk>>>::new(n)),
            }
        }};
    }
    Ok(match k {
        1 => for_k!(1),
        2 => for_k!(2),
        3 => for_k!(3),
        4 => for_k!(4),
        8 => for_k!(8),
        16 => for_k!(16),
        other => crate::bail!("unsupported element size k={other} (use {SUPPORTED_K:?})"),
    })
}

/// Build a `fetch_update`-mix target for (implementation, element words
/// k, size n) — the read-modify-write companion of [`make_array_target`].
/// Same [`SUPPORTED_K`] contract.
pub fn make_fetch_update_target(
    imp: AtomicImpl,
    k: usize,
    n: usize,
) -> Result<Box<dyn BenchTarget>> {
    macro_rules! for_k {
        ($kk:literal) => {{
            match imp {
                AtomicImpl::SeqLock => {
                    Box::new(FetchUpdateTarget::<$kk, SeqLock<Words<$kk>>>::new(n))
                        as Box<dyn BenchTarget>
                }
                AtomicImpl::SimpLock => {
                    Box::new(FetchUpdateTarget::<$kk, SimpLock<Words<$kk>>>::new(n))
                }
                AtomicImpl::LockPool => {
                    Box::new(FetchUpdateTarget::<$kk, LockPool<Words<$kk>>>::new(n))
                }
                AtomicImpl::Indirect => {
                    Box::new(FetchUpdateTarget::<$kk, Indirect<Words<$kk>>>::new(n))
                }
                AtomicImpl::CachedWaitFree => {
                    Box::new(FetchUpdateTarget::<$kk, CachedWaitFree<Words<$kk>>>::new(n))
                }
                AtomicImpl::CachedMemEff => {
                    Box::new(FetchUpdateTarget::<$kk, CachedMemEff<Words<$kk>>>::new(n))
                }
                AtomicImpl::CachedWritable => {
                    Box::new(FetchUpdateTarget::<$kk, CachedWritable<Words<$kk>>>::new(n))
                }
                AtomicImpl::HtmSim => {
                    Box::new(FetchUpdateTarget::<$kk, HtmSim<Words<$kk>>>::new(n))
                }
            }
        }};
    }
    Ok(match k {
        1 => for_k!(1),
        2 => for_k!(2),
        3 => for_k!(3),
        4 => for_k!(4),
        8 => for_k!(8),
        16 => for_k!(16),
        other => crate::bail!("unsupported element size k={other} (use {SUPPORTED_K:?})"),
    })
}

// ---------------------------------------------------------------------
// §5.2/5.3 hash-table target.
// ---------------------------------------------------------------------

pub struct MapTarget {
    map: Box<dyn ConcurrentMap>,
}

impl MapTarget {
    /// Prefill half the key space (load factor ~0.5 steady state so all
    /// three op kinds do real work; the table is sized for n).
    pub fn new(map: Box<dyn ConcurrentMap>, spec: &WorkloadSpec) -> Self {
        for rank in (0..spec.n).step_by(2) {
            let key = crate::util::rng::mix64(rank as u64);
            map.insert(key, rank as u64);
        }
        Self { map }
    }

    /// No prefill — the growth-under-load panel starts deliberately
    /// undersized *and* empty, so the timed region includes filling the
    /// table and every online resize that filling triggers.
    pub fn new_unfilled(map: Box<dyn ConcurrentMap>) -> Self {
        Self { map }
    }

    /// The map under test (capacity/occupancy probes after a run).
    pub fn map(&self) -> &dyn ConcurrentMap {
        &*self.map
    }
}

impl BenchTarget for MapTarget {
    #[inline]
    fn exec(&self, op: &GenOp) {
        match op.op {
            Op::Find => {
                std::hint::black_box(self.map.find(op.key));
            }
            Op::Insert => {
                let _ = self.map.insert(op.key, op.rank as u64);
            }
            Op::Delete => {
                let _ = self.map.remove(op.key);
            }
        }
    }

    fn label(&self) -> String {
        self.map.map_name().to_string()
    }
}

// ---------------------------------------------------------------------
// §5.3 arbitrary-length-key/value hash-table target.
// ---------------------------------------------------------------------

/// Key/value width (words) of the wide map workload.
pub const WIDE_WORDS: usize = 4;

/// Expand a benchmark key into the 4-word key the §5.3 comparison feeds
/// the generic tables (deterministic, collision-free in word 0).
#[inline]
pub fn widen_key(key: u64) -> Words<WIDE_WORDS> {
    Words([key, mix64(key), key.rotate_left(17), !key])
}

/// The §5.3 arbitrary-length workload: a `CacheHash` with 4-word keys
/// *and* 4-word values (a 9-word inlined link), driven by the same
/// generated op stream as [`MapTarget`].
pub struct WideMapTarget<A: BigAtomic<Link<Words<WIDE_WORDS>, Words<WIDE_WORDS>>>> {
    map: CacheHash<A, Words<WIDE_WORDS>, Words<WIDE_WORDS>>,
}

impl<A: BigAtomic<Link<Words<WIDE_WORDS>, Words<WIDE_WORDS>>>> WideMapTarget<A> {
    /// Prefill half the key space, like [`MapTarget::new`].
    pub fn new(spec: &WorkloadSpec) -> Self {
        let map: CacheHash<A, Words<WIDE_WORDS>, Words<WIDE_WORDS>> = CacheHash::new(spec.n);
        for rank in (0..spec.n).step_by(2) {
            let key = widen_key(mix64(rank as u64));
            map.insert(key, Words([rank as u64; WIDE_WORDS]));
        }
        Self { map }
    }
}

impl<A: BigAtomic<Link<Words<WIDE_WORDS>, Words<WIDE_WORDS>>>> BenchTarget for WideMapTarget<A> {
    #[inline]
    fn exec(&self, op: &GenOp) {
        let key = widen_key(op.key);
        match op.op {
            Op::Find => {
                std::hint::black_box(self.map.find(key));
            }
            Op::Insert => {
                let _ = self.map.insert(key, Words([op.rank as u64; WIDE_WORDS]));
            }
            Op::Delete => {
                let _ = self.map.remove(key);
            }
        }
    }

    fn label(&self) -> String {
        format!("{}[wide k/v={}w]", self.map.map_name(), WIDE_WORDS)
    }
}

/// Build a wide-map target over any big-atomic strategy.
pub fn make_wide_map_target(imp: AtomicImpl, spec: &WorkloadSpec) -> Box<dyn BenchTarget> {
    type L = Link<Words<WIDE_WORDS>, Words<WIDE_WORDS>>;
    match imp {
        AtomicImpl::SeqLock => Box::new(WideMapTarget::<SeqLock<L>>::new(spec)),
        AtomicImpl::SimpLock => Box::new(WideMapTarget::<SimpLock<L>>::new(spec)),
        AtomicImpl::LockPool => Box::new(WideMapTarget::<LockPool<L>>::new(spec)),
        AtomicImpl::Indirect => Box::new(WideMapTarget::<Indirect<L>>::new(spec)),
        AtomicImpl::CachedWaitFree => Box::new(WideMapTarget::<CachedWaitFree<L>>::new(spec)),
        AtomicImpl::CachedMemEff => Box::new(WideMapTarget::<CachedMemEff<L>>::new(spec)),
        AtomicImpl::CachedWritable => Box::new(WideMapTarget::<CachedWritable<L>>::new(spec)),
        AtomicImpl::HtmSim => Box::new(WideMapTarget::<HtmSim<L>>::new(spec)),
    }
}

/// The hash-table implementations under comparison.
#[derive(Copy, Clone, PartialEq, Eq, Debug)]
pub enum MapImpl {
    CacheHashSeqLock,
    CacheHashSimpLock,
    CacheHashIndirect,
    CacheHashWaitFree,
    CacheHashMemEff,
    CacheHashWritable,
    CacheHashHtm,
    Chaining,
    ShardedLock,
    GlobalLock,
}

impl MapImpl {
    /// Fig 3 set: CacheHash over the big-atomic strategies + Chaining.
    pub const FIG3: [MapImpl; 6] = [
        MapImpl::CacheHashSeqLock,
        MapImpl::CacheHashSimpLock,
        MapImpl::CacheHashIndirect,
        MapImpl::CacheHashWaitFree,
        MapImpl::CacheHashMemEff,
        MapImpl::Chaining,
    ];

    /// Fig 4 set: our two best vs the open-source stand-ins.
    pub const FIG4: [MapImpl; 5] = [
        MapImpl::CacheHashMemEff,
        MapImpl::CacheHashSeqLock,
        MapImpl::Chaining,
        MapImpl::ShardedLock,
        MapImpl::GlobalLock,
    ];

    pub fn name(&self) -> &'static str {
        match self {
            MapImpl::CacheHashSeqLock => "CacheHash(SeqLock)",
            MapImpl::CacheHashSimpLock => "CacheHash(SimpLock)",
            MapImpl::CacheHashIndirect => "CacheHash(Indirect)",
            MapImpl::CacheHashWaitFree => "CacheHash(WaitFree)",
            MapImpl::CacheHashMemEff => "CacheHash(MemEff)",
            MapImpl::CacheHashWritable => "CacheHash(Writable)",
            MapImpl::CacheHashHtm => "CacheHash(HTMsim)",
            MapImpl::Chaining => "Chaining(no-inline)",
            MapImpl::ShardedLock => "ShardedLock(os-standin)",
            MapImpl::GlobalLock => "GlobalLock(floor)",
        }
    }

    pub fn build(&self, n: usize, threads: usize) -> Box<dyn ConcurrentMap> {
        match self {
            MapImpl::CacheHashSeqLock => Box::new(CacheHash::<SeqLock<LinkVal>>::new(n)),
            MapImpl::CacheHashSimpLock => Box::new(CacheHash::<SimpLock<LinkVal>>::new(n)),
            MapImpl::CacheHashIndirect => Box::new(CacheHash::<Indirect<LinkVal>>::new(n)),
            MapImpl::CacheHashWaitFree => Box::new(CacheHash::<CachedWaitFree<LinkVal>>::new(n)),
            MapImpl::CacheHashMemEff => Box::new(CacheHash::<CachedMemEff<LinkVal>>::new(n)),
            MapImpl::CacheHashWritable => Box::new(CacheHash::<CachedWritable<LinkVal>>::new(n)),
            MapImpl::CacheHashHtm => Box::new(CacheHash::<HtmSim<LinkVal>>::new(n)),
            MapImpl::Chaining => Box::new(Chaining::new(n)),
            MapImpl::ShardedLock => Box::new(ShardedLockMap::new(n, threads * 4)),
            MapImpl::GlobalLock => Box::new(GlobalLockMap::new(n)),
        }
    }
}

// ---------------------------------------------------------------------
// The timed driver.
// ---------------------------------------------------------------------

/// Where operation streams come from.
pub enum OpSource<'a> {
    /// Pure-Rust sampler (default).
    Rust,
    /// The AOT-compiled workload model via PJRT.
    Artifact(&'a WorkloadEngine),
}

#[derive(Clone, Debug)]
pub struct RunResult {
    pub label: String,
    pub threads: usize,
    pub total_ops: u64,
    pub elapsed: Duration,
}

impl RunResult {
    /// Throughput in million ops/second (the paper reports Bop/s; at this
    /// machine's scale Mop/s is the readable unit — shapes are unchanged).
    pub fn mops(&self) -> f64 {
        self.total_ops as f64 / self.elapsed.as_secs_f64() / 1e6
    }
}

/// Ops pre-generated per thread (looped over during the timed region).
pub const OPS_PER_THREAD: usize = 1 << 15;

/// Run `target` for `duration` with `threads` threads over streams from
/// `source`.
pub fn run_throughput(
    target: &dyn BenchTarget,
    spec: &WorkloadSpec,
    threads: usize,
    duration: Duration,
    source: &OpSource,
) -> RunResult {
    // Stream generation happens before the clock starts.
    let buffers: Vec<Vec<GenOp>> = (0..threads)
        .map(|t| match source {
            OpSource::Rust => generate_rust(spec, OPS_PER_THREAD, t as u64),
            OpSource::Artifact(engine) => engine
                .generate(spec, OPS_PER_THREAD, t as u64)
                .expect("artifact generation failed"),
        })
        .collect();

    let stop = AtomicBool::new(false);
    let started = std::sync::Barrier::new(threads + 1);
    let total = AtomicU64::new(0);

    let elapsed = std::thread::scope(|s| {
        for buf in &buffers {
            s.spawn(|| {
                started.wait();
                let mut ops = 0u64;
                'outer: loop {
                    for chunk in buf.chunks(512) {
                        for op in chunk {
                            target.exec(op);
                        }
                        ops += chunk.len() as u64;
                        if stop.load(Ordering::Relaxed) {
                            break 'outer;
                        }
                    }
                }
                total.fetch_add(ops, Ordering::Relaxed);
            });
        }
        started.wait();
        let t0 = Instant::now();
        std::thread::sleep(duration);
        stop.store(true, Ordering::SeqCst);
        t0.elapsed()
        // scope joins all threads here
    });

    RunResult {
        label: target.label(),
        threads,
        total_ops: total.load(Ordering::SeqCst),
        elapsed,
    }
}

/// Convenience wrapper: array benchmark for one configuration point.
/// `Err` only for `k` outside [`SUPPORTED_K`].
pub fn run_atomics(
    imp: AtomicImpl,
    k: usize,
    spec: &WorkloadSpec,
    threads: usize,
    duration: Duration,
    source: &OpSource,
) -> Result<RunResult> {
    let target = make_array_target(imp, k, spec.n)?;
    Ok(run_throughput(&*target, spec, threads, duration, source))
}

/// Convenience wrapper: hash-table benchmark for one configuration point.
pub fn run_map(
    imp: MapImpl,
    spec: &WorkloadSpec,
    threads: usize,
    duration: Duration,
    source: &OpSource,
) -> RunResult {
    let target = MapTarget::new(imp.build(spec.n, threads), spec);
    run_throughput(&target, spec, threads, duration, source)
}

/// Convenience wrapper: the `fetch_update` op-mix benchmark.
/// `Err` only for `k` outside [`SUPPORTED_K`].
pub fn run_fetch_update(
    imp: AtomicImpl,
    k: usize,
    spec: &WorkloadSpec,
    threads: usize,
    duration: Duration,
    source: &OpSource,
) -> Result<RunResult> {
    let target = make_fetch_update_target(imp, k, spec.n)?;
    Ok(run_throughput(&*target, spec, threads, duration, source))
}

/// Convenience wrapper: the §5.3 wide (4-word key/value) hash-table
/// benchmark.
pub fn run_map_wide(
    imp: AtomicImpl,
    spec: &WorkloadSpec,
    threads: usize,
    duration: Duration,
    source: &OpSource,
) -> RunResult {
    let target = make_wide_map_target(imp, spec);
    run_throughput(&*target, spec, threads, duration, source)
}

/// This machine's hardware parallelism (the paper's "96 SMT threads"
/// reference point; 1 on the CI container — see DESIGN.md).
pub fn hw_threads() -> usize {
    std::thread::available_parallelism()
        .map(|n| n.get())
        .unwrap_or(1)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny_spec() -> WorkloadSpec {
        WorkloadSpec {
            n: 256,
            theta: 0.5,
            update_pct: 50,
            seed: 1,
        }
    }

    #[test]
    fn test_unsupported_k_is_err_not_panic() {
        // Regression: the seed panicked on out-of-set element sizes.
        for k in [0usize, 5, 7, 32] {
            assert!(make_array_target(AtomicImpl::SeqLock, k, 8).is_err(), "k={k}");
            assert!(make_fetch_update_target(AtomicImpl::SeqLock, k, 8).is_err(), "k={k}");
        }
        for &k in SUPPORTED_K {
            assert!(make_array_target(AtomicImpl::SeqLock, k, 8).is_ok(), "k={k}");
        }
    }

    #[test]
    fn test_array_target_exec_all_ops() {
        let t = make_array_target(AtomicImpl::CachedMemEff, 4, 64).unwrap();
        for (i, opk) in [Op::Find, Op::Insert, Op::Delete].iter().cycle().take(300).enumerate() {
            t.exec(&GenOp {
                op: *opk,
                rank: (i % 64) as u32,
                key: i as u64,
            });
        }
    }

    #[test]
    fn test_run_throughput_counts_ops() {
        let spec = tiny_spec();
        let r = run_atomics(
            AtomicImpl::SeqLock,
            2,
            &spec,
            2,
            Duration::from_millis(50),
            &OpSource::Rust,
        )
        .unwrap();
        assert!(r.total_ops > 1000, "only {} ops", r.total_ops);
        assert!(r.mops() > 0.0);
    }

    #[test]
    fn test_run_map_all_impls_smoke() {
        let spec = WorkloadSpec {
            n: 128,
            theta: 0.0,
            update_pct: 50,
            seed: 2,
        };
        for imp in [
            MapImpl::CacheHashMemEff,
            MapImpl::Chaining,
            MapImpl::ShardedLock,
            MapImpl::GlobalLock,
        ] {
            let r = run_map(imp, &spec, 2, Duration::from_millis(20), &OpSource::Rust);
            assert!(r.total_ops > 100, "{}: {} ops", imp.name(), r.total_ops);
        }
    }

    #[test]
    fn test_all_array_impls_and_sizes_smoke() {
        let spec = tiny_spec();
        for imp in AtomicImpl::ALL {
            let r = run_atomics(imp, 1, &spec, 1, Duration::from_millis(10), &OpSource::Rust)
                .unwrap();
            assert!(r.total_ops > 0, "{}", imp.name());
        }
        for k in [2usize, 8, 16] {
            let r = run_atomics(
                AtomicImpl::CachedMemEff,
                k,
                &spec,
                1,
                Duration::from_millis(10),
                &OpSource::Rust,
            )
            .unwrap();
            assert!(r.total_ops > 0, "k={k}");
        }
    }

    #[test]
    fn test_fetch_update_target_counts_exactly() {
        // Every update op must land exactly once, even under contention:
        // the witness-fed retry loop is the thing under test.
        let t: FetchUpdateTarget<2, CachedMemEff<Words<2>>> = FetchUpdateTarget::new(64);
        let spec = tiny_spec();
        let ops = generate_rust(&spec, 4_000, 3);
        let updates = ops.iter().filter(|o| o.op.is_update()).count() as u64;
        std::thread::scope(|s| {
            for chunk in ops.chunks(1_000) {
                let t = &t;
                s.spawn(move || {
                    for op in chunk {
                        // Clamp rank into the 64-slot array.
                        let mut op = *op;
                        op.rank %= 64;
                        t.exec(&op);
                    }
                });
            }
        });
        assert_eq!(t.counter_sum(), updates);
    }

    #[test]
    fn test_run_fetch_update_all_impls_smoke() {
        let spec = tiny_spec();
        for imp in AtomicImpl::ALL {
            let r = run_fetch_update(imp, 4, &spec, 2, Duration::from_millis(15), &OpSource::Rust)
                .unwrap();
            assert!(r.total_ops > 100, "{}: {} ops", imp.name(), r.total_ops);
            assert!(r.label.contains("fetch_update"));
        }
    }

    #[test]
    fn test_run_map_wide_smoke() {
        let spec = WorkloadSpec {
            n: 256,
            theta: 0.5,
            update_pct: 50,
            seed: 5,
        };
        for imp in [AtomicImpl::CachedMemEff, AtomicImpl::SeqLock] {
            let r = run_map_wide(imp, &spec, 2, Duration::from_millis(25), &OpSource::Rust);
            assert!(r.total_ops > 100, "{}: {} ops", imp.name(), r.total_ops);
            assert!(r.label.contains("wide"));
        }
    }

    #[test]
    fn test_widen_key_injective_word0() {
        for k in [0u64, 1, 99, u64::MAX] {
            assert_eq!(widen_key(k).0[0], k);
        }
        assert_ne!(widen_key(1), widen_key(2));
    }

    #[test]
    fn test_impl_from_name() {
        assert_eq!(AtomicImpl::from_name("seqlock"), Some(AtomicImpl::SeqLock));
        assert_eq!(
            AtomicImpl::from_name("Cached-MemEff"),
            Some(AtomicImpl::CachedMemEff)
        );
        assert_eq!(AtomicImpl::from_name("nope"), None);
    }
}
