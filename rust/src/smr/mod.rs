//! Safe memory reclamation (SMR), unified behind the [`Smr`] trait.
//!
//! The paper's indirect big-atomic nodes are heap values read through
//! pointers that concurrent updaters unlink; reclamation must wait until
//! no reader can still hold the pointer (§2).  Two schemes, both
//! implementations of one policy-parametric interface:
//!
//! * [`Hazard`] — hazard pointers [Michael '04] with cached per-thread
//!   slots (see [`hazard`]), the default for the pointer-protect
//!   consumers: `Indirect`, `CachedWaitFree` (Alg 1), `CachedWritable`
//!   (Alg 3), and the announcement array of Alg 2's slab recycler.
//! * [`Epoch`] — epoch-based reclamation (see [`epoch`]), the default
//!   for the hash tables' chain links (§4: "We use epoch-based memory
//!   management to protect the links that are being read").  Generic
//!   over [`OrderingPolicy`](crate::util::ordering::OrderingPolicy):
//!   `Epoch<Fenced>` is the dieted protocol (Acquire/Release/Relaxed
//!   plus two named `fence(SeqCst)` store-load points),
//!   `Epoch<SeqCstEverywhere>` restores the seed's blanket `SeqCst`.
//!
//! ## The trait split: [`Smr`] vs [`RegionSmr`]
//!
//! [`Smr`] is *pointer-grained*: a [`pin`](Smr::pin)ned guard protects
//! exactly the pointers it [`protect_ptr`](SmrGuard::protect_ptr)s /
//! [`protect_raw`](SmrGuard::protect_raw)s.  Both schemes implement it,
//! so every pointer-protect backend is generic over the scheme
//! (`Indirect<T, S>`, `CachedWaitFree<T, P, S>`, `CachedWritable<T, S>`,
//! `CachedMemEff<T, P, S>`) and `repro ablate --panel smr` compares
//! hazard vs epoch per backend in one binary.
//!
//! [`RegionSmr`] is the stronger *region-grained* contract: the guard
//! alone keeps **everything reachable at pin time** (and everything
//! retired afterwards) alive — what an unbounded chain traversal needs.
//! Only [`Epoch`] implements it.  This is a theorem, not a shortcut:
//! hazard pointers protect a constant number of announced addresses, so
//! a traversal of an unbounded chain cannot be protected by them without
//! per-node re-validation against the root, and the path-copying chains
//! here admit an (astronomically rare but real) bitwise-ABA on the
//! bucket head that defeats such validation.  The type system therefore
//! rejects `CacheHash<_, _, _, Hazard>` instead of letting it compile
//! into a use-after-free.  The hash tables stay generic where it is
//! meaningful: over the epoch *ordering policy* (`Epoch<Fenced>` vs
//! `Epoch<SeqCstEverywhere>` — the reclamation leg of the §Perf
//! ordering-diet ablation).
//!
//! ## Choosing a scheme for a backend
//!
//! ```
//! use big_atomics::atomics::{BigAtomic, Indirect, Words};
//! use big_atomics::smr::{Epoch, Hazard, Smr};
//!
//! // Default: hazard pointers (the paper's choice for indirect nodes).
//! let a: Indirect<Words<4>> = Indirect::new(Words([1; 4]));
//! // Explicit epoch instantiation — same API, reclamation deferred to
//! // epoch advances instead of per-pointer announcements.
//! let b: Indirect<Words<4>, Epoch> = Indirect::new(Words([2; 4]));
//! assert_eq!(a.load(), Words([1; 4]));
//! assert_eq!(b.load(), Words([2; 4]));
//! assert_eq!(Hazard::NAME, "hazard");
//! assert_eq!(<Epoch>::NAME, "epoch");
//! ```
//!
//! ## Recycler hooks
//!
//! Algorithm 2's thread-private slab recycler (§3.2) does not free
//! nodes — it *recycles* them — but its safety question is the same
//! ("can any reader still be looking at this node?").  The three
//! `reclaim_*` hooks let `CachedMemEff` ask that question of either
//! scheme: under [`Hazard`] the answer is an announcement scan
//! ([`reclaim_protected`](Smr::reclaim_protected)); under [`Epoch`] it
//! is a temporal check — every uninstall is stamped
//! ([`reclaim_stamp`](Smr::reclaim_stamp)) and a node may be recycled
//! once the global epoch has advanced past the stamp by the scheme's
//! free distance (two reader epochs plus one slack epoch — see
//! [`epoch`]) per [`reclaim_stamp_expired`](Smr::reclaim_stamp_expired).
//!
//! ## The page pool
//!
//! [`pool`] supplies the hash tables' chain nodes from per-thread pages
//! of recycled slots and retires drained chains page-wise through
//! [`Smr::retire_page`] — one scheme entry (and one eventual
//! orphan-lock acquisition) per page instead of per node. See the
//! [`pool`] module docs for the claim → carve → drain → retire →
//! recycle lifecycle and how each scheme keeps a retired page alive.

pub mod epoch;
pub mod hazard;
pub mod pool;

pub use epoch::Epoch;
pub use hazard::Hazard;

use std::cell::RefCell;
use std::sync::atomic::AtomicPtr;
use std::sync::{Mutex, MutexGuard, PoisonError, TryLockError};

/// The self-flushing per-thread retire bag both schemes share (one
/// generic instead of the former `epoch::LocalBag` / `hazard::RetireList`
/// twins): TLS destructor order is unspecified, so relying on the
/// registry exit hook alone could run after the bag is already gone and
/// leak its garbage — instead the bag's own destructor hands everything
/// to the scheme's orphan list.
pub(crate) struct RetireBag<T: 'static> {
    items: RefCell<Vec<T>>,
    orphans: &'static Mutex<Vec<T>>,
}

impl<T: 'static> RetireBag<T> {
    pub(crate) fn new(orphans: &'static Mutex<Vec<T>>) -> Self {
        Self {
            items: RefCell::new(Vec::new()),
            orphans,
        }
    }

    /// Append one retired item; returns the bag length (the schemes'
    /// collection-threshold check).
    pub(crate) fn push(&self, item: T) -> usize {
        let mut items = self.items.borrow_mut();
        items.push(item);
        items.len()
    }

    pub(crate) fn len(&self) -> usize {
        self.items.borrow().len()
    }

    /// Run a scheme's free pass over the bag's contents.
    ///
    /// The vec is taken *out* of the `RefCell` for the duration of `f`:
    /// freeing an item runs its destructor, and a destructor may itself
    /// retire (a pooled page of nodes holding owned values re-enters
    /// [`push`](Self::push)) — under a held borrow that re-entry would
    /// panic on the `RefCell`. Survivors are merged back with anything
    /// pushed re-entrantly while `f` ran.
    pub(crate) fn with_items<R>(&self, f: impl FnOnce(&mut Vec<T>) -> R) -> R {
        let mut taken = std::mem::take(&mut *self.items.borrow_mut());
        let r = f(&mut taken);
        let mut items = self.items.borrow_mut();
        // Keep `taken` (usually the larger vec, capacity-warm) and fold
        // the re-entrant pushes into it.
        taken.append(&mut items);
        *items = taken;
        r
    }

    /// Hand everything to the orphan list now (table drops on borrowed
    /// threads); thread exit needs no call — `Drop` below covers it.
    pub(crate) fn flush(&self) {
        let mut items = self.items.borrow_mut();
        if !items.is_empty() {
            crate::counter!(OrphanLock);
            // A poisoned orphan lock only means a panicking holder; the
            // vec inside is still a valid list of retired items, so
            // carry on rather than propagate — `unwrap()` here would
            // double-panic inside the TLS destructor path and abort.
            self.orphans
                .lock()
                .unwrap_or_else(PoisonError::into_inner)
                .append(&mut items);
        }
    }
}

impl<T: 'static> Drop for RetireBag<T> {
    fn drop(&mut self) {
        let items = std::mem::take(&mut *self.items.borrow_mut());
        if !items.is_empty() {
            crate::counter!(OrphanLock);
            // Poison-tolerant for the same reason as `flush`, and more
            // urgently: this destructor runs during thread teardown,
            // possibly while unwinding — a panic here aborts the
            // process.
            self.orphans
                .lock()
                .unwrap_or_else(PoisonError::into_inner)
                .extend(items);
        }
    }
}

/// Census read of a scheme's orphan list: bounded `try_lock` retries,
/// then a blocking (poison-tolerant) acquisition. The census is off the
/// hot path, and `try_lock().unwrap_or(0)` silently reported an empty
/// orphan column whenever a collector held the lock.
pub(crate) fn census_lock<T>(m: &Mutex<T>) -> MutexGuard<'_, T> {
    for _ in 0..64 {
        match m.try_lock() {
            Ok(g) => return g,
            Err(TryLockError::Poisoned(p)) => return p.into_inner(),
            Err(TryLockError::WouldBlock) => std::thread::yield_now(),
        }
    }
    m.lock().unwrap_or_else(PoisonError::into_inner)
}

/// A pinned guard's protection interface.
///
/// Under [`Hazard`] each call announces the address in the guard's slot
/// (re-arming replaces the previous protection); under [`Epoch`] the
/// pin itself is the protection and these are plain `Acquire` reads.
pub trait SmrGuard {
    /// Protect and read `src`: the returned pointer stays valid (not
    /// freed, address not recycled) until the guard is dropped or
    /// re-armed by a later `protect_*` call on the same guard.
    fn protect_ptr<T>(&self, src: &AtomicPtr<T>) -> *mut T;

    /// Tagged-pointer form: `load` reads the raw word, `to_node` strips
    /// tags to the node address that reclaimers compare against (0 =
    /// nothing to protect).  Same validity contract as
    /// [`protect_ptr`](Self::protect_ptr).
    fn protect_raw<F: Fn() -> usize, G: Fn(usize) -> usize>(&self, load: F, to_node: G) -> usize;
}

/// A safe-memory-reclamation scheme: RAII pinning, deferred reclamation
/// of retired allocations, and the recycler hooks Algorithm 2 needs.
///
/// Implementors are zero-sized tags ([`Hazard`], [`Epoch<P>`]); all
/// state is process-wide inside the scheme's module.
pub trait Smr: Send + Sync + 'static {
    /// The RAII guard returned by [`pin`](Self::pin).
    type Guard: SmrGuard;

    /// Scheme name for reports (`ablation_smr` rows).
    const NAME: &'static str;

    /// Enter a protected section.  Pointer validity is per
    /// [`SmrGuard`]'s contract — see [`RegionSmr`] for the stronger
    /// region guarantee.
    fn pin() -> Self::Guard;

    /// Defer-destroy a `Box<T>` allocation.
    ///
    /// # Safety
    /// `ptr` must be a unique, unlinked `Box<T>` allocation; no new
    /// references may be created after retirement (only readers that
    /// protected it before the unlink may still dereference it).
    unsafe fn retire_box<T>(ptr: *mut T);

    /// Defer-destroy a boxed slice (array retirement — how a resized
    /// hash table's drained bucket array travels to the allocator).
    ///
    /// `Box<[T]>` is a fat pointer, which [`retire_box`](Self::retire_box)'s
    /// thin-pointer `drop_fn` cannot carry; a small heap holder
    /// re-fattens the pointer at free time, so the slice inherits the
    /// scheme's full deferral guarantee.
    ///
    /// # Safety
    /// Same contract as [`retire_box`](Self::retire_box): the slice must
    /// be unlinked, and only readers that protected it (or, under a
    /// region scheme, pinned) before the unlink may still reference it.
    unsafe fn retire_boxed_slice<T>(slice: Box<[T]>)
    where
        Self: Sized,
    {
        struct FatBox<T> {
            ptr: *mut T,
            len: usize,
        }
        impl<T> Drop for FatBox<T> {
            fn drop(&mut self) {
                // SAFETY: (ptr, len) came from Box::<[T]>::into_raw
                // below; the retire contract runs this exactly once.
                unsafe {
                    drop(Box::from_raw(std::ptr::slice_from_raw_parts_mut(
                        self.ptr, self.len,
                    )))
                }
            }
        }
        let len = slice.len();
        let ptr = Box::into_raw(slice) as *mut T;
        // SAFETY: fresh unique holder; the slice's own safety is the
        // caller's contract.
        unsafe { Self::retire_box(Box::into_raw(Box::new(FatBox { ptr, len }))) }
    }

    /// Defer-run an arbitrary reclaimer on a raw address — the
    /// generalization [`retire_box`](Self::retire_box) is a special
    /// case of, and what the page pool's slot recycling rides on
    /// ([`pool::retire_node`]): `drop_fn(ptr)` runs exactly once, after
    /// the scheme's grace period proves no protected reader remains.
    ///
    /// # Safety
    /// `ptr` must identify an unlinked allocation `drop_fn` releases
    /// exactly once; no new references may be created after retirement
    /// (only readers protected before the unlink may still use it).
    unsafe fn retire_raw(ptr: usize, drop_fn: unsafe fn(usize));

    /// Retire a whole drained page of pooled chain nodes in **one**
    /// scheme entry — one bag push, one eventual orphan-lock
    /// acquisition — instead of one per node. The batch's slots recycle
    /// when its grace period expires: under [`Hazard`] the page counts
    /// as live while *any* slot address is announced (the scheme
    /// overrides this method with a per-slot probe); under [`Epoch`]
    /// the batch is stamped once, like `CachedMemEff`'s §3.2 recycler
    /// stamps nodes, and expires by the free-distance rule.
    ///
    /// # Safety
    /// Every slot in `page` must satisfy [`retire_raw`](Self::retire_raw)'s
    /// contract (unlinked, unique, no new references).
    unsafe fn retire_page(mut page: pool::PageBatch)
    where
        Self: Sized,
    {
        if page.is_empty() {
            return;
        }
        if !pool::enabled() {
            // Disabled-pool baseline (the `ablate --panel alloc` boxed
            // arm): retire each node individually — the per-node scheme
            // traffic the batching amortizes away.
            for (addr, recycle) in page.take_slots() {
                // SAFETY: slot contracts forwarded from the caller.
                unsafe { Self::retire_raw(addr, recycle) };
            }
            return;
        }
        pool::note_batch(page.len());
        unsafe fn drop_holder(addr: usize) {
            // SAFETY: leaked below; the retire contract runs this once.
            // Dropping the batch recycles every slot.
            drop(unsafe { Box::from_raw(addr as *mut pool::PageBatch) });
        }
        let holder = Box::into_raw(Box::new(page));
        // SAFETY: slot contracts forwarded from the caller; the holder
        // itself is a fresh unique allocation.
        unsafe { Self::retire_raw(holder as usize, drop_holder) }
    }

    /// Attempt to reclaim retired allocations now (hazard: scan; epoch:
    /// advance + free sufficiently old bags).
    fn collect();

    /// Retired-but-not-yet-freed allocations visible to this thread
    /// (plus orphans) — the §5.5 memory census.
    fn pending_reclaims() -> usize;

    /// Hand this thread's retired list to the process-wide orphan list
    /// (thread exit, or table drop on a borrowed thread).
    fn flush_thread_bag();

    /// Recycler phase-2 hook (§3.2): snapshot the set of protected node
    /// addresses into `buf`.  Hazard: the announcement array (behind the
    /// mandatory retire→scan fence).  Epoch: empty — protection is
    /// temporal — but the call tries one epoch advance so
    /// [`reclaim_stamp_expired`](Self::reclaim_stamp_expired) can make
    /// progress.
    fn reclaim_protected(buf: &mut Vec<usize>);

    /// Stamp recorded when a slab node is uninstalled (epoch: the global
    /// epoch; hazard: unused, 0).
    fn reclaim_stamp() -> u64;

    /// Is a node uninstalled at `stamp` temporally safe to recycle?
    /// Hazard: always (safety is the address scan).  Epoch: only once
    /// the global epoch has advanced the scheme's full free distance
    /// past the stamp (two reader epochs plus one stamp-slack epoch —
    /// see `epoch::FREE_DISTANCE`).
    fn reclaim_stamp_expired(stamp: u64) -> bool;
}

/// Region-grained SMR: the guard alone protects every allocation that
/// was reachable when [`pin`](Smr::pin) was called, for the guard's
/// whole lifetime — unbounded traversals need no per-pointer protection.
///
/// # Safety
/// Implementors must guarantee that no allocation reachable at pin time
/// (nor anything retired after it) is freed while any guard pinned at or
/// before that point is live.  Hazard pointers **cannot** satisfy this
/// (they protect a constant number of addresses), which is why the hash
/// tables bound their scheme parameter by this trait — see the module
/// docs.
pub unsafe trait RegionSmr: Smr {}
