//! Safe memory reclamation (SMR).
//!
//! The paper's indirect big-atomic nodes are heap values read through
//! pointers that concurrent updaters unlink; reclamation must wait until
//! no reader can still hold the pointer (§2).  Two schemes, matching the
//! paper's usage:
//!
//! * [`hazard`] — hazard pointers [Michael '04], used by `Indirect`,
//!   `CachedWaitFree` (Alg 1), `CachedWritable` (Alg 3), and for the
//!   announcement array of Alg 2's custom slab recycler.
//! * [`epoch`] — epoch-based reclamation, used by the hash tables'
//!   chain links (§4: "We use epoch-based memory management to protect
//!   the links that are being read").

pub mod epoch;
pub mod hazard;
