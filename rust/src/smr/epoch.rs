//! Epoch-based reclamation — the region-grained [`Smr`] scheme (§4).
//!
//! Classic epoch protocol: readers pin the global epoch for the
//! duration of an operation; unlinked nodes are retired (under a pin)
//! into the current epoch's bag and freed once the global epoch has
//! advanced `FREE_DISTANCE` past their retirement stamp — two epochs
//! of reader separation plus one slack epoch for the stamp's own
//! bounded staleness (no pinned reader can still see them).  The
//! protocol state (global epoch, announcement array, bags) is shared by
//! every [`Epoch<P>`] instantiation — the policy parameter changes only
//! the *strength* of each access, never the protocol shape, so
//! `Epoch<Fenced>` and `Epoch<SeqCstEverywhere>` interoperate in one
//! process (the smr ablation relies on this).
//!
//! ## Ordering contract
//!
//! The pin/advance handshake is store-load shaped end to end — exactly
//! the pattern Schweizer et al. show is where fences, not instruction
//! counts, dominate — and this module owns the crate's **other** two
//! mandatory `fence(SeqCst)` points (the first pair lives in
//! [`hazard`](super::hazard); everything else here is
//! Acquire/Release/Relaxed under the default
//! [`Fenced`](crate::util::ordering::Fenced) policy):
//!
//! 1. **pin → validate-global** ([`Epoch::pin`]): the epoch announcement
//!    store must be globally visible *before* the global epoch is
//!    re-read.  Without the fence the CPU may order the validating load
//!    before the announcement store; a concurrent advancer then scans,
//!    misses the announcement, advances twice, and frees garbage the
//!    reader is about to dereference — a use-after-free.
//! 2. **advance → scan-announcements** ([`try_advance_and_collect`]):
//!    the advancer's fence pairs with (1).  If the advancer's fence
//!    orders before a pinner's fence in the global SeqCst order, the
//!    pinner's validating load observes the (pre-advance or newer)
//!    global epoch and its announcement is at most one epoch behind —
//!    where the free-distance rule still covers it; otherwise the scan
//!    observes the announcement and refuses to advance past it.  Either
//!    way no pinned reader's nodes are freed.
//!
//! Around those two fences the accesses are demoted, each site naming
//! its happens-before edge inline: announcement stores are `RELAXED`
//! (the pin fence publishes them), the quiescent (unpin) store is
//! `RELEASE` (protected reads happen-before a scanner sees the slot
//! quiescent), announcement scans are `ACQUIRE` (pairing with that
//! `RELEASE`), the epoch-advance CAS is `ACQREL`, and bag bookkeeping is
//! `RELAXED` (owner-private, or re-validated by the epoch rule).
//! `cargo test --features seqcst_audit` restores blanket `SeqCst` at
//! every demoted site.

use std::cell::RefCell;
use std::marker::PhantomData;
use std::sync::atomic::{fence, AtomicU64, Ordering};
use std::sync::Mutex;

use super::{RegionSmr, RetireBag, Smr, SmrGuard};
use crate::util::ordering::{DefaultPolicy, OrderingPolicy};
use crate::util::registry::tid;
use crate::MAX_THREADS;

/// Retires per thread between advance attempts.
const ADVANCE_THRESHOLD: usize = 64;

/// Epoch distance between a retirement stamp and its free: two epochs
/// of reader separation (the classic rule) **plus one slack epoch**
/// absorbing the bounded staleness of the stamp itself (the stamp is
/// read under a pin, which caps the global at pin+1 — so the stamp may
/// lag the true unlink epoch by one).  Distance 3 makes every
/// boundary interleaving provably safe by fence-fence visibility: a
/// reader pinned at `stamp + 2` or later pinned after an advance whose
/// scan observed the unlinker quiescent, so its protected loads cannot
/// return the unlinked pointer; readers pinned earlier block the
/// advance to `stamp + 3`.
const FREE_DISTANCE: u64 = 3;

/// Epochs start at 2 so stamp arithmetic can never underflow into the
/// 0 = quiescent announcement sentinel.
static GLOBAL_EPOCH: AtomicU64 = AtomicU64::new(2);

/// Per-thread announcement: 0 = quiescent, else the pinned epoch.
static ANNOUNCE: [AtomicU64; MAX_THREADS] = {
    #[allow(clippy::declare_interior_mutable_const)]
    const Z: AtomicU64 = AtomicU64::new(0);
    [Z; MAX_THREADS]
};

struct Retired {
    epoch: u64,
    ptr: usize,
    drop_fn: unsafe fn(usize),
}

// SAFETY: consumed exactly once after the epoch rule proves no reader.
unsafe impl Send for Retired {}

static ORPHANS: Mutex<Vec<Retired>> = Mutex::new(Vec::new());

thread_local! {
    // The shared self-flushing bag (smr::RetireBag): its own TLS
    // destructor hands leftovers to ORPHANS in any destructor order.
    static BAG: RetireBag<Retired> = RetireBag::new(&ORPHANS);
    static PIN_DEPTH: RefCell<usize> = const { RefCell::new(0) };
}

/// Epoch-based reclamation as a zero-sized [`Smr`] tag, generic over the
/// memory-ordering policy (see the module docs).
pub struct Epoch<P: OrderingPolicy = DefaultPolicy>(PhantomData<fn() -> P>);

/// RAII pin: the thread participates in the current epoch until dropped.
/// Re-entrant (nested pins keep the outermost epoch).
pub struct Guard<P: OrderingPolicy = DefaultPolicy> {
    t: usize,
    _policy: PhantomData<fn() -> P>,
}

impl<P: OrderingPolicy> Epoch<P> {
    /// Pin the current thread (announce-and-validate loop).
    pub fn pin() -> Guard<P> {
        let t = tid();
        PIN_DEPTH.with(|d| {
            let mut d = d.borrow_mut();
            if *d == 0 {
                // Outermost pins only — nested re-pins are a depth bump
                // and pay no announce/fence, so they don't count.
                crate::counter!(EpochPin);
                // Ordering: RELAXED — the announcement below re-derives
                // from whatever we read; staleness only costs one loop
                // iteration.
                let mut e = GLOBAL_EPOCH.load(P::RELAXED);
                loop {
                    // Ordering: RELAXED store — the SeqCst fence below
                    // is what publishes the announcement before the
                    // validating re-read.
                    ANNOUNCE[t].store(e, P::RELAXED);
                    // Ordering: mandatory store-load fence (module docs,
                    // point 1): announce must be visible before the
                    // global epoch is re-read, pairing with the
                    // advancer's fence in `try_advance_and_collect`.
                    fence(Ordering::SeqCst);
                    // Fault window: announced but not yet validated — a
                    // stall here blocks every epoch advance (NOT
                    // kill-safe: a dead pinned thread wedges the epoch
                    // until on_thread_exit clears its slot).
                    crate::failpoint!(EpochPin);
                    // Ordering: RELAXED — ordered after the announce by
                    // the fence; on disagreement we re-announce, and on
                    // agreement the announcement is at most one advance
                    // behind any concurrent scan, which the free-
                    // distance rule tolerates.
                    let g = GLOBAL_EPOCH.load(P::RELAXED);
                    if g == e {
                        break;
                    }
                    e = g;
                }
            }
            *d += 1;
        });
        Guard {
            t,
            _policy: PhantomData,
        }
    }

    /// Retire a `Box<T>` allocation; freed once the global epoch passes
    /// `FREE_DISTANCE` beyond the retirement stamp.
    ///
    /// Retirement happens **under a pin** taken here (a depth bump when
    /// the caller already holds a guard): the pin's store-load fence is
    /// what bounds the stamp's staleness to one epoch — an unpinned
    /// relaxed read could lag arbitrarily and break the free rule.
    ///
    /// # Safety
    /// Same contract as [`Smr::retire_box`]: unlinked, unique.
    pub unsafe fn retire_box<T>(ptr: *mut T) {
        unsafe fn dropper<T>(addr: usize) {
            drop(unsafe { Box::from_raw(addr as *mut T) });
        }
        // SAFETY: forwarded contract (unique, unlinked Box).
        unsafe { Self::retire_raw(ptr as usize, dropper::<T>) }
    }

    /// Retire a raw address with a custom reclaimer — the
    /// [`Smr::retire_raw`] entry point ([`retire_box`](Self::retire_box)
    /// is the `Box` special case; the page pool's slot recycling and
    /// page batches ride here). The entry is stamped with the global
    /// epoch exactly like a boxed node — for a page batch that is the
    /// §3.2 recycler idiom: one stamp for the whole page, recycled when
    /// the epoch passes it by the free distance.
    ///
    /// # Safety
    /// Same contract as [`Smr::retire_raw`].
    pub unsafe fn retire_raw(ptr: usize, drop_fn: unsafe fn(usize)) {
        let _pin = Self::pin();
        // Ordering: ACQUIRE, read under the pin — coherence with the
        // pin's validated read makes the stamp at least the (outermost)
        // pin epoch, and a live pin caps the global at pin+1, so the
        // stamp lags the true unlink epoch by at most one — the slack
        // epoch in FREE_DISTANCE absorbs exactly that.
        let e = GLOBAL_EPOCH.load(P::ACQUIRE);
        crate::counter!(EpochRetire);
        // Fault window: node unlinked, stamp taken, not yet bagged — a
        // kill here (under the pin guard, which unwinds cleanly) leaks
        // the node; already-bagged items still flush via the TLS
        // destructor.
        crate::failpoint!(EpochRetire);
        let len = BAG.with(|b| {
            b.push(Retired {
                epoch: e,
                ptr,
                drop_fn,
            })
        });
        if len >= ADVANCE_THRESHOLD {
            Self::try_advance_and_collect();
        }
    }

    /// Attempt to advance the global epoch, then free sufficiently old
    /// garbage from this thread's bag (and orphans, opportunistically).
    pub fn try_advance_and_collect() {
        crate::counter!(EpochScan);
        // Fault window: advance attempt starting — dying or dawdling
        // here only defers reclamation; any other thread's next advance
        // makes the same progress.
        crate::failpoint!(EpochAdvance);
        // Ordering: mandatory store-load fence (module docs, point 2) —
        // pairs with the pinners' fences: every unlink/retire that
        // happened-before this call is ordered before the announcement
        // reads, so a reader that could still see that garbage either
        // shows up in the scan below or observes the advanced epoch in
        // its own validation.
        fence(Ordering::SeqCst);
        // Ordering: RELAXED — ordered by the fence above; the CAS below
        // re-validates against concurrent advancers.
        let global = GLOBAL_EPOCH.load(P::RELAXED);
        let mut can_advance = true;
        let hw = crate::util::registry::high_water();
        for a in ANNOUNCE[..hw].iter() {
            // Ordering: ACQUIRE — pairs with the RELEASE quiescent store
            // in Guard::drop, so a slot observed 0 implies its protected
            // reads completed; a stale *pinned* epoch blocks the advance
            // (the scan's safety is blocking, not synchronizing).
            let e = a.load(P::ACQUIRE);
            if e != 0 && e != global {
                can_advance = false;
                break;
            }
        }
        if can_advance {
            // CAS so concurrent advancers move it at most one step.
            // Ordering: ACQREL — the release half orders this advancer's
            // scan before the new epoch any pinner validates against;
            // the acquire half pairs with previous advancers so the +2
            // arithmetic below reads a coherent history. RELAXED on
            // failure: a loser changes nothing.
            if GLOBAL_EPOCH
                .compare_exchange(global, global + 1, P::ACQREL, P::RELAXED)
                .is_ok()
            {
                crate::counter!(EpochAdvance);
            }
        }
        // Ordering: ACQUIRE — pairs with the ACQREL advance CAS (ours or
        // a concurrent winner's): bags are freed against an epoch that
        // happened-after its scan.
        let now = GLOBAL_EPOCH.load(P::ACQUIRE);
        let free = |bag: &mut Vec<Retired>| {
            bag.retain(|item| {
                if item.epoch + FREE_DISTANCE <= now {
                    crate::counter!(EpochFree);
                    // SAFETY: stamped e under a pin (unlink epoch <=
                    // e+1); every reader that can still hold the
                    // pointer announced <= e+2 < now, and such
                    // announcements block the advance to `now` — so
                    // none remains pinned (see FREE_DISTANCE).
                    unsafe { (item.drop_fn)(item.ptr) };
                    false
                } else {
                    true
                }
            });
        };
        let _ = BAG.try_with(|b| b.with_items(&free));
        match ORPHANS.try_lock() {
            Ok(mut orphans) => {
                crate::counter!(OrphanLock);
                free(&mut orphans);
            }
            // Poisoned by a killed holder: the vec is still a valid
            // retired list — drain it rather than strand the garbage.
            Err(std::sync::TryLockError::Poisoned(p)) => {
                crate::counter!(OrphanLock);
                free(&mut p.into_inner());
            }
            Err(std::sync::TryLockError::WouldBlock) => {}
        }
    }
}

impl<P: OrderingPolicy> Drop for Guard<P> {
    fn drop(&mut self) {
        PIN_DEPTH.with(|d| {
            let mut d = d.borrow_mut();
            *d -= 1;
            if *d == 0 {
                // Ordering: RELEASE — all reads through pointers this pin
                // protected happen-before an advancer's ACQUIRE scan
                // observes the slot quiescent.
                ANNOUNCE[self.t].store(0, P::RELEASE);
            }
        });
    }
}

impl<P: OrderingPolicy> SmrGuard for Guard<P> {
    #[inline]
    fn protect_ptr<T>(&self, src: &std::sync::atomic::AtomicPtr<T>) -> *mut T {
        // Ordering: ACQUIRE — pairs with the installer's RELEASE
        // publication so node contents are visible before the caller
        // dereferences; the pin itself (not this read) is what keeps the
        // node from being freed.
        src.load(P::ACQUIRE)
    }

    #[inline]
    fn protect_raw<F: Fn() -> usize, G: Fn(usize) -> usize>(&self, load: F, _to_node: G) -> usize {
        // Region protection: one read suffices — anything reachable now
        // outlives the guard. The caller passes an ACQUIRE-loading
        // closure (see SmrGuard's contract in the hazard scheme).
        load()
    }
}

impl<P: OrderingPolicy> Smr for Epoch<P> {
    type Guard = Guard<P>;
    const NAME: &'static str = "epoch";

    #[inline]
    fn pin() -> Guard<P> {
        Epoch::<P>::pin()
    }

    unsafe fn retire_box<T>(ptr: *mut T) {
        unsafe { Epoch::<P>::retire_box(ptr) }
    }

    unsafe fn retire_raw(ptr: usize, drop_fn: unsafe fn(usize)) {
        unsafe { Epoch::<P>::retire_raw(ptr, drop_fn) }
    }

    fn collect() {
        Epoch::<P>::try_advance_and_collect();
    }

    fn pending_reclaims() -> usize {
        pending_reclaims()
    }

    fn flush_thread_bag() {
        flush_thread_bag();
    }

    fn reclaim_protected(buf: &mut Vec<usize>) {
        // Protection is temporal, not address-based: nothing to scan,
        // but try one advance so stamp expiry makes progress.
        buf.clear();
        Epoch::<P>::try_advance_and_collect();
    }

    fn reclaim_stamp() -> u64 {
        // Ordering: ACQUIRE — pairs with the advance CAS so the stamp is
        // no older than any epoch this thread already observed.
        GLOBAL_EPOCH.load(P::ACQUIRE)
    }

    fn reclaim_stamp_expired(stamp: u64) -> bool {
        // The slab-recycler analog of the bag rule: a node uninstalled
        // at `stamp` may be recycled once FREE_DISTANCE advances passed
        // — every reader that could still see it announced <= stamp+2
        // (one epoch of stamp slack included), and such announcements
        // block the final advance.
        // Ordering: ACQUIRE — as in reclaim_stamp.
        GLOBAL_EPOCH.load(P::ACQUIRE) >= stamp + FREE_DISTANCE
    }
}

// SAFETY: a live pin at epoch e blocks the global epoch at e+1, and
// nothing is freed (bags) or recycled (stamp rule) until the global
// epoch passes FREE_DISTANCE (= 3: two reader epochs + one stamp-slack
// epoch) beyond its retirement stamp — so everything reachable at pin
// time outlives the guard. This is the region guarantee the hash
// tables' unbounded chain traversals require.
unsafe impl<P: OrderingPolicy> RegionSmr for Epoch<P> {}

// ---------------------------------------------------------------------
// Default-policy free functions (compatibility surface; the generic
// consumers go through the Smr trait instead).
// ---------------------------------------------------------------------

/// Pin the current thread under the crate-default policy.
pub fn pin() -> Guard<DefaultPolicy> {
    Epoch::<DefaultPolicy>::pin()
}

/// Retire a `Box<T>` under the crate-default policy.
///
/// # Safety
/// Same contract as [`Smr::retire_box`]: unlinked, unique.
pub unsafe fn retire_box<T>(ptr: *mut T) {
    unsafe { Epoch::<DefaultPolicy>::retire_box(ptr) }
}

/// Attempt an epoch advance and free old garbage (crate-default policy).
pub fn try_advance_and_collect() {
    Epoch::<DefaultPolicy>::try_advance_and_collect();
}

/// The current global epoch (tests and the memory census).
pub fn global_epoch() -> u64 {
    GLOBAL_EPOCH.load(Ordering::Acquire)
}

/// Hand this thread's bag to the orphan list now (table drops on
/// borrowed threads). Thread *exit* needs no call: the bag's own TLS
/// destructor performs the handoff regardless of destructor order.
pub fn flush_thread_bag() {
    // One spill event per explicit handoff to ORPHANS (thread-exit
    // handoffs route through here from on_thread_exit).
    crate::counter!(EpochOrphanSpill);
    let _ = BAG.try_with(|b| b.flush());
}

/// Registry hook: a thread is exiting; park its garbage on the orphan
/// list (best-effort here — the self-flushing bag covers the rest) and
/// clear its announcement slot (a live pin at exit is a bug, but a
/// stale announcement would block the epoch forever).
pub(crate) fn on_thread_exit(t: usize) {
    flush_thread_bag();
    // Ordering: RELEASE — as in Guard::drop.
    ANNOUNCE[t].store(0, Ordering::Release);
}

/// Outstanding (retired, unfreed) node count — §5.5 memory census.
pub fn pending_reclaims() -> usize {
    let local = BAG.try_with(|b| b.len()).unwrap_or(0);
    // Census reads take the lock (bounded retry, then block): the old
    // `try_lock().unwrap_or(0)` silently reported an empty orphan
    // column whenever a concurrent collector held the lock — the §5.5
    // census undercounted exactly when reclamation was busiest.
    let orphaned = super::census_lock(&ORPHANS).len();
    local + orphaned
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicUsize;

    static DROPS: AtomicUsize = AtomicUsize::new(0);
    struct Counted;
    impl Drop for Counted {
        fn drop(&mut self) {
            DROPS.fetch_add(1, Ordering::AcqRel);
        }
    }

    #[test]
    fn test_pin_unpin_announces() {
        let t = tid();
        {
            let _g = pin();
            assert_ne!(ANNOUNCE[t].load(Ordering::Acquire), 0);
            {
                let _g2 = pin(); // nested
                assert_ne!(ANNOUNCE[t].load(Ordering::Acquire), 0);
            }
            assert_ne!(ANNOUNCE[t].load(Ordering::Acquire), 0);
        }
        assert_eq!(ANNOUNCE[t].load(Ordering::Acquire), 0);
    }

    #[test]
    fn test_pin_validates_against_global() {
        // The pinned epoch must equal the global epoch at some point
        // inside pin() — the validation loop's postcondition.
        let t = tid();
        let _g = pin();
        let announced = ANNOUNCE[t].load(Ordering::Acquire);
        // A concurrent advancer can move global at most one past the
        // announcement (the announcement blocks the next advance).
        let global = global_epoch();
        assert!(
            announced == global || announced + 1 == global,
            "announced {announced} vs global {global}"
        );
    }

    #[test]
    fn test_retire_eventually_freed_when_quiescent() {
        let before = DROPS.load(Ordering::Acquire);
        unsafe { retire_box(Box::into_raw(Box::new(Counted))) };
        // Two advances must pass before the free; other tests may pin
        // concurrently, so retry rather than count advances exactly.
        for _ in 0..10_000 {
            try_advance_and_collect();
            if DROPS.load(Ordering::Acquire) > before {
                return;
            }
            std::thread::yield_now();
        }
        panic!("retired node never freed while quiescent");
    }

    #[test]
    fn test_pinned_reader_blocks_advance_based_free() {
        // A reader pinned in an older epoch must prevent collection of
        // nodes retired afterwards from reaching the free threshold.
        let (tx, rx) = std::sync::mpsc::channel::<()>();
        let (done_tx, done_rx) = std::sync::mpsc::channel::<()>();
        let reader = std::thread::spawn(move || {
            let _g = pin();
            tx.send(()).unwrap();
            done_rx.recv().unwrap(); // hold the pin until told
        });
        rx.recv().unwrap();
        let epoch_at_pin = global_epoch();
        // The pinned reader stalls the epoch at most one advance away.
        for _ in 0..10 {
            try_advance_and_collect();
        }
        let now = global_epoch();
        assert!(
            now <= epoch_at_pin + 1,
            "epoch advanced past pinned reader: {epoch_at_pin} -> {now}"
        );
        done_tx.send(()).unwrap();
        reader.join().unwrap();
        for _ in 0..4 {
            try_advance_and_collect();
        }
    }

    #[test]
    fn test_retire_boxed_slice_defers_array_free() {
        // Array retirement (resized tables' bucket arrays): the whole
        // boxed slice must travel through the epoch deferral, each
        // element dropped exactly once.
        use std::sync::Arc;
        let drops = Arc::new(AtomicUsize::new(0));
        struct El(Arc<AtomicUsize>);
        impl Drop for El {
            fn drop(&mut self) {
                self.0.fetch_add(1, Ordering::AcqRel);
            }
        }
        let slice: Box<[El]> = (0..10).map(|_| El(Arc::clone(&drops))).collect();
        unsafe { <Epoch as Smr>::retire_boxed_slice(slice) };
        for _ in 0..10_000 {
            try_advance_and_collect();
            if drops.load(Ordering::Acquire) == 10 {
                return;
            }
            std::thread::yield_now();
        }
        panic!(
            "retired slice never fully freed ({}/10)",
            drops.load(Ordering::Acquire)
        );
    }

    #[test]
    fn test_concurrent_readers_and_retire_stress() {
        use std::sync::atomic::AtomicPtr;
        use std::sync::Arc;
        let src = Arc::new(AtomicPtr::new(Box::into_raw(Box::new(1u64))));
        let stop = Arc::new(std::sync::atomic::AtomicBool::new(false));
        let mut handles = Vec::new();
        for _ in 0..3 {
            let src = Arc::clone(&src);
            let stop = Arc::clone(&stop);
            handles.push(std::thread::spawn(move || {
                while !stop.load(Ordering::Relaxed) {
                    let g = pin();
                    let p = g.protect_ptr(&src);
                    let v = unsafe { *p };
                    assert!(v >= 1 && v < 1 << 40);
                }
                flush_thread_bag();
            }));
        }
        for gen in 2..2000u64 {
            let _g = pin();
            let new = Box::into_raw(Box::new(gen));
            let old = src.swap(new, Ordering::AcqRel);
            drop(_g);
            unsafe { retire_box(old) };
        }
        stop.store(true, Ordering::Release);
        for h in handles {
            h.join().unwrap();
        }
        flush_thread_bag();
    }

    #[test]
    fn test_both_policies_share_one_protocol() {
        // Fenced and SeqCstEverywhere instantiations must interoperate:
        // a pin under one is visible to an advance under the other.
        use crate::util::ordering::{Fenced, SeqCstEverywhere};
        let _g = Epoch::<Fenced>::pin();
        let e = global_epoch();
        for _ in 0..6 {
            Epoch::<SeqCstEverywhere>::try_advance_and_collect();
        }
        assert!(
            global_epoch() <= e + 1,
            "audit-policy advancer ignored fenced-policy pin"
        );
    }
}
