//! Epoch-based reclamation for the hash tables' chain links (§4).
//!
//! Classic three-epoch scheme: readers pin the global epoch for the
//! duration of an operation; unlinked nodes are retired into the current
//! epoch's bag and freed once the global epoch has advanced twice past
//! their retirement epoch (no pinned reader can still see them).

use std::cell::RefCell;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;

use crate::util::registry::tid;
use crate::MAX_THREADS;

/// Retires per thread between advance attempts.
const ADVANCE_THRESHOLD: usize = 64;

static GLOBAL_EPOCH: AtomicU64 = AtomicU64::new(2);

/// Per-thread announcement: 0 = quiescent, else the pinned epoch.
static ANNOUNCE: [AtomicU64; MAX_THREADS] = {
    #[allow(clippy::declare_interior_mutable_const)]
    const Z: AtomicU64 = AtomicU64::new(0);
    [Z; MAX_THREADS]
};

struct Retired {
    epoch: u64,
    ptr: usize,
    drop_fn: unsafe fn(usize),
}

// SAFETY: consumed exactly once after the epoch rule proves no reader.
unsafe impl Send for Retired {}

static ORPHANS: Mutex<Vec<Retired>> = Mutex::new(Vec::new());

thread_local! {
    static BAG: RefCell<Vec<Retired>> = const { RefCell::new(Vec::new()) };
    static PIN_DEPTH: RefCell<usize> = const { RefCell::new(0) };
}

/// RAII pin: the thread participates in the current epoch until dropped.
/// Re-entrant (nested pins keep the outermost epoch).
pub struct Guard {
    t: usize,
}

/// Pin the current thread.
pub fn pin() -> Guard {
    let t = tid();
    PIN_DEPTH.with(|d| {
        let mut d = d.borrow_mut();
        if *d == 0 {
            let e = GLOBAL_EPOCH.load(Ordering::SeqCst);
            ANNOUNCE[t].store(e, Ordering::SeqCst);
        }
        *d += 1;
    });
    Guard { t }
}

impl Drop for Guard {
    fn drop(&mut self) {
        PIN_DEPTH.with(|d| {
            let mut d = d.borrow_mut();
            *d -= 1;
            if *d == 0 {
                ANNOUNCE[self.t].store(0, Ordering::SeqCst);
            }
        });
    }
}

/// Retire a `Box<T>` allocation; freed once two epoch advances pass.
///
/// # Safety
/// Same contract as [`crate::smr::hazard::retire_box`]: unlinked, unique.
pub unsafe fn retire_box<T>(ptr: *mut T) {
    unsafe fn dropper<T>(addr: usize) {
        drop(unsafe { Box::from_raw(addr as *mut T) });
    }
    let e = GLOBAL_EPOCH.load(Ordering::SeqCst);
    let len = BAG.with(|b| {
        let mut b = b.borrow_mut();
        b.push(Retired {
            epoch: e,
            ptr: ptr as usize,
            drop_fn: dropper::<T>,
        });
        b.len()
    });
    if len >= ADVANCE_THRESHOLD {
        try_advance_and_collect();
    }
}

/// Attempt to advance the global epoch, then free sufficiently old
/// garbage from this thread's bag (and orphans, opportunistically).
pub fn try_advance_and_collect() {
    let global = GLOBAL_EPOCH.load(Ordering::SeqCst);
    let mut can_advance = true;
    let hw = crate::util::registry::high_water();
    for a in ANNOUNCE[..hw].iter() {
        let e = a.load(Ordering::SeqCst);
        if e != 0 && e != global {
            can_advance = false;
            break;
        }
    }
    if can_advance {
        // CAS so concurrent advancers move it at most one step.
        let _ = GLOBAL_EPOCH.compare_exchange(
            global,
            global + 1,
            Ordering::SeqCst,
            Ordering::SeqCst,
        );
    }
    let now = GLOBAL_EPOCH.load(Ordering::SeqCst);
    let free = |bag: &mut Vec<Retired>| {
        bag.retain(|item| {
            if item.epoch + 2 <= now {
                // SAFETY: retired in epoch e; every currently pinned
                // reader announced >= e+1 > e, so none predates the
                // unlink.
                unsafe { (item.drop_fn)(item.ptr) };
                false
            } else {
                true
            }
        });
    };
    BAG.with(|b| free(&mut b.borrow_mut()));
    if let Ok(mut orphans) = ORPHANS.try_lock() {
        free(&mut orphans);
    }
}

/// Registry/thread-exit hook analog (called from tests and table drops):
/// push this thread's bag to the orphan list.
pub fn flush_thread_bag() {
    let _ = BAG.try_with(|b| {
        let mut b = b.borrow_mut();
        if !b.is_empty() {
            ORPHANS.lock().unwrap().append(&mut b);
        }
    });
}

/// Outstanding (retired, unfreed) node count — §5.5 memory census.
pub fn pending_reclaims() -> usize {
    let local = BAG.try_with(|b| b.borrow().len()).unwrap_or(0);
    let orphaned = ORPHANS.try_lock().map(|o| o.len()).unwrap_or(0);
    local + orphaned
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicUsize;

    static DROPS: AtomicUsize = AtomicUsize::new(0);
    struct Counted;
    impl Drop for Counted {
        fn drop(&mut self) {
            DROPS.fetch_add(1, Ordering::SeqCst);
        }
    }

    #[test]
    fn test_pin_unpin_announces() {
        let t = tid();
        {
            let _g = pin();
            assert_ne!(ANNOUNCE[t].load(Ordering::SeqCst), 0);
            {
                let _g2 = pin(); // nested
                assert_ne!(ANNOUNCE[t].load(Ordering::SeqCst), 0);
            }
            assert_ne!(ANNOUNCE[t].load(Ordering::SeqCst), 0);
        }
        assert_eq!(ANNOUNCE[t].load(Ordering::SeqCst), 0);
    }

    #[test]
    fn test_retire_eventually_freed_when_quiescent() {
        let before = DROPS.load(Ordering::SeqCst);
        unsafe { retire_box(Box::into_raw(Box::new(Counted))) };
        // Two advances must pass before the free.
        for _ in 0..4 {
            try_advance_and_collect();
        }
        assert!(DROPS.load(Ordering::SeqCst) > before);
    }

    #[test]
    fn test_pinned_reader_blocks_advance_based_free() {
        // A reader pinned in an older epoch must prevent collection of
        // nodes retired afterwards from reaching the free threshold.
        let (tx, rx) = std::sync::mpsc::channel::<()>();
        let (done_tx, done_rx) = std::sync::mpsc::channel::<()>();
        let reader = std::thread::spawn(move || {
            let _g = pin();
            tx.send(()).unwrap();
            done_rx.recv().unwrap(); // hold the pin until told
        });
        rx.recv().unwrap();
        let epoch_at_pin = GLOBAL_EPOCH.load(Ordering::SeqCst);
        // The pinned reader stalls the epoch at most one advance away.
        for _ in 0..10 {
            try_advance_and_collect();
        }
        let now = GLOBAL_EPOCH.load(Ordering::SeqCst);
        assert!(
            now <= epoch_at_pin + 1,
            "epoch advanced past pinned reader: {epoch_at_pin} -> {now}"
        );
        done_tx.send(()).unwrap();
        reader.join().unwrap();
        for _ in 0..4 {
            try_advance_and_collect();
        }
    }

    #[test]
    fn test_concurrent_readers_and_retire_stress() {
        use std::sync::atomic::AtomicPtr;
        use std::sync::Arc;
        let src = Arc::new(AtomicPtr::new(Box::into_raw(Box::new(1u64))));
        let stop = Arc::new(std::sync::atomic::AtomicBool::new(false));
        let mut handles = Vec::new();
        for _ in 0..3 {
            let src = Arc::clone(&src);
            let stop = Arc::clone(&stop);
            handles.push(std::thread::spawn(move || {
                while !stop.load(Ordering::Relaxed) {
                    let _g = pin();
                    let p = src.load(Ordering::SeqCst);
                    let v = unsafe { *p };
                    assert!(v >= 1 && v < 1 << 40);
                }
                flush_thread_bag();
            }));
        }
        for gen in 2..2000u64 {
            let _g = pin();
            let new = Box::into_raw(Box::new(gen));
            let old = src.swap(new, Ordering::SeqCst);
            drop(_g);
            unsafe { retire_box(old) };
        }
        stop.store(true, Ordering::SeqCst);
        for h in handles {
            h.join().unwrap();
        }
        flush_thread_bag();
    }
}
