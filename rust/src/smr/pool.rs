//! Page-pool allocation for chain nodes, with batched (page-wise)
//! retirement through the [`Smr`](super::Smr) schemes.
//!
//! Under insert/remove churn the hash tables' hot path is not the CAS —
//! it is the allocator and the orphan-list lock: every chain link used
//! to be an individual `Box::new` and an individual `retire_box`, and
//! every retired node eventually funnels through a global
//! `Mutex<Vec<_>>` orphan list. This module amortizes both:
//!
//! * allocation is a per-thread free-list pop (no malloc on the steady
//!   state), backed by fixed-size **pages** of node slots;
//! * retirement of a drained chain is **one** scheme entry per page
//!   batch ([`Smr::retire_page`](super::Smr::retire_page)) instead of
//!   one per node, so the orphan-lock traffic drops by the batch size.
//!
//! ## Page lifecycle: claim → carve → drain → retire → recycle
//!
//! 1. **Claim.** A thread whose free list is empty claims slot capacity:
//!    first from the global spill list (slots parked by exited threads),
//!    else by allocating a fresh page ([`PAGE_SLOTS`] slots of one size
//!    class). The claim path carries the `PoolClaimPage` failpoint — a
//!    thread may die here and the pool stays live (the page is not yet
//!    carved, no lock is held across the kill window).
//! 2. **Carve.** The page is carved into headered slots pushed onto the
//!    claiming thread's free list; [`alloc_node`] pops one and writes
//!    the node in place. Each slot's header records its size class (or
//!    the boxed-fallback marker), so every free site is provenance-free:
//!    the slot says how it must be released.
//! 3. **Drain.** The tables unlink nodes as usual. Unpublished copies
//!    (a lost CAS) return immediately via [`free_node_now`]; published
//!    nodes are unlinked and handed to SMR.
//! 4. **Retire.** Single hot-path victims go through [`retire_node`]
//!    (one bag entry, exact-address protection under `Hazard`, a stamp
//!    under `Epoch`). Whole drained chains — the resize engines' bulk
//!    case — are gathered into a [`PageBatch`] and handed to
//!    [`Smr::retire_page`](super::Smr::retire_page): **one** retire
//!    entry, one eventual orphan-lock acquisition, for the whole page.
//! 5. **Recycle.** When the scheme proves the page dead it runs the
//!    batch's destructor: every slot's node is dropped in place and the
//!    slot returns to a free list — the pool's slots are recycled
//!    through the *same* grace period that used to free boxes, so no
//!    slot is ever handed out while a reader still protects it.
//!
//! ## Interaction with the schemes
//!
//! * **`Hazard`** scans compare exact announced addresses, which covers
//!    [`retire_node`] directly. A [`PageBatch`] is kept alive while
//!    *any* of its slots is announced: the batch's retired entry probes
//!    every slot address against the protection snapshot (see
//!    `hazard::retire_page_batch`), so a page is treated as live until
//!    its last protected slot is released.
//! * **`Epoch`** stamps the batch once at retire time — exactly how
//!    `CachedMemEff`'s §3.2 slab recycler stamps uninstalled nodes —
//!    and recycles all of its slots once the global epoch has advanced
//!    `FREE_DISTANCE` past the stamp. A pinned reader mid-chain blocks
//!    the advance, hence the whole page.
//!
//! Backing pages are retained at the high-water mark (slots recycle
//! forever; page memory is never returned to the OS), which is the
//! standard pool trade: churn throughput for a bounded, census-visible
//! footprint ([`stats`] reports pages, batches, and batch sizes).
//!
//! The pool can be disabled at runtime ([`set_enabled`]) for the
//! pooled-vs-boxed ablation (`repro ablate --panel alloc`): disabled,
//! [`alloc_node`] degrades to a headered heap allocation, and the
//! header keeps mixed populations safe — every node is freed the way it
//! was allocated, whichever way the toggle points now.

use std::alloc::{alloc, dealloc, handle_alloc_error, Layout};
use std::cell::RefCell;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Mutex, MutexGuard, PoisonError};

/// Node slots carved from one page (and the target batch size for
/// page-wise retirement).
pub const PAGE_SLOTS: usize = 64;

/// Bytes reserved at the head of every slot for the provenance header
/// (one word used; the rest keeps the payload 16-aligned).
const HEADER: usize = 16;

/// Slot alignment — covers every chain-node type in the crate (the
/// node payloads are `AtomicValue` words and raw pointers).
const SLOT_ALIGN: usize = 16;

/// Total slot footprints (header + payload), one per size class.
const CLASS_SIZES: [usize; 3] = [64, 128, 256];

const NUM_CLASSES: usize = CLASS_SIZES.len();

/// Header marker for the boxed (non-pooled) fallback allocation.
const BOXED: usize = usize::MAX;

/// Runtime toggle: `true` (default) pools qualifying node types;
/// `false` routes every [`alloc_node`] through the headered heap
/// fallback (the boxed baseline of `repro ablate --panel alloc`).
static ENABLED: AtomicBool = AtomicBool::new(true);

// Always-on pool accounting (relaxed, off the per-node hot path: pages
// are rare, batches are amortized) — powers the §5.5 memory census
// without the `telemetry` feature.
static PAGES: AtomicU64 = AtomicU64::new(0);
static BATCHES: AtomicU64 = AtomicU64::new(0);
static BATCH_SLOTS: AtomicU64 = AtomicU64::new(0);

/// Cumulative pool accounting for the §5.5 memory census.
#[derive(Clone, Copy, Debug, Default)]
pub struct PoolStats {
    /// Backing pages ever allocated (the pool's allocation rate: fresh
    /// page claims per unit work — near zero once recycling is warm).
    pub pages: u64,
    /// Page batches handed to a scheme via `Smr::retire_page`.
    pub batches: u64,
    /// Total slots across those batches (`batch_slots / batches` is the
    /// mean retire-batch size).
    pub batch_slots: u64,
}

/// Snapshot the cumulative pool counters (monotonic; consumers report
/// deltas).
pub fn stats() -> PoolStats {
    PoolStats {
        pages: PAGES.load(Ordering::Relaxed),
        batches: BATCHES.load(Ordering::Relaxed),
        batch_slots: BATCH_SLOTS.load(Ordering::Relaxed),
    }
}

/// Enable or disable pooled allocation; returns the previous setting.
/// Safe to flip at any time: the per-slot header records how each live
/// node was allocated, so frees never mismatch the toggle.
pub fn set_enabled(on: bool) -> bool {
    ENABLED.swap(on, Ordering::Relaxed)
}

/// Whether pooled allocation is currently enabled.
pub fn enabled() -> bool {
    ENABLED.load(Ordering::Relaxed)
}

/// Whether `T` qualifies for a pool size class (alignment within
/// [`SLOT_ALIGN`] and header + payload within the largest class).
fn class_of<T>() -> Option<usize> {
    if std::mem::align_of::<T>() > SLOT_ALIGN {
        return None;
    }
    let need = HEADER + std::mem::size_of::<T>();
    CLASS_SIZES.iter().position(|&s| need <= s)
}

/// Layout of the headered heap fallback for `T`.
fn boxed_layout<T>() -> Layout {
    Layout::from_size_align(
        HEADER + std::mem::size_of::<T>(),
        SLOT_ALIGN.max(std::mem::align_of::<T>()),
    )
    .expect("boxed fallback layout")
}

/// Poison-tolerant lock: the free lists hold plain addresses, so a
/// panicking holder leaves nothing half-updated worth poisoning over
/// (same discipline as the orphan-lock sites in `smr`).
fn lock<T>(m: &Mutex<T>) -> MutexGuard<'_, T> {
    m.lock().unwrap_or_else(PoisonError::into_inner)
}

/// Global spill lists (one per class): slots parked by exiting threads,
/// re-claimed page-wise before any fresh page is allocated.
static GLOBAL_FREE: [Mutex<Vec<usize>>; NUM_CLASSES] = {
    #[allow(clippy::declare_interior_mutable_const)]
    const L: Mutex<Vec<usize>> = Mutex::new(Vec::new());
    [L; NUM_CLASSES]
};

/// Per-thread free lists; the destructor parks leftovers on the global
/// spill lists so an exiting thread's slots stay claimable.
struct LocalLists([Vec<usize>; NUM_CLASSES]);

impl Drop for LocalLists {
    fn drop(&mut self) {
        for (class, list) in self.0.iter_mut().enumerate() {
            if !list.is_empty() {
                lock(&GLOBAL_FREE[class]).append(list);
            }
        }
    }
}

thread_local! {
    static LOCAL: RefCell<LocalLists> =
        RefCell::new(LocalLists([Vec::new(), Vec::new(), Vec::new()]));
}

/// Claim slot capacity for `class`: spill list first, else carve a
/// fresh page. Returns the slot base addresses.
fn claim_page(class: usize) -> Vec<usize> {
    // Fault window: a thread may die claiming — nothing is carved yet
    // and no lock is held, so rivals' claims and the pool stay live.
    crate::failpoint!(PoolClaimPage);
    {
        let mut spill = lock(&GLOBAL_FREE[class]);
        if !spill.is_empty() {
            let take = spill.len().min(PAGE_SLOTS);
            let at = spill.len() - take;
            return spill.split_off(at);
        }
    }
    let bytes = CLASS_SIZES[class] * PAGE_SLOTS;
    let layout = Layout::from_size_align(bytes, SLOT_ALIGN).expect("page layout");
    // SAFETY: non-zero, valid layout.
    let base = unsafe { alloc(layout) };
    if base.is_null() {
        handle_alloc_error(layout);
    }
    PAGES.fetch_add(1, Ordering::Relaxed);
    crate::counter!(PoolPageAlloc);
    (0..PAGE_SLOTS)
        .map(|i| base as usize + i * CLASS_SIZES[class])
        .collect()
}

/// Pop a slot base for `class` from this thread's list, claiming a page
/// on empty. Falls back to a direct claim when TLS is being torn down.
fn claim_slot(class: usize) -> usize {
    let fast = LOCAL.try_with(|l| l.borrow_mut().0[class].pop());
    match fast {
        Ok(Some(base)) => base,
        Ok(None) => {
            // Slow path outside the borrow: claim_page may yield/panic
            // under fault injection and must not wedge the RefCell.
            let mut carved = claim_page(class);
            let base = carved.pop().expect("claimed page has slots");
            if !carved.is_empty() {
                let spilled = LOCAL
                    .try_with(|l| l.borrow_mut().0[class].append(&mut carved))
                    .is_err();
                if spilled {
                    lock(&GLOBAL_FREE[class]).append(&mut carved);
                }
            }
            base
        }
        // TLS destructor already ran (allocation during thread exit):
        // claim straight from the global side.
        Err(_) => {
            let mut carved = claim_page(class);
            let base = carved.pop().expect("claimed page has slots");
            if !carved.is_empty() {
                lock(&GLOBAL_FREE[class]).append(&mut carved);
            }
            base
        }
    }
}

/// Return a slot to a free list (its node already dropped). `addr` is
/// the payload address; the header says how the slot was allocated.
///
/// # Safety
/// `addr` must be the payload address of a live [`alloc_node`]
/// allocation of type `T` whose node has already been dropped in place,
/// and no other reference to the slot may remain.
unsafe fn release_slot<T>(addr: usize) {
    let base = addr - HEADER;
    let header = unsafe { *(base as *const usize) };
    if header == BOXED {
        // SAFETY: allocated by alloc_node's fallback with this layout.
        unsafe { dealloc(base as *mut u8, boxed_layout::<T>()) };
        return;
    }
    debug_assert!(header < NUM_CLASSES, "corrupt pool slot header");
    crate::counter!(PoolRecycle);
    let parked = LOCAL
        .try_with(|l| l.borrow_mut().0[header].push(base))
        .is_err();
    if parked {
        // TLS teardown (scheme drop_fns can run inside destructors):
        // park on the global spill list instead.
        lock(&GLOBAL_FREE[header]).push(base);
    }
}

/// The type-erased "drop the node in place, then recycle its slot"
/// reclaimer for `T` — what the schemes run when a pooled node's grace
/// period expires.
pub(crate) fn recycle_fn<T>() -> unsafe fn(usize) {
    unsafe fn recycle<T>(addr: usize) {
        // SAFETY: retire contract — run exactly once, node unreachable.
        unsafe {
            std::ptr::drop_in_place(addr as *mut T);
            release_slot::<T>(addr);
        }
    }
    recycle::<T>
}

/// Allocate a chain node: pool slot when `T` qualifies and the pool is
/// enabled, headered heap fallback otherwise. Always release through
/// [`free_node_now`], [`retire_node`], or a [`PageBatch`] — never
/// `Box::from_raw`.
pub fn alloc_node<T>(value: T) -> *mut T {
    if enabled() {
        if let Some(class) = class_of::<T>() {
            let base = claim_slot(class);
            // SAFETY: the slot is exclusively ours (popped off a free
            // list), sized/aligned for the class that admitted T.
            unsafe {
                (base as *mut usize).write(class);
                let p = (base + HEADER) as *mut T;
                p.write(value);
                return p;
            }
        }
    }
    let layout = boxed_layout::<T>();
    // SAFETY: valid non-zero layout; header + payload writes are within
    // the allocation.
    unsafe {
        let base = alloc(layout);
        if base.is_null() {
            handle_alloc_error(layout);
        }
        (base as *mut usize).write(BOXED);
        let p = base.add(HEADER) as *mut T;
        p.write(value);
        p
    }
}

/// Drop a node and release its slot immediately — for exclusive paths
/// only (an unpublished copy after a lost CAS, exclusive table
/// teardown), where no concurrent reader can hold the pointer.
///
/// # Safety
/// `ptr` must come from [`alloc_node`], be unreachable by any other
/// thread, and not be released again.
pub unsafe fn free_node_now<T>(ptr: *mut T) {
    // SAFETY: caller guarantees exclusivity and single release.
    unsafe {
        std::ptr::drop_in_place(ptr);
        release_slot::<T>(ptr as usize);
    }
}

/// Retire a single published-then-unlinked node through scheme `S`: the
/// node is dropped and its slot recycled only after `S`'s grace period
/// (hazard: no announcement matches the address; epoch: the global
/// epoch passed the stamp by the free distance).
///
/// # Safety
/// `ptr` must come from [`alloc_node`] and satisfy
/// [`Smr::retire_box`](super::Smr::retire_box)'s contract: unlinked,
/// unique, no new references after retirement.
pub unsafe fn retire_node<S: super::Smr, T>(ptr: *mut T) {
    // SAFETY: forwarded contract.
    unsafe { S::retire_raw(ptr as usize, recycle_fn::<T>()) };
}

/// A drained page of retired nodes, awaiting one batched retirement
/// through [`Smr::retire_page`](super::Smr::retire_page). Dropping the
/// batch recycles every slot — the schemes arrange for that drop to run
/// only after the whole page's grace period.
pub struct PageBatch {
    slots: Vec<(usize, unsafe fn(usize))>,
}

impl PageBatch {
    pub fn new() -> Self {
        Self { slots: Vec::new() }
    }

    pub fn with_capacity(n: usize) -> Self {
        Self {
            slots: Vec::with_capacity(n),
        }
    }

    /// Add an unlinked node to the batch.
    ///
    /// # Safety
    /// Same contract as [`retire_node`]: `ptr` from [`alloc_node`],
    /// unlinked, unique, no new references.
    pub unsafe fn push<T>(&mut self, ptr: *mut T) {
        self.slots.push((ptr as usize, recycle_fn::<T>()));
    }

    pub fn len(&self) -> usize {
        self.slots.len()
    }

    pub fn is_empty(&self) -> bool {
        self.slots.is_empty()
    }

    /// Slot payload addresses — the hazard scheme's liveness probe: the
    /// page stays retired-but-unfreed while any of these is announced.
    pub(crate) fn addrs(&self) -> impl Iterator<Item = usize> + '_ {
        self.slots.iter().map(|&(a, _)| a)
    }

    /// Drain the batch for per-node retirement (the disabled-pool
    /// baseline in `Smr::retire_page`): the emptied batch's Drop
    /// becomes a no-op and each `(addr, recycle)` pair is the caller's
    /// to retire exactly once.
    pub(crate) fn take_slots(&mut self) -> Vec<(usize, unsafe fn(usize))> {
        std::mem::take(&mut self.slots)
    }
}

/// Serializes lib tests that flip [`set_enabled`] (the alloc-ablation
/// boxed arm) against tests whose assertions need the pool live for
/// their whole run (slot-reuse determinism, census batch counts).
#[cfg(test)]
pub(crate) static TOGGLE_TEST_LOCK: std::sync::Mutex<()> = std::sync::Mutex::new(());

impl Default for PageBatch {
    fn default() -> Self {
        Self::new()
    }
}

impl Drop for PageBatch {
    fn drop(&mut self) {
        for &(addr, recycle) in &self.slots {
            // SAFETY: each entry carries push()'s forwarded retire
            // contract; the batch is consumed exactly once.
            unsafe { recycle(addr) };
        }
    }
}

/// Batch accounting, called once per non-empty `retire_page`.
pub(crate) fn note_batch(len: usize) {
    BATCHES.fetch_add(1, Ordering::Relaxed);
    BATCH_SLOTS.fetch_add(len as u64, Ordering::Relaxed);
    crate::counter!(RetireBatch);
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn test_class_selection_and_oversize_fallback() {
        // Small PODs land in the first class; an over-aligned or huge
        // type is rejected (boxed fallback at alloc time).
        assert_eq!(class_of::<[u64; 3]>(), Some(0));
        assert_eq!(class_of::<[u64; 10]>(), Some(1));
        assert_eq!(class_of::<[u64; 29]>(), Some(2));
        assert_eq!(class_of::<[u64; 64]>(), None);
        #[repr(align(64))]
        struct Wide([u8; 8]);
        assert_eq!(class_of::<Wide>(), None);
    }

    #[test]
    fn test_alloc_free_roundtrip_reuses_slot() {
        // Hold the toggle lock: a parallel alloc-ablation test flipping
        // the pool off mid-roundtrip would break the reuse assertion.
        let _toggle = TOGGLE_TEST_LOCK
            .lock()
            .unwrap_or_else(std::sync::PoisonError::into_inner);
        let p = alloc_node([7u64, 8, 9]);
        assert_eq!(unsafe { (*p)[2] }, 9);
        unsafe { free_node_now(p) };
        // LIFO free list: the very next alloc of the same class must
        // reuse the slot (pool enabled by default).
        if enabled() {
            let q = alloc_node([1u64, 2, 3]);
            assert_eq!(q as usize, p as usize, "slot not recycled");
            unsafe { free_node_now(q) };
        }
    }

    #[test]
    fn test_boxed_fallback_roundtrip() {
        // Oversize type: always the headered heap fallback, and the
        // header routes the free correctly.
        let p = alloc_node([42u64; 64]);
        assert_eq!(unsafe { (*p)[63] }, 42);
        unsafe { free_node_now(p) };
        // Dropping a value with a destructor through the fallback.
        let s = alloc_node(String::from("pooled?"));
        unsafe { free_node_now(s) };
    }

    #[test]
    fn test_page_batch_drop_recycles_all() {
        use std::sync::atomic::AtomicUsize;
        use std::sync::Arc;
        struct D(Arc<AtomicUsize>);
        impl Drop for D {
            fn drop(&mut self) {
                self.0.fetch_add(1, Ordering::SeqCst);
            }
        }
        let drops = Arc::new(AtomicUsize::new(0));
        let mut batch = PageBatch::with_capacity(8);
        for _ in 0..8 {
            let p = alloc_node(D(Arc::clone(&drops)));
            unsafe { batch.push(p) };
        }
        assert_eq!(batch.len(), 8);
        drop(batch);
        assert_eq!(drops.load(Ordering::SeqCst), 8, "batch leaked nodes");
    }
}
