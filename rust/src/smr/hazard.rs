//! Hazard pointers (Michael, 2004) — the paper's SMR for indirect nodes.
//!
//! A single process-wide domain: a fixed announcement array with
//! [`SLOTS_PER_THREAD`] slots per registered thread (plus a grow-only
//! overflow list for guard nesting beyond the fixed budget — see
//! [`HazardPointer::new`]), per-thread retire lists with
//! threshold-triggered scans, and an orphan list absorbing the garbage
//! of exiting threads.
//!
//! The paper's fast path (§3.1) never dereferences the backup pointer, so
//! loads that hit the cache never touch this module; only slow-path reads
//! and updates pay the announce + fence cost.
//!
//! The announcement array is also what Algorithm 2's thread-private slab
//! recycler scans ("get_protected_ptrs", §3.2) — see
//! [`protected_snapshot`].
//!
//! ## Slot acquisition is cached, not claimed
//!
//! Acquiring a [`HazardPointer`] costs **one** thread-local access: each
//! thread caches its slot-array base (computed from the registry tid
//! once) together with the in-use bitmap in a single TLS struct, and
//! claims the lowest free slot with a `trailing_zeros`. Re-protecting
//! through a held guard ("re-arming") is just the announce store + fence
//! — no TLS at all. The seed instead walked two TLS variables and a
//! bitmap scan loop on *every* slow-path operation, which dominated the
//! announce cost.
//!
//! ## Ordering contract
//!
//! Hazard pointers are the textbook case of a required store-load
//! barrier, and this module owns the first of the crate's **two pairs**
//! of mandatory `fence(SeqCst)` points (the second pair — pin→validate
//! and advance→scan — lives in [`epoch`](super::epoch); everything else
//! in the synchronization core is Acquire/Release/Relaxed — see
//! [`crate::util::ordering`]):
//!
//! 1. **announce → revalidate** ([`HazardPointer::protect`] /
//!    [`protect_raw_with`](HazardPointer::protect_raw_with)): the slot
//!    store must be globally visible *before* the source pointer is
//!    re-read. Without the fence the CPU may order the revalidating load
//!    before the announcement store, and a concurrent
//!    retire→scan→free can miss the announcement while the revalidation
//!    still sees the old pointer — a use-after-free.
//! 2. **retire → scan** ([`scan`] / [`protected_snapshot`]): the
//!    reclaimer's fence pairs with (1). If the scanner's fence orders
//!    before an announcer's fence in the global SeqCst order, the
//!    announcer's revalidation is guaranteed to observe the unlink and
//!    retry; otherwise the scan observes the announcement. Either way no
//!    protected node is freed.
//!
//! Around those two fences, the individual accesses are demoted: slot
//! announce stores are `RELAXED` (the fence publishes them), slot scans
//! are `ACQUIRE` (pair with the publisher's `RELEASE` so node contents
//! are visible before any free), and slot clears are `RELEASE` (the
//! protected reads happen-before the slot release).

use std::cell::Cell;
use std::sync::atomic::{fence, AtomicBool, AtomicPtr, AtomicUsize, Ordering};
use std::sync::{Mutex, TryLockError};

use super::{pool, RetireBag, Smr, SmrGuard};
use crate::util::ordering::{DefaultPolicy as P, OrderingPolicy};
use crate::util::registry::tid;
use crate::MAX_THREADS;

/// Hazard slots available per thread (max simultaneously protected ptrs).
/// Algorithm 3 holds one on W while its inner Algorithm-1 CAS holds one on
/// Z's backup, and the hash tables can nest one more — 4 gives headroom.
pub const SLOTS_PER_THREAD: usize = 4;

const NSLOTS: usize = MAX_THREADS * SLOTS_PER_THREAD;

/// Retire-list length that triggers a scan. Scans are O(threads + list),
/// so amortized O(1) per retire with constant-factor tuning per §5.5's
/// c_h discussion.
pub const RETIRE_THRESHOLD: usize = 128;

static SLOTS: [AtomicUsize; NSLOTS] = {
    #[allow(clippy::declare_interior_mutable_const)]
    const Z: AtomicUsize = AtomicUsize::new(0);
    [Z; NSLOTS]
};

/// A raw retired allocation: pointer + type-erased destructor + the
/// liveness probe a scan consults ("is anything here still announced?").
struct Retired {
    ptr: usize,
    drop_fn: unsafe fn(usize),
    /// Returns `true` while the scan's protection snapshot still covers
    /// this entry: exact-address membership for single nodes
    /// ([`probe_single`]), any-slot membership for a retired page batch
    /// ([`probe_batch`] — the whole page is live while one slot is
    /// protected).
    probe: unsafe fn(usize, &[usize]) -> bool,
}

/// Exact-address protection: the classic hazard check.
fn probe_single(ptr: usize, protected: &[usize]) -> bool {
    protected.binary_search(&ptr).is_ok()
}

/// Page-batch protection: a pooled page is live while *any* of its slot
/// addresses is announced — exact-address search alone would free a page
/// out from under a reader protecting an interior node.
///
/// # Safety
/// `ptr` must point at the batch holder of a [`retire_page_batch`]
/// entry, still unfreed (the scan only probes entries it has not run
/// `drop_fn` on).
unsafe fn probe_batch(ptr: usize, protected: &[usize]) -> bool {
    unsafe { &*(ptr as *const pool::PageBatch) }
        .addrs()
        .any(|a| protected.binary_search(&a).is_ok())
}

// SAFETY: Retired is only ever consumed by calling drop_fn exactly once,
// after a scan proves no announcement references ptr.
unsafe impl Send for Retired {}

static ORPHANS: Mutex<Vec<Retired>> = Mutex::new(Vec::new());

/// The per-thread slot cache: base index into [`SLOTS`] plus the in-use
/// bitmap, resolved through a *single* TLS access per guard acquisition.
struct SlotCache {
    base: usize,
    bitmap: Cell<u8>,
}

thread_local! {
    // The shared self-flushing bag (smr::RetireBag): its own TLS
    // destructor hands leftovers to ORPHANS in any destructor order.
    static RETIRED: RetireBag<Retired> = RetireBag::new(&ORPHANS);
    // One TLS struct for the whole claim path (tid is resolved once, at
    // first use, not per operation).
    static SLOT_CACHE: SlotCache = SlotCache {
        base: tid() * SLOTS_PER_THREAD,
        bitmap: Cell::new(0),
    };
}

/// Overflow hazard slot: leased when a thread's [`SLOTS_PER_THREAD`]
/// fixed slots are all held (nesting deeper than the fixed budget
/// anticipated).  Nodes live on a grow-only lock-free list — allocated
/// once, leaked, and recycled through `in_use` — so the list's length is
/// the high-water mark of simultaneous overflow guards, and reclaimers
/// scan it exactly like the fixed array.
struct OverflowSlot {
    cell: AtomicUsize,
    in_use: AtomicBool,
    next: *const OverflowSlot,
}

// SAFETY: shared state is the two atomics; `next` is written only before
// the node is published and immutable afterwards.
unsafe impl Send for OverflowSlot {}
unsafe impl Sync for OverflowSlot {}

static OVERFLOW_HEAD: AtomicPtr<OverflowSlot> = AtomicPtr::new(std::ptr::null_mut());

/// Lease an overflow slot: recycle a free node or publish a fresh one.
fn acquire_overflow_slot() -> &'static OverflowSlot {
    // Ordering: ACQUIRE — pairs with the RELEASE push below so a node's
    // initialized fields (and its `next` chain) are visible.
    let mut p = OVERFLOW_HEAD.load(P::ACQUIRE);
    while !p.is_null() {
        // SAFETY: overflow nodes are leaked — 'static once published.
        let s = unsafe { &*p };
        // Ordering: RELAXED probe + ACQUIRE claim-CAS — the claim pairs
        // with the RELEASE lease-return in HazardPointer::drop, so the
        // previous holder's slot clear is visible before reuse.
        if !s.in_use.load(P::RELAXED)
            && s.in_use
                .compare_exchange(false, true, P::ACQUIRE, P::RELAXED)
                .is_ok()
        {
            return s;
        }
        p = s.next as *mut OverflowSlot;
    }
    let raw = Box::into_raw(Box::new(OverflowSlot {
        cell: AtomicUsize::new(0),
        in_use: AtomicBool::new(true),
        next: std::ptr::null(),
    }));
    // Ordering: RELAXED initial read + RELEASE publish-CAS (the node's
    // fields happen-before its address); RELAXED on failure — we only
    // re-link and retry.
    let mut head = OVERFLOW_HEAD.load(P::RELAXED);
    loop {
        // SAFETY: not yet published — exclusive.
        unsafe { (*raw).next = head };
        match OVERFLOW_HEAD.compare_exchange(head, raw, P::RELEASE, P::RELAXED) {
            // SAFETY: leaked — 'static.
            Ok(_) => return unsafe { &*raw },
            Err(h) => head = h,
        }
    }
}

/// Append every announced overflow address to `protected` (the overflow
/// leg of the reclaimers' announcement scans).
fn collect_overflow(protected: &mut Vec<usize>) {
    // Ordering: ACQUIRE — as in acquire_overflow_slot.
    let mut p = OVERFLOW_HEAD.load(P::ACQUIRE);
    while !p.is_null() {
        // SAFETY: leaked nodes.
        let s = unsafe { &*p };
        // Ordering: ACQUIRE — pairs with the RELEASE clear, as for the
        // fixed slots in `scan`.
        let v = s.cell.load(P::ACQUIRE);
        if v != 0 {
            protected.push(v);
        }
        p = s.next as *mut OverflowSlot;
    }
}

const SLOT_MASK: u8 = (1 << SLOTS_PER_THREAD) - 1;

/// RAII hazard slot. Acquire with [`HazardPointer::new`]; the protected
/// pointer is cleared when dropped. The slot itself is leased from the
/// thread's cached slot set — see the module docs.
pub struct HazardPointer {
    slot: &'static AtomicUsize,
    /// Fixed-slot bitmap bit; 0 for an overflow lease.
    bit: u8,
    /// The overflow node's recycle flag (`None` for fixed slots).
    lease: Option<&'static AtomicBool>,
}

/// Alias emphasizing the cached-slot acquisition path.
pub type HazardGuard = HazardPointer;

impl HazardPointer {
    /// Claim one of this thread's hazard slots (one TLS access + a
    /// trailing-zeros pick — no bitmap walk).
    ///
    /// When all [`SLOTS_PER_THREAD`] fixed slots are held, the guard
    /// spills to a registry-tracked overflow slot (scanned by the
    /// reclaimers like the fixed array) instead of panicking, so
    /// unusually deep guard nesting degrades to a slower claim rather
    /// than aborting the process.
    #[inline]
    pub fn new() -> Self {
        // Counts every guard acquisition (fixed or overflow) — the
        // hazard-side "pin" analog for the SMR traffic comparison.
        crate::counter!(HazardPin);
        SLOT_CACHE.with(|c| {
            let bm = c.bitmap.get();
            let free = !bm & SLOT_MASK;
            if free == 0 {
                crate::counter!(HazardOverflow);
                let s = acquire_overflow_slot();
                return HazardPointer {
                    slot: &s.cell,
                    bit: 0,
                    lease: Some(&s.in_use),
                };
            }
            let j = free.trailing_zeros() as usize;
            c.bitmap.set(bm | (1 << j));
            HazardPointer {
                slot: &SLOTS[c.base + j],
                bit: 1 << j,
                lease: None,
            }
        })
    }

    /// Protect the current value of `src`: announce-and-revalidate loop.
    /// On return the pointer cannot be reclaimed until this hazard is
    /// dropped or re-used.
    #[inline]
    pub fn protect<T>(&self, src: &AtomicPtr<T>) -> *mut T {
        loop {
            // Ordering: RELAXED — this speculative read is confirmed (or
            // retried) by the post-fence revalidation below.
            let p = src.load(P::RELAXED);
            // Ordering: RELAXED store — the SeqCst fence below is what
            // publishes the announcement before the revalidating load.
            self.slot.store(p as usize, P::RELAXED);
            // Ordering: mandatory store-load fence (module docs, point 1):
            // announce must be visible before `src` is re-read, pairing
            // with the reclaimer's fence in `scan`.
            fence(Ordering::SeqCst);
            // Fault window: announced but not yet revalidated — a stall
            // here pins the node indefinitely (scans must keep it), a
            // yield widens the announce/unlink race the fence resolves.
            crate::failpoint!(HazardAnnounce);
            // Ordering: ACQUIRE — on success this load pairs with the
            // Release publication of `p`, so the node's contents are
            // visible before the caller dereferences it.
            if src.load(P::ACQUIRE) == p {
                return p;
            }
        }
    }

    /// Protect a raw word (used for tagged/marked pointers where the
    /// caller strips tags itself). The *announced* value is the address
    /// the reclaimers compare against, so callers must announce the
    /// unmarked node address. `load` should be a `RELAXED`/`ACQUIRE`
    /// read of the source word — the fence here provides the store-load
    /// edge, and the final validating call of `load` is what the caller
    /// may rely on for Acquire publication (pass an `ACQUIRE` load).
    #[inline]
    pub fn protect_raw_with<F: Fn() -> usize, G: Fn(usize) -> usize>(
        &self,
        load: F,
        to_node: G,
    ) -> usize {
        loop {
            let raw = load();
            // Ordering: RELAXED store + mandatory SeqCst fence — same
            // announce→revalidate edge as `protect`.
            self.slot.store(to_node(raw), P::RELAXED);
            fence(Ordering::SeqCst);
            if load() == raw {
                return raw;
            }
        }
    }

    /// Announce an already-validated address directly (caller must ensure
    /// the node is still reachable afterwards, i.e. re-validate).
    #[inline]
    pub fn announce(&self, addr: usize) {
        // Ordering: RELAXED store + mandatory SeqCst fence — callers of
        // the raw announce still need the announce→revalidate edge
        // before any re-validation they perform.
        self.slot.store(addr, P::RELAXED);
        fence(Ordering::SeqCst);
    }

    /// Clear the announcement without releasing the slot.
    #[inline]
    pub fn clear(&self) {
        // Ordering: RELEASE — all reads through the protected pointer
        // happen-before the slot is observed empty by a scanner.
        self.slot.store(0, P::RELEASE);
    }
}

impl Default for HazardPointer {
    fn default() -> Self {
        Self::new()
    }
}

impl SmrGuard for HazardPointer {
    #[inline]
    fn protect_ptr<T>(&self, src: &AtomicPtr<T>) -> *mut T {
        self.protect(src)
    }

    #[inline]
    fn protect_raw<F: Fn() -> usize, G: Fn(usize) -> usize>(&self, load: F, to_node: G) -> usize {
        self.protect_raw_with(load, to_node)
    }
}

/// Hazard pointers as a zero-sized [`Smr`] tag — the pointer-grained
/// scheme (a guard protects exactly what it announces). The default for
/// every pointer-protect big-atomic backend.
pub struct Hazard;

impl Smr for Hazard {
    type Guard = HazardPointer;
    const NAME: &'static str = "hazard";

    #[inline]
    fn pin() -> HazardPointer {
        HazardPointer::new()
    }

    unsafe fn retire_box<T>(ptr: *mut T) {
        unsafe { retire_box(ptr) }
    }

    unsafe fn retire_raw(ptr: usize, drop_fn: unsafe fn(usize)) {
        unsafe { retire_raw(ptr, drop_fn) }
    }

    unsafe fn retire_page(mut page: pool::PageBatch) {
        if page.is_empty() {
            return;
        }
        if !pool::enabled() {
            // Disabled-pool baseline: per-node retirement, mirroring the
            // default impl (see `Smr::retire_page`).
            for (addr, recycle) in page.take_slots() {
                // SAFETY: slot contracts forwarded from the caller.
                unsafe { Self::retire_raw(addr, recycle) };
            }
            return;
        }
        pool::note_batch(page.len());
        retire_page_batch(page);
    }

    fn collect() {
        scan();
    }

    fn pending_reclaims() -> usize {
        pending_reclaims()
    }

    fn flush_thread_bag() {
        flush_thread_bag();
    }

    fn reclaim_protected(buf: &mut Vec<usize>) {
        protected_snapshot(buf);
    }

    fn reclaim_stamp() -> u64 {
        0 // protection is address-based; uninstall times are irrelevant
    }

    fn reclaim_stamp_expired(_stamp: u64) -> bool {
        true // ditto: the reclaim_protected scan is the whole answer
    }
}

impl Drop for HazardPointer {
    fn drop(&mut self) {
        // Ordering: RELEASE — as in `clear`: protected reads
        // happen-before a scanner observes the slot free.
        self.slot.store(0, P::RELEASE);
        match self.lease {
            // Ordering: RELEASE — the slot clear above happens-before
            // the next lessee's ACQUIRE claim sees the node free.
            Some(flag) => flag.store(false, P::RELEASE),
            None => {
                let _ = SLOT_CACHE.try_with(|c| c.bitmap.set(c.bitmap.get() & !self.bit));
            }
        }
    }
}

/// Retire a `Box<T>`-allocated node: reclaimed by a later scan once no
/// hazard announcement matches its address.
///
/// # Safety
/// `ptr` must be a unique, unlinked `Box<T>` allocation; no new
/// references may be created after retirement (only pre-existing
/// hazard-protected readers may still dereference it).
pub unsafe fn retire_box<T>(ptr: *mut T) {
    unsafe fn dropper<T>(addr: usize) {
        drop(unsafe { Box::from_raw(addr as *mut T) });
    }
    // SAFETY: forwarded contract (unique, unlinked Box).
    unsafe { retire_raw(ptr as usize, dropper::<T>) }
}

/// Retire a raw address with a custom reclaimer (the
/// [`Smr::retire_raw`] entry point — pool slot recycling rides here).
///
/// # Safety
/// Same contract as [`Smr::retire_raw`]: `drop_fn(ptr)` releases an
/// unlinked allocation exactly once; no references are created after
/// retirement.
pub unsafe fn retire_raw(ptr: usize, drop_fn: unsafe fn(usize)) {
    push_retired(Retired {
        ptr,
        drop_fn,
        probe: probe_single,
    });
}

/// Retire a drained page batch as **one** entry whose probe walks the
/// batch: the page's slots recycle together, only once no announcement
/// covers any of them (see [`probe_batch`]).
pub(crate) fn retire_page_batch(page: pool::PageBatch) {
    unsafe fn drop_holder(addr: usize) {
        // SAFETY: leaked on push below; the retire contract runs this
        // exactly once — dropping the batch recycles every slot.
        drop(unsafe { Box::from_raw(addr as *mut pool::PageBatch) });
    }
    let holder = Box::into_raw(Box::new(page));
    push_retired(Retired {
        ptr: holder as usize,
        drop_fn: drop_holder,
        probe: probe_batch,
    });
}

fn push_retired(item: Retired) {
    crate::counter!(HazardRetire);
    // Fault window: node unlinked, not yet on the retire list — a kill
    // here leaks the node (never double-frees); the RetireBag's TLS
    // destructor still hands already-pushed items to ORPHANS.
    crate::failpoint!(HazardRetire);
    let len = RETIRED.with(|r| r.push(item));
    if len >= RETIRE_THRESHOLD {
        scan();
    }
}

/// Scan announcements and free every retired node not protected.
/// Also opportunistically drains the orphan list of exited threads.
pub fn scan() {
    crate::counter!(HazardScan);
    // Fault window: scan about to snapshot announcements — dying here
    // only defers reclamation (the retire list survives in TLS/orphans).
    crate::failpoint!(HazardScan);
    // Ordering: mandatory store-load fence (module docs, point 2) —
    // pairs with the announcers' fences: every unlink that
    // happened-before this scan is ordered before the slot reads, so an
    // announcement made against the pre-unlink pointer either shows up
    // here or its owner's revalidation fails.
    fence(Ordering::SeqCst);
    // Snapshot all announcements (only slots of threads that ever
    // registered — see registry::high_water).
    let hw = crate::util::registry::high_water() * SLOTS_PER_THREAD;
    let mut protected: Vec<usize> = SLOTS[..hw]
        .iter()
        // Ordering: ACQUIRE — pairs with the RELEASE clear so a slot
        // observed empty implies its protected reads completed.
        .map(|s| s.load(P::ACQUIRE))
        .filter(|&p| p != 0)
        .collect();
    collect_overflow(&mut protected);
    protected.sort_unstable();

    let free = |list: &mut Vec<Retired>| {
        let mut kept = Vec::with_capacity(list.len());
        for item in list.drain(..) {
            // SAFETY (probe): page-batch probes dereference the retired
            // holder, which stays allocated until its drop_fn below.
            if unsafe { (item.probe)(item.ptr, &protected) } {
                kept.push(item);
            } else {
                crate::counter!(HazardFree);
                // SAFETY: unlinked before retirement and proven
                // unprotected by the snapshot above (every slot of a
                // page batch, per its probe); announcements made after
                // unlinking cannot reference it (protect() re-validates
                // against the source).
                unsafe { (item.drop_fn)(item.ptr) };
            }
        }
        *list = kept;
    };

    let _ = RETIRED.try_with(|r| r.with_items(&free));
    match ORPHANS.try_lock() {
        Ok(mut orphans) => {
            crate::counter!(OrphanLock);
            free(&mut orphans);
        }
        // Poisoned by a killed holder: the vec is still a valid retired
        // list — drain it rather than strand the garbage forever.
        Err(TryLockError::Poisoned(p)) => {
            crate::counter!(OrphanLock);
            free(&mut p.into_inner());
        }
        Err(TryLockError::WouldBlock) => {}
    }
}

/// Snapshot of all currently announced (non-zero) pointers.
/// Used by Algorithm 2's slab recycler (§3.2, "get_protected_ptrs").
pub fn protected_snapshot(buf: &mut Vec<usize>) {
    // Announcement-array walks by Algorithm 2's slab recycler count as
    // scans too — they pay the same fence + O(threads) cost.
    crate::counter!(HazardScan);
    buf.clear();
    // Ordering: mandatory store-load fence — same retire→scan edge as
    // `scan` (the slab recycler's uninstall store must be ordered before
    // these announcement reads).
    fence(Ordering::SeqCst);
    let hw = crate::util::registry::high_water() * SLOTS_PER_THREAD;
    for s in SLOTS[..hw].iter() {
        // Ordering: ACQUIRE — pairs with the announcers' publication as
        // in `scan`.
        let p = s.load(P::ACQUIRE);
        if p != 0 {
            buf.push(p);
        }
    }
    collect_overflow(buf);
}

/// Hand this thread's retire list to the process-wide orphan list now
/// (table drops on borrowed threads). Thread *exit* needs no call: the
/// list's own TLS destructor performs the handoff regardless of
/// destructor order.
pub fn flush_thread_bag() {
    // One spill event per explicit handoff to ORPHANS (thread-exit
    // handoffs via the TLS destructor route through here too, from
    // on_thread_exit).
    crate::counter!(HazardOrphanSpill);
    let _ = RETIRED.try_with(|r| r.flush());
}

/// Registry hook: a thread is exiting; park its garbage on the orphan
/// list and clear its announcement slots.
pub(crate) fn on_thread_exit(t: usize) {
    flush_thread_bag();
    for j in 0..SLOTS_PER_THREAD {
        // Ordering: RELEASE — the exiting thread's protected reads
        // happen-before any scanner sees its slots empty.
        SLOTS[t * SLOTS_PER_THREAD + j].store(0, P::RELEASE);
    }
}

/// Number of retired-but-not-yet-freed nodes owned by this thread,
/// plus everything on the orphan list — the §5.5 memory census.
pub fn pending_reclaims() -> usize {
    let local = RETIRED.try_with(|r| r.len()).unwrap_or(0);
    // Census reads take the lock (bounded retry, then block): the old
    // `try_lock().unwrap_or(0)` silently reported an empty orphan
    // column whenever a concurrent scan held the lock — the §5.5
    // census undercounted exactly when reclamation was busiest.
    let orphaned = super::census_lock(&ORPHANS).len();
    local + orphaned
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicUsize as AU;
    use std::sync::Arc;

    static DROPS: AU = AU::new(0);

    struct Counted(#[allow(dead_code)] u64);
    impl Drop for Counted {
        fn drop(&mut self) {
            DROPS.fetch_add(1, Ordering::SeqCst);
        }
    }

    #[test]
    fn test_protect_and_retire_roundtrip() {
        let node = Box::into_raw(Box::new(Counted(7)));
        let src = AtomicPtr::new(node);
        let h = HazardPointer::new();
        let p = h.protect(&src);
        assert_eq!(p, node);
        // Unlink + retire; protected, so a scan must not free it.
        src.store(std::ptr::null_mut(), Ordering::SeqCst);
        let before = DROPS.load(Ordering::SeqCst);
        unsafe { retire_box(p) };
        scan();
        assert_eq!(DROPS.load(Ordering::SeqCst), before);
        // Release protection; now a scan frees it.
        drop(h);
        scan();
        assert_eq!(DROPS.load(Ordering::SeqCst), before + 1);
    }

    #[test]
    fn test_slot_reuse_after_drop() {
        for _ in 0..100 {
            let h = HazardPointer::new();
            h.announce(0xdead0);
            drop(h);
        }
        // Must not panic ("all slots in use") — slots are recycled.
        let _hs: Vec<_> = (0..SLOTS_PER_THREAD).map(|_| HazardPointer::new()).collect();
    }

    #[test]
    fn test_guards_claim_distinct_slots() {
        // The trailing-zeros claim must never hand out the same slot to
        // two live guards, in any drop order.
        let a = HazardPointer::new();
        let b = HazardPointer::new();
        let c = HazardPointer::new();
        assert_ne!(a.slot as *const _, b.slot as *const _);
        assert_ne!(b.slot as *const _, c.slot as *const _);
        assert_ne!(a.slot as *const _, c.slot as *const _);
        // Non-LIFO release: drop the middle guard, re-acquire, and the
        // freed slot (and only it) is reused.
        let freed = b.slot as *const AtomicUsize;
        drop(b);
        let d = HazardPointer::new();
        assert_eq!(d.slot as *const _, freed);
    }

    #[test]
    fn test_threshold_scan_frees_unprotected() {
        let before = DROPS.load(Ordering::SeqCst);
        let n = RETIRE_THRESHOLD + 8;
        for i in 0..n {
            let node = Box::into_raw(Box::new(Counted(i as u64)));
            unsafe { retire_box(node) };
        }
        scan();
        assert!(DROPS.load(Ordering::SeqCst) >= before + n as usize);
    }

    #[test]
    fn test_concurrent_protect_no_use_after_free() {
        // One writer keeps swapping the pointer and retiring; readers
        // protect and read. Miri-style UAF would crash; we also check the
        // value invariant (field equals the generation it was born with).
        let src = Arc::new(AtomicPtr::new(Box::into_raw(Box::new(0u64))));
        let stop = Arc::new(std::sync::atomic::AtomicBool::new(false));
        let mut handles = Vec::new();
        for _ in 0..3 {
            let src = Arc::clone(&src);
            let stop = Arc::clone(&stop);
            handles.push(std::thread::spawn(move || {
                let h = HazardPointer::new();
                while !stop.load(Ordering::Relaxed) {
                    let p = h.protect(&src);
                    let v = unsafe { *p };
                    assert!(v < 1 << 40, "corrupt read {v:#x}");
                }
            }));
        }
        for gen in 1..3000u64 {
            let new = Box::into_raw(Box::new(gen));
            let old = src.swap(new, Ordering::SeqCst);
            unsafe { retire_box(old) };
        }
        stop.store(true, Ordering::SeqCst);
        for h in handles {
            h.join().unwrap();
        }
        unsafe { retire_box(src.load(Ordering::SeqCst)) };
    }

    #[test]
    fn test_overflow_slots_beyond_fixed_budget() {
        // Regression: the seed panicked when a thread's slot bitmap was
        // full. Over-acquiring must spill to overflow slots that protect
        // exactly like fixed ones and are recycled after release.
        struct LocalCounted(Arc<AU>);
        impl Drop for LocalCounted {
            fn drop(&mut self) {
                self.0.fetch_add(1, Ordering::AcqRel);
            }
        }
        let drops = Arc::new(AU::new(0));
        let guards: Vec<HazardPointer> = (0..SLOTS_PER_THREAD + 2)
            .map(|_| HazardPointer::new())
            .collect();
        // The last two guards hold overflow leases.
        assert!(guards[SLOTS_PER_THREAD].lease.is_some());
        assert!(guards[SLOTS_PER_THREAD + 1].lease.is_some());
        // An overflow guard's announcement shows up in snapshots...
        let node = Box::into_raw(Box::new(LocalCounted(Arc::clone(&drops))));
        let src = AtomicPtr::new(node);
        let h = guards.last().unwrap();
        let p = h.protect(&src);
        let mut buf = Vec::new();
        protected_snapshot(&mut buf);
        assert!(buf.contains(&(p as usize)));
        // ...and protects against the scan.
        src.store(std::ptr::null_mut(), Ordering::SeqCst);
        unsafe { retire_box(p) };
        scan();
        assert_eq!(drops.load(Ordering::Acquire), 0, "freed while protected");
        drop(guards);
        scan();
        assert_eq!(drops.load(Ordering::Acquire), 1, "not freed after release");
        // Released leases are recycled — a second over-acquisition must
        // reuse the leaked nodes, not panic.
        let again: Vec<HazardPointer> = (0..SLOTS_PER_THREAD + 2)
            .map(|_| HazardPointer::new())
            .collect();
        assert!(again.last().unwrap().lease.is_some());
    }

    #[test]
    fn test_protected_snapshot_contains_announced() {
        let h = HazardPointer::new();
        h.announce(0xabc0);
        let mut buf = Vec::new();
        protected_snapshot(&mut buf);
        assert!(buf.contains(&0xabc0));
        h.clear();
        protected_snapshot(&mut buf);
        assert!(!buf.contains(&0xabc0));
    }
}
