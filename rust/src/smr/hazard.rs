//! Hazard pointers (Michael, 2004) — the paper's SMR for indirect nodes.
//!
//! A single process-wide domain: a fixed announcement array with
//! [`SLOTS_PER_THREAD`] slots per registered thread, per-thread retire
//! lists with threshold-triggered scans, and an orphan list absorbing the
//! garbage of exiting threads.
//!
//! The paper's fast path (§3.1) never dereferences the backup pointer, so
//! loads that hit the cache never touch this module; only slow-path reads
//! and updates pay the announce + fence cost.
//!
//! The announcement array is also what Algorithm 2's thread-private slab
//! recycler scans ("get_protected_ptrs", §3.2) — see
//! [`protected_snapshot`].

use std::cell::RefCell;
use std::sync::atomic::{AtomicPtr, AtomicUsize, Ordering};
use std::sync::Mutex;

use crate::util::registry::tid;
use crate::MAX_THREADS;

/// Hazard slots available per thread (max simultaneously protected ptrs).
/// Algorithm 3 holds one on W while its inner Algorithm-1 CAS holds one on
/// Z's backup, and the hash tables can nest one more — 4 gives headroom.
pub const SLOTS_PER_THREAD: usize = 4;

const NSLOTS: usize = MAX_THREADS * SLOTS_PER_THREAD;

/// Retire-list length that triggers a scan. Scans are O(threads + list),
/// so amortized O(1) per retire with constant-factor tuning per §5.5's
/// c_h discussion.
pub const RETIRE_THRESHOLD: usize = 128;

static SLOTS: [AtomicUsize; NSLOTS] = {
    #[allow(clippy::declare_interior_mutable_const)]
    const Z: AtomicUsize = AtomicUsize::new(0);
    [Z; NSLOTS]
};

/// A raw retired allocation: pointer + type-erased destructor.
struct Retired {
    ptr: usize,
    drop_fn: unsafe fn(usize),
}

// SAFETY: Retired is only ever consumed by calling drop_fn exactly once,
// after a scan proves no announcement references ptr.
unsafe impl Send for Retired {}

static ORPHANS: Mutex<Vec<Retired>> = Mutex::new(Vec::new());

thread_local! {
    static RETIRED: RefCell<Vec<Retired>> = const { RefCell::new(Vec::new()) };
    // Cell, not RefCell: slot claim/release is on the cas hot path.
    static SLOT_BITMAP: std::cell::Cell<u8> = const { std::cell::Cell::new(0) };
}

/// RAII hazard slot. Acquire with [`HazardPointer::new`]; the protected
/// pointer is cleared when dropped.
pub struct HazardPointer {
    slot: &'static AtomicUsize,
    bit: u8,
}

impl HazardPointer {
    /// Claim one of this thread's hazard slots.
    ///
    /// Panics if all [`SLOTS_PER_THREAD`] slots are in use (a structural
    /// bug — operations hold at most a constant number).
    pub fn new() -> Self {
        let t = tid();
        SLOT_BITMAP.with(|bm| {
            let cur = bm.get();
            for j in 0..SLOTS_PER_THREAD {
                let bit = 1u8 << j;
                if cur & bit == 0 {
                    bm.set(cur | bit);
                    return HazardPointer {
                        slot: &SLOTS[t * SLOTS_PER_THREAD + j],
                        bit,
                    };
                }
            }
            panic!("all {SLOTS_PER_THREAD} hazard slots of thread {t} in use");
        })
    }

    /// Protect the current value of `src`: announce-and-revalidate loop.
    /// On return the pointer cannot be reclaimed until this hazard is
    /// dropped or re-used.
    #[inline]
    pub fn protect<T>(&self, src: &AtomicPtr<T>) -> *mut T {
        loop {
            let p = src.load(Ordering::SeqCst);
            self.slot.store(p as usize, Ordering::SeqCst);
            if src.load(Ordering::SeqCst) == p {
                return p;
            }
        }
    }

    /// Protect a raw word (used for tagged/marked pointers where the
    /// caller strips tags itself). The *announced* value is the address
    /// the reclaimers compare against, so callers must announce the
    /// unmarked node address.
    #[inline]
    pub fn protect_raw_with<F: Fn() -> usize, G: Fn(usize) -> usize>(
        &self,
        load: F,
        to_node: G,
    ) -> usize {
        loop {
            let raw = load();
            self.slot.store(to_node(raw), Ordering::SeqCst);
            if load() == raw {
                return raw;
            }
        }
    }

    /// Announce an already-validated address directly (caller must ensure
    /// the node is still reachable afterwards, i.e. re-validate).
    #[inline]
    pub fn announce(&self, addr: usize) {
        self.slot.store(addr, Ordering::SeqCst);
    }

    /// Clear the announcement without releasing the slot.
    #[inline]
    pub fn clear(&self) {
        self.slot.store(0, Ordering::Release);
    }
}

impl Default for HazardPointer {
    fn default() -> Self {
        Self::new()
    }
}

impl Drop for HazardPointer {
    fn drop(&mut self) {
        self.slot.store(0, Ordering::Release);
        SLOT_BITMAP.with(|bm| bm.set(bm.get() & !self.bit));
    }
}

/// Retire a `Box<T>`-allocated node: reclaimed by a later scan once no
/// hazard announcement matches its address.
///
/// # Safety
/// `ptr` must be a unique, unlinked `Box<T>` allocation; no new
/// references may be created after retirement (only pre-existing
/// hazard-protected readers may still dereference it).
pub unsafe fn retire_box<T>(ptr: *mut T) {
    unsafe fn dropper<T>(addr: usize) {
        drop(unsafe { Box::from_raw(addr as *mut T) });
    }
    let item = Retired {
        ptr: ptr as usize,
        drop_fn: dropper::<T>,
    };
    let len = RETIRED.with(|r| {
        let mut r = r.borrow_mut();
        r.push(item);
        r.len()
    });
    if len >= RETIRE_THRESHOLD {
        scan();
    }
}

/// Scan announcements and free every retired node not protected.
/// Also opportunistically drains the orphan list of exited threads.
pub fn scan() {
    // Snapshot all announcements (only slots of threads that ever
    // registered — see registry::high_water).
    let hw = crate::util::registry::high_water() * SLOTS_PER_THREAD;
    let mut protected: Vec<usize> = SLOTS[..hw]
        .iter()
        .map(|s| s.load(Ordering::SeqCst))
        .filter(|&p| p != 0)
        .collect();
    protected.sort_unstable();

    let free = |list: &mut Vec<Retired>| {
        let mut kept = Vec::with_capacity(list.len());
        for item in list.drain(..) {
            if protected.binary_search(&item.ptr).is_ok() {
                kept.push(item);
            } else {
                // SAFETY: unlinked before retirement and proven
                // unprotected by the snapshot above; announcements made
                // after unlinking cannot reference it (protect()
                // re-validates against the source).
                unsafe { (item.drop_fn)(item.ptr) };
            }
        }
        *list = kept;
    };

    RETIRED.with(|r| free(&mut r.borrow_mut()));
    if let Ok(mut orphans) = ORPHANS.try_lock() {
        free(&mut orphans);
    }
}

/// Snapshot of all currently announced (non-zero) pointers.
/// Used by Algorithm 2's slab recycler (§3.2, "get_protected_ptrs").
pub fn protected_snapshot(buf: &mut Vec<usize>) {
    buf.clear();
    let hw = crate::util::registry::high_water() * SLOTS_PER_THREAD;
    for s in SLOTS[..hw].iter() {
        let p = s.load(Ordering::SeqCst);
        if p != 0 {
            buf.push(p);
        }
    }
}

/// Registry hook: a thread is exiting; park its garbage on the orphan
/// list and clear its announcement slots.
pub(crate) fn on_thread_exit(t: usize) {
    // TLS destructor ordering is unspecified; RETIRED may already be gone.
    let _ = RETIRED.try_with(|r| {
        let mut r = r.borrow_mut();
        if !r.is_empty() {
            ORPHANS.lock().unwrap().append(&mut r);
        }
    });
    for j in 0..SLOTS_PER_THREAD {
        SLOTS[t * SLOTS_PER_THREAD + j].store(0, Ordering::Release);
    }
}

/// Number of retired-but-not-yet-freed nodes owned by this thread
/// (plus orphans if the lock is free) — used by the §5.5 memory census.
pub fn pending_reclaims() -> usize {
    let local = RETIRED.with(|r| r.borrow().len());
    let orphaned = ORPHANS.try_lock().map(|o| o.len()).unwrap_or(0);
    local + orphaned
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicUsize as AU;
    use std::sync::Arc;

    static DROPS: AU = AU::new(0);

    struct Counted(#[allow(dead_code)] u64);
    impl Drop for Counted {
        fn drop(&mut self) {
            DROPS.fetch_add(1, Ordering::SeqCst);
        }
    }

    #[test]
    fn test_protect_and_retire_roundtrip() {
        let node = Box::into_raw(Box::new(Counted(7)));
        let src = AtomicPtr::new(node);
        let h = HazardPointer::new();
        let p = h.protect(&src);
        assert_eq!(p, node);
        // Unlink + retire; protected, so a scan must not free it.
        src.store(std::ptr::null_mut(), Ordering::SeqCst);
        let before = DROPS.load(Ordering::SeqCst);
        unsafe { retire_box(p) };
        scan();
        assert_eq!(DROPS.load(Ordering::SeqCst), before);
        // Release protection; now a scan frees it.
        drop(h);
        scan();
        assert_eq!(DROPS.load(Ordering::SeqCst), before + 1);
    }

    #[test]
    fn test_slot_reuse_after_drop() {
        for _ in 0..100 {
            let h = HazardPointer::new();
            h.announce(0xdead0);
            drop(h);
        }
        // Must not panic ("all slots in use") — slots are recycled.
        let _hs: Vec<_> = (0..SLOTS_PER_THREAD).map(|_| HazardPointer::new()).collect();
    }

    #[test]
    fn test_threshold_scan_frees_unprotected() {
        let before = DROPS.load(Ordering::SeqCst);
        let n = RETIRE_THRESHOLD + 8;
        for i in 0..n {
            let node = Box::into_raw(Box::new(Counted(i as u64)));
            unsafe { retire_box(node) };
        }
        scan();
        assert!(DROPS.load(Ordering::SeqCst) >= before + n as usize);
    }

    #[test]
    fn test_concurrent_protect_no_use_after_free() {
        // One writer keeps swapping the pointer and retiring; readers
        // protect and read. Miri-style UAF would crash; we also check the
        // value invariant (field equals the generation it was born with).
        let src = Arc::new(AtomicPtr::new(Box::into_raw(Box::new(0u64))));
        let stop = Arc::new(std::sync::atomic::AtomicBool::new(false));
        let mut handles = Vec::new();
        for _ in 0..3 {
            let src = Arc::clone(&src);
            let stop = Arc::clone(&stop);
            handles.push(std::thread::spawn(move || {
                let h = HazardPointer::new();
                while !stop.load(Ordering::Relaxed) {
                    let p = h.protect(&src);
                    let v = unsafe { *p };
                    assert!(v < 1 << 40, "corrupt read {v:#x}");
                }
            }));
        }
        for gen in 1..3000u64 {
            let new = Box::into_raw(Box::new(gen));
            let old = src.swap(new, Ordering::SeqCst);
            unsafe { retire_box(old) };
        }
        stop.store(true, Ordering::SeqCst);
        for h in handles {
            h.join().unwrap();
        }
        unsafe { retire_box(src.load(Ordering::SeqCst)) };
    }

    #[test]
    fn test_protected_snapshot_contains_announced() {
        let h = HazardPointer::new();
        h.announce(0xabc0);
        let mut buf = Vec::new();
        protected_snapshot(&mut buf);
        assert!(buf.contains(&0xabc0));
        h.clear();
        protected_snapshot(&mut buf);
        assert!(!buf.contains(&0xabc0));
    }
}
