//! # big_atomics — a reproduction of *Big Atomics* (Anderson, Blelloch, Jayanti; 2025)
//!
//! Software multi-word ("big") atomics supporting `load`, `store`, and
//! `cas` over `k` adjacent 64-bit words, the full design-space the paper
//! evaluates, and the CacheHash concurrent hash table built on them.
//!
//! ## Implementations (paper Table 1)
//!
//! | Type | Progress | Operations | Paper § |
//! |---|---|---|---|
//! | [`atomics::SeqLock`] | blocks on race | load+store+cas | §2 |
//! | [`atomics::SimpLock`] | always blocks | load+store+cas | §2 |
//! | [`atomics::LockPool`] | always blocks (shared locks — the GNU libatomic / `std::atomic` analog) | load+store+cas | §5.1 |
//! | [`atomics::Indirect`] | lock-free | load+store+cas | §2 |
//! | [`atomics::CachedWaitFree`] | wait-free | load+cas (store = cas loop) | §3.1, Alg 1 |
//! | [`atomics::CachedMemEff`] | lock-free | load+store+cas | §3.2, Alg 2 |
//! | [`atomics::CachedWritable`] | wait-free | load+store+cas | §3.3, Alg 3 |
//! | [`atomics::HtmSim`] | blocks on fallback | load+store+cas | §5.4 (simulated RTM — see DESIGN.md §Substitutions) |
//!
//! ## Quick start
//!
//! ```
//! use big_atomics::atomics::{BigAtomic, CachedMemEff, Words};
//!
//! // A 4-word (32-byte) lock-free atomic value.
//! let a: CachedMemEff<Words<4>> = CachedMemEff::new(Words([1, 2, 3, 4]));
//! let v = a.load();
//! // The witnessing CAS: Ok(previous) on success, Err(current) on failure.
//! assert_eq!(a.compare_exchange(v, Words([5, 6, 7, 8])), Ok(v));
//! assert_eq!(a.load(), Words([5, 6, 7, 8]));
//! // Closure-shaped atomic updates (retries feed the witness back):
//! let prev = a.fetch_update(|mut w| { w.0[0] += 1; Some(w) }).unwrap();
//! assert_eq!(prev, Words([5, 6, 7, 8]));
//! ```
//!
//! ## Layout of this crate (three-layer architecture)
//!
//! * [`atomics`], [`smr`], [`hash`] — the paper's systems (L3).
//! * [`ingress`] — the lock-free sharded claim-queue front door of the
//!   KV service (multi-producer enqueue-and-tally on one big atomic,
//!   exactly-one-drainer runs, admission backpressure).
//! * [`obs`] — crate-native telemetry: per-thread sharded event counters
//!   (behind the `telemetry` feature's [`counter!`] macro) + lock-free
//!   log-linear latency histograms + JSON [`obs::ObsSnapshot`] dumps.
//! * [`fault`] — deterministic fault injection (behind the `fault`
//!   feature's [`failpoint!`]/[`failcas!`] macros): seeded plans that
//!   delay, stall, fail, or kill threads at named protocol points, plus
//!   the chaos scenarios proving the protocols survive.
//! * [`bench`] — workload generators + the harness regenerating every
//!   figure/table of the paper's §5.
//! * [`runtime`] — PJRT client executing the AOT-compiled JAX/Pallas
//!   workload model (`artifacts/*.hlo.txt`); build once via `make artifacts`.
//! * [`coordinator`] — benchmark leader + a mini KV service exercising the
//!   whole stack end to end.

pub mod apps;
pub mod atomics;
pub mod bench;
pub mod coordinator;
pub mod fault;
pub mod hash;
pub mod ingress;
pub mod obs;
pub mod runtime;
pub mod smr;
pub mod util;

/// Maximum number of registered threads (hazard slots, memeff pools, epochs).
pub const MAX_THREADS: usize = 256;
