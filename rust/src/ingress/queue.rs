//! `ClaimQueue<T>` — the multi-producer claim-pattern batch queue.
//!
//! The whole queue is one [`BigAtomic`]: a [`SeqLock`]`<`[`QueueState`]`>`
//! descriptor packing `{head, tally, claim}` into three words. Producers
//! push heap nodes onto the intrusive `head` list and bump `tally` with
//! **one witnessing `compare_exchange`** (enqueue-and-tally); a worker
//! becomes the queue's *exactly-one drainer* by CASing the whole
//! accumulated run out (`head/tally → 0`) while flipping the claim word
//! odd — claim-and-detach is also a single CAS. Retry loops continue
//! from the `Err` witness (never re-load) under the adaptive
//! [`Backoff`](crate::util::backoff::Backoff); detached nodes are
//! reclaimed through [`smr::epoch`](crate::smr::epoch).
//!
//! ## Linearization points
//!
//! * **enqueue** — the successful `compare_exchange` installing
//!   `{head: node, tally+1, claim}` (inside the seqlock writer's
//!   critical section; the version-word `RELEASE` unlock publishes the
//!   node's `next`/`stamp`/payload writes, which precede the CAS in
//!   program order, to any later `ACQUIRE` of the descriptor).
//! * **claim** — the successful `compare_exchange` installing
//!   `{head: 0, tally: 0, claim|1}`: the entire run transfers to the
//!   winning drainer at this instant, and every later `try_claim`
//!   observes the odd claim word and fails until release.
//! * **release** — the `fetch_update` bumping the odd claim word to the
//!   next even value ([`Run`]'s drop): the next successful claim's CAS
//!   is ordered after it by the witness contract.
//!
//! ## Why the claim word is an epoch, not a flag
//!
//! `claim` advances by one on every claim and every release (odd while
//! a drainer holds the run). Because it only ever grows, the
//! full-descriptor CAS is ABA-proof: a head pointer that was detached,
//! freed, reallocated, and re-pushed at the same address can never
//! reappear with the same `(tally, claim)` pair — any intervening
//! detach bumped `claim`. `claim >> 1` is also a free statistic: the
//! number of runs ever claimed (plus one while a drainer is active).
//!
//! ## Drainer leases (stall tolerance)
//!
//! A queue built with [`ClaimQueue::with_lease`] bounds how long a
//! drainer may sit on the claim word: the descriptor's fourth word
//! (`since`) records when the current claim was taken, and a
//! `try_claim` that finds the claim word odd *and expired* CASes it
//! away — `claim + 2` if there are fresh batches to drain (the caller
//! becomes the new drainer), `claim + 1` if not (a release on the dead
//! drainer's behalf). Both keep `claim` strictly growing, so the
//! ABA-proofing above is untouched. The displaced [`Run`] remembers the
//! odd claim value it installed and releases **only if it still
//! matches** at drop time; its undrained batches are re-pushed (bound
//! exempt — they were already admitted once), so a stalled or killed
//! drainer delays its backlog but never loses it, and never
//! double-releases a claim it no longer holds. [`ClaimQueue::new`]
//! disables the lease (`lease_ns = 0`): exactly-one-drainer then holds
//! unconditionally, as the linearizability suite pins.
//!
//! ## Reclamation
//!
//! After the claim CAS the chain is unreachable from the descriptor,
//! but a probing reader ([`ClaimQueue::peek_stamp`]) may have loaded the
//! old head under an epoch pin and still dereference it — so detached
//! nodes are retired through [`smr::epoch`](crate::smr::epoch), never
//! freed in place. Payloads move out at detach time; the node boxes ride
//! the epoch bags (`FREE_DISTANCE` behind the pinning front).

use std::marker::PhantomData;
use std::mem::ManuallyDrop;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::OnceLock;
use std::time::Instant;

use crate::atomics::{BigAtomic, SeqLock};
use crate::impl_atomic_value;
use crate::smr::epoch;
use crate::util::backoff::snooze_lazy;

/// Monotonic nanoseconds since the first lease-bearing operation in the
/// process — the clock the drainer lease is measured against. A plain
/// `Instant` can't ride inside the big-atomic descriptor; an offset
/// from a process-global origin can.
fn now_ns() -> u64 {
    static ORIGIN: OnceLock<Instant> = OnceLock::new();
    ORIGIN.get_or_init(Instant::now).elapsed().as_nanos() as u64
}

/// The queue descriptor: one 4-word big-atomic value.
///
/// `head` is the newest node's address (0 = empty), `tally` the number
/// of queued-but-unclaimed batches, `claim` the drainer epoch (odd ⇔ a
/// drainer holds the current run; see the module docs for why this is a
/// counter rather than a flag), `since` the [`now_ns`] timestamp of the
/// current claim (meaningful only while `claim` is odd; drives the
/// drainer lease).
#[repr(C, align(8))]
#[derive(Copy, Clone, PartialEq, Eq, Default, Debug)]
pub struct QueueState {
    /// Newest node (`*mut Node<T>` as u64); 0 when empty.
    pub head: u64,
    /// Batches enqueued and not yet claimed (the admission bound's
    /// currency).
    pub tally: u64,
    /// Drainer epoch: odd ⇔ claimed; bumps on claim *and* release.
    pub claim: u64,
    /// [`now_ns`] when the current claim was installed (lease anchor).
    pub since: u64,
}
impl_atomic_value!(QueueState);

impl QueueState {
    /// Whether a drainer currently holds a claimed run.
    #[inline]
    pub fn drainer_active(self) -> bool {
        self.claim & 1 == 1
    }

    /// Runs claimed so far (counting an in-flight one).
    #[inline]
    pub fn claim_runs(self) -> u64 {
        self.claim.div_ceil(2)
    }
}

/// Intrusive list node. `next`/`stamp` are plain fields: they are
/// written only while the node is thread-private (before the publishing
/// CAS) and read only by the exclusive drainer or by pinned peekers,
/// both ordered after the publication (module docs, "enqueue").
struct Node<T> {
    next: u64,
    /// Tally right after this node's enqueue — what
    /// [`ClaimQueue::peek_stamp`] probes.
    stamp: u64,
    item: ManuallyDrop<T>,
}

/// Multi-producer / exactly-one-drainer batch queue (see module docs).
///
/// `bound` caps `tally` (0 = unbounded): a full queue rejects pushes in
/// [`try_push`](Self::try_push), and the admission layer turns that into
/// shed-or-wait policy. No `Mutex`/`Condvar` anywhere — producers and
/// drainers use only the witnessing CAS, `util::backoff`, and the epoch
/// scheme.
pub struct ClaimQueue<T: Send + 'static> {
    state: SeqLock<QueueState>,
    bound: u64,
    /// Max nanoseconds a drainer may hold the claim word before any
    /// `try_claim` may take it over (0 = no lease, claims are held
    /// unconditionally).
    lease_ns: u64,
    /// Expired claims CASed away from a stalled drainer.
    takeovers: AtomicU64,
    /// Batches re-pushed by a displaced or aborted [`Run`]'s drop.
    requeued: AtomicU64,
    _owns: PhantomData<T>,
}

// SAFETY: the queue moves `T` values across threads (producer → drainer)
// but never shares a `&T`; `T: Send` is exactly the requirement. The
// descriptor itself is a big atomic.
unsafe impl<T: Send + 'static> Send for ClaimQueue<T> {}
unsafe impl<T: Send + 'static> Sync for ClaimQueue<T> {}

impl<T: Send + 'static> ClaimQueue<T> {
    /// An empty queue admitting at most `bound` queued batches
    /// (0 = unbounded). No drainer lease: a claimed run is held until
    /// its `Run` drops, however long that takes.
    pub fn new(bound: u64) -> Self {
        Self::with_lease(bound, 0)
    }

    /// Like [`new`](Self::new), but a drainer holding the claim word
    /// longer than `lease_ns` nanoseconds may be displaced by any later
    /// `try_claim` (0 = no lease). See the module docs, "Drainer
    /// leases".
    pub fn with_lease(bound: u64, lease_ns: u64) -> Self {
        Self {
            state: SeqLock::new(QueueState::default()),
            bound,
            lease_ns,
            takeovers: AtomicU64::new(0),
            requeued: AtomicU64::new(0),
            _owns: PhantomData,
        }
    }

    /// Expired claims this queue has CASed away from stalled drainers.
    #[inline]
    pub fn lease_takeovers(&self) -> u64 {
        self.takeovers.load(Ordering::Relaxed)
    }

    /// Batches re-pushed by displaced or aborted runs (each is still
    /// served exactly once — requeue is a delay, not a ledger event).
    #[inline]
    pub fn requeued(&self) -> u64 {
        self.requeued.load(Ordering::Relaxed)
    }

    /// The descriptor right now (one seqlock read).
    #[inline]
    pub fn state(&self) -> QueueState {
        self.state.load()
    }

    /// Queued-but-unclaimed batches.
    #[inline]
    pub fn depth(&self) -> u64 {
        self.state().tally
    }

    /// Empty *and* no drainer mid-run — the shutdown-drain condition:
    /// once producers stop, `is_idle` for every shard means every
    /// admitted batch has been handed to (and finished by) a drainer.
    #[inline]
    pub fn is_idle(&self) -> bool {
        let s = self.state();
        s.head == 0 && !s.drainer_active()
    }

    /// Enqueue-and-tally: push `item` with one witnessing CAS, returning
    /// `Ok(tally after the push)`. A full queue (`tally >= bound`)
    /// returns `Err((item, tally))` — the caller owns the shed-or-wait
    /// decision (see [`super::admission`]).
    pub fn try_push(&self, item: T) -> Result<u64, (T, u64)> {
        self.link(item, true)
    }

    /// The shared push loop. `enforce_bound: false` is the requeue path
    /// ([`Run`]'s drop returning already-admitted batches): the bound
    /// governs *admission*, and these batches were admitted once — a
    /// full queue must not turn a requeue into a silent drop.
    ///
    /// The failpoint sits *before* the node is boxed: a kill here loses
    /// nothing the caller still owns, and a spurious-CAS draw models
    /// losing the descriptor race once (one extra reload). It fires only
    /// on the admission path — the requeue path runs during `Run`'s
    /// drop, possibly mid-unwind, where a kill would abort the process.
    fn link(&self, item: T, enforce_bound: bool) -> Result<u64, (T, u64)> {
        let mut cur = self.state.load();
        if enforce_bound && crate::failcas!(IngressEnqueue) {
            cur = self.state.load();
        }
        let enforce = enforce_bound && self.bound != 0;
        if enforce && cur.tally >= self.bound {
            return Err((item, cur.tally));
        }
        let node = Box::into_raw(Box::new(Node {
            next: cur.head,
            stamp: cur.tally + 1,
            item: ManuallyDrop::new(item),
        }));
        let mut bo = None;
        loop {
            // SAFETY: `node` is thread-private until the CAS below
            // succeeds; these writes are published by the descriptor
            // CAS (module docs, "enqueue").
            unsafe {
                (*node).next = cur.head;
                (*node).stamp = cur.tally + 1;
            }
            let next = QueueState {
                head: node as u64,
                tally: cur.tally + 1,
                claim: cur.claim,
                since: cur.since,
            };
            match self.state.compare_exchange(cur, next) {
                Ok(_) => {
                    crate::counter!(KvEnqueue);
                    return Ok(next.tally);
                }
                Err(w) => {
                    if enforce && w.tally >= self.bound {
                        // Reclaim the unpublished node and hand the item
                        // back with the witnessed depth.
                        // SAFETY: the CAS failed, so `node` was never
                        // published; we still own it exclusively.
                        let mut n = unsafe { Box::from_raw(node) };
                        let item = unsafe { ManuallyDrop::take(&mut n.item) };
                        return Err((item, w.tally));
                    }
                    // Witness-fed retry (Dice et al.): continue from the
                    // witness, no re-load, back off the contended line.
                    crate::counter!(CasRetry);
                    cur = w;
                    snooze_lazy(&mut bo);
                }
            }
        }
    }

    /// Claim-and-detach: become the queue's exactly-one drainer and take
    /// the whole accumulated run. Returns `None` when the queue is empty
    /// or another drainer's claim word is odd and unexpired — **at most
    /// one *live* [`Run`] claim exists per queue at any time** (a
    /// displaced run still holds its batches, but its claim epoch is
    /// spent; see the module docs, "Drainer leases"). Dropping the `Run`
    /// releases the claim iff it still holds it.
    pub fn try_claim(&self) -> Option<Run<'_, T>> {
        crate::failpoint!(IngressClaim);
        let mut cur = self.state.load();
        let mut bo = None;
        loop {
            if cur.drainer_active() {
                if !self.lease_expired(cur) {
                    return None;
                }
                // Expired lease: CAS the dead claim away. With fresh
                // batches we take over as the new drainer (claim + 2
                // stays odd); with none we just release on the stalled
                // drainer's behalf (claim + 1, even). Both grow `claim`.
                let takeover = cur.head != 0;
                let next = QueueState {
                    head: 0,
                    tally: 0,
                    claim: cur.claim + if takeover { 2 } else { 1 },
                    since: now_ns(),
                };
                match self.state.compare_exchange(cur, next) {
                    Ok(prev) => {
                        self.takeovers.fetch_add(1, Ordering::Relaxed);
                        crate::counter!(KvLeaseTakeover);
                        if !takeover {
                            return None;
                        }
                        crate::counter!(KvClaim);
                        // SAFETY: as below — the winning CAS unlinked
                        // the chain at `prev.head`.
                        let items = unsafe { self.detach(prev.head) };
                        return Some(Run {
                            queue: self,
                            epoch: next.claim,
                            items,
                        });
                    }
                    Err(w) => {
                        crate::counter!(CasRetry);
                        cur = w;
                        snooze_lazy(&mut bo);
                        continue;
                    }
                }
            }
            if cur.head == 0 {
                return None;
            }
            let next = QueueState {
                head: 0,
                tally: 0,
                claim: cur.claim + 1, // even → odd: drainer active
                since: now_ns(),      // lease anchor for this claim
            };
            match self.state.compare_exchange(cur, next) {
                Ok(prev) => {
                    crate::counter!(KvClaim);
                    // The stall-a-drainer window: we hold the (odd)
                    // claim word but haven't served anything yet. A
                    // stall longer than the lease lets a rival take the
                    // claim — and any batches pushed after our CAS —
                    // away; the chain below stays exclusively ours.
                    crate::failpoint!(IngressDrain);
                    // SAFETY: the claim CAS unlinked the whole chain at
                    // `prev.head`; we are its unique owner (pinned
                    // peekers only read, and the nodes are epoch-retired
                    // below, not freed).
                    let items = unsafe { self.detach(prev.head) };
                    return Some(Run {
                        queue: self,
                        epoch: next.claim,
                        items,
                    });
                }
                Err(w) => {
                    crate::counter!(CasRetry);
                    cur = w;
                    snooze_lazy(&mut bo);
                }
            }
        }
    }

    /// Whether `s`'s odd claim has outlived the lease (always false on
    /// lease-less queues).
    #[inline]
    fn lease_expired(&self, s: QueueState) -> bool {
        self.lease_ns != 0
            && s.drainer_active()
            && now_ns().saturating_sub(s.since) > self.lease_ns
    }

    /// Move every payload out of the detached chain (reversing into
    /// FIFO/push order) and epoch-retire the node boxes.
    ///
    /// # Safety
    /// `head` must be a chain this caller exclusively owns (the winning
    /// claim CAS's `prev.head`).
    unsafe fn detach(&self, head: u64) -> Vec<T> {
        let mut items = Vec::new();
        let mut p = head as *mut Node<T>;
        while !p.is_null() {
            let next = unsafe { (*p).next } as *mut Node<T>;
            items.push(unsafe { ManuallyDrop::take(&mut (*p).item) });
            // SAFETY: unlinked by the claim CAS, unique (we just took
            // the payload); pinned peekers may still read `stamp`, so
            // the box must outlive their pins — the epoch scheme's job.
            unsafe { epoch::retire_box(p) };
            p = next;
        }
        // The chain links newest→oldest; serve in push order so each
        // producer's batches stay FIFO within the run.
        items.reverse();
        items
    }

    /// Probe the newest queued batch's enqueue stamp (its 1-based
    /// position in the accumulating run), or `None` when empty.
    ///
    /// This is the read that makes epoch reclamation load-bearing: the
    /// head node may be claimed and retired by a drainer at any moment
    /// after our descriptor read, so the dereference is only sound
    /// because the pin taken *before* that read blocks the epoch from
    /// advancing `FREE_DISTANCE` past the retirement stamp.
    pub fn peek_stamp(&self) -> Option<u64> {
        let _g = epoch::pin();
        let s = self.state.load();
        if s.head == 0 {
            return None;
        }
        // SAFETY: pinned before the descriptor read, so a node reachable
        // from it cannot have been epoch-freed yet; `stamp` was
        // published by the enqueue CAS (module docs).
        Some(unsafe { (*(s.head as *const Node<T>)).stamp })
    }
}

impl<T: Send + 'static> Drop for ClaimQueue<T> {
    fn drop(&mut self) {
        // Exclusive access (`&mut self`): free any never-claimed chain
        // directly, payloads included.
        let s = self.state.load();
        let mut p = s.head as *mut Node<T>;
        while !p.is_null() {
            // SAFETY: we own the whole chain; each node is dropped once.
            let mut n = unsafe { Box::from_raw(p) };
            p = n.next as *mut Node<T>;
            unsafe { ManuallyDrop::drop(&mut n.item) };
        }
    }
}

/// A claimed run: the entire batch backlog of one queue, owned by
/// exactly one drainer. Serve the batches (in push order) via
/// [`drain`](Self::drain); dropping the run releases the claim word
/// (odd → next even), letting the next drainer in. Holding the run while
/// serving is what keeps each producer's batches in order *across* runs:
/// batches pushed mid-service wait for the release.
pub struct Run<'a, T: Send + 'static> {
    queue: &'a ClaimQueue<T>,
    /// The odd claim value this run's winning CAS installed. Release
    /// only happens if the descriptor still carries it — a displaced
    /// run (lease takeover) must not bump an epoch it no longer owns.
    epoch: u64,
    items: Vec<T>,
}

impl<T: Send + 'static> Run<'_, T> {
    /// Batches in this run (≥ 1: empty queues are never claimed).
    pub fn len(&self) -> usize {
        self.items.len()
    }

    pub fn is_empty(&self) -> bool {
        self.items.is_empty()
    }

    /// The run's batches in push (per-producer FIFO) order.
    pub fn drain(&mut self) -> std::vec::Drain<'_, T> {
        self.items.drain(..)
    }
}

impl<T: Send + 'static> Drop for Run<'_, T> {
    fn drop(&mut self) {
        // This drop is the conservation backstop and runs on *every*
        // exit — normal completion, early drop, and a panicking
        // drainer's unwind alike. Two duties, in order:
        //
        // 1. Requeue anything not drained. The batches were admitted
        //    (tallied) once; dropping them here would silently break
        //    `offered == served + shed`, so they go back on the queue
        //    (bound exempt) for the next drainer.
        if !self.items.is_empty() {
            let n = self.items.len() as u64;
            for item in self.items.drain(..) {
                // `link` with the bound waived cannot fail.
                let _ = self.queue.link(item, false);
            }
            self.queue.requeued.fetch_add(n, Ordering::Relaxed);
            crate::counter!(KvRequeue, n);
        }
        // 2. Release the claim — odd → even — but only if the
        //    descriptor still carries *our* claim epoch. After a lease
        //    takeover the epoch has moved on and the release (or the
        //    whole queue's claim cycle) belongs to someone else.
        crate::failpoint!(IngressRelease);
        let _ = self.queue.state.fetch_update(|mut s| {
            if s.claim != self.epoch {
                return None;
            }
            debug_assert!(s.drainer_active(), "release without a claim");
            s.claim += 1;
            Some(s)
        });
        // Opportunistic epoch housekeeping off the enqueue path: one
        // advance/collect attempt per run bounds the node backlog.
        epoch::try_advance_and_collect();
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn test_push_claim_fifo_roundtrip() {
        let q: ClaimQueue<u64> = ClaimQueue::new(0);
        assert!(q.is_idle());
        assert!(q.try_claim().is_none(), "claimed an empty queue");
        for i in 0..5u64 {
            assert_eq!(q.try_push(i), Ok(i + 1));
        }
        assert_eq!(q.depth(), 5);
        assert_eq!(q.peek_stamp(), Some(5));
        let mut run = q.try_claim().expect("run");
        assert_eq!(run.len(), 5);
        // Claimed: empty tally, drainer active, not idle.
        assert_eq!(q.depth(), 0);
        assert!(q.state().drainer_active());
        assert!(!q.is_idle());
        assert!(q.try_claim().is_none(), "second drainer got in");
        let got: Vec<u64> = run.drain().collect();
        assert_eq!(got, vec![0, 1, 2, 3, 4], "not push order");
        drop(run);
        assert!(q.is_idle());
        assert_eq!(q.state().claim_runs(), 1);
    }

    #[test]
    fn test_bound_sheds_and_returns_item() {
        let q: ClaimQueue<u64> = ClaimQueue::new(2);
        assert_eq!(q.try_push(1), Ok(1));
        assert_eq!(q.try_push(2), Ok(2));
        let (back, depth) = q.try_push(3).unwrap_err();
        assert_eq!((back, depth), (3, 2));
        // Draining reopens admission (the run must be served, not just
        // dropped — an undrained drop requeues, keeping the queue full).
        let mut run = q.try_claim().expect("run");
        assert_eq!(run.drain().count(), 2);
        drop(run);
        assert_eq!(q.try_push(3), Ok(1));
    }

    #[test]
    fn test_new_pushes_during_run_wait_for_release() {
        let q: ClaimQueue<u64> = ClaimQueue::new(0);
        q.try_push(1).unwrap();
        let mut run = q.try_claim().expect("run");
        q.try_push(2).unwrap();
        assert_eq!(q.depth(), 1);
        assert!(q.try_claim().is_none(), "run 2 claimed while run 1 live");
        assert_eq!(run.drain().collect::<Vec<_>>(), vec![1]);
        drop(run);
        let mut r2 = q.try_claim().expect("run 2");
        assert_eq!(r2.drain().collect::<Vec<_>>(), vec![2]);
    }

    #[test]
    fn test_lease_takeover_displaced_run_requeues_and_skips_release() {
        let q: ClaimQueue<u64> = ClaimQueue::with_lease(0, 1_000_000); // 1ms
        q.try_push(10).unwrap();
        q.try_push(11).unwrap();
        let run1 = q.try_claim().expect("run1");
        assert_eq!(run1.len(), 2);
        // The drainer stalls past its lease while new batches arrive.
        std::thread::sleep(std::time::Duration::from_millis(10));
        q.try_push(12).unwrap();
        let mut run2 = q.try_claim().expect("takeover run");
        assert_eq!(q.lease_takeovers(), 1);
        assert_eq!(run2.drain().collect::<Vec<_>>(), vec![12]);
        drop(run2);
        assert!(q.is_idle(), "new drainer's release didn't land");
        // The displaced drainer finally drops: its undrained batches go
        // back on the queue, and it must NOT release an epoch it lost.
        drop(run1);
        assert_eq!(q.requeued(), 2);
        assert_eq!(q.depth(), 2);
        assert!(!q.state().drainer_active(), "stale release double-bumped");
        let mut run3 = q.try_claim().expect("requeued run");
        assert_eq!(run3.drain().collect::<Vec<_>>(), vec![10, 11]);
    }

    #[test]
    fn test_lease_expired_idle_claim_force_released() {
        let q: ClaimQueue<u64> = ClaimQueue::with_lease(0, 1_000_000); // 1ms
        q.try_push(7).unwrap();
        let run = q.try_claim().expect("run");
        std::thread::sleep(std::time::Duration::from_millis(10));
        // Nothing new to drain: the expired claim is released on the
        // stalled drainer's behalf, no run handed out.
        assert!(q.try_claim().is_none());
        assert_eq!(q.lease_takeovers(), 1);
        assert!(!q.state().drainer_active());
        // The stalled drainer never served its batch; drop requeues it.
        drop(run);
        assert_eq!(q.requeued(), 1);
        let mut r2 = q.try_claim().expect("requeued batch");
        assert_eq!(r2.drain().collect::<Vec<_>>(), vec![7]);
    }

    #[test]
    fn test_no_lease_claim_held_indefinitely() {
        let q: ClaimQueue<u64> = ClaimQueue::new(0);
        q.try_push(1).unwrap();
        let _run = q.try_claim().expect("run");
        std::thread::sleep(std::time::Duration::from_millis(5));
        q.try_push(2).unwrap();
        assert!(q.try_claim().is_none(), "lease-less claim was taken over");
        assert_eq!(q.lease_takeovers(), 0);
    }

    #[test]
    fn test_early_dropped_run_requeues_leftovers() {
        let q: ClaimQueue<u64> = ClaimQueue::new(0);
        for i in 0..4u64 {
            q.try_push(i).unwrap();
        }
        let run = q.try_claim().expect("run");
        // Dropped without draining: every batch must survive.
        drop(run);
        assert_eq!(q.requeued(), 4);
        assert_eq!(q.depth(), 4);
        let mut r2 = q.try_claim().expect("run 2");
        assert_eq!(r2.drain().collect::<Vec<_>>(), vec![0, 1, 2, 3]);
    }

    #[test]
    fn test_drop_frees_unclaimed_chain() {
        use std::sync::atomic::{AtomicUsize, Ordering};
        use std::sync::Arc;
        struct D(Arc<AtomicUsize>);
        impl Drop for D {
            fn drop(&mut self) {
                self.0.fetch_add(1, Ordering::SeqCst);
            }
        }
        let drops = Arc::new(AtomicUsize::new(0));
        let q: ClaimQueue<D> = ClaimQueue::new(0);
        for _ in 0..4 {
            assert!(q.try_push(D(Arc::clone(&drops))).is_ok());
        }
        drop(q);
        assert_eq!(drops.load(Ordering::SeqCst), 4, "leaked queued items");
    }
}
