//! `ClaimQueue<T>` — the multi-producer claim-pattern batch queue.
//!
//! The whole queue is one [`BigAtomic`]: a [`SeqLock`]`<`[`QueueState`]`>`
//! descriptor packing `{head, tally, claim}` into three words. Producers
//! push heap nodes onto the intrusive `head` list and bump `tally` with
//! **one witnessing `compare_exchange`** (enqueue-and-tally); a worker
//! becomes the queue's *exactly-one drainer* by CASing the whole
//! accumulated run out (`head/tally → 0`) while flipping the claim word
//! odd — claim-and-detach is also a single CAS. Retry loops continue
//! from the `Err` witness (never re-load) under the adaptive
//! [`Backoff`](crate::util::backoff::Backoff); detached nodes are
//! reclaimed through [`smr::epoch`](crate::smr::epoch).
//!
//! ## Linearization points
//!
//! * **enqueue** — the successful `compare_exchange` installing
//!   `{head: node, tally+1, claim}` (inside the seqlock writer's
//!   critical section; the version-word `RELEASE` unlock publishes the
//!   node's `next`/`stamp`/payload writes, which precede the CAS in
//!   program order, to any later `ACQUIRE` of the descriptor).
//! * **claim** — the successful `compare_exchange` installing
//!   `{head: 0, tally: 0, claim|1}`: the entire run transfers to the
//!   winning drainer at this instant, and every later `try_claim`
//!   observes the odd claim word and fails until release.
//! * **release** — the `fetch_update` bumping the odd claim word to the
//!   next even value ([`Run`]'s drop): the next successful claim's CAS
//!   is ordered after it by the witness contract.
//!
//! ## Why the claim word is an epoch, not a flag
//!
//! `claim` advances by one on every claim and every release (odd while
//! a drainer holds the run). Because it only ever grows, the
//! full-descriptor CAS is ABA-proof: a head pointer that was detached,
//! freed, reallocated, and re-pushed at the same address can never
//! reappear with the same `(tally, claim)` pair — any intervening
//! detach bumped `claim`. `claim >> 1` is also a free statistic: the
//! number of runs ever claimed (plus one while a drainer is active).
//!
//! ## Reclamation
//!
//! After the claim CAS the chain is unreachable from the descriptor,
//! but a probing reader ([`ClaimQueue::peek_stamp`]) may have loaded the
//! old head under an epoch pin and still dereference it — so detached
//! nodes are retired through [`smr::epoch`](crate::smr::epoch), never
//! freed in place. Payloads move out at detach time; the node boxes ride
//! the epoch bags (`FREE_DISTANCE` behind the pinning front).

use std::marker::PhantomData;
use std::mem::ManuallyDrop;

use crate::atomics::{BigAtomic, SeqLock};
use crate::impl_atomic_value;
use crate::smr::epoch;
use crate::util::backoff::snooze_lazy;

/// The queue descriptor: one 3-word big-atomic value.
///
/// `head` is the newest node's address (0 = empty), `tally` the number
/// of queued-but-unclaimed batches, `claim` the drainer epoch (odd ⇔ a
/// drainer holds the current run; see the module docs for why this is a
/// counter rather than a flag).
#[repr(C, align(8))]
#[derive(Copy, Clone, PartialEq, Eq, Default, Debug)]
pub struct QueueState {
    /// Newest node (`*mut Node<T>` as u64); 0 when empty.
    pub head: u64,
    /// Batches enqueued and not yet claimed (the admission bound's
    /// currency).
    pub tally: u64,
    /// Drainer epoch: odd ⇔ claimed; bumps on claim *and* release.
    pub claim: u64,
}
impl_atomic_value!(QueueState);

impl QueueState {
    /// Whether a drainer currently holds a claimed run.
    #[inline]
    pub fn drainer_active(self) -> bool {
        self.claim & 1 == 1
    }

    /// Runs claimed so far (counting an in-flight one).
    #[inline]
    pub fn claim_runs(self) -> u64 {
        self.claim.div_ceil(2)
    }
}

/// Intrusive list node. `next`/`stamp` are plain fields: they are
/// written only while the node is thread-private (before the publishing
/// CAS) and read only by the exclusive drainer or by pinned peekers,
/// both ordered after the publication (module docs, "enqueue").
struct Node<T> {
    next: u64,
    /// Tally right after this node's enqueue — what
    /// [`ClaimQueue::peek_stamp`] probes.
    stamp: u64,
    item: ManuallyDrop<T>,
}

/// Multi-producer / exactly-one-drainer batch queue (see module docs).
///
/// `bound` caps `tally` (0 = unbounded): a full queue rejects pushes in
/// [`try_push`](Self::try_push), and the admission layer turns that into
/// shed-or-wait policy. No `Mutex`/`Condvar` anywhere — producers and
/// drainers use only the witnessing CAS, `util::backoff`, and the epoch
/// scheme.
pub struct ClaimQueue<T: Send + 'static> {
    state: SeqLock<QueueState>,
    bound: u64,
    _owns: PhantomData<T>,
}

// SAFETY: the queue moves `T` values across threads (producer → drainer)
// but never shares a `&T`; `T: Send` is exactly the requirement. The
// descriptor itself is a big atomic.
unsafe impl<T: Send + 'static> Send for ClaimQueue<T> {}
unsafe impl<T: Send + 'static> Sync for ClaimQueue<T> {}

impl<T: Send + 'static> ClaimQueue<T> {
    /// An empty queue admitting at most `bound` queued batches
    /// (0 = unbounded).
    pub fn new(bound: u64) -> Self {
        Self {
            state: SeqLock::new(QueueState::default()),
            bound,
            _owns: PhantomData,
        }
    }

    /// The descriptor right now (one seqlock read).
    #[inline]
    pub fn state(&self) -> QueueState {
        self.state.load()
    }

    /// Queued-but-unclaimed batches.
    #[inline]
    pub fn depth(&self) -> u64 {
        self.state().tally
    }

    /// Empty *and* no drainer mid-run — the shutdown-drain condition:
    /// once producers stop, `is_idle` for every shard means every
    /// admitted batch has been handed to (and finished by) a drainer.
    #[inline]
    pub fn is_idle(&self) -> bool {
        let s = self.state();
        s.head == 0 && !s.drainer_active()
    }

    /// Enqueue-and-tally: push `item` with one witnessing CAS, returning
    /// `Ok(tally after the push)`. A full queue (`tally >= bound`)
    /// returns `Err((item, tally))` — the caller owns the shed-or-wait
    /// decision (see [`super::admission`]).
    pub fn try_push(&self, item: T) -> Result<u64, (T, u64)> {
        let mut cur = self.state.load();
        if self.bound != 0 && cur.tally >= self.bound {
            return Err((item, cur.tally));
        }
        let node = Box::into_raw(Box::new(Node {
            next: cur.head,
            stamp: cur.tally + 1,
            item: ManuallyDrop::new(item),
        }));
        let mut bo = None;
        loop {
            // SAFETY: `node` is thread-private until the CAS below
            // succeeds; these writes are published by the descriptor
            // CAS (module docs, "enqueue").
            unsafe {
                (*node).next = cur.head;
                (*node).stamp = cur.tally + 1;
            }
            let next = QueueState {
                head: node as u64,
                tally: cur.tally + 1,
                claim: cur.claim,
            };
            match self.state.compare_exchange(cur, next) {
                Ok(_) => {
                    crate::counter!(KvEnqueue);
                    return Ok(next.tally);
                }
                Err(w) => {
                    if self.bound != 0 && w.tally >= self.bound {
                        // Reclaim the unpublished node and hand the item
                        // back with the witnessed depth.
                        // SAFETY: the CAS failed, so `node` was never
                        // published; we still own it exclusively.
                        let mut n = unsafe { Box::from_raw(node) };
                        let item = unsafe { ManuallyDrop::take(&mut n.item) };
                        return Err((item, w.tally));
                    }
                    // Witness-fed retry (Dice et al.): continue from the
                    // witness, no re-load, back off the contended line.
                    crate::counter!(CasRetry);
                    cur = w;
                    snooze_lazy(&mut bo);
                }
            }
        }
    }

    /// Claim-and-detach: become the queue's exactly-one drainer and take
    /// the whole accumulated run. Returns `None` when the queue is empty
    /// or another drainer's claim word is odd — **at most one [`Run`]
    /// exists per queue at any time**. Dropping the `Run` releases the
    /// claim.
    pub fn try_claim(&self) -> Option<Run<'_, T>> {
        let mut cur = self.state.load();
        let mut bo = None;
        loop {
            if cur.head == 0 || cur.drainer_active() {
                return None;
            }
            let next = QueueState {
                head: 0,
                tally: 0,
                claim: cur.claim + 1, // even → odd: drainer active
            };
            match self.state.compare_exchange(cur, next) {
                Ok(prev) => {
                    crate::counter!(KvClaim);
                    // SAFETY: the claim CAS unlinked the whole chain at
                    // `prev.head`; we are its unique owner (pinned
                    // peekers only read, and the nodes are epoch-retired
                    // below, not freed).
                    let items = unsafe { self.detach(prev.head) };
                    return Some(Run { queue: self, items });
                }
                Err(w) => {
                    crate::counter!(CasRetry);
                    cur = w;
                    snooze_lazy(&mut bo);
                }
            }
        }
    }

    /// Move every payload out of the detached chain (reversing into
    /// FIFO/push order) and epoch-retire the node boxes.
    ///
    /// # Safety
    /// `head` must be a chain this caller exclusively owns (the winning
    /// claim CAS's `prev.head`).
    unsafe fn detach(&self, head: u64) -> Vec<T> {
        let mut items = Vec::new();
        let mut p = head as *mut Node<T>;
        while !p.is_null() {
            let next = unsafe { (*p).next } as *mut Node<T>;
            items.push(unsafe { ManuallyDrop::take(&mut (*p).item) });
            // SAFETY: unlinked by the claim CAS, unique (we just took
            // the payload); pinned peekers may still read `stamp`, so
            // the box must outlive their pins — the epoch scheme's job.
            unsafe { epoch::retire_box(p) };
            p = next;
        }
        // The chain links newest→oldest; serve in push order so each
        // producer's batches stay FIFO within the run.
        items.reverse();
        items
    }

    /// Probe the newest queued batch's enqueue stamp (its 1-based
    /// position in the accumulating run), or `None` when empty.
    ///
    /// This is the read that makes epoch reclamation load-bearing: the
    /// head node may be claimed and retired by a drainer at any moment
    /// after our descriptor read, so the dereference is only sound
    /// because the pin taken *before* that read blocks the epoch from
    /// advancing `FREE_DISTANCE` past the retirement stamp.
    pub fn peek_stamp(&self) -> Option<u64> {
        let _g = epoch::pin();
        let s = self.state.load();
        if s.head == 0 {
            return None;
        }
        // SAFETY: pinned before the descriptor read, so a node reachable
        // from it cannot have been epoch-freed yet; `stamp` was
        // published by the enqueue CAS (module docs).
        Some(unsafe { (*(s.head as *const Node<T>)).stamp })
    }
}

impl<T: Send + 'static> Drop for ClaimQueue<T> {
    fn drop(&mut self) {
        // Exclusive access (`&mut self`): free any never-claimed chain
        // directly, payloads included.
        let s = self.state.load();
        let mut p = s.head as *mut Node<T>;
        while !p.is_null() {
            // SAFETY: we own the whole chain; each node is dropped once.
            let mut n = unsafe { Box::from_raw(p) };
            p = n.next as *mut Node<T>;
            unsafe { ManuallyDrop::drop(&mut n.item) };
        }
    }
}

/// A claimed run: the entire batch backlog of one queue, owned by
/// exactly one drainer. Serve the batches (in push order) via
/// [`drain`](Self::drain); dropping the run releases the claim word
/// (odd → next even), letting the next drainer in. Holding the run while
/// serving is what keeps each producer's batches in order *across* runs:
/// batches pushed mid-service wait for the release.
pub struct Run<'a, T: Send + 'static> {
    queue: &'a ClaimQueue<T>,
    items: Vec<T>,
}

impl<T: Send + 'static> Run<'_, T> {
    /// Batches in this run (≥ 1: empty queues are never claimed).
    pub fn len(&self) -> usize {
        self.items.len()
    }

    pub fn is_empty(&self) -> bool {
        self.items.is_empty()
    }

    /// The run's batches in push (per-producer FIFO) order.
    pub fn drain(&mut self) -> std::vec::Drain<'_, T> {
        self.items.drain(..)
    }
}

impl<T: Send + 'static> Drop for Run<'_, T> {
    fn drop(&mut self) {
        // Release: odd → even, bumping the claim epoch. fetch_update's
        // closure is total, so the Err arm is unreachable.
        let _ = self.queue.state.fetch_update(|mut s| {
            debug_assert!(s.drainer_active(), "release without a claim");
            s.claim += 1;
            Some(s)
        });
        // Opportunistic epoch housekeeping off the enqueue path: one
        // advance/collect attempt per run bounds the node backlog.
        epoch::try_advance_and_collect();
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn test_push_claim_fifo_roundtrip() {
        let q: ClaimQueue<u64> = ClaimQueue::new(0);
        assert!(q.is_idle());
        assert!(q.try_claim().is_none(), "claimed an empty queue");
        for i in 0..5u64 {
            assert_eq!(q.try_push(i), Ok(i + 1));
        }
        assert_eq!(q.depth(), 5);
        assert_eq!(q.peek_stamp(), Some(5));
        let mut run = q.try_claim().expect("run");
        assert_eq!(run.len(), 5);
        // Claimed: empty tally, drainer active, not idle.
        assert_eq!(q.depth(), 0);
        assert!(q.state().drainer_active());
        assert!(!q.is_idle());
        assert!(q.try_claim().is_none(), "second drainer got in");
        let got: Vec<u64> = run.drain().collect();
        assert_eq!(got, vec![0, 1, 2, 3, 4], "not push order");
        drop(run);
        assert!(q.is_idle());
        assert_eq!(q.state().claim_runs(), 1);
    }

    #[test]
    fn test_bound_sheds_and_returns_item() {
        let q: ClaimQueue<u64> = ClaimQueue::new(2);
        assert_eq!(q.try_push(1), Ok(1));
        assert_eq!(q.try_push(2), Ok(2));
        let (back, depth) = q.try_push(3).unwrap_err();
        assert_eq!((back, depth), (3, 2));
        // Draining reopens admission.
        drop(q.try_claim().expect("run"));
        assert_eq!(q.try_push(3), Ok(1));
    }

    #[test]
    fn test_new_pushes_during_run_wait_for_release() {
        let q: ClaimQueue<u64> = ClaimQueue::new(0);
        q.try_push(1).unwrap();
        let run = q.try_claim().expect("run");
        q.try_push(2).unwrap();
        assert_eq!(q.depth(), 1);
        assert!(q.try_claim().is_none(), "run 2 claimed while run 1 live");
        drop(run);
        let mut r2 = q.try_claim().expect("run 2");
        assert_eq!(r2.drain().collect::<Vec<_>>(), vec![2]);
    }

    #[test]
    fn test_drop_frees_unclaimed_chain() {
        use std::sync::atomic::{AtomicUsize, Ordering};
        use std::sync::Arc;
        struct D(Arc<AtomicUsize>);
        impl Drop for D {
            fn drop(&mut self) {
                self.0.fetch_add(1, Ordering::SeqCst);
            }
        }
        let drops = Arc::new(AtomicUsize::new(0));
        let q: ClaimQueue<D> = ClaimQueue::new(0);
        for _ in 0..4 {
            assert!(q.try_push(D(Arc::clone(&drops))).is_ok());
        }
        drop(q);
        assert_eq!(drops.load(Ordering::SeqCst), 4, "leaked queued items");
    }
}
