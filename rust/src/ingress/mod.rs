//! Lock-free sharded ingress — the claim-pattern front door of the KV
//! service, built entirely from the crate's own primitives.
//!
//! The paper's headline claim is robustness under oversubscription, and
//! a `Mutex`+`Condvar` request queue is exactly what collapses there: a
//! descheduled lock holder wedges every producer behind it. This
//! subsystem replaces that layer with big-atomic machinery end to end:
//!
//! * [`queue::ClaimQueue`] — a multi-producer batch queue whose entire
//!   state is one `SeqLock<QueueState>` big atomic (`head | tally |
//!   claim-epoch`). Producers *enqueue-and-tally* with one witnessing
//!   `compare_exchange`; a worker *claims* the whole accumulated run —
//!   detach plus exactly-one-drainer handoff — with one more.
//! * [`shard::ShardRouter`] — N power-of-two shards by
//!   [`hash_value`](crate::hash::hash_value), per-shard queue, worker
//!   affinity with steal-on-idle, so hot Zipfian keys serialize one
//!   shard instead of the service.
//! * [`admission`] — the bounded-tally backpressure layer: a full shard
//!   sheds the batch back to the producer or makes it wait
//!   (spin/yield), per [`admission::AdmissionPolicy`].
//!
//! ## Linearization points (the claim protocol)
//!
//! All three are successful operations on the one queue descriptor, so
//! the per-queue history is the descriptor's modification order:
//!
//! 1. **Enqueue** — the CAS installing `{head: node, tally+1, claim}`.
//!    Batches of one producer appear in its program order (each CAS
//!    consumes the witness of the previous state).
//! 2. **Claim** — the CAS installing `{0, 0, claim+1}` (odd): the run
//!    transfers to exactly one drainer; every other `try_claim`
//!    observes the odd claim word and fails until release.
//! 3. **Release** — the `fetch_update` bumping the claim word back to
//!    even when the drainer drops its [`queue::Run`].
//!
//! Because runs are detached whole, served in reversed (push) order,
//! and serialized by the claim word, batches are served in claim-run
//! order with per-producer FIFO preserved across runs — the property
//! `tests/linearizability.rs` checks under concurrent enqueue +
//! claim-drain + shed.
//!
//! No `Mutex`/`Condvar` anywhere in this module: producers and drainers
//! use only the witnessing CAS, [`crate::util::backoff`], and
//! [`crate::smr::epoch`] (node reclamation). The only blocking is the
//! *explicit* `Wait` admission policy, and it blocks just the producer
//! that chose backpressure.

pub mod admission;
pub mod queue;
pub mod shard;

pub use admission::{admit, Admitted, AdmissionPolicy};
pub use queue::{ClaimQueue, QueueState, Run};
pub use shard::ShardRouter;
