//! Shard router: N independent [`ClaimQueue`]s keyed by
//! [`hash_value`](crate::hash::hash_value).
//!
//! Hot Zipfian keys all land in one shard, but the *other* shards keep
//! flowing — the router is what keeps a skewed key mix from serializing
//! the whole ingress behind one drainer. Workers have an affinity shard
//! (`worker % shards`) and steal a whole run from a sibling shard only
//! when their own queue has nothing claimable
//! ([`claim_from`](ShardRouter::claim_from)), so the common case keeps
//! each shard's batches on one core while idle workers still make
//! progress on any backlog.

use crate::hash::hash_value;
use crate::util::CachePadded;

use super::queue::{ClaimQueue, Run};

/// A power-of-two array of cache-padded claim queues.
pub struct ShardRouter<T: Send + 'static> {
    shards: Box<[CachePadded<ClaimQueue<T>>]>,
    mask: u64,
}

impl<T: Send + 'static> ShardRouter<T> {
    /// `shards` rounded up to a power of two (min 1), each queue bounded
    /// to `bound` queued batches (0 = unbounded).
    pub fn new(shards: usize, bound: u64) -> Self {
        Self::with_lease(shards, bound, 0)
    }

    /// Like [`new`](Self::new), but every shard queue carries a drainer
    /// lease of `lease_ns` nanoseconds (0 = no lease): a worker that
    /// stalls or dies holding a run delays only until the lease expires,
    /// then any sibling's claim takes the shard over (see
    /// [`ClaimQueue::with_lease`]).
    pub fn with_lease(shards: usize, bound: u64, lease_ns: u64) -> Self {
        let n = shards.max(1).next_power_of_two();
        Self {
            shards: (0..n)
                .map(|_| CachePadded::new(ClaimQueue::with_lease(bound, lease_ns)))
                .collect(),
            mask: (n - 1) as u64,
        }
    }

    /// Expired drainer claims CASed away, summed over all shards.
    pub fn lease_takeovers(&self) -> u64 {
        self.shards.iter().map(|q| q.lease_takeovers()).sum()
    }

    /// Batches re-pushed by displaced/aborted runs, summed over shards.
    pub fn requeued(&self) -> u64 {
        self.shards.iter().map(|q| q.requeued()).sum()
    }

    /// Number of shards (a power of two).
    #[inline]
    pub fn shards(&self) -> usize {
        self.shards.len()
    }

    /// The shard owning `key` — same word-fold hash as the tables, so a
    /// key's ingress shard is stable across the stack.
    #[inline]
    pub fn shard_of_key(&self, key: u64) -> usize {
        (hash_value(&key) & self.mask) as usize
    }

    /// Direct access to one shard's queue (producers route here).
    #[inline]
    pub fn queue(&self, shard: usize) -> &ClaimQueue<T> {
        &self.shards[shard]
    }

    /// Worker-side claim with affinity + steal-on-idle: try the home
    /// shard first, then scan siblings for a claimable run. Returns the
    /// shard served, whether it was a steal, and the run.
    pub fn claim_from(&self, home: usize) -> Option<(usize, bool, Run<'_, T>)> {
        let n = self.shards.len();
        for i in 0..n {
            let s = (home + i) & self.mask as usize;
            if let Some(run) = self.shards[s].try_claim() {
                if i != 0 {
                    crate::counter!(KvStealRun);
                }
                return Some((s, i != 0, run));
            }
        }
        None
    }

    /// Every shard empty with no drainer mid-run — with producers
    /// stopped, this is the "all admitted batches served" condition the
    /// shutdown drain spins on.
    pub fn all_idle(&self) -> bool {
        self.shards.iter().all(|q| q.is_idle())
    }

    /// Per-shard queued-batch depths (diagnostics).
    pub fn depths(&self) -> Vec<u64> {
        self.shards.iter().map(|q| q.depth()).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn test_router_shape_and_stable_routing() {
        let r: ShardRouter<u64> = ShardRouter::new(3, 0);
        assert_eq!(r.shards(), 4, "not rounded to a power of two");
        for key in [0u64, 1, 42, u64::MAX] {
            let s = r.shard_of_key(key);
            assert!(s < 4);
            assert_eq!(s, r.shard_of_key(key), "routing not stable");
        }
        assert!(r.all_idle());
        assert_eq!(r.depths(), vec![0; 4]);
    }

    #[test]
    fn test_claim_from_prefers_home_then_steals() {
        let r: ShardRouter<u64> = ShardRouter::new(2, 0);
        r.queue(0).try_push(10).unwrap();
        r.queue(1).try_push(11).unwrap();
        // Home shard first.
        let (s, stolen, mut run) = r.claim_from(1).expect("run");
        assert_eq!((s, stolen), (1, false));
        assert_eq!(run.drain().collect::<Vec<_>>(), vec![11]);
        drop(run);
        // Home empty: steal the sibling's run.
        let (s, stolen, mut run) = r.claim_from(1).expect("stolen run");
        assert_eq!((s, stolen), (0, true));
        assert_eq!(run.drain().collect::<Vec<_>>(), vec![10]);
        drop(run);
        assert!(r.all_idle());
        assert!(r.claim_from(0).is_none());
    }
}
