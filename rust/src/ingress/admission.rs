//! Admission control: what a producer does when its shard is full.
//!
//! The bound lives in the queue ([`ClaimQueue::try_push`] rejects past
//! it); this layer is the *policy* on rejection:
//!
//! * [`AdmissionPolicy::Wait`] — backpressure: spin/yield through the
//!   adaptive [`Backoff`] until the drainers make room. This is the one
//!   place the ingress blocks, and it blocks only the producer that
//!   chose to wait — never a drainer, never a sibling shard.
//! * [`AdmissionPolicy::Shed`] — load shedding: hand the batch back to
//!   the caller ([`Admitted::Shed`]) and count it. Conservation is the
//!   caller's contract: every batch is exactly one of served or shed.
//!
//! Both outcomes are surfaced as telemetry (`KvShed` / `KvAdmitWait`),
//! and every successful admission records the post-push shard depth in
//! the always-on `kv_shard_depth` histogram.

use crate::util::backoff::Backoff;
use crate::util::error::Result;

use super::queue::ClaimQueue;

/// Producer-side policy for a full shard.
#[derive(Copy, Clone, Debug, PartialEq, Eq, Default)]
pub enum AdmissionPolicy {
    /// Block (spin/yield) until the batch fits — bounded-queue
    /// backpressure.
    #[default]
    Wait,
    /// Drop the batch and tell the caller.
    Shed,
}

impl AdmissionPolicy {
    /// Parse a CLI spelling (`wait` | `shed`).
    pub fn parse(s: &str) -> Result<Self> {
        match s {
            "wait" => Ok(Self::Wait),
            "shed" => Ok(Self::Shed),
            other => crate::bail!("admission policy {other}: use wait|shed"),
        }
    }

    pub fn name(self) -> &'static str {
        match self {
            Self::Wait => "wait",
            Self::Shed => "shed",
        }
    }
}

/// Outcome of [`admit`].
pub enum Admitted<T> {
    /// Enqueued; `depth` is the shard tally after the push, `waited`
    /// whether admission had to back off at least once (Wait policy).
    Enqueued { depth: u64, waited: bool },
    /// Rejected under [`AdmissionPolicy::Shed`]; the batch comes back so
    /// the caller can account (or repurpose) it.
    Shed(T),
}

/// Push `item` into `queue` under `policy`. See [`Admitted`].
pub fn admit<T: Send + 'static>(
    queue: &ClaimQueue<T>,
    policy: AdmissionPolicy,
    item: T,
) -> Admitted<T> {
    match queue.try_push(item) {
        Ok(depth) => {
            crate::obs::KV_SHARD_DEPTH.record(depth);
            Admitted::Enqueued { depth, waited: false }
        }
        Err((item, _)) => match policy {
            AdmissionPolicy::Shed => {
                crate::counter!(KvShed);
                Admitted::Shed(item)
            }
            AdmissionPolicy::Wait => {
                crate::counter!(KvAdmitWait);
                let mut item = item;
                let mut bo = Backoff::adaptive();
                loop {
                    match queue.try_push(item) {
                        Ok(depth) => {
                            crate::obs::KV_SHARD_DEPTH.record(depth);
                            return Admitted::Enqueued { depth, waited: true };
                        }
                        Err((back, _)) => {
                            item = back;
                            bo.snooze();
                        }
                    }
                }
            }
        },
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn test_policy_parse_roundtrip() {
        for p in [AdmissionPolicy::Wait, AdmissionPolicy::Shed] {
            assert_eq!(AdmissionPolicy::parse(p.name()).unwrap(), p);
        }
        assert!(AdmissionPolicy::parse("drop").is_err());
    }

    #[test]
    fn test_shed_returns_the_batch() {
        let q: ClaimQueue<u64> = ClaimQueue::new(1);
        assert!(matches!(
            admit(&q, AdmissionPolicy::Shed, 1),
            Admitted::Enqueued { depth: 1, waited: false }
        ));
        match admit(&q, AdmissionPolicy::Shed, 2) {
            Admitted::Shed(v) => assert_eq!(v, 2),
            Admitted::Enqueued { .. } => panic!("admitted past the bound"),
        }
    }

    #[test]
    fn test_wait_admits_once_drained() {
        use std::sync::atomic::{AtomicBool, Ordering};
        let q: ClaimQueue<u64> = ClaimQueue::new(1);
        q.try_push(1).unwrap();
        let released = AtomicBool::new(false);
        std::thread::scope(|s| {
            s.spawn(|| {
                // Whether or not this thread had to back off (it may be
                // scheduled after the drain), admission can only succeed
                // once the run below was claimed — after `released`.
                match admit(&q, AdmissionPolicy::Wait, 2) {
                    Admitted::Enqueued { .. } => {
                        // Ordering: Acquire — pairs with the Release
                        // store before the drain that made room.
                        assert!(released.load(Ordering::Acquire), "admitted while full");
                    }
                    Admitted::Shed(_) => panic!("Wait policy shed"),
                }
            });
            std::thread::sleep(std::time::Duration::from_millis(20));
            // Ordering: Release — pairs with the waiter's Acquire above.
            released.store(true, Ordering::Release);
            // Serve (drain) the run — an undrained drop would requeue
            // the batches and leave the queue full forever.
            let mut run = q.try_claim().expect("run");
            assert_eq!(run.drain().count(), 2);
            drop(run);
        });
    }
}
