//! Copier census: per-thread announcements of "I am copying bucket X".
//!
//! Both growable tables allow *any* helper to re-copy a FROZEN bucket
//! whose sealing copier stalled or died (the copy is idempotent over
//! the immutable frozen image). That takeover creates one hazard the
//! idempotence argument alone does not cover: a straggling copier's
//! destination CAS landing *after* the bucket's DONE transition — at
//! that point users are already mutating the destination, and a stale
//! insert-if-absent could resurrect a key a user just removed.
//!
//! The census fences those writes out with the hazard-pointer protocol
//! turned around:
//!
//! * a copier **announces** the bucket address, fences (SeqCst), then
//!   **re-validates** that the bucket is still exactly FROZEN before
//!   writing anything — standing down if it moved on;
//! * the DONE publisher first seals the bucket CLOSING (no new copier
//!   joins a CLOSING bucket — the validation rejects it), fences
//!   (SeqCst) and **scans** the announcements, waiting until no rival
//!   still claims this bucket, and only then publishes DONE.
//!
//! The store→fence→load pattern on both sides gives the Dekker
//! guarantee: either the publisher's scan sees the copier's
//! announcement (and waits out its writes), or the copier's validation
//! sees CLOSING (and never writes). Every destination write therefore
//! happens-before DONE.
//!
//! Announcements are RAII ([`CopyGuard`]): a copier killed mid-copy
//! unwinds, the guard clears its slot, and the publisher proceeds — a
//! dead copier delays a bucket by one scan, never wedges it. A merely
//! *stalled* copier holds the publisher up until it resumes; that wait
//! is not an implementation weakness but the correctness fence itself
//! (the straggler's pending writes must land pre-DONE).
//!
//! One slot per thread suffices: the copy path never nests (a copier
//! never helps another migration while mid-copy), and bucket addresses
//! are unique across tables and engines while their migration is in
//! flight (the source table is epoch-protected until every bucket is
//! DONE, so no address can be recycled under a live announcement).

use std::sync::atomic::{fence, AtomicUsize, Ordering};

use crate::util::registry;
use crate::MAX_THREADS;

static SLOTS: [AtomicUsize; MAX_THREADS] = {
    #[allow(clippy::declare_interior_mutable_const)]
    const Z: AtomicUsize = AtomicUsize::new(0);
    [Z; MAX_THREADS]
};

/// RAII copy announcement; clears the slot on drop (including unwind —
/// this is what makes a killed copier invisible to the publisher).
pub(crate) struct CopyGuard {
    slot: &'static AtomicUsize,
}

impl Drop for CopyGuard {
    #[inline]
    fn drop(&mut self) {
        // Ordering: Release — the publisher's Acquire scan load sees the
        // copier's destination writes before it sees the cleared slot.
        self.slot.store(0, Ordering::Release);
    }
}

/// Announce this thread as a copier of the bucket at `addr`.
///
/// The caller MUST re-validate the bucket state *after* this returns
/// and before writing to the destination (see the module docs for the
/// fence pairing).
#[inline]
pub(crate) fn announce(addr: usize) -> CopyGuard {
    debug_assert!(addr != 0, "announcing the null bucket");
    let slot = &SLOTS[registry::tid()];
    debug_assert_eq!(slot.load(Ordering::Relaxed), 0, "nested copy announcement");
    // Ordering: Relaxed store + mandatory SeqCst fence — the announce
    // must be ordered before the caller's re-validating bucket load,
    // pairing with the publisher's fence in `rivals`.
    slot.store(addr, Ordering::Relaxed);
    fence(Ordering::SeqCst);
    CopyGuard { slot }
}

/// Does any *other* thread currently announce the bucket at `addr`?
///
/// The publisher calls this after its CLOSING transition and spins
/// until it returns false; each call re-fences so a fresh scan pairs
/// with any announce that could still validate FROZEN.
#[inline]
pub(crate) fn rivals(addr: usize) -> bool {
    // Ordering: mandatory store-load fence — orders the publisher's
    // CLOSING write before the scan loads, pairing with `announce`.
    fence(Ordering::SeqCst);
    let me = registry::tid();
    SLOTS[..registry::high_water()]
        .iter()
        .enumerate()
        // Ordering: Acquire — pairs with the guard's Release clear, so
        // a cleared rival's destination writes are visible to us (and
        // ordered before our DONE CAS).
        .any(|(t, s)| t != me && s.load(Ordering::Acquire) == addr)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn test_guard_clears_on_drop_and_unwind() {
        let addr = 0x1000usize;
        {
            let _g = announce(addr);
            assert_eq!(SLOTS[registry::tid()].load(Ordering::Relaxed), addr);
        }
        assert_eq!(SLOTS[registry::tid()].load(Ordering::Relaxed), 0);
        // Unwind path: the announcement must not survive a panic.
        let r = std::panic::catch_unwind(|| {
            let _g = announce(addr);
            panic!("copier dies mid-copy");
        });
        assert!(r.is_err());
        assert_eq!(SLOTS[registry::tid()].load(Ordering::Relaxed), 0);
    }

    #[test]
    fn test_rivals_ignores_own_slot_and_sees_others() {
        let addr = 0x2000usize;
        let _g = announce(addr);
        // Our own announcement is not a rival.
        assert!(!rivals(addr));
        std::thread::scope(|s| {
            s.spawn(|| {
                let _g2 = announce(addr);
                assert!(rivals(addr), "peer announcement not seen");
            })
            .join()
            .unwrap();
        });
        // Peer exited (guard dropped): no rivals again.
        assert!(!rivals(addr));
        assert!(!rivals(0x3000), "phantom rival on an unannounced address");
    }
}
