//! CacheHash (paper §4): separate chaining with the first link inlined
//! into the bucket as a big atomic — generic over key and value types,
//! and **growable online** (epoch-protected incremental resize).
//!
//! Each bucket is a big atomic [`Link<K, V>`] = (key, value, next+tags):
//! the common case (load factor one, most chains of length ≤ 1) touches
//! a single cache line and zero pointers — the paper's motivating win.
//! Chain nodes beyond the first are immutable heap links; every mutation
//! happens by a single `compare_exchange` on the bucket head (inserts
//! push the old head out to the heap; deletes path-copy the prefix), so
//! linearizability reduces to the big atomic's. Failed head CASes feed
//! their *witness* back into the retry — the bucket is re-read zero
//! extra times no matter how contended — and `insert` additionally
//! remembers which (immutable) chain it already proved duplicate-free,
//! so a retry whose witnessed chain pointer is unchanged skips the
//! second chain walk entirely. Retries back off through the adaptive
//! `util::backoff::Backoff`.
//!
//! ## Online resize
//!
//! Both directions (grow *and* shrink) run through the shared
//! [`resize`](super::resize) engine — descriptor lifecycle, stripe
//! claims, FROZEN→CLOSING→DONE seals, census-fenced copier takeover,
//! drained-table retirement, and the hysteresis triggers all live
//! there. This file contributes only the [`ResizeTable`] surface: the
//! big-atomic [`Link`] bucket encoding, `copy_image` (re-hash the
//! inlined pair + chain into the destination, insert-if-absent), and
//! page-batched chain retirement.
//!
//! `find` stays lock-free throughout a migration: it never helps, never
//! waits, reads FROZEN content in place, and crosses generations only
//! over DONE seal marks. The drained table itself is retired with
//! `S::retire_box` once every bucket is DONE — `RegionSmr` guarantees a
//! pinned reader mid-fall-through cannot see a freed table.
//!
//! Chain traversals are unbounded, so reclamation needs a
//! *region-grained* scheme ([`RegionSmr`]): epoch-based by default (§4:
//! "We use epoch-based memory management to protect the links"), with
//! the scheme parameter `S` selecting the epoch ordering policy
//! (`Epoch<Fenced>` vs `Epoch<SeqCstEverywhere>` — the reclamation leg
//! of the ordering ablation). Hazard pointers cannot satisfy the region
//! contract and are rejected at the type level — see `smr`'s module
//! docs for why.

use std::marker::PhantomData;
use std::ptr::null_mut;
use std::sync::atomic::{AtomicIsize, AtomicPtr, AtomicUsize, Ordering};

use super::resize::{self, Maintain, ResizeTable, FROZEN_PATIENCE, OCCUPANCY_STRIPE};
use super::{bucket_for, table_capacity, ConcurrentMap, ResizeState};
use crate::atomics::{AtomicValue, BigAtomic, SeqLock};
use crate::smr::{pool, Epoch, RegionSmr};
use crate::util::backoff::snooze_lazy;
use crate::util::CachePadded;

/// The inlined first link: key, value, and a tagged next pointer.
/// Bit 0 of `next` is the occupied flag, bit 1 the resize FORWARDED
/// seal, bit 2 the CLOSING mark — `0x0` = empty bucket, `0x1` = single
/// inline entry (null next), `ptr|1` = inline entry with a chain,
/// `ptr|1|2` = FROZEN (content intact, migration copy in progress),
/// `ptr|1|2|4` = CLOSING (copy complete; the publisher is waiting out
/// straggling copiers — see [`census`](super::census)), `0x2` = DONE
/// (contents live in the next table). "Null and empty have distinct
/// meanings" (§4), and so do the seal states.
#[repr(C, align(8))]
#[derive(Copy, Clone, PartialEq, Debug, Default)]
pub struct Link<K: AtomicValue, V: AtomicValue> {
    pub key: K,
    pub value: V,
    pub next: u64,
}

// SAFETY: repr(C) of AtomicValue fields and a u64 — all 8-byte aligned,
// sizes multiples of 8, no padding, bitwise PartialEq.
unsafe impl<K: AtomicValue, V: AtomicValue> AtomicValue for Link<K, V> {}

/// The classic single-word instantiation (§5.2's 8-byte keys/values).
pub type LinkVal = Link<u64, u64>;

impl Link<u64, u64> {
    pub const EMPTY: LinkVal = LinkVal {
        key: 0,
        value: 0,
        next: 0,
    };
}

const OCCUPIED: u64 = 1;
const FORWARDED: u64 = 2;
/// Copier window closed: set on a FROZEN image once a completed copy
/// starts draining rival copiers before the DONE transition. Chain
/// nodes are 8-byte aligned, so bit 2 of the pointer is free.
const CLOSING: u64 = 4;
const TAG_MASK: u64 = OCCUPIED | FORWARDED | CLOSING;

impl<K: AtomicValue, V: AtomicValue> Link<K, V> {
    /// An unoccupied bucket value.
    #[inline]
    pub fn empty() -> Self {
        Self::default()
    }

    #[inline]
    fn occupied(&self) -> bool {
        self.next & OCCUPIED == OCCUPIED
    }

    /// Any seal tag set (FROZEN, CLOSING, or DONE).
    #[inline]
    fn forwarded(&self) -> bool {
        self.next & FORWARDED == FORWARDED
    }

    /// Sealed with content, copier window open: helpers may still join
    /// the copy (after the census announce/validate handshake).
    #[inline]
    fn frozen(&self) -> bool {
        self.next & TAG_MASK == OCCUPIED | FORWARDED
    }

    /// Sealed with content, copier window closed: the frozen image is
    /// fully copied and a publisher is draining rival copiers before
    /// the DONE transition. No new copier may join.
    #[inline]
    fn closing(&self) -> bool {
        self.next & TAG_MASK == OCCUPIED | FORWARDED | CLOSING
    }

    /// This FROZEN image with the CLOSING mark added.
    #[inline]
    fn closing_image(mut self) -> Self {
        debug_assert!(self.frozen(), "closing an unsealed bucket");
        self.next |= CLOSING;
        self
    }

    /// Sealed empty: contents (if any) live in the next generation.
    #[inline]
    fn done(&self) -> bool {
        self.next & TAG_MASK == FORWARDED
    }

    /// This bucket's image with the FORWARDED seal added.
    #[inline]
    fn sealed(mut self) -> Self {
        self.next |= FORWARDED;
        self
    }

    /// The empty-forwarded sentinel a fully-migrated bucket holds.
    #[inline]
    fn done_link() -> Self {
        Link {
            key: K::default(),
            value: V::default(),
            next: FORWARDED,
        }
    }

    #[inline]
    fn next_ptr(&self) -> *mut ChainNode<K, V> {
        (self.next & !TAG_MASK) as *mut ChainNode<K, V>
    }

    #[inline]
    fn with_chain(key: K, value: V, chain: *mut ChainNode<K, V>) -> Self {
        Link {
            key,
            value,
            next: (chain as u64) | OCCUPIED,
        }
    }
}

/// Immutable-after-publish chain link.
struct ChainNode<K, V> {
    key: K,
    value: V,
    next: *mut ChainNode<K, V>,
}

/// One generation of the bucket array. Resizes allocate a fresh (larger
/// or smaller) `Table`, migrate into it, and epoch-retire the drained
/// source. Public only because it is the [`ResizeTable::Table`]
/// associated type; its fields and methods are module-private.
pub struct Table<A, K, V>
where
    K: AtomicValue,
    V: AtomicValue,
    A: BigAtomic<Link<K, V>>,
{
    buckets: Box<[CachePadded<A>]>,
    /// Per-stripe live-entry estimates (insert +1 / remove −1) feeding
    /// the growth trigger. Signed: the +1 and −1 of a racing
    /// insert/remove pair may land in either order.
    stripes: Box<[CachePadded<AtomicIsize>]>,
    /// Buckets sealed DONE; reaching `len()` completes the migration.
    migrated: AtomicUsize,
}

impl<A, K, V> Table<A, K, V>
where
    K: AtomicValue,
    V: AtomicValue,
    A: BigAtomic<Link<K, V>>,
{
    fn new(cap: usize) -> Self {
        let nstripes = cap.div_ceil(OCCUPANCY_STRIPE).max(1);
        Self {
            buckets: (0..cap)
                .map(|_| CachePadded::new(A::new(Link::empty())))
                .collect(),
            stripes: (0..nstripes)
                .map(|_| CachePadded::new(AtomicIsize::new(0)))
                .collect(),
            migrated: AtomicUsize::new(0),
        }
    }

    #[inline]
    fn len(&self) -> usize {
        self.buckets.len()
    }

    #[inline]
    fn bucket(&self, idx: usize) -> &A {
        &self.buckets[idx]
    }

    #[inline]
    fn stripe(&self, idx: usize) -> &AtomicIsize {
        &self.stripes[idx / OCCUPANCY_STRIPE]
    }
}

/// Free a table and every chain still linked from its buckets
/// (exclusive access — `Drop` only; DONE buckets' chains were already
/// retired at their DONE transitions).
unsafe fn drop_table<A, K, V>(ptr: *mut Table<A, K, V>)
where
    K: AtomicValue,
    V: AtomicValue,
    A: BigAtomic<Link<K, V>>,
{
    // SAFETY: caller guarantees exclusivity; the Box frees the arrays.
    let t = unsafe { Box::from_raw(ptr) };
    for b in t.buckets.iter() {
        let head = b.load();
        if head.occupied() {
            let mut p = head.next_ptr();
            while !p.is_null() {
                // SAFETY: exclusive in Drop; nodes come from the page pool.
                let nx = unsafe { (*p).next };
                unsafe { pool::free_node_now(p) };
                p = nx;
            }
        }
    }
}

pub struct CacheHash<A, K = u64, V = u64, S = Epoch>
where
    K: AtomicValue,
    V: AtomicValue,
    A: BigAtomic<Link<K, V>>,
    S: RegionSmr,
{
    /// The live generation. Readers reach newer generations by falling
    /// through DONE seal marks; updated once a migration completes.
    root: AtomicPtr<Table<A, K, V>>,
    /// The migration descriptor (see [`ResizeState`]); a `SeqLock` big
    /// atomic so stripe claims are witness-fed CASes.
    resize: SeqLock<ResizeState>,
    /// Completed grows (each retired one drained table through `S`).
    generations: AtomicUsize,
    /// Completed shrinks (the engine's other direction).
    shrink_generations: AtomicUsize,
    /// Construction-time capacity: shrink never halves below this.
    floor: usize,
    name: &'static str,
    _kv: PhantomData<(Link<K, V>, fn() -> S)>,
}

// SAFETY: buckets are Sync big atomics; chain nodes and drained tables
// are immutable and region-protected.
unsafe impl<A, K, V, S> Send for CacheHash<A, K, V, S>
where
    K: AtomicValue,
    V: AtomicValue,
    A: BigAtomic<Link<K, V>>,
    S: RegionSmr,
{
}
unsafe impl<A, K, V, S> Sync for CacheHash<A, K, V, S>
where
    K: AtomicValue,
    V: AtomicValue,
    A: BigAtomic<Link<K, V>>,
    S: RegionSmr,
{
}

impl<A, K, V, S> CacheHash<A, K, V, S>
where
    K: AtomicValue,
    V: AtomicValue,
    A: BigAtomic<Link<K, V>>,
    S: RegionSmr,
{
    /// A table with capacity for ~`n` entries at load factor one.
    /// Undershooting is no longer fatal: the table grows online once the
    /// estimated load factor crosses the engine's
    /// [`GROW_LOAD_FACTOR`](resize::GROW_LOAD_FACTOR) — and drains back
    /// down (never below this construction capacity) once it falls under
    /// the shrink band.
    pub fn new(n: usize) -> Self {
        let cap = table_capacity(n);
        Self {
            root: AtomicPtr::new(Box::into_raw(Box::new(Table::new(cap)))),
            resize: SeqLock::new(ResizeState::default()),
            generations: AtomicUsize::new(0),
            shrink_generations: AtomicUsize::new(0),
            floor: cap,
            name: A::name(),
            _kv: PhantomData,
        }
    }

    /// Walk the (immutable) chain for `key`.
    #[inline]
    fn chain_find(mut p: *mut ChainNode<K, V>, key: &K) -> Option<V> {
        while !p.is_null() {
            // SAFETY: region-pinned by caller; nodes retired only after
            // being unlinked by a bucket CAS that happened-after our
            // head load.
            let n = unsafe { &*p };
            if n.key == *key {
                return Some(n.value);
            }
            p = n.next;
        }
        None
    }

    /// True while a migration descriptor is published.
    pub fn resize_in_flight(&self) -> bool {
        self.resize.load().in_flight()
    }

    /// Completed grows (old tables retired through `S`).
    pub fn generation(&self) -> usize {
        self.generations.load(Ordering::Acquire)
    }

    /// Completed shrinks (half-size migrations that returned memory).
    pub fn shrink_generation(&self) -> usize {
        self.shrink_generations.load(Ordering::Acquire)
    }

    /// Drive any in-flight migration (either direction) to completion —
    /// a cooperative helper for maintenance threads, drops, and tests;
    /// normal updates migrate one stripe at a time. See
    /// [`resize::finish_resizes`] for the stall-proofing argument.
    pub fn finish_resizes(&self) {
        let _g = S::pin();
        resize::finish_resizes(self);
    }

    /// Insert-if-absent into the destination table (no growth trigger:
    /// the destination cannot resize while this migration holds the
    /// descriptor; its stripe counters still accumulate for the next
    /// cycle).
    fn copy_entry(&self, new: &Table<A, K, V>, key: K, value: V) {
        let idx = bucket_for(&key, new.len());
        let bucket = new.bucket(idx);
        let mut head = bucket.load();
        let mut bo = None;
        loop {
            debug_assert!(!head.forwarded(), "destination sealed mid-migration");
            if !head.occupied() {
                match bucket.compare_exchange(head, Link::with_chain(key, value, null_mut())) {
                    Ok(_) => {
                        // Ordering: Relaxed — estimate, as in note_insert.
                        new.stripe(idx).fetch_add(1, Ordering::Relaxed);
                        return;
                    }
                    Err(w) => {
                        head = w;
                        snooze_lazy(&mut bo);
                        continue;
                    }
                }
            }
            if head.key == key || Self::chain_find(head.next_ptr(), &key).is_some() {
                // Already present: a user insert of this key cannot land
                // here pre-DONE, so this is idempotence insurance only.
                return;
            }
            let spill = pool::alloc_node(ChainNode {
                key: head.key,
                value: head.value,
                next: head.next_ptr(),
            });
            match bucket.compare_exchange(head, Link::with_chain(key, value, spill)) {
                Ok(_) => {
                    // Ordering: Relaxed — estimate.
                    new.stripe(idx).fetch_add(1, Ordering::Relaxed);
                    return;
                }
                Err(w) => {
                    // SAFETY: never published.
                    unsafe { pool::free_node_now(spill) };
                    head = w;
                    snooze_lazy(&mut bo);
                }
            }
        }
    }
}

// SAFETY: every method is called under the region pin (`S: RegionSmr`);
// bucket loads/CASes go through the big atomic `A` (linearizable with
// witnessed failure); the FROZEN/CLOSING/DONE predicates mirror the
// `Link` tag encoding exactly; `copy_image` is insert-if-absent over an
// immutable image; `retire_image`/`retire_drained_table` go through the
// region scheme, never freeing directly.
unsafe impl<A, K, V, S> ResizeTable for CacheHash<A, K, V, S>
where
    K: AtomicValue,
    V: AtomicValue,
    A: BigAtomic<Link<K, V>>,
    S: RegionSmr,
{
    type Table = Table<A, K, V>;
    type Image = Link<K, V>;

    fn resize_cell(&self) -> &SeqLock<ResizeState> {
        &self.resize
    }

    fn root_cell(&self) -> &AtomicPtr<Table<A, K, V>> {
        &self.root
    }

    fn grow_cell(&self) -> &AtomicUsize {
        &self.generations
    }

    fn shrink_cell(&self) -> &AtomicUsize {
        &self.shrink_generations
    }

    fn floor(&self) -> usize {
        self.floor
    }

    fn alloc_table(&self, cap: usize) -> *mut Table<A, K, V> {
        Box::into_raw(Box::new(Table::new(cap)))
    }

    unsafe fn free_unpublished_table(&self, t: *mut Table<A, K, V>) {
        // SAFETY: never published (engine contract) — plain Box drop;
        // a fresh table has no chains.
        drop(unsafe { Box::from_raw(t) });
    }

    unsafe fn retire_drained_table(&self, t: *mut Table<A, K, V>) {
        // SAFETY: unlinked from root and descriptor (engine contract).
        unsafe { S::retire_box(t) };
    }

    fn len_of(t: &Table<A, K, V>) -> usize {
        t.len()
    }

    fn migrated_of(t: &Table<A, K, V>) -> &AtomicUsize {
        &t.migrated
    }

    fn stripe_of(t: &Table<A, K, V>, idx: usize) -> &AtomicIsize {
        t.stripe(idx)
    }

    fn occupancy_of(t: &Table<A, K, V>) -> isize {
        // Ordering: Relaxed — estimate.
        t.stripes.iter().map(|s| s.load(Ordering::Relaxed)).sum()
    }

    fn load_bucket(t: &Table<A, K, V>, idx: usize) -> Link<K, V> {
        t.bucket(idx).load()
    }

    fn cas_bucket(
        t: &Table<A, K, V>,
        idx: usize,
        cur: Link<K, V>,
        new: Link<K, V>,
    ) -> Result<(), Link<K, V>> {
        t.bucket(idx).compare_exchange(cur, new).map(|_| ())
    }

    fn bucket_addr(t: &Table<A, K, V>, idx: usize) -> usize {
        t.bucket(idx) as *const A as usize
    }

    fn is_done(img: Link<K, V>) -> bool {
        img.done()
    }

    fn is_frozen(img: Link<K, V>) -> bool {
        img.frozen()
    }

    fn is_closing(img: Link<K, V>) -> bool {
        img.closing()
    }

    fn is_empty_img(img: Link<K, V>) -> bool {
        !img.occupied() && !img.forwarded()
    }

    fn sealed(img: Link<K, V>) -> Link<K, V> {
        img.sealed()
    }

    fn closing_of(img: Link<K, V>) -> Link<K, V> {
        img.closing_image()
    }

    fn done_img() -> Link<K, V> {
        Link::done_link()
    }

    fn copy_image(&self, new: &Table<A, K, V>, img: Link<K, V>) {
        // The inlined pair, then every chain node, insert-if-absent.
        self.copy_entry(new, img.key, img.value);
        // A kill here unwinds the census guard — the publisher stops
        // waiting for us and the copy is re-run by a rival
        // (idempotently).
        crate::failpoint!(ResizeCopyEntry);
        let mut p = img.next_ptr();
        while !p.is_null() {
            // SAFETY: chain reachable from the frozen head (DONE not
            // published, nothing retired yet); region-pinned.
            let n = unsafe { &*p };
            self.copy_entry(new, n.key, n.value);
            crate::failpoint!(ResizeCopyEntry);
            p = n.next;
        }
    }

    unsafe fn retire_image(&self, img: Link<K, V>) {
        // Retire the drained chain through the region scheme as ONE
        // page batch (one retire entry and one eventual orphan-lock
        // acquisition per chain).
        let mut batch = pool::PageBatch::new();
        let mut p = img.next_ptr();
        while !p.is_null() {
            // SAFETY: unlinked by the DONE transition; lagging readers
            // of the frozen image are pinned, which keeps the whole
            // batch unrecycled until they unpin.
            let nx = unsafe { (*p).next };
            unsafe { batch.push(p) };
            p = nx;
        }
        // SAFETY: every pushed node is unlinked and unique.
        unsafe { S::retire_page(batch) };
    }
}

impl<A, K, V, S> Maintain for CacheHash<A, K, V, S>
where
    K: AtomicValue,
    V: AtomicValue,
    A: BigAtomic<Link<K, V>>,
    S: RegionSmr,
{
    fn maintain(&self) -> bool {
        {
            let _g = S::pin();
            resize::try_begin_shrink(self, resize::root_table(self));
        }
        self.finish_resizes();
        !self.resize_in_flight()
    }
}

impl<A, K, V, S> ConcurrentMap<K, V> for CacheHash<A, K, V, S>
where
    K: AtomicValue,
    V: AtomicValue,
    A: BigAtomic<Link<K, V>>,
    S: RegionSmr,
{
    fn find(&self, key: K) -> Option<V> {
        let _g = S::pin();
        let mut t = resize::root_table(self);
        loop {
            let head = t.bucket(bucket_for(&key, t.len())).load();
            if head.done() {
                // Fully migrated: fall through old → new. No lock, no
                // helping, no waiting — the find path stays lock-free.
                t = resize::table_after(self, t);
                continue;
            }
            if !head.occupied() {
                return None;
            }
            if head.key == key {
                return Some(head.value); // the inlined fast path (frozen included)
            }
            return Self::chain_find(head.next_ptr(), &key);
        }
    }

    fn insert(&self, key: K, value: V) -> bool {
        let _g = S::pin();
        // Updates pay the incremental-migration toll: one stripe.
        resize::help_resize(self);
        let mut t = resize::root_table(self);
        let mut idx = bucket_for(&key, t.len());
        let mut bucket = t.bucket(idx);
        let mut head = bucket.load();
        // Bounded patience with a FROZEN bucket before helping copy it.
        let mut frozen_waits = 0u32;
        // The chain pointer we last walked and proved free of `key`.
        // Chain nodes are immutable after publish and we hold the region
        // pin for the whole operation, so no node reachable from a head
        // we read can be freed (or its address reused) before we return
        // — pointer equality therefore implies the entire chain is
        // unchanged, and a witness-fed retry whose chain pointer matches
        // skips the second walk (the duplicate check cost under
        // contention).
        let mut searched: Option<*mut ChainNode<K, V>> = None;
        // Lazy: an uncontended insert pays no backoff/TLS cost.
        let mut bo = None;
        loop {
            if head.forwarded() {
                if head.frozen() || head.closing() {
                    // The stripe owner is copying this bucket out; the
                    // window is bounded by the chain length — unless the
                    // copier died in it. Wait a bounded number of beats,
                    // then help: copy the frozen image ourselves and
                    // race its DONE transition (idempotent takeover).
                    resize::note_frozen_wait(self, t);
                    frozen_waits += 1;
                    if frozen_waits > FROZEN_PATIENCE {
                        frozen_waits = 0;
                        resize::help_frozen_bucket(self, t, idx);
                    } else {
                        snooze_lazy(&mut bo);
                    }
                    head = bucket.load();
                    continue;
                }
                // DONE: this bucket's keys live in a newer generation.
                t = resize::table_after(self, t);
                idx = bucket_for(&key, t.len());
                bucket = t.bucket(idx);
                head = bucket.load();
                searched = None;
                continue;
            }
            if !head.occupied() {
                // Empty bucket: install inline. On failure the witness
                // is the new head — no re-load.
                match bucket.compare_exchange(head, Link::with_chain(key, value, null_mut())) {
                    Ok(_) => {
                        resize::note_insert(self, t, idx);
                        return true;
                    }
                    Err(w) => {
                        head = w;
                        snooze_lazy(&mut bo);
                        continue;
                    }
                }
            }
            if head.key == key {
                return false;
            }
            let chain = head.next_ptr();
            if searched != Some(chain) {
                if Self::chain_find(chain, &key).is_some() {
                    return false;
                }
                searched = Some(chain);
            }
            // Push-front: the new pair goes inline; the old inline pair
            // moves out to a pooled link pointing at the existing chain.
            let spill = pool::alloc_node(ChainNode {
                key: head.key,
                value: head.value,
                next: chain,
            });
            match bucket.compare_exchange(head, Link::with_chain(key, value, spill)) {
                Ok(_) => {
                    resize::note_insert(self, t, idx);
                    return true;
                }
                Err(w) => {
                    // SAFETY: never published.
                    unsafe { pool::free_node_now(spill) };
                    head = w;
                    snooze_lazy(&mut bo);
                }
            }
        }
    }

    fn remove(&self, key: K) -> bool {
        let _g = S::pin();
        // Updates pay the incremental-migration toll: one stripe.
        resize::help_resize(self);
        let mut t = resize::root_table(self);
        let mut idx = bucket_for(&key, t.len());
        let mut bucket = t.bucket(idx);
        let mut head = bucket.load();
        // Lazy: an uncontended remove pays no backoff/TLS cost.
        let mut bo = None;
        // Bounded patience with a FROZEN bucket before helping copy it.
        let mut frozen_waits = 0u32;
        loop {
            if head.forwarded() {
                if head.frozen() || head.closing() {
                    resize::note_frozen_wait(self, t);
                    frozen_waits += 1;
                    if frozen_waits > FROZEN_PATIENCE {
                        frozen_waits = 0;
                        resize::help_frozen_bucket(self, t, idx);
                    } else {
                        snooze_lazy(&mut bo);
                    }
                    head = bucket.load();
                    continue;
                }
                t = resize::table_after(self, t);
                idx = bucket_for(&key, t.len());
                bucket = t.bucket(idx);
                head = bucket.load();
                continue;
            }
            if !head.occupied() {
                return false;
            }
            if head.key == key {
                let p = head.next_ptr();
                if p.is_null() {
                    // Single inline entry -> empty.
                    match bucket.compare_exchange(head, Link::empty()) {
                        Ok(_) => {
                            resize::note_remove(self, t, idx);
                            return true;
                        }
                        Err(w) => {
                            head = w;
                            snooze_lazy(&mut bo);
                            continue;
                        }
                    }
                }
                // Promote the first chain node inline.
                // SAFETY: region-pinned, reachable.
                let n = unsafe { &*p };
                let promoted = Link::with_chain(n.key, n.value, n.next);
                match bucket.compare_exchange(head, promoted) {
                    Ok(_) => {
                        // SAFETY: p unlinked by the successful CAS.
                        unsafe { pool::retire_node::<S, _>(p) };
                        resize::note_remove(self, t, idx);
                        return true;
                    }
                    Err(w) => {
                        head = w;
                        snooze_lazy(&mut bo);
                        continue;
                    }
                }
            }
            // Delete inside the chain: path-copy the prefix (§4).
            let mut prefix: Vec<(K, V)> = Vec::new();
            let mut p = head.next_ptr();
            let mut found = false;
            let mut suffix: *mut ChainNode<K, V> = null_mut();
            while !p.is_null() {
                // SAFETY: region-pinned traversal.
                let n = unsafe { &*p };
                if n.key == key {
                    found = true;
                    suffix = n.next;
                    break;
                }
                prefix.push((n.key, n.value));
                p = n.next;
            }
            if !found {
                return false;
            }
            let victim = p;
            // Rebuild the prefix copies back-to-front onto the suffix.
            let mut new_chain = suffix;
            for &(k, v) in prefix.iter().rev() {
                new_chain = pool::alloc_node(ChainNode {
                    key: k,
                    value: v,
                    next: new_chain,
                });
            }
            let new_head = Link::with_chain(head.key, head.value, new_chain);
            match bucket.compare_exchange(head, new_head) {
                Ok(_) => {
                    // Retire the victim and the replaced original prefix.
                    // SAFETY: all unlinked by the successful CAS;
                    // pool-retired so slots recycle after the region
                    // grace period.
                    unsafe {
                        pool::retire_node::<S, _>(victim);
                        let mut q = head.next_ptr();
                        while q != victim {
                            let nx = (*q).next;
                            pool::retire_node::<S, _>(q);
                            q = nx;
                        }
                    }
                    resize::note_remove(self, t, idx);
                    return true;
                }
                Err(w) => {
                    // CAS failed: free the unpublished copies, continue
                    // from the witnessed head.
                    let mut q = new_chain;
                    while q != suffix {
                        // SAFETY: never published.
                        let nx = unsafe { (*q).next };
                        unsafe { pool::free_node_now(q) };
                        q = nx;
                    }
                    head = w;
                    snooze_lazy(&mut bo);
                }
            }
        }
    }

    fn map_name(&self) -> &'static str {
        self.name
    }

    fn capacity(&self) -> usize {
        let _g = S::pin();
        resize::root_table(self).len()
    }

    fn occupancy(&self) -> usize {
        let _g = S::pin();
        <Self as ResizeTable>::occupancy_of(resize::root_table(self)).max(0) as usize
    }

    fn shrink_generation(&self) -> usize {
        CacheHash::shrink_generation(self)
    }
}

impl<A, K, V, S> Drop for CacheHash<A, K, V, S>
where
    K: AtomicValue,
    V: AtomicValue,
    A: BigAtomic<Link<K, V>>,
    S: RegionSmr,
{
    fn drop(&mut self) {
        let root = *self.root.get_mut();
        let rs = self.resize.load();
        // Exclusive (&mut self): free the live table and, when a
        // migration was abandoned mid-flight, its half-built destination
        // (migration copies are fresh allocations, so the two frees are
        // disjoint; chains behind DONE seals were already retired).
        unsafe {
            if rs.in_flight() {
                debug_assert_eq!(rs.old, root as u64, "descriptor of a foreign root at drop");
                drop_table(rs.new as *mut Table<A, K, V>);
            }
            drop_table(root);
        }
        S::flush_thread_bag();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::atomics::{CachedMemEff, SeqLock, Words};
    use std::sync::Arc;

    fn basic<A: BigAtomic<LinkVal>>() {
        let t: CacheHash<A> = CacheHash::new(64);
        assert_eq!(t.find(1), None);
        assert!(t.insert(1, 10));
        assert!(!t.insert(1, 11), "duplicate insert must fail");
        assert_eq!(t.find(1), Some(10));
        assert!(t.remove(1));
        assert!(!t.remove(1));
        assert_eq!(t.find(1), None);
    }

    #[test]
    fn test_basic_seqlock() {
        basic::<SeqLock<LinkVal>>();
    }

    #[test]
    fn test_basic_memeff() {
        basic::<CachedMemEff<LinkVal>>();
    }

    #[test]
    fn test_explicit_epoch_policy_instantiations() {
        // The table is generic over the epoch ordering policy: the
        // fenced default and the blanket-SeqCst audit instantiation must
        // behave identically (the smr ablation compares them).
        use crate::smr::Epoch;
        use crate::util::ordering::{Fenced, SeqCstEverywhere};
        fn run<S: crate::smr::RegionSmr>() {
            let t: CacheHash<SeqLock<LinkVal>, u64, u64, S> = CacheHash::new(8);
            for k in 0..64u64 {
                assert!(t.insert(k, k + 1));
            }
            for k in (0..64u64).step_by(2) {
                assert!(t.remove(k));
            }
            for k in 0..64u64 {
                let want = if k % 2 == 0 { None } else { Some(k + 1) };
                assert_eq!(t.find(k), want);
            }
        }
        run::<Epoch<Fenced>>();
        run::<Epoch<SeqCstEverywhere>>();
    }

    #[test]
    fn test_generic_multiword_keys_and_values() {
        // The §5.3 arbitrary-length instantiation: 4-word keys, 4-word
        // values, including forced collisions in a tiny table.
        type K = Words<4>;
        type V = Words<4>;
        let t: CacheHash<CachedMemEff<Link<K, V>>, K, V> = CacheHash::new(4);
        for i in 0..200u64 {
            assert!(t.insert(Words([i, i ^ 7, 0, i]), Words([i; 4])));
        }
        for i in 0..200u64 {
            assert_eq!(t.find(Words([i, i ^ 7, 0, i])), Some(Words([i; 4])));
        }
        assert_eq!(t.find(Words([1, 1, 1, 1])), None);
        for i in (0..200u64).step_by(3) {
            assert!(t.remove(Words([i, i ^ 7, 0, i])));
        }
        for i in 0..200u64 {
            let want = if i % 3 == 0 { None } else { Some(Words([i; 4])) };
            assert_eq!(t.find(Words([i, i ^ 7, 0, i])), want);
        }
    }

    #[test]
    fn test_mixed_width_key_value() {
        // Asymmetric instantiation: wide key, single-word value.
        type K = Words<2>;
        let t: CacheHash<SeqLock<Link<K, u64>>, K, u64> = CacheHash::new(16);
        assert!(t.insert(Words([7, 8]), 99));
        assert_eq!(t.find(Words([7, 8])), Some(99));
        assert_eq!(t.find(Words([8, 7])), None);
        assert!(t.remove(Words([7, 8])));
    }

    #[test]
    fn test_chains_beyond_one_bucket() {
        // Tiny table forces chains (and, since the resize PR, growth);
        // all pairs must survive both.
        let t: CacheHash<SeqLock<LinkVal>> = CacheHash::new(2);
        for k in 0..100u64 {
            assert!(t.insert(k, k * 7));
        }
        for k in 0..100u64 {
            assert_eq!(t.find(k), Some(k * 7), "key {k}");
        }
        // Delete interior/head/tail mixes.
        for k in (0..100u64).step_by(3) {
            assert!(t.remove(k));
        }
        for k in 0..100u64 {
            let want = if k % 3 == 0 { None } else { Some(k * 7) };
            assert_eq!(t.find(k), want, "key {k}");
        }
    }

    #[test]
    fn test_grow_from_tiny_capacity_single_thread() {
        // Deterministic growth: a capacity-2 table absorbing 10k inserts
        // must double repeatedly, keep every pair, and end with the
        // descriptor idle (single-threaded helpers finish inline).
        let t: CacheHash<CachedMemEff<LinkVal>> = CacheHash::new(2);
        assert_eq!(t.capacity(), 2);
        for k in 0..10_000u64 {
            assert!(t.insert(k, k ^ 0xBEEF));
        }
        t.finish_resizes();
        assert!(!t.resize_in_flight());
        assert!(t.capacity() >= 2048, "capacity stuck at {}", t.capacity());
        assert!(t.generation() >= 10, "only {} doublings", t.generation());
        let occ = t.occupancy();
        assert!(
            (9_000..=11_000).contains(&occ),
            "occupancy estimate {occ} far from 10000"
        );
        // No lost keys, no duplicates: each key removes exactly once.
        for k in 0..10_000u64 {
            assert_eq!(t.find(k), Some(k ^ 0xBEEF), "key {k}");
            assert!(t.remove(k), "lost key {k}");
            assert!(!t.remove(k), "duplicated key {k}");
        }
    }

    #[test]
    fn test_shrink_after_drain_returns_to_floor() {
        // Grow from the construction floor, drain completely, and let
        // the removal-triggered + maintenance shrinks walk the capacity
        // all the way back down — memory is actually returned, and the
        // grow counter is untouched by the shrink generations.
        let t: CacheHash<CachedMemEff<LinkVal>> = CacheHash::new(2);
        for k in 0..10_000u64 {
            assert!(t.insert(k, k));
        }
        t.finish_resizes();
        let peak = t.capacity();
        let grows = t.generation();
        assert!(peak >= 2048);
        for k in 0..10_000u64 {
            assert!(t.remove(k));
        }
        // Each maintain pass publishes at most one halving; iterate
        // until idle *and* stable.
        loop {
            let before = t.capacity();
            let idle = t.maintain();
            if idle && t.capacity() == before {
                break;
            }
        }
        assert_eq!(t.capacity(), 2, "drained table must return to its floor");
        assert!(t.shrink_generation() >= 1, "no shrink completed");
        assert_eq!(t.generation(), grows, "shrinks must not count as grows");
        // Still a fully working table after the round trip.
        for k in 0..100u64 {
            assert!(t.insert(k, k * 3));
            assert_eq!(t.find(k), Some(k * 3));
        }
    }

    #[test]
    fn test_concurrent_disjoint_keys() {
        let t: Arc<CacheHash<CachedMemEff<LinkVal>>> = Arc::new(CacheHash::new(1024));
        let threads = 4;
        let per = 2_000u64;
        let handles: Vec<_> = (0..threads)
            .map(|tix| {
                let t = Arc::clone(&t);
                std::thread::spawn(move || {
                    let base = tix as u64 * 1_000_000;
                    for i in 0..per {
                        assert!(t.insert(base + i, i));
                    }
                    for i in 0..per {
                        assert_eq!(t.find(base + i), Some(i));
                    }
                    for i in (0..per).step_by(2) {
                        assert!(t.remove(base + i));
                    }
                    for i in 0..per {
                        let want = if i % 2 == 0 { None } else { Some(i) };
                        assert_eq!(t.find(base + i), want);
                    }
                })
            })
            .collect();
        for h in handles {
            h.join().unwrap();
        }
    }

    #[test]
    fn test_concurrent_duplicate_inserts_exactly_one_winner() {
        // Both threads race to insert the same keys into a 2-bucket
        // table (long chains force the duplicate check through the
        // witness-fed retry with the searched-chain skip, and growth
        // races the inserts): every key must be inserted exactly once.
        let t: Arc<CacheHash<CachedMemEff<LinkVal>>> = Arc::new(CacheHash::new(2));
        let wins = Arc::new(std::sync::atomic::AtomicU64::new(0));
        let handles: Vec<_> = (0..2)
            .map(|_| {
                let t = Arc::clone(&t);
                let wins = Arc::clone(&wins);
                std::thread::spawn(move || {
                    for k in 0..500u64 {
                        if t.insert(k, k + 1) {
                            wins.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
                        }
                    }
                })
            })
            .collect();
        for h in handles {
            h.join().unwrap();
        }
        assert_eq!(wins.load(std::sync::atomic::Ordering::SeqCst), 500);
        for k in 0..500u64 {
            assert_eq!(t.find(k), Some(k + 1), "key {k}");
        }
    }

    #[test]
    fn test_concurrent_same_key_contention() {
        // Insert/remove storms on one key: at the end, state must be
        // consistent with the net count of successful ops.
        let t: Arc<CacheHash<CachedMemEff<LinkVal>>> = Arc::new(CacheHash::new(8));
        let inserts = Arc::new(std::sync::atomic::AtomicU64::new(0));
        let removes = Arc::new(std::sync::atomic::AtomicU64::new(0));
        let handles: Vec<_> = (0..4)
            .map(|tix| {
                let t = Arc::clone(&t);
                let inserts = Arc::clone(&inserts);
                let removes = Arc::clone(&removes);
                std::thread::spawn(move || {
                    for i in 0..4_000u64 {
                        if (i + tix) % 2 == 0 {
                            if t.insert(42, i) {
                                inserts.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
                            }
                        } else if t.remove(42) {
                            removes.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
                        }
                    }
                })
            })
            .collect();
        for h in handles {
            h.join().unwrap();
        }
        let ins = inserts.load(std::sync::atomic::Ordering::SeqCst);
        let rem = removes.load(std::sync::atomic::Ordering::SeqCst);
        let present = t.find(42).is_some() as u64;
        assert_eq!(ins, rem + present, "ins={ins} rem={rem} present={present}");
    }
}
